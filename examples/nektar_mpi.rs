//! The Figure-5/6 workflow: profile the Nektar++ IncNSS MPI solver,
//! expose the load imbalance by switching off aggressive progress,
//! validate with a structured mesh, then relink BLAS.

// Uses the deprecated `profile` wrapper on purpose: the examples
// double as compatibility coverage for the pre-Session API.
#![allow(deprecated)]

use gapp::gapp::{profile, GappConfig};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::KernelConfig;
use gapp::util::Summary;
use gapp::workload::apps::{
    nektar, partition_weights, BlasImpl, MeshKind, MpiMode, NektarConfig,
};

fn show(label: &str, cfg: NektarConfig) -> anyhow::Result<()> {
    let app = nektar(7, cfg);
    let (report, _) = profile(
        &app,
        KernelConfig::default(),
        GappConfig {
            dt: 500_000,
            ..Default::default()
        },
        AnalysisEngine::auto(),
    )?;
    let cms: Vec<f64> = report.threads.iter().map(|t| t.cm_ms).collect();
    println!(
        "{label:<42} CMetric CV {:.3} | top {:?}",
        Summary::of(&cms).cv(),
        report.top_functions(2)
    );
    let series: Vec<String> = cms.iter().map(|c| format!("{c:.0}")).collect();
    println!("  per-rank CMetric (ms): [{}]", series.join(","));
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("partition weights (cylinder): {:?}\n",
        partition_weights(MeshKind::Cylinder, 16, 7)
            .iter()
            .map(|w| format!("{w:.2}"))
            .collect::<Vec<_>>());

    show(
        "OpenMPI aggressive (busy-wait) — masked",
        NektarConfig {
            mode: MpiMode::Aggressive,
            ..Default::default()
        },
    )?;
    show("MPICH ch3:sock (blocking) — imbalance visible", NektarConfig::default())?;
    show(
        "structured cuboid mesh, 8 ranks — balanced",
        NektarConfig {
            mesh: MeshKind::Cuboid,
            ranks: 8,
            ..Default::default()
        },
    )?;
    show(
        "OpenBLAS relink — bottleneck moves to Vmath::Dot2",
        NektarConfig {
            blas: BlasImpl::OpenBlas,
            ..Default::default()
        },
    )?;
    Ok(())
}
