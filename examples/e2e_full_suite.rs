//! END-TO-END DRIVER: runs the full reproduction on real (synthetic)
//! workloads and regenerates every table and figure of the paper,
//! writing the results block that EXPERIMENTS.md records.
//!
//! This is the one-command proof that all layers compose: 13 workloads →
//! simulated kernel → eBPF-style probes → ring buffer → batched XLA
//! analysis (AOT Pallas kernels via PJRT when artifacts are present) →
//! merge/rank/symbolize → paper tables.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_full_suite
//! ```

use std::io::Write;
use std::time::Instant;

use gapp::experiments::{
    baselines_cmp, dedup_alloc, fig3, fig4, fig5, fig6, fig7, overhead, sensitivity,
    table2, EngineKind,
};

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    let engine = EngineKind::Auto;
    let threads = 64;
    let seed = 7;
    let mut out = String::new();

    let backend = engine.make()?.backend_name();
    out.push_str(&format!(
        "# GAPP reproduction — end-to-end suite (backend: {backend}, threads: {threads}, seed: {seed})\n\n",
    ));

    macro_rules! section {
        ($title:expr, $body:expr) => {{
            let t = Instant::now();
            let text = $body;
            println!("{text}");
            out.push_str(&text);
            out.push_str(&format!("\n[{} took {:.2} s]\n\n", $title, t.elapsed().as_secs_f64()));
        }};
    }

    section!("table2", table2::render(&table2::run(engine, threads, seed)?));
    section!("fig3", fig3::render(&fig3::run(engine, 32, seed)?));
    section!("fig4", fig4::render(&fig4::run(engine, seed)?));
    section!("fig5", fig5::render(&fig5::run(engine, seed)?));
    section!("fig6", fig6::render(&fig6::run(engine, seed)?));
    section!("fig7", fig7::render(&fig7::run(engine, seed)?));
    section!("dedup-alloc", dedup_alloc::render(&dedup_alloc::run(engine, seed)?));
    section!("sensitivity", sensitivity::render(&sensitivity::run(engine, seed)?));
    section!("overhead", overhead::render(&overhead::run(engine, threads, seed)?));
    section!("baselines", baselines_cmp::render(&baselines_cmp::run(engine, seed)?));

    out.push_str(&format!(
        "total suite time: {:.1} s (host)\n",
        t0.elapsed().as_secs_f64()
    ));
    let path = "e2e_results.txt";
    std::fs::File::create(path)?.write_all(out.as_bytes())?;
    println!("\nwrote {path} ({} bytes) in {:.1} s", out.len(), t0.elapsed().as_secs_f64());
    Ok(())
}
