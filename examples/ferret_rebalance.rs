//! The Figure-4 workflow: use per-thread CMetric to rebalance Ferret's
//! pipeline stages until the profile flattens (paper: 2-1-18-39, ~50%
//! faster than 15-15-15-15).

// Uses the deprecated `profile` wrapper on purpose: the examples
// double as compatibility coverage for the pre-Session API.
#![allow(deprecated)]

use gapp::gapp::{profile, GappConfig};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::KernelConfig;
use gapp::util::Summary;
use gapp::workload::apps::{ferret, FerretConfig};

fn show(label: &str, cfg: FerretConfig) -> anyhow::Result<(u64, f64)> {
    let app = ferret(31, cfg);
    let gcfg = GappConfig {
        dt: 500_000,
        ..Default::default()
    };
    let (report, _) = profile(&app, KernelConfig::default(), gcfg, AnalysisEngine::auto())?;
    let cms: Vec<f64> = report.threads.iter().map(|t| t.cm_ms).collect();
    let s = Summary::of(&cms);
    println!(
        "{label:<24} runtime {:>8.2} ms | CMetric mean {:>7.2} ms cv {:.3} | top {:?}",
        report.runtime_ns as f64 / 1e6,
        s.mean,
        s.cv(),
        report.top_functions(2)
    );
    // The Figure-4 curve: CMetric per thread, in spawn order.
    let series: Vec<String> = report
        .threads
        .iter()
        .map(|t| format!("{:.0}", t.cm_ms))
        .collect();
    println!("  per-thread CMetric (ms): [{}]", series.join(","));
    Ok((report.runtime_ns, s.cv()))
}

fn main() -> anyhow::Result<()> {
    let (t0, cv0) = show("default 15-15-15-15", FerretConfig::default())?;
    let (t1, _) = show("[10]'s 20-1-22-21", FerretConfig::with_alloc(20, 1, 22, 21))?;
    let (t2, cv2) = show("balanced 2-1-18-39", FerretConfig::with_alloc(2, 1, 18, 39))?;
    println!(
        "\nimprovement: balanced {:.1}% (paper ~50%), [10] {:.1}% (paper ~23%); CMetric CV {:.3} -> {:.3}",
        100.0 * (t0 as f64 - t2 as f64) / t0 as f64,
        100.0 * (t0 as f64 - t1 as f64) / t0 as f64,
        cv0,
        cv2
    );
    Ok(())
}
