//! The Figure-7 workflow: profile MySQL under OLTP_RW, find fil_flush
//! and the sync_array spin path, tune innodb_buffer_pool_size then
//! INNODB_SPIN_WAIT_DELAY, and verify the order matters.

// Uses the deprecated `profile` wrapper on purpose: the examples
// double as compatibility coverage for the pre-Session API.
#![allow(deprecated)]

use gapp::gapp::{profile, GappConfig};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::KernelConfig;
use gapp::workload::apps::{mysql, run_oltp, MysqlConfig};

fn bench(label: &str, cfg: MysqlConfig) -> f64 {
    let o = run_oltp(32, 41, cfg);
    println!(
        "{label:<34} {:>9.0} tps  avg latency {:>7.2} ms",
        o.tps,
        o.avg_latency_ns / 1e6
    );
    o.tps
}

fn main() -> anyhow::Result<()> {
    println!("--- profile MySQL 5.7 under sysbench OLTP_Read_Write ---");
    let app = mysql(32, 41, MysqlConfig::default());
    let (report, _) = profile(
        &app,
        KernelConfig::default(),
        GappConfig {
            dt: 300_000,
            ..Default::default()
        },
        AnalysisEngine::auto(),
    )?;
    println!("top critical functions: {:?}", report.top_functions(5));
    for b in report.bottlenecks.iter().take(2) {
        println!("critical path: {}", b.call_path.join(" -> "));
    }

    println!("\n--- tuning ladder (paper: +19% then +34% cumulative) ---");
    let base = bench("default (8GB pool, spin 6)", MysqlConfig::default());
    let buf = bench(
        "innodb_buffer_pool_size = 90GB",
        MysqlConfig {
            buffer_pool_gb: 90,
            ..Default::default()
        },
    );
    let both = bench(
        "+ INNODB_SPIN_WAIT_DELAY = 30",
        MysqlConfig {
            buffer_pool_gb: 90,
            spin_wait_delay: 30,
            ..Default::default()
        },
    );
    let spin_first = bench(
        "spin 30 only (wrong order)",
        MysqlConfig {
            spin_wait_delay: 30,
            ..Default::default()
        },
    );
    println!(
        "\nbuffer: {:+.1}% | cumulative: {:+.1}% | spin-first: {:+.1}% (≈0 — fix bottlenecks in criticality order)",
        100.0 * (buf - base) / base,
        100.0 * (both - base) / base,
        100.0 * (spin_first - base) / base
    );
    Ok(())
}
