//! The Figure-3 workflow as a user would live it: profile bodytrack,
//! see OutputBMP + RecvCmd at the top, apply the writer-thread fix, and
//! re-measure.

// Uses the deprecated `profile` wrapper on purpose: the examples
// double as compatibility coverage for the pre-Session API.
#![allow(deprecated)]

use gapp::gapp::{profile, run_unprofiled, GappConfig};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::KernelConfig;
use gapp::workload::apps::{bodytrack, BodytrackConfig};

fn main() -> anyhow::Result<()> {
    let threads = 32;
    let seed = 21;
    let gcfg = GappConfig {
        dt: 200_000,
        ..Default::default()
    };

    println!("--- step 1: profile the stock binary ---");
    let app = bodytrack(threads, seed, BodytrackConfig::default());
    let (report, _) = profile(
        &app,
        KernelConfig::default(),
        gcfg.clone(),
        AnalysisEngine::auto(),
    )?;
    println!("{report}");
    println!("top functions: {:?}\n", report.top_functions(4));

    println!("--- step 2: confirm by removing OutputBMP (paper: −45% RecvCmd samples) ---");
    let app = bodytrack(threads, seed, BodytrackConfig { skip_output: true, ..Default::default() });
    let (confirm, _) = profile(&app, KernelConfig::default(), gcfg, AnalysisEngine::auto())?;
    let before = report.samples_of("condition_variable::RecvCmd");
    let after = confirm.samples_of("condition_variable::RecvCmd");
    println!(
        "RecvCmd samples {before} -> {after} ({:.0}% reduction)\n",
        100.0 * (before.saturating_sub(after)) as f64 / before.max(1) as f64
    );

    println!("--- step 3: apply the writerThread fix and re-measure ---");
    let (base_ns, _) = run_unprofiled(
        &bodytrack(threads, seed, BodytrackConfig::default()),
        KernelConfig::default(),
    )?;
    let (fixed_ns, _) = run_unprofiled(
        &bodytrack(threads, seed, BodytrackConfig { offload_writer: true, ..Default::default() }),
        KernelConfig::default(),
    )?;
    println!(
        "runtime {:.1} ms -> {:.1} ms: {:.1}% improvement (paper: 22%)",
        base_ns as f64 / 1e6,
        fixed_ns as f64 / 1e6,
        100.0 * (base_ns - fixed_ns) as f64 / base_ns as f64
    );
    Ok(())
}
