//! Quickstart: profile one synthetic application with GAPP through the
//! library-first `Session` API and print the ranked bottleneck report.
//!
//! ```sh
//! cargo run --release --example quickstart            # native backend
//! make artifacts && cargo run --release --example quickstart  # XLA backend
//! ```

use gapp::gapp::sink::HumanSink;
use gapp::gapp::{GappConfig, Session};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::KernelConfig;
use gapp::workload::apps;

fn main() -> anyhow::Result<()> {
    // A 62-thread Dedup pipeline with the paper's 1-20-20-20-1 layout.
    let app = apps::dedup(7, apps::DedupConfig::default());

    // AnalysisEngine::auto() uses the AOT-compiled XLA artifacts when
    // `make artifacts` has been run, else the native fallback.
    let engine = AnalysisEngine::auto();
    println!("analysis backend: {}", engine.backend_name());

    // The sink renders the report as it is produced; swap it for a
    // `JsonSink`/`JsonlSink` (or tee several) for machine output.
    let out = Session::builder(engine)
        .kernel(KernelConfig::default()) // 64 simulated CPUs
        .config(GappConfig::default()) // Nmin = n/2, Δt = 3 ms
        .app(&app)
        .sink(HumanSink::new(std::io::stdout()))
        .run()?;

    println!(
        "kernel: {} context switches, {} wakeups, {} probe-ns charged",
        out.kernel.stats.switches, out.kernel.stats.wakeups, out.kernel.stats.probe_ns
    );
    println!("\ntop critical functions (paper Table 2: deflate_slow):");
    for (f, n) in out.report.top_functions(5) {
        println!("  {n:>6}  {f}");
    }

    // Crash-safe sessions: `.checkpoint(path)` snapshots the session
    // state atomically at every window close (live mode) or at start
    // (batch); after a crash, an identically-configured session with
    // `.restore(path)` replays the completed epochs, verifies them
    // against the snapshot, and finishes with a byte-identical report.
    // The CLI spells it `gapp live --checkpoint FILE` / `--resume FILE`
    // (plus `--on-overflow degrade` to absorb ring overflow instead of
    // shedding records). For example:
    //
    //     Session::builder(AnalysisEngine::auto())
    //         .app(&app)
    //         .window_us(5_000)
    //         .checkpoint("/var/tmp/gapp.ckpt")
    //         .sink(HumanSink::new(std::io::stdout()))
    //         .run()?;                       // …crash here…
    //
    //     Session::builder(AnalysisEngine::auto())
    //         .app(&app)
    //         .window_us(5_000)
    //         .restore("/var/tmp/gapp.ckpt") // …resume, finish identically
    //         .sink(HumanSink::new(std::io::stdout()))
    //         .run()?;

    // Parallel lane workers: with the default `--merge tree` and two or
    // more ring shards, `.lane_threads(N)` (CLI: `--lane-threads N`)
    // folds each shard's window state on one of N scoped OS threads,
    // with a single barrier at window close for the pairwise merge
    // tree. The report is byte-identical at every thread count — the
    // knob buys wall-clock on wide runs, never different output:
    //
    //     Session::builder(AnalysisEngine::auto())
    //         .app(&app)
    //         .window_us(5_000)
    //         .shards(4)
    //         .lane_threads(4)
    //         .sink(HumanSink::new(std::io::stdout()))
    //         .run()?;

    // Scored benchmarks: the declarative scenario harness compiles a
    // `scenarios/*.json` spec (injected pathologies with known classes,
    // optional background apps and open-loop arrivals) into a session
    // and grades `classify()` against the injected ground truth:
    //
    //     gapp scenario run scenarios/lock_convoy.json        # one case
    //     gapp scenario matrix scenarios/mixed.json           # seeds × threads
    //
    // emits the usual report plus a per-class precision/recall/F1
    // scorecard (an additive `scorecard` event in json/jsonl output).
    // From the library:
    //
    //     let sc = gapp::scenario::Scenario::load("scenarios/lock_convoy.json")?;
    //     let case = gapp::scenario::Case { index: 0, seed: sc.seed, threads: None };
    //     let out = gapp::scenario::run_case(&sc, &case, AnalysisEngine::auto(), None)?;
    //     print!("{}", gapp::gapp::sink::human::render_scorecard(&out.scorecard));
    Ok(())
}
