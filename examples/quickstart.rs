//! Quickstart: profile one synthetic application with GAPP through the
//! library-first `Session` API and print the ranked bottleneck report.
//!
//! ```sh
//! cargo run --release --example quickstart            # native backend
//! make artifacts && cargo run --release --example quickstart  # XLA backend
//! ```

use gapp::gapp::sink::HumanSink;
use gapp::gapp::{GappConfig, Session};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::KernelConfig;
use gapp::workload::apps;

fn main() -> anyhow::Result<()> {
    // A 62-thread Dedup pipeline with the paper's 1-20-20-20-1 layout.
    let app = apps::dedup(7, apps::DedupConfig::default());

    // AnalysisEngine::auto() uses the AOT-compiled XLA artifacts when
    // `make artifacts` has been run, else the native fallback.
    let engine = AnalysisEngine::auto();
    println!("analysis backend: {}", engine.backend_name());

    // The sink renders the report as it is produced; swap it for a
    // `JsonSink`/`JsonlSink` (or tee several) for machine output.
    let out = Session::builder(engine)
        .kernel(KernelConfig::default()) // 64 simulated CPUs
        .config(GappConfig::default()) // Nmin = n/2, Δt = 3 ms
        .app(&app)
        .sink(HumanSink::new(std::io::stdout()))
        .run()?;

    println!(
        "kernel: {} context switches, {} wakeups, {} probe-ns charged",
        out.kernel.stats.switches, out.kernel.stats.wakeups, out.kernel.stats.probe_ns
    );
    println!("\ntop critical functions (paper Table 2: deflate_slow):");
    for (f, n) in out.report.top_functions(5) {
        println!("  {n:>6}  {f}");
    }
    Ok(())
}
