"""Hypothesis sweeps over the Pallas kernels' shape/dtype/content space.

The session contract: hypothesis sweeps the kernel's shapes/dtypes and
assert_allclose against ref.py. Shapes are drawn so B is a multiple of the
block size (the runtime zero-pads to guarantee this).
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.cmetric import cmetric_pallas
from compile.kernels.rank import rank_pallas
from compile.kernels import ref

# interpret-mode Pallas is slow; keep example counts modest but meaningful.
_SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def batches(draw):
    b_blk = draw(st.sampled_from([32, 64, 128]))
    nblk = draw(st.integers(1, 4))
    b = b_blk * nblk
    t = draw(st.sampled_from([8, 64, 128]))
    density = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    a = (rng.random((b, t)) < density).astype(np.float32)
    # Durations spanning ns..ms magnitudes, incl. zeros.
    dur = rng.choice(
        [0.0, 1.0, 37.0, 1e3, 3e6, 1e7], size=(b,)
    ).astype(np.float32) + rng.random(b).astype(np.float32)
    return a, dur, b_blk


@given(batches())
@settings(**_SETTINGS)
def test_cmetric_property_matches_ref(batch):
    a, dur, b_blk = batch
    cm, wall, gcm = cmetric_pallas(jnp.asarray(a), jnp.asarray(dur), b_blk=b_blk)
    cm_r, wall_r, gcm_r = ref.cmetric_ref(jnp.asarray(a), jnp.asarray(dur))
    np.testing.assert_allclose(cm, cm_r, rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(wall, wall_r, rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(gcm, gcm_r, rtol=1e-4, atol=1e-2)


@given(batches())
@settings(**_SETTINGS)
def test_cmetric_property_conservation(batch):
    """sum_j cm_j == busy wall time; 0 <= cm_j <= wall_j; gcm <= busy."""
    a, dur, b_blk = batch
    cm, wall, gcm = cmetric_pallas(jnp.asarray(a), jnp.asarray(dur), b_blk=b_blk)
    cm = np.asarray(cm)
    wall = np.asarray(wall)
    n = a.sum(axis=1)
    busy = float(dur[n > 0].sum())
    np.testing.assert_allclose(cm.sum(), busy, rtol=1e-4, atol=1e-2)
    assert (cm >= -1e-3).all()
    assert (cm <= wall + 1e-2).all()          # n_i >= 1 while active
    assert float(gcm) <= busy * (1 + 1e-5) + 1e-2


@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 100, 512, 1024]),
       st.sampled_from([1, 4, 16]))
@settings(**_SETTINGS)
def test_rank_property_matches_ref(seed, p, k):
    if k > p:
        return
    rng = np.random.default_rng(seed)
    scores = rng.gamma(1.0, 1e5, size=(p,)).astype(np.float32)
    vals, idx = rank_pallas(jnp.asarray(scores), k=k)
    vals_r, _ = ref.rank_ref(jnp.asarray(scores), k)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vals_r), rtol=1e-6)
    assert (scores[np.asarray(idx)] == np.asarray(vals)).all()
