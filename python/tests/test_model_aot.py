"""Layer-2 model contract + AOT lowering smoke tests."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile import aot


def _batch(b=256, t=128, seed=0):
    rng = np.random.default_rng(seed)
    a = (rng.random((b, t)) < 0.1).astype(np.float32)
    dur = rng.gamma(2.0, 1e6, size=(b,)).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(dur)


def test_analyze_shapes_and_dtypes():
    a, dur = _batch()
    cm, wall, tav, gcm = model.analyze(a, dur)
    assert cm.shape == (128,) and wall.shape == (128,)
    assert tav.shape == (128,) and gcm.shape == (1,)
    for x in (cm, wall, tav, gcm):
        assert x.dtype == jnp.float32


def test_analyze_matches_jnp_twin():
    a, dur = _batch(seed=3)
    got = model.analyze(a, dur)
    want = model.analyze_jnp(a, dur)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-2)


def test_threads_av_bounds():
    """threads_av in [1, T] wherever the slot accumulated CMetric."""
    a, dur = _batch(seed=5)
    cm, _, tav, _ = model.analyze(a, dur)
    tav = np.asarray(tav)
    mask = np.asarray(cm) > 0
    assert (tav[mask] >= 1.0 - 1e-4).all()
    assert (tav[mask] <= 128.0 + 1e-4).all()
    assert (tav[~mask] == 0.0).all()


def test_rank_matches_topk():
    rng = np.random.default_rng(9)
    scores = jnp.asarray(rng.random(1024).astype(np.float32))
    vals, idx = model.rank(scores, k=16)
    vals_r, _ = model.rank_jnp(scores, k=16)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(vals_r), rtol=1e-6)
    assert (np.asarray(scores)[np.asarray(idx)] == np.asarray(vals)).all()


@pytest.mark.parametrize("b,t,b_blk", [(256, 128, 128)])
def test_aot_analyze_lowers_to_hlo_text(b, t, b_blk):
    text = aot.lower_analyze(b, t, b_blk)
    assert text.startswith("HloModule")
    assert f"f32[{b},{t}]" in text
    # Tuple-return convention the Rust loader unwraps.
    assert "ROOT" in text


def test_aot_rank_lowers_to_hlo_text():
    text = aot.lower_rank(64, 4)
    assert text.startswith("HloModule")
    assert "f32[64]" in text


def test_aot_partial_batch_padding_exact():
    """Zero-padding the tail of a batch is exactly a no-op in analyze()."""
    a, dur = _batch(b=1024, seed=11)
    a = a.at[700:].set(0.0)
    dur = dur.at[700:].set(0.0)
    full = model.analyze(a, dur)
    head = model.analyze_jnp(a[:700].reshape(700, 128), dur[:700])
    for g, w in zip(full, head):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-2)
