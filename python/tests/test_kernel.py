"""Kernel-vs-oracle correctness: the CORE numeric signal for Layer 1.

Every test compares the Pallas kernels (interpret mode) against the
pure-jnp oracle in ``compile.kernels.ref`` with assert_allclose.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels.cmetric import cmetric_pallas, vmem_bytes
from compile.kernels.rank import rank_pallas
from compile.kernels import ref


def _random_batch(rng, b, t, density=0.1, dur_scale=1e6):
    """Random activity matrix + durations shaped like real drain batches."""
    a = (rng.random((b, t)) < density).astype(np.float32)
    dur = rng.gamma(2.0, dur_scale, size=(b,)).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(dur)


# ---------------------------------------------------------------------------
# cmetric kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,b_blk", [
    (128, 128, 128),
    (256, 128, 128),
    (256, 128, 256),
    (512, 64, 128),
    (1024, 128, 256),
    (256, 8, 64),
])
def test_cmetric_matches_ref(b, t, b_blk):
    rng = np.random.default_rng(b * 31 + t)
    a, dur = _random_batch(rng, b, t)
    cm, wall, gcm = cmetric_pallas(a, dur, b_blk=b_blk)
    cm_r, wall_r, gcm_r = ref.cmetric_ref(a, dur)
    np.testing.assert_allclose(cm, cm_r, rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(wall, wall_r, rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(gcm, gcm_r, rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("density", [0.0, 0.01, 0.5, 1.0])
def test_cmetric_density_extremes(density):
    rng = np.random.default_rng(7)
    a, dur = _random_batch(rng, 256, 128, density=density)
    cm, wall, gcm = cmetric_pallas(a, dur)
    cm_r, wall_r, gcm_r = ref.cmetric_ref(a, dur)
    np.testing.assert_allclose(cm, cm_r, rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(wall, wall_r, rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(gcm, gcm_r, rtol=1e-5, atol=1e-2)


def test_cmetric_zero_batch_contributes_nothing():
    """Zero-padded rows (the runtime's partial-batch trick) are exact no-ops."""
    rng = np.random.default_rng(3)
    a, dur = _random_batch(rng, 256, 128)
    # Zero out the second half of the batch entirely.
    a = a.at[128:].set(0.0)
    dur = dur.at[128:].set(0.0)
    cm, wall, gcm = cmetric_pallas(a, dur)
    cm_h, wall_h, gcm_h = ref.cmetric_ref(a[:128], dur[:128])
    np.testing.assert_allclose(cm, cm_h, rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(wall, wall_h, rtol=1e-5, atol=1e-2)
    np.testing.assert_allclose(gcm, gcm_h, rtol=1e-5, atol=1e-2)


def test_cmetric_conservation():
    """sum_j cm_j == sum_i T_i over intervals with >= 1 active thread.

    This is the paper's invariant: each interval's duration is split
    evenly among its active threads, so summing per-thread CMetric
    recovers total busy wall time (Amdahl bookkeeping).
    """
    rng = np.random.default_rng(11)
    a, dur = _random_batch(rng, 512, 128, density=0.05)
    cm, _, gcm = cmetric_pallas(a, dur, b_blk=128)
    n = np.asarray(a).sum(axis=1)
    busy = float(np.asarray(dur)[n > 0].sum())
    np.testing.assert_allclose(float(jnp.sum(cm)), busy, rtol=1e-4)
    # And global_cm is the serial-equivalent time: sum of T_i/n_i.
    contrib = np.where(n > 0, np.asarray(dur) / np.maximum(n, 1), 0.0)
    np.testing.assert_allclose(float(gcm), contrib.sum(), rtol=1e-4)


def test_cmetric_single_thread_equals_wall():
    """With exactly one active thread everywhere, cm == wall (n_i = 1)."""
    b, t = 256, 128
    a = np.zeros((b, t), np.float32)
    a[:, 5] = 1.0
    dur = np.linspace(1.0, 100.0, b).astype(np.float32)
    cm, wall, gcm = cmetric_pallas(jnp.asarray(a), jnp.asarray(dur))
    np.testing.assert_allclose(cm, wall, rtol=1e-6)
    np.testing.assert_allclose(float(cm[5]), dur.sum(), rtol=1e-5)
    np.testing.assert_allclose(float(gcm), dur.sum(), rtol=1e-5)


def test_cmetric_figure1_worked_example():
    """The paper's Figure-1 trace: Thread3's slice spans T2 (n=2), T3 (n=3).

    Interval layout (rows) with threads 1..4 in slots 0..3:
      T1: {1}        T2: {3,4}      T3: {2,3,4}
      T4: {2,4}      T5: {2}        T6: {1,2}
    """
    t = 128
    rows = [
        ([0], 10.0),
        ([2, 3], 8.0),
        ([1, 2, 3], 9.0),
        ([1, 3], 6.0),
        ([1], 4.0),
        ([0, 1], 5.0),
    ]
    b = 128
    a = np.zeros((b, t), np.float32)
    dur = np.zeros((b,), np.float32)
    for i, (slots, d) in enumerate(rows):
        a[i, slots] = 1.0
        dur[i] = d
    cm, wall, _ = cmetric_pallas(jnp.asarray(a), jnp.asarray(dur), b_blk=128)
    # Thread3 (slot 2): T2/2 + T3/3 = 4 + 3 = 7
    np.testing.assert_allclose(float(cm[2]), 7.0, rtol=1e-6)
    # Thread2 (slot 1): 9/3 + 6/2 + 4/1 + 5/2 = 3+3+4+2.5 = 12.5
    np.testing.assert_allclose(float(cm[1]), 12.5, rtol=1e-6)
    # threads_av for Thread3 = wall/cm = 17/7
    np.testing.assert_allclose(float(wall[2]) / float(cm[2]), 17.0 / 7.0,
                               rtol=1e-6)


def test_vmem_budget_under_16mb():
    for b_blk in (128, 256, 512, 1024):
        assert vmem_bytes(b_blk, 128) < 16 * 2**20


# ---------------------------------------------------------------------------
# rank kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,k", [(64, 4), (1024, 16), (4096, 32), (128, 1)])
def test_rank_matches_ref(p, k):
    rng = np.random.default_rng(p + k)
    scores = jnp.asarray(rng.gamma(1.5, 1e6, size=(p,)).astype(np.float32))
    vals, idx = rank_pallas(scores, k=k)
    vals_r, idx_r = ref.rank_ref(scores, k)
    np.testing.assert_allclose(vals, vals_r, rtol=1e-6)
    # Indices must point at the same values even under ties.
    np.testing.assert_allclose(np.asarray(scores)[np.asarray(idx)],
                               np.asarray(vals_r), rtol=1e-6)


def test_rank_descending_and_valid_indices():
    rng = np.random.default_rng(5)
    scores = jnp.asarray(rng.random(1024).astype(np.float32))
    vals, idx = rank_pallas(scores, k=16)
    v = np.asarray(vals)
    assert (np.diff(v) <= 1e-9).all()
    assert ((np.asarray(idx) >= 0) & (np.asarray(idx) < 1024)).all()
    assert len(set(np.asarray(idx).tolist())) == 16  # distinct winners


def test_rank_ties_stable_first_index():
    scores = np.zeros(256, np.float32)
    scores[[10, 20, 30]] = 5.0
    vals, idx = rank_pallas(jnp.asarray(scores), k=3)
    assert np.asarray(idx).tolist() == [10, 20, 30]
    np.testing.assert_allclose(np.asarray(vals), [5.0, 5.0, 5.0])
