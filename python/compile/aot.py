"""AOT compile path: lower the Layer-2 analysis graphs to HLO *text*.

HLO text — NOT ``lowered.compile().serialize()`` and NOT a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos
with 64-bit instruction ids which the Rust side's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``). The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written (all consumed by ``rust/src/runtime/engine.rs``):

  artifacts/cmetric_b{B}_t{T}.hlo.txt   analyze() for batch B, slots T
  artifacts/rank_p{P}_k{K}.hlo.txt      rank() for P paths, top-K
  artifacts/MANIFEST.txt                one line per artifact: name shape info

Run once via ``make artifacts``; the Makefile skips the rebuild when inputs
are unchanged. Python never runs on the profiling path.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Variants compiled by default. The runtime picks by batch size; the
# multiple batch sizes exist for the §Perf batching sweep.
ANALYZE_VARIANTS = [
    # (B, T, b_blk)
    (256, 128, 128),
    (1024, 128, 256),
    (4096, 128, 256),
]
RANK_VARIANTS = [
    # (P, K)
    (1024, 16),
    (4096, 32),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_analyze(b: int, t: int, b_blk: int) -> str:
    fn = functools.partial(model.analyze, b_blk=b_blk)
    a_spec = jax.ShapeDtypeStruct((b, t), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((b,), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(a_spec, t_spec))


def lower_rank(p: int, k: int) -> str:
    fn = functools.partial(model.rank, k=k)
    s_spec = jax.ShapeDtypeStruct((p,), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(s_spec))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the primary artifact; siblings are "
                         "written next to it")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    for b, t, b_blk in ANALYZE_VARIANTS:
        name = f"cmetric_b{b}_t{t}.hlo.txt"
        text = lower_analyze(b, t, b_blk)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest.append(f"analyze {name} B={b} T={t} b_blk={b_blk}")
        print(f"wrote {name}: {len(text)} chars")

    for p, k in RANK_VARIANTS:
        name = f"rank_p{p}_k{k}.hlo.txt"
        text = lower_rank(p, k)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest.append(f"rank {name} P={p} K={k}")
        print(f"wrote {name}: {len(text)} chars")

    # The Makefile's primary target: alias of the default analyze variant.
    default = f"cmetric_b{ANALYZE_VARIANTS[1][0]}_t{ANALYZE_VARIANTS[1][1]}.hlo.txt"
    with open(os.path.join(out_dir, default)) as f:
        primary = f.read()
    with open(args.out, "w") as f:
        f.write(primary)
    manifest.append(f"primary model.hlo.txt -> {default}")

    with open(os.path.join(out_dir, "MANIFEST.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote MANIFEST.txt ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
