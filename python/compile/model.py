"""Layer-2 JAX analysis graph for GAPP's user-space engine.

Two exported computations, both AOT-lowered to HLO text by ``aot.py`` and
executed from the Rust coordinator via PJRT (Python never runs on the
profiling path):

  ``analyze(A, t)`` — the batched CMetric step. Calls the Layer-1 Pallas
    kernel for the fused ``A^T(t/n)`` / ``A^T t`` reductions and derives
    ``threads_av`` (the paper's §4.2 trigger quantity) on top.

  ``rank(scores)`` — top-K bottleneck selection over merged call-path
    CMetric totals (paper §4.4), via the Layer-1 iterative-max kernel.

Shapes are static per artifact (one compiled executable per variant, as the
runtime expects); the Rust side zero-pads the final partial batch, which is
exact because empty intervals (all-zero rows, t=0) contribute nothing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.cmetric import cmetric_pallas
from compile.kernels.rank import rank_pallas


def analyze(a: jnp.ndarray, t: jnp.ndarray, *, b_blk: int = 256):
    """Batched CMetric analysis over one ring-buffer drain.

    Args:
      a: ``[B, T]`` float32 activity matrix (interval x thread-slot).
      t: ``[B]`` float32 interval durations (ns).

    Returns a 4-tuple (the runtime indexes by position):
      cm        ``[T]`` per-thread-slot CMetric delta,
      wall      ``[T]`` per-thread-slot active wall time,
      threads_av``[T]`` time-weighted harmonic mean of the active count
                        while each slot was active (0 where cm == 0),
      global_cm ``[1]``  batch global_cm delta.
    """
    cm, wall, gcm = cmetric_pallas(a, t, b_blk=b_blk)
    threads_av = jnp.where(cm > 0, wall / jnp.maximum(cm, 1e-30), 0.0)
    return cm, wall, threads_av, gcm.reshape(1)


def rank(scores: jnp.ndarray, *, k: int = 16):
    """Top-K call paths by total CMetric. Returns (values [k], idx [k])."""
    return rank_pallas(scores, k=k)


# ---------------------------------------------------------------------------
# Pure-jnp twins — used by the pytest suite to confirm the Pallas kernels
# lower to the same numbers inside the jitted graph, and handy for ad-hoc
# sanity checks when Pallas interpret mode is too slow.
# ---------------------------------------------------------------------------

def analyze_jnp(a: jnp.ndarray, t: jnp.ndarray):
    """analyze() without Pallas, same contract."""
    from compile.kernels.ref import cmetric_ref

    cm, wall, gcm = cmetric_ref(a, t)
    threads_av = jnp.where(cm > 0, wall / jnp.maximum(cm, 1e-30), 0.0)
    return cm, wall, threads_av, gcm.reshape(1)


def rank_jnp(scores: jnp.ndarray, *, k: int = 16):
    """rank() via lax.top_k (reference)."""
    return jax.lax.top_k(scores, k)
