"""Pure-jnp oracle for the CMetric aggregation and ranking kernels.

This is the correctness reference for the Pallas kernels in this package.
It implements the batched form of GAPP's CMetric bookkeeping (paper §2.1,
§4.1):

  * ``n_i``      — number of active application threads in switching
                   interval ``i`` (row-sum of the activity matrix).
  * ``c_i``      — the interval's CMetric contribution ``t_i / max(n_i, 1)``.
  * ``cm_j``     — per-thread CMetric delta ``sum_i A[i, j] * c_i``
                   (what ``cm_hash[pid] += global_cm - local_cm``
                   accumulates in the paper).
  * ``wall_j``   — per-thread active wall time ``sum_i A[i, j] * t_i``
                   (used to derive ``threads_av = wall / cm``).
  * ``global_cm``— ``sum_i [n_i > 0] * c_i`` (the paper's ``global_cm``
                   counter over the batch).

Everything is float32; intervals with no active thread contribute nothing.
"""

from __future__ import annotations

import jax.numpy as jnp


def cmetric_ref(a: jnp.ndarray, t: jnp.ndarray):
    """Reference CMetric aggregation.

    Args:
      a: activity matrix, shape ``[B, T]``, entries in {0, 1} (float).
      t: interval durations, shape ``[B]`` or ``[B, 1]`` (float, ns scaled).

    Returns:
      ``(cm, wall, global_cm)`` with shapes ``[T]``, ``[T]``, ``[]``.
    """
    a = a.astype(jnp.float32)
    t = t.reshape(-1).astype(jnp.float32)
    n = jnp.sum(a, axis=1)                      # [B]
    c = t / jnp.maximum(n, 1.0)                  # [B]
    active = (n > 0).astype(jnp.float32)         # [B]
    cm = a.T @ c                                 # [T]
    wall = a.T @ t                               # [T]
    global_cm = jnp.sum(active * c)              # []
    return cm, wall, global_cm


def threads_av_ref(cm: jnp.ndarray, wall: jnp.ndarray) -> jnp.ndarray:
    """Time-weighted harmonic mean of the active-thread count per thread.

    ``threads_av_j = wall_j / cm_j`` — exactly the quantity derivable from
    the paper's ``global_cm``/``local_cm`` counters at timeslice end
    (§4.2). Threads with no accumulated CMetric report 0.
    """
    return jnp.where(cm > 0, wall / jnp.maximum(cm, 1e-30), 0.0)


def rank_ref(scores: jnp.ndarray, k: int):
    """Reference top-K ranking of merged call-path CMetric scores (§4.4)."""
    order = jnp.argsort(-scores)
    idx = order[:k]
    return scores[idx], idx
