"""Layer-1 kernel: top-K selection over merged call-path CMetric scores.

The paper's user-space probe (§4.4) ends with "the entries with the top N
total CMetrics are then taken as the bottlenecks". The score vector is
small (one entry per distinct call path), so the interesting part is not
the matmul but doing the selection without a full sort and without leaving
the device. We use a Pallas kernel that performs iterative
max-extract-mask over a padded score block — K passes over a VMEM-resident
vector — which is exact and avoids materializing an argsort of the whole
buffer.

For very large P one would tile this (per-tile top-K then merge); P here
is <= 4096 call paths, one VMEM block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_P = 1024
DEFAULT_K = 16

_NEG = -3.0e38  # sentinel below any real score (scores are >= 0 ns)


def _rank_kernel(k: int, s_ref, vals_ref, idx_ref):
    """Iterative max-extract: K rounds over a VMEM-resident score row."""
    s = s_ref[...]                                    # [1, P]
    p = s.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, p), 1)

    def body(j, carry):
        s_cur, vals, idx = carry
        m = jnp.max(s_cur)
        # argmax via masked iota (first occurrence wins => stable ties).
        hit = s_cur >= m
        am = jnp.min(jnp.where(hit, iota, jnp.int32(2**30)))
        vals = vals.at[0, j].set(m)
        idx = idx.at[0, j].set(am)
        s_cur = jnp.where(iota == am, jnp.float32(_NEG), s_cur)
        return s_cur, vals, idx

    vals0 = jnp.full((1, k), jnp.float32(_NEG))
    idx0 = jnp.zeros((1, k), jnp.int32)
    _, vals, idx = jax.lax.fori_loop(0, k, body, (s, vals0, idx0))
    vals_ref[...] = vals
    idx_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("k",))
def rank_pallas(scores: jnp.ndarray, *, k: int = DEFAULT_K):
    """Top-K (values, indices) of a score vector, descending, stable ties.

    Args:
      scores: ``[P]`` float32 merged call-path CMetric totals.
      k: number of bottleneck candidates to emit (paper's N).

    Returns:
      ``(values [k], indices [k])``.
    """
    p = scores.shape[0]
    s2 = scores.reshape(1, p).astype(jnp.float32)
    vals, idx = pl.pallas_call(
        functools.partial(_rank_kernel, k),
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        interpret=True,
    )(s2)
    return vals[0], idx[0]
