"""Layer-1 Pallas kernel: fused batched CMetric aggregation.

GAPP's hot analysis step reformulated for a matrix unit (DESIGN.md
§Hardware-Adaptation): instead of the paper's scalar per-event update

    global_cm += (t - t_switch) / thread_count

we aggregate a *batch* of B switching intervals at once. The batch is an
activity matrix ``A in {0,1}^{B x T}`` (interval x thread-slot) plus a
duration vector ``t in R^B``, and the kernel computes, in a single pass
over ``A``:

    n      = A @ 1            (active threads per interval,   [B])
    c      = t / max(n, 1)    (interval CMetric contribution, [B])
    cm     = A^T c            (per-thread CMetric delta,      [T])
    wall   = A^T t            (per-thread active wall time,   [T])
    gcm    = sum([n > 0] c)   (global_cm delta,               scalar)

The two reductions share the read of ``A``: both are vector-matrix
products against the same tile, so each ``B_blk x T`` tile is loaded from
HBM into VMEM exactly once and hit twice by the MXU. Accumulators live in
the (revisited) output blocks across grid steps — the standard Pallas
"initialize at step 0, accumulate after" pattern.

VMEM budget per grid step (f32): ``B_blk*T + 3*B_blk + 3*T`` words; for
``B_blk = 256, T = 128`` that is ~131 KB — far under the ~16 MB VMEM of a
TPU core, leaving room for double-buffering the next A tile.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* in EXPERIMENTS.md §Perf
from the VMEM footprint and MXU utilization, per the session contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Thread-slot width. 128 = TPU lane width; apps in this repo use <= 64
# worker threads plus a few helpers, so one slot page suffices.
DEFAULT_T = 128
# Default interval-batch block; swept in the §Perf pass (128/256/512).
DEFAULT_B_BLK = 256


def _cmetric_kernel(a_ref, t_ref, cm_ref, wall_ref, gcm_ref):
    """One grid step: fold a [B_blk, T] activity tile into the accumulators."""
    step = pl.program_id(0)

    a = a_ref[...]                                   # [B_blk, T] f32
    t = t_ref[...]                                   # [B_blk, 1] f32

    # Row statistics: active-thread count and per-interval contribution.
    n = jnp.sum(a, axis=1, keepdims=True)            # [B_blk, 1]
    c = t / jnp.maximum(n, 1.0)                      # [B_blk, 1]
    active = (n > 0.0).astype(jnp.float32)           # [B_blk, 1]

    # Both reductions ride the same A tile. Stacking the two row vectors
    # gives one [2, B_blk] x [B_blk, T] matmul for the MXU instead of two
    # vector-matrix products.
    lhs = jnp.concatenate([c, t], axis=1).T          # [2, B_blk]
    acc = jnp.dot(lhs, a, preferred_element_type=jnp.float32)  # [2, T]
    gcm_blk = jnp.sum(active * c)

    @pl.when(step == 0)
    def _init():
        cm_ref[...] = jnp.zeros_like(cm_ref)
        wall_ref[...] = jnp.zeros_like(wall_ref)
        gcm_ref[...] = jnp.zeros_like(gcm_ref)

    cm_ref[...] += acc[0:1, :]
    wall_ref[...] += acc[1:2, :]
    gcm_ref[...] += gcm_blk.reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("b_blk",))
def cmetric_pallas(a: jnp.ndarray, t: jnp.ndarray, *, b_blk: int = DEFAULT_B_BLK):
    """Batched CMetric aggregation via the Pallas kernel.

    Args:
      a: ``[B, T]`` float32 activity matrix (entries in {0, 1}). ``B`` must
         be a multiple of ``b_blk``.
      t: ``[B]`` or ``[B, 1]`` float32 interval durations.
      b_blk: interval-block size (grid = B / b_blk steps).

    Returns:
      ``(cm, wall, global_cm)``: shapes ``[T]``, ``[T]``, ``[]``.
    """
    b, tt = a.shape
    if b % b_blk != 0:
        raise ValueError(f"batch {b} not a multiple of block {b_blk}")
    t2 = t.reshape(b, 1).astype(jnp.float32)
    grid = (b // b_blk,)

    cm2, wall2, gcm2 = pl.pallas_call(
        _cmetric_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, tt), lambda i: (i, 0)),
            pl.BlockSpec((b_blk, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tt), lambda i: (0, 0)),
            pl.BlockSpec((1, tt), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, tt), jnp.float32),
            jax.ShapeDtypeStruct((1, tt), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(a.astype(jnp.float32), t2)

    return cm2[0], wall2[0], gcm2[0, 0]


def vmem_bytes(b_blk: int = DEFAULT_B_BLK, t: int = DEFAULT_T) -> int:
    """Static VMEM footprint estimate (f32 words x 4) for one grid step.

    Counted: the A tile, the t tile, the n/c/active row vectors, the [2, T]
    matmul result and the three resident accumulator blocks. Used by the
    §Perf block-size sweep and reported in EXPERIMENTS.md.
    """
    words = b_blk * t + b_blk + 3 * b_blk + 2 * t + (2 * t + 1)
    return 4 * words


def mxu_flops(b: int, t: int = DEFAULT_T) -> int:
    """MACs issued to the MXU per batch: one [2, B] x [B, T] matmul."""
    return 2 * 2 * b * t
