//! Hot-path microbenches for the §Perf pass: the simulated kernel's
//! event loop, the probe fast path (per-event `handle()` cost), the
//! ring buffer, stack interning, the batched analysis engine (native vs
//! XLA), merge, and symbolization.
//!
//! `cargo bench --bench bench_hotpath -- <filter>`
//!
//! `Bench::finish` writes `BENCH_hotpath.json` at the repo root so the
//! perf trajectory of these numbers is tracked across PRs.

// Benches drive the deprecated `profile`/`run_live` wrappers on
// purpose: their rows are tracked across PRs and the wrappers add no
// measurable cost over the Session driver they delegate to.
#![allow(deprecated)]

use gapp::ebpf::{RingBuf, ShardedRing, StackMap};
use gapp::gapp::records::{mask_set, Record, SlotMask};
use gapp::gapp::{profile, GappConfig, MergeStrategy};
use gapp::runtime::{analysis, AnalysisEngine, BATCH, T_SLOTS};
use gapp::simkernel::{KernelConfig, TaskState, WaitKind};
use gapp::util::bench::{sink, Bench};
use gapp::util::Prng;
use gapp::workload::apps;

fn random_batch(seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Prng::new(seed);
    let a: Vec<f32> = (0..BATCH * T_SLOTS)
        .map(|_| if rng.chance(0.07) { 1.0 } else { 0.0 })
        .collect();
    let t: Vec<f32> = (0..BATCH).map(|_| rng.exp(2e6) as f32).collect();
    (a, t)
}

/// Probes preloaded with `nthreads` registered app threads.
fn loaded_probes(nmin: f64, nthreads: u32) -> gapp::gapp::probes::KernelProbes {
    let mut p = gapp::gapp::probes::KernelProbes::new(
        GappConfig {
            nmin: Some(nmin),
            ..Default::default()
        },
        4,
    )
    .unwrap();
    for pid in 1..=nthreads {
        p.on_task_new(pid, 0, 0);
    }
    p
}

fn main() {
    let mut b = Bench::from_env("hotpath");

    // --- L3: simulated kernel event throughput -------------------------
    b.bench("sched_run_streamcluster_8t", || {
        let app = apps::streamcluster(8, 3);
        let mut k = gapp::simkernel::Kernel::new(KernelConfig::default());
        app.spawn_into(&mut k);
        sink(k.run().unwrap());
    });

    b.bench("profile_canneal_16t_end_to_end", || {
        let app = apps::canneal(16, 3);
        sink(
            profile(
                &app,
                KernelConfig::default(),
                GappConfig::default(),
                AnalysisEngine::native(),
            )
            .unwrap()
            .0
            .runtime_ns,
        );
    });

    // Streaming-vs-batch: the same profile driven through the online
    // analyzer in 5 ms epoch windows (drain + window merge + per-window
    // top-K each epoch). Compare against profile_canneal_16t_end_to_end
    // to read the streaming overhead directly from BENCH_hotpath.json.
    // This historical row measures the *serial* consumer (its numbers
    // predate the merge tree); the `_merge_tree` row next to it is the
    // same run through shard-local folding + the pairwise tree, so the
    // strategy cost reads directly from the pair.
    for (name, merge) in [
        ("live_canneal_16t_w5ms_end_to_end", MergeStrategy::Serial),
        ("live_canneal_16t_w5ms_merge_tree", MergeStrategy::Tree),
    ] {
        b.bench(name, || {
            let app = apps::canneal(16, 3);
            let run = gapp::gapp::stream::run_live(
                std::slice::from_ref(&app),
                KernelConfig::default(),
                GappConfig {
                    merge,
                    ..Default::default()
                },
                AnalysisEngine::native(),
                gapp::gapp::stream::LiveConfig {
                    window_ns: 5_000_000,
                    ..Default::default()
                },
                |w| sink(w.top.len()),
            )
            .unwrap();
            sink(run.report.runtime_ns);
        });
    }

    // Parallel lanes: the same tree-merge run with per-lane folding on
    // 2 and 4 worker threads (4 shards). The outputs are byte-identical
    // to the single-threaded rows above (golden-tested); this pair
    // tracks what the SPSC hand-off + window-close barrier buys (or
    // costs) over driver-thread folding across PRs.
    for (name, lane_threads) in [
        ("live_canneal_16t_w5ms_tree_mt2", 2usize),
        ("live_canneal_16t_w5ms_tree_mt4", 4),
    ] {
        b.bench(name, || {
            let app = apps::canneal(16, 3);
            let run = gapp::gapp::stream::run_live(
                std::slice::from_ref(&app),
                KernelConfig::default(),
                GappConfig {
                    merge: MergeStrategy::Tree,
                    shards: Some(4),
                    lane_threads,
                    ..Default::default()
                },
                AnalysisEngine::native(),
                gapp::gapp::stream::LiveConfig {
                    window_ns: 5_000_000,
                    ..Default::default()
                },
                |w| sink(w.top.len()),
            )
            .unwrap();
            sink(run.report.runtime_ns);
        });
    }

    // Tiered compaction: the tree-merge run again with closed windows
    // folding into a base-8 tier pyramid instead of the flat per-window
    // history. Output is byte-identical (golden-tested); read this row
    // against live_canneal_16t_w5ms_merge_tree to see what the
    // O(B·log T) bound costs (or saves) per run across PRs.
    b.bench("live_canneal_16t_w5ms_compact_b8", || {
        let app = apps::canneal(16, 3);
        let run = gapp::gapp::stream::run_live(
            std::slice::from_ref(&app),
            KernelConfig::default(),
            GappConfig {
                merge: MergeStrategy::Tree,
                compact_base: Some(8),
                ..Default::default()
            },
            AnalysisEngine::native(),
            gapp::gapp::stream::LiveConfig {
                window_ns: 5_000_000,
                ..Default::default()
            },
            |w| sink(w.top.len()),
        )
        .unwrap();
        sink(run.report.runtime_ns);
    });

    // Sharded vs single-ring end-to-end pair: same run, transport forced
    // to one shared ring vs 4 per-CPU shards. The outputs are provably
    // byte-identical (golden-tested); this row pair tracks the *cost* of
    // the per-shard routing + timestamp-merge drain across PRs (serial
    // strategy — the merge-tree rows above track the other consumer).
    for (name, shards) in [
        ("live_canneal_16t_w5ms_ring1_end_to_end", 1usize),
        ("live_canneal_16t_w5ms_shards4_end_to_end", 4),
    ] {
        b.bench(name, || {
            let app = apps::canneal(16, 3);
            let run = gapp::gapp::stream::run_live(
                std::slice::from_ref(&app),
                KernelConfig::default(),
                GappConfig {
                    shards: Some(shards),
                    merge: MergeStrategy::Serial,
                    ..Default::default()
                },
                AnalysisEngine::native(),
                gapp::gapp::stream::LiveConfig {
                    window_ns: 5_000_000,
                    ..Default::default()
                },
                |w| sink(w.top.len()),
            )
            .unwrap();
            sink(run.report.runtime_ns);
        });
    }

    // Scenario harness end-to-end: expand a 2-seed lock-convoy matrix
    // at 8 threads, run both cases silently, score classify() against
    // the injected labels. Tracks the cost of the declarative path
    // (spec → apps → windowed sessions → scorecards) across PRs.
    b.bench("scenario_matrix_lockconvoy_8x", || {
        use gapp::scenario::spec::{MatrixSpec, PathologySpec};
        use gapp::scenario::{PathologyKind, Scenario};
        let sc = Scenario {
            name: "bench".to_string(),
            seed: 7,
            window_us: 5_000,
            top_k: 8,
            nmin: None,
            arrival: None,
            mix: Vec::new(),
            pathologies: vec![PathologySpec {
                kind: PathologyKind::LockConvoy,
                threads: 8,
                items: 24,
            }],
            matrix: Some(MatrixSpec {
                seeds: vec![7, 11],
                threads: vec![8],
            }),
        };
        let mut drop_sink =
            gapp::gapp::sink::FnSink(|_ev: &gapp::gapp::sink::ReportEvent<'_>| {});
        let cards = gapp::experiments::scenario_matrix::run_matrix(
            &sc,
            &AnalysisEngine::native,
            &mut drop_sink,
        )
        .unwrap();
        sink(cards.last().unwrap().overall().tp);
    });

    // --- report sinks: serialization overhead on one live run -----------
    // Replay the captured event stream of a 16-thread canneal live run
    // through each backend. The run itself is amortized out, so the row
    // pair reads as "what does JSON serialization cost over the human
    // renderer" — the number the ROADMAP's transport work budgets from.
    {
        use gapp::gapp::sink::{
            FinalEvent, HumanSink, JsonSink, JsonlSink, ReportEvent, ReportSink,
            SessionInfo, SessionMode,
        };
        use gapp::gapp::stream::WindowReport;

        let app = apps::canneal(16, 3);
        let mut windows: Vec<WindowReport> = Vec::new();
        let run = gapp::gapp::stream::run_live(
            std::slice::from_ref(&app),
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
            gapp::gapp::stream::LiveConfig {
                window_ns: 5_000_000,
                ..Default::default()
            },
            |w| windows.push(w.clone()),
        )
        .unwrap();
        let info = SessionInfo {
            mode: SessionMode::Live,
            apps: vec![app.name.clone()],
            shards: 1,
            window_ns: Some(5_000_000),
            config: GappConfig::default(),
        };
        let mut replay = |s: &mut dyn ReportSink| {
            s.on_event(&ReportEvent::SessionStart(&info)).unwrap();
            for w in &windows {
                s.on_event(&ReportEvent::WindowClosed(w)).unwrap();
            }
            s.on_event(&ReportEvent::Final(FinalEvent {
                report: &run.report,
                windows: &run.windows,
                windows_total: run.report.windows_total,
                sketch_top: &run.sketch_top,
                sketch_lines: &run.sketch_lines,
                recent_top: &[],
                recent_lines: &[],
            }))
            .unwrap();
            s.on_event(&ReportEvent::SessionEnd {
                runtime_ns: run.runtime_ns,
            })
            .unwrap();
            s.finish().unwrap();
        };
        b.bench("sink_human_live_canneal_16t_render", || {
            let mut s = HumanSink::new(Vec::<u8>::with_capacity(64 << 10));
            replay(&mut s);
            sink(s.into_inner().len());
        });
        b.bench("sink_json_live_canneal_16t_render", || {
            let mut s = JsonSink::new(Vec::<u8>::with_capacity(64 << 10));
            replay(&mut s);
            sink(s.into_inner().len());
        });
        b.bench("sink_jsonl_live_canneal_16t_render", || {
            let mut s = JsonlSink::new(Vec::<u8>::with_capacity(64 << 10));
            replay(&mut s);
            sink(s.into_inner().len());
        });
    }

    // The window-merge primitive on its own: fold 64 snapshots of 8
    // paths each into the cumulative merge.
    {
        use gapp::gapp::userspace::{PathAccumulator, SliceEntry};
        let mut windows = Vec::new();
        for w in 0..64u64 {
            let mut acc = PathAccumulator::new();
            for i in 0..256u64 {
                acc.add_slice(
                    &SliceEntry {
                        ts_id: w * 256 + i,
                        pid: (i % 16) as u32,
                        cm_ns: 1000.0 + i as f64,
                        threads_av: 1.0,
                        stack_id: (i % 8) as u32,
                        addrs: vec![0x40_0000 + (i % 32) * 8],
                        from_stack_top: false,
                        wait: WaitKind::Futex,
                        woken_by: 0,
                    },
                    0,
                );
            }
            windows.push(acc.take_paths());
        }
        b.bench_items("window_merge_64x8_paths", 64 * 8, || {
            sink(gapp::gapp::stream::merge_snapshots(
                windows.iter().map(|w| w.as_slice()),
            ));
        });
    }

    // The pairwise merge-tree primitive on its own: combine 8 shard
    // partials (16 paths each, half shared across shards) into one
    // canonical window snapshot — the per-window cross-shard work the
    // tree consumer performs in place of the serial k-way record merge.
    {
        use gapp::gapp::userspace::{PathAccumulator, SliceEntry};
        let mk_partial = |shard: u64| {
            let mut acc = PathAccumulator::new();
            for i in 0..256u64 {
                acc.add_slice(
                    &SliceEntry {
                        ts_id: i * 8 + shard,
                        pid: (i % 16) as u32,
                        cm_ns: 900.0 + i as f64,
                        threads_av: 1.0,
                        // Ids 0..8 appear on every shard, 8..16 are
                        // shard-private: both merge paths exercised.
                        stack_id: ((i % 8) + (i % 2) * (8 + shard)) as u32,
                        addrs: vec![0x40_0000 + (i % 32) * 8],
                        from_stack_top: false,
                        wait: WaitKind::Futex,
                        woken_by: 0,
                    },
                    0,
                );
            }
            acc.take_paths()
        };
        let partials: Vec<Vec<gapp::gapp::userspace::MergedPath>> =
            (0..8).map(mk_partial).collect();
        // merge_tree consumes its input, so each iteration pays one
        // clone of the partials alongside the merge itself — the row is
        // an upper bound on the per-window cross-shard cost (constant
        // bias across PRs; regressions in the merge still move it).
        b.bench_items("window_merge_pairwise_S8", 8, || {
            sink(gapp::gapp::stream::merge_tree(partials.clone()));
        });

        // The same fold through the accumulator pool: every pairwise
        // merge reuses a drained PathAccumulator instead of allocating
        // a fresh map. Read against window_merge_pairwise_S8 to see
        // what the pool buys per window (same clone bias in both rows).
        let mut pool = gapp::gapp::stream::MergePool::new();
        b.bench_items("window_merge_pairwise_S8_pooled", 8, || {
            sink(gapp::gapp::stream::merge_tree_pooled(
                partials.clone(),
                &mut pool,
            ));
        });
    }

    // The decayed sketch primitive on its own: 1e5 weighted adds over
    // 32 distinct keys into a 64-entry DecayedSpaceSaving, advancing
    // simulated time every 1k adds so the halving path (count decay +
    // lazy min-heap rebuild) is exercised, not just the hash-hit path.
    {
        use gapp::gapp::stream::DecayedSpaceSaving;
        b.bench_items("decayed_topk_add_1e5", 100_000, || {
            let mut d: DecayedSpaceSaving<u32> =
                DecayedSpaceSaving::new(64, 1_000_000_000);
            for i in 0..100_000u64 {
                if i % 1_000 == 0 {
                    d.advance_to(i * 20_000);
                }
                d.add((i % 32) as u32, 1_000 + (i % 7));
            }
            sink(d.top(16).len());
        });
    }

    // --- fleet aggregation: cross-process merge --------------------------
    // Two real producer captures (16-thread canneal live runs shipping
    // `--shard-partials` + symbols as JSONL), merged the two ways the
    // fleet subsystem offers: line-rate ingestion through the global
    // re-intern (`gapp aggregate` / the serve reader path), and the
    // per-fleet-window merge_tree fold the service performs at window
    // close.
    {
        use std::cell::RefCell;
        use std::rc::Rc;

        use gapp::fleet::{FleetMerge, Ingested};
        use gapp::gapp::userspace::MergedPath;

        #[derive(Clone, Default)]
        struct Buf(Rc<RefCell<Vec<u8>>>);
        impl std::io::Write for Buf {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.borrow_mut().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let capture = |seed: u64| -> String {
            let app = apps::canneal(16, seed);
            let buf = Buf::default();
            gapp::gapp::Session::builder(AnalysisEngine::native())
                .config(GappConfig::default())
                .app(&app)
                .live(gapp::gapp::stream::LiveConfig {
                    window_ns: 5_000_000,
                    shard_partials: true,
                    ..Default::default()
                })
                .sink(gapp::gapp::sink::JsonlSink::new(buf.clone()))
                .run()
                .unwrap();
            String::from_utf8(buf.0.borrow().clone()).unwrap()
        };
        let prod_a = capture(3);
        let prod_b = capture(4);
        let nlines = (prod_a.lines().count() + prod_b.lines().count()) as u64;
        b.bench_items("fleet_ingest_2prod_jsonl", nlines, || {
            let mut fleet = FleetMerge::new();
            fleet.ingest("a", &prod_a);
            fleet.ingest("b", &prod_b);
            sink(fleet.render_top(5).len());
        });

        // The service's window-close work alone: both producers' parts
        // of each fleet window folded through the pairwise merge tree.
        // merge_tree consumes its input, so each iteration pays one
        // clone alongside the merge (constant bias, same caveat as
        // window_merge_pairwise_S8).
        let mut by_window: std::collections::BTreeMap<u64, Vec<Vec<MergedPath>>> =
            Default::default();
        let mut fleet = FleetMerge::new();
        for text in [&prod_a, &prod_b] {
            let slot = fleet.register("p");
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                if let Some(Ingested::Window { index, paths, .. }) =
                    fleet.ingest_line(slot, line)
                {
                    by_window.entry(index).or_default().push(paths);
                }
            }
        }
        let fleet_windows: Vec<Vec<Vec<MergedPath>>> = by_window.into_values().collect();
        let nwin = fleet_windows.len() as u64;
        b.bench_items("fleet_merge_w5ms_2prod", nwin, || {
            for parts in &fleet_windows {
                sink(gapp::gapp::stream::merge_tree(parts.clone()));
            }
        });
    }

    // --- probe handlers: per-event cost ---------------------------------
    // Discard path (nmin=1 → no slice is ever critical).
    {
        let mut p = loaded_probes(1.0, 8);
        let stack = [0x40_0000u64, 0x40_1000, 0x40_2000, 0x40_3000];
        let mut now = 0u64;
        let mut i = 0u64;
        b.bench_items("probe_switch_discard_4096", 4096, || {
            for _ in 0..4096 {
                now += 1_000;
                let prev = 1 + (i % 8) as u32;
                let next = 1 + ((i + 1) % 8) as u32;
                sink(p.on_switch(
                    now,
                    0,
                    prev,
                    TaskState::Runnable,
                    next,
                    0xAB,
                    &stack,
                    WaitKind::Futex,
                ));
                i += 1;
            }
            while p.rings.pop_global().is_some() {}
        });
    }
    // Critical path (nmin high → every slice captures + interns a stack).
    {
        let mut p = loaded_probes(64.0, 8);
        let stacks: Vec<[u64; 4]> = (0..32u64)
            .map(|s| [0x40_0000, 0x40_1000 + s * 64, 0x40_2000, 0x40_3000 + s])
            .collect();
        let mut now = 0u64;
        let mut i = 0u64;
        b.bench_items("probe_switch_critical_4096", 4096, || {
            for _ in 0..4096 {
                now += 1_000;
                let prev = 1 + (i % 8) as u32;
                let next = 1 + ((i + 1) % 8) as u32;
                sink(p.on_switch(
                    now,
                    0,
                    prev,
                    TaskState::Runnable,
                    next,
                    0xAB,
                    &stacks[(i % 32) as usize],
                    WaitKind::Futex,
                ));
                i += 1;
            }
            while p.rings.pop_global().is_some() {}
        });
    }

    // --- eBPF stack map: intern + resolve -------------------------------
    {
        let mut sm = StackMap::new("bench_stacks", 1 << 14);
        let stacks: Vec<Vec<u64>> = (0..256u64)
            .map(|s| (0..8).map(|d| 0x40_0000 + s * 4096 + d * 8).collect())
            .collect();
        b.bench_items("stackmap_intern_resolve_4096", 4096, || {
            for i in 0..4096u64 {
                let id = sm.intern(&stacks[(i % 256) as usize]);
                sink(sm.resolve(id).len());
            }
        });
    }

    // --- eBPF ring buffer ----------------------------------------------
    let mut rb: RingBuf<Record> = RingBuf::new(1 << 16);
    let mut mask: SlotMask = [0; 2];
    mask_set(&mut mask, 3);
    b.bench_items("ringbuf_push_pop_4096", 4096, || {
        for _ in 0..4096 {
            rb.push(Record::Interval { dur: 1000, mask });
        }
        while rb.pop().is_some() {}
    });

    // Per-CPU sharded transport: route by CPU, drain in global
    // timestamp order (the perf_event_array read path).
    let mut srb: ShardedRing<Record> = ShardedRing::new(4, 1 << 16);
    b.bench_items("ringbuf_sharded4_push_popglobal_4096", 4096, || {
        for i in 0..4096u64 {
            srb.push((i % 4) as usize, i, Record::Interval { dur: 1000, mask });
        }
        while srb.pop_global().is_some() {}
    });

    // --- L1/L2: batched analysis, native vs XLA -------------------------
    let (a, t) = random_batch(11);
    b.bench_items("analyze_native_b1024", BATCH as u64, || {
        sink(analysis::native_analyze(&a, &t, T_SLOTS));
    });
    if let Ok(mut xla) = AnalysisEngine::xla() {
        b.bench_items("analyze_xla_b1024", BATCH as u64, || {
            sink(xla.analyze(&a, &t).unwrap());
        });
        let scores: Vec<f32> = (0..1024).map(|i| (i * 37 % 1013) as f32).collect();
        b.bench("rank_xla_p1024_k16", || {
            sink(xla.rank(&scores, 16).unwrap());
        });
        // §Perf batching sweep: per-interval throughput across the
        // compiled analyze variants (PJRT call overhead amortization).
        for batch in [256usize, 4096] {
            if let Ok(mut e) = gapp::runtime::XlaEngine::load_variant(
                &gapp::runtime::artifacts_dir(),
                batch,
                T_SLOTS,
            ) {
                let mut rng = Prng::new(batch as u64);
                let av: Vec<f32> = (0..batch * T_SLOTS)
                    .map(|_| if rng.chance(0.07) { 1.0 } else { 0.0 })
                    .collect();
                let tv: Vec<f32> = (0..batch).map(|_| rng.exp(2e6) as f32).collect();
                b.bench_items(&format!("analyze_xla_b{batch}"), batch as u64, || {
                    sink(e.analyze(&av, &tv).unwrap());
                });
            }
        }
    } else {
        println!("  (artifacts/ absent: run `make artifacts` for XLA benches)");
    }
    let scores: Vec<f32> = (0..1024).map(|i| (i * 37 % 1013) as f32).collect();
    b.bench("rank_native_p1024_k16", || {
        sink(analysis::native_rank(&scores, 16));
    });

    // --- user-space merge + symbolize -----------------------------------
    b.bench("merge_rank_10k_slices", || {
        let mut u = gapp::gapp::userspace::UserProbe::new(AnalysisEngine::native());
        for i in 0..10_000u64 {
            u.consume(Record::SliceEnd {
                ts_id: i,
                pid: (i % 64) as u32,
                cm_ns: (i % 977) as f64,
                threads_av: 1.0,
                ip: 0x40_0000 + (i % 40) * 16,
                stack_id: (i % 8) as u32,
                stack_top: 0x40_1000 + (i % 8) * 4096,
                wait: WaitKind::Futex,
                woken_by: ((i + 1) % 64) as u32,
            });
        }
        sink(u.merge_and_rank(5));
    });

    b.bench("symbolize_1k_addrs_cached", || {
        let mut st = gapp::workload::SymbolTable::new();
        for i in 0..32 {
            st.add(&format!("fn{i}"), "app.c", 10 * i);
        }
        let mut sym = gapp::gapp::symbolize::Symbolizer::new(&st);
        for rep in 0..4 {
            for i in 0..256u64 {
                sink(sym.resolve(0x40_0000 + (i % 32) * 4096 + rep));
            }
        }
    });

    b.finish();
}
