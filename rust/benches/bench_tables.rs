//! End-to-end benches: one per paper table/figure. Each bench times the
//! full regeneration (workload + profiler + post-processing) and prints
//! the regenerated artefact once, so `cargo bench` doubles as the
//! reproduction run.
//!
//! Filter like criterion: `cargo bench --bench bench_tables -- fig4`.

use gapp::experiments::{
    baselines_cmp, dedup_alloc, fig3, fig4, fig5, fig6, fig7, overhead, sensitivity,
    table2, EngineKind,
};
use gapp::util::bench::{Bench, BenchConfig};

fn cfg() -> BenchConfig {
    BenchConfig {
        warmup_iters: 0,
        min_samples: 2,
        min_time: std::time::Duration::from_millis(1),
        batch: 1,
        ..Default::default()
    }
}

fn main() {
    let engine = EngineKind::Auto;
    let seed = 7;
    let mut b = Bench::new("paper-tables", cfg());
    // Print each artefact once so bench output is self-documenting.
    println!("{}", table2::render(&table2::run(engine, 64, seed).unwrap()));
    b.bench("table2_full_13_apps", || {
        gapp::util::bench::sink(table2::run(engine, 64, seed).unwrap());
    });

    println!("{}", fig3::render(&fig3::run(engine, 32, seed).unwrap()));
    b.bench("fig3_bodytrack", || {
        gapp::util::bench::sink(fig3::run(engine, 32, seed).unwrap());
    });

    println!("{}", fig4::render(&fig4::run(engine, seed).unwrap()));
    b.bench("fig4_ferret_allocs", || {
        gapp::util::bench::sink(fig4::run(engine, seed).unwrap());
    });

    println!("{}", fig5::render(&fig5::run(engine, seed).unwrap()));
    b.bench("fig5_nektar_modes", || {
        gapp::util::bench::sink(fig5::run(engine, seed).unwrap());
    });

    println!("{}", fig6::render(&fig6::run(engine, seed).unwrap()));
    b.bench("fig6_nektar_blas", || {
        gapp::util::bench::sink(fig6::run(engine, seed).unwrap());
    });

    println!("{}", fig7::render(&fig7::run(engine, seed).unwrap()));
    b.bench("fig7_mysql_tuning", || {
        gapp::util::bench::sink(fig7::run(engine, seed).unwrap());
    });

    println!("{}", dedup_alloc::render(&dedup_alloc::run(engine, seed).unwrap()));
    b.bench("dedup_alloc_sweep", || {
        gapp::util::bench::sink(dedup_alloc::run(engine, seed).unwrap());
    });

    println!("{}", sensitivity::render(&sensitivity::run(engine, seed).unwrap()));
    b.bench("sensitivity_nmin_dt", || {
        gapp::util::bench::sink(sensitivity::run(engine, seed).unwrap());
    });

    println!("{}", overhead::render(&overhead::run(engine, 64, seed).unwrap()));
    b.bench("overhead_13_apps", || {
        gapp::util::bench::sink(overhead::run(engine, 64, seed).unwrap());
    });

    println!("{}", baselines_cmp::render(&baselines_cmp::run(engine, seed).unwrap()));
    b.bench("baselines_wperf_coz_critstacks", || {
        gapp::util::bench::sink(baselines_cmp::run(engine, seed).unwrap());
    });

    b.finish();
}
