//! Streaming-analyzer golden tests.
//!
//! The streaming subsystem (`gapp::stream`) claims that batch profiling
//! is the one-window special case of its epoch-windowed pipeline. These
//! tests pin that claim down on fixed seeds:
//!
//! 1. The live report — built by merging per-window snapshots — renders
//!    *byte-identical* to the batch report of the same run (volatile
//!    host-side fields normalized), and the simulated timeline is
//!    untouched by epoch pausing.
//! 2. The concatenation of callback-observed window snapshots merges to
//!    exactly the batch merge (integer CMetric and all counters).
//! 3. Ring-buffer wraparound under a deliberately slow consumer drops
//!    records, and every drop is attributed to the window in which it
//!    occurred.
//! 4. System-wide mode: two applications share the kernel and every
//!    bottleneck carries per-app attribution.
//! 5. Stack-map policies: LRU never drops where drop-new does, and the
//!    eviction policy cannot perturb the simulated timeline.
//! 6. Sharded transport: a per-CPU-ring run (`--shards ≥ 2`) renders a
//!    byte-identical report to the single-shared-ring run on the same
//!    seed, per-shard per-epoch drop deltas sum exactly to the global
//!    dropped counter, and random shard interleavings composed with
//!    random ragged window boundaries always merge to the batch result.
//! 7. Lane threads: `--lane-threads N` moves the shard folds onto real
//!    worker threads; reports, window summaries, sketches and
//!    per-(window × shard) drop attribution must be byte- and
//!    count-identical at every N, live and batch, with and without LRU.

// The deprecated `profile`/`run_live` wrappers stay under golden
// coverage: they must keep producing byte-identical results to the
// Session driver they delegate to.
#![allow(deprecated)]

use gapp::gapp::stream::{
    merge_pair, merge_snapshots, merge_tree, run_live, LiveConfig, WindowAccumulator,
};
use gapp::gapp::userspace::{MergedPath, PathAccumulator, SliceEntry};
use gapp::gapp::{profile, GappConfig, GappSession, MergeStrategy, Report};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::{Kernel, KernelConfig, WaitKind};
use gapp::util::check::property;
use gapp::workload::apps;

/// Zero the fields that depend on host timing or on *when* the ring was
/// drained (peak memory), and strip streaming-only metadata — leaving
/// every simulated / analytical quantity to be compared exactly.
fn normalize(r: &mut Report) {
    r.ppt_seconds = 0.0;
    r.memory_bytes = 0;
    r.window_drops = Vec::new();
    // The window aggregates key the renderer's "windows N" line; strip
    // them with the vector so live reports compare against batch ones
    // (which close no windows) exactly as before the aggregates existed.
    r.windows_total = 0;
    r.windows_lossy = 0;
    r.windows_drop_total = 0;
}

#[test]
fn window_merged_report_is_byte_identical_to_batch() {
    let mk = || apps::canneal(8, 5);
    let (batch, _) = profile(
        &mk(),
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
    )
    .unwrap();

    let app = mk();
    let mut windows = 0u64;
    let run = run_live(
        std::slice::from_ref(&app),
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
        LiveConfig {
            window_ns: 2_000_000,
            ..Default::default()
        },
        |_| windows += 1,
    )
    .unwrap();
    assert!(windows > 1, "run too short for a multi-window golden");

    // Epoch pausing must not perturb the simulated timeline at all.
    assert_eq!(batch.runtime_ns, run.report.runtime_ns);
    assert_eq!(batch.total_slices, run.report.total_slices);
    assert_eq!(batch.critical_slices, run.report.critical_slices);
    assert_eq!(batch.probe_cost_ns, run.report.probe_cost_ns);

    let mut a = batch.clone();
    let mut b = run.report.clone();
    normalize(&mut a);
    normalize(&mut b);
    assert_eq!(
        a.to_string(),
        b.to_string(),
        "window-merged report differs from the batch report"
    );
}

#[test]
fn window_snapshots_concatenate_to_the_exact_batch_merge() {
    let mk = || apps::canneal(8, 5);

    // Batch reference: full (un-truncated) merge of all slices —
    // serial strategy, which is the one that retains the raw slice
    // buffer in `core.user` for this re-merge.
    let serial = || GappConfig {
        merge: MergeStrategy::Serial,
        ..Default::default()
    };
    let app = mk();
    let session = GappSession::new(serial(), 64, AnalysisEngine::native()).unwrap();
    let mut kernel = Kernel::new(KernelConfig::default());
    kernel.attach_probe(session.probe());
    app.spawn_into(&mut kernel);
    let end = kernel.run().unwrap();
    let _ = session.finish(&app, &kernel, end);
    let batch_paths = {
        let mut core = session.core.borrow_mut();
        core.user.merge_and_rank(usize::MAX / 2)
    };
    assert!(!batch_paths.is_empty());

    // Streaming run: collect every window snapshot from the callback.
    let app2 = mk();
    let mut snaps: Vec<Vec<MergedPath>> = Vec::new();
    run_live(
        std::slice::from_ref(&app2),
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
        LiveConfig {
            window_ns: 2_000_000,
            ..Default::default()
        },
        |w| snaps.push(w.snapshot.clone()),
    )
    .unwrap();
    assert!(snaps.len() > 1);

    let merged = merge_snapshots(snaps.iter().map(|s| s.as_slice()));
    // Rank the merged paths the same way the batch reference was ranked
    // (rank preserves first-seen order on ties and drops zero scores).
    let ranked = {
        let session2 =
            GappSession::new(GappConfig::default(), 64, AnalysisEngine::native())
                .unwrap();
        let mut core = session2.core.borrow_mut();
        core.user.rank_merged(&merged, usize::MAX / 2)
    };
    assert_eq!(ranked.len(), batch_paths.len());
    for (a, b) in batch_paths.iter().zip(&ranked) {
        assert_eq!(a.stack_id, b.stack_id, "merge order diverged");
        assert_eq!(a.cm_fs, b.cm_fs, "integer CMetric diverged");
        assert_eq!(a.slices, b.slices);
        assert_eq!(a.addr_freq, b.addr_freq);
        assert_eq!(a.stack_top_samples, b.stack_top_samples);
        assert_eq!(a.wait_hist, b.wait_hist);
        assert_eq!(a.wakers, b.wakers);
    }
}

#[test]
fn ring_wraparound_drops_are_attributed_per_window() {
    // A deliberately slow consumer: one tiny shared ring, and the
    // kernel-side drain threshold disabled so nothing drains until each
    // epoch ends.
    let app = apps::canneal(8, 5);
    let gcfg = GappConfig {
        ring_capacity: 64,
        shards: Some(1),
        drain_threshold: usize::MAX,
        ..Default::default()
    };
    let run = run_live(
        std::slice::from_ref(&app),
        KernelConfig::default(),
        gcfg,
        AnalysisEngine::native(),
        LiveConfig {
            window_ns: 5_000_000,
            ..Default::default()
        },
        |_| {},
    )
    .unwrap();
    let per_window: u64 = run.report.window_drops.iter().sum();
    assert!(
        run.report.ring_dropped > 0,
        "64-record ring with no mid-epoch drain should overflow"
    );
    // The accounting identity: per-window attribution covers every drop.
    assert_eq!(per_window, run.report.ring_dropped);
    assert!(run.report.window_drops.iter().any(|d| *d > 0));
    // Summaries agree with the report's attribution.
    let summary_total: u64 = run.windows.iter().map(|w| w.drops).sum();
    assert_eq!(summary_total, per_window);
    // The report surfaces the streaming drop line.
    assert!(run.report.to_string().contains("ring drops"));
}

#[test]
fn system_wide_mode_attributes_bottlenecks_per_app() {
    let mysql = apps::by_name("mysql", 8, 7).unwrap();
    let dedup = apps::by_name("dedup", 8, 7).unwrap();
    let pair = [mysql, dedup];
    let mut windows = 0u64;
    let run = run_live(
        &pair,
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
        LiveConfig {
            window_ns: 5_000_000,
            ..Default::default()
        },
        |w| {
            windows += 1;
            for line in &w.top {
                assert!(
                    line.app == "mysql" || line.app == "dedup",
                    "unknown app {:?}",
                    line.app
                );
            }
        },
    )
    .unwrap();
    assert!(windows > 1);
    assert_eq!(run.report.app, "mysql+dedup");
    assert!(!run.report.bottlenecks.is_empty());
    for b in &run.report.bottlenecks {
        assert!(
            !b.apps.is_empty(),
            "system-wide bottlenecks must carry app attribution"
        );
        for (name, n) in &b.apps {
            assert!(name == "mysql" || name == "dedup");
            assert!(*n > 0);
        }
    }
    assert!(run.report.to_string().contains("apps: "));
    // Threads of both applications appear in the per-thread table.
    assert!(
        run.report.threads.len() > 8,
        "expected threads from both apps, got {}",
        run.report.threads.len()
    );
}

#[test]
fn live_with_lru_re_interns_snapshots_into_stable_ids() {
    // Streaming + LRU end to end: a small kernel map forces recycling,
    // and the final report must still resolve call paths because
    // snapshots were re-keyed into the stable userspace map at window
    // close (raw kernel ids would dangle after eviction).
    let app = apps::canneal(8, 5);
    let gcfg = GappConfig {
        stack_map_entries: 4,
        stack_lru: true,
        ..Default::default()
    };
    let run = run_live(
        std::slice::from_ref(&app),
        KernelConfig::default(),
        gcfg,
        AnalysisEngine::native(),
        LiveConfig {
            window_ns: 2_000_000,
            ..Default::default()
        },
        |_| {},
    )
    .unwrap();
    assert_eq!(run.report.stack_drops, 0, "LRU must never drop");
    assert!(!run.report.bottlenecks.is_empty());
    assert!(
        run.report
            .bottlenecks
            .iter()
            .any(|b| !b.call_path.is_empty()),
        "re-interned ids must still resolve to call paths"
    );
    assert!(!run.sketch_lines.is_empty());
}

#[test]
fn stack_lru_never_drops_and_cannot_perturb_the_timeline() {
    // Exercises the eviction *mechanics* under extreme pressure (a
    // 1-entry map). Attribution quality under LRU is the streaming
    // path's job (snapshots re-intern into a stable userspace map at
    // window close); batch mode documents the conflation caveat.
    let tiny = |lru: bool| GappConfig {
        stack_map_entries: 1,
        stack_lru: lru,
        ..Default::default()
    };
    let (drop_new, _) = profile(
        &apps::dedup(7, Default::default()),
        KernelConfig::default(),
        tiny(false),
        AnalysisEngine::native(),
    )
    .unwrap();
    let (lru, _) = profile(
        &apps::dedup(7, Default::default()),
        KernelConfig::default(),
        tiny(true),
        AnalysisEngine::native(),
    )
    .unwrap();
    // Interning policy is invisible to the simulated timeline: capture
    // costs are charged whether the stack is kept, dropped or evicted.
    assert_eq!(drop_new.runtime_ns, lru.runtime_ns);
    assert_eq!(drop_new.total_slices, lru.total_slices);
    assert_eq!(drop_new.critical_slices, lru.critical_slices);
    // Drop-new saturates a 1-entry map; LRU recycles instead.
    assert!(
        drop_new.stack_drops > 0,
        "dedup pipeline should exceed one distinct critical path"
    );
    assert_eq!(lru.stack_drops, 0);
    assert!(lru.stack_evictions > 0);
    assert!(!lru.bottlenecks.is_empty());
}

#[test]
fn sharded_run_is_byte_identical_to_single_ring() {
    // The acceptance golden: the per-CPU sharded transport must be
    // invisible to the analysis. Same fixed seed, one run through a
    // single shared ring, one through 4 per-CPU shards — the drains
    // re-establish global record order from capture timestamps, so the
    // final reports render byte-identically (host-side memory/PPT
    // normalized; ring buffering is the only thing that may differ).
    let run_with = |shards: usize| {
        let app = apps::canneal(8, 5);
        run_live(
            std::slice::from_ref(&app),
            KernelConfig::default(),
            GappConfig {
                shards: Some(shards),
                ..Default::default()
            },
            AnalysisEngine::native(),
            LiveConfig {
                window_ns: 2_000_000,
                ..Default::default()
            },
            |_| {},
        )
        .unwrap()
    };
    let single = run_with(1);
    let sharded = run_with(4);
    assert_eq!(single.report.ring_shards.len(), 1);
    assert_eq!(sharded.report.ring_shards.len(), 4);
    // Records actually spread across shards (multi-CPU workload).
    assert!(
        sharded.report.ring_shards.iter().filter(|s| s.pushed > 0).count() > 1,
        "expected records on more than one shard"
    );
    // The simulated timeline is untouched by the transport shape.
    assert_eq!(single.report.runtime_ns, sharded.report.runtime_ns);
    assert_eq!(single.report.total_slices, sharded.report.total_slices);
    assert_eq!(single.report.probe_cost_ns, sharded.report.probe_cost_ns);
    assert_eq!(single.report.ring_dropped, 0);
    assert_eq!(sharded.report.ring_dropped, 0);
    let mut a = single.report.clone();
    let mut b = sharded.report.clone();
    normalize(&mut a);
    normalize(&mut b);
    assert_eq!(
        a.to_string(),
        b.to_string(),
        "sharded drain must reproduce the single-ring report byte for byte"
    );
    // Batch is identical too: the same golden holds for `profile`.
    let (batch1, _) = profile(
        &apps::canneal(8, 5),
        KernelConfig::default(),
        GappConfig {
            shards: Some(1),
            ..Default::default()
        },
        AnalysisEngine::native(),
    )
    .unwrap();
    let (batch4, _) = profile(
        &apps::canneal(8, 5),
        KernelConfig::default(),
        GappConfig {
            shards: Some(4),
            ..Default::default()
        },
        AnalysisEngine::native(),
    )
    .unwrap();
    let mut a = batch1;
    let mut b = batch4;
    normalize(&mut a);
    normalize(&mut b);
    assert_eq!(a.to_string(), b.to_string());
}

#[test]
fn sharded_drops_sum_to_the_global_counter_across_epochs_and_shards() {
    // Force overflow on a sharded transport: tiny per-shard rings and
    // no mid-epoch drains. The accounting identity must hold on both
    // axes — per-window drops (summed over shards) equal the report's
    // window attribution, and per-shard totals sum to the global
    // dropped counter — under *both* merge strategies and at *every*
    // lane-thread count (the tree's per-shard cursors must not lose or
    // double-charge a drop; drop accounting is driver-side, so moving
    // the folds onto workers must not move a single drop).
    let variants = [
        (MergeStrategy::Serial, 1usize),
        (MergeStrategy::Tree, 1),
        (MergeStrategy::Tree, 2),
        (MergeStrategy::Tree, 4),
    ];
    // Per-variant per-(window × shard) drop matrix, for the cross-
    // variant invariance check below.
    let mut drop_matrices: Vec<Vec<Vec<u64>>> = Vec::new();
    for (merge, lane_threads) in variants {
        let tag = format!("{merge:?} x{lane_threads}");
        let app = apps::canneal(8, 5);
        let gcfg = GappConfig {
            ring_capacity: 16,
            shards: Some(4),
            drain_threshold: usize::MAX,
            merge,
            lane_threads,
            ..Default::default()
        };
        let mut window_shard_totals: Vec<u64> = vec![0; 4];
        let mut matrix: Vec<Vec<u64>> = Vec::new();
        let run = run_live(
            std::slice::from_ref(&app),
            KernelConfig::default(),
            gcfg,
            AnalysisEngine::native(),
            LiveConfig {
                window_ns: 5_000_000,
                ..Default::default()
            },
            |w| {
                assert_eq!(w.shard_drops.len(), 4);
                assert_eq!(
                    w.shard_drops.iter().sum::<u64>(),
                    w.drops,
                    "window {}: shard breakdown must sum to the window total",
                    w.index
                );
                for (i, d) in w.shard_drops.iter().enumerate() {
                    window_shard_totals[i] += d;
                }
                matrix.push(w.shard_drops.clone());
            },
        )
        .unwrap();
        assert!(
            run.report.ring_dropped > 0,
            "16-record shards with no mid-epoch drain should overflow ({tag})"
        );
        // Per-window attribution covers every drop...
        let per_window: u64 = run.report.window_drops.iter().sum();
        assert_eq!(per_window, run.report.ring_dropped, "{tag}");
        // ...and so does the per-shard attribution, window by window.
        assert_eq!(
            window_shard_totals.iter().sum::<u64>(),
            run.report.ring_dropped,
            "{tag}"
        );
        // The report's final per-shard counters agree with the per-epoch
        // deltas accumulated through the consumer's cursors.
        assert_eq!(run.report.ring_shards.len(), 4);
        for (i, s) in run.report.ring_shards.iter().enumerate() {
            assert_eq!(
                s.dropped, window_shard_totals[i],
                "shard {i} ({tag}): cursor deltas must sum to the ring's counter"
            );
        }
        drop_matrices.push(matrix);
    }
    // Acceptance invariant: the full (window × shard) drop matrix is
    // identical across strategies and thread counts.
    for (m, (merge, lane_threads)) in drop_matrices.iter().zip(variants).skip(1) {
        assert_eq!(
            *m, drop_matrices[0],
            "{merge:?} x{lane_threads}: per-(window × shard) drops moved"
        );
    }
}

#[test]
fn merge_tree_reports_are_byte_identical_to_serial() {
    // The tentpole acceptance golden: `--merge tree` (shard-local
    // folding + pairwise merge tree) must render byte-identically to
    // `--merge serial` (global re-serialization) — live and batch,
    // single-ring and sharded. Lossless runs, so buffering/drain-timing
    // differences between the strategies cannot surface (the same
    // caveat the shards-1-vs-4 golden carries).
    for shards in [1usize, 4] {
        let cfg = |merge: MergeStrategy| GappConfig {
            shards: Some(shards),
            merge,
            ..Default::default()
        };
        // Live (epoch-windowed) drivers.
        let live = |merge: MergeStrategy| {
            let app = apps::canneal(8, 5);
            run_live(
                std::slice::from_ref(&app),
                KernelConfig::default(),
                cfg(merge),
                AnalysisEngine::native(),
                LiveConfig {
                    window_ns: 2_000_000,
                    ..Default::default()
                },
                |_| {},
            )
            .unwrap()
        };
        let s = live(MergeStrategy::Serial);
        let t = live(MergeStrategy::Tree);
        assert_eq!(s.report.runtime_ns, t.report.runtime_ns);
        assert_eq!(s.report.ring_dropped, 0);
        assert_eq!(t.report.ring_dropped, 0);
        assert_eq!(s.sketch_top, t.sketch_top, "shards={shards}");
        assert_eq!(s.sketch_lines, t.sketch_lines, "shards={shards}");
        let mut a = s.report.clone();
        let mut b = t.report.clone();
        normalize(&mut a);
        normalize(&mut b);
        assert_eq!(
            a.to_string(),
            b.to_string(),
            "live --shards {shards}: tree must reproduce serial byte for byte"
        );
        // Batch drivers (the one-window special case).
        let batch = |merge: MergeStrategy| {
            profile(
                &apps::canneal(8, 5),
                KernelConfig::default(),
                cfg(merge),
                AnalysisEngine::native(),
            )
            .unwrap()
            .0
        };
        let mut a = batch(MergeStrategy::Serial);
        let mut b = batch(MergeStrategy::Tree);
        normalize(&mut a);
        normalize(&mut b);
        assert_eq!(
            a.to_string(),
            b.to_string(),
            "batch --shards {shards}: tree must reproduce serial byte for byte"
        );
    }
}

#[test]
fn lane_thread_counts_are_byte_invisible_live_and_batch() {
    // The tentpole acceptance golden: `--lane-threads N` moves the
    // shard folds onto N scoped worker threads, and nothing else — the
    // serial oracle, the inline tree and every threaded variant must
    // render byte-identical reports, live and batch, with and without
    // kernel-side LRU (the stable re-intern runs downstream of the
    // merge, so worker topology must not reach it). Only the shards-4
    // axis carries thread variants: `--lane-threads 2 --shards 1` is a
    // config error by design, covered in the config unit tests.
    for lru in [false, true] {
        let cfg = |merge: MergeStrategy, lane_threads: usize| GappConfig {
            shards: Some(4),
            merge,
            lane_threads,
            stack_lru: lru,
            // Small enough to recycle ids under LRU, so the re-intern
            // path is actually exercised.
            stack_map_entries: if lru { 4 } else { 1 << 10 },
            ..Default::default()
        };
        let live = |merge: MergeStrategy, lane_threads: usize| {
            let app = apps::canneal(8, 5);
            run_live(
                std::slice::from_ref(&app),
                KernelConfig::default(),
                cfg(merge, lane_threads),
                AnalysisEngine::native(),
                LiveConfig {
                    window_ns: 2_000_000,
                    ..Default::default()
                },
                |_| {},
            )
            .unwrap()
        };
        let batch = |merge: MergeStrategy, lane_threads: usize| {
            profile(
                &apps::canneal(8, 5),
                KernelConfig::default(),
                cfg(merge, lane_threads),
                AnalysisEngine::native(),
            )
            .unwrap()
            .0
        };
        let norm = |mut r: Report| {
            normalize(&mut r);
            r.to_string()
        };
        let live_ref = live(MergeStrategy::Serial, 1);
        let batch_ref = norm(batch(MergeStrategy::Serial, 1));
        for lane_threads in [1usize, 2, 4] {
            let l = live(MergeStrategy::Tree, lane_threads);
            assert_eq!(
                l.windows, live_ref.windows,
                "lru={lru} x{lane_threads}: window summaries moved"
            );
            assert_eq!(l.sketch_top, live_ref.sketch_top, "lru={lru} x{lane_threads}");
            assert_eq!(
                l.sketch_lines, live_ref.sketch_lines,
                "lru={lru} x{lane_threads}"
            );
            assert_eq!(
                norm(l.report),
                norm(live_ref.report.clone()),
                "live lru={lru} x{lane_threads}: report must not move by a byte"
            );
            assert_eq!(
                norm(batch(MergeStrategy::Tree, lane_threads)),
                batch_ref,
                "batch lru={lru} x{lane_threads}: report must not move by a byte"
            );
        }
    }
}

#[test]
fn random_workloads_fold_identically_at_every_lane_thread_count() {
    // Property (satellite): random workload × seed × window length ×
    // shard count — the serial global-stream fold, the inline tree and
    // the threaded lanes at 2 and 4 workers agree on everything the
    // session reports. Random shard interleavings arise naturally (the
    // scheduler deals slices onto per-CPU shards) and the random window
    // length makes the epoch boundaries ragged relative to the slices.
    property("lane threads × random workloads", 6, |rng| {
        let names = ["canneal", "dedup", "mysql", "blackscholes"];
        let name = names[rng.pick(names.len())];
        let nthreads = 4 + rng.pick(8);
        let seed = 1 + rng.pick(50) as u64;
        let window_ns = 1_000_000 + rng.pick(4) as u64 * 700_000;
        let shards = 2 + rng.pick(3);
        let run = |merge: MergeStrategy, lane_threads: usize| {
            let app = apps::by_name(name, nthreads, seed).unwrap();
            run_live(
                std::slice::from_ref(&app),
                KernelConfig::default(),
                GappConfig {
                    shards: Some(shards),
                    merge,
                    lane_threads,
                    ..Default::default()
                },
                AnalysisEngine::native(),
                LiveConfig {
                    window_ns,
                    ..Default::default()
                },
                |_| {},
            )
            .unwrap()
        };
        let norm = |mut r: Report| {
            normalize(&mut r);
            r.to_string()
        };
        let serial = run(MergeStrategy::Serial, 1);
        let serial_text = norm(serial.report.clone());
        for lane_threads in [1usize, 2, 4] {
            let t = run(MergeStrategy::Tree, lane_threads);
            let tag = format!(
                "{name} threads={nthreads} seed={seed} window={window_ns} \
                 shards={shards} lane_threads={lane_threads}"
            );
            assert_eq!(t.windows, serial.windows, "{tag}");
            assert_eq!(t.sketch_top, serial.sketch_top, "{tag}");
            assert_eq!(norm(t.report), serial_text, "{tag}");
        }
    });
}

#[test]
fn tier_compaction_is_byte_invisible_across_the_config_matrix() {
    // The PR 10 acceptance golden: `--compact-base B` bounds retained
    // state to O(B·log T) and must change *nothing* the session
    // reports. Every transport shape the profiler offers — serial and
    // tree merges, single and sharded rings, driver-thread and worker
    // lanes, drop-new and LRU stack maps — is run flat and compacted
    // at several bases, and the rendered reports (windows line
    // included — only host timing normalized) must match byte for
    // byte, along with the sketch.
    let run = |base: Option<usize>,
               merge: MergeStrategy,
               shards: usize,
               lane_threads: usize,
               lru: bool| {
        let app = apps::canneal(8, 5);
        run_live(
            std::slice::from_ref(&app),
            KernelConfig::default(),
            GappConfig {
                shards: Some(shards),
                merge,
                lane_threads,
                stack_lru: lru,
                stack_map_entries: if lru { 4 } else { 1 << 10 },
                compact_base: base,
                ..Default::default()
            },
            AnalysisEngine::native(),
            LiveConfig {
                window_ns: 2_000_000,
                ..Default::default()
            },
            |_| {},
        )
        .unwrap()
    };
    // Only host timing is normalized: the window aggregates (and with
    // them the rendered "windows N" line) must survive compaction
    // untouched, so this comparison is stricter than `normalize`.
    let norm = |mut r: Report| {
        r.ppt_seconds = 0.0;
        r.memory_bytes = 0;
        r.to_string()
    };
    let matrix = [
        (MergeStrategy::Serial, 1usize, 1usize, false),
        (MergeStrategy::Tree, 4, 1, false),
        (MergeStrategy::Tree, 4, 2, false),
        (MergeStrategy::Serial, 4, 1, true),
    ];
    for (merge, shards, lane_threads, lru) in matrix {
        let flat = run(None, merge, shards, lane_threads, lru);
        let flat_text = norm(flat.report.clone());
        assert!(flat.windows.len() > 1, "run too short for a compaction golden");
        for base in [2usize, 3, 8] {
            let c = run(Some(base), merge, shards, lane_threads, lru);
            let tag = format!(
                "base={base} {merge:?} shards={shards} lanes={lane_threads} lru={lru}"
            );
            assert_eq!(norm(c.report.clone()), flat_text, "{tag}");
            assert_eq!(c.sketch_top, flat.sketch_top, "{tag}");
            assert_eq!(c.sketch_lines, flat.sketch_lines, "{tag}");
            // The summary list is the folded tier view: fewer entries,
            // same totals, same final window index.
            assert!(c.windows.len() <= flat.windows.len(), "{tag}");
            assert_eq!(
                c.windows.iter().map(|w| w.slices).sum::<u64>(),
                flat.windows.iter().map(|w| w.slices).sum::<u64>(),
                "{tag}"
            );
            assert_eq!(
                c.windows.iter().map(|w| w.drops).sum::<u64>(),
                flat.windows.iter().map(|w| w.drops).sum::<u64>(),
                "{tag}"
            );
            assert_eq!(
                c.windows.last().map(|w| w.index),
                flat.windows.last().map(|w| w.index),
                "{tag}"
            );
            // The per-window breakdown is the one thing compaction
            // folds away; the aggregates stand in for it.
            assert!(c.report.window_drops.is_empty(), "{tag}");
            assert_eq!(
                c.report.windows_drop_total,
                flat.report.window_drops.iter().sum::<u64>(),
                "{tag}"
            );
        }
    }
    // Batch sessions close no windows: the knob must be inert there.
    let batch = |base: Option<usize>| {
        profile(
            &apps::canneal(8, 5),
            KernelConfig::default(),
            GappConfig {
                compact_base: base,
                ..Default::default()
            },
            AnalysisEngine::native(),
        )
        .unwrap()
        .0
    };
    assert_eq!(norm(batch(Some(4))), norm(batch(None)));
}

#[test]
fn system_wide_merge_tree_matches_serial_with_app_attribution() {
    // Per-app attribution crosses the shard split (a path's slices can
    // land on any shard under any app); the merged app histograms and
    // dominant-app symbolization must not care.
    let run = |merge: MergeStrategy| {
        let pair = [
            apps::by_name("mysql", 8, 7).unwrap(),
            apps::by_name("dedup", 8, 7).unwrap(),
        ];
        run_live(
            &pair,
            KernelConfig::default(),
            GappConfig {
                shards: Some(4),
                merge,
                ..Default::default()
            },
            AnalysisEngine::native(),
            LiveConfig {
                window_ns: 5_000_000,
                ..Default::default()
            },
            |_| {},
        )
        .unwrap()
    };
    let s = run(MergeStrategy::Serial);
    let t = run(MergeStrategy::Tree);
    assert!(!t.report.bottlenecks.is_empty());
    assert!(t.report.bottlenecks.iter().all(|b| !b.apps.is_empty()));
    let mut a = s.report.clone();
    let mut b = t.report.clone();
    normalize(&mut a);
    normalize(&mut b);
    assert_eq!(a.to_string(), b.to_string());
}

#[test]
fn every_merge_tree_shape_equals_the_serial_global_stream_fold() {
    // Property (satellite): deal one slice stream onto S shard FIFOs,
    // fold each shard locally through ragged window boundaries, and
    // combine the per-window shard partials through a *random-shape*
    // binary merge tree. Whatever the sharding, the window boundaries
    // and the tree shape, the result must equal the serial fold of the
    // globally-ordered stream — associativity (PR 2), shard affinity +
    // stamp-keyed order reconciliation (this PR).
    property("shard partials × ragged windows × tree shapes", 24, |rng| {
        let n = 40 + rng.pick(140) as u64;
        let mk = |i: u64| SliceEntry {
            ts_id: i + 1, // capture stamp: the reconciliation key
            pid: (1 + i % 5) as u32,
            cm_ns: 3.0 + (i as f64) * 0.813,
            threads_av: 1.0,
            stack_id: (i % 6) as u32,
            addrs: vec![0x400 + i % 9],
            from_stack_top: i % 3 == 0,
            wait: if i % 2 == 0 {
                WaitKind::Futex
            } else {
                WaitKind::Queue
            },
            woken_by: (i % 3) as u32,
        };
        let slices: Vec<SliceEntry> = (0..n).map(mk).collect();

        // Serial reference: fold the stream in capture order through
        // ragged windows, then concatenate the window snapshots.
        let nwindows = 1 + rng.pick(4);
        let mut boundaries: Vec<u64> =
            (0..nwindows - 1).map(|_| rng.pick(n as usize) as u64).collect();
        boundaries.push(n);
        boundaries.sort_unstable();
        let window_of = |i: u64, bounds: &[u64]| {
            bounds.iter().position(|b| i < *b).unwrap_or(bounds.len() - 1)
        };
        let mut serial = WindowAccumulator::new();
        let mut serial_windows: Vec<Vec<MergedPath>> = Vec::new();
        for w in 0..nwindows {
            for (i, s) in slices.iter().enumerate() {
                if window_of(i as u64, &boundaries) == w {
                    serial.add_slice(s, (s.pid % 2) as u16);
                }
            }
            serial_windows.push(serial.snapshot());
        }

        // Tree side: random shard owner per slice (FIFO per shard, like
        // per-CPU buffers), shard-local folds per window, then a
        // random-shape pairwise tree over each window's partials.
        let nshards = 1 + rng.pick(6);
        let mut shard_of: Vec<usize> = Vec::with_capacity(n as usize);
        for _ in 0..n {
            shard_of.push(rng.pick(nshards));
        }
        let mut folders: Vec<WindowAccumulator> =
            (0..nshards).map(|_| WindowAccumulator::new()).collect();
        let mut tree_windows: Vec<Vec<MergedPath>> = Vec::new();
        for w in 0..nwindows {
            // Each shard folds its own sub-stream in shard order.
            for shard in 0..nshards {
                for (i, s) in slices.iter().enumerate() {
                    if shard_of[i] == shard && window_of(i as u64, &boundaries) == w {
                        folders[shard].add_slice(s, (s.pid % 2) as u16);
                    }
                }
            }
            let mut parts: Vec<Vec<MergedPath>> =
                folders.iter_mut().map(|f| f.snapshot()).collect();
            // Random tree shape: repeatedly merge two random partials
            // until one remains. Every binary tree over the partials is
            // reachable this way.
            while parts.len() > 1 {
                let i = rng.pick(parts.len());
                let a = parts.swap_remove(i);
                let j = rng.pick(parts.len());
                let b = parts.swap_remove(j);
                parts.push(merge_pair(a, b));
            }
            tree_windows.push(merge_tree(parts));
        }

        // Window by window, and cumulatively, the two sides agree.
        assert_eq!(serial_windows.len(), tree_windows.len());
        for (sw, tw) in serial_windows.iter().zip(&tree_windows) {
            assert_eq!(sw.len(), tw.len(), "window path-set size diverged");
            for (a, b) in sw.iter().zip(tw) {
                assert_eq!(a.stack_id, b.stack_id, "canonical order diverged");
                assert_eq!(a.first_seen, b.first_seen);
                assert_eq!(a.cm_fs, b.cm_fs, "integer CMetric must match exactly");
                assert_eq!(a.slices, b.slices);
                assert_eq!(a.addr_freq, b.addr_freq);
                assert_eq!(a.stack_top_samples, b.stack_top_samples);
                assert_eq!(a.wait_hist, b.wait_hist);
                assert_eq!(a.wakers, b.wakers);
                assert_eq!(a.app_slices, b.app_slices);
            }
        }
        let s_all = merge_snapshots(serial_windows.iter().map(|s| s.as_slice()));
        let t_all = merge_snapshots(tree_windows.iter().map(|s| s.as_slice()));
        assert_eq!(s_all.len(), t_all.len());
        for (a, b) in s_all.iter().zip(&t_all) {
            assert_eq!(a.stack_id, b.stack_id);
            assert_eq!(a.cm_fs, b.cm_fs);
            assert_eq!(a.slices, b.slices);
        }
    });
}

#[test]
fn random_shard_interleavings_and_ragged_windows_merge_to_the_batch_report() {
    // Property: take one slice stream; deal it onto S simulated shard
    // queues (each preserving relative order, like per-CPU FIFOs); have
    // a consumer merge the queues back into global order by the slices'
    // capture sequence; aggregate through random ragged window
    // boundaries; merge the snapshots. However the records were sharded
    // and windowed, the result must equal the one-shot batch merge —
    // associativity (PR 2) composed with timestamp re-ordering (this
    // PR) is exactly what the sharded drain relies on.
    property("shard interleaving × ragged windows", 24, |rng| {
        let n = 60 + rng.pick(120) as u64;
        let mk = |i: u64| SliceEntry {
            ts_id: i, // capture sequence: the merge key
            pid: (1 + i % 5) as u32,
            cm_ns: 8.0 + (i as f64) * 0.591,
            threads_av: 1.0,
            stack_id: (i % 7) as u32,
            addrs: vec![0x400 + i % 9],
            from_stack_top: i % 3 == 0,
            wait: if i % 2 == 0 {
                WaitKind::Futex
            } else {
                WaitKind::Queue
            },
            woken_by: (i % 3) as u32,
        };
        let slices: Vec<SliceEntry> = (0..n).map(mk).collect();

        // Reference: one batch merge over the stream in capture order.
        let mut batch = PathAccumulator::new();
        for s in &slices {
            batch.add_slice(s, (s.pid % 2) as u16);
        }
        let batch_paths = batch.take_paths();

        // Shard the stream: random owner per slice, FIFO per shard.
        let nshards = 2 + rng.pick(4);
        let mut shards: Vec<Vec<SliceEntry>> = vec![Vec::new(); nshards];
        for s in &slices {
            shards[rng.pick(nshards)].push(s.clone());
        }
        // Consumer: re-establish global order by capture sequence
        // (pop the shard whose head has the smallest ts_id).
        let mut heads = vec![0usize; nshards];
        let mut merged_stream: Vec<&SliceEntry> = Vec::new();
        loop {
            let mut best: Option<(usize, u64)> = None;
            for (i, q) in shards.iter().enumerate() {
                if let Some(s) = q.get(heads[i]) {
                    if best.map_or(true, |(_, b)| s.ts_id < b) {
                        best = Some((i, s.ts_id));
                    }
                }
            }
            match best {
                Some((i, _)) => {
                    merged_stream.push(&shards[i][heads[i]]);
                    heads[i] += 1;
                }
                None => break,
            }
        }
        assert_eq!(merged_stream.len(), slices.len());

        // Aggregate through random ragged windows, then merge snapshots.
        let mut wacc = WindowAccumulator::new();
        let mut snaps: Vec<Vec<MergedPath>> = Vec::new();
        for s in &merged_stream {
            wacc.add_slice(s, (s.pid % 2) as u16);
            if rng.chance(0.07) {
                snaps.push(wacc.snapshot());
            }
        }
        snaps.push(wacc.snapshot());
        let merged = merge_snapshots(snaps.iter().map(|s| s.as_slice()));

        assert_eq!(merged.len(), batch_paths.len());
        for (a, b) in batch_paths.iter().zip(&merged) {
            assert_eq!(a.stack_id, b.stack_id, "first-seen order must survive");
            assert_eq!(a.cm_fs, b.cm_fs, "integer CMetric must match exactly");
            assert_eq!(a.slices, b.slices);
            assert_eq!(a.addr_freq, b.addr_freq);
            assert_eq!(a.stack_top_samples, b.stack_top_samples);
            assert_eq!(a.wait_hist, b.wait_hist);
            assert_eq!(a.wakers, b.wakers);
            assert_eq!(a.app_slices, b.app_slices);
        }
    });
}
