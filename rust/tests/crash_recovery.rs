//! Crash-safe session goldens: kill → restore → finish must be
//! indistinguishable from never having crashed.
//!
//! The durability tentpole claims three invariants, pinned here on
//! fixed seeds across the transport/strategy matrix:
//!
//! 1. **Recovery identity** — a session killed after window k (by a
//!    fault plan, right after that window's checkpoint was published)
//!    and resumed from the checkpoint finishes with per-window output
//!    and a final report *byte-identical* to the uninterrupted run —
//!    batch and live, `--shards 1|4`, `--merge serial|tree`, with and
//!    without `--lru`, at every `--lane-threads` count (which a resume
//!    may legally change), and under active fault plans.
//! 2. **Degradation accounting** — injected overflow bursts drop (and
//!    are counted) under `--on-overflow shed`, and are absorbed by
//!    emergency drains + window widening (and are counted) under
//!    `--on-overflow degrade`; a stalled shard lane with adequate
//!    buffering is *invisible* to the output.
//! 3. **Quarantine** — corrupt `shard_window` JSONL lines feed the
//!    partial reader's per-producer quarantine counters, never a panic
//!    and never a silent skip.

use std::cell::RefCell;
use std::io;
use std::rc::Rc;

use gapp::gapp::checkpoint::Checkpoint;
use gapp::gapp::faults::{corrupt_jsonl, FaultPlan, OverflowBurst, StallSpec};
use gapp::gapp::sink::{FnSink, JsonlSink, ReportEvent};
use gapp::gapp::stream::partials::PartialAggregator;
use gapp::gapp::stream::LiveConfig;
use gapp::gapp::{
    GappConfig, MergeStrategy, OverflowPolicy, Report, Session, SessionOutput,
};
use gapp::runtime::AnalysisEngine;
use gapp::workload::apps;

/// Unique scratch path per (process, label) so parallel tests never
/// collide on checkpoint files.
fn tmp(label: &str) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("gapp_crash_{}_{label}", std::process::id()));
    p.to_string_lossy().into_owned()
}

/// Zero host-timing fields; everything else must match exactly.
fn normalize(r: &Report) -> String {
    let mut r = r.clone();
    r.ppt_seconds = 0.0;
    r.memory_bytes = 0;
    r.to_string()
}

/// One live-session configuration under test.
#[derive(Clone)]
struct Spec {
    shards: usize,
    merge: MergeStrategy,
    lane_threads: usize,
    lru: bool,
    on_overflow: OverflowPolicy,
    ring_capacity: Option<usize>,
    drain_threshold: Option<usize>,
    window_ns: u64,
    plan: FaultPlan,
    checkpoint: Option<String>,
    resume: Option<String>,
    compact_base: Option<usize>,
    decay_us: Option<u64>,
}

impl Spec {
    fn new(shards: usize, merge: MergeStrategy) -> Spec {
        Spec {
            shards,
            merge,
            lane_threads: 1,
            lru: false,
            on_overflow: OverflowPolicy::Shed,
            ring_capacity: None,
            drain_threshold: None,
            window_ns: 2_000_000,
            plan: FaultPlan::default(),
            checkpoint: None,
            resume: None,
            compact_base: None,
            decay_us: None,
        }
    }

    fn kill_at(mut self, window: u64, path: &str) -> Spec {
        self.plan.kill_after_window = Some(window);
        self.checkpoint = Some(path.to_string());
        self
    }

    fn lanes(mut self, n: usize) -> Spec {
        self.lane_threads = n;
        self
    }

    fn resume_from(mut self, path: &str) -> Spec {
        // Keep the same fault plan (minus nothing — completed kill
        // points cannot re-fire, the driver resumes past them).
        self.resume = Some(path.to_string());
        self
    }
}

/// Run one live canneal session under `spec`, capturing every rendered
/// window (plus degraded markers) exactly as a human sink would show
/// them.
fn run_spec(spec: &Spec) -> (anyhow::Result<SessionOutput>, Vec<String>) {
    let app = apps::canneal(8, 5);
    let mut gcfg = GappConfig {
        shards: Some(spec.shards),
        merge: spec.merge,
        lane_threads: spec.lane_threads,
        on_overflow: spec.on_overflow,
        ..Default::default()
    };
    if let Some(cap) = spec.ring_capacity {
        gcfg.ring_capacity = cap;
    }
    if let Some(t) = spec.drain_threshold {
        gcfg.drain_threshold = t;
    }
    if spec.lru {
        gcfg.stack_lru = true;
        gcfg.stack_map_entries = 4;
    }
    gcfg.compact_base = spec.compact_base;
    gcfg.decay_half_life_us = spec.decay_us;
    let lines = Rc::new(RefCell::new(Vec::<String>::new()));
    let l2 = lines.clone();
    let mut session = Session::builder(AnalysisEngine::native())
        .app(&app)
        .config(gcfg)
        .live(LiveConfig {
            window_ns: spec.window_ns,
            ..Default::default()
        })
        .fault_plan(spec.plan.clone())
        .sink(FnSink(move |ev: &ReportEvent<'_>| {
            let mut lines = l2.borrow_mut();
            match ev {
                ReportEvent::WindowClosed(w) => lines.push(w.to_string()),
                ReportEvent::Degraded {
                    window,
                    drains,
                    widened,
                } => lines.push(format!("degraded {window} {drains} {widened}")),
                _ => {}
            }
        }));
    if let Some(path) = &spec.checkpoint {
        session = session.checkpoint(path);
    }
    if let Some(path) = &spec.resume {
        session = session.restore(path);
    }
    let result = session.run();
    let lines = lines.borrow().clone();
    (result, lines)
}

/// Baseline / crash / resume triple for one spec: assert the recovery
/// identity and return the baseline for further checks.
fn assert_recovery_identity(spec: Spec, kill_after: u64, label: &str) -> SessionOutput {
    let ck = tmp(label);
    let (base, base_lines) = run_spec(&spec);
    let base = base.expect("uninterrupted run");
    // A kill point may sit on any closed window, the last one included
    // (a crash between the final checkpoint and the final report).
    assert!(
        kill_after >= 1 && base.windows.len() as u64 >= kill_after,
        "{label}: kill point {kill_after} needs a longer run \
         ({} windows)",
        base.windows.len()
    );

    let (crash, crash_lines) = run_spec(&spec.clone().kill_at(kill_after, &ck));
    let err = crash.expect_err("the fault plan must kill the run");
    assert!(
        err.to_string()
            .contains(&format!("killed after window {kill_after}")),
        "{label}: {err}"
    );

    let (resumed, resumed_lines) =
        run_spec(&spec.clone().kill_at(kill_after, &ck).resume_from(&ck));
    let resumed = resumed.expect("resumed run");

    // Pre-crash output ++ post-resume output == uninterrupted output,
    // rendered byte for byte (replayed windows are not re-emitted).
    let stitched: Vec<String> = crash_lines
        .iter()
        .chain(&resumed_lines)
        .cloned()
        .collect();
    assert_eq!(stitched, base_lines, "{label}: window streams diverged");

    assert_eq!(resumed.runtime_ns, base.runtime_ns, "{label}");
    assert_eq!(resumed.windows, base.windows, "{label}");
    assert_eq!(resumed.sketch_top, base.sketch_top, "{label}");
    assert_eq!(resumed.sketch_lines, base.sketch_lines, "{label}");
    assert_eq!(resumed.recent_top, base.recent_top, "{label}");
    assert_eq!(resumed.recent_lines, base.recent_lines, "{label}");
    assert_eq!(
        normalize(&resumed.report),
        normalize(&base.report),
        "{label}: final reports diverged"
    );
    let _ = std::fs::remove_file(&ck);
    base
}

#[test]
fn kill_restore_finish_is_byte_identical_across_the_matrix() {
    for shards in [1usize, 4] {
        for merge in [MergeStrategy::Serial, MergeStrategy::Tree] {
            let label = format!("matrix_s{shards}_{merge:?}");
            assert_recovery_identity(Spec::new(shards, merge), 1, &label);
        }
    }
}

#[test]
fn recovery_identity_holds_under_lru_id_recycling() {
    // A 4-entry kernel stack map forces eviction/re-interning; the
    // checkpoint carries the *stable userspace* map, so resumed ids
    // must keep resolving.
    let mut spec = Spec::new(4, MergeStrategy::Tree);
    spec.lru = true;
    let base = assert_recovery_identity(spec, 2, "lru");
    assert_eq!(base.report.stack_drops, 0, "LRU must never drop");
    assert!(base.report.stack_evictions > 0, "map too big to exercise LRU");
}

#[test]
fn recovery_identity_holds_with_active_faults_and_degrade() {
    // The hard case: resume must replay the *same hazards* (bursts +
    // degrade drains + widened windows) to land in the same state.
    let mut spec = Spec::new(2, MergeStrategy::Tree);
    spec.on_overflow = OverflowPolicy::Degrade;
    spec.ring_capacity = Some(256);
    spec.plan.bursts = vec![
        OverflowBurst {
            epoch: 1,
            cpu: 0,
            records: 300,
        },
        OverflowBurst {
            epoch: 3,
            cpu: 1,
            records: 300,
        },
    ];
    let base = assert_recovery_identity(spec, 1, "degrade_faults");
    assert!(base.report.degraded_drains > 0, "bursts should force drains");
    assert_eq!(base.report.ring_dropped, 0, "degrade must prevent drops");
}

#[test]
fn a_crash_after_the_final_window_resumes_into_the_same_report() {
    // Checkpoint covers the whole run (crash between the last window's
    // snapshot and the final report): replay finishes the workload and
    // no extra window may appear.
    let spec = Spec::new(4, MergeStrategy::Tree);
    let (probe, _) = run_spec(&spec);
    let last = probe.unwrap().windows.len() as u64;
    assert!(last > 1);
    assert_recovery_identity(spec, last, "final_window");
}

#[test]
fn an_empty_checkpoint_resumes_into_a_full_run() {
    // kill_after_window 0: die right after the start-of-session
    // snapshot. Resuming replays nothing and runs everything.
    let ck = tmp("empty");
    let spec = Spec::new(2, MergeStrategy::Serial);
    let (base, base_lines) = run_spec(&spec);
    let base = base.unwrap();

    let (crash, crash_lines) = run_spec(&spec.clone().kill_at(0, &ck));
    assert!(crash
        .unwrap_err()
        .to_string()
        .contains("killed after window 0"));
    assert!(crash_lines.is_empty(), "no window may close before kill 0");
    let cp = Checkpoint::load(&ck).unwrap();
    assert_eq!(cp.epochs, 0);
    assert!(cp.summaries.is_empty());
    assert!(cp.cumulative.is_empty());

    let (resumed, lines) = run_spec(&spec.clone().kill_at(0, &ck).resume_from(&ck));
    let resumed = resumed.unwrap();
    assert_eq!(lines, base_lines);
    assert_eq!(normalize(&resumed.report), normalize(&base.report));
    let _ = std::fs::remove_file(&ck);
}

#[test]
fn batch_sessions_checkpoint_and_resume_too() {
    let app = || apps::canneal(8, 5);
    let run = |ck: Option<&str>, resume: Option<&str>, kill: bool| {
        let a = app();
        let mut plan = FaultPlan::default();
        if kill {
            plan.kill_after_window = Some(0);
        }
        let mut s = Session::builder(AnalysisEngine::native())
            .app(&a)
            .fault_plan(plan);
        if let Some(p) = ck {
            s = s.checkpoint(p);
        }
        if let Some(p) = resume {
            s = s.restore(p);
        }
        s.run()
    };
    let base = run(None, None, false).unwrap();
    let ck = tmp("batch");
    let err = run(Some(&ck), None, true).unwrap_err();
    assert!(err.to_string().contains("killed after window 0"), "{err}");
    let resumed = run(Some(&ck), Some(&ck), true).unwrap();
    assert_eq!(resumed.runtime_ns, base.runtime_ns);
    assert_eq!(normalize(&resumed.report), normalize(&base.report));
    let _ = std::fs::remove_file(&ck);
}

#[test]
fn resume_rejects_foreign_or_mismatched_checkpoints() {
    let ck = tmp("mismatch");
    let spec = Spec::new(4, MergeStrategy::Tree);
    let (crash, _) = run_spec(&spec.clone().kill_at(1, &ck));
    crash.unwrap_err();

    // Different shard count: the fingerprint names the knob.
    let (r, _) = run_spec(&Spec::new(1, MergeStrategy::Tree).resume_from(&ck));
    let err = r.unwrap_err().to_string();
    assert!(err.contains("shards"), "{err}");
    assert!(err.contains("different configuration"), "{err}");

    // Different merge strategy likewise.
    let (r, _) = run_spec(&Spec::new(4, MergeStrategy::Serial).resume_from(&ck));
    let err = r.unwrap_err().to_string();
    assert!(err.contains("merge"), "{err}");

    // A live checkpoint cannot seed a batch session.
    let a = apps::canneal(8, 5);
    let err = Session::builder(AnalysisEngine::native())
        .app(&a)
        .restore(&ck)
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("mode"), "{err}");

    // Corrupt checkpoint bytes: a descriptive error, never a panic.
    let garbled = tmp("garbled");
    std::fs::write(&garbled, "{\"checkpoint\": 1, \"epochs\": \"many\"}").unwrap();
    let (r, _) = run_spec(&spec.clone().resume_from(&garbled));
    r.unwrap_err();

    // Foreign version: rejected by policy, naming both versions.
    std::fs::write(&garbled, "{\"checkpoint\": 2}").unwrap();
    let (r, _) = run_spec(&spec.resume_from(&garbled));
    let err = r.unwrap_err().to_string();
    assert!(err.contains('2') && err.contains('1'), "{err}");

    let _ = std::fs::remove_file(&ck);
    let _ = std::fs::remove_file(&garbled);
}

#[test]
fn serial_and_tree_checkpoints_are_byte_identical() {
    // The checkpoint document is canonical: both merge strategies must
    // snapshot the *same state* (modulo the fingerprint naming the
    // strategy), or a restore could not hop the report-identity proof
    // from one strategy to the other.
    let docs: Vec<String> = [MergeStrategy::Serial, MergeStrategy::Tree]
        .into_iter()
        .map(|merge| {
            let ck = tmp(&format!("canon_{merge:?}"));
            let (crash, _) = run_spec(&Spec::new(4, merge).kill_at(2, &ck));
            crash.unwrap_err();
            let doc = std::fs::read_to_string(&ck).unwrap();
            let _ = std::fs::remove_file(&ck);
            doc
        })
        .collect();
    assert_eq!(
        docs[0].replace("serial", "tree"),
        docs[1],
        "checkpoints must agree on everything but the strategy name"
    );
}

#[test]
fn a_resume_may_change_the_lane_thread_count() {
    // `lane_threads` is the one fingerprint knob a resume may legally
    // change: lane workers decide *who* folds a shard, never what the
    // fold produces. A checkpoint written single-threaded resumes under
    // 4 lane workers (and vice versa) into the same window stream and
    // final report the uninterrupted run produces.
    let base_spec = Spec::new(4, MergeStrategy::Tree);
    let (base, base_lines) = run_spec(&base_spec);
    let base = base.unwrap();

    for (write_threads, resume_threads) in [(1usize, 4usize), (4, 1)] {
        let label = format!("hop_{write_threads}to{resume_threads}");
        let ck = tmp(&label);
        let (crash, crash_lines) =
            run_spec(&base_spec.clone().lanes(write_threads).kill_at(2, &ck));
        crash.unwrap_err();

        let (resumed, resumed_lines) = run_spec(
            &base_spec
                .clone()
                .lanes(resume_threads)
                .kill_at(2, &ck)
                .resume_from(&ck),
        );
        let resumed = resumed.expect("a thread-count hop must resume");
        let stitched: Vec<String> = crash_lines
            .iter()
            .chain(&resumed_lines)
            .cloned()
            .collect();
        assert_eq!(stitched, base_lines, "{label}");
        assert_eq!(resumed.windows, base.windows, "{label}");
        assert_eq!(resumed.sketch_top, base.sketch_top, "{label}");
        assert_eq!(
            normalize(&resumed.report),
            normalize(&base.report),
            "{label}"
        );
        let _ = std::fs::remove_file(&ck);
    }
}

#[test]
fn recovery_identity_holds_under_tier_compaction_at_a_fold_boundary() {
    // PR 10: under `--compact-base 2`, window 2 fills level 0 and
    // cascades into level 1 — a checkpoint published right after it
    // snapshots a freshly folded pyramid, so killing there exercises
    // restore *at* a tier boundary. Killing after window 3 restores a
    // half-full level 0 instead. Both must finish byte-identical to
    // the uninterrupted compacted run, which itself must report
    // byte-identically to the flat (uncompacted) run.
    let mut spec = Spec::new(4, MergeStrategy::Tree);
    spec.compact_base = Some(2);
    spec.decay_us = Some(1_000);
    for kill_after in [2u64, 3] {
        let label = format!("compact_kill{kill_after}");
        let base = assert_recovery_identity(spec.clone(), kill_after, &label);
        assert!(
            !base.recent_top.is_empty(),
            "{label}: the decayed sketch should have survived the round trip"
        );
        let (flat, _) = run_spec(&Spec::new(4, MergeStrategy::Tree));
        assert_eq!(
            normalize(&base.report),
            normalize(&flat.unwrap().report),
            "{label}: compaction must not move the report by a byte"
        );
    }
}

#[test]
fn compacted_checkpoints_carry_tiers_instead_of_flat_vectors() {
    // Checkpoint-size governance: with compaction on, the snapshot
    // serializes the O(B·log T) tier pyramid and drops the flat
    // per-window vectors entirely — that is where the bounded-disk
    // claim comes from (CI asserts the size ratio on a long run).
    let ck = tmp("compact_doc");
    let mut spec = Spec::new(4, MergeStrategy::Tree);
    spec.compact_base = Some(2);
    spec.decay_us = Some(1_000);
    let (crash, _) = run_spec(&spec.clone().kill_at(3, &ck));
    crash.unwrap_err();
    let cp = Checkpoint::load(&ck).unwrap();
    assert!(cp.summaries.is_empty(), "flat summaries must be folded away");
    assert!(cp.cumulative.is_empty(), "flat cumulative must be folded away");
    let tiers = cp.tiers.as_ref().expect("a compacting session snapshots tiers");
    assert_eq!(tiers.base, 2);
    assert_eq!(tiers.windows_total, 3);
    assert!(cp.recent.is_some(), "the decayed sketch snapshots too");
    let fp = cp.fingerprint.as_ref().unwrap();
    assert_eq!(fp.compact_base, 2);
    assert_eq!(fp.decay_half_life_us, 1_000);
    let _ = std::fs::remove_file(&ck);
}

#[test]
fn resume_rejects_a_compaction_knob_change() {
    // The tier pyramid's shape depends on the base and the decayed
    // sketch on its half-life: a resume under different knobs could
    // not reproduce the uninterrupted run, so the fingerprint rejects
    // it, naming the knob both ways (on→off and off→on).
    let ck = tmp("compact_mismatch");
    let mut compacted = Spec::new(4, MergeStrategy::Tree);
    compacted.compact_base = Some(2);
    compacted.decay_us = Some(1_000);
    let (crash, _) = run_spec(&compacted.clone().kill_at(2, &ck));
    crash.unwrap_err();

    // Base change and compaction turned off both name the knob.
    let mut other = compacted.clone();
    other.compact_base = Some(3);
    let (r, _) = run_spec(&other.resume_from(&ck));
    let err = r.unwrap_err().to_string();
    assert!(err.contains("compact_base"), "{err}");

    let mut off = compacted.clone();
    off.compact_base = None;
    let (r, _) = run_spec(&off.resume_from(&ck));
    let err = r.unwrap_err().to_string();
    assert!(err.contains("compact_base"), "{err}");

    // Half-life change likewise.
    let mut decay = compacted.clone();
    decay.decay_us = Some(2_000);
    let (r, _) = run_spec(&decay.resume_from(&ck));
    let err = r.unwrap_err().to_string();
    assert!(err.contains("decay_half_life_us"), "{err}");

    // A flat checkpoint cannot seed a compacting session either.
    let flat_ck = tmp("flat_for_compact");
    let (crash, _) =
        run_spec(&Spec::new(4, MergeStrategy::Tree).kill_at(2, &flat_ck));
    crash.unwrap_err();
    let (r, _) = run_spec(&compacted.clone().resume_from(&flat_ck));
    let err = r.unwrap_err().to_string();
    assert!(err.contains("compact_base"), "{err}");

    let _ = std::fs::remove_file(&ck);
    let _ = std::fs::remove_file(&flat_ck);
}

#[test]
fn thread_count_checkpoints_differ_only_in_the_fingerprint() {
    // Lane workers fold eagerly off-thread, but window close merges
    // everything back onto the driver before the snapshot is taken, so
    // the only trace of the thread count in the checkpoint bytes is the
    // fingerprint's provenance field.
    let docs: Vec<String> = [1usize, 4]
        .into_iter()
        .map(|threads| {
            let ck = tmp(&format!("lanes_{threads}"));
            let spec = Spec::new(4, MergeStrategy::Tree)
                .lanes(threads)
                .kill_at(2, &ck);
            let (crash, _) = run_spec(&spec);
            crash.unwrap_err();
            let doc = std::fs::read_to_string(&ck).unwrap();
            let _ = std::fs::remove_file(&ck);
            doc
        })
        .collect();
    assert_eq!(
        docs[0].replace("\"lane_threads\":1", "\"lane_threads\":4"),
        docs[1],
        "checkpoints must agree on everything but the thread count"
    );
}

#[test]
fn bursts_drop_under_shed_and_are_absorbed_under_degrade() {
    let bursts = vec![
        OverflowBurst {
            epoch: 1,
            cpu: 0,
            records: 400,
        },
        OverflowBurst {
            epoch: 2,
            cpu: 0,
            records: 400,
        },
    ];
    let mut shed = Spec::new(1, MergeStrategy::Tree);
    shed.ring_capacity = Some(256); // below the drain watermark: no relief
    shed.plan.bursts = bursts.clone();
    let (out, lines) = run_spec(&shed);
    let out = out.unwrap();
    assert!(
        out.report.ring_dropped > 0,
        "400-record bursts into a 256-slot ring must shed"
    );
    assert_eq!(out.report.degraded_windows, 0);
    assert_eq!(out.report.degraded_drains, 0);
    assert!(
        lines.iter().all(|l| !l.starts_with("degraded")),
        "shed must not emit Degraded events"
    );

    let mut degrade = shed.clone();
    degrade.on_overflow = OverflowPolicy::Degrade;
    let (out, lines) = run_spec(&degrade);
    let out = out.unwrap();
    assert_eq!(out.report.ring_dropped, 0, "degrade must prevent the drops");
    assert!(out.report.degraded_drains > 0, "…by emergency-draining");
    assert!(
        out.report.degraded_windows > 0,
        "a drained window widens once to let the consumer catch up"
    );
    assert!(
        lines.iter().any(|l| l.starts_with("degraded")),
        "degradation must be visible in the event stream"
    );
    // Both policies finish; degradation trades fidelity, never survival.
}

#[test]
fn a_stalled_shard_with_adequate_buffering_is_invisible() {
    // An aggressive watermark (drain at 8 queued records) makes the
    // reader's mid-epoch drains part of normal operation; stalling one
    // shard suppresses exactly those drains. With ample ring capacity
    // the stalled lane just buffers until the window-close epoch drain
    // catches it up — drain *timing* changes, the output must not.
    let mut clean = Spec::new(4, MergeStrategy::Tree);
    clean.drain_threshold = Some(8);
    clean.window_ns = 5_000_000;
    let (base, base_lines) = run_spec(&clean);
    let base = base.unwrap();

    let mut stalled = clean.clone();
    stalled.plan.stall = Some(StallSpec {
        shard: 0,
        from_epoch: 1,
        epochs: 2,
    });
    let (out, lines) = run_spec(&stalled);
    let out = out.unwrap();
    assert_eq!(out.report.ring_dropped, 0);
    assert_eq!(lines, base_lines);
    assert_eq!(normalize(&out.report), normalize(&base.report));

    // An undersized ring alone is still safe: the watermark drains at 8
    // queued records and no single kernel event pushes more than a
    // handful, so a 16-record ring never overflows…
    let mut tight = clean.clone();
    tight.shards = 1;
    tight.ring_capacity = Some(16);
    let (control, _) = run_spec(&tight);
    assert_eq!(control.unwrap().report.ring_dropped, 0);

    // …but wedge its reader for the whole run and the watermark can't
    // save it. canneal at 5 ms windows overflows a 16-record ring
    // without mid-epoch drains (the sharded-drops golden proves it),
    // so records shed, the drops are attributed to the stalled shard,
    // and the session still completes — degradation, not death.
    tight.plan.stall = Some(StallSpec {
        shard: 0,
        from_epoch: 1,
        epochs: 1_000,
    });
    let (out, _) = run_spec(&tight);
    let out = out.unwrap();
    assert!(out.report.ring_dropped > 0);
    assert_eq!(out.report.ring_shards.len(), 1);
    assert_eq!(out.report.ring_shards[0].dropped, out.report.ring_dropped);
}

/// Shared capture buffer so a consuming sink's output can be read back.
#[derive(Clone, Default)]
struct SharedBuf(Rc<RefCell<Vec<u8>>>);

impl io::Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.borrow_mut().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

#[test]
fn corrupt_shard_partial_streams_are_quarantined_end_to_end() {
    // Produce a real JSONL stream with per-shard partial events…
    let app = apps::canneal(8, 5);
    let buf = SharedBuf::default();
    Session::builder(AnalysisEngine::native())
        .app(&app)
        .config(GappConfig {
            shards: Some(4),
            ..Default::default()
        })
        .live(LiveConfig {
            window_ns: 2_000_000,
            shard_partials: true,
            ..Default::default()
        })
        .sink(JsonlSink::new(buf.clone()))
        .run()
        .unwrap();
    let clean = String::from_utf8(buf.0.borrow().clone()).unwrap();
    assert!(clean.contains("\"shard_window\""));

    // …aggregate it cleanly: every line is valid, partials merge.
    let mut agg = PartialAggregator::new();
    agg.ingest("clean", &clean);
    let stats = agg.producers()[0].stats.clone();
    assert_eq!(stats.quarantined, 0, "{:?}", stats.first_error);
    assert!(stats.partials > 0);
    assert!(!agg.top(5).is_empty());

    // …then corrupt every third line: quarantine counts it, the reader
    // survives, and the intact lines still merge.
    let dirty = corrupt_jsonl(&clean, 0x5EED, 3);
    let mut agg = PartialAggregator::new();
    agg.ingest("dirty", &dirty);
    let stats = agg.producers()[0].stats.clone();
    assert!(stats.quarantined >= 1, "{stats:?}");
    assert!(stats.first_error.is_some());
    assert!(stats.partials > 0, "intact partials must still merge");
    let report = agg.render(5);
    assert!(report.contains("quarantined"));
}
