//! Golden-report regression tests for the stack-interning refactor.
//!
//! Critical-slice call paths travel through the pipeline as interned
//! `u32` stack ids instead of owned frame vectors. These tests pin down
//! that this changed the *representation*, not the *results*:
//!
//! 1. Profiling a fixed-seed app twice yields byte-identical ranked
//!    call paths and per-thread CMetric totals (determinism golden).
//! 2. Merging by stack id is exactly equivalent to merging by resolved
//!    frames — recomputed independently from the raw slices against the
//!    kernel stack map (semantic golden: interning is lossless).

// The deprecated `profile` wrapper stays under golden coverage: it must
// keep producing byte-identical results to the Session it delegates to.
#![allow(deprecated)]

use std::collections::BTreeMap;

use gapp::gapp::{profile, GappConfig, GappSession, MergeStrategy};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::{Kernel, KernelConfig};
use gapp::workload::apps;
use gapp::workload::App;

/// The stable fingerprint of a profile: ranked symbolized call paths
/// with their CMetric/slice totals, plus per-thread CMetric totals.
fn fingerprint(app: &App) -> (Vec<(Vec<String>, u64, u64)>, Vec<(u32, u64, u64)>) {
    let (report, _) = profile(
        app,
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
    )
    .unwrap();
    let paths = report
        .bottlenecks
        .iter()
        .map(|b| {
            (
                b.call_path.clone(),
                // Round through fixed-point so the fingerprint is exact.
                (b.total_cm_ms * 1e6) as u64,
                b.slices,
            )
        })
        .collect();
    let threads = report
        .threads
        .iter()
        .map(|t| (t.pid, (t.cm_ms * 1e6) as u64, (t.wall_ms * 1e6) as u64))
        .collect();
    (paths, threads)
}

#[test]
fn fixed_seed_profiles_are_byte_identical() {
    for mk in [
        (|| apps::blackscholes(8, 3)) as fn() -> App,
        || apps::canneal(8, 5),
    ] {
        let a = fingerprint(&mk());
        let b = fingerprint(&mk());
        assert_eq!(a, b, "profile fingerprint changed between identical runs");
        assert!(!a.0.is_empty(), "no bottlenecks found");
        assert!(!a.1.is_empty(), "no per-thread totals");
    }
}

#[test]
fn merge_by_stack_id_equals_merge_by_frames() {
    for mk in [
        (|| apps::blackscholes(8, 3)) as fn() -> App,
        || apps::canneal(8, 5),
    ] {
        let app = mk();
        // Serial merge: this test re-derives the reference from the raw
        // slice buffer, which only the serial consumer retains in
        // `core.user` (the tree strategy folds slices in per-shard
        // lanes; its equivalence has its own goldens).
        let session = GappSession::new(
            GappConfig {
                merge: MergeStrategy::Serial,
                ..Default::default()
            },
            64,
            AnalysisEngine::native(),
        )
        .unwrap();
        let mut kernel = Kernel::new(KernelConfig::default());
        kernel.attach_probe(session.probe());
        app.spawn_into(&mut kernel);
        let end = kernel.run().unwrap();
        let _report = session.finish(&app, &kernel, end);

        let mut core = session.core.borrow_mut();
        // These runs must fit the stack map: interning may never have
        // dropped a path, or the comparison below is vacuous.
        assert_eq!(core.kernel.stacks.stats.drops, 0);
        assert!(core.kernel.stacks.len() > 0, "no stacks interned");

        // Reference: group raw slices by *resolved frames* (exactly what
        // the pre-interning pipeline hashed on).
        let mut by_frames: BTreeMap<Vec<u64>, (f64, u64)> = BTreeMap::new();
        for s in core.user.slices().to_vec() {
            let frames = core.kernel.stacks.resolve(s.stack_id).to_vec();
            let e = by_frames.entry(frames).or_insert((0.0, 0));
            e.0 += s.cm_ns;
            e.1 += 1;
        }

        // Under test: the id-grouped merge, over ALL paths (top_n large
        // enough to rank everything the native backend returns).
        let merged = core.user.merge_and_rank(usize::MAX / 2);
        let mut by_id: BTreeMap<Vec<u64>, (f64, u64)> = BTreeMap::new();
        for m in &merged {
            let frames = core.kernel.stacks.resolve(m.stack_id).to_vec();
            let prev = by_id.insert(frames, (m.total_cm_ns, m.slices));
            assert!(prev.is_none(), "two merged paths resolved to one stack");
        }

        // Ranking excludes zero-CMetric paths; mirror that in the
        // reference before comparing.
        by_frames.retain(|_, (cm, _)| *cm > 0.0);
        assert_eq!(
            by_frames.keys().collect::<Vec<_>>(),
            by_id.keys().collect::<Vec<_>>(),
            "id-merge and frame-merge disagree on the path set"
        );
        for (frames, (cm, n)) in &by_frames {
            let (cm2, n2) = by_id[frames];
            assert_eq!(*n, n2, "slice count differs for {frames:?}");
            assert!(
                (cm - cm2).abs() < 1e-6 * cm.max(1.0),
                "CMetric differs for {frames:?}: {cm} vs {cm2}"
            );
        }
    }
}
