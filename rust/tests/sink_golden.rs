//! Sink-subsystem goldens: the output seam must not move a byte.
//!
//! 1. `HumanSink` is byte-identical to the pre-sink CLI — batch
//!    (`println!("{report}")`) and live (windows as they close + final
//!    header + cumulative sketch + lossy note), `--shards 1` and
//!    `--shards 4`.
//! 2. JSON round-trip: a `JsonSink` document parsed back through
//!    `report_from_json` re-renders *byte-identically* to the direct
//!    text golden of the same run.
//! 3. JSONL: concatenating the live window events reconstructs
//!    `Report::window_drops` exactly, drop for drop.
//! 4. The deprecated wrappers (`profile`, `run_live`) stay equivalent
//!    to the `Session` driver they delegate to.

// (4) exercises the deprecated wrappers on purpose.
#![allow(deprecated)]

use std::cell::RefCell;
use std::io;
use std::rc::Rc;

use gapp::gapp::sink::human::{render_live_tail, render_report, render_window};
use gapp::gapp::sink::{
    report_from_json, FinalEvent, FnSink, HumanSink, JsonSink, JsonlSink, ReportEvent,
};
use gapp::gapp::stream::{run_live, LiveConfig};
use gapp::gapp::{profile, GappConfig, Report, Session};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::KernelConfig;
use gapp::util::json::Json;
use gapp::workload::apps;

/// An `io::Write` the test can read back after the session consumed
/// the sink.
#[derive(Clone, Default)]
struct SharedBuf(Rc<RefCell<Vec<u8>>>);

impl SharedBuf {
    fn take_string(&self) -> String {
        String::from_utf8(std::mem::take(&mut *self.0.borrow_mut())).unwrap()
    }
}

impl io::Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.borrow_mut().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Zero host-timing fields so two *separate* fixed-seed runs compare
/// exactly (within one run nothing needs normalizing).
fn normalize(r: &mut Report) {
    r.ppt_seconds = 0.0;
    r.memory_bytes = 0;
}

#[test]
fn batch_human_sink_is_byte_identical_to_println_of_the_report() {
    for shards in [1usize, 4] {
        let app = apps::canneal(8, 5);
        let buf = SharedBuf::default();
        let out = Session::builder(AnalysisEngine::native())
            .config(GappConfig {
                shards: Some(shards),
                ..Default::default()
            })
            .app(&app)
            .sink(HumanSink::new(buf.clone()))
            .run()
            .unwrap();
        // Exactly what `println!("{report}")` printed before sinks.
        assert_eq!(
            buf.take_string(),
            format!("{}\n", out.report),
            "--shards {shards}: HumanSink drifted from the batch golden"
        );
    }
}

#[test]
fn live_human_sink_is_byte_identical_to_the_old_cli_assembly() {
    for shards in [1usize, 4] {
        let app = apps::canneal(8, 5);
        let buf = SharedBuf::default();
        // Collect the window renderings through a tee'd callback sink —
        // the pre-sink CLI printed each window with `print!("{w}")`.
        let windows_text = Rc::new(RefCell::new(String::new()));
        let wt = windows_text.clone();
        let out = Session::builder(AnalysisEngine::native())
            .config(GappConfig {
                shards: Some(shards),
                ..Default::default()
            })
            .app(&app)
            .live(LiveConfig {
                window_ns: 2_000_000,
                ..Default::default()
            })
            .sink(HumanSink::new(buf.clone()))
            .sink(FnSink(|ev: &ReportEvent<'_>| {
                if let ReportEvent::WindowClosed(w) = ev {
                    wt.borrow_mut().push_str(&w.to_string());
                }
            }))
            .run()
            .unwrap();
        assert!(out.windows.len() > 1, "need a multi-window run");
        // Reassemble what the pre-sink `cmd_live` printed.
        let mut expected = windows_text.borrow().clone();
        expected.push_str(&render_live_tail(&FinalEvent {
            report: &out.report,
            windows: &out.windows,
            windows_total: out.report.windows_total,
            sketch_top: &out.sketch_top,
            sketch_lines: &out.sketch_lines,
            recent_top: &out.recent_top,
            recent_lines: &out.recent_lines,
        }));
        assert_eq!(
            buf.take_string(),
            expected,
            "--shards {shards}: HumanSink drifted from the live golden"
        );
        // The tail itself matches the historical line-by-line format.
        assert!(expected.contains(&format!(
            "\n== final (merged from {} windows) ==\n",
            out.windows.len()
        )));
        assert!(expected
            .contains("cumulative top-"));
    }
}

#[test]
fn json_round_trip_re_renders_to_the_text_golden() {
    // The satellite golden: JsonSink output for the fixed-seed canneal
    // profile, re-rendered through the HumanSink logic, must byte-match
    // the direct text golden of the same run.
    let app = apps::canneal(8, 5);
    let buf = SharedBuf::default();
    let out = Session::builder(AnalysisEngine::native())
        .app(&app)
        .sink(JsonSink::new(buf.clone()))
        .run()
        .unwrap();
    let doc = Json::parse(&buf.take_string()).expect("JsonSink emits valid JSON");
    assert_eq!(doc.get("schema").unwrap().as_u64(), Some(1));
    assert_eq!(doc.get("type").unwrap().as_str(), Some("gapp.session"));
    assert_eq!(
        doc.get("runtime_ns").unwrap().as_u64(),
        Some(out.runtime_ns)
    );
    assert_eq!(
        doc.get("session")
            .and_then(|s| s.get("mode"))
            .and_then(|m| m.as_str()),
        Some("batch")
    );
    let rt = report_from_json(doc.get("report").unwrap()).unwrap();
    assert_eq!(
        render_report(&rt),
        render_report(&out.report),
        "JSON round-trip changed the rendered report"
    );
    // Fields the renderer elides must round-trip too.
    assert_eq!(rt.runtime_ns, out.report.runtime_ns);
    assert_eq!(rt.probe_cost_ns, out.report.probe_cost_ns);
    assert_eq!(rt.intervals, out.report.intervals);
    assert_eq!(rt.window_drops, out.report.window_drops);
}

#[test]
fn jsonl_window_events_reconstruct_window_drops_exactly() {
    // Tiny single ring + disabled mid-epoch drain forces overflow, so
    // the per-window drop attribution is non-trivial (some windows
    // lossy, some not).
    let app = apps::canneal(8, 5);
    let buf = SharedBuf::default();
    let out = Session::builder(AnalysisEngine::native())
        .config(GappConfig {
            ring_capacity: 64,
            shards: Some(1),
            drain_threshold: usize::MAX,
            ..Default::default()
        })
        .app(&app)
        .live(LiveConfig {
            window_ns: 5_000_000,
            ..Default::default()
        })
        .sink(JsonlSink::new(buf.clone()))
        .run()
        .unwrap();
    assert!(
        out.report.ring_dropped > 0,
        "the forced-overflow setup stopped overflowing"
    );
    let text = buf.take_string();
    let mut events: Vec<Json> = Vec::new();
    for line in text.lines() {
        let v = Json::parse(line).expect("every JSONL line parses alone");
        assert_eq!(v.get("schema").unwrap().as_u64(), Some(1));
        events.push(v);
    }
    // Framing: session_start, windows…, final, session_end.
    let kinds: Vec<&str> = events
        .iter()
        .map(|e| e.get("event").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(kinds.first(), Some(&"session_start"));
    assert_eq!(kinds.last(), Some(&"session_end"));
    assert_eq!(kinds[kinds.len() - 2], "final");
    // Concatenated window events reconstruct Report::window_drops.
    let drops: Vec<u64> = events
        .iter()
        .filter(|e| e.get("event").unwrap().as_str() == Some("window"))
        .map(|e| {
            e.get("window")
                .and_then(|w| w.get("drops"))
                .and_then(|d| d.as_u64())
                .unwrap()
        })
        .collect();
    assert_eq!(
        drops, out.report.window_drops,
        "JSONL window stream disagrees with the report's attribution"
    );
    // And the embedded final report round-trips those same drops.
    let final_ev = &events[events.len() - 2];
    let rt = report_from_json(final_ev.get("report").unwrap()).unwrap();
    assert_eq!(rt.window_drops, out.report.window_drops);
    assert_eq!(render_report(&rt), render_report(&out.report));
}

#[test]
fn deprecated_wrappers_match_the_session_driver() {
    // profile() is now a wrapper over Session: same fixed seed, same
    // (normalized) report.
    let (mut a, _) = profile(
        &apps::canneal(8, 5),
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
    )
    .unwrap();
    let app = apps::canneal(8, 5);
    let mut b = Session::builder(AnalysisEngine::native())
        .app(&app)
        .run()
        .unwrap()
        .report;
    normalize(&mut a);
    normalize(&mut b);
    assert_eq!(a.to_string(), b.to_string());

    // run_live() relays every WindowClosed event to its callback.
    let app = apps::canneal(8, 5);
    let mut seen: Vec<String> = Vec::new();
    let run = run_live(
        std::slice::from_ref(&app),
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
        LiveConfig {
            window_ns: 2_000_000,
            ..Default::default()
        },
        |w| seen.push(render_window(w)),
    )
    .unwrap();
    assert_eq!(seen.len(), run.windows.len());
    assert!(seen.len() > 1);
    // Strip the streaming-only window accounting (the batch reference
    // closed no windows): the per-window vector and the aggregates the
    // renderer keys the "windows N" line off.
    let strip_windows = |r: &mut Report| {
        r.window_drops = Vec::new();
        r.windows_total = 0;
        r.windows_lossy = 0;
        r.windows_drop_total = 0;
    };
    let mut c = run.report;
    normalize(&mut c);
    strip_windows(&mut c);
    let mut d = b;
    strip_windows(&mut d);
    assert_eq!(c.to_string(), d.to_string());
}
