//! Fleet-aggregation goldens: the cross-process merge must be exactly
//! as trustworthy as the in-process one.
//!
//! 1. Split invariance (the acceptance property): the same captured
//!    windows, split across 1, 2, or N producers — deterministically or
//!    at random — merge to a byte-identical top-N report, with and
//!    without symbol exchange.
//! 2. Raw-id fallback: on a capture with no `symbols` events the new
//!    [`FleetMerge`] renders byte-identically to the historical
//!    [`PartialAggregator`].
//! 3. Quarantine isolation: a corrupt / foreign-schema producer is
//!    counted and reported without perturbing its peers' merge by a
//!    byte.
//! 4. The live service: two producers streaming over a real Unix
//!    socket through [`serve_on`] produce the same top-N as a one-shot
//!    offline aggregation, and the *merged stream it re-emits* is
//!    itself a valid capture — re-aggregating it reproduces the report
//!    (hierarchical aggregation).
//! 5. Symbol round-trip: every merged global id resolves back to
//!    frames some producer announced, and renders by producer-side
//!    symbolization, not raw ids.

use std::cell::RefCell;
use std::io;
use std::os::unix::net::{UnixListener, UnixStream};
use std::rc::Rc;

use gapp::fleet::{serve_on, FleetMerge, ServeConfig};
use gapp::gapp::sink::{JsonlSink, ReportSink};
use gapp::gapp::stream::partials::{parse_envelope, parse_symbols, PartialAggregator};
use gapp::gapp::stream::LiveConfig;
use gapp::gapp::{GappConfig, Session};
use gapp::runtime::AnalysisEngine;
use gapp::simkernel::KernelConfig;
use gapp::util::check::property;
use gapp::workload::apps;

/// An `io::Write` the test can read back after the sink consumed it.
#[derive(Clone, Default)]
struct SharedBuf(Rc<RefCell<Vec<u8>>>);

impl SharedBuf {
    fn take_string(&self) -> String {
        String::from_utf8(std::mem::take(&mut *self.0.borrow_mut())).unwrap()
    }
}

impl io::Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.borrow_mut().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Capture one live session as a producer would ship it: JSONL with
/// per-shard window partials and `symbols` announcements.
fn capture(seed: u64, shards: usize) -> String {
    let app = apps::canneal(8, seed);
    let buf = SharedBuf::default();
    Session::builder(AnalysisEngine::native())
        .kernel(KernelConfig::default())
        .config(GappConfig {
            shards: Some(shards),
            ..Default::default()
        })
        .app(&app)
        .live(LiveConfig {
            window_ns: 2_000_000,
            shard_partials: true,
            ..Default::default()
        })
        .sink(JsonlSink::new(buf.clone()))
        .run()
        .unwrap();
    buf.take_string()
}

fn event_kind(line: &str) -> String {
    parse_envelope(line).expect("capture line must be valid v1").event
}

/// The split-invariant tail of a fleet report (the accounting lines
/// above it legitimately vary with how the stream was split).
fn top_section(report: &str) -> &str {
    let i = report
        .find("top ")
        .or_else(|| report.find("no partials merged"))
        .expect("report has no top section");
    &report[i..]
}

/// Split a capture into `n` producer streams: every producer gets the
/// full `symbols` prologue (re-announcing identical frames is a no-op
/// by the id-stability contract) and window `i` goes to the producer
/// `assign(i)` picks.
fn split(text: &str, n: usize, mut assign: impl FnMut(usize) -> usize) -> Vec<String> {
    let symbols: String = text
        .lines()
        .filter(|l| !l.trim().is_empty() && event_kind(l) == "symbols")
        .map(|l| format!("{l}\n"))
        .collect();
    let mut streams = vec![symbols; n];
    for (i, l) in text
        .lines()
        .filter(|l| !l.trim().is_empty() && event_kind(l) == "shard_window")
        .enumerate()
    {
        let s = &mut streams[assign(i) % n];
        s.push_str(l);
        s.push('\n');
    }
    streams
}

fn merge_streams(streams: &[String]) -> FleetMerge {
    let mut fleet = FleetMerge::new();
    for (i, s) in streams.iter().enumerate() {
        fleet.ingest(&format!("p{i}"), s);
    }
    fleet
}

#[test]
fn windows_split_across_producers_merge_byte_identically() {
    let text = capture(5, 4);
    let reference = merge_streams(&[text.clone()]);
    assert_eq!(reference.quarantined(), 0);
    let golden = reference.render_top(10);
    assert!(golden.starts_with("top "), "{golden}");

    for n in [2usize, 3, 5] {
        let fleet = merge_streams(&split(&text, n, |i| i));
        assert_eq!(fleet.quarantined(), 0, "split {n}");
        assert_eq!(fleet.producer_count(), n);
        assert_eq!(
            fleet.render_top(10),
            golden,
            "split across {n} producers moved the merged report"
        );
    }
}

#[test]
fn random_splits_and_symbol_presence_never_move_the_report() {
    // Property: any split of the same windows across any number of
    // producers — and stripping the symbol exchange entirely (raw-id
    // fallback) — yields the same top-N as the unsplit stream of the
    // same symbol regime.
    let text = capture(5, 2);
    let raw: String = text
        .lines()
        .filter(|l| !l.trim().is_empty() && event_kind(l) != "symbols")
        .map(|l| format!("{l}\n"))
        .collect();
    let golden = merge_streams(&[text.clone()]).render_top(10);
    let golden_raw = merge_streams(&[raw.clone()]).render_top(10);
    assert_ne!(golden, golden_raw, "symbolized sites must differ from raw ids");
    property("fleet split invariance", 8, |rng| {
        let n = 1 + rng.pick(4);
        let symbolized = rng.chance(0.5);
        let src = if symbolized { &text } else { &raw };
        let fleet = merge_streams(&split(src, n, |_| rng.pick(n)));
        assert_eq!(fleet.quarantined(), 0);
        assert_eq!(
            fleet.render_top(10),
            if symbolized { golden.clone() } else { golden_raw.clone() },
            "random split across {n} producers (symbolized={symbolized})"
        );
    });
}

#[test]
fn raw_id_captures_render_byte_identically_to_the_historical_aggregator() {
    // `gapp aggregate` switched engines (PartialAggregator →
    // FleetMerge); on captures without `symbols` events — everything
    // recorded before this PR — the full report must not move a byte.
    let text = capture(5, 4);
    let raw: String = text
        .lines()
        .filter(|l| !l.trim().is_empty() && event_kind(l) != "symbols")
        .map(|l| format!("{l}\n"))
        .collect();
    let mut old = PartialAggregator::new();
    old.ingest("p0", &raw);
    let mut new = FleetMerge::new();
    new.ingest("p0", &raw);
    assert_eq!(new.render(10), old.render(10));
    assert_eq!(new.render(3), old.render(3));
}

#[test]
fn a_corrupt_producer_is_quarantined_without_perturbing_its_peers() {
    let a = capture(5, 2);
    let b = capture(7, 2);
    let golden = {
        let mut fleet = FleetMerge::new();
        fleet.ingest("a", &a);
        fleet.ingest("b", &b);
        fleet.render_top(10)
    };
    // A producer on a foreign schema version plus assorted bit rot.
    let corrupt = "{\"schema\": 2, \"event\": \"shard_window\"}\n\
                   {not json at all\n\
                   {\"schema\": 1, \"event\": \"shard_window\", \
                   \"shard_window\": {\"paths\": [{\"stack_id\": \"oops\"}]}}\n";
    let mut fleet = FleetMerge::new();
    fleet.ingest("a", &a);
    fleet.ingest("corrupt", corrupt);
    fleet.ingest("b", &b);
    assert_eq!(
        fleet.render_top(10),
        golden,
        "a corrupt peer must not move the merge by a byte"
    );
    let reports = fleet.producers();
    assert_eq!(reports[0].stats.quarantined, 0);
    assert_eq!(reports[2].stats.quarantined, 0);
    assert_eq!(reports[1].stats.quarantined, 3);
    let err = reports[1].stats.first_error.clone().unwrap();
    assert!(err.contains("schema version 2"), "{err}");
    let r = fleet.render(10);
    assert!(r.contains("3 producer(s)"), "{r}");
    assert!(r.contains("corrupt: 0 line(s) ok, 0 partial(s), 3 quarantined"), "{r}");
    assert!(r.contains("first error"), "{r}");
}

#[test]
fn serve_merges_socket_producers_and_the_merged_stream_reaggregates() {
    let a = capture(5, 2);
    let b = capture(7, 2);

    // Offline one-shot reference: the special case `serve` generalizes.
    let mut oneshot = FleetMerge::new();
    oneshot.ingest("a", &a);
    oneshot.ingest("b", &b);
    let golden = oneshot.render_top(10).to_string();

    let dir = std::env::temp_dir().join(format!("gapp-fleet-golden-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let sock = dir.join("fleet.sock");
    let _ = std::fs::remove_file(&sock);
    let listener = UnixListener::bind(&sock).unwrap();

    let buf = SharedBuf::default();
    let mut sinks: Vec<Box<dyn ReportSink>> = vec![Box::new(JsonlSink::new(buf.clone()))];
    let cfg = ServeConfig {
        listen: sock.to_string_lossy().into_owned(),
        producers: 2,
        top: 10,
        // Effectively unbounded: this test wants a lossless merged
        // stream (no forced-late windows), whatever the thread timing.
        horizon: 1 << 20,
        compact_base: None,
    };
    let report = std::thread::scope(|s| {
        for text in [a.clone(), b.clone()] {
            let path = sock.clone();
            s.spawn(move || {
                use std::io::Write;
                let mut c = UnixStream::connect(&path).unwrap();
                c.write_all(text.as_bytes()).unwrap();
                // Dropping the stream is the producer's EOF.
            });
        }
        serve_on(listener, &cfg, &mut sinks).unwrap()
    });
    let _ = std::fs::remove_file(&sock);

    assert_eq!(
        top_section(&report),
        golden,
        "the live service must merge exactly like the one-shot aggregator"
    );
    assert!(report.contains("2 producer(s)"), "{report}");

    // Hierarchical aggregation: the merged session the service
    // re-emitted is itself a valid capture — aggregating it reproduces
    // the same report.
    let merged_stream = buf.take_string();
    assert!(!merged_stream.is_empty(), "serve must re-emit a merged stream");
    let mut again = FleetMerge::new();
    again.ingest("merged", &merged_stream);
    assert_eq!(again.quarantined(), 0, "{merged_stream}");
    assert_eq!(
        again.render_top(10),
        golden,
        "re-aggregating the merged stream must reproduce the report"
    );
}

#[test]
fn merged_global_ids_resolve_back_to_producer_announced_frames() {
    let a = capture(5, 2);
    let b = capture(7, 2);
    // Every frame set any producer announced, straight off the wire.
    let mut announced: Vec<Vec<u64>> = Vec::new();
    for line in a.lines().chain(b.lines()).filter(|l| !l.trim().is_empty()) {
        let env = parse_envelope(line).unwrap();
        if env.event == "symbols" {
            for e in parse_symbols(&env.value).unwrap() {
                announced.push(e.frames);
            }
        }
    }
    assert!(!announced.is_empty(), "captures must carry symbol exchange");

    let mut fleet = FleetMerge::new();
    fleet.ingest("a", &a);
    fleet.ingest("b", &b);
    let top = fleet.top(10);
    assert!(!top.is_empty());
    for p in &top {
        let frames = fleet.resolve(p.stack_id);
        assert!(
            announced.iter().any(|f| f == frames),
            "global id {} resolves to frames no producer announced: {frames:?}",
            p.stack_id
        );
        let site = fleet.site(p.stack_id);
        assert!(
            !site.starts_with("stack ") && site != "??",
            "symbolized capture must not fall back to raw ids: {site}"
        );
    }
}
