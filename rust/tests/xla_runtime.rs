//! End-to-end AOT integration: load the jax/Pallas-lowered HLO artifacts
//! via PJRT, execute them from Rust, and check them against the native
//! twin — the numeric proof that all three layers compose.
//!
//! These tests are skipped (not failed) when `artifacts/` has not been
//! built yet: run `make artifacts` first.

use gapp::runtime::{analysis, AnalysisEngine, XlaEngine, BATCH, T_SLOTS};
use gapp::util::Prng;

/// XLA runs need both the compiled crate feature and built artifacts;
/// missing either skips (does not fail) these tests.
fn xla_available() -> bool {
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the `xla` feature");
        return false;
    }
    let present = gapp::runtime::artifacts_dir()
        .join(format!("cmetric_b{BATCH}_t{T_SLOTS}.hlo.txt"))
        .exists();
    if !present {
        eprintln!("skipping: run `make artifacts` first");
    }
    present
}

fn random_batch(seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Prng::new(seed);
    let a: Vec<f32> = (0..BATCH * T_SLOTS)
        .map(|_| if rng.chance(0.07) { 1.0 } else { 0.0 })
        .collect();
    let t: Vec<f32> = (0..BATCH).map(|_| rng.exp(2e6) as f32).collect();
    (a, t)
}

#[test]
fn xla_analyze_matches_native() {
    if !xla_available() {
        return;
    }
    let mut e = XlaEngine::load(&gapp::runtime::artifacts_dir()).expect("load artifacts");
    for seed in [1u64, 7, 42] {
        let (a, t) = random_batch(seed);
        let xla = e.analyze(&a, &t).expect("xla analyze");
        let nat = analysis::native_analyze(&a, &t, T_SLOTS);
        for j in 0..T_SLOTS {
            let rel = (xla.cm[j] - nat.cm[j]).abs() / nat.cm[j].abs().max(1.0);
            assert!(rel < 1e-3, "cm[{j}]: {} vs {}", xla.cm[j], nat.cm[j]);
            let relw = (xla.wall[j] - nat.wall[j]).abs() / nat.wall[j].abs().max(1.0);
            assert!(relw < 1e-3, "wall[{j}]");
        }
        let relg =
            (xla.global_cm - nat.global_cm).abs() / nat.global_cm.abs().max(1.0);
        assert!(relg < 1e-3, "gcm: {} vs {}", xla.global_cm, nat.global_cm);
    }
}

#[test]
fn xla_rank_matches_native() {
    if !xla_available() {
        return;
    }
    let mut e = XlaEngine::load(&gapp::runtime::artifacts_dir()).expect("load artifacts");
    let mut rng = Prng::new(9);
    let scores: Vec<f32> = (0..1024).map(|_| rng.exp(1e6) as f32).collect();
    let xla = e.rank(&scores).expect("xla rank");
    let nat = analysis::native_rank(&scores, 16);
    assert_eq!(xla.len(), nat.len());
    for (x, n) in xla.iter().zip(&nat) {
        assert_eq!(x.0, n.0, "index mismatch: {xla:?} vs {nat:?}");
        assert!((x.1 - n.1).abs() < 1e-3);
    }
}

#[test]
fn full_profile_with_xla_backend_matches_kernel_cm_hash() {
    if !xla_available() {
        return;
    }
    use gapp::gapp::{GappConfig, GappSession};
    use gapp::simkernel::{Kernel, KernelConfig};
    use gapp::workload::apps;

    let app = apps::canneal(8, 5);
    let engine = AnalysisEngine::xla().expect("xla engine");
    assert_eq!(engine.backend_name(), "xla");
    let session = GappSession::new(GappConfig::default(), 64, engine).unwrap();
    let mut kernel = Kernel::new(KernelConfig::default());
    kernel.attach_probe(session.probe());
    app.spawn_into(&mut kernel);
    let end = kernel.run().unwrap();
    let report = session.finish(&app, &kernel, end);
    assert_eq!(report.backend, "xla");
    assert!(!report.threads.is_empty());
    let core = session.core.borrow();
    for t in &report.threads {
        let kernel_cm = core.kernel.cm_hash(t.pid);
        let user_cm = t.cm_ms * 1e6;
        let rel = (kernel_cm - user_cm).abs() / kernel_cm.max(1.0);
        assert!(
            rel < 0.02,
            "pid {}: kernel {kernel_cm:.0} vs xla {user_cm:.0} (rel {rel:.4})",
            t.pid
        );
    }
}
