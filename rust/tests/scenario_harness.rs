//! Scenario-harness integration: the checked-in exemplar specs are the
//! contract the CI scenario-smoke job pins.
//!
//! 1. Every spec under `scenarios/` parses, validates, and builds.
//! 2. Each taxonomy class's exemplar achieves recall 1.0 on its own
//!    class — the injected pathology is found *and* labeled correctly.
//! 3. A fixed seed makes the whole pipeline byte-deterministic: two
//!    separate runs render identical scorecards and reports.
//! 4. The scorecard travels the real sink stack (JSONL event line).

use std::cell::RefCell;
use std::io;
use std::rc::Rc;

use gapp::gapp::classify::BottleneckClass;
use gapp::gapp::sink::human::render_scorecard;
use gapp::gapp::sink::JsonlSink;
use gapp::gapp::Report;
use gapp::runtime::AnalysisEngine;
use gapp::scenario::{build_case, run_case, Case, Scenario};
use gapp::util::json::Json;

fn scenarios_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .join("scenarios")
}

fn load(name: &str) -> Scenario {
    let path = scenarios_dir().join(name);
    Scenario::load(path.to_str().unwrap())
        .unwrap_or_else(|e| panic!("{name}: {e}"))
}

fn base_case(sc: &Scenario) -> Case {
    Case {
        index: 0,
        seed: sc.seed,
        threads: None,
    }
}

/// Zero host-timing fields so two *separate* fixed-seed runs compare
/// exactly.
fn normalize(r: &mut Report) {
    r.ppt_seconds = 0.0;
    r.memory_bytes = 0;
}

#[derive(Clone, Default)]
struct SharedBuf(Rc<RefCell<Vec<u8>>>);

impl SharedBuf {
    fn take_string(&self) -> String {
        String::from_utf8(std::mem::take(&mut *self.0.borrow_mut())).unwrap()
    }
}

impl io::Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.borrow_mut().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

#[test]
fn every_checked_in_spec_parses_and_builds() {
    let mut seen = 0;
    for entry in std::fs::read_dir(scenarios_dir()).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let name = path.file_name().unwrap().to_str().unwrap().to_string();
        let sc = Scenario::load(path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        // Every expanded case must compile to apps (matrix overrides
        // included), with one truth label per pathology.
        for case in sc.cases() {
            let setup = build_case(&sc, &case)
                .unwrap_or_else(|e| panic!("{name} {}: {e}", case.label()));
            assert_eq!(setup.truth.len(), sc.pathologies.len(), "{name}");
            assert_eq!(
                setup.apps.len(),
                sc.mix.len() + sc.pathologies.len(),
                "{name}"
            );
        }
        seen += 1;
    }
    assert!(seen >= 7, "expected the 7 exemplar specs, found {seen}");
}

#[test]
fn each_class_exemplar_achieves_full_recall_on_its_class() {
    for (file, class) in [
        ("lock_convoy.json", BottleneckClass::Synchronization),
        ("thread_imbalance.json", BottleneckClass::Imbalance),
        ("pipeline_stall.json", BottleneckClass::Pipeline),
        ("io_storm.json", BottleneckClass::Io),
        ("message_storm.json", BottleneckClass::Messaging),
        ("busy_wait.json", BottleneckClass::Compute),
    ] {
        let sc = load(file);
        let outcome = run_case(&sc, &base_case(&sc), AnalysisEngine::auto(), None)
            .unwrap_or_else(|e| panic!("{file}: {e}"));
        let row = outcome
            .scorecard
            .rows
            .iter()
            .find(|r| r.class == class)
            .unwrap();
        assert_eq!(
            row.recall(),
            1.0,
            "{file}: {} recall {} (assignments: {:?})",
            class.label(),
            row.recall(),
            outcome.scorecard.assignments,
        );
    }
}

#[test]
fn fixed_seed_runs_are_byte_identical() {
    let sc = load("lock_convoy.json");
    let run = || {
        let outcome =
            run_case(&sc, &base_case(&sc), AnalysisEngine::auto(), None).unwrap();
        let mut report = outcome.output.report.clone();
        normalize(&mut report);
        (render_scorecard(&outcome.scorecard), report.to_string())
    };
    let (card_a, report_a) = run();
    let (card_b, report_b) = run();
    assert_eq!(card_a, card_b, "scorecard drifted under a fixed seed");
    assert_eq!(report_a, report_b, "report drifted under a fixed seed");
    // A different seed produces a different profile (the determinism
    // above is not vacuous).
    let mut other = sc.clone();
    other.seed = 12345;
    let outcome =
        run_case(&other, &base_case(&other), AnalysisEngine::auto(), None).unwrap();
    let mut report = outcome.output.report.clone();
    normalize(&mut report);
    assert_ne!(report.to_string(), report_a, "seed must matter");
}

#[test]
fn scorecard_travels_the_jsonl_sink_stack() {
    let sc = load("io_storm.json");
    let buf = SharedBuf::default();
    let sink = JsonlSink::new(buf.clone());
    run_case(
        &sc,
        &base_case(&sc),
        AnalysisEngine::auto(),
        Some(Box::new(sink)),
    )
    .unwrap();
    let out = buf.take_string();
    let card_line = out
        .lines()
        .find(|l| l.contains("\"event\":\"scorecard\""))
        .expect("no scorecard event in the JSONL stream");
    let v = Json::parse(card_line).unwrap();
    let body = v.get("scorecard").unwrap();
    assert_eq!(body.get("cases").unwrap().as_u64(), Some(1));
    let io_row = body
        .get("rows")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|r| r.get("class").unwrap().as_str() == Some("blocking I/O"))
        .unwrap();
    assert_eq!(io_row.get("recall").unwrap().as_f64(), Some(1.0));
    // The stream still ends with session_end after the scorecard.
    let last = out.lines().last().unwrap();
    assert!(last.contains("\"event\":\"session_end\""), "{last}");
}
