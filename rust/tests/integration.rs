//! Cross-module integration and property tests.
//!
//! Property tests use the in-crate mini-framework (`util::check`) since
//! proptest is unavailable in the offline registry; failures report the
//! case seed for reproduction.

// `profile` is a deprecated thin wrapper over `Session` now; these
// tests keep exercising it so the compatibility surface stays covered.
#![allow(deprecated)]

use gapp::gapp::{profile, run_unprofiled, GappConfig};
use gapp::runtime::{analysis, AnalysisEngine};
use gapp::simkernel::{Kernel, KernelConfig};
use gapp::util::check::property;
use gapp::workload::apps;

// ---------------------------------------------------------------------
// Property: CMetric conservation through the full probe pipeline.
// ---------------------------------------------------------------------

#[test]
fn prop_cmetric_conservation_through_probes() {
    property("cmetric conservation", 12, |rng| {
        let threads = 4 + rng.pick(12);
        let seed = rng.next_u64();
        let app = apps::blackscholes(threads, seed);
        let (report, kernel) = profile(
            &app,
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
        )
        .unwrap();
        // Σ per-thread CMetric == Σ busy wall time / ... conservation:
        // the user-space totals must equal the serial-equivalent busy
        // time distribution: Σ cm_j == Σ_i T_i over busy intervals, and
        // each thread's cm ≤ its wall.
        for t in &report.threads {
            assert!(
                t.cm_ms <= t.wall_ms + 1e-6,
                "thread {} cm {} > wall {}",
                t.pid,
                t.cm_ms,
                t.wall_ms
            );
        }
        // Total CPU time across tasks bounds total wall attribution.
        let total_cpu: u64 = kernel.all_tasks().map(|t| t.cpu_time).sum();
        let total_wall: f64 = report.threads.iter().map(|t| t.wall_ms * 1e6).sum();
        // wall counts runnable (not just running) time, so it's ≥ cpu.
        assert!(
            total_wall >= 0.9 * total_cpu as f64,
            "wall {total_wall} vs cpu {total_cpu}"
        );
    });
}

// ---------------------------------------------------------------------
// Property: scheduler sanity across random workload mixes.
// ---------------------------------------------------------------------

#[test]
fn prop_scheduler_invariants_random_apps() {
    property("scheduler invariants", 10, |rng| {
        let names = ["canneal", "swaptions", "fluidanimate", "vips"];
        let name = names[rng.pick(names.len())];
        let threads = 4 + rng.pick(12);
        let seed = rng.next_u64();
        let app = apps::by_name(name, threads, seed).unwrap();
        let mut k = Kernel::new(KernelConfig::default());
        let pids = app.spawn_into(&mut k);
        let end = k.run().unwrap();
        assert!(end > 0);
        for pid in pids {
            let t = k.task(pid).unwrap();
            // Everyone tracked exited, consumed CPU, and stayed causal.
            assert_eq!(t.state, gapp::simkernel::TaskState::Exited, "{name}");
            assert!(t.cpu_time > 0, "{name} pid {pid} never ran");
            assert!(t.exited_at.unwrap() <= end);
            assert!(t.cpu_time <= end, "cpu_time exceeds wallclock");
        }
    });
}

// ---------------------------------------------------------------------
// Property: profiling never changes the workload's logical results,
// only its timing (observer effect is bounded).
// ---------------------------------------------------------------------

#[test]
fn prop_profiling_preserves_work_and_bounds_slowdown() {
    property("bounded observer effect", 8, |rng| {
        let threads = 8 + rng.pick(8);
        let seed = rng.next_u64();
        let mk = || apps::vips(threads, seed);
        let (base, kb) = run_unprofiled(&mk(), KernelConfig::default()).unwrap();
        let (report, kp) = profile(
            &mk(),
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
        )
        .unwrap();
        // Same amount of work happened (same spawned/exited counts).
        assert_eq!(kb.stats.spawned, kp.stats.spawned);
        assert_eq!(kb.stats.exited, kp.stats.exited);
        // Profiled run stays within a sane envelope. (It can be a hair
        // *faster*: probe delays perturb queue orderings, and a perturbed
        // schedule occasionally dodges a convoy — a real observer effect.)
        assert!((report.runtime_ns as f64) >= base as f64 * 0.97);
        assert!((report.runtime_ns as f64) < base as f64 * 1.5);
    });
}

// ---------------------------------------------------------------------
// Property: native analysis matches a direct per-row computation for
// arbitrary batches (the rust twin of the hypothesis sweep).
// ---------------------------------------------------------------------

#[test]
fn prop_native_analyze_matches_direct() {
    property("native analyze vs direct", 100, |rng| {
        let b = 1 + rng.pick(64);
        let ts = [8, 32, 128][rng.pick(3)];
        let mut a = vec![0f32; b * ts];
        let mut t = vec![0f32; b];
        for i in 0..b {
            t[i] = rng.below(1_000_000) as f32;
            for j in 0..ts {
                if rng.chance(0.2) {
                    a[i * ts + j] = 1.0;
                }
            }
        }
        let out = analysis::native_analyze(&a, &t, ts);
        let mut cm = vec![0f64; ts];
        let mut gcm = 0f64;
        for i in 0..b {
            let n: f32 = a[i * ts..(i + 1) * ts].iter().sum();
            if n == 0.0 {
                continue;
            }
            gcm += (t[i] / n) as f64;
            for j in 0..ts {
                if a[i * ts + j] > 0.0 {
                    cm[j] += (t[i] / n) as f64;
                }
            }
        }
        for j in 0..ts {
            assert!(
                (out.cm[j] as f64 - cm[j]).abs() <= 1e-2 + cm[j] * 1e-4,
                "slot {j}: {} vs {}",
                out.cm[j],
                cm[j]
            );
        }
        assert!((out.global_cm as f64 - gcm).abs() <= 1e-2 + gcm * 1e-4);
    });
}

// ---------------------------------------------------------------------
// Determinism: same seed → identical profile; different seed → same
// detected bottleneck (robustness), different timings.
// ---------------------------------------------------------------------

#[test]
fn profiles_are_deterministic_per_seed() {
    let run = || {
        let app = apps::dedup(9, apps::DedupConfig {
            chunks: 120,
            ..apps::DedupConfig::with_alloc(8, 8, 8)
        });
        let (r, _) = profile(
            &app,
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
        )
        .unwrap();
        (
            r.runtime_ns,
            r.total_slices,
            r.critical_slices,
            r.top_functions(3),
        )
    };
    assert_eq!(run(), run());
}

#[test]
fn detection_robust_across_seeds() {
    for seed in [1u64, 2, 3] {
        let app = apps::bodytrack(16, seed, apps::BodytrackConfig::default());
        let (r, _) = profile(
            &app,
            KernelConfig::default(),
            GappConfig {
                dt: 200_000,
                ..Default::default()
            },
            AnalysisEngine::native(),
        )
        .unwrap();
        let tops = r.top_functions(2);
        assert!(
            tops.iter().any(|(f, _)| f.contains("RecvCmd") || f.contains("OutputBMP")),
            "seed {seed}: top={tops:?}"
        );
    }
}

// ---------------------------------------------------------------------
// Full pipeline on every app: no panics, non-empty reports, bounded
// ring-buffer drops.
// ---------------------------------------------------------------------

#[test]
fn every_app_profiles_cleanly() {
    for name in apps::ALL_APPS {
        let app = apps::by_name(name, 16, 5).unwrap();
        let (r, _) = profile(
            &app,
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
        )
        .unwrap();
        assert!(r.total_slices > 0, "{name}: no timeslices observed");
        assert_eq!(r.ring_dropped, 0, "{name}: ring buffer dropped records");
        assert!(!r.threads.is_empty(), "{name}: no per-thread CMetric");
        assert!(r.memory_bytes > 0);
    }
}

// ---------------------------------------------------------------------
// PIE limitation (§6.1): position-independent binaries defeat addr2line
// but sym() still names functions.
// ---------------------------------------------------------------------

#[test]
fn pie_binaries_degrade_to_symbol_names() {
    let mut app = apps::blackscholes(8, 3);
    // Rebuild the symbol table in PIE mode (the gcc default the paper
    // overrides with -no-pie).
    let mut symtab = (*app.symtab).clone();
    symtab.pie = true;
    app.symtab = std::rc::Rc::new(symtab);
    let (r, _) = profile(
        &app,
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
    )
    .unwrap();
    // Bottlenecks still found; rendered samples have no file:line but
    // carry the bare symbol name fallback.
    assert!(!r.bottlenecks.is_empty());
    for b in &r.bottlenecks {
        for s in &b.samples {
            assert!(
                !s.rendered.contains(".c:"),
                "PIE run leaked a line mapping: {}",
                s.rendered
            );
        }
    }
}

// ---------------------------------------------------------------------
// Ring-buffer sizing: a deliberately tiny buffer drops records and the
// report says so (perf-buffer tuning failure mode).
// ---------------------------------------------------------------------

#[test]
fn tiny_ring_buffer_reports_drops() {
    let app = apps::streamcluster(16, 3);
    let (r, _) = profile(
        &app,
        KernelConfig::default(),
        GappConfig {
            ring_capacity: 64,
            shards: Some(1), // one tiny shared ring
            drain_threshold: usize::MAX, // never drain mid-run
            ..Default::default()
        },
        AnalysisEngine::native(),
    )
    .unwrap();
    assert!(r.ring_dropped > 0);
}

// ---------------------------------------------------------------------
// §7 extension: bottleneck classification + waker attribution.
// ---------------------------------------------------------------------

#[test]
fn classification_labels_match_mechanisms() {
    use gapp::gapp::classify::BottleneckClass;
    // Fluidanimate's top bottleneck is the barrier → Imbalance.
    let app = apps::fluidanimate(16, 2);
    let (r, _) = profile(
        &app,
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
    )
    .unwrap();
    let classes: Vec<_> = r.bottlenecks.iter().map(|b| b.class).collect();
    assert!(
        classes.contains(&BottleneckClass::Imbalance),
        "fluidanimate classes: {classes:?}"
    );

    // MySQL's flush path is I/O; its rwlock path is Synchronization.
    let app = apps::mysql(16, 41, apps::MysqlConfig::default());
    let (r, _) = profile(
        &app,
        KernelConfig::default(),
        GappConfig::default(),
        AnalysisEngine::native(),
    )
    .unwrap();
    let classes: Vec<_> = r.bottlenecks.iter().map(|b| b.class).collect();
    assert!(
        classes.iter().any(|c| matches!(
            c,
            BottleneckClass::Io | BottleneckClass::Synchronization
        )),
        "mysql classes: {classes:?}"
    );
}

#[test]
fn waker_attribution_names_the_parent_in_bodytrack() {
    // Workers waiting in NotifyDone/RecvCmd are gated by the parent
    // thread ("bodytrack") — the §7 critical-waker analysis should name
    // it on at least one worker-side bottleneck path.
    let app = apps::bodytrack(16, 21, apps::BodytrackConfig::default());
    let (r, _) = profile(
        &app,
        KernelConfig::default(),
        GappConfig {
            dt: 200_000,
            ..Default::default()
        },
        AnalysisEngine::native(),
    )
    .unwrap();
    let worker_paths_with_wakers: Vec<_> = r
        .bottlenecks
        .iter()
        .filter(|b| b.call_path.iter().any(|f| f.contains("WorkerThread")))
        .flat_map(|b| b.top_wakers.iter())
        .collect();
    assert!(
        worker_paths_with_wakers
            .iter()
            .any(|(comm, _)| comm == "bodytrack" || comm.starts_with("bodytrack-w")),
        "wakers: {worker_paths_with_wakers:?}"
    );
}
