//! The discrete-event scheduling engine.
//!
//! Single-threaded, deterministic: a binary heap of timestamped events
//! (segment ends, timed wakeups, sampling ticks) drives a CFS-like
//! scheduler over `cfg.cpus` CPUs sharing a global vruntime-ordered
//! runqueue. Workload behaviour is injected through [`TaskLogic`]; probe
//! behaviour through [`Probe`]s whose per-event costs are charged to the
//! emitting CPU — the profiled application literally runs slower when a
//! probe is expensive, which is how the Table-2 O/H column is measured.
//!
//! Hot-path design: the runqueue is a lazy-deletion binary min-heap
//! keyed on `(vruntime, pid)` with O(1) membership tokens (no `BTreeSet`
//! rebalancing per switch), and tracepoint events borrow the outgoing
//! task's stack/comm instead of cloning them, so steady-state switching
//! allocates nothing.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{bail, Result};

use super::task::{Pid, Task, TaskState, IDLE_PID};
use super::tracepoint::{Event, Probe, SampleView};
use super::Time;

/// Kernel configuration.
#[derive(Clone, Debug)]
pub struct KernelConfig {
    /// Number of CPUs (the paper's testbed exposes 64 hardware threads).
    pub cpus: usize,
    /// Scheduling quantum (CFS-ish; preemption only when others wait).
    pub quantum_ns: Time,
    /// Intrinsic hardware context-switch cost charged on every switch.
    pub switch_cost_ns: Time,
    /// Hard stop (simulated ns) — deadlock/runaway safety net.
    pub max_time_ns: Time,
    /// Safety cap on zero-duration logic steps at one instant.
    pub max_instant_steps: u32,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            cpus: 64,
            quantum_ns: 4_000_000, // 4 ms
            switch_cost_ns: 1_500, // ~1.5 µs direct switch cost
            max_time_ns: 600_000_000_000, // 10 simulated minutes
            max_instant_steps: 100_000,
        }
    }
}

/// What a task does next (returned by [`TaskLogic::step`]).
#[derive(Debug)]
pub enum Step {
    /// Consume CPU for `ns` nanoseconds, then step again.
    Compute { ns: Time },
    /// Block until another task calls `wake(pid)`. The logic must have
    /// already registered itself in some wait structure.
    Block,
    /// Block for a fixed duration (sleep / simulated I/O).
    Sleep { ns: Time },
    /// Relinquish the CPU but stay runnable.
    Yield,
    /// Terminate the task.
    Exit,
}

/// Per-step context handed to workload logic. Wakes and spawns take
/// effect at the current instant, with tracepoint events emitted in order.
pub struct StepCtx<'a> {
    pub now: Time,
    pub pid: Pid,
    /// Simulated instruction pointer (what the sampling probe reads).
    pub ip: &'a mut u64,
    /// Simulated call stack, innermost last (what a stack walk reads).
    pub stack: &'a mut Vec<u64>,
    /// Set before returning `Step::Block`/`Step::Sleep` to tell the
    /// kernel (and through it, profilers) what the task waits on.
    pub wait_kind: &'a mut super::task::WaitKind,
    pub(crate) wakes: Vec<Pid>,
    pub(crate) spawns: Vec<(Pid, String, Box<dyn TaskLogic>)>,
    pub(crate) next_pid: &'a mut Pid,
}

impl<'a> StepCtx<'a> {
    /// Wake a blocked task (no-op if it is runnable, running or exited).
    pub fn wake(&mut self, pid: Pid) {
        self.wakes.push(pid);
    }

    /// Create a new task running `logic`; returns its pid immediately.
    pub fn spawn(&mut self, comm: &str, logic: Box<dyn TaskLogic>) -> Pid {
        let pid = *self.next_pid;
        *self.next_pid += 1;
        self.spawns.push((pid, comm.to_string(), logic));
        pid
    }
}

/// Behaviour of one simulated task; implemented by the workload layer.
pub trait TaskLogic {
    fn step(&mut self, ctx: &mut StepCtx) -> Step;
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum EvKind {
    /// The running task's current segment on `cpu` ends.
    SegEnd { cpu: usize, pid: Pid, gen: u64 },
    /// Timed wakeup for a sleeping task.
    WakeAt { pid: Pid },
    /// Periodic sampling interrupt.
    SampleTick,
}

struct Cpu {
    current: Option<Pid>,
    /// Probe cost accrued mid-segment (sampling ticks), applied by
    /// deferring the next segment end.
    pending_lag: Time,
}

/// Global runqueue: a binary min-heap over `(vruntime, pid)` with lazy
/// deletion. Each pid holds at most one *live* entry, identified by a
/// per-push token; superseded or removed entries stay in the heap and
/// are skipped when they surface. Compared to the previous
/// `BTreeSet<(Time, Pid)>`, push/remove are O(1)/O(log n) with no node
/// rebalancing, min-peek is O(1) amortized, and the `(vruntime, pid)`
/// ordering (ties broken by pid) is preserved exactly.
#[derive(Default)]
struct RunQueue {
    heap: BinaryHeap<Reverse<(Time, Pid, u64)>>,
    /// pid → token of its live heap entry (0 = not queued).
    token: Vec<u64>,
    next_token: u64,
    live: usize,
}

impl RunQueue {
    /// Queue `pid` at `vruntime` (superseding any previous entry).
    fn push(&mut self, pid: Pid, vruntime: Time) {
        self.next_token += 1;
        let tok = self.next_token;
        let i = pid as usize;
        if i >= self.token.len() {
            self.token.resize(i + 1, 0);
        }
        if self.token[i] == 0 {
            self.live += 1;
        }
        self.token[i] = tok;
        self.heap.push(Reverse((vruntime, pid, tok)));
    }

    /// Drop `pid`'s live entry, if any (O(1): token invalidation).
    fn remove(&mut self, pid: Pid) {
        if let Some(slot) = self.token.get_mut(pid as usize) {
            if *slot != 0 {
                *slot = 0;
                self.live -= 1;
            }
        }
    }

    /// Pop the leftmost (min `(vruntime, pid)`) runnable task.
    fn pop_min(&mut self) -> Option<(Time, Pid)> {
        while let Some(Reverse((vr, pid, tok))) = self.heap.pop() {
            if self.token[pid as usize] == tok {
                self.token[pid as usize] = 0;
                self.live -= 1;
                return Some((vr, pid));
            }
        }
        None
    }

    /// Leftmost entry without removing it (skims stale heap tops).
    fn peek_min(&mut self) -> Option<(Time, Pid)> {
        while let Some(&Reverse((vr, pid, tok))) = self.heap.peek() {
            if self.token[pid as usize] == tok {
                return Some((vr, pid));
            }
            self.heap.pop();
        }
        None
    }

    #[inline]
    fn is_empty(&self) -> bool {
        self.live == 0
    }
}

/// Outcome of one [`Kernel::run_until`] epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunOutcome {
    /// The tracked group exited (or the event queue drained) at `.0`.
    Done(Time),
    /// Simulated time reached the epoch limit; more events pending.
    /// Call `run_until` again to continue.
    Paused(Time),
}

/// Aggregate run statistics.
#[derive(Clone, Debug, Default)]
pub struct KernelStats {
    pub switches: u64,
    pub wakeups: u64,
    pub spawned: u64,
    pub exited: u64,
    pub probe_ns: Time,
    pub sample_ticks: u64,
    pub idle_switches: u64,
    /// Final simulated time when the tracked group finished.
    pub finished_at: Time,
}

/// The simulated kernel. See module docs.
pub struct Kernel {
    pub cfg: KernelConfig,
    tasks: Vec<Option<Task>>,
    logic: Vec<Option<Box<dyn TaskLogic>>>,
    runqueue: RunQueue,
    cpus: Vec<Cpu>,
    heap: BinaryHeap<Reverse<(Time, u64, EvKind)>>,
    seq: u64,
    next_pid: Pid,
    probes: Vec<Box<dyn Probe>>,
    sample_period: Option<Time>,
    tracked: Vec<Pid>,
    tracked_live: usize,
    /// Simulated clock: advances as events are processed and pauses at
    /// epoch limits (see [`Kernel::run_until`]).
    clock: Time,
    /// Initial dispatch + sampler arming performed (first run epoch).
    started: bool,
    /// Run completed; further `run_until` calls return `Done` at once.
    finished: bool,
    pub stats: KernelStats,
}

impl Kernel {
    pub fn new(cfg: KernelConfig) -> Kernel {
        let ncpu = cfg.cpus;
        let mut k = Kernel {
            cfg,
            tasks: Vec::new(),
            logic: Vec::new(),
            runqueue: RunQueue::default(),
            cpus: (0..ncpu)
                .map(|_| Cpu { current: None, pending_lag: 0 })
                .collect(),
            heap: BinaryHeap::new(),
            seq: 0,
            next_pid: 1,
            probes: Vec::new(),
            sample_period: None,
            tracked: Vec::new(),
            tracked_live: 0,
            clock: 0,
            started: false,
            finished: false,
            stats: KernelStats::default(),
        };
        // Pid 0: the idle task placeholder.
        k.tasks.push(Some(Task::new(IDLE_PID, "swapper", 0)));
        k.logic.push(None);
        k
    }

    /// Attach a probe (before `run`). Its sampling period, if any, arms
    /// the periodic tick (multiple probes: the minimum period wins).
    pub fn attach_probe(&mut self, p: Box<dyn Probe>) {
        if let Some(period) = p.sample_period() {
            self.sample_period = Some(match self.sample_period {
                Some(cur) => cur.min(period),
                None => period,
            });
        }
        self.probes.push(p);
    }

    /// Detach all probes, returning them for inspection.
    pub fn take_probes(&mut self) -> Vec<Box<dyn Probe>> {
        std::mem::take(&mut self.probes)
    }

    /// Spawn a root task before `run` (emits `task_newtask` at t=0,
    /// charged to the boot CPU).
    pub fn spawn(&mut self, comm: &str, logic: Box<dyn TaskLogic>) -> Pid {
        let pid = self.next_pid;
        self.next_pid += 1;
        self.admit(pid, comm, logic, 0, IDLE_PID, 0);
        pid
    }

    /// Mark `pid` as part of the tracked group; `run` stops when all
    /// tracked tasks have exited (daemon threads may stay blocked).
    pub fn track(&mut self, pid: Pid) {
        self.tracked.push(pid);
        self.tracked_live += 1;
    }

    pub fn task(&self, pid: Pid) -> Option<&Task> {
        self.tasks.get(pid as usize).and_then(|t| t.as_ref())
    }

    /// All tasks ever created (excluding idle), for post-run reporting.
    pub fn all_tasks(&self) -> impl Iterator<Item = &Task> {
        self.tasks
            .iter()
            .flatten()
            .filter(|t| t.pid != IDLE_PID)
    }

    fn push_ev(&mut self, time: Time, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Reverse((time, self.seq, kind)));
    }

    /// Emit a tracepoint event to all probes; returns total cost (ns).
    /// An associated fn over the two fields it touches, so callers may
    /// emit events that borrow *other* fields of `self` (e.g. a task's
    /// stack) without cloning.
    fn emit_to(
        probes: &mut [Box<dyn Probe>],
        stats: &mut KernelStats,
        ev: &Event<'_>,
    ) -> Time {
        let mut cost = 0;
        for p in probes.iter_mut() {
            cost += p.on_event(ev);
        }
        stats.probe_ns += cost;
        cost
    }

    fn emit(&mut self, ev: &Event<'_>) -> Time {
        Self::emit_to(&mut self.probes, &mut self.stats, ev)
    }

    fn admit(
        &mut self,
        pid: Pid,
        comm: &str,
        logic: Box<dyn TaskLogic>,
        now: Time,
        parent: Pid,
        cpu: usize,
    ) {
        while self.tasks.len() <= pid as usize {
            self.tasks.push(None);
            self.logic.push(None);
        }
        // New tasks start at the minimum runqueue vruntime so they are
        // scheduled promptly but cannot starve existing tasks (CFS places
        // new tasks near min_vruntime).
        let min_vr = self.runqueue.peek_min().map(|(v, _)| v).unwrap_or(0);
        let mut t = Task::new(pid, comm, now);
        t.vruntime = min_vr;
        self.tasks[pid as usize] = Some(t);
        self.logic[pid as usize] = Some(logic);
        self.stats.spawned += 1;
        self.emit(&Event::TaskNew {
            time: now,
            cpu,
            pid,
            parent,
            comm,
        });
        self.runqueue.push(pid, min_vr);
    }

    fn task_mut(&mut self, pid: Pid) -> &mut Task {
        self.tasks[pid as usize].as_mut().expect("live task")
    }

    /// Dispatch the next runnable task onto `cpu` (which must be idle),
    /// emitting the sched_switch from `prev`. The event borrows the
    /// outgoing task's ip/stack snapshot straight from its TCB — no
    /// per-switch clone.
    fn dispatch(&mut self, cpu: usize, now: Time, prev_pid: Pid, prev_state: TaskState) {
        debug_assert!(self.cpus[cpu].current.is_none());
        let next_pid = match self.runqueue.pop_min() {
            Some((_, pid)) => pid,
            None => IDLE_PID,
        };
        if next_pid == IDLE_PID && prev_pid == IDLE_PID {
            return; // idle -> idle: nothing happens, no event
        }
        self.stats.switches += 1;
        if next_pid == IDLE_PID {
            self.stats.idle_switches += 1;
        }
        let prev = if prev_pid == IDLE_PID {
            None
        } else {
            self.tasks.get(prev_pid as usize).and_then(|t| t.as_ref())
        };
        let prev_ip = prev.map_or(0, |t| t.ip);
        let prev_stack: &[u64] = prev.map_or(&[], |t| t.stack.as_slice());
        let prev_wait = if prev_state == TaskState::Blocked {
            prev.map(|t| t.wait_kind).unwrap_or_default()
        } else {
            super::task::WaitKind::None
        };
        let cost = Self::emit_to(
            &mut self.probes,
            &mut self.stats,
            &Event::SchedSwitch {
                time: now,
                cpu,
                prev_pid,
                prev_state,
                next_pid,
                prev_ip,
                prev_stack,
                prev_wait,
            },
        ) + self.cfg.switch_cost_ns;
        if next_pid == IDLE_PID {
            self.cpus[cpu].current = None;
            return;
        }
        let quantum = self.cfg.quantum_ns;
        let start = now + cost;
        {
            let t = self.task_mut(next_pid);
            t.state = TaskState::Running;
            t.cpu = cpu;
            t.slice_start = start;
            t.quantum_left = quantum;
            t.genseq += 1;
        }
        self.cpus[cpu].current = Some(next_pid);
        self.schedule_segment(cpu, next_pid, start);
    }

    /// Schedule the next segment-end for the running task on `cpu`.
    /// If the task has no pending compute (remaining == 0) the segment
    /// ends immediately (zero length) and the logic is stepped there.
    fn schedule_segment(&mut self, cpu: usize, pid: Pid, now: Time) {
        let lag = std::mem::take(&mut self.cpus[cpu].pending_lag);
        let t = self.task_mut(pid);
        let dt = t.remaining.min(t.quantum_left).max(0);
        let gen = t.genseq;
        self.push_ev(now + lag + dt, EvKind::SegEnd { cpu, pid, gen });
    }

    /// Make `pid` runnable (if blocked); emit sched_wakeup; dispatch to an
    /// idle CPU when one exists. `waker_cpu` is charged the probe cost.
    fn wake(&mut self, pid: Pid, now: Time, waker_cpu: usize) {
        let Some(t) = self.tasks.get_mut(pid as usize).and_then(|t| t.as_mut())
        else {
            return;
        };
        if t.state != TaskState::Blocked {
            return;
        }
        t.state = TaskState::Runnable;
        t.wait_kind = super::task::WaitKind::None;
        // Re-key into the runqueue at max(own vruntime, min_vruntime):
        // sleepers get a fair re-entry without hoarding credit.
        let min_vr = self.runqueue.peek_min().map(|(v, _)| v).unwrap_or(0);
        let vr = self.tasks[pid as usize].as_ref().unwrap().vruntime.max(min_vr);
        self.tasks[pid as usize].as_mut().unwrap().vruntime = vr;
        self.runqueue.push(pid, vr);
        self.stats.wakeups += 1;
        let cost = self.emit(&Event::SchedWakeup { time: now, cpu: waker_cpu, pid });
        self.cpus[waker_cpu].pending_lag += cost;
        // Pull onto an idle CPU immediately if one exists.
        if let Some(idle) = (0..self.cpus.len()).find(|c| self.cpus[*c].current.is_none())
        {
            self.dispatch(idle, now, IDLE_PID, TaskState::Runnable);
        }
    }

    fn on_tracked_exit(&mut self, pid: Pid) {
        if self.tracked.contains(&pid) {
            self.tracked_live = self.tracked_live.saturating_sub(1);
        }
    }

    /// Run until the tracked group exits, the event queue drains, or the
    /// safety limits trip. Returns final simulated time.
    pub fn run(&mut self) -> Result<Time> {
        match self.run_until(Time::MAX)? {
            RunOutcome::Done(t) | RunOutcome::Paused(t) => Ok(t),
        }
    }

    /// Current simulated time (the epoch driver's clock source).
    pub fn now(&self) -> Time {
        self.clock
    }

    /// Run events with `time <= limit`, then pause — the epoch hook the
    /// streaming analyzer drives: simulate one window, drain the probe
    /// ring, repeat. The first call performs the initial dispatch; a
    /// call after `Done` is a no-op returning `Done` again. Event
    /// processing is identical to an uninterrupted [`Kernel::run`], so
    /// epoch boundaries cannot perturb the simulated timeline.
    pub fn run_until(&mut self, limit: Time) -> Result<RunOutcome> {
        if self.finished {
            return Ok(RunOutcome::Done(self.stats.finished_at));
        }
        let ncpu = self.cpus.len();
        if !self.started {
            self.started = true;
            // Initial dispatch across idle CPUs.
            for c in 0..ncpu {
                if self.cpus[c].current.is_none() && !self.runqueue.is_empty() {
                    self.dispatch(c, 0, IDLE_PID, TaskState::Runnable);
                }
            }
            if let Some(p) = self.sample_period {
                self.push_ev(p, EvKind::SampleTick);
            }
        }
        loop {
            // Stop BEFORE advancing the clock to a future event: once the
            // tracked group has exited, pending timer ticks must not
            // inflate the reported runtime.
            if self.tracked_live == 0 && !self.tracked.is_empty() {
                break;
            }
            let Some(&Reverse((t, _, _))) = self.heap.peek() else {
                break;
            };
            if t > limit {
                // Epoch boundary: leave the event queued for the next
                // epoch and report the pause.
                self.clock = limit;
                return Ok(RunOutcome::Paused(limit));
            }
            let Some(Reverse((t, _seq, kind))) = self.heap.pop() else {
                break;
            };
            let now = t;
            self.clock = now;
            if now > self.cfg.max_time_ns {
                bail!("simulation exceeded max_time_ns at {now} ns (deadlock or runaway?)");
            }
            match kind {
                EvKind::SegEnd { cpu, pid, gen } => self.on_seg_end(cpu, pid, gen, now)?,
                EvKind::WakeAt { pid } => {
                    // Timed wakeups are charged to the woken task's last CPU
                    // (timer interrupt locality is irrelevant to the model).
                    let cpu = self
                        .task(pid)
                        .map(|t| if t.cpu < ncpu { t.cpu } else { 0 })
                        .unwrap_or(0);
                    self.wake(pid, now, cpu);
                }
                EvKind::SampleTick => self.on_sample_tick(now),
            }
        }
        self.finished = true;
        self.stats.finished_at = self.clock;
        let finals = self.clock;
        for p in &mut self.probes {
            p.on_finish(finals);
        }
        Ok(RunOutcome::Done(finals))
    }

    fn on_sample_tick(&mut self, now: Time) {
        self.stats.sample_ticks += 1;
        for cpu in 0..self.cpus.len() {
            if let Some(pid) = self.cpus[cpu].current {
                let t = self.tasks[pid as usize].as_ref().unwrap();
                let view = SampleView {
                    cpu,
                    pid,
                    ip: t.ip,
                    stack_top: t.stack.last().copied().unwrap_or(0),
                };
                let cost = self.emit(&Event::SampleTick { time: now, view });
                self.cpus[cpu].pending_lag += cost;
            }
        }
        if self.tracked_live > 0 || self.tracked.is_empty() {
            if let Some(p) = self.sample_period {
                self.push_ev(now + p, EvKind::SampleTick);
            }
        }
    }

    fn on_seg_end(&mut self, cpu: usize, pid: Pid, gen: u64, now: Time) -> Result<()> {
        // Stale event? (task was preempted/blocked and re-dispatched)
        let Some(task) = self.tasks.get(pid as usize).and_then(|t| t.as_ref()) else {
            return Ok(());
        };
        if task.genseq != gen || task.state != TaskState::Running || task.cpu != cpu {
            return Ok(());
        }
        // Mid-segment probe lag (sampling ticks): defer completion.
        let lag = std::mem::take(&mut self.cpus[cpu].pending_lag);
        if lag > 0 {
            self.push_ev(now + lag, EvKind::SegEnd { cpu, pid, gen });
            return Ok(());
        }
        {
            // seg = min(remaining, quantum_left) was the scheduled length;
            // both fields are only mutated at segment boundaries, so this
            // recovers exactly the dt used by schedule_segment.
            let t = self.task_mut(pid);
            let seg = t.remaining.min(t.quantum_left);
            t.cpu_time += seg;
            t.vruntime += seg;
            t.remaining -= seg;
            t.quantum_left -= seg;
        }
        let t_rem = self.task(pid).unwrap().remaining;
        if t_rem > 0 {
            // Quantum expired mid-compute: preempt only if others wait.
            if self.runqueue.is_empty() {
                let q = self.cfg.quantum_ns;
                let t = self.task_mut(pid);
                t.quantum_left = q;
                t.genseq += 1;
                t.slice_start = now;
                self.schedule_segment(cpu, pid, now);
            } else {
                let vr = {
                    let t = self.task_mut(pid);
                    t.state = TaskState::Runnable;
                    t.nivcsw += 1;
                    t.genseq += 1;
                    t.vruntime
                };
                self.runqueue.push(pid, vr);
                self.cpus[cpu].current = None;
                self.dispatch(cpu, now, pid, TaskState::Runnable);
            }
            return Ok(());
        }
        // Current step complete: ask the logic what happens next.
        self.drive_logic(cpu, pid, now)
    }

    /// Step the task's logic until it yields a non-instant action.
    fn drive_logic(&mut self, cpu: usize, pid: Pid, mut now: Time) -> Result<()> {
        let mut instant_steps = 0u32;
        loop {
            instant_steps += 1;
            if instant_steps > self.cfg.max_instant_steps {
                bail!("task {pid} performed too many zero-time steps at {now} ns");
            }
            let mut logic = self.logic[pid as usize].take().expect("logic present");
            let step = {
                let mut next_pid = self.next_pid;
                let task = self.tasks[pid as usize].as_mut().unwrap();
                let mut ctx = StepCtx {
                    now,
                    pid,
                    ip: &mut task.ip,
                    stack: &mut task.stack,
                    wait_kind: &mut task.wait_kind,
                    wakes: Vec::new(),
                    spawns: Vec::new(),
                    next_pid: &mut next_pid,
                };
                let step = logic.step(&mut ctx);
                let wakes = std::mem::take(&mut ctx.wakes);
                let spawns = std::mem::take(&mut ctx.spawns);
                self.next_pid = next_pid;
                // Re-install logic before applying side effects (a wake can
                // never re-enter this task's logic synchronously).
                self.logic[pid as usize] = Some(logic);
                for (cpid, comm, clogic) in spawns {
                    self.admit(cpid, &comm, clogic, now, pid, cpu);
                    if let Some(idle) =
                        (0..self.cpus.len()).find(|c| self.cpus[*c].current.is_none())
                    {
                        self.dispatch(idle, now, IDLE_PID, TaskState::Runnable);
                    }
                }
                for w in wakes {
                    self.wake(w, now, cpu);
                }
                step
            };
            // Side-effect probe lag delays this task's next action.
            now += std::mem::take(&mut self.cpus[cpu].pending_lag);
            match step {
                Step::Compute { ns } => {
                    if ns == 0 {
                        continue;
                    }
                    let q = self.cfg.quantum_ns;
                    let t = self.task_mut(pid);
                    t.remaining = ns;
                    if t.quantum_left == 0 {
                        t.quantum_left = q;
                    }
                    t.genseq += 1;
                    t.slice_start = now;
                    self.schedule_segment(cpu, pid, now);
                    return Ok(());
                }
                Step::Yield => {
                    let vr = {
                        let t = self.task_mut(pid);
                        t.state = TaskState::Runnable;
                        t.nvcsw += 1;
                        t.genseq += 1;
                        t.vruntime
                    };
                    self.runqueue.push(pid, vr);
                    self.cpus[cpu].current = None;
                    // CFS: if we are still the leftmost task, keep running
                    // (no switch event, same as prev == next re-selection).
                    if let Some((_, next)) = self.runqueue.peek_min() {
                        if next == pid {
                            self.runqueue.remove(pid);
                            let q = self.cfg.quantum_ns;
                            let t = self.task_mut(pid);
                            t.state = TaskState::Running;
                            t.quantum_left = q;
                            t.genseq += 1;
                            self.cpus[cpu].current = Some(pid);
                            continue; // keep stepping at the same instant
                        }
                    }
                    self.dispatch(cpu, now, pid, TaskState::Runnable);
                    return Ok(());
                }
                Step::Block | Step::Sleep { .. } => {
                    if let Step::Sleep { ns } = step {
                        self.push_ev(now + ns, EvKind::WakeAt { pid });
                        let t = self.task_mut(pid);
                        if t.wait_kind == super::task::WaitKind::None {
                            t.wait_kind = super::task::WaitKind::Io;
                        }
                    }
                    {
                        let t = self.task_mut(pid);
                        t.state = TaskState::Blocked;
                        t.nvcsw += 1;
                        t.genseq += 1;
                    }
                    self.cpus[cpu].current = None;
                    self.dispatch(cpu, now, pid, TaskState::Blocked);
                    return Ok(());
                }
                Step::Exit => {
                    {
                        let t = self.task_mut(pid);
                        t.state = TaskState::Exited;
                        t.exited_at = Some(now);
                        t.genseq += 1;
                    }
                    self.logic[pid as usize] = None;
                    self.stats.exited += 1;
                    self.emit(&Event::ProcessExit { time: now, cpu, pid });
                    self.on_tracked_exit(pid);
                    self.cpus[cpu].current = None;
                    self.dispatch(cpu, now, pid, TaskState::Blocked);
                    return Ok(());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    /// Logic from a simple script of steps.
    struct Script {
        steps: Vec<Step>,
        at: usize,
    }

    impl Script {
        fn new(steps: Vec<Step>) -> Box<Script> {
            Box::new(Script { steps, at: 0 })
        }
    }

    impl TaskLogic for Script {
        fn step(&mut self, _ctx: &mut StepCtx) -> Step {
            if self.at >= self.steps.len() {
                return Step::Exit;
            }
            let s = match &self.steps[self.at] {
                Step::Compute { ns } => Step::Compute { ns: *ns },
                Step::Sleep { ns } => Step::Sleep { ns: *ns },
                Step::Block => Step::Block,
                Step::Yield => Step::Yield,
                Step::Exit => Step::Exit,
            };
            self.at += 1;
            s
        }
    }

    fn small_cfg(cpus: usize) -> KernelConfig {
        KernelConfig {
            cpus,
            quantum_ns: 1_000_000,
            switch_cost_ns: 0,
            ..Default::default()
        }
    }

    #[test]
    fn runqueue_orders_by_vruntime_then_pid() {
        let mut rq = RunQueue::default();
        rq.push(5, 100);
        rq.push(3, 100);
        rq.push(9, 50);
        assert_eq!(rq.peek_min(), Some((50, 9)));
        assert_eq!(rq.pop_min(), Some((50, 9)));
        // Tie on vruntime: lower pid wins (the BTreeSet ordering).
        assert_eq!(rq.pop_min(), Some((100, 3)));
        assert_eq!(rq.pop_min(), Some((100, 5)));
        assert_eq!(rq.pop_min(), None);
        assert!(rq.is_empty());
    }

    #[test]
    fn runqueue_lazy_deletion_skips_stale_entries() {
        let mut rq = RunQueue::default();
        rq.push(1, 10);
        rq.push(2, 20);
        rq.remove(1);
        assert_eq!(rq.peek_min(), Some((20, 2)));
        // Re-push supersedes: only the newest entry for a pid is live.
        rq.push(2, 5);
        assert_eq!(rq.pop_min(), Some((5, 2)));
        assert_eq!(rq.pop_min(), None);
        assert!(rq.is_empty());
    }

    #[test]
    fn single_task_runtime_equals_compute() {
        let mut k = Kernel::new(small_cfg(1));
        let pid = k.spawn("t", Script::new(vec![Step::Compute { ns: 5_000_000 }]));
        k.track(pid);
        let end = k.run().unwrap();
        assert_eq!(end, 5_000_000);
        assert_eq!(k.task(pid).unwrap().cpu_time, 5_000_000);
        assert_eq!(k.task(pid).unwrap().state, TaskState::Exited);
    }

    #[test]
    fn two_tasks_share_one_cpu() {
        let mut k = Kernel::new(small_cfg(1));
        let a = k.spawn("a", Script::new(vec![Step::Compute { ns: 3_000_000 }]));
        let b = k.spawn("b", Script::new(vec![Step::Compute { ns: 3_000_000 }]));
        k.track(a);
        k.track(b);
        let end = k.run().unwrap();
        assert_eq!(end, 6_000_000); // serialized on one CPU
        assert!(k.stats.switches >= 4); // preemptions happened
    }

    #[test]
    fn two_tasks_two_cpus_parallel() {
        let mut k = Kernel::new(small_cfg(2));
        let a = k.spawn("a", Script::new(vec![Step::Compute { ns: 3_000_000 }]));
        let b = k.spawn("b", Script::new(vec![Step::Compute { ns: 3_000_000 }]));
        k.track(a);
        k.track(b);
        let end = k.run().unwrap();
        assert_eq!(end, 3_000_000);
    }

    #[test]
    fn sleep_then_finish() {
        let mut k = Kernel::new(small_cfg(1));
        let a = k.spawn(
            "a",
            Script::new(vec![
                Step::Compute { ns: 1_000 },
                Step::Sleep { ns: 10_000 },
                Step::Compute { ns: 1_000 },
            ]),
        );
        k.track(a);
        let end = k.run().unwrap();
        assert_eq!(end, 12_000);
    }

    struct WakerLogic {
        target: Rc<RefCell<Option<Pid>>>,
        at: usize,
    }

    impl TaskLogic for WakerLogic {
        fn step(&mut self, ctx: &mut StepCtx) -> Step {
            self.at += 1;
            match self.at {
                1 => Step::Compute { ns: 5_000 },
                2 => {
                    if let Some(t) = *self.target.borrow() {
                        ctx.wake(t);
                    }
                    Step::Exit
                }
                _ => Step::Exit,
            }
        }
    }

    struct SleeperLogic {
        at: usize,
    }

    impl TaskLogic for SleeperLogic {
        fn step(&mut self, _ctx: &mut StepCtx) -> Step {
            self.at += 1;
            match self.at {
                1 => Step::Block,
                2 => Step::Compute { ns: 1_000 },
                _ => Step::Exit,
            }
        }
    }

    #[test]
    fn block_and_wake() {
        let mut k = Kernel::new(small_cfg(2));
        let target = Rc::new(RefCell::new(None));
        let s = k.spawn("sleeper", Box::new(SleeperLogic { at: 0 }));
        *target.borrow_mut() = Some(s);
        let w = k.spawn("waker", Box::new(WakerLogic { target, at: 0 }));
        k.track(s);
        k.track(w);
        let end = k.run().unwrap();
        // Sleeper blocked immediately; waker computes 5µs then wakes it;
        // sleeper computes 1µs more.
        assert_eq!(end, 6_000);
        assert!(k.stats.wakeups >= 1);
    }

    struct CostProbe;

    impl Probe for CostProbe {
        fn on_event(&mut self, ev: &Event<'_>) -> u64 {
            match ev {
                Event::SchedSwitch { .. } => 10_000,
                _ => 0,
            }
        }
    }

    #[test]
    fn probe_cost_inflates_runtime() {
        let run = |with_probe: bool| {
            let mut k = Kernel::new(small_cfg(1));
            if with_probe {
                k.attach_probe(Box::new(CostProbe));
            }
            let a = k.spawn("a", Script::new(vec![Step::Compute { ns: 1_000_000 }]));
            k.track(a);
            k.run().unwrap()
        };
        let base = run(false);
        let probed = run(true);
        assert!(probed > base, "probed={probed} base={base}");
    }

    struct SamplerProbe {
        ticks: Rc<RefCell<u64>>,
    }

    impl Probe for SamplerProbe {
        fn on_event(&mut self, ev: &Event<'_>) -> u64 {
            if matches!(ev, Event::SampleTick { .. }) {
                *self.ticks.borrow_mut() += 1;
            }
            0
        }
        fn sample_period(&self) -> Option<Time> {
            Some(100_000)
        }
    }

    #[test]
    fn sampler_ticks_fire() {
        let ticks = Rc::new(RefCell::new(0));
        let mut k = Kernel::new(small_cfg(1));
        k.attach_probe(Box::new(SamplerProbe { ticks: ticks.clone() }));
        let a = k.spawn("a", Script::new(vec![Step::Compute { ns: 1_000_000 }]));
        k.track(a);
        k.run().unwrap();
        // ~10 ticks during 1 ms of compute at 100 µs period.
        let got = *ticks.borrow();
        assert!((5..=15).contains(&got), "got {got}");
    }

    #[test]
    fn spawn_from_logic_runs_child() {
        struct Parent {
            at: usize,
        }
        impl TaskLogic for Parent {
            fn step(&mut self, ctx: &mut StepCtx) -> Step {
                self.at += 1;
                match self.at {
                    1 => {
                        ctx.spawn("child", Script::new(vec![Step::Compute { ns: 2_000 }]));
                        // Outlive the child so its full runtime is simulated
                        // before the tracked group (just the parent) exits.
                        Step::Compute { ns: 3_000 }
                    }
                    _ => Step::Exit,
                }
            }
        }
        let mut k = Kernel::new(small_cfg(2));
        let p = k.spawn("parent", Box::new(Parent { at: 0 }));
        k.track(p);
        k.run().unwrap();
        assert_eq!(k.stats.spawned, 2);
        // Child ran in parallel on cpu 1.
        let child = k.all_tasks().find(|t| t.comm == "child").unwrap();
        assert_eq!(child.cpu_time, 2_000);
    }

    #[test]
    fn exited_tasks_counted() {
        let mut k = Kernel::new(small_cfg(4));
        let mut pids = Vec::new();
        for i in 0..8 {
            let p = k.spawn(
                &format!("t{i}"),
                Script::new(vec![Step::Compute { ns: 1_000 * (i + 1) }]),
            );
            pids.push(p);
            k.track(p);
        }
        k.run().unwrap();
        assert_eq!(k.stats.exited, 8);
        for p in pids {
            assert_eq!(k.task(p).unwrap().state, TaskState::Exited);
        }
    }

    #[test]
    fn run_until_pauses_and_resumes_without_perturbing_the_timeline() {
        let build = || {
            let mut k = Kernel::new(small_cfg(2));
            for i in 0..4 {
                let p = k.spawn(
                    &format!("t{i}"),
                    Script::new(vec![
                        Step::Compute { ns: 900_000 + i * 133 },
                        Step::Sleep { ns: 400_000 },
                        Step::Compute { ns: 700_000 },
                    ]),
                );
                k.track(p);
            }
            k
        };
        // Reference: one uninterrupted run.
        let mut k1 = build();
        let end1 = k1.run().unwrap();
        // Same workload, driven in 250 µs epochs.
        let mut k2 = build();
        let mut epochs = 0u32;
        let end2 = loop {
            epochs += 1;
            let limit = 250_000u64 * epochs as u64;
            match k2.run_until(limit).unwrap() {
                RunOutcome::Done(t) => break t,
                RunOutcome::Paused(t) => assert_eq!(t, limit),
            }
        };
        assert!(epochs > 3, "expected several epochs, got {epochs}");
        assert_eq!(end1, end2);
        assert_eq!(k1.stats.switches, k2.stats.switches);
        assert_eq!(k1.stats.wakeups, k2.stats.wakeups);
        assert_eq!(k1.stats.sample_ticks, k2.stats.sample_ticks);
        // After Done, further epochs are no-ops.
        assert_eq!(k2.run_until(u64::MAX).unwrap(), RunOutcome::Done(end2));
        assert_eq!(k2.now(), end2);
    }

    #[test]
    fn deterministic_across_runs() {
        let run_once = || {
            let mut k = Kernel::new(small_cfg(2));
            let mut last = 0;
            for i in 0..5 {
                let p = k.spawn(
                    &format!("t{i}"),
                    Script::new(vec![
                        Step::Compute { ns: 10_000 + i * 77 },
                        Step::Sleep { ns: 5_000 },
                        Step::Compute { ns: 7_000 },
                    ]),
                );
                k.track(p);
                last = p;
            }
            let _ = last;
            (k.run().unwrap(), k.stats.switches, k.stats.wakeups)
        };
        assert_eq!(run_once(), run_once());
    }
}
