//! Kernel tracepoints and the probe attachment interface.
//!
//! These mirror the Linux tracepoints GAPP attaches to (paper §3):
//! `sched_switch`, `sched_wakeup`, `task_newtask`, `task_rename`,
//! `sched_process_exit`, plus the perf-style periodic sampling hook the
//! paper builds its §4.3 sampler on.
//!
//! A [`Probe`] returns the nanosecond cost of its handler; the kernel
//! charges that cost to the CPU that fired the event. That is the entire
//! mechanism behind the paper's overhead numbers (Table 2 O/H), so the
//! cost model lives here, front and center.

use super::task::{Pid, TaskState};
use super::Time;

/// Snapshot of what a sampling interrupt sees on one CPU.
#[derive(Clone, Debug)]
pub struct SampleView {
    pub cpu: usize,
    pub pid: Pid,
    /// Current simulated instruction pointer.
    pub ip: u64,
    /// Innermost stack entry (return address of the caller) — used by the
    /// paper's "critical timeslices with no samples" fallback (§4.4).
    pub stack_top: u64,
}

/// A kernel tracepoint event, with the arguments the real ABI provides.
///
/// Borrowed, not owned: a real tracepoint hands probes pointers into
/// kernel structures valid for the handler's duration, and the event
/// fan-out must not allocate. `prev_stack` and `comm` are therefore
/// slices borrowed from the emitting kernel; probes that need to keep
/// them copy explicitly (as a real BPF program would with
/// `bpf_probe_read`).
#[derive(Clone, Debug)]
pub enum Event<'a> {
    /// Context switch on `cpu`: `prev` out (in `prev_state`), `next` in.
    /// `prev_stack`/`prev_ip` snapshot what a kernel stack walk would see
    /// for the outgoing task (empty for the idle task).
    SchedSwitch {
        time: Time,
        cpu: usize,
        prev_pid: Pid,
        prev_state: TaskState,
        next_pid: Pid,
        prev_ip: u64,
        prev_stack: &'a [u64],
        /// What `prev` blocked on when `prev_state == Blocked` (the §7
        /// classification extension's input; a real deployment derives
        /// it from futex/syscall tracepoints).
        prev_wait: super::task::WaitKind,
    },
    /// A blocked task became runnable.
    SchedWakeup { time: Time, cpu: usize, pid: Pid },
    /// New task created (`task_newtask`); `comm` as `task_rename` reports.
    /// `cpu` is where the spawning context ran — real tracepoints fire on
    /// the CPU executing the syscall, and per-CPU ring transports route
    /// records by it (pre-run spawns are charged to the boot CPU, 0).
    TaskNew {
        time: Time,
        cpu: usize,
        pid: Pid,
        parent: Pid,
        comm: &'a str,
    },
    /// Task exited (`sched_process_exit`) on `cpu`.
    ProcessExit { time: Time, cpu: usize, pid: Pid },
    /// Periodic sampling tick (one per sampled CPU with a running task).
    SampleTick { time: Time, view: SampleView },
}

impl<'a> Event<'a> {
    pub fn time(&self) -> Time {
        match self {
            Event::SchedSwitch { time, .. }
            | Event::SchedWakeup { time, .. }
            | Event::TaskNew { time, .. }
            | Event::ProcessExit { time, .. }
            | Event::SampleTick { time, .. } => *time,
        }
    }

    /// CPU the event fired on — the shard any record this event's
    /// handlers emit lands in (per-CPU ring routing).
    pub fn cpu(&self) -> usize {
        match self {
            Event::SchedSwitch { cpu, .. }
            | Event::SchedWakeup { cpu, .. }
            | Event::TaskNew { cpu, .. }
            | Event::ProcessExit { cpu, .. } => *cpu,
            Event::SampleTick { view, .. } => view.cpu,
        }
    }
}

/// Cost (ns) a probe handler charges to the CPU that fired the event.
pub type ProbeCost = u64;

/// An attached kernel probe. Implementations: the GAPP probe set
/// (`gapp::probes`), baseline profilers, and test instrumentation.
pub trait Probe {
    /// Handle an event; return the handler's cost in nanoseconds.
    fn on_event(&mut self, ev: &Event<'_>) -> ProbeCost;

    /// Sampling period, if this probe wants `SampleTick`s (paper's Δt).
    fn sample_period(&self) -> Option<Time> {
        None
    }

    /// Called once when the simulation ends (flush buffers, etc.).
    fn on_finish(&mut self, _now: Time) {}
}

/// Calibrated handler-cost constants (ns). Chosen so the emergent
/// overhead lands in the paper's reported band: sub-1% for compute-bound
/// apps with ~0% critical slices, ~12% for Dedup-class apps with ~40%
/// critical slices (EXPERIMENTS.md §Overhead shows the calibration run).
pub mod cost {
    /// eBPF map update + clock read on every sched_switch.
    pub const SWITCH_FAST_PATH: u64 = 220;
    /// Additional cost when the switch touches an application thread
    /// (thread_list lookup + CMetric arithmetic + map writes).
    pub const SWITCH_APP_PATH: u64 = 450;
    /// sched_wakeup handler (thread_list + thread_count update).
    pub const WAKEUP: u64 = 180;
    /// task_newtask / task_rename / exit bookkeeping.
    pub const LIFECYCLE: u64 = 400;
    /// Walking one stack frame during capture.
    pub const STACK_FRAME: u64 = 80;
    /// `bpf_get_stackid()`-style intern: hash the walked frames and
    /// look them up in the bounded stack map (the record then carries a
    /// 4-byte id instead of the frames).
    pub const STACKMAP_LOOKUP: u64 = 120;
    /// Ring-buffer reserve/commit for one record.
    pub const RINGBUF_RECORD: u64 = 150;
    /// Sampling interrupt fast path (thread_count compare).
    pub const SAMPLE_FAST_PATH: u64 = 100;
    /// Sampling slow path (record IP to ring buffer).
    pub const SAMPLE_RECORD: u64 = 250;
}

#[cfg(test)]
mod tests {
    use super::*;

    struct CountingProbe {
        switches: usize,
    }

    impl Probe for CountingProbe {
        fn on_event(&mut self, ev: &Event<'_>) -> ProbeCost {
            if matches!(ev, Event::SchedSwitch { .. }) {
                self.switches += 1;
            }
            100
        }
    }

    #[test]
    fn probe_counts_and_charges() {
        let mut p = CountingProbe { switches: 0 };
        let ev = Event::SchedSwitch {
            time: 5,
            cpu: 0,
            prev_pid: 1,
            prev_state: TaskState::Blocked,
            next_pid: 2,
            prev_ip: 0,
            prev_stack: &[],
            prev_wait: super::super::task::WaitKind::Futex,
        };
        assert_eq!(p.on_event(&ev), 100);
        assert_eq!(p.switches, 1);
        assert_eq!(ev.time(), 5);
    }

    #[test]
    fn default_no_sampling() {
        let p = CountingProbe { switches: 0 };
        assert!(p.sample_period().is_none());
    }
}
