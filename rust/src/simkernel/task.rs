//! Task control blocks for the simulated kernel.

use super::Time;

/// Process/thread identifier. Pid 0 is the idle task ("swapper").
pub type Pid = u32;

/// The idle task: what a CPU "runs" when the runqueue is empty.
pub const IDLE_PID: Pid = 0;

/// What a blocked task is waiting on — the kernel-visible wait class
/// GAPP's §7 "bottleneck classification" extension keys on (futex vs
/// I/O vs pipeline etc., as a real deployment would learn from the
/// syscall/futex tracepoints the paper describes experimenting with).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum WaitKind {
    /// Not waiting (running/runnable) — slices ending by preemption.
    #[default]
    None,
    /// Futex-backed mutex/condvar/rwlock park.
    Futex,
    /// Barrier rendezvous.
    Barrier,
    /// Bounded pipeline queue (full/empty).
    Queue,
    /// Blocking I/O or timer sleep.
    Io,
    /// Message-passing receive.
    Channel,
}

/// Scheduler state of a task. `Running` and `Runnable` together correspond
/// to Linux's `TASK_RUNNING` — the state GAPP treats as *active* (§2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// Currently executing on a CPU.
    Running,
    /// In the runqueue, waiting for a CPU (still TASK_RUNNING in Linux).
    Runnable,
    /// Blocked: sleeping, waiting on a futex, or in simulated I/O
    /// (TASK_INTERRUPTIBLE / TASK_UNINTERRUPTIBLE).
    Blocked,
    /// Exited; the TCB is kept for post-mortem queries.
    Exited,
}

impl TaskState {
    /// Linux `TASK_RUNNING`?
    pub fn is_running_state(self) -> bool {
        matches!(self, TaskState::Running | TaskState::Runnable)
    }
}

/// Task control block.
#[derive(Clone, Debug)]
pub struct Task {
    pub pid: Pid,
    /// Command name (`comm`), as `task_rename` would report.
    pub comm: String,
    pub state: TaskState,
    /// CFS-style virtual runtime (ns of CPU consumed; no nice weighting).
    pub vruntime: Time,
    /// Total CPU time consumed.
    pub cpu_time: Time,
    /// Remaining nanoseconds of the task's current compute step.
    pub remaining: Time,
    /// CPU the task is currently on (valid while `Running`).
    pub cpu: usize,
    /// Event-generation counter: invalidates stale segment-end events.
    pub genseq: u64,
    /// Time the task last started a timeslice (switched in).
    pub slice_start: Time,
    /// Quantum budget left in the current timeslice.
    pub quantum_left: Time,
    /// Simulated instruction pointer (set by the workload's current op).
    pub ip: u64,
    /// What the task is blocked on (valid while `Blocked`).
    pub wait_kind: WaitKind,
    /// Simulated call stack, innermost last (symbol addresses).
    pub stack: Vec<u64>,
    /// Creation and exit timestamps.
    pub created_at: Time,
    pub exited_at: Option<Time>,
    /// Number of voluntary (blocking) and involuntary (preempt) switches.
    pub nvcsw: u64,
    pub nivcsw: u64,
}

impl Task {
    pub fn new(pid: Pid, comm: &str, now: Time) -> Task {
        Task {
            pid,
            comm: comm.to_string(),
            state: TaskState::Runnable,
            vruntime: 0,
            cpu_time: 0,
            remaining: 0,
            cpu: usize::MAX,
            genseq: 0,
            slice_start: 0,
            quantum_left: 0,
            ip: 0,
            wait_kind: WaitKind::None,
            stack: Vec::new(),
            created_at: now,
            exited_at: None,
            nvcsw: 0,
            nivcsw: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_states() {
        assert!(TaskState::Running.is_running_state());
        assert!(TaskState::Runnable.is_running_state());
        assert!(!TaskState::Blocked.is_running_state());
        assert!(!TaskState::Exited.is_running_state());
    }

    #[test]
    fn new_task_defaults() {
        let t = Task::new(3, "worker", 100);
        assert_eq!(t.pid, 3);
        assert_eq!(t.state, TaskState::Runnable);
        assert_eq!(t.created_at, 100);
        assert!(t.exited_at.is_none());
    }
}
