//! Discrete-event Linux-scheduler simulator — the "kernel" substrate.
//!
//! The real GAPP hooks four kernel tracepoints (`sched_switch`,
//! `sched_wakeup`, `task_newtask`/`task_rename`, `sched_process_exit`).
//! This module provides a deterministic scheduler that emits exactly those
//! events with the same argument shapes, so the profiler layers above run
//! unmodified logic against simulated workloads (DESIGN.md §1).
//!
//! Model: `cpus` symmetric CPUs share a global vruntime-ordered runqueue
//! (CFS-like). Tasks are driven by a [`TaskLogic`] implementation supplied
//! by the workload layer; each scheduling segment runs until the task's
//! current step completes, its quantum expires (preempt only when someone
//! else is waiting, as CFS does), or it blocks. Probe costs returned by
//! attached [`Probe`]s are charged to the emitting CPU's timeline, which is
//! how profiler overhead arises *mechanically* rather than being assumed.

pub mod task;
pub mod tracepoint;
pub mod kernel;

pub use kernel::{Kernel, KernelConfig, RunOutcome, Step, StepCtx, TaskLogic};
pub use task::{Pid, Task, TaskState, WaitKind, IDLE_PID};
pub use tracepoint::{Event, Probe, ProbeCost, SampleView};

/// Simulated time in nanoseconds since boot.
pub type Time = u64;
