//! Open-loop arrival processes for scenario load generation.
//!
//! A scenario may pace its loop-driven pathologies with an
//! [`ArrivalSpec`]: each work item is preceded by an inter-arrival
//! gap drawn from one of four processes. Gaps are pre-drawn from a
//! seeded [`Prng`] stream at build time and compiled into the
//! program as `[arrival_wait]` sleeps, so a paced run is exactly as
//! deterministic as an unpaced one.
//!
//! This is an *approximation* of a true open-loop generator: the gap
//! is inserted relative to the previous item's completion rather
//! than an absolute arrival timetable, so a slow service leg delays
//! subsequent arrivals instead of queueing them. For the scorecard's
//! purpose — varying the interleaving and duty cycle of the injected
//! pathologies — relative gaps are sufficient, and they keep the
//! generator a pure function of the spec and seed.

use crate::util::Prng;

use super::spec::{ArrivalProcess, ArrivalSpec};

/// Draw `n` inter-arrival gaps (ns) for one thread's item loop.
///
/// * `constant` — every gap is the mean.
/// * `poisson` — exponential gaps (memoryless arrivals).
/// * `bursty` — items arrive back-to-back in bursts of
///   `spec.burst`; the first item of each burst waits the whole
///   burst's worth of mean gap, the rest wait zero.
/// * `diurnal` — a deterministic sinusoidal load curve: the gap
///   swings `±80%` around the mean over `spec.period_ns` of
///   accumulated gap time (a compressed day).
pub fn gaps(spec: &ArrivalSpec, rng: &mut Prng, n: usize) -> Vec<u64> {
    let mean = spec.mean_gap_ns as f64;
    let mut out = Vec::with_capacity(n);
    let mut elapsed = 0.0f64;
    for i in 0..n {
        let gap = match spec.process {
            ArrivalProcess::Constant => mean,
            ArrivalProcess::Poisson => rng.exp(mean),
            ArrivalProcess::Bursty => {
                if i as u64 % spec.burst == 0 {
                    mean * spec.burst as f64
                } else {
                    0.0
                }
            }
            ArrivalProcess::Diurnal => {
                let phase = elapsed / spec.period_ns as f64;
                mean * (1.0 + 0.8 * (2.0 * std::f64::consts::PI * phase).sin())
            }
        };
        let gap = gap.max(0.0);
        elapsed += gap;
        out.push(gap.round() as u64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(process: ArrivalProcess) -> ArrivalSpec {
        ArrivalSpec {
            process,
            mean_gap_ns: 10_000,
            burst: 4,
            period_ns: 200_000,
        }
    }

    #[test]
    fn constant_gaps_are_the_mean() {
        let mut rng = Prng::new(7);
        assert_eq!(
            gaps(&spec(ArrivalProcess::Constant), &mut rng, 3),
            vec![10_000, 10_000, 10_000]
        );
    }

    #[test]
    fn poisson_gaps_are_seed_deterministic_with_the_right_mean() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        let s = spec(ArrivalProcess::Poisson);
        let ga = gaps(&s, &mut a, 4096);
        assert_eq!(ga, gaps(&s, &mut b, 4096), "same seed, same gaps");
        let mut c = Prng::new(8);
        assert_ne!(ga, gaps(&s, &mut c, 4096), "seed must matter");
        let avg = ga.iter().sum::<u64>() as f64 / ga.len() as f64;
        assert!(
            (avg - 10_000.0).abs() < 1_000.0,
            "exponential mean drifted: {avg}"
        );
    }

    #[test]
    fn bursts_frontload_the_gap() {
        let mut rng = Prng::new(7);
        let g = gaps(&spec(ArrivalProcess::Bursty), &mut rng, 8);
        assert_eq!(g, vec![40_000, 0, 0, 0, 40_000, 0, 0, 0]);
        // Total pacing matches the constant process over a full cycle.
        assert_eq!(g.iter().sum::<u64>(), 8 * 10_000);
    }

    #[test]
    fn diurnal_swings_around_the_mean_and_stays_nonnegative() {
        let mut rng = Prng::new(7);
        let g = gaps(&spec(ArrivalProcess::Diurnal), &mut rng, 64);
        assert!(g.iter().any(|&x| x > 10_000), "no peak phase");
        assert!(g.iter().any(|&x| x < 10_000), "no trough phase");
        let lo = (10_000.0 * 0.2 - 1.0) as u64;
        let hi = (10_000.0 * 1.8 + 1.0) as u64;
        assert!(g.iter().all(|&x| x >= lo && x <= hi), "outside ±80%");
    }
}
