//! Injected pathologies: synthetic apps whose bottleneck class is
//! known by construction.
//!
//! Each [`PathologyKind`] builds one [`App`] exhibiting exactly one
//! entry of the paper's bottleneck taxonomy — lock convoys, priority
//! inversion, busy-wait spinning, CPU hogs, memory-bandwidth
//! contention, thread imbalance, pipeline stalls, blocking I/O storms
//! and message storms — and carries the ground-truth
//! [`BottleneckClass`] the profiler *should* report for it. The
//! scorecard (see [`super::score`]) compares `classify()`'s verdict on
//! the top-K reported bottlenecks against these labels.
//!
//! # Making the injected slices critical
//!
//! GAPP only records a timeslice when it is *critical*:
//! `threads_av < N_min`, where `N_min` defaults to half the peak
//! thread count observed across the whole session
//! (`Probes::nmin`). A pathology whose active threads all run in
//! parallel (CPU hogs, spinners, lock-step I/O) would therefore never
//! cross the gate on its own — `threads_av ≈ n` against
//! `N_min = n/2`. Those builders park `n + 2` extra *companion*
//! threads on a latch for the duration of the run: companions count
//! toward the peak (raising `N_min` to `n + 1`) while contributing
//! nothing runnable, exactly like the idle helper/pool threads real
//! servers carry. Contention kinds (lock convoy, priority inversion)
//! need no companions — their own blocked waiters keep the runnable
//! count far below `N_min`.
//!
//! # Keeping the vote on the right path
//!
//! Two structural details matter for classification:
//!
//! * A thread that never blocks ends its one giant timeslice at
//!   `Exit`, and `Ret` pops stack frames — so every builder *omits*
//!   the final `ret()`, leaving the worker frame open so the exit
//!   slice (WaitKind::None) lands on the worker's named path instead
//!   of the empty stack.
//! * Every synthetic app's symbol table starts at the same
//!   `TEXT_BASE`, so stacks of identical shape from different apps
//!   would intern to the same id and merge into one cross-app path
//!   with mixed wait votes. [`build`] pads each pathology app's
//!   symbol table into a disjoint address band (`sym_pad` dummy
//!   slots) so its paths can never collide with another app's.

use crate::gapp::classify::BottleneckClass;
use crate::util::Prng;
use crate::workload::program::ProgramBuilder;
use crate::workload::{App, AppBuilder};

use super::spec::ArrivalSpec;

/// Mean in-critical-section work of one lock-convoy item (ns).
const CONVOY_HOLD_NS: u64 = 40_000;
/// Work done outside the convoy lock per item (ns).
const CONVOY_OUTSIDE_NS: u64 = 5_000;
/// The inverting long holder's critical section (ns).
const PRIO_LONG_HOLD_NS: u64 = 200_000;
/// A victim's short critical section (ns).
const PRIO_SHORT_HOLD_NS: u64 = 10_000;
/// Work outside the lock per iteration (ns).
const PRIO_OUTSIDE_NS: u64 = 5_000;
/// Busy-wait poll burst length (ns) — each burst is pure compute.
const SPIN_POLL_NS: u64 = 2_000;
/// The busy-wait setter's work per item before raising the flag (ns).
const SPIN_WORK_ITEM_NS: u64 = 50_000;
/// One CPU-hog work item (ns).
const HOG_ITEM_NS: u64 = 50_000;
/// Base memory-bandwidth work item (ns); scaled by the thread count
/// at build time to model bandwidth saturation slowing everyone down.
const MEMBW_ITEM_NS: u64 = 20_000;
/// Fast workers' per-round compute in the imbalance pathology (ns).
const IMBALANCE_FAST_NS: u64 = 10_000;
/// The straggler's per-round compute (10x the fast workers).
const IMBALANCE_SLOW_NS: u64 = 100_000;
/// Pipeline/message source: per-item production cost (ns).
const STAGE_SOURCE_NS: u64 = 10_000;
/// Pipeline/message sink: per-consumer slice of the service time (ns).
/// Consumers take `8_000 * consumers` each, so in aggregate they are
/// faster than the source (`0.8x` its period) and block between items
/// — the queue/channel wait is where the criticality accrues.
const STAGE_SINK_PER_CONSUMER_NS: u64 = 8_000;
/// I/O storm: compute between blocking "disk" waits (ns).
const IO_COMPUTE_NS: u64 = 10_000;
/// I/O storm: blocking wait per item (ns).
const IO_WAIT_NS: u64 = 100_000;

/// One entry of the injectable-pathology taxonomy. `membw_contention`
/// and `cpu_hog` share a truth class (both are compute saturation —
/// GAPP cannot tell them apart from scheduler events alone, and does
/// not claim to); they stay distinct kinds because their *shape*
/// differs (membw work inflates with the thread count).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PathologyKind {
    LockConvoy,
    PriorityInversion,
    BusyWait,
    CpuHog,
    MembwContention,
    ThreadImbalance,
    PipelineStall,
    IoStorm,
    MessageStorm,
}

impl PathologyKind {
    pub const ALL: [PathologyKind; 9] = [
        PathologyKind::LockConvoy,
        PathologyKind::PriorityInversion,
        PathologyKind::BusyWait,
        PathologyKind::CpuHog,
        PathologyKind::MembwContention,
        PathologyKind::ThreadImbalance,
        PathologyKind::PipelineStall,
        PathologyKind::IoStorm,
        PathologyKind::MessageStorm,
    ];

    /// Spec-file name of the kind.
    pub fn name(self) -> &'static str {
        match self {
            PathologyKind::LockConvoy => "lock_convoy",
            PathologyKind::PriorityInversion => "priority_inversion",
            PathologyKind::BusyWait => "busy_wait",
            PathologyKind::CpuHog => "cpu_hog",
            PathologyKind::MembwContention => "membw_contention",
            PathologyKind::ThreadImbalance => "thread_imbalance",
            PathologyKind::PipelineStall => "pipeline_stall",
            PathologyKind::IoStorm => "io_storm",
            PathologyKind::MessageStorm => "message_storm",
        }
    }

    pub fn from_name(name: &str) -> Option<PathologyKind> {
        PathologyKind::ALL.into_iter().find(|k| k.name() == name)
    }

    /// Ground-truth class the profiler should report.
    pub fn truth(self) -> BottleneckClass {
        match self {
            PathologyKind::LockConvoy => BottleneckClass::Synchronization,
            PathologyKind::PriorityInversion => BottleneckClass::Synchronization,
            PathologyKind::BusyWait => BottleneckClass::Compute,
            PathologyKind::CpuHog => BottleneckClass::Compute,
            PathologyKind::MembwContention => BottleneckClass::Compute,
            PathologyKind::ThreadImbalance => BottleneckClass::Imbalance,
            PathologyKind::PipelineStall => BottleneckClass::Pipeline,
            PathologyKind::IoStorm => BottleneckClass::Io,
            PathologyKind::MessageStorm => BottleneckClass::Messaging,
        }
    }

    /// Fewest active threads for which the pathology still manifests
    /// (validated at spec parse time). Contention kinds need enough
    /// waiters to keep `threads_av < n/2`; staged kinds need the
    /// consumer side to be aggregate-faster than the source.
    pub fn min_threads(self) -> usize {
        match self {
            PathologyKind::LockConvoy => 4,
            PathologyKind::PriorityInversion => 4,
            PathologyKind::BusyWait => 2,
            PathologyKind::CpuHog => 1,
            PathologyKind::MembwContention => 1,
            PathologyKind::ThreadImbalance => 2,
            PathologyKind::PipelineStall => 3,
            PathologyKind::IoStorm => 1,
            PathologyKind::MessageStorm => 3,
        }
    }

    /// Latch-parked companion threads added on top of the `n` active
    /// ones (zero for the contention kinds — see the module docs).
    pub fn companions(self, threads: usize) -> usize {
        match self {
            PathologyKind::LockConvoy | PathologyKind::PriorityInversion => 0,
            _ => threads + 2,
        }
    }
}

/// Arrival pacing shared by the loop-driven builders: pre-draws one
/// inter-arrival gap per item from the scenario's arrival process
/// (seeded, per-thread stream) and prepends a `[arrival_wait]` sleep
/// to the item. The sleep blocks on its own sub-path, so pacing never
/// pollutes the pathology path's wait histogram. Burst-compute kinds
/// (busy-wait, CPU hog, membw, imbalance) have no per-item loop to
/// pace and ignore the arrival spec.
struct Pacer<'s> {
    arrival: Option<&'s ArrivalSpec>,
    seed: u64,
}

impl Pacer<'_> {
    fn gaps(&self, thread: usize, items: u64) -> Vec<u64> {
        match self.arrival {
            None => Vec::new(),
            Some(spec) => {
                // Tag space disjoint from App::spawn_into's per-thread
                // forks (those use small consecutive tags on the app's
                // own rng, this is a separate root).
                let mut root = Prng::new(self.seed ^ 0x4152_5256_4c21);
                let mut rng = root.fork(thread as u64 + 1);
                super::arrival::gaps(spec, &mut rng, items as usize)
            }
        }
    }

    fn pace(pb: &mut ProgramBuilder<'_>, gaps: &[u64], item: usize) {
        if let Some(&gap) = gaps.get(item) {
            if gap > 0 {
                pb.call("arrival_wait", "arrival.c", 1);
                pb.sleep(gap, 0.0);
                pb.ret();
            }
        }
    }
}

/// Build the pathology as one synthetic [`App`].
///
/// * `name` becomes the app name the report attributes slices to.
/// * `threads` is the number of *active* threads `n` (companions are
///   added internally — `App::num_threads` exceeds `n` for the
///   latch-parked kinds).
/// * `items` scales the work (loop iterations / rounds per thread).
/// * `sym_pad` shifts the app's symbols into a private address band;
///   pass a distinct value per app in the session (the harness uses
///   `64 + 16 * app_index`).
pub fn build(
    kind: PathologyKind,
    name: &str,
    threads: usize,
    items: u64,
    arrival: Option<&ArrivalSpec>,
    seed: u64,
    sym_pad: usize,
) -> App {
    assert!(
        threads >= kind.min_threads(),
        "{} needs at least {} threads (got {threads})",
        kind.name(),
        kind.min_threads(),
    );
    assert!(items >= 1, "{} needs at least one item", kind.name());
    let mut ab = AppBuilder::new(name, seed);
    for _ in 0..sym_pad {
        ab.symtab.add("_pad", "pad.c", 1);
    }
    let pacer = Pacer { arrival, seed };
    match kind {
        PathologyKind::LockConvoy => lock_convoy(&mut ab, threads, items, &pacer),
        PathologyKind::PriorityInversion => priority_inversion(&mut ab, threads, items, &pacer),
        PathologyKind::BusyWait => busy_wait(&mut ab, threads, items),
        PathologyKind::CpuHog => cpu_hog(&mut ab, threads, items),
        PathologyKind::MembwContention => membw_contention(&mut ab, threads, items),
        PathologyKind::ThreadImbalance => thread_imbalance(&mut ab, threads, items),
        PathologyKind::PipelineStall => pipeline_stall(&mut ab, threads, items, &pacer),
        PathologyKind::IoStorm => io_storm(&mut ab, threads, items, &pacer),
        PathologyKind::MessageStorm => message_storm(&mut ab, threads, items, &pacer),
    }
    ab.finish()
}

/// Park `count` companion threads on `latch` (raises `N_min`, adds
/// nothing runnable). Their only slices are a near-zero-cost park and
/// the post-release exit, both on the separate `companion_park` path.
fn park_companions(ab: &mut AppBuilder, latch: crate::workload::ObjId, count: usize) {
    for c in 0..count {
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("companion_park", "companion.c", 5);
            pb.latch_wait(latch);
            pb.build()
        };
        ab.thread(&format!("park{c}"), prog);
    }
}

/// `n` workers hammer one mutex; each item holds it for
/// `CONVOY_HOLD_NS` and does a sliver of work outside. At any instant
/// one worker runs and `n - 1` sit blocked in `futex_wait`, so every
/// re-acquire slice is critical and votes Futex on the shared
/// `convoy_worker` path.
fn lock_convoy(ab: &mut AppBuilder, n: usize, items: u64, pacer: &Pacer<'_>) {
    let m = ab.world.new_mutex();
    for t in 0..n {
        let gaps = pacer.gaps(t, items);
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("convoy_worker", "convoy.c", 10);
            for i in 0..items {
                Pacer::pace(&mut pb, &gaps, i as usize);
                pb.lock(m);
                pb.call("convoy_hold", "convoy.c", 40);
                pb.compute(CONVOY_HOLD_NS, 0.0);
                pb.ret();
                pb.unlock(m);
                pb.compute(CONVOY_OUTSIDE_NS, 0.0);
            }
            // No trailing ret: the exit slice stays on convoy_worker.
            pb.build()
        };
        ab.thread(&format!("convoy{t}"), prog);
    }
}

/// One low-priority-style holder camps on the mutex for
/// `PRIO_LONG_HOLD_NS` per round while `n - 1` victims need it for
/// only `PRIO_SHORT_HOLD_NS`. Victims spend almost all their time
/// blocked behind the long hold — Futex votes on `prio_victim`.
fn priority_inversion(ab: &mut AppBuilder, n: usize, items: u64, pacer: &Pacer<'_>) {
    let m = ab.world.new_mutex();
    let holder = {
        let mut pb = ProgramBuilder::new(&mut ab.symtab);
        pb.call("prio_holder", "prio.c", 10);
        for _ in 0..items {
            pb.lock(m);
            pb.call("prio_long_hold", "prio.c", 40);
            pb.compute(PRIO_LONG_HOLD_NS, 0.0);
            pb.ret();
            pb.unlock(m);
            pb.compute(PRIO_OUTSIDE_NS, 0.0);
        }
        pb.build()
    };
    ab.thread("holder", holder);
    for t in 1..n {
        let gaps = pacer.gaps(t, items);
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("prio_victim", "prio.c", 80);
            for i in 0..items {
                Pacer::pace(&mut pb, &gaps, i as usize);
                pb.lock(m);
                pb.compute(PRIO_SHORT_HOLD_NS, 0.0);
                pb.unlock(m);
                pb.compute(PRIO_OUTSIDE_NS, 0.0);
            }
            pb.build()
        };
        ab.thread(&format!("victim{t}"), prog);
    }
}

/// `n - 1` spinners poll a flag in `SPIN_POLL_NS` compute bursts while
/// one setter grinds through the real work. Spinners never block, so
/// each ends the run as one giant critical slice with WaitKind::None
/// — a Compute vote on `spin_worker` — which is exactly how GAPP sees
/// a busy-wait loop (the paper's §2 motivating case).
fn busy_wait(ab: &mut AppBuilder, n: usize, items: u64) {
    let flag = ab.world.new_flag();
    let latch = ab.world.new_latch(1);
    for t in 0..n - 1 {
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("spin_worker", "spin.c", 10);
            pb.spin_until(flag, SPIN_POLL_NS);
            pb.build()
        };
        ab.thread(&format!("spin{t}"), prog);
    }
    let setter = {
        let mut pb = ProgramBuilder::new(&mut ab.symtab);
        pb.call("spin_setter", "spin.c", 60);
        pb.compute(items * SPIN_WORK_ITEM_NS, 0.0);
        pb.set_flag(flag);
        pb.latch_signal(latch);
        pb.build()
    };
    ab.thread("setter", setter);
    park_companions(ab, latch, PathologyKind::BusyWait.companions(n));
}

/// `n` hogs compute flat-out. With the companions parked on the
/// latch, `N_min = n + 1 > threads_av ≈ n`, so each hog's single
/// exit-terminated slice is critical and votes Compute.
fn cpu_hog(ab: &mut AppBuilder, n: usize, items: u64) {
    let latch = ab.world.new_latch(1);
    for t in 0..n {
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("hog_worker", "hog.c", 10);
            pb.compute(items * HOG_ITEM_NS, 0.0);
            if t == 0 {
                pb.latch_signal(latch);
            }
            pb.build()
        };
        ab.thread(&format!("hog{t}"), prog);
    }
    park_companions(ab, latch, PathologyKind::CpuHog.companions(n));
}

/// Memory-bandwidth contention: like the hog, but each thread's work
/// inflates linearly with the thread count (saturated bus — adding
/// threads slows everyone down). Same observable class as `cpu_hog`;
/// scheduler events cannot distinguish stalled loads from arithmetic.
fn membw_contention(ab: &mut AppBuilder, n: usize, items: u64) {
    let latch = ab.world.new_latch(1);
    for t in 0..n {
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("membw_worker", "membw.c", 10);
            pb.compute(items * MEMBW_ITEM_NS * n as u64, 0.0);
            if t == 0 {
                pb.latch_signal(latch);
            }
            pb.build()
        };
        ab.thread(&format!("membw{t}"), prog);
    }
    park_companions(ab, latch, PathologyKind::MembwContention.companions(n));
}

/// `items` barrier rounds where one straggler does 10x the work.
/// The `n - 1` fast workers block at the barrier every round —
/// `(n-1) * items` Barrier votes on `imbalance_worker` — while the
/// straggler (always last to arrive) never blocks and contributes a
/// single exit-terminated None vote to the same path. Barrier wins
/// the majority; the straggler's solo runtime carries the CMetric.
fn thread_imbalance(ab: &mut AppBuilder, n: usize, items: u64) {
    let b = ab.world.new_barrier(n);
    let latch = ab.world.new_latch(1);
    for t in 0..n {
        let straggler = t == n - 1;
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("imbalance_worker", "imbalance.c", 10);
            for _ in 0..items {
                pb.compute(
                    if straggler {
                        IMBALANCE_SLOW_NS
                    } else {
                        IMBALANCE_FAST_NS
                    },
                    0.0,
                );
                pb.barrier(b);
            }
            if straggler {
                pb.latch_signal(latch);
            }
            pb.build()
        };
        ab.thread(&format!("bal{t}"), prog);
    }
    park_companions(ab, latch, PathologyKind::ThreadImbalance.companions(n));
}

/// A source feeds `n - 1` consumers through a shared queue. Consumers
/// are aggregate-faster than the source, so the queue idles empty and
/// every `queue_pop` blocks — Queue votes on the shared
/// `pipeline_stage` path, whose combined service time out-weighs the
/// source's single None slice in CMetric.
fn pipeline_stall(ab: &mut AppBuilder, n: usize, items: u64, pacer: &Pacer<'_>) {
    let k = n - 1;
    let q = ab.world.new_queue(1024);
    let latch = ab.world.new_latch(1);
    let sink_ns = STAGE_SINK_PER_CONSUMER_NS * k as u64;
    let gaps = pacer.gaps(0, items);
    let source = {
        let mut pb = ProgramBuilder::new(&mut ab.symtab);
        pb.call("pipeline_source", "pipeline.c", 10);
        for i in 0..items {
            Pacer::pace(&mut pb, &gaps, i as usize);
            pb.compute(STAGE_SOURCE_NS, 0.0);
            pb.queue_push(q);
        }
        pb.latch_signal(latch);
        pb.build()
    };
    ab.thread("source", source);
    for j in 0..k {
        // Deterministic partition: the first items % k consumers take
        // one extra, so pops exactly match pushes (no drain deadlock).
        let share = items / k as u64 + u64::from((j as u64) < items % k as u64);
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("pipeline_stage", "pipeline.c", 60);
            for _ in 0..share {
                pb.queue_pop(q);
                pb.compute(sink_ns, 0.0);
            }
            pb.build()
        };
        ab.thread(&format!("stage{j}"), prog);
    }
    park_companions(ab, latch, PathologyKind::PipelineStall.companions(n));
}

/// `n` workers alternate a sliver of compute with a blocking "disk"
/// wait 10x as long — every slice ends in `WaitKind::Io`.
fn io_storm(ab: &mut AppBuilder, n: usize, items: u64, pacer: &Pacer<'_>) {
    let latch = ab.world.new_latch(1);
    for t in 0..n {
        let gaps = pacer.gaps(t, items);
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("io_worker", "io.c", 10);
            for i in 0..items {
                Pacer::pace(&mut pb, &gaps, i as usize);
                pb.compute(IO_COMPUTE_NS, 0.0);
                pb.sleep(IO_WAIT_NS, 0.0);
            }
            if t == 0 {
                pb.latch_signal(latch);
            }
            pb.build()
        };
        ab.thread(&format!("io{t}"), prog);
    }
    park_companions(ab, latch, PathologyKind::IoStorm.companions(n));
}

/// One producer sends `(n-1) * items` messages; `n - 1` consumers
/// each take `items` off the channel with a blocking `recv`. The
/// consumers are aggregate-faster than the producer, so the channel
/// idles empty and every recv blocks — Channel votes on `msg_sink`.
fn message_storm(ab: &mut AppBuilder, n: usize, items: u64, pacer: &Pacer<'_>) {
    let k = n - 1;
    let ch = ab.world.new_channel();
    let latch = ab.world.new_latch(1);
    let sink_ns = STAGE_SINK_PER_CONSUMER_NS * k as u64;
    let total = items * k as u64;
    let gaps = pacer.gaps(0, total);
    let source = {
        let mut pb = ProgramBuilder::new(&mut ab.symtab);
        pb.call("msg_source", "msg.c", 10);
        for i in 0..total {
            Pacer::pace(&mut pb, &gaps, i as usize);
            pb.compute(STAGE_SOURCE_NS, 0.0);
            pb.send(ch);
        }
        pb.latch_signal(latch);
        pb.build()
    };
    ab.thread("source", source);
    for j in 0..k {
        let prog = {
            let mut pb = ProgramBuilder::new(&mut ab.symtab);
            pb.call("msg_sink", "msg.c", 60);
            for _ in 0..items {
                pb.recv(ch, false, 0);
                pb.compute(sink_ns, 0.0);
            }
            pb.build()
        };
        ab.thread(&format!("sink{j}"), prog);
    }
    park_companions(ab, latch, PathologyKind::MessageStorm.companions(n));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip_and_are_distinct() {
        for k in PathologyKind::ALL {
            assert_eq!(PathologyKind::from_name(k.name()), Some(k));
        }
        let mut names: Vec<&str> = PathologyKind::ALL.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), PathologyKind::ALL.len());
        assert_eq!(PathologyKind::from_name("quantum_entanglement"), None);
    }

    #[test]
    fn every_kind_builds_with_expected_thread_count() {
        for k in PathologyKind::ALL {
            let n = k.min_threads().max(4);
            let app = build(k, "t", n, 3, None, 7, 0);
            assert_eq!(
                app.num_threads(),
                n + k.companions(n),
                "{} thread count",
                k.name()
            );
        }
    }

    #[test]
    fn truth_covers_every_bottleneck_class() {
        let mut classes: Vec<BottleneckClass> =
            PathologyKind::ALL.iter().map(|k| k.truth()).collect();
        classes.sort_by_key(|c| c.label().to_string());
        classes.dedup();
        assert_eq!(
            classes.len(),
            BottleneckClass::ALL.len(),
            "the taxonomy must exercise all six classes"
        );
    }

    #[test]
    fn symbol_padding_shifts_the_address_band() {
        let a = build(PathologyKind::CpuHog, "a", 2, 2, None, 7, 0);
        let b = build(PathologyKind::CpuHog, "b", 2, 2, None, 7, 64);
        // Padded app's first real symbol sits 64 slots higher.
        assert_eq!(
            b.symtab.addr_of(64),
            a.symtab.addr_of(0) + 64 * crate::workload::symbols::FUNC_SIZE
        );
    }
}
