//! Scorecard arithmetic: grade `classify()`'s top-K output against a
//! scenario's injected ground truth.
//!
//! Scoring is per *labeled app*, not per report line: each injected
//! pathology app carries one truth class, and its prediction is the
//! class of the highest-ranked bottleneck attributed to that app
//! (rank order is the profiler's own severity claim, so the first
//! attributed line is "what GAPP says is wrong with this app"). An
//! app absent from the top-K scores a false negative — burying a real
//! bottleneck below the fold is a miss, exactly like mislabeling it.
//!
//! Precision/recall/F1 then fall out of the per-class confusion
//! counts: a mislabel charges a false positive to the predicted class
//! *and* a false negative to the true class. Mix apps (background
//! load) carry no label and are never scored. Aggregation across
//! matrix cases re-sums the integer counts — never the ratios — so a
//! merged scorecard equals the scorecard of the merged assignments.

use crate::gapp::classify::BottleneckClass;
use crate::gapp::report::{Bottleneck, Report};
use crate::gapp::sink::{Assignment, ScoreRow, ScorecardEvent};

/// The app a report line is attributed to: the dominant app by slice
/// count in system-wide mode. Single-app reports elide the `apps`
/// vector entirely (their attribution is the whole report), so a bare
/// line matches only when the scenario injected exactly one app.
fn dominant_app(b: &Bottleneck) -> Option<&str> {
    b.apps.first().map(|(a, _)| a.as_str())
}

/// Grade one case's report against its injected labels.
pub fn score_case(
    report: &Report,
    truth: &[(String, BottleneckClass)],
    scope: &str,
) -> ScorecardEvent {
    let assignments: Vec<Assignment> = truth
        .iter()
        .map(|(app, class)| Assignment {
            app: app.clone(),
            truth: *class,
            predicted: report
                .bottlenecks
                .iter()
                .find(|b| match dominant_app(b) {
                    Some(a) => a == app,
                    // No apps vector: a single-app profile; every line
                    // belongs to the sole injected app.
                    None => truth.len() == 1,
                })
                .map(|b| b.class),
        })
        .collect();
    scorecard_of(assignments, scope, 1)
}

/// Pure confusion-count arithmetic over a finished assignment list —
/// the piece the fixture tests pin down by hand.
pub fn scorecard_of(
    assignments: Vec<Assignment>,
    scope: &str,
    cases: u64,
) -> ScorecardEvent {
    let mut rows: Vec<ScoreRow> = BottleneckClass::ALL
        .iter()
        .map(|c| ScoreRow {
            class: *c,
            tp: 0,
            fp: 0,
            fn_: 0,
        })
        .collect();
    let idx = |c: BottleneckClass| {
        BottleneckClass::ALL.iter().position(|k| *k == c).unwrap()
    };
    for a in &assignments {
        match a.predicted {
            Some(p) if p == a.truth => rows[idx(p)].tp += 1,
            Some(p) => {
                rows[idx(p)].fp += 1;
                rows[idx(a.truth)].fn_ += 1;
            }
            None => rows[idx(a.truth)].fn_ += 1,
        }
    }
    ScorecardEvent {
        scope: scope.to_string(),
        cases,
        rows,
        assignments,
    }
}

/// Merge per-case scorecards into one aggregate by re-summing the
/// integer counts. Per-case assignment detail is dropped — the
/// aggregate answers "how often is each class right", the per-case
/// cards answer "which app went wrong where".
pub fn merge(cards: &[ScorecardEvent], scope: &str) -> ScorecardEvent {
    let mut rows: Vec<ScoreRow> = BottleneckClass::ALL
        .iter()
        .map(|c| ScoreRow {
            class: *c,
            tp: 0,
            fp: 0,
            fn_: 0,
        })
        .collect();
    for card in cards {
        for r in &card.rows {
            let slot = rows
                .iter_mut()
                .find(|s| s.class == r.class)
                .expect("rows cover every class");
            slot.tp += r.tp;
            slot.fp += r.fp;
            slot.fn_ += r.fn_;
        }
    }
    ScorecardEvent {
        scope: scope.to_string(),
        cases: cards.iter().map(|c| c.cases).sum(),
        rows,
        assignments: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn asn(
        app: &str,
        truth: BottleneckClass,
        predicted: Option<BottleneckClass>,
    ) -> Assignment {
        Assignment {
            app: app.to_string(),
            truth,
            predicted,
        }
    }

    fn row(sc: &ScorecardEvent, c: BottleneckClass) -> &ScoreRow {
        sc.rows.iter().find(|r| r.class == c).unwrap()
    }

    #[test]
    fn hand_computed_fixture_checks_the_arithmetic() {
        use BottleneckClass::*;
        // 4 labeled apps: one hit, one mislabel (Io read as Compute),
        // one hit, one absent from the top-K.
        let sc = scorecard_of(
            vec![
                asn("lock_convoy#0", Synchronization, Some(Synchronization)),
                asn("io_storm#1", Io, Some(Compute)),
                asn("busy_wait#2", Compute, Some(Compute)),
                asn("pipeline#3", Pipeline, None),
            ],
            "seed=7",
            1,
        );
        assert_eq!(sc.rows.len(), BottleneckClass::ALL.len());
        // Synchronization: clean hit → p = r = f1 = 1.
        let r = row(&sc, Synchronization);
        assert_eq!((r.tp, r.fp, r.fn_), (1, 0, 0));
        assert_eq!((r.precision(), r.recall(), r.f1()), (1.0, 1.0, 1.0));
        // Io: missed entirely → recall 0, and 0/0 precision reads 0.
        let r = row(&sc, Io);
        assert_eq!((r.tp, r.fp, r.fn_), (0, 0, 1));
        assert_eq!((r.precision(), r.recall(), r.f1()), (0.0, 0.0, 0.0));
        // Compute: one hit plus the stolen Io prediction → p 1/2, r 1.
        let r = row(&sc, Compute);
        assert_eq!((r.tp, r.fp, r.fn_), (1, 1, 0));
        assert_eq!(r.precision(), 0.5);
        assert_eq!(r.recall(), 1.0);
        assert!((r.f1() - 2.0 / 3.0).abs() < 1e-12);
        // Pipeline: buried below the fold → FN only.
        let r = row(&sc, Pipeline);
        assert_eq!((r.tp, r.fp, r.fn_), (0, 0, 1));
        // Untouched class stays all-zero.
        let r = row(&sc, Messaging);
        assert_eq!((r.tp, r.fp, r.fn_), (0, 0, 0));
        // Overall sums the counts: tp 2, fp 1, fn 2.
        let o = sc.overall();
        assert_eq!((o.tp, o.fp, o.fn_), (2, 1, 2));
        assert!((o.precision() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(o.recall(), 0.5);
    }

    #[test]
    fn scoring_reads_the_top_ranked_attributed_line() {
        use crate::gapp::report::Report;
        use BottleneckClass::*;
        let line = |rank: usize, class, apps: &[(&str, u64)]| Bottleneck {
            rank,
            total_cm_ms: 1.0,
            slices: 1,
            class,
            top_wakers: Vec::new(),
            apps: apps.iter().map(|(a, n)| (a.to_string(), *n)).collect(),
            call_path: vec!["main".to_string()],
            samples: Vec::new(),
            stack_top_samples: 0,
        };
        let report = Report {
            app: "mix".into(),
            // Rank 1 belongs to the convoy; rank 2 is a second convoy
            // line (ignored — only the first attributed line counts);
            // rank 3 mislabels the io app.
            bottlenecks: vec![
                line(1, Synchronization, &[("convoy", 9), ("io", 1)]),
                line(2, Compute, &[("convoy", 5)]),
                line(3, Compute, &[("io", 4)]),
            ],
            ..Default::default()
        };
        let truth = vec![
            ("convoy".to_string(), Synchronization),
            ("io".to_string(), Io),
            ("ghost".to_string(), Messaging),
        ];
        let sc = score_case(&report, &truth, "case");
        assert_eq!(sc.assignments[0].predicted, Some(Synchronization));
        assert_eq!(sc.assignments[1].predicted, Some(Compute));
        assert_eq!(sc.assignments[2].predicted, None, "ghost never appears");
        assert_eq!(row(&sc, Synchronization).tp, 1);
        assert_eq!(row(&sc, Io).fn_, 1);
        assert_eq!(row(&sc, Compute).fp, 1);
        assert_eq!(row(&sc, Messaging).fn_, 1);

        // Single-app profiles elide the apps vector; a sole label still
        // matches, two labels cannot (attribution would be a guess).
        let bare = Report {
            app: "solo".into(),
            bottlenecks: vec![line(1, Io, &[])],
            ..Default::default()
        };
        let sc = score_case(&bare, &[("solo".to_string(), Io)], "case");
        assert_eq!(sc.assignments[0].predicted, Some(Io));
        let sc = score_case(
            &bare,
            &[("a".to_string(), Io), ("b".to_string(), Io)],
            "case",
        );
        assert_eq!(sc.assignments[0].predicted, None);
        assert_eq!(sc.assignments[1].predicted, None);
    }

    #[test]
    fn merged_cards_equal_the_card_of_merged_assignments() {
        use BottleneckClass::*;
        let a = scorecard_of(
            vec![asn("x", Io, Some(Io)), asn("y", Pipeline, Some(Compute))],
            "seed=7",
            1,
        );
        let b = scorecard_of(vec![asn("x", Io, None)], "seed=11", 1);
        let merged = merge(&[a, b], "aggregate");
        assert_eq!(merged.scope, "aggregate");
        assert_eq!(merged.cases, 2);
        assert!(merged.assignments.is_empty());
        let want = scorecard_of(
            vec![
                asn("x", Io, Some(Io)),
                asn("y", Pipeline, Some(Compute)),
                asn("x", Io, None),
            ],
            "aggregate",
            2,
        );
        for (m, w) in merged.rows.iter().zip(&want.rows) {
            assert_eq!((m.class, m.tp, m.fp, m.fn_), (w.class, w.tp, w.fp, w.fn_));
        }
    }
}
