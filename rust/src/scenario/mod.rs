//! Declarative scenario harness: JSON-driven workloads with injected,
//! ground-truth-labeled bottlenecks, and a scored benchmark of the
//! profiler's classification quality.
//!
//! A scenario file (`scenarios/*.json`, [`spec`]) declares a mix of
//! Table-2 background applications, a set of injected pathologies
//! ([`pathology`]) each carrying the [`BottleneckClass`] a correct
//! profiler must report, optional open-loop arrival pacing
//! ([`arrival`]), and an optional seeds × thread-counts matrix. The
//! harness compiles the declaration into synthetic [`App`]s, runs a
//! windowed [`Session`] per expanded case, and grades `classify()`'s
//! top-K output against the injected labels ([`score`]), emitting the
//! result as a [`ScorecardEvent`] through the ordinary sink layer —
//! so the benchmark's verdict travels in the same human / JSON / JSONL
//! transports as every profile.
//!
//! The CLI surface is `gapp scenario run FILE` (base case, full
//! report + scorecard) and `gapp scenario matrix FILE` (sweep the
//! matrix silently, emit one scorecard per case plus an aggregate).
//! Both are byte-deterministic for a fixed spec and seed: workloads,
//! arrival gaps, the simulated kernel, and the scoring are all pure
//! functions of the spec.

pub mod arrival;
pub mod pathology;
pub mod score;
pub mod spec;

pub use pathology::PathologyKind;
pub use spec::{ArrivalProcess, ArrivalSpec, Case, Scenario};

use anyhow::{anyhow, Result};

use crate::gapp::classify::BottleneckClass;
use crate::gapp::config::GappConfig;
use crate::gapp::sink::{ReportEvent, ReportSink, ScorecardEvent};
use crate::gapp::stream::LiveConfig;
use crate::gapp::{Session, SessionOutput};
use crate::runtime::AnalysisEngine;
use crate::workload::{apps, App};

/// Distance between the private symbol-address bands the harness
/// assigns to the apps of one case. Every `SymbolTable` lays functions
/// out from the same text base, so two apps' same-shape stacks would
/// otherwise intern to the same ids and merge across apps; padding
/// app `i`'s table with `SYM_BAND_BASE + SYM_BAND_STRIDE * i` dummy
/// symbols keeps each app's real functions in a disjoint band (a
/// pathology defines ~6 symbols, far under the stride).
pub const SYM_BAND_BASE: usize = 64;
pub const SYM_BAND_STRIDE: usize = 16;

/// One expanded case, compiled to runnable apps plus its truth table.
pub struct CaseSetup {
    /// Mix apps first (unlabeled), then one app per pathology.
    pub apps: Vec<App>,
    /// `(app name, injected class)` for each pathology app.
    pub truth: Vec<(String, BottleneckClass)>,
}

/// Compile one case of a scenario into apps + ground-truth labels.
///
/// Pathology apps are named `{kind}#{index}` (stable across runs, so
/// scorecard assignments are self-describing), seeded from the case
/// seed plus their position, and placed in disjoint symbol bands. A
/// matrix thread override replaces every pathology's thread count;
/// mix apps keep their declared sizes — they are background load, not
/// the subject under test.
pub fn build_case(sc: &Scenario, case: &Case) -> Result<CaseSetup, String> {
    let mut out = CaseSetup {
        apps: Vec::with_capacity(sc.mix.len() + sc.pathologies.len()),
        truth: Vec::with_capacity(sc.pathologies.len()),
    };
    let mut app_index = 0usize;
    for m in &sc.mix {
        let seed = case.seed.wrapping_add(app_index as u64);
        let app = apps::by_name(&m.app, m.threads, seed)
            .ok_or_else(|| format!("scenario: unknown mix app {:?}", m.app))?;
        out.apps.push(app);
        app_index += 1;
    }
    for (i, p) in sc.pathologies.iter().enumerate() {
        let threads = case.threads.unwrap_or(p.threads);
        if threads < p.kind.min_threads() {
            return Err(format!(
                "scenario: {:?} needs at least {} threads (got {threads})",
                p.kind.name(),
                p.kind.min_threads()
            ));
        }
        let name = format!("{}#{i}", p.kind.name());
        let seed = case.seed.wrapping_add(app_index as u64);
        let sym_pad = SYM_BAND_BASE + SYM_BAND_STRIDE * app_index;
        out.apps.push(pathology::build(
            p.kind,
            &name,
            threads,
            p.items,
            sc.arrival.as_ref(),
            seed,
            sym_pad,
        ));
        out.truth.push((name, p.kind.truth()));
        app_index += 1;
    }
    Ok(out)
}

/// Result of one executed case.
pub struct CaseOutcome {
    pub output: SessionOutput,
    pub scorecard: ScorecardEvent,
}

/// Forwards every event to the inner sink and, immediately after
/// `Final`, computes and injects the case's `Scorecard` — so a plain
/// `--format jsonl` consumer sees the grade inline in the stream it
/// already parses.
pub struct ScorecardSink<S: ReportSink> {
    inner: S,
    truth: Vec<(String, BottleneckClass)>,
    scope: String,
}

impl<S: ReportSink> ScorecardSink<S> {
    pub fn new(
        inner: S,
        truth: Vec<(String, BottleneckClass)>,
        scope: impl Into<String>,
    ) -> ScorecardSink<S> {
        ScorecardSink {
            inner,
            truth,
            scope: scope.into(),
        }
    }
}

impl<S: ReportSink> ReportSink for ScorecardSink<S> {
    fn on_event(&mut self, ev: &ReportEvent<'_>) -> Result<()> {
        self.inner.on_event(ev)?;
        if let ReportEvent::Final(fe) = ev {
            let card = score::score_case(fe.report, &self.truth, &self.scope);
            self.inner.on_event(&ReportEvent::Scorecard(&card))?;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.inner.finish()
    }
}

/// Run one case end to end: compile apps, run a windowed session
/// (with the optional sink seeing the full event stream including the
/// injected `Scorecard`), and grade the final report.
pub fn run_case(
    sc: &Scenario,
    case: &Case,
    engine: AnalysisEngine,
    sink: Option<Box<dyn ReportSink + '_>>,
) -> Result<CaseOutcome> {
    let setup = build_case(sc, case).map_err(|e| anyhow!(e))?;
    let gcfg = GappConfig {
        top_n: sc.top_k,
        nmin: sc.nmin,
        ..GappConfig::default()
    };
    let lcfg = LiveConfig {
        window_ns: sc.window_us * 1_000,
        top_k: sc.top_k,
        ..LiveConfig::default()
    };
    let scope = format!("case {}: {}", case.index, case.label());
    let mut session = Session::builder(engine).config(gcfg).live(lcfg);
    for app in &setup.apps {
        session = session.app(app);
    }
    if let Some(s) = sink {
        session = session.sink(ScorecardSink::new(s, setup.truth.clone(), scope.clone()));
    }
    let output = session.run()?;
    let scorecard = score::score_case(&output.report, &setup.truth, &scope);
    Ok(CaseOutcome { output, scorecard })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::sink::FnSink;
    use crate::scenario::spec::PathologySpec;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn tiny_scenario(kind: PathologyKind, threads: usize) -> Scenario {
        Scenario {
            name: "test".to_string(),
            seed: 7,
            window_us: 5_000,
            top_k: 8,
            nmin: None,
            arrival: None,
            mix: Vec::new(),
            pathologies: vec![PathologySpec {
                kind,
                threads,
                items: 6,
            }],
            matrix: None,
        }
    }

    #[test]
    fn build_case_names_bands_and_labels_every_pathology() {
        let mut sc = tiny_scenario(PathologyKind::LockConvoy, 4);
        sc.pathologies.push(PathologySpec {
            kind: PathologyKind::IoStorm,
            threads: 2,
            items: 4,
        });
        sc.mix.push(spec::MixSpec {
            app: "blackscholes".to_string(),
            threads: 2,
        });
        let case = Case {
            index: 0,
            seed: 7,
            threads: None,
        };
        let setup = build_case(&sc, &case).unwrap();
        assert_eq!(setup.apps.len(), 3, "mix + two pathologies");
        assert_eq!(setup.apps[0].name, "blackscholes");
        assert_eq!(setup.apps[1].name, "lock_convoy#0");
        assert_eq!(setup.apps[2].name, "io_storm#1");
        assert_eq!(
            setup.truth,
            vec![
                ("lock_convoy#0".to_string(), BottleneckClass::Synchronization),
                ("io_storm#1".to_string(), BottleneckClass::Io),
            ]
        );
    }

    #[test]
    fn matrix_thread_override_applies_to_pathologies_only() {
        let mut sc = tiny_scenario(PathologyKind::LockConvoy, 4);
        sc.mix.push(spec::MixSpec {
            app: "blackscholes".to_string(),
            threads: 2,
        });
        let case = Case {
            index: 0,
            seed: 7,
            threads: Some(6),
        };
        let setup = build_case(&sc, &case).unwrap();
        assert_eq!(setup.apps[1].num_threads(), 6, "override applied");
        // And an override below the kind's floor is a real error even
        // though parse-time validation cannot see runtime overrides.
        let case = Case {
            index: 0,
            seed: 7,
            threads: Some(2),
        };
        let err = build_case(&sc, &case).unwrap_err();
        assert!(err.contains("at least 4"), "{err}");
    }

    #[test]
    fn run_case_emits_the_scorecard_after_final() {
        let sc = tiny_scenario(PathologyKind::LockConvoy, 4);
        let case = Case {
            index: 0,
            seed: 7,
            threads: None,
        };
        let events = Rc::new(RefCell::new(Vec::<String>::new()));
        let ev2 = events.clone();
        let sink = FnSink(move |ev: &ReportEvent<'_>| {
            let name = match ev {
                ReportEvent::SessionStart(_) => "start",
                ReportEvent::Symbols(_) => "symbols",
                ReportEvent::ShardWindow(_) => "shard",
                ReportEvent::Degraded { .. } => "degraded",
                ReportEvent::WindowClosed(_) => "window",
                ReportEvent::Final(_) => "final",
                ReportEvent::Scorecard(sc) => {
                    assert_eq!(sc.cases, 1);
                    assert_eq!(sc.assignments.len(), 1);
                    "scorecard"
                }
                ReportEvent::SessionEnd { .. } => "end",
            };
            ev2.borrow_mut().push(name.to_string());
        });
        let outcome = run_case(
            &sc,
            &case,
            AnalysisEngine::native(),
            Some(Box::new(sink)),
        )
        .unwrap();
        let seen = events.borrow();
        let pos = |name: &str| seen.iter().position(|e| e == name).unwrap();
        assert!(pos("final") < pos("scorecard"));
        assert!(pos("scorecard") < pos("end"));
        // The returned scorecard matches the one injected mid-stream
        // (both are score_case over the same report).
        assert_eq!(outcome.scorecard.assignments.len(), 1);
        assert_eq!(
            outcome.scorecard.assignments[0].truth,
            BottleneckClass::Synchronization
        );
    }
}
