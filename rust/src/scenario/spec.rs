//! Versioned scenario spec files (`scenarios/*.json`).
//!
//! A scenario is operator input, so it follows the fault-plan parsing
//! policy (`gapp/faults.rs`), not the wire-schema policy: the document
//! carries a `"scenario": 1` version stamp, every unknown key is a
//! hard error, and every numeric knob is validated at parse time — a
//! typo must not silently drop the pathology it meant to inject.
//!
//! ```json
//! {
//!   "scenario": 1,
//!   "name": "lock convoy exemplar",
//!   "seed": 7,
//!   "window_us": 5000,
//!   "top_k": 8,
//!   "arrival": {"process": "poisson", "mean_gap_us": 20},
//!   "mix": [{"app": "mysql", "threads": 8}],
//!   "pathologies": [{"kind": "lock_convoy", "threads": 8, "items": 24}],
//!   "matrix": {"seeds": [7, 11], "threads": [4, 8]}
//! }
//! ```
//!
//! See `scenarios/README.md` for the full schema reference and the
//! versioning policy.

use crate::util::json::Json;
use crate::workload::apps::ALL_APPS;

use super::pathology::PathologyKind;

/// Version stamp of the scenario document schema.
pub const SCENARIO_VERSION: u64 = 1;

/// Default base seed when the spec does not pick one.
pub const DEFAULT_SEED: u64 = 7;
/// Default epoch window length (µs).
pub const DEFAULT_WINDOW_US: u64 = 5_000;
/// Default number of top bottlenecks the scorecard inspects.
pub const DEFAULT_TOP_K: usize = 8;
/// Default burst length for the bursty arrival process.
pub const DEFAULT_BURST: u64 = 4;
/// Default diurnal period (µs of accumulated gap time).
pub const DEFAULT_PERIOD_US: f64 = 20_000.0;

/// The arrival-process family (see [`super::arrival::gaps`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalProcess {
    Constant,
    Poisson,
    Bursty,
    Diurnal,
}

impl ArrivalProcess {
    pub fn name(self) -> &'static str {
        match self {
            ArrivalProcess::Constant => "constant",
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty => "bursty",
            ArrivalProcess::Diurnal => "diurnal",
        }
    }

    fn from_name(name: &str) -> Option<ArrivalProcess> {
        [
            ArrivalProcess::Constant,
            ArrivalProcess::Poisson,
            ArrivalProcess::Bursty,
            ArrivalProcess::Diurnal,
        ]
        .into_iter()
        .find(|p| p.name() == name)
    }
}

/// Open-loop pacing applied to the loop-driven pathologies.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ArrivalSpec {
    pub process: ArrivalProcess,
    /// Mean inter-arrival gap (ns).
    pub mean_gap_ns: u64,
    /// Items per burst (`bursty` only).
    pub burst: u64,
    /// Sinusoid period in ns of accumulated gap time (`diurnal` only).
    pub period_ns: u64,
}

/// One background application drawn from `workload/apps`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MixSpec {
    pub app: String,
    pub threads: usize,
}

/// One injected pathology instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PathologySpec {
    pub kind: PathologyKind,
    /// Active threads (companions are added by the builder).
    pub threads: usize,
    /// Work items / rounds per thread.
    pub items: u64,
}

/// The seeds × thread-counts sweep `gapp scenario matrix` expands.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MatrixSpec {
    pub seeds: Vec<u64>,
    /// Thread-count overrides applied to every pathology in the case.
    pub threads: Vec<usize>,
}

/// One expanded case of a scenario: a concrete seed plus an optional
/// matrix thread-count override. `scenario run` executes the base
/// case; `scenario matrix` sweeps [`Scenario::cases`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Case {
    pub index: usize,
    pub seed: u64,
    pub threads: Option<usize>,
}

impl Case {
    /// Stable display label (`seed=7`, `seed=7 threads=8`).
    pub fn label(&self) -> String {
        match self.threads {
            Some(t) => format!("seed={} threads={}", self.seed, t),
            None => format!("seed={}", self.seed),
        }
    }
}

/// A parsed, validated scenario document.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub seed: u64,
    pub window_us: u64,
    pub top_k: usize,
    /// `N_min` override for the session (`None` = GAPP's `n/2`).
    pub nmin: Option<f64>,
    pub arrival: Option<ArrivalSpec>,
    pub mix: Vec<MixSpec>,
    pub pathologies: Vec<PathologySpec>,
    pub matrix: Option<MatrixSpec>,
}

impl Scenario {
    /// Parse and validate a scenario document. Unknown keys are
    /// rejected at every nesting level.
    pub fn parse(text: &str) -> Result<Scenario, String> {
        let doc = Json::parse(text).map_err(|e| format!("scenario: {e}"))?;
        let fields = match &doc {
            Json::Obj(fields) => fields,
            _ => return Err("scenario: document must be an object".to_string()),
        };
        let version = doc
            .get("scenario")
            .ok_or("scenario: missing \"scenario\" version stamp")?
            .as_u64()
            .ok_or("scenario: \"scenario\" is not a u64")?;
        if version != SCENARIO_VERSION {
            return Err(format!(
                "scenario: unsupported version {version} (expected {SCENARIO_VERSION})"
            ));
        }
        let mut name = None;
        let mut seed = DEFAULT_SEED;
        let mut window_us = DEFAULT_WINDOW_US;
        let mut top_k = DEFAULT_TOP_K;
        let mut nmin = None;
        let mut arrival = None;
        let mut mix = Vec::new();
        let mut pathologies = Vec::new();
        let mut matrix = None;
        for (key, value) in fields {
            match key.as_str() {
                "scenario" => {}
                "name" => {
                    name = Some(
                        value
                            .as_str()
                            .ok_or("scenario: \"name\" is not a string")?
                            .to_string(),
                    );
                }
                "seed" => {
                    seed = value.as_u64().ok_or("scenario: \"seed\" is not a u64")?;
                }
                "window_us" => {
                    window_us = value
                        .as_u64()
                        .ok_or("scenario: \"window_us\" is not a u64")?;
                    if window_us == 0 {
                        return Err("scenario: \"window_us\" must be >= 1".to_string());
                    }
                }
                "top_k" => {
                    let k = value.as_u64().ok_or("scenario: \"top_k\" is not a u64")?;
                    if k == 0 {
                        return Err("scenario: \"top_k\" must be >= 1".to_string());
                    }
                    top_k = k as usize;
                }
                "nmin" => {
                    let v = value
                        .as_f64()
                        .ok_or("scenario: \"nmin\" is not a number")?;
                    if !v.is_finite() || v <= 0.0 {
                        return Err(format!("scenario: \"nmin\" must be positive (got {v})"));
                    }
                    nmin = Some(v);
                }
                "arrival" => arrival = Some(parse_arrival(value)?),
                "mix" => {
                    let arr = value.as_arr().ok_or("scenario: \"mix\" is not an array")?;
                    for entry in arr {
                        mix.push(parse_mix(entry)?);
                    }
                }
                "pathologies" => {
                    let arr = value
                        .as_arr()
                        .ok_or("scenario: \"pathologies\" is not an array")?;
                    for entry in arr {
                        pathologies.push(parse_pathology(entry)?);
                    }
                }
                "matrix" => matrix = Some(parse_matrix(value)?),
                other => {
                    return Err(format!(
                        "scenario: unknown key {other:?} (a typo would silently \
                         drop the knob it meant to set)"
                    ))
                }
            }
        }
        let scenario = Scenario {
            name: name.ok_or("scenario: missing required key \"name\"")?,
            seed,
            window_us,
            top_k,
            nmin,
            arrival,
            mix,
            pathologies,
            matrix,
        };
        scenario.validate()?;
        Ok(scenario)
    }

    /// Read and parse a scenario file.
    pub fn load(path: &str) -> Result<Scenario, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read scenario {path:?}: {e}"))?;
        Scenario::parse(&text)
    }

    fn validate(&self) -> Result<(), String> {
        if self.pathologies.is_empty() {
            return Err(
                "scenario: \"pathologies\" must name at least one injected pathology"
                    .to_string(),
            );
        }
        if let Some(m) = &self.matrix {
            if m.seeds.is_empty() {
                return Err("scenario: \"matrix\" \"seeds\" must be non-empty".to_string());
            }
            if m.threads.is_empty() {
                return Err("scenario: \"matrix\" \"threads\" must be non-empty".to_string());
            }
            for p in &self.pathologies {
                let floor = p.kind.min_threads();
                for &t in &m.threads {
                    if t < floor {
                        return Err(format!(
                            "scenario: matrix threads {t} below {:?} floor of {floor}",
                            p.kind.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Expand the matrix: seeds outer, thread counts inner, in spec
    /// order. Without a `matrix` block this is the single base case.
    pub fn cases(&self) -> Vec<Case> {
        match &self.matrix {
            None => vec![Case {
                index: 0,
                seed: self.seed,
                threads: None,
            }],
            Some(m) => {
                let mut out = Vec::with_capacity(m.seeds.len() * m.threads.len());
                for &seed in &m.seeds {
                    for &threads in &m.threads {
                        out.push(Case {
                            index: out.len(),
                            seed,
                            threads: Some(threads),
                        });
                    }
                }
                out
            }
        }
    }
}

fn parse_arrival(value: &Json) -> Result<ArrivalSpec, String> {
    let fields = match value {
        Json::Obj(fields) => fields,
        _ => return Err("scenario: \"arrival\" is not an object".to_string()),
    };
    let mut process = None;
    let mut mean_gap_ns = None;
    let mut burst = DEFAULT_BURST;
    let mut period_us = DEFAULT_PERIOD_US;
    for (key, v) in fields {
        match key.as_str() {
            "process" => {
                let s = v
                    .as_str()
                    .ok_or("scenario: arrival \"process\" is not a string")?;
                process = Some(ArrivalProcess::from_name(s).ok_or_else(|| {
                    format!(
                        "scenario: unknown arrival process {s:?} \
                         (constant|poisson|bursty|diurnal)"
                    )
                })?);
            }
            "mean_gap_us" => {
                let us = v
                    .as_f64()
                    .ok_or("scenario: arrival \"mean_gap_us\" is not a number")?;
                if !us.is_finite() || us <= 0.0 {
                    return Err(format!(
                        "scenario: arrival \"mean_gap_us\" must be positive (got {us})"
                    ));
                }
                mean_gap_ns = Some((us * 1_000.0).round() as u64);
            }
            "burst" => {
                burst = v
                    .as_u64()
                    .ok_or("scenario: arrival \"burst\" is not a u64")?;
                if burst == 0 {
                    return Err("scenario: arrival \"burst\" must be >= 1".to_string());
                }
            }
            "period_us" => {
                period_us = v
                    .as_f64()
                    .ok_or("scenario: arrival \"period_us\" is not a number")?;
                if !period_us.is_finite() || period_us <= 0.0 {
                    return Err(format!(
                        "scenario: arrival \"period_us\" must be positive (got {period_us})"
                    ));
                }
            }
            other => {
                return Err(format!("scenario: unknown arrival key {other:?}"));
            }
        }
    }
    Ok(ArrivalSpec {
        process: process.ok_or("scenario: arrival is missing \"process\"")?,
        mean_gap_ns: mean_gap_ns.ok_or("scenario: arrival is missing \"mean_gap_us\"")?
            .max(1),
        burst,
        period_ns: (period_us * 1_000.0).round().max(1.0) as u64,
    })
}

fn parse_mix(value: &Json) -> Result<MixSpec, String> {
    let fields = match value {
        Json::Obj(fields) => fields,
        _ => return Err("scenario: \"mix\" entries must be objects".to_string()),
    };
    let mut app = None;
    let mut threads = None;
    for (key, v) in fields {
        match key.as_str() {
            "app" => {
                let s = v.as_str().ok_or("scenario: mix \"app\" is not a string")?;
                if !ALL_APPS.contains(&s) {
                    return Err(format!(
                        "scenario: unknown mix app {s:?} (see `gapp list-apps`)"
                    ));
                }
                app = Some(s.to_string());
            }
            "threads" => {
                let t = v
                    .as_u64()
                    .ok_or("scenario: mix \"threads\" is not a u64")?;
                if t == 0 {
                    return Err("scenario: mix \"threads\" must be >= 1".to_string());
                }
                threads = Some(t as usize);
            }
            other => return Err(format!("scenario: unknown mix key {other:?}")),
        }
    }
    Ok(MixSpec {
        app: app.ok_or("scenario: mix entry is missing \"app\"")?,
        threads: threads.ok_or("scenario: mix entry is missing \"threads\"")?,
    })
}

fn parse_pathology(value: &Json) -> Result<PathologySpec, String> {
    let fields = match value {
        Json::Obj(fields) => fields,
        _ => return Err("scenario: \"pathologies\" entries must be objects".to_string()),
    };
    let mut kind = None;
    let mut threads = None;
    let mut items = 24u64;
    for (key, v) in fields {
        match key.as_str() {
            "kind" => {
                let s = v
                    .as_str()
                    .ok_or("scenario: pathology \"kind\" is not a string")?;
                kind = Some(PathologyKind::from_name(s).ok_or_else(|| {
                    let known: Vec<&str> =
                        PathologyKind::ALL.iter().map(|k| k.name()).collect();
                    format!(
                        "scenario: unknown pathology kind {s:?} (one of {})",
                        known.join("|")
                    )
                })?);
            }
            "threads" => {
                let t = v
                    .as_u64()
                    .ok_or("scenario: pathology \"threads\" is not a u64")?;
                if t == 0 {
                    return Err("scenario: pathology \"threads\" must be >= 1".to_string());
                }
                threads = Some(t as usize);
            }
            "items" => {
                items = v
                    .as_u64()
                    .ok_or("scenario: pathology \"items\" is not a u64")?;
                if items == 0 {
                    return Err("scenario: pathology \"items\" must be >= 1".to_string());
                }
            }
            other => return Err(format!("scenario: unknown pathology key {other:?}")),
        }
    }
    let kind = kind.ok_or("scenario: pathology entry is missing \"kind\"")?;
    let threads = threads.ok_or("scenario: pathology entry is missing \"threads\"")?;
    if threads < kind.min_threads() {
        return Err(format!(
            "scenario: {:?} needs at least {} threads (got {threads})",
            kind.name(),
            kind.min_threads()
        ));
    }
    Ok(PathologySpec {
        kind,
        threads,
        items,
    })
}

fn parse_matrix(value: &Json) -> Result<MatrixSpec, String> {
    let fields = match value {
        Json::Obj(fields) => fields,
        _ => return Err("scenario: \"matrix\" is not an object".to_string()),
    };
    let mut seeds = Vec::new();
    let mut threads = Vec::new();
    for (key, v) in fields {
        match key.as_str() {
            "seeds" => {
                let arr = v
                    .as_arr()
                    .ok_or("scenario: matrix \"seeds\" is not an array")?;
                for s in arr {
                    seeds.push(
                        s.as_u64()
                            .ok_or("scenario: matrix \"seeds\" entries must be u64s")?,
                    );
                }
            }
            "threads" => {
                let arr = v
                    .as_arr()
                    .ok_or("scenario: matrix \"threads\" is not an array")?;
                for t in arr {
                    let t = t
                        .as_u64()
                        .ok_or("scenario: matrix \"threads\" entries must be u64s")?;
                    if t == 0 {
                        return Err("scenario: matrix \"threads\" must be >= 1".to_string());
                    }
                    threads.push(t as usize);
                }
            }
            other => return Err(format!("scenario: unknown matrix key {other:?}")),
        }
    }
    Ok(MatrixSpec { seeds, threads })
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = r#"{
        "scenario": 1,
        "name": "t",
        "pathologies": [{"kind": "cpu_hog", "threads": 2}]
    }"#;

    #[test]
    fn minimal_spec_gets_the_documented_defaults() {
        let sc = Scenario::parse(MINIMAL).unwrap();
        assert_eq!(sc.seed, DEFAULT_SEED);
        assert_eq!(sc.window_us, DEFAULT_WINDOW_US);
        assert_eq!(sc.top_k, DEFAULT_TOP_K);
        assert_eq!(sc.nmin, None);
        assert!(sc.arrival.is_none() && sc.mix.is_empty() && sc.matrix.is_none());
        assert_eq!(sc.pathologies[0].items, 24);
        assert_eq!(sc.cases(), vec![Case { index: 0, seed: 7, threads: None }]);
    }

    #[test]
    fn full_spec_round_trips_every_knob() {
        let sc = Scenario::parse(
            r#"{
                "scenario": 1,
                "name": "full",
                "seed": 11,
                "window_us": 2000,
                "top_k": 5,
                "nmin": 6.5,
                "arrival": {"process": "bursty", "mean_gap_us": 15.5,
                            "burst": 3, "period_us": 1000},
                "mix": [{"app": "mysql", "threads": 4}],
                "pathologies": [
                    {"kind": "lock_convoy", "threads": 6, "items": 10},
                    {"kind": "io_storm", "threads": 2}
                ],
                "matrix": {"seeds": [1, 2], "threads": [4, 8, 16]}
            }"#,
        )
        .unwrap();
        assert_eq!(sc.seed, 11);
        assert_eq!(sc.nmin, Some(6.5));
        let a = sc.arrival.unwrap();
        assert_eq!(a.process, ArrivalProcess::Bursty);
        assert_eq!(a.mean_gap_ns, 15_500);
        assert_eq!(a.burst, 3);
        assert_eq!(a.period_ns, 1_000_000);
        assert_eq!(sc.mix[0].app, "mysql");
        assert_eq!(sc.pathologies.len(), 2);
        // Matrix expansion: seeds outer, threads inner, stable indexes.
        let cases = sc.cases();
        assert_eq!(cases.len(), 6);
        assert_eq!(cases[0], Case { index: 0, seed: 1, threads: Some(4) });
        assert_eq!(cases[4], Case { index: 4, seed: 2, threads: Some(8) });
        assert_eq!(cases[5].label(), "seed=2 threads=16");
    }

    #[test]
    fn bad_specs_get_descriptive_errors() {
        for (text, what) in [
            ("[1]", "object"),
            ("{\"name\": \"x\"}", "version stamp"),
            ("{\"scenario\": 2, \"name\": \"x\"}", "version 2"),
            ("{\"scenario\": 1, \"nmae\": \"typo\"}", "nmae"),
            ("{\"scenario\": 1, \"pathologies\": []}", "name"),
            (MINIMAL_WITHOUT_PATHOLOGIES, "pathologies"),
            (
                r#"{"scenario": 1, "name": "x",
                    "pathologies": [{"kind": "cpu_hog", "threads": 0}]}"#,
                "threads",
            ),
            (
                r#"{"scenario": 1, "name": "x",
                    "pathologies": [{"kind": "lock_convoy", "threads": 2}]}"#,
                "at least 4",
            ),
            (
                r#"{"scenario": 1, "name": "x",
                    "pathologies": [{"kind": "warp_drive", "threads": 2}]}"#,
                "warp_drive",
            ),
            (
                r#"{"scenario": 1, "name": "x",
                    "arrival": {"process": "poisson", "mean_gap_us": -5},
                    "pathologies": [{"kind": "cpu_hog", "threads": 2}]}"#,
                "mean_gap_us",
            ),
            (
                r#"{"scenario": 1, "name": "x",
                    "arrival": {"process": "warp", "mean_gap_us": 5},
                    "pathologies": [{"kind": "cpu_hog", "threads": 2}]}"#,
                "warp",
            ),
            (
                r#"{"scenario": 1, "name": "x",
                    "mix": [{"app": "notanapp", "threads": 2}],
                    "pathologies": [{"kind": "cpu_hog", "threads": 2}]}"#,
                "notanapp",
            ),
            (
                r#"{"scenario": 1, "name": "x",
                    "pathologies": [{"kind": "lock_convoy", "threads": 8}],
                    "matrix": {"seeds": [1], "threads": [2]}}"#,
                "floor",
            ),
            (
                r#"{"scenario": 1, "name": "x",
                    "pathologies": [{"kind": "cpu_hog", "threads": 2}],
                    "matrix": {"seeds": [], "threads": [4]}}"#,
                "seeds",
            ),
            ("{not json", "scenario"),
        ] {
            let err = Scenario::parse(text).unwrap_err();
            assert!(err.contains(what), "{text}: {err:?} should mention {what:?}");
        }
    }

    const MINIMAL_WITHOUT_PATHOLOGIES: &str = r#"{"scenario": 1, "name": "x"}"#;
}
