//! `gapp scenario matrix` — sweep a scenario's seeds × thread-counts
//! matrix and emit one classification scorecard per case plus a
//! micro-averaged aggregate.
//!
//! Each expanded case runs as a *silent* session (no sink: the full
//! per-case report stream would drown the sweep's verdict); only the
//! scorecards travel to the caller's sink, framed as an ordinary
//! event sequence — per-case `Scorecard` events carrying the
//! assignment detail, then one aggregate card with the summed counts,
//! then `SessionEnd` with the total simulated runtime. A `--format
//! json` consumer therefore gets one document whose `scorecards`
//! array is the whole benchmark result.

use anyhow::Result;

use crate::gapp::sink::{ReportEvent, ReportSink};
use crate::runtime::AnalysisEngine;
use crate::scenario::{run_case, score, Scenario};

/// Run every expanded case of `sc` and stream scorecards into `sink`.
/// `engine` builds one fresh analysis engine per case (sessions
/// consume theirs). Returns the per-case cards plus the aggregate.
pub fn run_matrix(
    sc: &Scenario,
    engine: &dyn Fn() -> AnalysisEngine,
    sink: &mut dyn ReportSink,
) -> Result<Vec<crate::gapp::sink::ScorecardEvent>> {
    let cases = sc.cases();
    let mut cards = Vec::with_capacity(cases.len() + 1);
    let mut runtime_ns = 0u64;
    for case in &cases {
        let outcome = run_case(sc, case, engine(), None)?;
        runtime_ns += outcome.output.runtime_ns;
        cards.push(outcome.scorecard);
    }
    let aggregate = score::merge(&cards, "matrix aggregate");
    for card in &cards {
        sink.on_event(&ReportEvent::Scorecard(card))?;
    }
    sink.on_event(&ReportEvent::Scorecard(&aggregate))?;
    sink.on_event(&ReportEvent::SessionEnd { runtime_ns })?;
    sink.finish()?;
    cards.push(aggregate);
    Ok(cards)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::sink::FnSink;
    use crate::scenario::spec::{MatrixSpec, PathologySpec};
    use crate::scenario::PathologyKind;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn matrix_emits_per_case_cards_then_aggregate_then_end() {
        let sc = Scenario {
            name: "m".to_string(),
            seed: 7,
            window_us: 5_000,
            top_k: 8,
            nmin: None,
            arrival: None,
            mix: Vec::new(),
            pathologies: vec![PathologySpec {
                kind: PathologyKind::LockConvoy,
                threads: 4,
                items: 6,
            }],
            matrix: Some(MatrixSpec {
                seeds: vec![7, 11],
                threads: vec![4],
            }),
        };
        let log = Rc::new(RefCell::new(Vec::<String>::new()));
        let l2 = log.clone();
        let mut sink = FnSink(move |ev: &ReportEvent<'_>| {
            l2.borrow_mut().push(match ev {
                ReportEvent::Scorecard(c) => format!("card:{}", c.scope),
                ReportEvent::SessionEnd { runtime_ns } => {
                    assert!(*runtime_ns > 0);
                    "end".to_string()
                }
                _ => "other".to_string(),
            });
        });
        let cards =
            run_matrix(&sc, &AnalysisEngine::native, &mut sink).unwrap();
        assert_eq!(cards.len(), 3, "two cases + aggregate");
        assert_eq!(
            *log.borrow(),
            vec![
                "card:case 0: seed=7 threads=4".to_string(),
                "card:case 1: seed=11 threads=4".to_string(),
                "card:matrix aggregate".to_string(),
                "end".to_string(),
            ]
        );
        let agg = cards.last().unwrap();
        assert_eq!(agg.cases, 2);
        assert!(agg.assignments.is_empty());
        // Aggregate counts are the sums of the per-case counts.
        let sum: u64 = cards[..2].iter().map(|c| c.overall().tp).sum();
        assert_eq!(agg.overall().tp, sum);
    }
}
