//! Figure 3 + §5.2 Bodytrack: detect the serial OutputBMP, confirm by
//! commenting it out (RecvCmd samples drop ~45%), fix by offloading to a
//! writerThread (~22% faster).

use anyhow::Result;

use crate::gapp::GappConfig;
use crate::simkernel::KernelConfig;
use crate::workload::apps::{bodytrack, BodytrackConfig};

use super::runner::{profiled_run, EngineKind};

#[derive(Clone, Debug)]
pub struct Fig3Result {
    /// Baseline: top functions and RecvCmd sample count.
    pub base_top: Vec<(String, u64)>,
    pub base_recvcmd_samples: u64,
    pub base_runtime_ns: u64,
    /// OutputBMP commented out: RecvCmd sample reduction (%).
    pub skip_recvcmd_samples: u64,
    pub recvcmd_reduction_pct: f64,
    /// writerThread fix: runtime improvement (%).
    pub fixed_runtime_ns: u64,
    pub runtime_improvement_pct: f64,
}

pub fn run(engine: EngineKind, threads: usize, seed: u64) -> Result<Fig3Result> {
    let kcfg = KernelConfig::default();
    // Sample faster than the default 3 ms: bodytrack's serial section is
    // ~1.2 ms per frame (the paper's native input is ~50× larger).
    let gcfg = GappConfig {
        dt: 200_000,
        ..Default::default()
    };

    let base = profiled_run(
        || bodytrack(threads, seed, BodytrackConfig::default()),
        kcfg.clone(),
        gcfg.clone(),
        engine,
    )?;
    let skip = profiled_run(
        || {
            bodytrack(
                threads,
                seed,
                BodytrackConfig {
                    skip_output: true,
                    ..Default::default()
                },
            )
        },
        kcfg.clone(),
        gcfg.clone(),
        engine,
    )?;
    let fixed = profiled_run(
        || {
            bodytrack(
                threads,
                seed,
                BodytrackConfig {
                    offload_writer: true,
                    ..Default::default()
                },
            )
        },
        kcfg,
        gcfg,
        engine,
    )?;

    let recv = "condition_variable::RecvCmd";
    let base_recv = base.report.samples_of(recv);
    let skip_recv = skip.report.samples_of(recv);
    let reduction = if base_recv > 0 {
        100.0 * (base_recv.saturating_sub(skip_recv)) as f64 / base_recv as f64
    } else {
        0.0
    };
    let improvement = 100.0
        * (base.base_ns as f64 - fixed.base_ns as f64)
        / base.base_ns as f64;

    Ok(Fig3Result {
        base_top: base.report.top_functions(4),
        base_recvcmd_samples: base_recv,
        base_runtime_ns: base.base_ns,
        skip_recvcmd_samples: skip_recv,
        recvcmd_reduction_pct: reduction,
        fixed_runtime_ns: fixed.base_ns,
        runtime_improvement_pct: improvement,
    })
}

pub fn render(r: &Fig3Result) -> String {
    let mut s = String::from("== Figure 3 / §5.2 Bodytrack ==\n");
    s.push_str(&format!("top functions: {:?}\n", r.base_top));
    s.push_str(&format!(
        "RecvCmd samples: {} -> {} when OutputBMP removed ({:.0}% reduction; paper: ~45%)\n",
        r.base_recvcmd_samples, r.skip_recvcmd_samples, r.recvcmd_reduction_pct
    ));
    s.push_str(&format!(
        "runtime: {:.2} ms -> {:.2} ms with writerThread ({:.1}% better; paper: 22%)\n",
        r.base_runtime_ns as f64 / 1e6,
        r.fixed_runtime_ns as f64 / 1e6,
        r.runtime_improvement_pct
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_detects_and_fixes_the_bottleneck() {
        let r = run(EngineKind::Native, 16, 21).unwrap();
        // GAPP must surface the wait (RecvCmd) and/or the serial culprit.
        assert!(
            r.base_top
                .iter()
                .any(|(f, _)| f.contains("RecvCmd") || f.contains("OutputBMP")),
            "top={:?}",
            r.base_top
        );
        // Commenting out OutputBMP reduces RecvCmd samples (paper: 45%).
        assert!(
            r.recvcmd_reduction_pct > 15.0,
            "reduction={:.1}%",
            r.recvcmd_reduction_pct
        );
        // The writer-thread fix lands in the paper's band.
        assert!(
            (10.0..35.0).contains(&r.runtime_improvement_pct),
            "improvement={:.1}%",
            r.runtime_improvement_pct
        );
    }
}
