//! §6 baseline comparisons:
//!  B1 — wPerf's post-processing time dwarfs GAPP's on the same trace
//!       (paper: 271.9 s vs 3 s for MySQL).
//!  B2 — Coz-style causal profiling varies across runs; GAPP is
//!       deterministic for a given input.
//!  B3 — on-CPU criticality (Criticality Stacks) miscounts parallelism
//!       when threads > CPUs; GAPP's TASK_RUNNING count does not.

use anyhow::Result;

use crate::baselines::{CozProfiler, CritStacksProfiler, WPerfProfiler};
use crate::gapp::{GappConfig, Session};
use crate::simkernel::{Kernel, KernelConfig};
use crate::workload::apps;

use super::runner::EngineKind;

#[derive(Clone, Debug)]
pub struct BaselinesResult {
    // B1
    pub gapp_ppt_s: f64,
    pub wperf_ppt_s: f64,
    pub wperf_segments: usize,
    // B2
    pub coz_distinct_rankings: usize,
    pub coz_runs: usize,
    pub gapp_distinct_top: usize,
    // B3
    pub oncpu_avg_parallelism: f64,
    pub gapp_avg_parallelism: f64,
}

pub fn run(engine: EngineKind, seed: u64) -> Result<BaselinesResult> {
    // ---- B1: MySQL trace through both post-processors -----------------
    let mysql_cfg = apps::MysqlConfig::default();
    let app = apps::mysql(32, seed, mysql_cfg);
    let report = Session::builder(engine.make()?)
        .config(GappConfig::default())
        .app(&app)
        .run()?
        .report;
    let gapp_ppt_s = report.ppt_seconds;

    let app2 = apps::mysql(32, seed, mysql_cfg);
    let wperf = WPerfProfiler::new(64);
    let mut k = Kernel::new(KernelConfig::default());
    k.attach_probe(wperf.probe());
    app2.spawn_into(&mut k);
    k.run()?;
    let wreport = wperf.finish();

    // ---- B2: run-to-run stability --------------------------------------
    let coz_runs = 5;
    let mut rankings = Vec::new();
    for s in 0..coz_runs {
        let app = apps::ferret(
            seed,
            apps::FerretConfig {
                queries: 80,
                ..apps::FerretConfig::with_alloc(4, 2, 6, 10)
            },
        );
        let r = CozProfiler::run(&app, KernelConfig::default(), seed + s as u64)?;
        rankings.push(
            r.ranking().into_iter().take(3).collect::<Vec<_>>(),
        );
    }
    let mut distinct = rankings.clone();
    distinct.sort();
    distinct.dedup();
    let coz_distinct_rankings = distinct.len();

    let mut gapp_tops = Vec::new();
    for _ in 0..3 {
        let app = apps::ferret(
            seed,
            apps::FerretConfig {
                queries: 80,
                ..apps::FerretConfig::with_alloc(4, 2, 6, 10)
            },
        );
        let rep = Session::builder(EngineKind::Native.make()?)
            .config(GappConfig::default())
            .app(&app)
            .run()?
            .report;
        gapp_tops.push(rep.top_functions(1));
    }
    gapp_tops.dedup();
    let gapp_distinct_top = gapp_tops.len();

    // ---- B3: oversubscription -------------------------------------------
    let kcfg8 = KernelConfig {
        cpus: 8,
        ..Default::default()
    };
    let app = apps::blackscholes(32, seed);
    let (_, oncpu_avg) = CritStacksProfiler::run(&app, kcfg8.clone())?;
    let app2 = apps::blackscholes(32, seed);
    let rep = Session::builder(EngineKind::Native.make()?)
        .kernel(kcfg8)
        .config(GappConfig::default())
        .app(&app2)
        .run()?
        .report;
    let (w, c) = rep
        .threads
        .iter()
        .fold((0.0, 0.0), |(w, c), t| (w + t.wall_ms, c + t.cm_ms));
    let gapp_avg = w / c.max(1e-9);

    Ok(BaselinesResult {
        gapp_ppt_s,
        wperf_ppt_s: wreport.ppt_seconds,
        wperf_segments: wreport.segments,
        coz_distinct_rankings,
        coz_runs,
        gapp_distinct_top,
        oncpu_avg_parallelism: oncpu_avg,
        gapp_avg_parallelism: gapp_avg,
    })
}

pub fn render(r: &BaselinesResult) -> String {
    format!(
        "== §6 baseline comparisons ==\n\
         B1 PPT on MySQL trace: GAPP {:.3} s vs wPerf {:.3} s over {} wait \
         segments ({}x; paper: 3 s vs 271.9 s)\n\
         B2 stability: Coz produced {}/{} distinct top-3 rankings across \
         seeds; GAPP produced {} distinct top-1 across repeat runs\n\
         B3 oversubscription (32 threads / 8 CPUs): avg parallelism \
         on-CPU {:.1} vs GAPP {:.1} (TASK_RUNNING)\n",
        r.gapp_ppt_s,
        r.wperf_ppt_s,
        r.wperf_segments,
        if r.gapp_ppt_s > 0.0 {
            (r.wperf_ppt_s / r.gapp_ppt_s) as u64
        } else {
            0
        },
        r.coz_distinct_rankings,
        r.coz_runs,
        r.gapp_distinct_top,
        r.oncpu_avg_parallelism,
        r.gapp_avg_parallelism
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_comparisons_hold() {
        let r = run(EngineKind::Native, 41).unwrap();
        // B1: wPerf post-processing costs more than GAPP's.
        assert!(
            r.wperf_ppt_s > r.gapp_ppt_s,
            "wperf={:.4}s gapp={:.4}s",
            r.wperf_ppt_s,
            r.gapp_ppt_s
        );
        // B2: Coz varies; GAPP deterministic.
        assert!(r.coz_distinct_rankings > 1);
        assert_eq!(r.gapp_distinct_top, 1);
        // B3: on-CPU parallelism saturates at the CPU count.
        assert!(r.oncpu_avg_parallelism <= 8.0 + 1e-6);
        assert!(r.gapp_avg_parallelism > 2.0 * r.oncpu_avg_parallelism);
    }
}
