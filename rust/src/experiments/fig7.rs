//! Figure 7 + §5.3 MySQL: fil_flush (InnoDB log flush) is the top
//! critical path; enlarging the buffer pool gives +19% tps / −16%
//! latency; raising INNODB_SPIN_WAIT_DELAY on top gives +34% tps
//! cumulative; spin-delay alone is negligible — bottlenecks must be
//! fixed in criticality order.

use anyhow::Result;

use crate::gapp::GappConfig;
use crate::simkernel::KernelConfig;
use crate::workload::apps::{mysql, run_oltp, MysqlConfig};

use super::runner::{profiled_run, EngineKind};

#[derive(Clone, Debug)]
pub struct TpsPoint {
    pub label: String,
    pub tps: f64,
    pub avg_latency_ms: f64,
}

#[derive(Clone, Debug)]
pub struct Fig7Result {
    /// Top critical call path of the default configuration (Fig 7a/b).
    pub default_top: Vec<(String, u64)>,
    pub default_paths: Vec<Vec<String>>,
    pub points: Vec<TpsPoint>,
    pub buffer_gain_pct: f64,
    pub cumulative_gain_pct: f64,
    pub spin_only_gain_pct: f64,
    pub latency_reduction_pct: f64,
}

const THREADS: usize = 32;

fn oltp(label: &str, seed: u64, cfg: MysqlConfig) -> TpsPoint {
    let o = run_oltp(THREADS, seed, cfg);
    TpsPoint {
        label: label.to_string(),
        tps: o.tps,
        avg_latency_ms: o.avg_latency_ns / 1e6,
    }
}

pub fn run(engine: EngineKind, seed: u64) -> Result<Fig7Result> {
    // Profile the default configuration to get the critical paths.
    let profiled = profiled_run(
        || mysql(THREADS, seed, MysqlConfig::default()),
        KernelConfig::default(),
        GappConfig {
            dt: 300_000,
            ..Default::default()
        },
        engine,
    )?;
    let default_top = profiled.report.top_functions(5);
    let default_paths: Vec<Vec<String>> = profiled
        .report
        .bottlenecks
        .iter()
        .take(3)
        .map(|b| b.call_path.clone())
        .collect();

    // Tuning ladder (unprofiled runs, as sysbench would measure).
    let base = oltp("default (8GB pool, spin 6)", seed, MysqlConfig::default());
    let buffer = oltp(
        "buffer pool 90GB",
        seed,
        MysqlConfig {
            buffer_pool_gb: 90,
            ..Default::default()
        },
    );
    let both = oltp(
        "90GB pool + spin 30",
        seed,
        MysqlConfig {
            buffer_pool_gb: 90,
            spin_wait_delay: 30,
            ..Default::default()
        },
    );
    let spin_only = oltp(
        "spin 30 only",
        seed,
        MysqlConfig {
            spin_wait_delay: 30,
            ..Default::default()
        },
    );

    let pct = |a: f64, b: f64| 100.0 * (b - a) / a;
    Ok(Fig7Result {
        default_top,
        default_paths,
        buffer_gain_pct: pct(base.tps, buffer.tps),
        cumulative_gain_pct: pct(base.tps, both.tps),
        spin_only_gain_pct: pct(base.tps, spin_only.tps),
        latency_reduction_pct: -pct(base.avg_latency_ms, buffer.avg_latency_ms),
        points: vec![base, buffer, both, spin_only],
    })
}

pub fn render(r: &Fig7Result) -> String {
    let mut s = String::from("== Figure 7 / §5.3 MySQL ==\n");
    s.push_str(&format!("top critical functions: {:?}\n", r.default_top));
    for (i, p) in r.default_paths.iter().enumerate() {
        s.push_str(&format!("critical path #{}: {}\n", i + 1, p.join(" -> ")));
    }
    for p in &r.points {
        s.push_str(&format!(
            "{:<28} {:>9.0} tps   avg latency {:>7.2} ms\n",
            p.label, p.tps, p.avg_latency_ms
        ));
    }
    s.push_str(&format!(
        "buffer-pool gain {:.1}% (paper +19%) | cumulative {:.1}% (paper +34%) | \
         spin-only {:.1}% (paper ≈0) | latency −{:.1}% (paper −16%)\n",
        r.buffer_gain_pct,
        r.cumulative_gain_pct,
        r.spin_only_gain_pct,
        r.latency_reduction_pct
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_critical_path_and_tuning_ladder() {
        let r = run(EngineKind::Native, 41).unwrap();
        // fil_flush (via pfs_os_file_flush_func) tops the profile.
        assert!(
            r.default_top
                .iter()
                .take(3)
                .any(|(f, _)| f.contains("fil_flush")
                    || f.contains("pfs_os_file_flush_func")),
            "top={:?}",
            r.default_top
        );
        // The spin path appears among the critical functions too.
        assert!(
            r.default_top
                .iter()
                .any(|(f, _)| f.contains("sync_array_reserve_cell")
                    || f.contains("rw_lock_s_lock_spin")),
            "top={:?}",
            r.default_top
        );
        // Tuning ladder shape.
        assert!(
            (8.0..45.0).contains(&r.buffer_gain_pct),
            "buffer={:.1}%",
            r.buffer_gain_pct
        );
        assert!(
            r.cumulative_gain_pct > r.buffer_gain_pct,
            "cumulative={:.1}% buffer={:.1}%",
            r.cumulative_gain_pct,
            r.buffer_gain_pct
        );
        assert!(
            r.spin_only_gain_pct.abs() < 8.0,
            "spin_only={:.1}%",
            r.spin_only_gain_pct
        );
        assert!(r.latency_reduction_pct > 0.0);
    }
}
