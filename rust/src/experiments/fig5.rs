//! Figure 5 + §5.3 Nektar++: aggressive-mode busy-waiting masks the load
//! imbalance (flat CMetric); blocking mode reveals it; a structured,
//! uniformly-partitioned mesh flattens it for the right reason.

use anyhow::Result;

use crate::gapp::GappConfig;
use crate::simkernel::KernelConfig;
use crate::workload::apps::{nektar, MeshKind, MpiMode, NektarConfig};

use super::runner::{profiled_run, EngineKind};

#[derive(Clone, Debug)]
pub struct ModeRun {
    pub label: String,
    pub cm_series: Vec<(String, f64)>,
    pub cm_cv: f64,
}

#[derive(Clone, Debug)]
pub struct Fig5Result {
    pub aggressive: ModeRun,
    pub blocking: ModeRun,
    pub cuboid: ModeRun,
}

fn one(engine: EngineKind, seed: u64, label: &str, cfg: NektarConfig) -> Result<ModeRun> {
    let r = profiled_run(
        || nektar(seed, cfg),
        KernelConfig::default(),
        GappConfig::default(),
        engine,
    )?;
    let cm_series = r.report.thread_cm_series();
    let cv = crate::util::Summary::of(
        &cm_series.iter().map(|(_, c)| *c).collect::<Vec<_>>(),
    )
    .cv();
    Ok(ModeRun {
        label: label.to_string(),
        cm_series,
        cm_cv: cv,
    })
}

pub fn run(engine: EngineKind, seed: u64) -> Result<Fig5Result> {
    let aggressive = one(
        engine,
        seed,
        "OpenMPI aggressive (cylinder)",
        NektarConfig {
            mode: MpiMode::Aggressive,
            ..Default::default()
        },
    )?;
    let blocking = one(
        engine,
        seed,
        "MPICH ch3:sock blocking (cylinder)",
        NektarConfig::default(),
    )?;
    let cuboid = one(
        engine,
        seed,
        "blocking (structured cuboid, 8 ranks)",
        NektarConfig {
            mesh: MeshKind::Cuboid,
            ranks: 8,
            ..Default::default()
        },
    )?;
    Ok(Fig5Result {
        aggressive,
        blocking,
        cuboid,
    })
}

pub fn render(r: &Fig5Result) -> String {
    let mut s = String::from("== Figure 5 / §5.3 Nektar++ (per-process CMetric) ==\n");
    for m in [&r.aggressive, &r.blocking, &r.cuboid] {
        s.push_str(&format!("{:<40} CMetric CV {:.3}\n", m.label, m.cm_cv));
        let series: Vec<String> = m
            .cm_series
            .iter()
            .map(|(_, c)| format!("{c:.1}"))
            .collect();
        s.push_str(&format!("  per-rank CMetric (ms): [{}]\n", series.join(", ")));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_masking_and_unmasking() {
        let r = run(EngineKind::Native, 7).unwrap();
        // Aggressive mode: flat (spinning ranks are always "active").
        // Blocking: imbalance visible. Cuboid: flat again (real balance).
        assert!(
            r.aggressive.cm_cv < 0.5 * r.blocking.cm_cv,
            "aggr={:.3} block={:.3}",
            r.aggressive.cm_cv,
            r.blocking.cm_cv
        );
        assert!(
            r.cuboid.cm_cv < 0.5 * r.blocking.cm_cv,
            "cuboid={:.3} block={:.3}",
            r.cuboid.cm_cv,
            r.blocking.cm_cv
        );
        assert_eq!(r.blocking.cm_series.len(), 16);
        assert_eq!(r.cuboid.cm_series.len(), 8);
    }
}
