//! Experiment harness: one module per paper table/figure (DESIGN.md §4).
//!
//! Every experiment is a function returning structured results plus a
//! rendered text block; the CLI (`gapp <exp>`), the benches and the
//! end-to-end example all call the same code, so the numbers in
//! EXPERIMENTS.md are regenerated rather than transcribed.

pub mod runner;
pub mod table2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod dedup_alloc;
pub mod sensitivity;
pub mod overhead;
pub mod baselines_cmp;
pub mod scenario_matrix;

pub use runner::{profiled_run, EngineKind, ProfiledRun};
