//! §5.4 overhead study: per-app runtime overhead with its drivers. The
//! paper reports avg ≈4%, max ≈13%, and that overhead tracks the
//! critical-slice ratio, stack depth and number of distinct stacks — all
//! of which emerge from the probe cost model here.

use anyhow::Result;

use crate::gapp::GappConfig;
use crate::simkernel::KernelConfig;
use crate::util::stats::Table;
use crate::workload::apps;

use super::runner::{profiled_run, EngineKind};

#[derive(Clone, Debug)]
pub struct OverheadRow {
    pub app: String,
    pub overhead_pct: f64,
    pub critical_ratio_pct: f64,
    pub switches_per_ms: f64,
    pub probe_cost_ms: f64,
}

#[derive(Clone, Debug)]
pub struct OverheadResult {
    pub rows: Vec<OverheadRow>,
    pub mean_pct: f64,
    pub max_pct: f64,
    /// Pearson correlation between CR and overhead across apps.
    pub cr_correlation: f64,
}

pub fn run(engine: EngineKind, threads: usize, seed: u64) -> Result<OverheadResult> {
    let mut rows = Vec::new();
    for name in apps::ALL_APPS {
        let r = profiled_run(
            || apps::by_name(name, threads, seed).expect("known app"),
            KernelConfig::default(),
            GappConfig::default(),
            engine,
        )?;
        rows.push(OverheadRow {
            app: name.to_string(),
            overhead_pct: r.overhead_pct,
            critical_ratio_pct: 100.0 * r.report.critical_ratio(),
            switches_per_ms: r.report.total_slices as f64
                / (r.report.runtime_ns as f64 / 1e6),
            probe_cost_ms: r.report.probe_cost_ns as f64 / 1e6,
        });
    }
    let ohs: Vec<f64> = rows.iter().map(|r| r.overhead_pct).collect();
    let crs: Vec<f64> = rows.iter().map(|r| r.critical_ratio_pct).collect();
    let mean_pct = ohs.iter().sum::<f64>() / ohs.len() as f64;
    let max_pct = ohs.iter().cloned().fold(0.0, f64::max);
    Ok(OverheadResult {
        rows,
        mean_pct,
        max_pct,
        cr_correlation: pearson(&crs, &ohs),
    })
}

fn pearson(x: &[f64], y: &[f64]) -> f64 {
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let cov: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum::<f64>().sqrt();
    let sy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum::<f64>().sqrt();
    if sx * sy == 0.0 {
        0.0
    } else {
        cov / (sx * sy)
    }
}

pub fn render(r: &OverheadResult) -> String {
    let mut t = Table::new(&["Application", "O/H", "CR", "switch/ms", "probe (ms)"]);
    for row in &r.rows {
        t.row(&[
            row.app.clone(),
            format!("{:.2}%", row.overhead_pct),
            format!("{:.2}%", row.critical_ratio_pct),
            format!("{:.1}", row.switches_per_ms),
            format!("{:.2}", row.probe_cost_ms),
        ]);
    }
    format!(
        "== §5.4 overhead ==\n{}mean {:.2}% (paper ≈4%) | max {:.2}% (paper ≈13%) | corr(CR, O/H) = {:.2}\n",
        t.render(),
        r.mean_pct,
        r.max_pct,
        r.cr_correlation
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_band_matches_paper_shape() {
        let r = run(EngineKind::Native, 16, 7).unwrap();
        assert!(r.mean_pct < 8.0, "mean={:.2}%", r.mean_pct);
        assert!(r.max_pct < 18.0, "max={:.2}%", r.max_pct);
        // Overhead should broadly track the event/critical-slice volume.
        assert!(r.cr_correlation > 0.0, "corr={:.2}", r.cr_correlation);
    }
}
