//! Figure 4 + §5.2 Ferret: per-thread CMetric under different stage
//! allocations; CMetric-guided rebalancing to 2-1-18-39 (~50% faster,
//! vs ~23% for [10]'s 20-1-22-21).

use anyhow::Result;

use crate::gapp::GappConfig;
use crate::simkernel::KernelConfig;
use crate::workload::apps::{ferret, FerretConfig};

use super::runner::{profiled_run, EngineKind};

#[derive(Clone, Debug)]
pub struct AllocRun {
    pub label: String,
    pub alloc: (usize, usize, usize, usize),
    pub runtime_ns: u64,
    /// Per-thread CMetric (ms), in thread order (the Figure-4 series).
    pub cm_series: Vec<(String, f64)>,
    pub cm_cv: f64,
    pub top_functions: Vec<(String, u64)>,
}

#[derive(Clone, Debug)]
pub struct Fig4Result {
    pub runs: Vec<AllocRun>,
    pub balanced_improvement_pct: f64,
    pub coz_improvement_pct: f64,
}

fn one(
    engine: EngineKind,
    seed: u64,
    label: &str,
    a: (usize, usize, usize, usize),
) -> Result<AllocRun> {
    // Scaled workload → scaled sampling period (the paper's native-input
    // runs are ~30 s; ours are tens of ms, so Δt shrinks accordingly).
    let gcfg = GappConfig {
        dt: 500_000,
        ..Default::default()
    };
    let r = profiled_run(
        || ferret(seed, FerretConfig::with_alloc(a.0, a.1, a.2, a.3)),
        KernelConfig::default(),
        gcfg,
        engine,
    )?;
    let cm_series = r.report.thread_cm_series();
    let cv = crate::util::Summary::of(
        &cm_series.iter().map(|(_, c)| *c).collect::<Vec<_>>(),
    )
    .cv();
    Ok(AllocRun {
        label: label.to_string(),
        alloc: a,
        runtime_ns: r.base_ns,
        cm_series,
        cm_cv: cv,
        top_functions: r.report.top_functions(3),
    })
}

pub fn run(engine: EngineKind, seed: u64) -> Result<Fig4Result> {
    let default = one(engine, seed, "default 15-15-15-15", (15, 15, 15, 15))?;
    let coz = one(engine, seed, "coz 20-1-22-21", (20, 1, 22, 21))?;
    let balanced = one(engine, seed, "balanced 2-1-18-39", (2, 1, 18, 39))?;
    let imp = |x: &AllocRun| {
        100.0 * (default.runtime_ns as f64 - x.runtime_ns as f64)
            / default.runtime_ns as f64
    };
    let balanced_improvement_pct = imp(&balanced);
    let coz_improvement_pct = imp(&coz);
    Ok(Fig4Result {
        runs: vec![default, coz, balanced],
        balanced_improvement_pct,
        coz_improvement_pct,
    })
}

pub fn render(r: &Fig4Result) -> String {
    let mut s = String::from("== Figure 4 / §5.2 Ferret ==\n");
    for run in &r.runs {
        s.push_str(&format!(
            "{:<22} runtime {:>8.2} ms  CMetric CV {:.3}  top {:?}\n",
            run.label,
            run.runtime_ns as f64 / 1e6,
            run.cm_cv,
            run.top_functions.iter().take(2).collect::<Vec<_>>()
        ));
    }
    s.push_str(&format!(
        "balanced improvement: {:.1}% (paper ~50%) | [10]'s alloc: {:.1}% (paper ~23%)\n",
        r.balanced_improvement_pct, r.coz_improvement_pct
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shape_holds() {
        let r = run(EngineKind::Native, 31).unwrap();
        // Rank-stage kernels dominate the default run's critical samples.
        assert!(
            r.runs[0]
                .top_functions
                .iter()
                .any(|(f, _)| f.contains("dist_L2_float") || f.contains("emd")),
            "top={:?}",
            r.runs[0].top_functions
        );
        // Balanced allocation flattens the CMetric profile…
        assert!(
            r.runs[2].cm_cv < r.runs[0].cm_cv,
            "cv balanced={:.3} default={:.3}",
            r.runs[2].cm_cv,
            r.runs[0].cm_cv
        );
        // …and wins by roughly the paper's margin, beating [10]'s alloc.
        assert!(
            (35.0..65.0).contains(&r.balanced_improvement_pct),
            "balanced={:.1}%",
            r.balanced_improvement_pct
        );
        assert!(r.balanced_improvement_pct > r.coz_improvement_pct);
    }
}
