//! Table 2: critical functions, overhead, runtime, critical ratio,
//! memory and post-processing time for all 13 applications.

use anyhow::Result;

use crate::gapp::GappConfig;
use crate::simkernel::KernelConfig;
use crate::util::stats::Table;
use crate::workload::apps;

use super::runner::{profiled_run, EngineKind};

/// One Table-2 row.
#[derive(Clone, Debug)]
pub struct Row {
    pub app: String,
    pub critical_functions: Vec<String>,
    pub overhead_pct: f64,
    pub runtime_s: f64,
    pub critical_slices: u64,
    pub critical_ratio_pct: f64,
    pub memory_mb: f64,
    pub ppt_s: f64,
    pub backend: &'static str,
}

/// Regenerate Table 2.
pub fn run(engine: EngineKind, threads: usize, seed: u64) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for name in apps::ALL_APPS {
        let r = profiled_run(
            || apps::by_name(name, threads, seed).expect("known app"),
            KernelConfig::default(),
            GappConfig::default(),
            engine,
        )?;
        let top: Vec<String> = r
            .report
            .top_functions(2)
            .into_iter()
            .map(|(f, _)| f)
            .collect();
        rows.push(Row {
            app: name.to_string(),
            critical_functions: top,
            overhead_pct: r.overhead_pct,
            runtime_s: r.base_ns as f64 / 1e9,
            critical_slices: r.report.critical_slices,
            critical_ratio_pct: 100.0 * r.report.critical_ratio(),
            memory_mb: r.report.memory_bytes as f64 / (1024.0 * 1024.0),
            ppt_s: r.report.ppt_seconds,
            backend: r.report.backend,
        });
    }
    Ok(rows)
}

/// Render rows in the paper's column layout.
pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&[
        "Application",
        "Critical functions (GAPP)",
        "O/H",
        "T (s)",
        "CR",
        "M (MB)",
        "PPT (s)",
    ]);
    for r in rows {
        t.row(&[
            r.app.clone(),
            r.critical_functions.join(", "),
            format!("{:.1}%", r.overhead_pct),
            format!("{:.3}", r.runtime_s),
            format!("{} ({:.2}%)", r.critical_slices, r.critical_ratio_pct),
            format!("{:.1}", r.memory_mb),
            format!("{:.3}", r.ppt_s),
        ]);
    }
    format!("== Table 2 (regenerated) ==\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_small_subset_has_sane_shape() {
        // Full Table 2 runs in the bench/example; here spot-check one
        // high-CR app and one low-CR app at reduced thread counts.
        let rows = run(EngineKind::Native, 16, 7).unwrap();
        assert_eq!(rows.len(), 13);
        let by_name = |n: &str| rows.iter().find(|r| r.app == n).unwrap();
        let dedup = by_name("dedup");
        let blacks = by_name("blackscholes");
        // Dedup's critical ratio dwarfs blackscholes' (40% vs 2% in the
        // paper); shape check only.
        assert!(
            dedup.critical_ratio_pct > 5.0 * blacks.critical_ratio_pct.max(0.1),
            "dedup={:.2}% blackscholes={:.2}%",
            dedup.critical_ratio_pct,
            blacks.critical_ratio_pct
        );
        // Every app produced a report with at least one critical function.
        for r in &rows {
            assert!(
                !r.critical_functions.is_empty(),
                "{} produced no critical functions",
                r.app
            );
            assert!(r.overhead_pct < 25.0, "{}: O/H {:.1}%", r.app, r.overhead_pct);
        }
    }
}
