//! §5.1 parameter sensitivity: N_min (default n/2) and Δt (default 3 ms).
//! The paper defers the sweep to its repository README; we regenerate it:
//! CR and overhead grow with N_min; sample volume grows as Δt shrinks;
//! the *identity of the top bottleneck* should be stable across a wide
//! band (that robustness is the reason the defaults are usable).

use anyhow::Result;

use crate::gapp::GappConfig;
use crate::simkernel::KernelConfig;
use crate::workload::apps::{bodytrack, BodytrackConfig};

use super::runner::{profiled_run, EngineKind};

#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub param: String,
    pub critical_ratio_pct: f64,
    pub samples: u64,
    pub overhead_pct: f64,
    pub top_function: Option<String>,
}

#[derive(Clone, Debug)]
pub struct SensitivityResult {
    pub nmin_sweep: Vec<SweepPoint>,
    pub dt_sweep: Vec<SweepPoint>,
}

const THREADS: usize = 16;

fn point(engine: EngineKind, seed: u64, label: String, gcfg: GappConfig) -> Result<SweepPoint> {
    let r = profiled_run(
        || bodytrack(THREADS, seed, BodytrackConfig::default()),
        KernelConfig::default(),
        gcfg,
        engine,
    )?;
    Ok(SweepPoint {
        param: label,
        critical_ratio_pct: 100.0 * r.report.critical_ratio(),
        samples: r.report.samples,
        overhead_pct: r.overhead_pct,
        top_function: r.report.top_functions(1).first().map(|(f, _)| f.clone()),
    })
}

pub fn run(engine: EngineKind, seed: u64) -> Result<SensitivityResult> {
    let n = (THREADS + 1) as f64;
    let mut nmin_sweep = Vec::new();
    for frac in [0.125, 0.25, 0.5, 0.75, 1.0] {
        let gcfg = GappConfig {
            nmin: Some(n * frac),
            dt: 200_000,
            ..Default::default()
        };
        nmin_sweep.push(point(engine, seed, format!("Nmin = {frac} n"), gcfg)?);
    }
    let mut dt_sweep = Vec::new();
    for dt_us in [100u64, 300, 1000, 3000, 10_000] {
        let gcfg = GappConfig {
            dt: dt_us * 1000,
            ..Default::default()
        };
        dt_sweep.push(point(engine, seed, format!("dt = {dt_us} us"), gcfg)?);
    }
    Ok(SensitivityResult {
        nmin_sweep,
        dt_sweep,
    })
}

pub fn render(r: &SensitivityResult) -> String {
    let mut s = String::from("== §5.1 sensitivity (bodytrack) ==\n");
    for (name, sweep) in [("Nmin", &r.nmin_sweep), ("dt", &r.dt_sweep)] {
        s.push_str(&format!("-- {name} sweep --\n"));
        for p in sweep {
            s.push_str(&format!(
                "{:<16} CR {:>6.2}%  samples {:>6}  O/H {:>5.2}%  top {:?}\n",
                p.param, p.critical_ratio_pct, p.samples, p.overhead_pct, p.top_function
            ));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nmin_monotonicity_and_stability() {
        let r = run(EngineKind::Native, 21).unwrap();
        // CR grows (weakly) with Nmin: a higher threshold marks more
        // slices critical.
        let crs: Vec<f64> = r.nmin_sweep.iter().map(|p| p.critical_ratio_pct).collect();
        assert!(
            crs.windows(2).all(|w| w[1] >= w[0] - 0.5),
            "CR not monotone: {crs:?}"
        );
        // The detected top function is stable across the useful band
        // (n/4 .. 3n/4); the extremes legitimately change what counts
        // as "critical".
        let tops: Vec<_> = r.nmin_sweep[1..4]
            .iter()
            .filter_map(|p| p.top_function.clone())
            .collect();
        assert!(!tops.is_empty());
        assert!(
            tops.windows(2).all(|w| w[0] == w[1]),
            "unstable tops: {tops:?}"
        );
    }

    #[test]
    fn dt_drives_sample_volume() {
        let r = run(EngineKind::Native, 21).unwrap();
        // Finer sampling → at least as many samples.
        let samples: Vec<u64> = r.dt_sweep.iter().map(|p| p.samples).collect();
        assert!(
            samples.windows(2).all(|w| w[0] >= w[1]),
            "samples not decreasing with dt: {samples:?}"
        );
        assert!(samples[0] > samples[samples.len() - 1]);
    }
}
