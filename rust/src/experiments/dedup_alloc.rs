//! §5.2 Dedup thread-allocation study: 1-20-20-20-1 default;
//! 1-16-16-28-1 is *slower* (compress contention); 1-20-20-15-1 is ~14%
//! faster. write_file and deflate_slow are the top critical paths.

use anyhow::Result;

use crate::gapp::GappConfig;
use crate::simkernel::KernelConfig;
use crate::workload::apps::{dedup, DedupConfig};

use super::runner::{profiled_run, EngineKind};

#[derive(Clone, Debug)]
pub struct AllocPoint {
    pub label: String,
    pub runtime_ns: u64,
    pub top_functions: Vec<(String, u64)>,
    pub critical_ratio_pct: f64,
}

#[derive(Clone, Debug)]
pub struct DedupResult {
    pub points: Vec<AllocPoint>,
    pub fewer_gain_pct: f64,
    pub more_gain_pct: f64,
}

fn one(engine: EngineKind, seed: u64, label: &str, a: (usize, usize, usize)) -> Result<AllocPoint> {
    let r = profiled_run(
        || dedup(seed, DedupConfig::with_alloc(a.0, a.1, a.2)),
        KernelConfig::default(),
        GappConfig::default(),
        engine,
    )?;
    Ok(AllocPoint {
        label: label.to_string(),
        runtime_ns: r.base_ns,
        top_functions: r.report.top_functions(4),
        critical_ratio_pct: 100.0 * r.report.critical_ratio(),
    })
}

pub fn run(engine: EngineKind, seed: u64) -> Result<DedupResult> {
    let base = one(engine, seed, "1-20-20-20-1 (default)", (20, 20, 20))?;
    let more = one(engine, seed, "1-16-16-28-1 (more compress)", (16, 16, 28))?;
    let fewer = one(engine, seed, "1-20-20-15-1 (fewer compress)", (20, 20, 15))?;
    let pct = |x: &AllocPoint| {
        100.0 * (base.runtime_ns as f64 - x.runtime_ns as f64) / base.runtime_ns as f64
    };
    let fewer_gain_pct = pct(&fewer);
    let more_gain_pct = pct(&more);
    Ok(DedupResult {
        points: vec![base, more, fewer],
        fewer_gain_pct,
        more_gain_pct,
    })
}

pub fn render(r: &DedupResult) -> String {
    let mut s = String::from("== §5.2 Dedup thread allocations ==\n");
    for p in &r.points {
        s.push_str(&format!(
            "{:<30} {:>9.2} ms  CR {:>5.1}%  top {:?}\n",
            p.label,
            p.runtime_ns as f64 / 1e6,
            p.critical_ratio_pct,
            p.top_functions.iter().take(2).collect::<Vec<_>>()
        ));
    }
    s.push_str(&format!(
        "fewer-compress gain {:.1}% (paper +14%) | more-compress gain {:.1}% (paper < 0)\n",
        r.fewer_gain_pct, r.more_gain_pct
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_alloc_shape() {
        let r = run(EngineKind::Native, 17).unwrap();
        // deflate_slow / write_file dominate the critical profile.
        assert!(
            r.points[0]
                .top_functions
                .iter()
                .any(|(f, _)| f.contains("deflate_slow") || f.contains("write_file")),
            "top={:?}",
            r.points[0].top_functions
        );
        // Direction of both interventions matches the paper.
        assert!(r.fewer_gain_pct > 4.0, "fewer={:.1}%", r.fewer_gain_pct);
        assert!(r.more_gain_pct < 0.0, "more={:.1}%", r.more_gain_pct);
    }
}
