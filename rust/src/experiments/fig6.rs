//! Figure 6 + §5.3 Nektar++: dgemv_ is the top critical function under
//! reference BLAS; relinking with OpenBLAS improves runtime ~27% and
//! moves the bottleneck to Vmath::Dot2.

use anyhow::Result;

use crate::gapp::GappConfig;
use crate::simkernel::KernelConfig;
use crate::workload::apps::{nektar, BlasImpl, NektarConfig};

use super::runner::{profiled_run, EngineKind};

#[derive(Clone, Debug)]
pub struct Fig6Result {
    pub reference_top: Vec<(String, u64)>,
    pub openblas_top: Vec<(String, u64)>,
    pub reference_runtime_ns: u64,
    pub openblas_runtime_ns: u64,
    pub improvement_pct: f64,
}

pub fn run(engine: EngineKind, seed: u64) -> Result<Fig6Result> {
    let gcfg = GappConfig {
        dt: 500_000, // dgemv_ slices are ~1.5 ms here; sample well inside
        ..Default::default()
    };
    let reference = profiled_run(
        || nektar(seed, NektarConfig::default()),
        KernelConfig::default(),
        gcfg.clone(),
        engine,
    )?;
    let openblas = profiled_run(
        || {
            nektar(
                seed,
                NektarConfig {
                    blas: BlasImpl::OpenBlas,
                    ..Default::default()
                },
            )
        },
        KernelConfig::default(),
        gcfg,
        engine,
    )?;
    let improvement = 100.0
        * (reference.base_ns as f64 - openblas.base_ns as f64)
        / reference.base_ns as f64;
    Ok(Fig6Result {
        reference_top: reference.report.top_functions(4),
        openblas_top: openblas.report.top_functions(4),
        reference_runtime_ns: reference.base_ns,
        openblas_runtime_ns: openblas.base_ns,
        improvement_pct: improvement,
    })
}

pub fn render(r: &Fig6Result) -> String {
    format!(
        "== Figure 6 / §5.3 Nektar++ BLAS ==\n\
         reference BLAS top: {:?}\n\
         OpenBLAS top:       {:?}\n\
         runtime {:.1} ms -> {:.1} ms ({:.1}% better; paper: 27%)\n",
        r.reference_top,
        r.openblas_top,
        r.reference_runtime_ns as f64 / 1e6,
        r.openblas_runtime_ns as f64 / 1e6,
        r.improvement_pct
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rank_of(top: &[(String, u64)], f: &str) -> Option<usize> {
        top.iter().position(|(n, _)| n == f)
    }

    #[test]
    fn fig6_bottleneck_moves_with_blas() {
        let r = run(EngineKind::Native, 7).unwrap();
        // dgemv_ leads under reference BLAS.
        assert_eq!(
            rank_of(&r.reference_top, "dgemv_"),
            Some(0),
            "reference top: {:?}",
            r.reference_top
        );
        // With OpenBLAS, Vmath::Dot2 overtakes dgemv_.
        let dot2 = rank_of(&r.openblas_top, "Vmath::Dot2").expect("Dot2 present");
        let dgemv = rank_of(&r.openblas_top, "dgemv_").unwrap_or(usize::MAX);
        assert!(dot2 < dgemv, "openblas top: {:?}", r.openblas_top);
        // Runtime gain near the paper's 27%.
        assert!(
            (15.0..40.0).contains(&r.improvement_pct),
            "improvement={:.1}%",
            r.improvement_pct
        );
    }
}
