//! Shared experiment plumbing: run an app with and without GAPP, compute
//! overhead, and pick the analysis backend.

use anyhow::Result;

use crate::gapp::{run_unprofiled, GappConfig, Report, Session};
use crate::runtime::AnalysisEngine;
use crate::simkernel::KernelConfig;
use crate::workload::App;

/// Which analysis backend experiments use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// XLA when artifacts are present, otherwise native (default).
    Auto,
    Native,
    Xla,
}

impl EngineKind {
    pub fn make(self) -> Result<AnalysisEngine> {
        Ok(match self {
            EngineKind::Auto => AnalysisEngine::auto(),
            EngineKind::Native => AnalysisEngine::native(),
            EngineKind::Xla => AnalysisEngine::xla()?,
        })
    }

    pub fn from_flag(use_xla: bool, use_native: bool) -> EngineKind {
        match (use_xla, use_native) {
            (true, _) => EngineKind::Xla,
            (_, true) => EngineKind::Native,
            _ => EngineKind::Auto,
        }
    }
}

/// A profiled run with its unprofiled baseline.
pub struct ProfiledRun {
    pub report: Report,
    /// Unprofiled runtime (ns) of an identical app instance.
    pub base_ns: u64,
    /// Runtime overhead of profiling, percent.
    pub overhead_pct: f64,
}

/// Run `mk()` twice — once bare, once under GAPP — and report both.
pub fn profiled_run(
    mk: impl Fn() -> App,
    kcfg: KernelConfig,
    gcfg: GappConfig,
    engine: EngineKind,
) -> Result<ProfiledRun> {
    let (base_ns, _) = run_unprofiled(&mk(), kcfg.clone())?;
    let app = mk();
    let report = Session::builder(engine.make()?)
        .kernel(kcfg)
        .config(gcfg)
        .app(&app)
        .run()?
        .report;
    let overhead_pct = if base_ns > 0 {
        (report.runtime_ns as f64 - base_ns as f64) / base_ns as f64 * 100.0
    } else {
        0.0
    };
    Ok(ProfiledRun {
        report,
        base_ns,
        overhead_pct,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::apps;

    #[test]
    fn profiled_run_reports_overhead() {
        let r = profiled_run(
            || apps::blackscholes(8, 3),
            KernelConfig::default(),
            GappConfig::default(),
            EngineKind::Native,
        )
        .unwrap();
        assert!(r.base_ns > 0);
        assert!(r.overhead_pct >= 0.0);
        assert!(r.overhead_pct < 30.0, "oh={}", r.overhead_pct);
    }
}
