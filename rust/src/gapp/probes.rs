//! The GAPP kernel probes (paper §3–4): sched_switch / sched_wakeup /
//! task lifecycle handlers maintaining the Table-1 map set, triggering
//! stack captures on critical timeslices, and the Δt sampling probe.
//!
//! Each handler returns its cost (ns), which the simulated kernel
//! charges to the CPU that fired the event — the paper's overhead column
//! is therefore an *output* of this cost model, not an input.
//!
//! The handlers are allocation-free on the steady-state path: per-pid
//! state lives in dense [`PidMap`] tables (no hashing), critical-slice
//! stacks are interned to `u32` ids through the bounded [`StackMap`]
//! (`bpf_get_stackid()`), and every ring-buffer record is fixed-size
//! `Copy` POD.
//!
//! Transport is sharded per CPU ([`ShardedRing`], the `PERF_EVENT_ARRAY`
//! shape): each handler pushes to the ring of the CPU its event fired
//! on, preserving per-CPU FIFO order; consumers re-establish the global
//! order from the records' capture timestamps.

use crate::ebpf::maps::{HashMap64, Scalar};
use crate::ebpf::ringbuf::ShardedRing;
use crate::ebpf::stackmap::{EvictPolicy, StackMap};
use crate::ebpf::verifier::{ProgramSpec, Verifier};
use crate::simkernel::tracepoint::cost;
use crate::simkernel::{Event, Pid, TaskState, Time, WaitKind};
use crate::util::PidMap;

use super::config::GappConfig;
use super::records::{mask_clear, mask_count, mask_set, Record, SlotMask};

/// Counters describing one profiled run.
#[derive(Clone, Debug, Default)]
pub struct ProbeStats {
    pub total_slices: u64,
    pub critical_slices: u64,
    pub samples_recorded: u64,
    pub sample_ticks_checked: u64,
    pub stack_frames_captured: u64,
    pub intervals_emitted: u64,
    pub switch_events: u64,
    pub wakeup_events: u64,
}

/// Kernel-side state: the Table-1 eBPF maps plus slot management for the
/// batched activity matrix.
pub struct KernelProbes {
    pub cfg: GappConfig,
    // ---- Table-1 maps -------------------------------------------------
    /// pid → 1 if active (TASK_RUNNING), 0 otherwise.
    pub thread_list: HashMap64,
    /// pid → accumulated CMetric (ns) — the paper's in-kernel cm_hash.
    /// Kept alongside the XLA path as the cross-check reference.
    /// Dense pid-indexed table (no hashing on the hot path).
    cm_ns: PidMap<f64>,
    /// Number of active application threads right now.
    pub thread_count: Scalar,
    /// Total application threads alive.
    pub total_count: Scalar,
    /// Peak of `total_count` — the paper's n (threads in the app),
    /// from which the default N_min = n/2 is derived.
    pub peak_total: u64,
    /// Cumulative Σ T_i / n_i over all switching intervals (ns).
    pub global_cm: f64,
    /// Timestamp of the most recent switching event.
    pub t_switch: Time,
    /// Per-CPU: global_cm value when the current app thread switched in.
    local_cm: Vec<f64>,
    /// Per-CPU: switch-in time of the current app thread's timeslice.
    slice_start: Vec<Time>,
    // ---- stack interning ------------------------------------------------
    /// Bounded stack-trace interner (BPF_MAP_TYPE_STACK_TRACE): walked
    /// stacks become `u32` ids at capture time; user space resolves ids
    /// back to frames only at report time.
    pub stacks: StackMap,
    // ---- slots ---------------------------------------------------------
    slot_of: PidMap<usize>,
    free_slots: Vec<usize>,
    active_mask: SlotMask,
    /// Threads that exited but whose final timeslice is still open.
    exiting: PidMap<()>,
    /// Task currently on each CPU (to attribute wakers, §7 extension).
    running: Vec<Pid>,
    /// pid → thread that issued its most recent wakeup.
    last_waker: PidMap<Pid>,
    /// Per-CPU: waker of the thread currently in its timeslice.
    slice_waker: Vec<Pid>,
    // ---- output ---------------------------------------------------------
    /// Per-CPU ring shards (`--shards`, default one per simulated CPU).
    pub rings: ShardedRing<Record>,
    next_ts_id: u64,
    pub stats: ProbeStats,
}

impl KernelProbes {
    /// Build and verifier-check the probe set for an `ncpu`-CPU kernel.
    pub fn new(cfg: GappConfig, ncpu: usize) -> anyhow::Result<KernelProbes> {
        cfg.validate()
            .map_err(|e| anyhow::anyhow!("invalid GAPP configuration: {e}"))?;
        let spec = ProgramSpec {
            name: "gapp",
            maps: 8, // Table-1 set + the stack-trace map
            map_bytes: (1 << 22)
                + StackMap::bytes_for(cfg.stack_map_entries, cfg.stack_depth),
            ringbuf_records: cfg.ring_capacity,
            stack_depth: cfg.stack_depth,
            stack_map_entries: cfg.stack_map_entries,
            sample_period_ns: Some(cfg.dt),
            max_insns: 4096,
        };
        Verifier::default()
            .check(&spec)
            .map_err(|e| anyhow::anyhow!("verifier rejected GAPP probes: {e}"))?;
        let evict = if cfg.stack_lru {
            EvictPolicy::Lru
        } else {
            EvictPolicy::DropNew
        };
        let nshards = cfg.shards.unwrap_or(ncpu).max(1);
        Ok(KernelProbes {
            rings: ShardedRing::new(nshards, cfg.ring_capacity),
            stacks: StackMap::with_policy("stack_traces", cfg.stack_map_entries, evict),
            cfg,
            thread_list: HashMap64::new("thread_list"),
            cm_ns: PidMap::new(),
            thread_count: Scalar::default(),
            total_count: Scalar::default(),
            peak_total: 0,
            global_cm: 0.0,
            t_switch: 0,
            local_cm: vec![0.0; ncpu],
            slice_start: vec![0; ncpu],
            running: vec![0; ncpu],
            last_waker: PidMap::new(),
            slice_waker: vec![0; ncpu],
            slot_of: PidMap::new(),
            free_slots: (0..crate::runtime::T_SLOTS).rev().collect(),
            active_mask: [0; 2],
            exiting: PidMap::new(),
            next_ts_id: 0,
            stats: ProbeStats::default(),
        })
    }

    /// Effective N_min: configured, or n/2 where n is the application's
    /// thread count (peak observed — §5.1's "n is the number of
    /// application threads").
    pub fn nmin(&self) -> f64 {
        self.cfg
            .nmin
            .unwrap_or_else(|| (self.peak_total as f64 / 2.0).max(1.0))
    }

    /// In-kernel CMetric accumulated for `pid` (the paper's cm_hash).
    pub fn cm_hash(&self, pid: Pid) -> f64 {
        self.cm_ns.get(pid).copied().unwrap_or(0.0)
    }

    /// Close the current switching interval at `now`: update global_cm
    /// and emit the interval row for the batched analysis. The row is
    /// routed to `cpu`'s ring shard (the CPU whose event closed it).
    fn advance_interval(&mut self, now: Time, cpu: usize) -> u64 {
        let dur = now.saturating_sub(self.t_switch);
        self.t_switch = now;
        let n = self.thread_count.get();
        if dur == 0 || n == 0 {
            return 0;
        }
        self.global_cm += dur as f64 / n as f64;
        debug_assert_eq!(n as u32, mask_count(&self.active_mask));
        self.rings.push(
            cpu,
            now,
            Record::Interval {
                dur,
                mask: self.active_mask,
            },
        );
        self.stats.intervals_emitted += 1;
        cost::RINGBUF_RECORD
    }

    fn mark_active(&mut self, pid: Pid) {
        if self.thread_list.get(pid as u64) == Some(0) {
            self.thread_list.insert(pid as u64, 1);
            self.thread_count.add(1);
            if let Some(slot) = self.slot_of.get(pid) {
                mask_set(&mut self.active_mask, *slot);
            }
        }
    }

    fn mark_inactive(&mut self, pid: Pid) {
        if self.thread_list.get(pid as u64) == Some(1) {
            self.thread_list.insert(pid as u64, 0);
            self.thread_count.sub_sat(1);
            if let Some(slot) = self.slot_of.get(pid) {
                mask_clear(&mut self.active_mask, *slot);
            }
        }
    }

    /// task_newtask / task_rename: register an application thread.
    /// `cpu` is where the spawning context ran (ring routing).
    pub fn on_task_new(&mut self, pid: Pid, now: Time, cpu: usize) -> u64 {
        let mut c = cost::LIFECYCLE + self.advance_interval(now, cpu);
        let slot = match self.free_slots.pop() {
            Some(s) => s,
            None => {
                // Slot pages are 128 wide; apps here stay under that.
                // Fall back to dropping matrix attribution for overflow.
                usize::MAX
            }
        };
        self.total_count.add(1);
        self.peak_total = self.peak_total.max(self.total_count.get());
        self.thread_list.insert(pid as u64, 0);
        if slot != usize::MAX {
            self.slot_of.insert(pid, slot);
            self.rings.push(cpu, now, Record::SlotAssign { pid, slot });
            c += cost::RINGBUF_RECORD;
        }
        // New tasks are runnable immediately.
        self.mark_active(pid);
        c
    }

    /// sched_process_exit: the final timeslice is still open; defer the
    /// cleanup to the context switch that follows.
    pub fn on_process_exit(&mut self, pid: Pid, _now: Time) -> u64 {
        self.exiting.insert(pid, ());
        cost::LIFECYCLE
    }

    /// sched_wakeup: a blocked thread became runnable — this changes the
    /// degree of parallelism *now*, before the thread is switched in.
    /// `cpu` is the waking CPU: whatever runs there is the waker.
    pub fn on_wakeup_from(&mut self, pid: Pid, now: Time, waker_cpu: usize) -> u64 {
        let waker = self.running.get(waker_cpu).copied().unwrap_or(0);
        if waker != 0 && waker != pid {
            self.last_waker.insert(pid, waker);
        }
        self.on_wakeup(pid, now, waker_cpu)
    }

    /// sched_wakeup handler body (waker attribution done by the caller);
    /// `cpu` is the waking CPU, whose ring shard takes the interval row.
    pub fn on_wakeup(&mut self, pid: Pid, now: Time, cpu: usize) -> u64 {
        self.stats.wakeup_events += 1;
        if self.thread_list.get(pid as u64).is_none() {
            return cost::WAKEUP; // not an application thread
        }
        let c = self.advance_interval(now, cpu);
        self.mark_active(pid);
        cost::WAKEUP + c
    }

    /// sched_switch: the core probe (paper §4.1–4.2).
    #[allow(clippy::too_many_arguments)]
    pub fn on_switch(
        &mut self,
        now: Time,
        cpu: usize,
        prev_pid: Pid,
        prev_state: TaskState,
        next_pid: Pid,
        prev_ip: u64,
        prev_stack: &[u64],
        prev_wait: WaitKind,
    ) -> u64 {
        self.stats.switch_events += 1;
        if cpu < self.running.len() {
            self.running[cpu] = next_pid;
        }
        let prev_is_app = self.thread_list.get(prev_pid as u64).is_some();
        let next_is_app = self.thread_list.get(next_pid as u64).is_some();
        if !prev_is_app && !next_is_app {
            return cost::SWITCH_FAST_PATH;
        }
        let mut c = cost::SWITCH_FAST_PATH + self.advance_interval(now, cpu);

        if prev_is_app {
            c += cost::SWITCH_APP_PATH;
            // Close the timeslice: cm_hash[prev] += global_cm - local_cm.
            let cm_delta = (self.global_cm - self.local_cm[cpu]).max(0.0);
            self.cm_ns.add(prev_pid, cm_delta);
            let wall = now.saturating_sub(self.slice_start[cpu]) as f64;
            self.stats.total_slices += 1;

            if prev_state == TaskState::Blocked {
                self.mark_inactive(prev_pid);
            }

            // threads_av: time-weighted harmonic mean of the active count
            // over the slice, derived from the counters we already have.
            let threads_av = if cm_delta > 0.0 { wall / cm_delta } else { 0.0 };
            let critical = cm_delta > 0.0 && threads_av < self.nmin();
            if critical {
                self.stats.critical_slices += 1;
                let depth = prev_stack.len().min(self.cfg.stack_depth);
                let frames = &prev_stack[prev_stack.len() - depth..];
                self.stats.stack_frames_captured += depth as u64;
                // bpf_get_stackid(): walk + hash + intern; the record
                // carries the 4-byte id, never the frames.
                let stack_id = self.stacks.intern(frames);
                let stack_top = frames.last().copied().unwrap_or(0);
                self.next_ts_id += 1;
                let woken_by = self.slice_waker.get(cpu).copied().unwrap_or(0);
                self.rings.push(
                    cpu,
                    now,
                    Record::SliceEnd {
                        ts_id: self.next_ts_id,
                        pid: prev_pid,
                        cm_ns: cm_delta,
                        threads_av,
                        ip: prev_ip,
                        stack_id,
                        stack_top,
                        wait: prev_wait,
                        woken_by,
                    },
                );
                c += cost::STACK_FRAME * depth as u64
                    + cost::STACKMAP_LOOKUP
                    + cost::RINGBUF_RECORD;
            } else {
                self.rings.push(cpu, now, Record::SliceDiscard { pid: prev_pid });
                c += cost::RINGBUF_RECORD;
            }

            // Deferred exit cleanup.
            if self.exiting.remove(prev_pid).is_some() {
                self.mark_inactive(prev_pid);
                self.thread_list.remove(prev_pid as u64);
                self.total_count.sub_sat(1);
                if let Some(slot) = self.slot_of.remove(prev_pid) {
                    self.rings.push(
                        cpu,
                        now,
                        Record::SlotFree {
                            pid: prev_pid,
                            slot,
                        },
                    );
                    self.free_slots.push(slot);
                    c += cost::RINGBUF_RECORD;
                }
            }
        }

        if next_is_app {
            // Open the next timeslice: local_cm = global_cm.
            self.local_cm[cpu] = self.global_cm;
            self.slice_start[cpu] = now;
            self.slice_waker[cpu] = self.last_waker.remove(next_pid).unwrap_or(0);
            // Safety net from the paper: a switched-in thread must be
            // active even if we missed its wakeup.
            self.mark_active(next_pid);
        }
        c
    }

    /// The Δt sampling probe (§4.3); `cpu` is the sampled CPU.
    pub fn on_sample(&mut self, pid: Pid, ip: u64, now: Time, cpu: usize) -> u64 {
        self.stats.sample_ticks_checked += 1;
        let is_app = self.thread_list.get(pid as u64).is_some();
        if is_app && (self.thread_count.get() as f64) < self.nmin() {
            self.rings.push(cpu, now, Record::Sample { pid, ip });
            self.stats.samples_recorded += 1;
            cost::SAMPLE_RECORD
        } else {
            cost::SAMPLE_FAST_PATH
        }
    }

    /// Route a kernel tracepoint event to its handler. Returns the cost.
    pub fn handle(&mut self, ev: &Event<'_>) -> u64 {
        match ev {
            Event::TaskNew { time, cpu, pid, .. } => self.on_task_new(*pid, *time, *cpu),
            Event::ProcessExit { time, pid, .. } => self.on_process_exit(*pid, *time),
            Event::SchedWakeup { time, pid, cpu } => {
                self.on_wakeup_from(*pid, *time, *cpu)
            }
            Event::SchedSwitch {
                time,
                cpu,
                prev_pid,
                prev_state,
                next_pid,
                prev_ip,
                prev_stack,
                prev_wait,
            } => self.on_switch(
                *time,
                *cpu,
                *prev_pid,
                *prev_state,
                *next_pid,
                *prev_ip,
                prev_stack,
                *prev_wait,
            ),
            Event::SampleTick { time, view } => {
                self.on_sample(view.pid, view.ip, *time, view.cpu)
            }
        }
    }

    /// Peak kernel-side memory estimate (maps + stack map + ring), bytes.
    /// Dense pid tables are charged at their backing-vector size, since
    /// that is what they actually allocate (pid-indexed, not per-entry).
    pub fn memory_bytes(&self) -> u64 {
        self.thread_list.peak_bytes()
            + self.cm_ns.approx_bytes()
            + self.slot_of.approx_bytes()
            + self.last_waker.approx_bytes()
            + self.exiting.approx_bytes()
            + self.stacks.bytes()
            + self.rings.peak_bytes()
            + (self.local_cm.len() as u64) * 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probes() -> KernelProbes {
        KernelProbes::new(GappConfig::default(), 4).unwrap()
    }

    #[test]
    fn figure1_worked_example_in_kernel_path() {
        // Reproduce the paper's Figure 1 with the actual probe handlers:
        // 4 threads; E1..E7; check Thread3's cm after its timeslice.
        let mut p = probes();
        // Register threads 1..4 at t=0 (all runnable).
        for pid in 1..=4 {
            p.on_task_new(pid, 0, 0);
        }
        // Make 2 and 3 and 4 inactive first so we can control intervals.
        // E1 (t=10): thread1 had run alone [0,10]; switch out blocked.
        // Setup: only thread 1 active in [0,10].
        p.mark_inactive(2);
        p.mark_inactive(3);
        p.mark_inactive(4);
        assert_eq!(p.thread_count.get(), 1);
        // Thread 1 switched in on cpu0 at 0.
        p.on_switch(0, 0, 0, TaskState::Runnable, 1, 0, &[], WaitKind::Futex);
        // E2 (t=10): threads 3 and 4 wake; thread 1 blocks.
        p.on_wakeup(3, 10, 0);
        p.on_wakeup(4, 10, 0);
        p.on_switch(10, 0, 1, TaskState::Blocked, 3, 0, &[], WaitKind::Futex);
        p.on_switch(10, 1, 0, TaskState::Runnable, 4, 0, &[], WaitKind::Futex);
        // interval [0,10]: n=1 → global_cm=10.
        assert!((p.global_cm - 10.0).abs() < 1e-9);
        // E3 (t=18): thread 2 wakes (n was 2 during [10,18]).
        p.on_wakeup(2, 18, 0);
        // E4 (t=27): thread 3 blocks after [18,27] with n=3.
        p.on_switch(27, 0, 3, TaskState::Blocked, 2, 0, &[], WaitKind::Futex);
        // Thread3 cm = T2/2 + T3/3 = 8/2 + 9/3 = 7.
        assert!((p.cm_hash(3) - 7.0).abs() < 1e-9, "{}", p.cm_hash(3));
    }

    #[test]
    fn critical_slice_triggers_stack_record() {
        let mut p = KernelProbes::new(
            GappConfig {
                nmin: Some(2.0),
                ..Default::default()
            },
            2,
        )
        .unwrap();
        p.on_task_new(1, 0, 0);
        p.on_switch(0, 0, 0, TaskState::Runnable, 1, 0, &[], WaitKind::Futex);
        // Thread 1 alone for 1 ms → threads_av = 1 < 2 → critical.
        p.on_switch(
            1_000_000,
            0,
            1,
            TaskState::Blocked,
            0,
            0xABC,
            &[0x400000],
            WaitKind::Futex,
        );
        assert_eq!(p.stats.critical_slices, 1);
        let mut saw_slice = false;
        while let Some(r) = p.rings.pop_global() {
            if let Record::SliceEnd {
                pid,
                cm_ns,
                ip,
                stack_id,
                stack_top,
                ..
            } = r
            {
                assert_eq!(pid, 1);
                assert!((cm_ns - 1e6).abs() < 1.0);
                assert_eq!(ip, 0xABC);
                // The record carries the id; the map resolves the frames.
                assert_eq!(p.stacks.resolve(stack_id), &[0x400000]);
                assert_eq!(stack_top, 0x400000);
                saw_slice = true;
            }
        }
        assert!(saw_slice);
    }

    #[test]
    fn identical_stacks_share_one_id() {
        let mut p = KernelProbes::new(
            GappConfig {
                nmin: Some(2.0),
                ..Default::default()
            },
            2,
        )
        .unwrap();
        p.on_task_new(1, 0, 0);
        let stack = [0x400000u64, 0x401000];
        let mut t = 0u64;
        for _ in 0..5 {
            p.on_switch(t, 0, 0, TaskState::Runnable, 1, 0, &[], WaitKind::Futex);
            t += 1_000_000;
            p.on_switch(t, 0, 1, TaskState::Blocked, 0, 0xA, &stack, WaitKind::Futex);
            p.on_wakeup(1, t, 0);
        }
        assert_eq!(p.stats.critical_slices, 5);
        // One interned stack, five hits-or-inserts totalling 5 lookups.
        assert_eq!(p.stacks.len(), 1);
        assert_eq!(p.stacks.stats.inserts, 1);
        assert_eq!(p.stacks.stats.hits, 4);
        let mut ids = std::collections::BTreeSet::new();
        while let Some(r) = p.rings.pop_global() {
            if let Record::SliceEnd { stack_id, .. } = r {
                ids.insert(stack_id);
            }
        }
        assert_eq!(ids.len(), 1);
    }

    #[test]
    fn non_critical_slice_discards() {
        let mut p = KernelProbes::new(
            GappConfig {
                nmin: Some(1.0), // nothing is ever below 1 thread
                ..Default::default()
            },
            2,
        )
        .unwrap();
        p.on_task_new(1, 0, 0);
        p.on_switch(0, 0, 0, TaskState::Runnable, 1, 0, &[], WaitKind::Futex);
        p.on_switch(1_000, 0, 1, TaskState::Blocked, 0, 0, &[], WaitKind::Futex);
        assert_eq!(p.stats.critical_slices, 0);
        let mut saw_discard = false;
        while let Some(r) = p.rings.pop_global() {
            if matches!(r, Record::SliceDiscard { pid: 1 }) {
                saw_discard = true;
            }
        }
        assert!(saw_discard);
    }

    #[test]
    fn sampler_respects_nmin_gate() {
        let mut p = KernelProbes::new(
            GappConfig {
                nmin: Some(2.0),
                ..Default::default()
            },
            2,
        )
        .unwrap();
        p.on_task_new(1, 0, 0);
        p.on_task_new(2, 0, 0);
        // Both active: count=2 ≥ nmin → fast path.
        assert_eq!(p.on_sample(1, 0x1, 100, 0), cost::SAMPLE_FAST_PATH);
        p.mark_inactive(2);
        // One active: record.
        assert_eq!(p.on_sample(1, 0x2, 200, 0), cost::SAMPLE_RECORD);
        assert_eq!(p.stats.samples_recorded, 1);
    }

    #[test]
    fn exit_frees_slot_after_final_slice() {
        let mut p = probes();
        p.on_task_new(7, 0, 0);
        let slots_before = p.free_slots.len();
        p.on_switch(0, 0, 0, TaskState::Runnable, 7, 0, &[], WaitKind::Futex);
        p.on_process_exit(7, 500);
        p.on_switch(500, 0, 7, TaskState::Blocked, 0, 0, &[], WaitKind::Futex);
        assert_eq!(p.free_slots.len(), slots_before + 1);
        assert!(p.thread_list.get(7).is_none());
        assert_eq!(p.total_count.get(), 0);
    }

    #[test]
    fn interval_mask_matches_count() {
        let mut p = probes();
        for pid in 1..=5 {
            p.on_task_new(pid, 0, 0);
        }
        p.on_wakeup(1, 100, 0); // no-op (already active), but advances time
        p.on_switch(200, 0, 0, TaskState::Runnable, 1, 0, &[], WaitKind::Futex);
        let mut rows = 0;
        while let Some(r) = p.rings.pop_global() {
            if let Record::Interval { mask, .. } = r {
                assert_eq!(mask_count(&mask), 5);
                rows += 1;
            }
        }
        assert!(rows >= 1);
    }

    #[test]
    fn records_route_to_the_firing_cpus_shard() {
        // Per-CPU sharding: switches on CPUs 0 and 1 must land on their
        // own shards, and the global drain must replay them in event
        // order (the perf-buffer merge a real consumer performs).
        let mut p = KernelProbes::new(
            GappConfig {
                nmin: Some(4.0), // everything is critical
                ..Default::default()
            },
            2,
        )
        .unwrap();
        assert_eq!(p.rings.num_shards(), 2);
        p.on_task_new(1, 0, 0);
        p.on_task_new(2, 0, 0);
        p.on_switch(0, 0, 0, TaskState::Runnable, 1, 0, &[], WaitKind::Futex);
        p.on_switch(0, 1, 0, TaskState::Runnable, 2, 0, &[], WaitKind::Futex);
        // Slices end on different CPUs at different times.
        p.on_switch(
            1_000,
            1,
            2,
            TaskState::Blocked,
            0,
            0xB,
            &[0x500000],
            WaitKind::Futex,
        );
        p.on_switch(
            2_000,
            0,
            1,
            TaskState::Blocked,
            0,
            0xA,
            &[0x400000],
            WaitKind::Futex,
        );
        // Shard 1 got CPU 1's records, shard 0 CPU 0's.
        assert!(p.rings.shard(1).stats.pushed > 0);
        assert!(p.rings.shard(0).stats.pushed > 0);
        // Global drain re-establishes timestamp order: pid 2's slice
        // (t=1000, cpu 1) comes out before pid 1's (t=2000, cpu 0).
        let mut slice_pids = Vec::new();
        while let Some(r) = p.rings.pop_global() {
            if let Record::SliceEnd { pid, .. } = r {
                slice_pids.push(pid);
            }
        }
        assert_eq!(slice_pids, vec![2, 1]);
    }
}
