//! GAPP — the paper's profiler, assembled.
//!
//! [`probes`] implements the kernel side (§3–4.3), [`userspace`] the
//! user-space probe (§4.4), [`symbolize`] the addr2line step, and
//! [`report`] the final frequency tables. [`profile`] wires a synthetic
//! application, the simulated kernel and the profiler together and
//! returns the [`report::Report`] plus the kernel for post-run queries.
//!
//! [`stream`] is the *online* half of the system: an epoch-windowed
//! analyzer that drains the ring concurrently with simulation progress,
//! aggregates incrementally per window, and profiles several
//! applications system-wide at once. The batch path here is its
//! one-window special case (proven equivalent by the streaming golden
//! tests).
//!
//! [`session`] is the library-first entry point: a builder-style
//! [`Session`] drives batch, live and system-wide runs through one
//! event-emitting loop, and [`sink`] turns the typed event stream into
//! output — human text (byte-identical to the pre-sink CLI), JSON,
//! JSONL, or any future transport. [`profile`] and
//! [`stream::run_live`] survive as thin deprecated wrappers.

pub mod config;
pub mod records;
pub mod probes;
pub mod userspace;
pub mod symbolize;
pub mod report;
pub mod classify;
pub mod stream;
pub mod sink;
pub mod session;
pub mod faults;
pub mod checkpoint;

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use crate::ebpf::StackMap;
use crate::runtime::AnalysisEngine;
use crate::simkernel::{Event, Kernel, KernelConfig, Probe, Time};
use crate::workload::{App, SymbolTable};

use userspace::MergedPath;

pub use config::{GappConfig, MergeStrategy, OverflowPolicy, ReportFormat};
pub use report::{Bottleneck, Report, SampleLine, ThreadCm};
pub use session::{Session, SessionOutput};

/// Where drained records go — the consumer-side dispatch installed in
/// [`GappCore::lanes`], one variant per analysis topology.
pub enum LaneDispatch {
    /// [`MergeStrategy::Serial`]: no lanes at all — every drain k-way
    /// merges the shards straight into [`GappCore::user`].
    None,
    /// [`MergeStrategy::Tree`] on the driver thread (`--lane-threads 1`,
    /// the default): each ring shard drains into its own lane; slice
    /// records fold shard-locally, matrix records queue for the
    /// window-close re-merge.
    Inline(userspace::ShardLanes),
    /// [`MergeStrategy::Tree`] with `--lane-threads N > 1`: drained
    /// batches hand off to scoped lane workers
    /// ([`stream::lanes::spawn_lane_workers`]); the session driver
    /// installs this inside its `thread::scope` and restores `Inline`
    /// before the scope exits (dropping the [`stream::lanes::LaneIo`]
    /// is what lets the workers join).
    Threaded(stream::lanes::LaneIo),
}

impl LaneDispatch {
    /// True for the tree strategy's driver-thread lanes (the variant
    /// the inline fold path operates on).
    pub fn is_inline(&self) -> bool {
        matches!(self, LaneDispatch::Inline(_))
    }

    /// True when lane workers own the fold state (`--lane-threads N`).
    pub fn is_threaded(&self) -> bool {
        matches!(self, LaneDispatch::Threaded(_))
    }
}

/// Kernel-side + user-side state behind one shared handle.
pub struct GappCore {
    pub kernel: probes::KernelProbes,
    pub user: userspace::UserProbe,
    /// Consumer-side dispatch for drained records — see
    /// [`LaneDispatch`] for the three topologies.
    pub lanes: LaneDispatch,
    /// Live fault-injection / degradation state consulted on the probe
    /// hot path. Inert by default; the session driver arms it per epoch
    /// from the fault plan and the `--on-overflow` policy.
    pub hazard: faults::HazardControl,
}

impl GappCore {
    /// Move buffered records from the per-CPU ring shards into the
    /// user-space consumer (the paper's concurrently-running user
    /// probe). Serial strategy: one k-way merge re-establishes the
    /// global record order from the capture timestamps, so the sharded
    /// transport feeds the analysis the exact sequence a single shared
    /// ring would have. Tree strategy: each shard drains *in shard
    /// order* into its own lane — no cross-shard comparisons at all;
    /// the order-sensitive matrix substream is re-merged later, at
    /// window close ([`userspace::ShardLanes::feed_matrix_into`]).
    ///
    /// This is the *epoch* drain: it always runs, even for a shard
    /// whose watermark consumer is stalled by a fault plan — a
    /// restarted reader catches up at the window boundary.
    pub fn drain(&mut self) {
        let GappCore {
            kernel, user, lanes, ..
        } = self;
        match lanes {
            LaneDispatch::None => {
                kernel.rings.drain_global(|rec| user.consume(rec));
            }
            LaneDispatch::Inline(lanes) => {
                for i in 0..kernel.rings.num_shards() {
                    kernel.rings.drain_shard(i, |rec| lanes.route(i, rec));
                }
            }
            LaneDispatch::Threaded(io) => {
                // SPSC hand-off: one recycled batch per shard per drain,
                // no per-record messaging. Quiet shards cost nothing
                // (an empty batch goes back to the pool unsent).
                for i in 0..kernel.rings.num_shards() {
                    let mut buf = io.take_buf();
                    kernel.rings.drain_shard_into(i, &mut buf);
                    io.feed(i, buf);
                }
            }
        }
    }

    /// The watermark-triggered drain on the probe hot path. `cpu` is
    /// the CPU whose push crossed the threshold: under the tree
    /// strategy only that CPU's shard is drained (targeted relief — the
    /// other shards' readers are independent, like real per-CPU perf
    /// buffers); the serial strategy keeps its historical behaviour of
    /// draining everything through the global merge.
    pub fn drain_watermark(&mut self, cpu: usize) {
        if matches!(self.lanes, LaneDispatch::None) {
            return self.drain();
        }
        let GappCore { kernel, lanes, .. } = self;
        let i = cpu % kernel.rings.num_shards();
        match lanes {
            LaneDispatch::Inline(lanes) => {
                kernel.rings.drain_shard(i, |rec| lanes.route(i, rec));
            }
            LaneDispatch::Threaded(io) => {
                let mut buf = io.take_buf();
                kernel.rings.drain_shard_into(i, &mut buf);
                io.feed(i, buf);
            }
            LaneDispatch::None => unreachable!(),
        }
    }

    /// Threaded lanes, window close: run the barrier — collect one
    /// [`stream::lanes::LaneWindow`] per shard from the workers, replay
    /// the buffered activity-matrix records into [`GappCore::user`] in
    /// global `(t, seq)` order on this (the driver) thread, and return
    /// the shard partials for the merge tree.
    ///
    /// Panics unless [`GappCore::lanes`] is [`LaneDispatch::Threaded`].
    pub fn close_lane_window(&mut self) -> Vec<stream::ShardPartial> {
        let GappCore { user, lanes, .. } = self;
        match lanes {
            LaneDispatch::Threaded(io) => {
                let mut windows = io.close_window();
                stream::lanes::merge_matrix_into(&mut windows, user);
                windows
                    .into_iter()
                    .map(|w| stream::ShardPartial {
                        shard: w.shard,
                        slices_in: w.slices_in,
                        paths: w.paths,
                    })
                    .collect()
            }
            _ => panic!("close_lane_window requires threaded lanes (--lane-threads N > 1)"),
        }
    }

    /// Consumer-side memory estimate (user probe + shard lanes).
    /// Threaded lanes report zero: their fold state lives in the
    /// workers and every window closes it out, so by the time a report
    /// reads this the lanes are empty either way.
    pub fn consumer_memory_bytes(&self) -> u64 {
        self.user.memory_bytes()
            + match &self.lanes {
                LaneDispatch::None | LaneDispatch::Threaded(_) => 0,
                LaneDispatch::Inline(l) => l.memory_bytes(),
            }
    }
}

/// The probe object attached to the simulated kernel.
pub struct GappProbeHandle {
    core: Rc<RefCell<GappCore>>,
    dt: Time,
}

impl Probe for GappProbeHandle {
    fn on_event(&mut self, ev: &Event<'_>) -> u64 {
        let mut core = self.core.borrow_mut();
        let cost = core.kernel.handle(ev);
        // The user-space probe drains the buffers concurrently with the
        // application (it runs on spare cores); its work is therefore
        // not charged to the traced CPUs. The watermark is per shard —
        // each CPU's buffer wakes the reader independently — and only
        // the shard this event pushed to can have grown, so one O(1)
        // length probe suffices.
        let cpu = ev.cpu();
        let shard = cpu % core.kernel.rings.num_shards();
        if core.hazard.stalled_shard == Some(shard) {
            // Fault injection: this shard's reader is wedged. No
            // watermark relief, no emergency drains — the ring fills
            // and, under the shed policy, drops. The epoch drain at
            // window close still catches up.
            return cost;
        }
        if core.kernel.rings.len_for_cpu(cpu) >= core.kernel.cfg.drain_threshold {
            core.drain_watermark(cpu);
        } else if core.hazard.degrade
            && core.kernel.rings.len_for_cpu(cpu)
                >= core.kernel.cfg.ring_capacity.saturating_sub(faults::DEGRADE_HEADROOM)
        {
            // `--on-overflow degrade`: the ring is about to overflow
            // (the watermark alone can't save it — e.g. the threshold
            // exceeds the capacity, or a burst outran the reader).
            // Emergency-drain instead of shedding; the session driver
            // accounts the drain and widens the window it happened in.
            core.drain_watermark(cpu);
            core.hazard.window_drains += 1;
            core.hazard.total_drains += 1;
        }
        cost
    }

    fn sample_period(&self) -> Option<Time> {
        Some(self.dt)
    }
}

/// A GAPP profiling session.
pub struct GappSession {
    pub core: Rc<RefCell<GappCore>>,
    cfg: GappConfig,
}

impl GappSession {
    pub fn new(cfg: GappConfig, ncpu: usize, engine: AnalysisEngine) -> Result<GappSession> {
        let kernel = probes::KernelProbes::new(cfg.clone(), ncpu)?;
        let user = userspace::UserProbe::new(engine);
        // `--lane-threads N > 1` starts Inline too: scoped workers can
        // only exist inside a `thread::scope`, so the session driver
        // swaps in `LaneDispatch::Threaded` for the duration of its
        // scope (and back out before the scope joins).
        let lanes = match cfg.merge {
            MergeStrategy::Serial => LaneDispatch::None,
            MergeStrategy::Tree => LaneDispatch::Inline(
                userspace::ShardLanes::new(kernel.rings.num_shards()),
            ),
        };
        Ok(GappSession {
            core: Rc::new(RefCell::new(GappCore {
                kernel,
                user,
                lanes,
                hazard: Default::default(),
            })),
            cfg,
        })
    }

    /// The probe to attach to a [`Kernel`].
    pub fn probe(&self) -> Box<dyn Probe> {
        Box::new(GappProbeHandle {
            core: self.core.clone(),
            dt: self.cfg.dt,
        })
    }

    /// Post-process after the run: drain, merge, rank, symbolize.
    /// `runtime_ns` is the profiled run's simulated end time.
    ///
    /// Batch profiling is the one-window special case, so the merge
    /// strategy applies here too: under `Tree` each lane's slices fold
    /// into a shard-local accumulator and the partials combine through
    /// the pairwise merge tree — rendering byte-identically to the
    /// serial global-stream merge (golden-tested).
    pub fn finish(&self, app: &App, kernel: &Kernel, runtime_ns: u64) -> Report {
        let ppt_start = Instant::now();
        let mut core = self.core.borrow_mut();
        core.drain();
        let merged = if core.lanes.is_threaded() {
            // Window-close barrier: collect the workers' shard partials
            // (the matrix substream replays into `user` inside) and
            // combine them through the depth-parallel merge tree.
            let parts = core.close_lane_window();
            core.user.flush_batch();
            let merged = stream::merge_tree_parallel(
                parts.into_iter().map(|p| p.paths).collect(),
                self.cfg.lane_threads,
            );
            core.user.rank_merged(&merged, self.cfg.top_n)
        } else if core.lanes.is_inline() {
            let c = &mut *core;
            let lanes = match &mut c.lanes {
                LaneDispatch::Inline(l) => l,
                _ => unreachable!(),
            };
            // Matrix records reach the analysis in global capture
            // order; slices were already assembled shard-locally.
            lanes.feed_matrix_into(&mut c.user);
            c.user.flush_batch();
            let mut parts = Vec::with_capacity(lanes.len());
            for lane in lanes.iter_mut() {
                let mut acc = userspace::PathAccumulator::new();
                for s in lane.asm.slices.drain(..) {
                    acc.add_slice(&s, 0);
                }
                parts.push(acc.take_paths());
            }
            let merged = stream::merge_tree(parts);
            c.user.rank_merged(&merged, self.cfg.top_n)
        } else {
            core.user.flush_batch();
            core.user.merge_and_rank(self.cfg.top_n)
        };
        let ctx = ReportCtx {
            label: app.name.clone(),
            syms: vec![(app.name.as_str(), app.symtab.as_ref())],
            multi_app: false,
            window_drops: Vec::new(),
            stacks: None,
        };
        build_report(&core, kernel, runtime_ns, &merged, ctx, ppt_start)
    }
}

/// Everything the report assembler needs besides the merged paths.
/// `syms` maps application id → (name, symbol table); the batch path
/// passes exactly one entry, the system-wide streaming path one per
/// profiled application.
pub(crate) struct ReportCtx<'a> {
    pub label: String,
    pub syms: Vec<(&'a str, &'a SymbolTable)>,
    pub multi_app: bool,
    pub window_drops: Vec<u64>,
    /// Resolve stack ids against this map instead of the kernel's. The
    /// streaming analyzer re-interns window snapshots into a stable
    /// userspace map when kernel-side LRU recycling is on (a recycled
    /// kernel id changes owner mid-run, so resolving it at report time
    /// would mis-attribute evicted paths). `None` = kernel map.
    pub stacks: Option<&'a StackMap>,
}

/// Assemble a [`Report`] from ranked merged paths. Shared by the batch
/// `finish` and the streaming analyzer so that equivalent merges render
/// byte-identical reports.
pub(crate) fn build_report(
    core: &GappCore,
    kernel: &Kernel,
    runtime_ns: u64,
    merged: &[MergedPath],
    ctx: ReportCtx<'_>,
    ppt_start: Instant,
) -> Report {
    let mut syms: Vec<symbolize::Symbolizer<'_>> = ctx
        .syms
        .iter()
        .map(|(_, st)| symbolize::Symbolizer::new(st))
        .collect();
    let stacks = ctx.stacks.unwrap_or(&core.kernel.stacks);
    let bottlenecks: Vec<Bottleneck> = merged
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let mut samples: Vec<(u64, u64)> =
                m.addr_freq.iter().map(|(a, c)| (*a, *c)).collect();
            samples.sort_by(|x, y| y.1.cmp(&x.1).then(x.0.cmp(&y.0)));
            // Symbolize against the app that owns most of the path's
            // slices (single-app profiles always resolve to app 0).
            let owner = m.owner_app(ctx.multi_app, syms.len());
            let symtab = ctx.syms[owner].1;
            let sym = &mut syms[owner];
            // Resolve the interned stack id back to frames — the only
            // point in the pipeline where ids become call paths.
            let frames = stacks.resolve(m.stack_id);
            let apps = if ctx.multi_app {
                let mut v: Vec<(String, u64)> = m
                    .app_slices
                    .iter()
                    .map(|(a, n)| {
                        let name = ctx
                            .syms
                            .get(*a as usize)
                            .map(|(nm, _)| nm.to_string())
                            .unwrap_or_else(|| format!("app{a}"));
                        (name, *n)
                    })
                    .collect();
                v.sort_by(|x, y| y.1.cmp(&x.1).then(x.0.cmp(&y.0)));
                v
            } else {
                Vec::new()
            };
            Bottleneck {
                rank: i + 1,
                total_cm_ms: m.total_cm_ns / 1e6,
                slices: m.slices,
                class: classify::classify(m),
                top_wakers: classify::top_wakers(m, 3)
                    .into_iter()
                    .map(|(pid, n)| {
                        let comm = kernel
                            .task(pid)
                            .map(|t| t.comm.clone())
                            .unwrap_or_else(|| format!("pid{pid}"));
                        (comm, n)
                    })
                    .collect(),
                apps,
                call_path: sym.render_path(frames),
                samples: samples
                    .into_iter()
                    .map(|(a, c)| SampleLine {
                        rendered: sym.render(a),
                        function: sym
                            .resolve(a)
                            .map(|l| l.function)
                            .or_else(|| symtab.sym_name(a).map(|s| s.to_string())),
                        count: c,
                    })
                    .collect(),
                stack_top_samples: m.stack_top_samples,
            }
        })
        .collect();

    // Per-thread CMetric totals (Figures 4/5). PidMap iteration is
    // already ascending by pid.
    let threads: Vec<ThreadCm> = core
        .user
        .totals
        .iter()
        .map(|(pid, t)| ThreadCm {
            pid,
            comm: kernel
                .task(pid)
                .map(|t| t.comm.clone())
                .unwrap_or_default(),
            cm_ms: t.cm_ns / 1e6,
            wall_ms: t.wall_ns / 1e6,
        })
        .collect();

    let stats = core.kernel.stats.clone();
    let sstats = core.kernel.stacks.stats;
    // The whole-run window aggregates derive from the per-window vector
    // here; the compacting streaming driver (which keeps no per-window
    // vector) overwrites them from its tier pyramid totals afterwards.
    let windows_total = ctx.window_drops.len() as u64;
    let windows_lossy = ctx.window_drops.iter().filter(|d| **d > 0).count() as u64;
    let windows_drop_total = ctx.window_drops.iter().sum();
    Report {
        app: ctx.label,
        backend: core.user.backend_name(),
        runtime_ns,
        bottlenecks,
        threads,
        total_slices: stats.total_slices,
        critical_slices: stats.critical_slices,
        samples: stats.samples_recorded,
        intervals: stats.intervals_emitted,
        ring_dropped: core.kernel.rings.stats().dropped,
        ring_shards: core.kernel.rings.shard_stats(),
        stack_ids: sstats.inserts,
        stack_drops: sstats.drops,
        stack_evictions: sstats.evictions,
        window_drops: ctx.window_drops,
        windows_total,
        windows_lossy,
        windows_drop_total,
        memory_bytes: core.kernel.memory_bytes() + core.consumer_memory_bytes(),
        ppt_seconds: ppt_start.elapsed().as_secs_f64(),
        probe_cost_ns: kernel.stats.probe_ns,
        // Lazy query index; built on first samples_of/top_functions.
        ..Default::default()
    }
}

/// Run `app` under GAPP and return the report plus the kernel.
///
/// Thin wrapper over the [`Session`] builder, kept so pre-sink callers
/// (examples, experiment harness, figures) compile unchanged. New code
/// should build a [`Session`] — it exposes the same run plus event
/// sinks, windowing and system-wide mode.
#[deprecated(note = "use gapp::Session::builder(engine).app(app).run()")]
pub fn profile(
    app: &App,
    kcfg: KernelConfig,
    gcfg: GappConfig,
    engine: AnalysisEngine,
) -> Result<(Report, Kernel)> {
    let out = Session::builder(engine)
        .kernel(kcfg)
        .config(gcfg)
        .app(app)
        .run()?;
    Ok((out.report, out.kernel))
}

/// Run `app` without any profiler (baseline for overhead measurement).
pub fn run_unprofiled(app: &App, kcfg: KernelConfig) -> Result<(u64, Kernel)> {
    let mut kernel = Kernel::new(kcfg);
    app.spawn_into(&mut kernel);
    let end = kernel.run()?;
    Ok((end, kernel))
}

#[cfg(test)]
// The deprecated `profile` wrapper is itself under test here (it must
// stay byte-equivalent to the Session it delegates to).
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::workload::apps;

    #[test]
    fn profile_blackscholes_finds_cndf() {
        let app = apps::blackscholes(16, 3);
        let (report, _) = profile(
            &app,
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
        )
        .unwrap();
        assert!(report.total_slices > 0);
        assert!(!report.bottlenecks.is_empty());
        // CNDF (or its serial main) must appear among top samples.
        let top = report.top_functions(5);
        assert!(
            top.iter().any(|(f, _)| f == "CNDF" || f == "main"),
            "top={top:?}"
        );
    }

    #[test]
    fn overhead_is_positive_but_small() {
        let app = apps::blackscholes(16, 3);
        let (base, _) = run_unprofiled(&app, KernelConfig::default()).unwrap();
        let app2 = apps::blackscholes(16, 3);
        let (report, _) = profile(
            &app2,
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
        )
        .unwrap();
        assert!(report.runtime_ns >= base);
        let oh = (report.runtime_ns - base) as f64 / base as f64;
        assert!(oh < 0.25, "overhead {oh:.3}");
    }

    #[test]
    fn user_and_kernel_cmetric_agree() {
        // The batched (user-space) CMetric totals must match the paper's
        // in-kernel scalar accumulation (within f32 batch error).
        let app = apps::canneal(8, 5);
        let gcfg = GappConfig::default();
        let session =
            GappSession::new(gcfg.clone(), 64, AnalysisEngine::native()).unwrap();
        let mut kernel = Kernel::new(KernelConfig::default());
        kernel.attach_probe(session.probe());
        app.spawn_into(&mut kernel);
        let end = kernel.run().unwrap();
        let report = session.finish(&app, &kernel, end);
        assert!(!report.threads.is_empty());
        let core = session.core.borrow();
        for t in &report.threads {
            let kernel_cm = core.kernel.cm_hash(t.pid);
            let user_cm = t.cm_ms * 1e6;
            let rel = (kernel_cm - user_cm).abs() / kernel_cm.max(1.0);
            assert!(
                rel < 0.02,
                "pid {}: kernel {kernel_cm:.0} vs user {user_cm:.0} (rel {rel:.4})",
                t.pid
            );
        }
    }
}
