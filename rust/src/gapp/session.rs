//! Library-first profiling sessions.
//!
//! [`Session`] is the single entry point behind every mode the CLI
//! exposes: batch (`gapp profile`), epoch-windowed live (`gapp live`),
//! and system-wide multi-app. One builder configures the run; one
//! driver executes it and *emits typed events* ([`ReportEvent`])
//! through any number of [`ReportSink`]s — the driver never formats a
//! string, so text, JSON, JSONL and future transports are all equal
//! consumers of the same stream:
//!
//! ```no_run
//! use gapp::gapp::{Session, sink::HumanSink};
//! use gapp::runtime::AnalysisEngine;
//! use gapp::workload::apps;
//!
//! # fn main() -> anyhow::Result<()> {
//! let app = apps::canneal(8, 5);
//! let out = Session::builder(AnalysisEngine::native())
//!     .app(&app)
//!     .window_us(5_000)
//!     .shards(4)
//!     .sink(HumanSink::new(std::io::stdout()))
//!     .run()?;
//! println!("critical ratio {:.3}", out.report.critical_ratio());
//! # Ok(())
//! # }
//! ```
//!
//! The deprecated free functions `gapp::profile` and
//! `gapp::stream::run_live` are thin wrappers over this type.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use crate::ebpf::StackMap;
use crate::runtime::AnalysisEngine;
use crate::simkernel::{Kernel, KernelConfig, RunOutcome};
use crate::workload::App;

use super::sink::{
    FinalEvent, ReportEvent, ReportSink, SessionInfo, SessionMode, ShardWindowEvent,
};
use super::stream::live::live_lines;
use super::stream::{
    merge_tree, AppRegistry, LiveConfig, RegistryProbe, ShardPartial,
    ShardedConsumer, SpaceSaving, WindowAccumulator, WindowReport, WindowSummary,
};
use super::symbolize::Symbolizer;
use super::userspace::{PathAccumulator, SliceEntry};
use super::{build_report, GappConfig, GappSession, MergeStrategy, Report, ReportCtx};

/// Everything a finished session hands back to library callers —
/// sinks receive the same data as events while the run progresses.
pub struct SessionOutput {
    pub report: Report,
    /// The simulated kernel, for post-run queries (task tables, stats).
    pub kernel: Kernel,
    /// Simulated end time of the run (ns).
    pub runtime_ns: u64,
    /// One summary per closed epoch window (empty for batch runs).
    pub windows: Vec<WindowSummary>,
    /// Cumulative space-saving top-K
    /// `(stack_id, cm_fs_upper_bound, max_overestimate_fs)`.
    pub sketch_top: Vec<(u32, u64, u64)>,
    /// `sketch_top` rendered for display.
    pub sketch_lines: Vec<String>,
}

/// A configured profiling session (see the module docs). Construct
/// with [`Session::builder`], chain the setters, then [`Session::run`].
pub struct Session<'a> {
    engine: AnalysisEngine,
    kcfg: KernelConfig,
    gcfg: GappConfig,
    lcfg: LiveConfig,
    windowed: bool,
    apps: Vec<&'a App>,
    sinks: Vec<Box<dyn ReportSink + 'a>>,
}

impl<'a> Session<'a> {
    /// Start configuring a session around an analysis engine.
    pub fn builder(engine: AnalysisEngine) -> Session<'a> {
        Session {
            engine,
            kcfg: KernelConfig::default(),
            gcfg: GappConfig::default(),
            lcfg: LiveConfig::default(),
            windowed: false,
            apps: Vec::new(),
            sinks: Vec::new(),
        }
    }

    /// Add an application. Repeat for system-wide profiling (which is
    /// windowed: also set [`Session::window_us`]).
    pub fn app(mut self, app: &'a App) -> Self {
        self.apps.push(app);
        self
    }

    pub fn kernel(mut self, kcfg: KernelConfig) -> Self {
        self.kcfg = kcfg;
        self
    }

    pub fn config(mut self, gcfg: GappConfig) -> Self {
        self.gcfg = gcfg;
        self
    }

    /// Switch to the epoch-windowed (live) driver with this window
    /// length, in simulated microseconds.
    pub fn window_us(mut self, us: u64) -> Self {
        self.lcfg.window_ns = us * 1000;
        self.windowed = true;
        self
    }

    /// Full live configuration (window length, per-window top-K,
    /// sketch capacity); switches to the windowed driver.
    pub fn live(mut self, lcfg: LiveConfig) -> Self {
        self.lcfg = lcfg;
        self.windowed = true;
        self
    }

    /// Ring-shard count override (`GappConfig::shards`).
    pub fn shards(mut self, shards: usize) -> Self {
        self.gcfg.shards = Some(shards);
        self
    }

    /// Shard-aggregation strategy (`GappConfig::merge`): `Tree`
    /// (default) folds each ring shard locally and combines partials
    /// through a pairwise merge tree; `Serial` re-serializes the shards
    /// into one globally-ordered stream. Byte-identical output either
    /// way — `Serial` exists as the oracle and for A/B benching.
    pub fn merge(mut self, strategy: MergeStrategy) -> Self {
        self.gcfg.merge = strategy;
        self
    }

    /// Emit per-shard `ShardWindow` partial events before each window
    /// closes (windowed tree sessions only; see
    /// `LiveConfig::shard_partials`).
    pub fn shard_partials(mut self, on: bool) -> Self {
        self.lcfg.shard_partials = on;
        self
    }

    /// Attach a sink. Repeatable — every sink sees every event (the
    /// builder tees internally; [`super::sink::TeeSink`] exists for
    /// composing sinks outside the builder).
    pub fn sink(mut self, sink: impl ReportSink + 'a) -> Self {
        self.sinks.push(Box::new(sink));
        self
    }

    /// Run the session: validate, simulate, analyze, emit events.
    pub fn run(self) -> Result<SessionOutput> {
        let Session {
            engine,
            kcfg,
            gcfg,
            lcfg,
            windowed,
            apps,
            mut sinks,
        } = self;
        let result = (|| {
            anyhow::ensure!(!apps.is_empty(), "session needs at least one app");
            if windowed {
                anyhow::ensure!(
                    lcfg.window_ns > 0,
                    "window length must be positive (--window-us 0 would never close a window)"
                );
                anyhow::ensure!(
                    lcfg.top_k >= 1,
                    "top_k must be >= 1 (--top 0 would report nothing)"
                );
                anyhow::ensure!(
                    lcfg.sketch_entries >= 1,
                    "sketch_entries must be >= 1 (--sketch 0 cannot track anything)"
                );
                anyhow::ensure!(
                    !(lcfg.shard_partials && gcfg.merge == MergeStrategy::Serial),
                    "shard partials require the tree merge strategy \
                     (--shard-partials needs --merge tree; the serial \
                     consumer never forms per-shard partials)"
                );
                run_windowed(engine, kcfg, gcfg, lcfg, &apps, &mut sinks)
            } else {
                anyhow::ensure!(
                    apps.len() == 1,
                    "system-wide (multi-app) profiling is windowed — set window_us(..)"
                );
                anyhow::ensure!(
                    !lcfg.shard_partials,
                    "shard partials are a windowed (live) feature — batch \
                     sessions close no windows, so shard_partials(true) \
                     would silently emit nothing; set window_us(..)"
                );
                run_batch(engine, kcfg, gcfg, apps[0], &mut sinks)
            }
        })();
        // Flush every sink exactly once, success or not: the sink
        // contract says buffered backends flush in finish() because
        // SessionEnd may never arrive (driver error, a tee'd peer's
        // on_event failing). The driver's error still wins; the first
        // finish() error is reported when the run itself succeeded.
        let mut finish_err: Option<anyhow::Error> = None;
        for s in sinks.iter_mut() {
            if let Err(e) = s.finish() {
                finish_err.get_or_insert(e);
            }
        }
        let out = result?;
        match finish_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }
}

fn emit(sinks: &mut [Box<dyn ReportSink + '_>], ev: &ReportEvent<'_>) -> Result<()> {
    for s in sinks.iter_mut() {
        s.on_event(ev)?;
    }
    Ok(())
}

/// The batch driver: one kernel run, one merge, one report — exactly
/// the pre-Session `gapp::profile` pipeline, with events around it.
fn run_batch(
    engine: AnalysisEngine,
    kcfg: KernelConfig,
    gcfg: GappConfig,
    app: &App,
    sinks: &mut [Box<dyn ReportSink + '_>],
) -> Result<SessionOutput> {
    // Construct (and thereby validate) before announcing the session.
    let session = GappSession::new(gcfg.clone(), kcfg.cpus, engine)?;
    let info = SessionInfo {
        mode: SessionMode::Batch,
        apps: vec![app.name.clone()],
        shards: gcfg.shards.unwrap_or(kcfg.cpus),
        window_ns: None,
        config: gcfg,
    };
    emit(sinks, &ReportEvent::SessionStart(&info))?;
    let mut kernel = Kernel::new(kcfg);
    kernel.attach_probe(session.probe());
    app.spawn_into(&mut kernel);
    let end = kernel.run()?;
    let report = session.finish(app, &kernel, end);
    emit(
        sinks,
        &ReportEvent::Final(FinalEvent {
            report: &report,
            windows: &[],
            sketch_top: &[],
            sketch_lines: &[],
        }),
    )?;
    emit(sinks, &ReportEvent::SessionEnd { runtime_ns: end })?;
    Ok(SessionOutput {
        report,
        kernel,
        runtime_ns: end,
        windows: Vec::new(),
        sketch_top: Vec::new(),
        sketch_lines: Vec::new(),
    })
}

/// The epoch-windowed driver (live + system-wide): simulate one window,
/// drain the ring shards, aggregate, emit `WindowClosed`, repeat; then
/// merge the window snapshots into the final report. This is the former
/// `stream::run_live` body, emitting events instead of invoking a
/// callback.
fn run_windowed(
    engine: AnalysisEngine,
    kcfg: KernelConfig,
    gcfg: GappConfig,
    lcfg: LiveConfig,
    apps: &[&App],
    sinks: &mut [Box<dyn ReportSink + '_>],
) -> Result<SessionOutput> {
    let top_n = gcfg.top_n;
    let stack_lru = gcfg.stack_lru;
    let strategy = gcfg.merge;
    let shards = gcfg.shards.unwrap_or(kcfg.cpus);
    let session = GappSession::new(gcfg.clone(), kcfg.cpus, engine)?;
    let mut kernel = Kernel::new(kcfg);
    kernel.attach_probe(session.probe());
    // System-wide attribution: a zero-cost probe tags every task with
    // its application (children inherit), so attaching it cannot
    // perturb the simulated timeline relative to a batch run.
    let registry = Rc::new(RefCell::new(AppRegistry::new()));
    kernel.attach_probe(Box::new(RegistryProbe::new(registry.clone())));
    for app in apps {
        registry.borrow_mut().begin_app(&app.name);
        app.spawn_into(&mut kernel);
        registry.borrow_mut().end_spawn();
    }
    let names: Vec<String> = registry.borrow().names().to_vec();
    let info = SessionInfo {
        mode: SessionMode::Live,
        apps: names.clone(),
        shards,
        window_ns: Some(lcfg.window_ns),
        config: gcfg,
    };
    emit(sinks, &ReportEvent::SessionStart(&info))?;
    let multi_app = apps.len() > 1;
    let mut syms: Vec<Symbolizer<'_>> = apps
        .iter()
        .map(|a| Symbolizer::new(a.symtab.as_ref()))
        .collect();

    // One cursor per ring shard: the transport is per-CPU perf buffers,
    // drained together at each epoch boundary.
    let mut consumer =
        ShardedConsumer::new(session.core.borrow().kernel.rings.num_shards());
    let mut wacc = WindowAccumulator::new();
    let mut cumulative = PathAccumulator::new();
    let mut sketch: SpaceSaving<u32> = SpaceSaving::new(lcfg.sketch_entries);
    let mut scratch: Vec<SliceEntry> = Vec::new();
    let mut summaries: Vec<WindowSummary> = Vec::new();
    let mut window_drops: Vec<u64> = Vec::new();
    // Kernel-side LRU recycles stack ids mid-run, so everything that
    // outlives a window (cumulative merge, sketch, final report) must
    // not key on raw kernel ids. Snapshots are re-interned here — at
    // window close, while id → frames is still fresh — into a stable
    // userspace map. Without LRU, kernel ids are already stable and
    // this stays `None`.
    let mut user_stacks: Option<StackMap> = if stack_lru {
        Some(StackMap::new("live_user_stacks", 1 << 20))
    } else {
        None
    };

    let mut epoch: u64 = 0;
    let runtime_ns = loop {
        epoch += 1;
        let limit = lcfg.window_ns.saturating_mul(epoch);
        let outcome = kernel.run_until(limit)?;
        let (end_ns, done) = match outcome {
            RunOutcome::Done(t) => (t, true),
            RunOutcome::Paused(t) => (t, false),
        };
        let start_ns = lcfg.window_ns.saturating_mul(epoch - 1).min(end_ns);
        let wr = {
            let mut core = session.core.borrow_mut();
            let estats = consumer.drain_epoch(&mut core);
            // Tree + shard_partials: partials held back here until the
            // window's id namespace is settled (LRU re-key below).
            let mut pending_partials: Option<Vec<ShardPartial>> = None;
            let (slices_in, mut snapshot) = match strategy {
                // Serial: fold the globally re-ordered stream through
                // one accumulator (the equivalence oracle).
                MergeStrategy::Serial => {
                    scratch.clear();
                    core.user.drain_slices_into(&mut scratch);
                    {
                        let reg = registry.borrow();
                        let app_of = reg.tagger();
                        for s in &scratch {
                            wacc.add_slice(s, app_of(s.pid));
                        }
                    }
                    (wacc.slices_in, wacc.snapshot())
                }
                // Tree: each shard's folder closes its partial; the
                // pairwise merge tree combines them — the only
                // cross-shard work of the whole window, O(log S) deep.
                MergeStrategy::Tree => {
                    let parts = {
                        let reg = registry.borrow();
                        consumer.fold_partials(&mut core, reg.tagger())
                    };
                    let slices_in: u64 = parts.iter().map(|p| p.slices_in).sum();
                    let merged = if lcfg.shard_partials {
                        // Partials outlive the merge so they can be
                        // emitted with window-stable ids below; the
                        // path clones are paid only on this opt-in
                        // transport path.
                        pending_partials = Some(parts);
                        merge_tree(
                            pending_partials
                                .as_ref()
                                .unwrap()
                                .iter()
                                .map(|p| p.paths.clone())
                                .collect(),
                        )
                    } else {
                        merge_tree(parts.into_iter().map(|p| p.paths).collect())
                    };
                    (slices_in, merged)
                }
            };
            // Under kernel-side LRU, re-key the snapshot into the
            // stable userspace map while id → frames is still fresh,
            // remembering the window's kernel→stable mapping so the
            // emitted partials speak the same id namespace.
            let mut id_remap: Option<crate::util::FxHashMap<u32, u32>> = None;
            if let Some(us) = user_stacks.as_mut() {
                let mut m = crate::util::FxHashMap::default();
                for p in &mut snapshot {
                    let old = p.stack_id;
                    let frames = core.kernel.stacks.resolve(old);
                    p.stack_id = us.intern(frames);
                    m.insert(old, p.stack_id);
                }
                id_remap = Some(m);
            }
            // Emit the per-shard partials (opt-in), after the re-key so
            // a cross-process consumer never sees a recyclable kernel
            // id: every partial path's id also appears in the merged
            // snapshot, so the remap covers them all.
            if let Some(parts) = pending_partials.take() {
                for mut p in parts {
                    if let Some(m) = id_remap.as_ref() {
                        for path in &mut p.paths {
                            if let Some(id) = m.get(&path.stack_id) {
                                path.stack_id = *id;
                            }
                        }
                    }
                    let d = &estats.per_shard[p.shard];
                    emit(
                        sinks,
                        &ReportEvent::ShardWindow(ShardWindowEvent {
                            index: epoch,
                            shard: p.shard,
                            slices: p.slices_in,
                            drained: d.drained,
                            drops: d.dropped,
                            paths: &p.paths,
                        }),
                    )?;
                }
            }
            let ranked = core.user.rank_merged(&snapshot, lcfg.top_k);
            let stacks = user_stacks.as_ref().unwrap_or(&core.kernel.stacks);
            let top = live_lines(&ranked, stacks, &names, &mut syms, multi_app);
            WindowReport {
                index: epoch,
                start_ns,
                end_ns,
                slices: slices_in,
                drained: estats.delta.drained,
                drops: estats.delta.dropped,
                shard_drops: estats.per_shard.iter().map(|d| d.dropped).collect(),
                top,
                snapshot,
            }
        };
        emit(sinks, &ReportEvent::WindowClosed(&wr))?;
        // Fold the window into the cumulative state; the snapshot dies
        // here, keeping resident memory O(top-K + live stack ids).
        for p in &wr.snapshot {
            cumulative.merge_path(p);
            sketch.add(p.stack_id, p.cm_fs);
        }
        window_drops.push(wr.drops);
        summaries.push(WindowSummary {
            index: wr.index,
            slices: wr.slices,
            drained: wr.drained,
            drops: wr.drops,
        });
        if done {
            break end_ns;
        }
    };

    // Final report from the merged window snapshots (post-processing
    // proper starts here, mirroring the batch `finish`).
    let ppt_start = Instant::now();
    let mut core = session.core.borrow_mut();
    core.user.flush_batch();
    let merged = cumulative.take_paths();
    let ranked = core.user.rank_merged(&merged, top_n);
    // Cumulative sketch tail: the sketch tracks raw stack ids; app
    // ownership comes from the cumulative merge (address spaces may
    // overlap between apps in system-wide mode, so each site must be
    // symbolized through the app that owns the path).
    let sketch_top = sketch.top(lcfg.top_k);
    let sketch_lines: Vec<String> = {
        let stacks = user_stacks.as_ref().unwrap_or(&core.kernel.stacks);
        let owner_of: crate::util::FxHashMap<u32, usize> = merged
            .iter()
            .map(|p| (p.stack_id, p.owner_app(multi_app, syms.len())))
            .collect();
        sketch_top
            .iter()
            .map(|(id, cm_fs, err_fs)| {
                let owner = owner_of.get(id).copied().unwrap_or(0);
                let site = match stacks.resolve(*id).last() {
                    Some(a) => syms[owner].render(*a),
                    None => "<no frames>".to_string(),
                };
                let app_name = names
                    .get(owner)
                    .cloned()
                    .unwrap_or_else(|| format!("app{owner}"));
                format!(
                    "{:<14} {:>9.3} ms (+{:.3} max over)  {}",
                    app_name,
                    *cm_fs as f64 / 1e12,
                    *err_fs as f64 / 1e12,
                    site,
                )
            })
            .collect()
    };
    let ctx = ReportCtx {
        label: names.join("+"),
        syms: apps
            .iter()
            .map(|a| (a.name.as_str(), a.symtab.as_ref()))
            .collect(),
        multi_app,
        window_drops,
        stacks: user_stacks.as_ref(),
    };
    let mut report = build_report(&core, &kernel, runtime_ns, &ranked, ctx, ppt_start);
    if let Some(us) = user_stacks.as_ref() {
        // The stable userspace re-intern map is part of the analyzer:
        // if it saturates on a long run, the loss must be as visible as
        // the kernel map's own drop counter.
        report.stack_drops += us.stats.drops;
    }
    drop(core);
    emit(
        sinks,
        &ReportEvent::Final(FinalEvent {
            report: &report,
            windows: &summaries,
            sketch_top: &sketch_top,
            sketch_lines: &sketch_lines,
        }),
    )?;
    emit(sinks, &ReportEvent::SessionEnd { runtime_ns })?;
    Ok(SessionOutput {
        report,
        kernel,
        runtime_ns,
        windows: summaries,
        sketch_top,
        sketch_lines,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::sink::FnSink;
    use crate::workload::apps;

    #[test]
    fn batch_session_emits_start_final_end_in_order() {
        let app = apps::blackscholes(8, 3);
        let events = Rc::new(RefCell::new(Vec::<String>::new()));
        let ev2 = events.clone();
        let out = Session::builder(AnalysisEngine::native())
            .app(&app)
            .sink(FnSink(move |ev: &ReportEvent<'_>| {
                ev2.borrow_mut().push(
                    match ev {
                        ReportEvent::SessionStart(i) => {
                            assert_eq!(i.mode, SessionMode::Batch);
                            assert_eq!(i.apps, vec!["blackscholes".to_string()]);
                            assert!(i.window_ns.is_none());
                            "start"
                        }
                        ReportEvent::ShardWindow(_) => "shard",
                        ReportEvent::WindowClosed(_) => "window",
                        ReportEvent::Final(fe) => {
                            assert!(fe.windows.is_empty());
                            assert!(!fe.report.bottlenecks.is_empty());
                            "final"
                        }
                        ReportEvent::SessionEnd { runtime_ns } => {
                            assert!(*runtime_ns > 0);
                            "end"
                        }
                    }
                    .to_string(),
                );
            }))
            .run()
            .unwrap();
        assert_eq!(
            *events.borrow(),
            vec!["start".to_string(), "final".to_string(), "end".to_string()]
        );
        assert!(out.report.total_slices > 0);
        assert!(out.windows.is_empty());
        assert_eq!(out.runtime_ns, out.report.runtime_ns);
        // Kernel comes back for post-run queries.
        assert!(out.kernel.stats.switches > 0);
    }

    #[test]
    fn windowed_session_emits_one_window_event_per_summary() {
        let app = apps::canneal(8, 5);
        let seen = Rc::new(RefCell::new(0u64));
        let s2 = seen.clone();
        let out = Session::builder(AnalysisEngine::native())
            .app(&app)
            .window_us(2_000)
            .sink(FnSink(move |ev: &ReportEvent<'_>| {
                if let ReportEvent::WindowClosed(w) = ev {
                    *s2.borrow_mut() += 1;
                    assert_eq!(w.index, *s2.borrow());
                }
            }))
            .run()
            .unwrap();
        assert!(*seen.borrow() > 1, "expected multiple windows");
        assert_eq!(out.windows.len() as u64, *seen.borrow());
        assert_eq!(out.report.window_drops.len(), out.windows.len());
        assert!(!out.sketch_lines.is_empty());
    }

    #[test]
    fn shard_partials_emit_per_shard_and_sum_to_the_window() {
        let app = apps::canneal(8, 5);
        // (window index, shard, slices) per ShardWindow; slices per
        // WindowClosed — partials must cover each window exactly.
        let log = Rc::new(RefCell::new((Vec::<(u64, usize, u64)>::new(), Vec::new())));
        let l2 = log.clone();
        Session::builder(AnalysisEngine::native())
            .app(&app)
            .window_us(2_000)
            .shards(4)
            .shard_partials(true)
            .sink(FnSink(move |ev: &ReportEvent<'_>| {
                let mut log = l2.borrow_mut();
                match ev {
                    ReportEvent::ShardWindow(sw) => {
                        log.0.push((sw.index, sw.shard, sw.slices));
                    }
                    ReportEvent::WindowClosed(w) => log.1.push((w.index, w.slices)),
                    _ => {}
                }
            }))
            .run()
            .unwrap();
        let log = log.borrow();
        assert!(!log.1.is_empty());
        for (index, slices) in &log.1 {
            let shard_events: Vec<_> =
                log.0.iter().filter(|(i, _, _)| i == index).collect();
            // One partial per shard, in shard order, before the window.
            assert_eq!(shard_events.len(), 4, "window {index}");
            for (j, (_, shard, _)) in shard_events.iter().enumerate() {
                assert_eq!(*shard, j);
            }
            let sum: u64 = shard_events.iter().map(|(_, _, s)| s).sum();
            assert_eq!(sum, *slices, "window {index}: partials must cover it");
        }
    }

    #[test]
    fn serial_and_tree_sessions_agree_on_the_report() {
        let run_with = |strategy: MergeStrategy| {
            let app = apps::canneal(8, 5);
            Session::builder(AnalysisEngine::native())
                .app(&app)
                .window_us(2_000)
                .shards(4)
                .merge(strategy)
                .run()
                .unwrap()
        };
        let serial = run_with(MergeStrategy::Serial);
        let tree = run_with(MergeStrategy::Tree);
        assert_eq!(serial.runtime_ns, tree.runtime_ns);
        assert_eq!(serial.windows.len(), tree.windows.len());
        assert_eq!(serial.sketch_top, tree.sketch_top);
        let mut a = serial.report;
        let mut b = tree.report;
        a.ppt_seconds = 0.0;
        b.ppt_seconds = 0.0;
        a.memory_bytes = 0;
        b.memory_bytes = 0;
        assert_eq!(a.to_string(), b.to_string());
    }

    #[test]
    fn sessions_reject_invalid_shapes() {
        let err = Session::builder(AnalysisEngine::native()).run().unwrap_err();
        assert!(err.to_string().contains("at least one app"));

        let a = apps::by_name("mysql", 8, 7).unwrap();
        let b = apps::by_name("dedup", 8, 7).unwrap();
        let err = Session::builder(AnalysisEngine::native())
            .app(&a)
            .app(&b)
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("windowed"), "{err}");

        // Requesting per-shard partials from the serial consumer would
        // silently emit nothing — reject it instead.
        let c = apps::by_name("mysql", 8, 7).unwrap();
        let err = Session::builder(AnalysisEngine::native())
            .app(&c)
            .window_us(2_000)
            .merge(MergeStrategy::Serial)
            .shard_partials(true)
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("merge tree"), "{err}");

        // ...and so would a batch session, which closes no windows.
        let d = apps::by_name("mysql", 8, 7).unwrap();
        let err = Session::builder(AnalysisEngine::native())
            .app(&d)
            .shard_partials(true)
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("windowed (live) feature"), "{err}");
    }
}
