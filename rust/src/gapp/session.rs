//! Library-first profiling sessions.
//!
//! [`Session`] is the single entry point behind every mode the CLI
//! exposes: batch (`gapp profile`), epoch-windowed live (`gapp live`),
//! and system-wide multi-app. One builder configures the run; one
//! driver executes it and *emits typed events* ([`ReportEvent`])
//! through any number of [`ReportSink`]s — the driver never formats a
//! string, so text, JSON, JSONL and future transports are all equal
//! consumers of the same stream:
//!
//! ```no_run
//! use gapp::gapp::{Session, sink::HumanSink};
//! use gapp::runtime::AnalysisEngine;
//! use gapp::workload::apps;
//!
//! # fn main() -> anyhow::Result<()> {
//! let app = apps::canneal(8, 5);
//! let out = Session::builder(AnalysisEngine::native())
//!     .app(&app)
//!     .window_us(5_000)
//!     .shards(4)
//!     .sink(HumanSink::new(std::io::stdout()))
//!     .run()?;
//! println!("critical ratio {:.3}", out.report.critical_ratio());
//! # Ok(())
//! # }
//! ```
//!
//! The deprecated free functions `gapp::profile` and
//! `gapp::stream::run_live` are thin wrappers over this type.

use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::Result;

use crate::ebpf::StackMap;
use crate::runtime::AnalysisEngine;
use crate::simkernel::{Kernel, KernelConfig, RunOutcome};
use crate::workload::App;

use super::checkpoint::{
    recent_snapshot_of, tier_snapshot_of, Checkpoint, Fingerprint, StackSnapshot,
};
use super::config::OverflowPolicy;
use super::faults::{FaultPlan, DEGRADE_HEADROOM};
use super::records::Record;
use super::sink::{
    FinalEvent, ReportEvent, ReportSink, SessionInfo, SessionMode, ShardWindowEvent,
    SymbolEntry, SymbolsEvent,
};
use super::stream::live::live_lines;
use super::stream::{
    lanes, merge_pair, merge_tree_parallel, AppRegistry, DecayedSpaceSaving,
    LiveConfig, RegistryProbe, ShardPartial, ShardedConsumer, SpaceSaving,
    TierPyramid, WindowAccumulator, WindowReport, WindowSummary,
};
use super::symbolize::Symbolizer;
use super::userspace::{PathAccumulator, ShardLanes, SliceEntry};
use super::{
    build_report, GappConfig, GappCore, GappSession, LaneDispatch,
    MergeStrategy, Report, ReportCtx,
};

/// Everything a finished session hands back to library callers —
/// sinks receive the same data as events while the run progresses.
pub struct SessionOutput {
    pub report: Report,
    /// The simulated kernel, for post-run queries (task tables, stats).
    pub kernel: Kernel,
    /// Simulated end time of the run (ns).
    pub runtime_ns: u64,
    /// One summary per closed epoch window (empty for batch runs).
    pub windows: Vec<WindowSummary>,
    /// Cumulative space-saving top-K
    /// `(stack_id, cm_fs_upper_bound, max_overestimate_fs)`.
    pub sketch_top: Vec<(u32, u64, u64)>,
    /// `sketch_top` rendered for display.
    pub sketch_lines: Vec<String>,
    /// Time-decayed recent top-K (same tuple shape as `sketch_top`;
    /// empty unless `--decay-half-life-us` is set).
    pub recent_top: Vec<(u32, u64, u64)>,
    /// `recent_top` rendered for display.
    pub recent_lines: Vec<String>,
}

/// A configured profiling session (see the module docs). Construct
/// with [`Session::builder`], chain the setters, then [`Session::run`].
pub struct Session<'a> {
    engine: AnalysisEngine,
    kcfg: KernelConfig,
    gcfg: GappConfig,
    lcfg: LiveConfig,
    windowed: bool,
    apps: Vec<&'a App>,
    sinks: Vec<Box<dyn ReportSink + 'a>>,
    durability: Durability,
}

/// Crash-safety knobs of one session: where (and how often) to publish
/// checkpoints, which checkpoint to resume from, and the fault plan to
/// inject. All default to "off".
#[derive(Clone, Debug, Default)]
struct Durability {
    /// `--checkpoint FILE`: publish a snapshot here (atomically) at
    /// session start and after qualifying window closes.
    checkpoint_path: Option<String>,
    /// Write every n-th window's checkpoint (default 1 = every window).
    checkpoint_every: u64,
    /// `--resume FILE`: restore this snapshot and continue the run.
    resume_path: Option<String>,
    /// `--fault-plan FILE`: deterministic fault schedule.
    plan: FaultPlan,
}

impl<'a> Session<'a> {
    /// Start configuring a session around an analysis engine.
    pub fn builder(engine: AnalysisEngine) -> Session<'a> {
        Session {
            engine,
            kcfg: KernelConfig::default(),
            gcfg: GappConfig::default(),
            lcfg: LiveConfig::default(),
            windowed: false,
            apps: Vec::new(),
            sinks: Vec::new(),
            durability: Durability {
                checkpoint_every: 1,
                ..Default::default()
            },
        }
    }

    /// Add an application. Repeat for system-wide profiling (which is
    /// windowed: also set [`Session::window_us`]).
    pub fn app(mut self, app: &'a App) -> Self {
        self.apps.push(app);
        self
    }

    pub fn kernel(mut self, kcfg: KernelConfig) -> Self {
        self.kcfg = kcfg;
        self
    }

    pub fn config(mut self, gcfg: GappConfig) -> Self {
        self.gcfg = gcfg;
        self
    }

    /// Switch to the epoch-windowed (live) driver with this window
    /// length, in simulated microseconds.
    pub fn window_us(mut self, us: u64) -> Self {
        self.lcfg.window_ns = us * 1000;
        self.windowed = true;
        self
    }

    /// Full live configuration (window length, per-window top-K,
    /// sketch capacity); switches to the windowed driver.
    pub fn live(mut self, lcfg: LiveConfig) -> Self {
        self.lcfg = lcfg;
        self.windowed = true;
        self
    }

    /// Ring-shard count override (`GappConfig::shards`).
    pub fn shards(mut self, shards: usize) -> Self {
        self.gcfg.shards = Some(shards);
        self
    }

    /// Shard-aggregation strategy (`GappConfig::merge`): `Tree`
    /// (default) folds each ring shard locally and combines partials
    /// through a pairwise merge tree; `Serial` re-serializes the shards
    /// into one globally-ordered stream. Byte-identical output either
    /// way — `Serial` exists as the oracle and for A/B benching.
    pub fn merge(mut self, strategy: MergeStrategy) -> Self {
        self.gcfg.merge = strategy;
        self
    }

    /// Lane worker threads (`GappConfig::lane_threads`): with N > 1
    /// each ring shard's fold runs on a pool of N scoped OS threads
    /// (tree strategy only — the config validator rejects dead-end
    /// combinations). Output is byte-identical at every N; the default
    /// of 1 keeps the folds inline on the driver thread.
    pub fn lane_threads(mut self, n: usize) -> Self {
        self.gcfg.lane_threads = n;
        self
    }

    /// Emit per-shard `ShardWindow` partial events before each window
    /// closes (windowed tree sessions only; see
    /// `LiveConfig::shard_partials`).
    pub fn shard_partials(mut self, on: bool) -> Self {
        self.lcfg.shard_partials = on;
        self
    }

    /// Tier-compaction base (`GappConfig::compact_base`): retain closed
    /// windows in a base-`b` tier pyramid instead of a flat list —
    /// O(b·log T) resident state over T windows, with the cumulative
    /// report byte-identical to the uncompacted run.
    pub fn compact_base(mut self, b: usize) -> Self {
        self.gcfg.compact_base = Some(b);
        self
    }

    /// Track a time-decayed recent top-K beside the cumulative sketch
    /// (`GappConfig::decay_half_life_us`): each site's decayed count
    /// halves per `us` microseconds of simulated idle time.
    pub fn decay_half_life_us(mut self, us: u64) -> Self {
        self.gcfg.decay_half_life_us = Some(us);
        self
    }

    /// Publish a crash-safe snapshot to `path` (atomically: temp file +
    /// rename) at session start and after each qualifying window close.
    /// A killed run can then continue via [`Session::restore`] and
    /// finish with a byte-identical report.
    pub fn checkpoint(mut self, path: impl Into<String>) -> Self {
        self.durability.checkpoint_path = Some(path.into());
        self
    }

    /// Write every `n`-th window's checkpoint instead of every window's
    /// (coarser durability, fewer writes). The start-of-session snapshot
    /// is always written.
    pub fn checkpoint_every(mut self, n: u64) -> Self {
        self.durability.checkpoint_every = n;
        self
    }

    /// Resume from a checkpoint written by an identically-configured
    /// session (the stored fingerprint is checked knob by knob). The
    /// completed epochs are replayed through the deterministic kernel to
    /// rebuild transport state — with the analysis folds skipped, since
    /// the checkpoint carries those — and the run continues from the
    /// first incomplete window.
    pub fn restore(mut self, path: impl Into<String>) -> Self {
        self.durability.resume_path = Some(path.into());
        self
    }

    /// Inject a deterministic [`FaultPlan`] (overflow bursts, a stalled
    /// shard lane, kill points) into the run.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.durability.plan = plan;
        self
    }

    /// Attach a sink. Repeatable — every sink sees every event (the
    /// builder tees internally; [`super::sink::TeeSink`] exists for
    /// composing sinks outside the builder).
    pub fn sink(mut self, sink: impl ReportSink + 'a) -> Self {
        self.sinks.push(Box::new(sink));
        self
    }

    /// Run the session: validate, simulate, analyze, emit events.
    pub fn run(self) -> Result<SessionOutput> {
        let Session {
            engine,
            kcfg,
            gcfg,
            lcfg,
            windowed,
            apps,
            mut sinks,
            durability,
        } = self;
        let result = (|| {
            anyhow::ensure!(!apps.is_empty(), "session needs at least one app");
            anyhow::ensure!(
                durability.checkpoint_every >= 1,
                "checkpoint_every must be >= 1 (0 would never write a checkpoint)"
            );
            if windowed {
                anyhow::ensure!(
                    lcfg.window_ns > 0,
                    "window length must be positive (--window-us 0 would never close a window)"
                );
                anyhow::ensure!(
                    lcfg.top_k >= 1,
                    "top_k must be >= 1 (--top 0 would report nothing)"
                );
                anyhow::ensure!(
                    lcfg.sketch_entries >= 1,
                    "sketch_entries must be >= 1 (--sketch 0 cannot track anything)"
                );
                anyhow::ensure!(
                    !(lcfg.shard_partials && gcfg.merge == MergeStrategy::Serial),
                    "shard partials require the tree merge strategy \
                     (--shard-partials needs --merge tree; the serial \
                     consumer never forms per-shard partials)"
                );
                run_windowed(engine, kcfg, gcfg, lcfg, &apps, &mut sinks, &durability)
            } else {
                anyhow::ensure!(
                    apps.len() == 1,
                    "system-wide (multi-app) profiling is windowed — set window_us(..)"
                );
                anyhow::ensure!(
                    !lcfg.shard_partials,
                    "shard partials are a windowed (live) feature — batch \
                     sessions close no windows, so shard_partials(true) \
                     would silently emit nothing; set window_us(..)"
                );
                run_batch(engine, kcfg, gcfg, apps[0], &mut sinks, &durability)
            }
        })();
        // Flush every sink exactly once, success or not: the sink
        // contract says buffered backends flush in finish() because
        // SessionEnd may never arrive (driver error, a tee'd peer's
        // on_event failing). The driver's error still wins; the first
        // finish() error is reported when the run itself succeeded.
        let mut finish_err: Option<anyhow::Error> = None;
        for s in sinks.iter_mut() {
            if let Err(e) = s.finish() {
                finish_err.get_or_insert(e);
            }
        }
        let out = result?;
        match finish_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }
}

fn emit(sinks: &mut [Box<dyn ReportSink + '_>], ev: &ReportEvent<'_>) -> Result<()> {
    for s in sinks.iter_mut() {
        s.on_event(ev)?;
    }
    Ok(())
}

/// The configuration surface a checkpoint must match to be resumable
/// (see [`Fingerprint`]).
fn fingerprint_of(
    mode: &str,
    gcfg: &GappConfig,
    shards: usize,
    window_ns: u64,
    apps: &[String],
) -> Fingerprint {
    Fingerprint {
        mode: mode.to_string(),
        merge: gcfg.merge.name().to_string(),
        shards,
        window_ns,
        apps: apps.to_vec(),
        stack_lru: gcfg.stack_lru,
        on_overflow: gcfg.on_overflow.name().to_string(),
        ring_capacity: gcfg.ring_capacity,
        drain_threshold: gcfg.drain_threshold as u64,
        dt: gcfg.dt,
        lane_threads: gcfg.lane_threads as u64,
        compact_base: gcfg.compact_base.map(|b| b as u64).unwrap_or(0),
        decay_half_life_us: gcfg.decay_half_life_us.unwrap_or(0),
    }
}

/// Surface the benign fingerprint notes a resume check produced (knobs
/// that may legally differ between the checkpointing and resuming
/// sessions — today only `lane_threads`, whose value never reaches the
/// aggregation output).
fn report_fingerprint_notes(path: &str, notes: &[String]) {
    for n in notes {
        eprintln!("gapp: resuming {path:?}: note: {n}");
    }
}

/// Run `body` with the session's lanes handed to scoped worker threads
/// (`--lane-threads N > 1`); with one lane thread this is just `body()`
/// — the inline dispatch the session was built with stays put, and no
/// thread is spawned. The workers live exactly as long as `body`: a
/// drop guard restores the inline dispatch (disconnecting the feed
/// channels, which is what lets the workers exit and the scope join)
/// even on an early error return or unwind.
fn with_lane_scope<T>(
    session: &GappSession,
    lane_threads: usize,
    registry: Option<Arc<RwLock<AppRegistry>>>,
    body: impl FnOnce() -> Result<T>,
) -> Result<T> {
    if lane_threads <= 1 {
        return body();
    }
    let nshards = session.core.borrow().kernel.rings.num_shards();
    std::thread::scope(|s| {
        let io = lanes::spawn_lane_workers(s, lane_threads, nshards, registry);
        session.core.borrow_mut().lanes = LaneDispatch::Threaded(io);
        struct Reset<'a>(&'a GappSession, usize);
        impl Drop for Reset<'_> {
            fn drop(&mut self) {
                if let Ok(mut core) = self.0.core.try_borrow_mut() {
                    core.lanes = LaneDispatch::Inline(ShardLanes::new(self.1));
                }
            }
        }
        let reset = Reset(session, nshards);
        let out = body();
        drop(reset);
        out
    })
}

/// The deterministic abort a fault plan's `kill_after_window` injects.
/// Raised *after* the window's checkpoint is published, so recovery can
/// resume from it.
fn kill_error(window: u64) -> anyhow::Error {
    anyhow::anyhow!("fault injection: killed after window {window} (per fault plan)")
}

/// Arm the per-epoch hazard state: the degrade policy and this epoch's
/// stalled shard (if any). Run on every epoch — including replayed ones
/// on resume, so emergency drains and drops recompute identically.
fn arm_hazard(core: &mut GappCore, plan: &FaultPlan, degrade: bool, epoch: u64) {
    core.hazard.degrade = degrade;
    core.hazard.stalled_shard = plan.stalled_shard_at(epoch);
}

/// Push this epoch's scheduled overflow bursts into the ring shards.
/// Under the degrade policy a burst is emergency-drained ahead of each
/// record that would otherwise overflow (unless the shard is stalled);
/// under shed it overflows and the drops are counted, like any other
/// traffic.
fn inject_bursts(core: &mut GappCore, plan: &FaultPlan, epoch: u64, now_ns: u64) {
    let nshards = core.kernel.rings.num_shards();
    let margin = core.kernel.cfg.ring_capacity.saturating_sub(DEGRADE_HEADROOM);
    for b in plan.bursts_at(epoch) {
        let stalled = core.hazard.stalled_shard == Some(b.cpu % nshards);
        for _ in 0..b.records {
            if core.hazard.degrade
                && !stalled
                && core.kernel.rings.len_for_cpu(b.cpu) >= margin
            {
                core.drain_watermark(b.cpu);
                core.hazard.window_drains += 1;
                core.hazard.total_drains += 1;
            }
            core.kernel.rings.push(b.cpu, now_ns, Record::Noise);
        }
    }
}

/// Snapshot the windowed driver's cross-window accumulators. With tier
/// compaction on, the pyramid replaces the flat per-window vectors and
/// the cumulative paths wholesale (they are not maintained in that
/// mode); serializing it fills each closed entry's JSON cache once, so
/// periodic checkpoints re-serialize only entries folded since the last
/// write (append-only tier serialization).
#[allow(clippy::too_many_arguments)]
fn build_checkpoint(
    epochs: u64,
    fp: &Fingerprint,
    summaries: &[WindowSummary],
    window_drops: &[u64],
    degraded_windows: u64,
    total_drains: u64,
    cumulative: &PathAccumulator,
    sketch: &SpaceSaving<u32>,
    user_stacks: Option<&StackMap>,
    tiers: Option<&mut TierPyramid>,
    recent: Option<&DecayedSpaceSaving<u32>>,
) -> Checkpoint {
    let (sketch_cap, sketch_entries) = sketch.export();
    let tiers = tiers.map(|p| tier_snapshot_of(p));
    let compacted = tiers.is_some();
    Checkpoint {
        epochs,
        fingerprint: Some(fp.clone()),
        summaries: if compacted { Vec::new() } else { summaries.to_vec() },
        window_drops: if compacted { Vec::new() } else { window_drops.to_vec() },
        degraded_windows,
        degraded_drains: total_drains,
        cumulative: if compacted {
            Vec::new()
        } else {
            cumulative.paths().to_vec()
        },
        sketch_cap,
        sketch: sketch_entries,
        stacks: user_stacks.map(StackSnapshot::of),
        tiers,
        recent: recent.map(recent_snapshot_of),
    }
}

/// What one (possibly widened) simulated window produced, before the
/// analysis-side merge: raw epoch accounting plus the un-merged shard
/// partials (tree strategy). Shared by the live loop and the resume
/// replay — replay discards the analysis payload, which is exactly what
/// "skip the folds the checkpoint covers" means.
struct WindowOutcome {
    end_ns: u64,
    done: bool,
    /// First simkernel epoch of this window (1-based).
    first_epoch: u64,
    widened: bool,
    drained: u64,
    drops: u64,
    shard_drained: Vec<u64>,
    shard_drops: Vec<u64>,
    slices_in: u64,
    /// Per-epoch shard partials (tree strategy; empty under serial).
    parts: Vec<ShardPartial>,
    /// Emergency drains while this window was open (degrade policy).
    degraded_drains: u64,
}

/// Simulate one epoch window: arm hazards, inject scheduled bursts, run
/// the kernel to the epoch boundary, drain the ring shards, fold the
/// slices (serial: into `wacc`; tree: into shard partials). Under the
/// degrade policy a window that needed emergency drains widens once,
/// absorbing the next epoch — at most one widen per window, so the
/// driver always makes progress.
#[allow(clippy::too_many_arguments)]
fn simulate_window(
    kernel: &mut Kernel,
    session: &GappSession,
    consumer: &mut ShardedConsumer,
    registry: &Arc<RwLock<AppRegistry>>,
    wacc: &mut WindowAccumulator,
    scratch: &mut Vec<SliceEntry>,
    strategy: MergeStrategy,
    degrade: bool,
    plan: &FaultPlan,
    window_ns: u64,
    epoch: &mut u64,
    nshards: usize,
) -> Result<WindowOutcome> {
    let first_epoch = *epoch + 1;
    let mut widened = false;
    let mut drained = 0u64;
    let mut drops = 0u64;
    let mut shard_drained = vec![0u64; nshards];
    let mut shard_drops = vec![0u64; nshards];
    let mut slices_in = 0u64;
    let mut parts_acc: Vec<ShardPartial> = Vec::new();
    let (end_ns, done) = loop {
        *epoch += 1;
        {
            let mut core = session.core.borrow_mut();
            arm_hazard(&mut core, plan, degrade, *epoch);
            inject_bursts(
                &mut core,
                plan,
                *epoch,
                window_ns.saturating_mul(*epoch - 1),
            );
        }
        let limit = window_ns.saturating_mul(*epoch);
        let outcome = kernel.run_until(limit)?;
        let (end_ns, done) = match outcome {
            RunOutcome::Done(t) => (t, true),
            RunOutcome::Paused(t) => (t, false),
        };
        let mut core = session.core.borrow_mut();
        let estats = consumer.drain_epoch(&mut core);
        drained += estats.delta.drained;
        drops += estats.delta.dropped;
        for (i, d) in estats.per_shard.iter().enumerate() {
            shard_drained[i] += d.drained;
            shard_drops[i] += d.dropped;
        }
        match strategy {
            // Serial: fold the globally re-ordered stream through one
            // accumulator (the equivalence oracle).
            MergeStrategy::Serial => {
                scratch.clear();
                core.user.drain_slices_into(scratch);
                let reg = registry.read().unwrap();
                let app_of = reg.tagger();
                for s in scratch.iter() {
                    wacc.add_slice(s, app_of(s.pid));
                }
            }
            // Tree: each shard's folder closes its partial per epoch;
            // the window-close merge combines them. Threaded lanes fold
            // eagerly in their workers as the drained batches arrive —
            // the partials are collected once, at the window-close
            // barrier below, not per epoch.
            MergeStrategy::Tree if !core.lanes.is_threaded() => {
                let parts = {
                    let reg = registry.read().unwrap();
                    consumer.fold_partials(&mut core, reg.tagger())
                };
                slices_in += parts.iter().map(|p| p.slices_in).sum::<u64>();
                parts_acc.extend(parts);
            }
            MergeStrategy::Tree => {}
        }
        if degrade && !widened && !done && core.hazard.window_drains > 0 {
            widened = true;
            continue;
        }
        break (end_ns, done);
    };
    let mut core = session.core.borrow_mut();
    if core.lanes.is_threaded() {
        // Window-close barrier: one partial per shard comes back from
        // the lane workers, and the buffered activity-matrix records
        // replay into the user probe in global capture order.
        let parts = core.close_lane_window();
        slices_in = parts.iter().map(|p| p.slices_in).sum();
        parts_acc = parts;
    }
    let degraded_drains = core.hazard.window_drains;
    core.hazard.window_drains = 0;
    if strategy == MergeStrategy::Serial {
        slices_in = wacc.slices_in;
    }
    Ok(WindowOutcome {
        end_ns,
        done,
        first_epoch,
        widened,
        drained,
        drops,
        shard_drained,
        shard_drops,
        slices_in,
        parts: parts_acc,
        degraded_drains,
    })
}

/// Combine the per-epoch shard partials of a widened window into one
/// partial per shard (the transport contract for `ShardWindow` events:
/// one event per shard per window, whatever the window's epoch span).
fn coalesce_partials(parts: Vec<ShardPartial>) -> Vec<ShardPartial> {
    let mut by_shard: Vec<Option<ShardPartial>> = Vec::new();
    for p in parts {
        if by_shard.len() <= p.shard {
            by_shard.resize_with(p.shard + 1, || None);
        }
        by_shard[p.shard] = Some(match by_shard[p.shard].take() {
            None => p,
            Some(prev) => ShardPartial {
                shard: p.shard,
                slices_in: prev.slices_in + p.slices_in,
                paths: merge_pair(prev.paths, p.paths),
            },
        });
    }
    by_shard.into_iter().flatten().collect()
}

/// The batch driver: one kernel run, one merge, one report — exactly
/// the pre-Session `gapp::profile` pipeline, with events around it.
fn run_batch(
    engine: AnalysisEngine,
    kcfg: KernelConfig,
    gcfg: GappConfig,
    app: &App,
    sinks: &mut [Box<dyn ReportSink + '_>],
    dur: &Durability,
) -> Result<SessionOutput> {
    // Construct (and thereby validate) before announcing the session.
    let session = GappSession::new(gcfg.clone(), kcfg.cpus, engine)?;
    let shards = gcfg.shards.unwrap_or(kcfg.cpus);
    let degrade = gcfg.on_overflow == OverflowPolicy::Degrade;
    let lane_threads = gcfg.lane_threads;
    // A batch run closes no windows, so its only checkpoint is the
    // start-of-session one (epoch 0) and resuming is a
    // fingerprint-checked rerun from zero — the degenerate case of the
    // windowed recovery invariant.
    let fp = fingerprint_of("batch", &gcfg, shards, 0, &[app.name.clone()]);
    if let Some(path) = &dur.resume_path {
        let cp = Checkpoint::load(path)?;
        let stored = cp.fingerprint.as_ref().ok_or_else(|| {
            anyhow::anyhow!("checkpoint {path:?} carries no fingerprint")
        })?;
        let notes = stored.check(&fp).map_err(anyhow::Error::msg)?;
        report_fingerprint_notes(path, &notes);
        anyhow::ensure!(
            cp.epochs == 0 && cp.summaries.is_empty(),
            "checkpoint {path:?} holds {} completed window(s), but a batch \
             session has no windows to resume between — it was written by a \
             live session",
            cp.summaries.len()
        );
    }
    let info = SessionInfo {
        mode: SessionMode::Batch,
        apps: vec![app.name.clone()],
        shards,
        window_ns: None,
        config: gcfg,
    };
    emit(sinks, &ReportEvent::SessionStart(&info))?;
    if dur.resume_path.is_none() {
        if let Some(path) = &dur.checkpoint_path {
            Checkpoint {
                fingerprint: Some(fp.clone()),
                ..Default::default()
            }
            .write_atomic(path)?;
        }
        if dur.plan.kill_after_window == Some(0) {
            return Err(kill_error(0));
        }
    }
    // Batch runs have no registry: every path belongs to the one app,
    // so threaded lane workers attribute everything to app 0.
    with_lane_scope(&session, lane_threads, None, || {
        let mut kernel = Kernel::new(kcfg);
        kernel.attach_probe(session.probe());
        app.spawn_into(&mut kernel);
        {
            // The whole batch run counts as epoch 1 for fault scheduling.
            let mut core = session.core.borrow_mut();
            arm_hazard(&mut core, &dur.plan, degrade, 1);
            inject_bursts(&mut core, &dur.plan, 1, 0);
        }
        let end = kernel.run()?;
        let mut report = session.finish(app, &kernel, end);
        report.degraded_drains = session.core.borrow().hazard.total_drains;
        emit(
            sinks,
            &ReportEvent::Final(FinalEvent {
                report: &report,
                windows: &[],
                windows_total: 0,
                sketch_top: &[],
                sketch_lines: &[],
                recent_top: &[],
                recent_lines: &[],
            }),
        )?;
        emit(sinks, &ReportEvent::SessionEnd { runtime_ns: end })?;
        Ok(SessionOutput {
            report,
            kernel,
            runtime_ns: end,
            windows: Vec::new(),
            sketch_top: Vec::new(),
            sketch_lines: Vec::new(),
            recent_top: Vec::new(),
            recent_lines: Vec::new(),
        })
    })
}

/// The epoch-windowed driver (live + system-wide): simulate one window,
/// drain the ring shards, aggregate, emit `WindowClosed`, repeat; then
/// merge the window snapshots into the final report. This is the former
/// `stream::run_live` body, emitting events instead of invoking a
/// callback.
fn run_windowed(
    engine: AnalysisEngine,
    kcfg: KernelConfig,
    gcfg: GappConfig,
    lcfg: LiveConfig,
    apps: &[&App],
    sinks: &mut [Box<dyn ReportSink + '_>],
    dur: &Durability,
) -> Result<SessionOutput> {
    let top_n = gcfg.top_n;
    let stack_lru = gcfg.stack_lru;
    let strategy = gcfg.merge;
    let degrade = gcfg.on_overflow == OverflowPolicy::Degrade;
    let lane_threads = gcfg.lane_threads;
    let compact_base = gcfg.compact_base;
    let decay_half_life_us = gcfg.decay_half_life_us;
    let shards = gcfg.shards.unwrap_or(kcfg.cpus);
    let session = GappSession::new(gcfg.clone(), kcfg.cpus, engine)?;
    let mut kernel = Kernel::new(kcfg);
    kernel.attach_probe(session.probe());
    // System-wide attribution: a zero-cost probe tags every task with
    // its application (children inherit), so attaching it cannot
    // perturb the simulated timeline relative to a batch run. The
    // registry lives behind an `Arc<RwLock>` so threaded lane workers
    // can read the (append-only) pid → app table while the driver's
    // kernel probe extends it.
    let registry = Arc::new(RwLock::new(AppRegistry::new()));
    kernel.attach_probe(Box::new(RegistryProbe::new(registry.clone())));
    for app in apps {
        registry.write().unwrap().begin_app(&app.name);
        app.spawn_into(&mut kernel);
        registry.write().unwrap().end_spawn();
    }
    let names: Vec<String> = registry.read().unwrap().names().to_vec();
    let fp = fingerprint_of("live", &gcfg, shards, lcfg.window_ns, &names);
    // Load and fingerprint-check the resume checkpoint before
    // announcing the session: a bad resume fails before events flow.
    let resume: Option<Checkpoint> = match &dur.resume_path {
        None => None,
        Some(path) => {
            let cp = Checkpoint::load(path)?;
            let stored = cp.fingerprint.as_ref().ok_or_else(|| {
                anyhow::anyhow!("checkpoint {path:?} carries no fingerprint")
            })?;
            let notes = stored.check(&fp).map_err(anyhow::Error::msg)?;
            report_fingerprint_notes(path, &notes);
            anyhow::ensure!(
                cp.sketch_cap == lcfg.sketch_entries,
                "checkpoint {path:?} holds a sketch of capacity {} but this \
                 session is configured for {} entries",
                cp.sketch_cap,
                lcfg.sketch_entries
            );
            anyhow::ensure!(
                cp.stacks.is_some() == stack_lru,
                "checkpoint {path:?} {} a userspace stack map but this \
                 session {} --lru",
                if cp.stacks.is_some() { "holds" } else { "lacks" },
                if stack_lru { "uses" } else { "does not use" },
            );
            Some(cp)
        }
    };
    let info = SessionInfo {
        mode: SessionMode::Live,
        apps: names.clone(),
        shards,
        window_ns: Some(lcfg.window_ns),
        config: gcfg,
    };
    emit(sinks, &ReportEvent::SessionStart(&info))?;
    // Everything that drains the rings — resume replay, the window
    // loop, the final report — runs inside the lane scope, so threaded
    // sessions have their workers up for the whole drive.
    with_lane_scope(&session, lane_threads, Some(registry.clone()), || {
        run_windowed_inner(
            kernel, &session, &registry, &lcfg, apps, sinks, dur, names,
            &fp, resume, top_n, stack_lru, strategy, degrade, lane_threads,
            compact_base, decay_half_life_us,
        )
    })
}

/// The windowed driver body, run inside the lane scope (lane workers
/// are live iff `--lane-threads N > 1`): resume replay, the window
/// loop, and the final report built from the merged window snapshots.
#[allow(clippy::too_many_arguments)]
fn run_windowed_inner(
    mut kernel: Kernel,
    session: &GappSession,
    registry: &Arc<RwLock<AppRegistry>>,
    lcfg: &LiveConfig,
    apps: &[&App],
    sinks: &mut [Box<dyn ReportSink + '_>],
    dur: &Durability,
    names: Vec<String>,
    fp: &Fingerprint,
    resume: Option<Checkpoint>,
    top_n: usize,
    stack_lru: bool,
    strategy: MergeStrategy,
    degrade: bool,
    lane_threads: usize,
    compact_base: Option<usize>,
    decay_half_life_us: Option<u64>,
) -> Result<SessionOutput> {
    let multi_app = apps.len() > 1;
    let mut syms: Vec<Symbolizer<'_>> = apps
        .iter()
        .map(|a| Symbolizer::new(a.symtab.as_ref()))
        .collect();

    // One cursor per ring shard: the transport is per-CPU perf buffers,
    // drained together at each epoch boundary.
    let nshards = session.core.borrow().kernel.rings.num_shards();
    let mut consumer = ShardedConsumer::new(nshards);
    let mut wacc = WindowAccumulator::new();
    let mut cumulative = PathAccumulator::new();
    let mut sketch: SpaceSaving<u32> = SpaceSaving::new(lcfg.sketch_entries);
    let mut scratch: Vec<SliceEntry> = Vec::new();
    let mut summaries: Vec<WindowSummary> = Vec::new();
    let mut window_drops: Vec<u64> = Vec::new();
    // Tier compaction (`--compact-base B`): closed windows fold into a
    // base-B pyramid instead of the flat `summaries`/`window_drops`/
    // `cumulative` state, bounding resident memory at O(B·log T) over T
    // windows. The final cumulative report is byte-identical either way
    // (golden-tested), so the flat path stays as the oracle.
    let mut tiers: Option<TierPyramid> = compact_base.map(TierPyramid::new);
    // Decayed recent top-K (`--decay-half-life-us`): rides beside the
    // cumulative sketch, decayed to each window's end time.
    let mut recent: Option<DecayedSpaceSaving<u32>> = decay_half_life_us
        .map(|us| DecayedSpaceSaving::new(lcfg.sketch_entries, us.saturating_mul(1_000)));
    // Kernel-side LRU recycles stack ids mid-run, so everything that
    // outlives a window (cumulative merge, sketch, final report) must
    // not key on raw kernel ids. Snapshots are re-interned here — at
    // window close, while id → frames is still fresh — into a stable
    // userspace map. Without LRU, kernel ids are already stable and
    // this stays `None`.
    let mut user_stacks: Option<StackMap> = if stack_lru {
        Some(StackMap::new("live_user_stacks", 1 << 20))
    } else {
        None
    };

    let mut degraded_windows: u64 = 0;
    let mut epoch: u64 = 0;
    let mut window_index: u64 = 0;

    if resume.is_none() {
        // Publish the start-of-session snapshot (epoch 0): a crash
        // during the very first window still leaves a resumable file.
        if let Some(path) = &dur.checkpoint_path {
            build_checkpoint(
                0,
                fp,
                &[],
                &[],
                0,
                0,
                &cumulative,
                &sketch,
                user_stacks.as_ref(),
                tiers.as_mut(),
                recent.as_ref(),
            )
            .write_atomic(path)?;
        }
        if dur.plan.kill_after_window == Some(0) {
            return Err(kill_error(0));
        }
    }

    // ---- resume: replay the checkpointed epochs ----
    // The simkernel is deterministic and the analysis never feeds back
    // into it, so replaying epochs 1..=N with identical hazards (fault
    // plan + degrade policy) rebuilds the exact pre-crash kernel, ring,
    // lane and drop state. The analysis-side folds the checkpoint
    // already covers are skipped: window snapshots are discarded
    // unmerged, and nothing reaches the cumulative accumulator, the
    // sketch, the stable stack map, or the sinks. The replayed window
    // summaries double as a total integrity check against the
    // checkpointed ones.
    let mut finished_in_replay: Option<u64> = None;
    if let Some(cp) = &resume {
        while epoch < cp.epochs && finished_in_replay.is_none() {
            window_index += 1;
            let wo = simulate_window(
                &mut kernel,
                &session,
                &mut consumer,
                registry,
                &mut wacc,
                &mut scratch,
                strategy,
                degrade,
                &dur.plan,
                lcfg.window_ns,
                &mut epoch,
                nshards,
            )?;
            if strategy == MergeStrategy::Serial {
                // Reset the window accumulator; the merged snapshot is
                // covered by the checkpoint's cumulative state.
                let _ = wacc.snapshot();
            }
            if wo.widened {
                degraded_windows += 1;
            }
            let summary = WindowSummary {
                index: window_index,
                slices: wo.slices_in,
                drained: wo.drained,
                drops: wo.drops,
            };
            match tiers.as_mut() {
                // Compaction: replay the fold structure paths-free (the
                // analysis payload is discarded above); the resulting
                // shape is checked against the checkpointed pyramid
                // below, then replaced by it.
                Some(py) => {
                    let _ = py.push(summary, Vec::new());
                }
                None => {
                    window_drops.push(wo.drops);
                    summaries.push(summary);
                }
            }
            if wo.done {
                anyhow::ensure!(
                    epoch >= cp.epochs,
                    "checkpoint claims {} completed epoch(s) but the workload \
                     finished after epoch {}: it does not belong to this run",
                    cp.epochs,
                    epoch
                );
                // The checkpoint covers the entire run (a crash between
                // the last window's checkpoint and the final report):
                // nothing is left to simulate.
                finished_in_replay = Some(wo.end_ns);
            }
        }
        let windows_match = match tiers.as_ref() {
            // Compaction: rebuild the checkpointed pyramid (paths and
            // all) and compare its shape against the paths-free replay.
            // On a match it replaces the replay pyramid, installing the
            // folded analysis state the replay skipped.
            Some(replayed) => {
                let snap = cp.tiers.as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "checkpoint carries no tier pyramid but this session \
                         compacts (fingerprint should have caught this)"
                    )
                })?;
                let entries = snap.parse_entries().map_err(anyhow::Error::msg)?;
                let stored = TierPyramid::restore(snap.base as usize, entries)
                    .map_err(anyhow::Error::msg)?;
                // The snapshot's stored totals double-check the entry
                // payload they were computed from.
                let totals_ok = stored.windows_total() == snap.windows_total
                    && stored.slices_total() == snap.slices_total
                    && stored.drained_total() == snap.drained_total
                    && stored.drops_total() == snap.drops_total
                    && stored.lossy_windows() == snap.lossy_windows;
                let ok = totals_ok && replayed.same_shape(&stored);
                if ok {
                    tiers = Some(stored);
                }
                ok
            }
            None => summaries == cp.summaries && window_drops == cp.window_drops,
        };
        anyhow::ensure!(
            epoch == cp.epochs
                && windows_match
                && degraded_windows == cp.degraded_windows
                && session.core.borrow().hazard.total_drains == cp.degraded_drains,
            "checkpoint integrity check failed: replaying {} epoch(s) \
             produced different window summaries than the checkpoint \
             records — it does not belong to this run",
            cp.epochs
        );
        // Install the analysis state the replay skipped. Cumulative
        // paths re-merge in stored (insertion) order, so the final
        // ranking and rendering are byte-identical to an uninterrupted
        // run; the sketch restores counters and future behaviour; the
        // stable stack map re-interns in id order and restores its
        // counters (replay must not count re-interns as fresh inserts).
        for p in &cp.cumulative {
            cumulative.merge_path(p);
        }
        sketch =
            SpaceSaving::from_parts(cp.sketch_cap, &cp.sketch).map_err(anyhow::Error::msg)?;
        if let Some(us) = decay_half_life_us {
            let snap = cp.recent.as_ref().ok_or_else(|| {
                anyhow::anyhow!(
                    "checkpoint carries no recent sketch but this session \
                     decays (fingerprint should have caught this)"
                )
            })?;
            anyhow::ensure!(
                snap.cap == lcfg.sketch_entries,
                "checkpoint holds a recent sketch of capacity {} but this \
                 session is configured for {} entries",
                snap.cap,
                lcfg.sketch_entries
            );
            recent = Some(
                DecayedSpaceSaving::from_parts(
                    snap.cap,
                    us.saturating_mul(1_000),
                    snap.now_ns,
                    &snap.counters,
                )
                .map_err(anyhow::Error::msg)?,
            );
        }
        if let Some(snap) = &cp.stacks {
            user_stacks = Some(
                snap.rebuild("live_user_stacks", 1 << 20)
                    .map_err(anyhow::Error::msg)?,
            );
        }
    }

    // Stack ids already announced over the symbol-exchange event
    // (opt-in, with the partials). Ids are session-stable — the
    // userspace map never recycles, and without LRU the kernel map
    // only ever grows — so one announcement per id suffices; a resume
    // replay may re-announce, which consumers treat as a no-op.
    let mut announced: crate::util::FxHashSet<u32> = crate::util::FxHashSet::default();
    let runtime_ns = if let Some(t) = finished_in_replay {
        t
    } else {
        loop {
            window_index += 1;
            let wo = simulate_window(
                &mut kernel,
                &session,
                &mut consumer,
                registry,
                &mut wacc,
                &mut scratch,
                strategy,
                degrade,
                &dur.plan,
                lcfg.window_ns,
                &mut epoch,
                nshards,
            )?;
            let mut wr = {
                let mut core = session.core.borrow_mut();
                // Tree + shard_partials: partials held back here until
                // the window's id namespace is settled (LRU re-key
                // below).
                let mut pending_partials: Option<Vec<ShardPartial>> = None;
                let (slices_in, mut snapshot) = match strategy {
                    // Serial: the globally re-ordered stream was folded
                    // through one accumulator (the equivalence oracle).
                    MergeStrategy::Serial => (wo.slices_in, wacc.snapshot()),
                    // Tree: each shard's folder closed its partial; the
                    // pairwise merge tree combines them — the only
                    // cross-shard work of the whole window, O(log S)
                    // deep. A widened window's per-epoch partials
                    // coalesce to one per shard first.
                    MergeStrategy::Tree => {
                        let parts = if wo.widened {
                            coalesce_partials(wo.parts)
                        } else {
                            wo.parts
                        };
                        let merged = if lcfg.shard_partials {
                            // Partials outlive the merge so they can be
                            // emitted with window-stable ids below; the
                            // path clones are paid only on this opt-in
                            // transport path.
                            pending_partials = Some(parts);
                            merge_tree_parallel(
                                pending_partials
                                    .as_ref()
                                    .unwrap()
                                    .iter()
                                    .map(|p| p.paths.clone())
                                    .collect(),
                                lane_threads,
                            )
                        } else {
                            merge_tree_parallel(
                                parts.into_iter().map(|p| p.paths).collect(),
                                lane_threads,
                            )
                        };
                        (wo.slices_in, merged)
                    }
                };
                // Under kernel-side LRU, re-key the snapshot into the
                // stable userspace map while id → frames is still
                // fresh, remembering the window's kernel→stable mapping
                // so the emitted partials speak the same id namespace.
                let mut id_remap: Option<crate::util::FxHashMap<u32, u32>> = None;
                if let Some(us) = user_stacks.as_mut() {
                    let mut m = crate::util::FxHashMap::default();
                    for p in &mut snapshot {
                        let old = p.stack_id;
                        let frames = core.kernel.stacks.resolve(old);
                        p.stack_id = us.intern(frames);
                        m.insert(old, p.stack_id);
                    }
                    id_remap = Some(m);
                }
                // Symbol exchange (opt-in, with the partials): announce
                // every id this window introduced — frames plus the
                // producer-side symbolization — *before* the partials
                // that reference it, so a cross-process consumer can
                // resolve each id on arrival. Every partial path id
                // appears in the merged snapshot (same invariant the
                // remap relies on), so walking the snapshot covers the
                // window's whole id set.
                if pending_partials.is_some() {
                    let stacks =
                        user_stacks.as_ref().unwrap_or(&core.kernel.stacks);
                    let mut entries: Vec<SymbolEntry> = Vec::new();
                    for p in &snapshot {
                        if !announced.insert(p.stack_id) {
                            continue;
                        }
                        let frames = stacks.resolve(p.stack_id).to_vec();
                        let owner = p.owner_app(multi_app, syms.len());
                        let rendered = frames
                            .iter()
                            .map(|a| syms[owner].render(*a))
                            .collect();
                        entries.push(SymbolEntry {
                            stack_id: p.stack_id,
                            frames,
                            rendered,
                        });
                    }
                    if !entries.is_empty() {
                        emit(
                            sinks,
                            &ReportEvent::Symbols(SymbolsEvent {
                                entries: &entries,
                            }),
                        )?;
                    }
                }
                // Emit the per-shard partials (opt-in), after the
                // re-key so a cross-process consumer never sees a
                // recyclable kernel id: every partial path's id also
                // appears in the merged snapshot, so the remap covers
                // them all.
                if let Some(parts) = pending_partials.take() {
                    for mut p in parts {
                        if let Some(m) = id_remap.as_ref() {
                            for path in &mut p.paths {
                                if let Some(id) = m.get(&path.stack_id) {
                                    path.stack_id = *id;
                                }
                            }
                        }
                        emit(
                            sinks,
                            &ReportEvent::ShardWindow(ShardWindowEvent {
                                index: window_index,
                                shard: p.shard,
                                slices: p.slices_in,
                                drained: wo.shard_drained[p.shard],
                                drops: wo.shard_drops[p.shard],
                                paths: &p.paths,
                            }),
                        )?;
                    }
                }
                let ranked = core.user.rank_merged(&snapshot, lcfg.top_k);
                let stacks = user_stacks.as_ref().unwrap_or(&core.kernel.stacks);
                let top = live_lines(&ranked, stacks, &names, &mut syms, multi_app);
                WindowReport {
                    index: window_index,
                    start_ns: lcfg
                        .window_ns
                        .saturating_mul(wo.first_epoch - 1)
                        .min(wo.end_ns),
                    end_ns: wo.end_ns,
                    slices: slices_in,
                    drained: wo.drained,
                    drops: wo.drops,
                    shard_drops: wo.shard_drops.clone(),
                    degraded_drains: wo.degraded_drains,
                    widened: wo.widened,
                    top,
                    snapshot,
                }
            };
            if wr.degraded_drains > 0 || wr.widened {
                emit(
                    sinks,
                    &ReportEvent::Degraded {
                        window: window_index,
                        drains: wr.degraded_drains,
                        widened: wr.widened,
                    },
                )?;
            }
            emit(sinks, &ReportEvent::WindowClosed(&wr))?;
            if wr.widened {
                degraded_windows += 1;
            }
            // Both sketches are fed per window in either mode — they
            // are additive, so compaction cannot change them. The
            // decayed sketch first decays to this window's end time.
            if let Some(d) = recent.as_mut() {
                d.advance_to(wr.end_ns);
            }
            for p in &wr.snapshot {
                sketch.add(p.stack_id, p.cm_fs);
                if let Some(d) = recent.as_mut() {
                    d.add(p.stack_id, p.cm_fs);
                }
            }
            let summary = WindowSummary {
                index: wr.index,
                slices: wr.slices,
                drained: wr.drained,
                drops: wr.drops,
            };
            match tiers.as_mut() {
                // Compaction: the snapshot moves into the tier pyramid
                // (folding cascades announce themselves), keeping
                // resident state O(B·log T) over T windows.
                Some(py) => {
                    for f in py.push(summary, std::mem::take(&mut wr.snapshot)) {
                        emit(
                            sinks,
                            &ReportEvent::TierFolded {
                                level: f.level,
                                first_window: f.first_index,
                                last_window: f.last_index,
                                windows: f.windows,
                                retained: f.retained,
                            },
                        )?;
                    }
                }
                // Flat mode: fold the window into the cumulative state;
                // the snapshot dies here, keeping resident memory
                // O(top-K + live stack ids).
                None => {
                    for p in &wr.snapshot {
                        cumulative.merge_path(p);
                    }
                    window_drops.push(wr.drops);
                    summaries.push(summary);
                }
            }
            // Publish the snapshot before honouring a kill point, so
            // the injected crash has a checkpoint to recover from.
            if let Some(path) = &dur.checkpoint_path {
                if window_index % dur.checkpoint_every == 0 {
                    let total_drains = session.core.borrow().hazard.total_drains;
                    build_checkpoint(
                        epoch,
                        fp,
                        &summaries,
                        &window_drops,
                        degraded_windows,
                        total_drains,
                        &cumulative,
                        &sketch,
                        user_stacks.as_ref(),
                        tiers.as_mut(),
                        recent.as_ref(),
                    )
                    .write_atomic(path)?;
                }
            }
            if dur.plan.kill_after_window == Some(window_index) {
                return Err(kill_error(window_index));
            }
            if wo.done {
                break wo.end_ns;
            }
        }
    };

    // Final report from the merged window snapshots (post-processing
    // proper starts here, mirroring the batch `finish`).
    let ppt_start = Instant::now();
    let mut core = session.core.borrow_mut();
    core.user.flush_batch();
    // Compacted runs re-fold the retained tier entries oldest-first —
    // byte-identical (fields and order) to the flat cumulative fold,
    // because first_seen stamps increase across windows.
    let merged = match tiers.as_ref() {
        Some(py) => py.merged_cumulative(),
        None => cumulative.take_paths(),
    };
    let ranked = core.user.rank_merged(&merged, top_n);
    // Cumulative sketch tail: the sketch tracks raw stack ids; app
    // ownership comes from the cumulative merge (address spaces may
    // overlap between apps in system-wide mode, so each site must be
    // symbolized through the app that owns the path).
    let sketch_top = sketch.top(lcfg.top_k);
    let recent_top: Vec<(u32, u64, u64)> = recent
        .as_ref()
        .map(|d| d.top(lcfg.top_k))
        .unwrap_or_default();
    let (sketch_lines, recent_lines) = {
        let stacks = user_stacks.as_ref().unwrap_or(&core.kernel.stacks);
        let owner_of: crate::util::FxHashMap<u32, usize> = merged
            .iter()
            .map(|p| (p.stack_id, p.owner_app(multi_app, syms.len())))
            .collect();
        let mut render = |top: &[(u32, u64, u64)]| -> Vec<String> {
            top.iter()
                .map(|(id, cm_fs, err_fs)| {
                    let owner = owner_of.get(id).copied().unwrap_or(0);
                    let site = match stacks.resolve(*id).last() {
                        Some(a) => syms[owner].render(*a),
                        None => "<no frames>".to_string(),
                    };
                    let app_name = names
                        .get(owner)
                        .cloned()
                        .unwrap_or_else(|| format!("app{owner}"));
                    format!(
                        "{:<14} {:>9.3} ms (+{:.3} max over)  {}",
                        app_name,
                        *cm_fs as f64 / 1e12,
                        *err_fs as f64 / 1e12,
                        site,
                    )
                })
                .collect()
        };
        (render(&sketch_top), render(&recent_top))
    };
    let ctx = ReportCtx {
        label: names.join("+"),
        syms: apps
            .iter()
            .map(|a| (a.name.as_str(), a.symtab.as_ref()))
            .collect(),
        multi_app,
        window_drops,
        stacks: user_stacks.as_ref(),
    };
    let mut report = build_report(&core, &kernel, runtime_ns, &ranked, ctx, ppt_start);
    if let Some(us) = user_stacks.as_ref() {
        // The stable userspace re-intern map is part of the analyzer:
        // if it saturates on a long run, the loss must be as visible as
        // the kernel map's own drop counter.
        report.stack_drops += us.stats.drops;
    }
    report.degraded_windows = degraded_windows;
    report.degraded_drains = core.hazard.total_drains;
    if let Some(py) = tiers.as_ref() {
        // The flat per-window vector was never kept; the pyramid's
        // exact whole-run totals replace the (empty-vector-derived)
        // aggregates, so the rendered drop line cannot move by a byte.
        report.windows_total = py.windows_total();
        report.windows_lossy = py.lossy_windows();
        report.windows_drop_total = py.drops_total();
    }
    drop(core);
    // Under compaction the final event reports the retained tier-entry
    // summaries (counts summed per entry, index = the span's last
    // window) instead of the flat per-window list.
    let summaries = match tiers.as_ref() {
        Some(py) => py.summaries(),
        None => summaries,
    };
    emit(
        sinks,
        &ReportEvent::Final(FinalEvent {
            report: &report,
            windows: &summaries,
            windows_total: report.windows_total,
            sketch_top: &sketch_top,
            sketch_lines: &sketch_lines,
            recent_top: &recent_top,
            recent_lines: &recent_lines,
        }),
    )?;
    emit(sinks, &ReportEvent::SessionEnd { runtime_ns })?;
    Ok(SessionOutput {
        report,
        kernel,
        runtime_ns,
        windows: summaries,
        sketch_top,
        sketch_lines,
        recent_top,
        recent_lines,
    })
}

#[cfg(test)]
mod tests {
    use std::cell::RefCell;
    use std::rc::Rc;

    use super::*;
    use crate::gapp::sink::FnSink;
    use crate::workload::apps;

    #[test]
    fn batch_session_emits_start_final_end_in_order() {
        let app = apps::blackscholes(8, 3);
        let events = Rc::new(RefCell::new(Vec::<String>::new()));
        let ev2 = events.clone();
        let out = Session::builder(AnalysisEngine::native())
            .app(&app)
            .sink(FnSink(move |ev: &ReportEvent<'_>| {
                ev2.borrow_mut().push(
                    match ev {
                        ReportEvent::SessionStart(i) => {
                            assert_eq!(i.mode, SessionMode::Batch);
                            assert_eq!(i.apps, vec!["blackscholes".to_string()]);
                            assert!(i.window_ns.is_none());
                            "start"
                        }
                        ReportEvent::Symbols(_) => "symbols",
                        ReportEvent::ShardWindow(_) => "shard",
                        ReportEvent::Degraded { .. } => "degraded",
                        ReportEvent::TierFolded { .. } => "tier",
                        ReportEvent::WindowClosed(_) => "window",
                        ReportEvent::Scorecard(_) => "scorecard",
                        ReportEvent::Final(fe) => {
                            assert!(fe.windows.is_empty());
                            assert!(!fe.report.bottlenecks.is_empty());
                            "final"
                        }
                        ReportEvent::SessionEnd { runtime_ns } => {
                            assert!(*runtime_ns > 0);
                            "end"
                        }
                    }
                    .to_string(),
                );
            }))
            .run()
            .unwrap();
        assert_eq!(
            *events.borrow(),
            vec!["start".to_string(), "final".to_string(), "end".to_string()]
        );
        assert!(out.report.total_slices > 0);
        assert!(out.windows.is_empty());
        assert_eq!(out.runtime_ns, out.report.runtime_ns);
        // Kernel comes back for post-run queries.
        assert!(out.kernel.stats.switches > 0);
    }

    #[test]
    fn windowed_session_emits_one_window_event_per_summary() {
        let app = apps::canneal(8, 5);
        let seen = Rc::new(RefCell::new(0u64));
        let s2 = seen.clone();
        let out = Session::builder(AnalysisEngine::native())
            .app(&app)
            .window_us(2_000)
            .sink(FnSink(move |ev: &ReportEvent<'_>| {
                if let ReportEvent::WindowClosed(w) = ev {
                    *s2.borrow_mut() += 1;
                    assert_eq!(w.index, *s2.borrow());
                }
            }))
            .run()
            .unwrap();
        assert!(*seen.borrow() > 1, "expected multiple windows");
        assert_eq!(out.windows.len() as u64, *seen.borrow());
        assert_eq!(out.report.window_drops.len(), out.windows.len());
        assert!(!out.sketch_lines.is_empty());
    }

    #[test]
    fn shard_partials_emit_per_shard_and_sum_to_the_window() {
        let app = apps::canneal(8, 5);
        // (window index, shard, slices) per ShardWindow; slices per
        // WindowClosed — partials must cover each window exactly.
        let log = Rc::new(RefCell::new((Vec::<(u64, usize, u64)>::new(), Vec::new())));
        let l2 = log.clone();
        Session::builder(AnalysisEngine::native())
            .app(&app)
            .window_us(2_000)
            .shards(4)
            .shard_partials(true)
            .sink(FnSink(move |ev: &ReportEvent<'_>| {
                let mut log = l2.borrow_mut();
                match ev {
                    ReportEvent::ShardWindow(sw) => {
                        log.0.push((sw.index, sw.shard, sw.slices));
                    }
                    ReportEvent::WindowClosed(w) => log.1.push((w.index, w.slices)),
                    _ => {}
                }
            }))
            .run()
            .unwrap();
        let log = log.borrow();
        assert!(!log.1.is_empty());
        for (index, slices) in &log.1 {
            let shard_events: Vec<_> =
                log.0.iter().filter(|(i, _, _)| i == index).collect();
            // One partial per shard, in shard order, before the window.
            assert_eq!(shard_events.len(), 4, "window {index}");
            for (j, (_, shard, _)) in shard_events.iter().enumerate() {
                assert_eq!(*shard, j);
            }
            let sum: u64 = shard_events.iter().map(|(_, _, s)| s).sum();
            assert_eq!(sum, *slices, "window {index}: partials must cover it");
        }
    }

    #[test]
    fn serial_and_tree_sessions_agree_on_the_report_at_every_thread_count() {
        let run_with = |strategy: MergeStrategy, lane_threads: usize| {
            let app = apps::canneal(8, 5);
            Session::builder(AnalysisEngine::native())
                .app(&app)
                .window_us(2_000)
                .shards(4)
                .merge(strategy)
                .lane_threads(lane_threads)
                .run()
                .unwrap()
        };
        let normalize = |out: SessionOutput| {
            let mut r = out.report;
            r.ppt_seconds = 0.0;
            r.memory_bytes = 0;
            (out.runtime_ns, out.windows, out.sketch_top, r.to_string())
        };
        let serial = normalize(run_with(MergeStrategy::Serial, 1));
        // Threaded lanes move the folds onto worker threads; the
        // report must not move by a byte for any worker count.
        for lane_threads in [1, 2, 4, 7] {
            let tree = normalize(run_with(MergeStrategy::Tree, lane_threads));
            assert_eq!(serial, tree, "lane_threads={lane_threads}");
        }
    }

    #[test]
    fn compacted_sessions_report_byte_identically_to_uncompacted() {
        // The tentpole invariant: `--compact-base B` bounds resident
        // state but must not move the final cumulative report by a
        // byte, for any base, merge strategy, or lane count.
        let run_with = |base: Option<usize>, strategy: MergeStrategy, lanes: usize| {
            let app = apps::canneal(8, 5);
            let mut b = Session::builder(AnalysisEngine::native())
                .app(&app)
                .window_us(2_000)
                .shards(4)
                .merge(strategy)
                .lane_threads(lanes);
            if let Some(base) = base {
                b = b.compact_base(base);
            }
            b.run().unwrap()
        };
        let normalize = |out: SessionOutput| {
            let mut r = out.report;
            r.ppt_seconds = 0.0;
            r.memory_bytes = 0;
            (out.runtime_ns, out.sketch_top, out.sketch_lines, r.to_string())
        };
        let flat_out = run_with(None, MergeStrategy::Tree, 1);
        let flat_windows = flat_out.windows.clone();
        let flat = normalize(flat_out);
        for base in [2usize, 3, 8] {
            let out = run_with(Some(base), MergeStrategy::Tree, 1);
            // Tier-entry summaries cover the same span with the same
            // totals, in O(base · log T) entries.
            assert!(
                out.windows.len() <= flat_windows.len(),
                "base {base}: compaction must not grow the summary list"
            );
            assert_eq!(
                out.windows.iter().map(|w| w.slices).sum::<u64>(),
                flat_windows.iter().map(|w| w.slices).sum::<u64>(),
                "base {base}"
            );
            assert_eq!(
                out.windows.last().map(|w| w.index),
                flat_windows.last().map(|w| w.index),
                "base {base}"
            );
            assert_eq!(normalize(out), flat, "base {base}");
        }
        // Serial merge and threaded lanes agree too (the full matrix
        // lives in the integration goldens).
        assert_eq!(normalize(run_with(Some(2), MergeStrategy::Serial, 1)), flat);
        assert_eq!(normalize(run_with(Some(2), MergeStrategy::Tree, 2)), flat);
    }

    #[test]
    fn decayed_recent_topk_rides_along_without_touching_the_report() {
        let run_with = |half_life: Option<u64>| {
            let app = apps::canneal(8, 5);
            let mut b = Session::builder(AnalysisEngine::native())
                .app(&app)
                .window_us(2_000)
                .shards(4);
            if let Some(us) = half_life {
                b = b.decay_half_life_us(us);
            }
            b.run().unwrap()
        };
        let plain = run_with(None);
        assert!(plain.recent_top.is_empty());
        assert!(plain.recent_lines.is_empty());
        let decayed = run_with(Some(1_000));
        assert!(!decayed.recent_top.is_empty());
        assert_eq!(decayed.recent_top.len(), decayed.recent_lines.len());
        // The recent block is purely additive: cumulative sketch and
        // report are untouched.
        assert_eq!(decayed.sketch_top, plain.sketch_top);
        let strip = |mut r: crate::gapp::Report| {
            r.ppt_seconds = 0.0;
            r.memory_bytes = 0;
            r.to_string()
        };
        assert_eq!(strip(decayed.report), strip(plain.report));
        // A fast decay can only shrink a site's count relative to the
        // undecayed cumulative upper bound.
        let cum: std::collections::HashMap<u32, u64> =
            plain.sketch_top.iter().map(|(id, cm, _)| (*id, *cm)).collect();
        for (id, cm, _) in &decayed.recent_top {
            if let Some(upper) = cum.get(id) {
                assert!(cm <= upper, "stack {id}: decayed {cm} > cumulative {upper}");
            }
        }
    }

    #[test]
    fn sessions_reject_invalid_shapes() {
        let err = Session::builder(AnalysisEngine::native()).run().unwrap_err();
        assert!(err.to_string().contains("at least one app"));

        let a = apps::by_name("mysql", 8, 7).unwrap();
        let b = apps::by_name("dedup", 8, 7).unwrap();
        let err = Session::builder(AnalysisEngine::native())
            .app(&a)
            .app(&b)
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("windowed"), "{err}");

        // Requesting per-shard partials from the serial consumer would
        // silently emit nothing — reject it instead.
        let c = apps::by_name("mysql", 8, 7).unwrap();
        let err = Session::builder(AnalysisEngine::native())
            .app(&c)
            .window_us(2_000)
            .merge(MergeStrategy::Serial)
            .shard_partials(true)
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("merge tree"), "{err}");

        // ...and so would a batch session, which closes no windows.
        let d = apps::by_name("mysql", 8, 7).unwrap();
        let err = Session::builder(AnalysisEngine::native())
            .app(&d)
            .shard_partials(true)
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("windowed (live) feature"), "{err}");

        // --checkpoint-every 0 would never write a checkpoint.
        let e = apps::by_name("mysql", 8, 7).unwrap();
        let err = Session::builder(AnalysisEngine::native())
            .app(&e)
            .checkpoint("/tmp/unused")
            .checkpoint_every(0)
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("checkpoint_every"), "{err}");
    }

    /// `--output` regression: a sink whose writer fails at flush time
    /// must surface that failure as the session error — not swallow it
    /// because the simulation itself succeeded — and must not stop the
    /// tee'd peers from seeing the full event stream first.
    #[test]
    fn failing_output_writer_is_a_session_error_after_peers_flush() {
        struct FailingWrite;
        impl std::io::Write for FailingWrite {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                Ok(buf.len()) // accept bytes; fail only at flush
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Err(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "disk full (injected)",
                ))
            }
        }
        let app = apps::blackscholes(8, 3);
        let peer_saw_end = Rc::new(RefCell::new(false));
        let p2 = peer_saw_end.clone();
        let err = Session::builder(AnalysisEngine::native())
            .app(&app)
            .sink(crate::gapp::sink::JsonSink::new(FailingWrite))
            .sink(FnSink(move |ev: &ReportEvent<'_>| {
                if matches!(ev, ReportEvent::SessionEnd { .. }) {
                    *p2.borrow_mut() = true;
                }
            }))
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("disk full"), "{err}");
        assert!(
            *peer_saw_end.borrow(),
            "tee'd peer must see the whole stream before the error surfaces"
        );
    }
}
