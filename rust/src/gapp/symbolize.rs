//! Address → source-line mapping with a cache (paper §5.4: "GAPP caches
//! address-to-symbol mapping, and hence the mapping time will be less
//! when stack traces are identical").

use std::collections::HashMap;

use crate::workload::symbols::{Location, SymbolTable};

/// Caching wrapper over the app's `addr2line`.
pub struct Symbolizer<'a> {
    symtab: &'a SymbolTable,
    cache: HashMap<u64, Option<Location>>,
    pub lookups: u64,
    pub cache_hits: u64,
}

impl<'a> Symbolizer<'a> {
    pub fn new(symtab: &'a SymbolTable) -> Symbolizer<'a> {
        Symbolizer {
            symtab,
            cache: HashMap::new(),
            lookups: 0,
            cache_hits: 0,
        }
    }

    /// Resolve an address (None for PIE / out-of-image, per §6.1).
    pub fn resolve(&mut self, addr: u64) -> Option<Location> {
        self.lookups += 1;
        if let Some(hit) = self.cache.get(&addr) {
            self.cache_hits += 1;
            return hit.clone();
        }
        let loc = self.symtab.addr2line(addr);
        self.cache.insert(addr, loc.clone());
        loc
    }

    /// Render an address as "func (file:line)" or a raw fallback.
    pub fn render(&mut self, addr: u64) -> String {
        match self.resolve(addr) {
            Some(l) => format!("{} ({}:{})", l.function, l.file, l.line),
            None => match self.symtab.sym_name(addr) {
                Some(n) => format!("{n} (+0x{:x})", addr),
                None => format!("0x{addr:x}"),
            },
        }
    }

    /// Render a call path, outermost → innermost.
    pub fn render_path(&mut self, stack: &[u64]) -> Vec<String> {
        stack.iter().map(|a| self.render(*a)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caches_repeated_lookups() {
        let mut st = SymbolTable::new();
        let f = st.add("emd", "emd.c", 55);
        let addr = st.ip(f, 32);
        let mut s = Symbolizer::new(&st);
        let a = s.resolve(addr).unwrap();
        let b = s.resolve(addr).unwrap();
        assert_eq!(a, b);
        assert_eq!(s.lookups, 2);
        assert_eq!(s.cache_hits, 1);
    }

    #[test]
    fn renders_paths() {
        let mut st = SymbolTable::new();
        let main = st.add("main", "a.c", 1);
        let inner = st.add("worker", "a.c", 50);
        let mut s = Symbolizer::new(&st);
        let path = s.render_path(&[st.addr_of(main), st.addr_of(inner)]);
        assert_eq!(path.len(), 2);
        assert!(path[0].starts_with("main"));
        assert!(path[1].starts_with("worker"));
    }

    #[test]
    fn unknown_address_rendered_raw() {
        let st = SymbolTable::new();
        let mut s = Symbolizer::new(&st);
        assert_eq!(s.render(0x123), "0x123");
    }
}
