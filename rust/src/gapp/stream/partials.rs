//! Fleet aggregation, reader half: merge `shard_window` partial events
//! from JSONL streams produced by other gapp processes (the
//! `--shard-partials` transport), tolerating the failure shapes a real
//! fleet produces — torn writes, bit rot, truncated tails.
//!
//! The contract mirrors the sink schema policy from the other side of
//! the wire:
//!
//! * a line that parses and carries `schema: 1` but a *different* event
//!   kind is **skipped silently** — additive event kinds must not scare
//!   older readers;
//! * a line that does not parse, carries a foreign schema version, or
//!   is missing/mistyping a required field is **quarantined**: counted
//!   per producer (with the first error retained verbatim), never a
//!   panic, never a silent skip.
//!
//! Partials merge exactly like the in-process tree
//! ([`crate::gapp::stream::merge_tree`]): sums combine, first-seen
//! stamps take the minimum, and the canonical order falls out of the
//! stamps. The CLI front-end is `gapp aggregate FILE [FILE...]` (one
//! producer per file).

use crate::gapp::sink::json::SCHEMA_VERSION;
use crate::gapp::sink::SymbolEntry;
use crate::util::json::Json;
use crate::util::FxHashMap;

/// One merged call path across every ingested partial — the four
/// associative fields the `shard_window` wire format carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartialPath {
    pub stack_id: u32,
    /// Total CMetric, femtoseconds.
    pub cm_fs: u64,
    pub slices: u64,
    /// Earliest capture stamp (min across producers).
    pub first_seen: u64,
}

// ---- wire parsing (shared by the offline aggregator and the live
// ---- fleet service) ----------------------------------------------------

/// A validated v1 envelope: the event kind plus the parsed line.
/// Everything past the envelope is event-specific.
pub struct Envelope {
    pub event: String,
    pub value: Json,
}

/// Parse and validate one JSONL line's envelope: well-formed JSON,
/// `schema: 1`, a string `event`. The error string is the quarantine
/// reason, retained verbatim in [`ProducerStats::first_error`].
pub fn parse_envelope(line: &str) -> Result<Envelope, String> {
    let v = Json::parse(line)?;
    let schema = v
        .get("schema")
        .ok_or("line carries no \"schema\" field")?
        .as_u64()
        .ok_or("\"schema\" is not a u64")?;
    if schema != SCHEMA_VERSION {
        return Err(format!(
            "schema version {schema} (this reader understands {SCHEMA_VERSION})"
        ));
    }
    let event = v
        .get("event")
        .ok_or("line carries no \"event\" field")?
        .as_str()
        .ok_or("\"event\" is not a string")?
        .to_string();
    Ok(Envelope { event, value: v })
}

/// One `shard_window` line as it crosses the wire: the window/shard
/// coordinates, the shard accounting, and the partial paths. The whole
/// line validates before any of it is used (a line corrupt in its third
/// path must not half-apply).
pub struct WireWindow {
    pub index: u64,
    pub shard: u64,
    pub slices: u64,
    pub drained: u64,
    pub drops: u64,
    pub paths: Vec<PartialPath>,
}

fn field_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key)
        .ok_or_else(|| format!("shard_window missing {key:?}"))?
        .as_u64()
        .ok_or_else(|| format!("field {key:?} is not a u64"))
}

/// Parse the body of a `shard_window` line (the envelope's `value`).
pub fn parse_shard_window(v: &Json) -> Result<WireWindow, String> {
    let body = v
        .get("shard_window")
        .ok_or("shard_window line carries no \"shard_window\" body")?;
    let mut parsed: Vec<PartialPath> = Vec::new();
    for p in body
        .get("paths")
        .and_then(|p| p.as_arr())
        .ok_or("\"paths\" is missing or not an array")?
    {
        let field = |key: &str| -> Result<u64, String> {
            p.get(key)
                .ok_or_else(|| format!("path entry missing {key:?}"))?
                .as_u64()
                .ok_or_else(|| format!("path field {key:?} is not a u64"))
        };
        parsed.push(PartialPath {
            stack_id: field("stack_id")? as u32,
            cm_fs: field("cm_fs")?,
            slices: field("slices")?,
            first_seen: field("first_seen")?,
        });
    }
    Ok(WireWindow {
        index: field_u64(body, "index")?,
        shard: field_u64(body, "shard")?,
        slices: field_u64(body, "slices")?,
        drained: field_u64(body, "drained")?,
        drops: field_u64(body, "drops")?,
        paths: parsed,
    })
}

/// Parse the body of a `symbols` line: the producer's announcement of
/// newly interned stack ids (id → frames → rendering).
pub fn parse_symbols(v: &Json) -> Result<Vec<SymbolEntry>, String> {
    let body = v
        .get("symbols")
        .ok_or("symbols line carries no \"symbols\" body")?;
    let mut out = Vec::new();
    for e in body
        .get("entries")
        .and_then(|e| e.as_arr())
        .ok_or("\"entries\" is missing or not an array")?
    {
        let stack_id = e
            .get("stack_id")
            .ok_or("symbol entry missing \"stack_id\"")?
            .as_u64()
            .ok_or("symbol \"stack_id\" is not a u64")? as u32;
        let frames = e
            .get("frames")
            .and_then(|f| f.as_arr())
            .ok_or("symbol entry missing \"frames\" array")?
            .iter()
            .map(|a| a.as_u64().ok_or("symbol frame is not a u64".to_string()))
            .collect::<Result<Vec<u64>, String>>()?;
        let rendered = e
            .get("rendered")
            .and_then(|r| r.as_arr())
            .ok_or("symbol entry missing \"rendered\" array")?
            .iter()
            .map(|s| {
                s.as_str()
                    .map(|s| s.to_string())
                    .ok_or("rendered frame is not a string".to_string())
            })
            .collect::<Result<Vec<String>, String>>()?;
        out.push(SymbolEntry {
            stack_id,
            frames,
            rendered,
        });
    }
    Ok(out)
}

/// Per-producer ingestion accounting.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ProducerStats {
    /// Lines that parsed and carried a valid v1 envelope (including
    /// event kinds this reader skips by policy).
    pub lines_ok: u64,
    /// `shard_window` lines actually merged.
    pub partials: u64,
    /// Malformed lines refused and counted instead of trusted.
    pub quarantined: u64,
    /// The first quarantine reason, verbatim (diagnosis aid).
    pub first_error: Option<String>,
}

/// One producer's name + accounting, in ingestion order.
#[derive(Clone, Debug)]
pub struct ProducerReport {
    pub name: String,
    pub stats: ProducerStats,
}

/// Merges `shard_window` partials from any number of producers.
#[derive(Default)]
pub struct PartialAggregator {
    paths: FxHashMap<u32, PartialPath>,
    producers: Vec<ProducerReport>,
}

impl PartialAggregator {
    pub fn new() -> PartialAggregator {
        PartialAggregator::default()
    }

    /// Ingest one producer's JSONL stream. Never fails: malformed lines
    /// are quarantined into the producer's [`ProducerStats`].
    pub fn ingest(&mut self, producer: &str, text: &str) {
        let mut stats = ProducerStats::default();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match self.ingest_line(line) {
                Ok(merged) => {
                    stats.lines_ok += 1;
                    if merged {
                        stats.partials += 1;
                    }
                }
                Err(e) => {
                    stats.quarantined += 1;
                    stats.first_error.get_or_insert(e);
                }
            }
        }
        self.producers.push(ProducerReport {
            name: producer.to_string(),
            stats,
        });
    }

    /// Ingest a JSONL file, using its path as the producer name. I/O
    /// failure is a real error; content failures quarantine per line.
    pub fn ingest_file(&mut self, path: &str) -> anyhow::Result<()> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read partials {path:?}: {e}"))?;
        self.ingest(path, &text);
        Ok(())
    }

    /// `Ok(true)` = a `shard_window` line was merged; `Ok(false)` = a
    /// valid line of another event kind was skipped by policy.
    fn ingest_line(&mut self, line: &str) -> Result<bool, String> {
        let env = parse_envelope(line)?;
        if env.event != "shard_window" {
            // Another valid v1 event kind — not partial transport.
            return Ok(false);
        }
        // Validate the whole line before merging any of it, so a line
        // corrupt in its third path does not half-apply.
        let wire = parse_shard_window(&env.value)?;
        for p in wire.paths {
            let e = self.paths.entry(p.stack_id).or_insert(PartialPath {
                stack_id: p.stack_id,
                cm_fs: 0,
                slices: 0,
                first_seen: u64::MAX,
            });
            e.cm_fs = e.cm_fs.saturating_add(p.cm_fs);
            e.slices += p.slices;
            e.first_seen = e.first_seen.min(p.first_seen);
        }
        Ok(true)
    }

    /// Per-producer accounting, in ingestion order.
    pub fn producers(&self) -> &[ProducerReport] {
        &self.producers
    }

    /// Total quarantined lines across all producers.
    pub fn quarantined(&self) -> u64 {
        self.producers.iter().map(|p| p.stats.quarantined).sum()
    }

    /// Merged paths ranked by CMetric (ties: earlier first-seen, then
    /// lower id — fully deterministic).
    pub fn top(&self, n: usize) -> Vec<PartialPath> {
        let mut all: Vec<PartialPath> = self.paths.values().copied().collect();
        all.sort_by(|a, b| {
            b.cm_fs
                .cmp(&a.cm_fs)
                .then(a.first_seen.cmp(&b.first_seen))
                .then(a.stack_id.cmp(&b.stack_id))
        });
        all.truncate(n);
        all
    }

    /// Render the fleet-aggregation report: per-producer accounting
    /// (quarantine is *visible*, never silent) and the merged top-N.
    pub fn render(&self, n: usize) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        writeln!(
            out,
            "fleet partials: {} producer(s), {} merged path(s)",
            self.producers.len(),
            self.paths.len(),
        )
        .unwrap();
        for p in &self.producers {
            write!(
                out,
                "  {}: {} line(s) ok, {} partial(s), {} quarantined",
                p.name, p.stats.lines_ok, p.stats.partials, p.stats.quarantined,
            )
            .unwrap();
            match &p.stats.first_error {
                Some(e) => writeln!(out, " (first error: {e})").unwrap(),
                None => writeln!(out).unwrap(),
            }
        }
        let top = self.top(n);
        if top.is_empty() {
            writeln!(out, "no partials merged").unwrap();
        } else {
            writeln!(out, "top {} path(s) by CMetric:", top.len()).unwrap();
            for p in &top {
                writeln!(
                    out,
                    "  stack {:>6}  cm {:>10.3} ms  slices {:>6}  first seen {}",
                    p.stack_id,
                    p.cm_fs as f64 / 1e12,
                    p.slices,
                    p.first_seen,
                )
                .unwrap();
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::faults::corrupt_jsonl;

    fn line(index: u64, shard: u64, paths: &[(u64, u64, u64, u64)]) -> String {
        Json::obj(vec![
            ("schema", Json::u64(SCHEMA_VERSION)),
            ("event", Json::str("shard_window")),
            (
                "shard_window",
                Json::obj(vec![
                    ("index", Json::u64(index)),
                    ("shard", Json::u64(shard)),
                    ("slices", Json::u64(paths.iter().map(|p| p.2).sum())),
                    ("drained", Json::u64(10)),
                    ("drops", Json::u64(0)),
                    (
                        "paths",
                        Json::Arr(
                            paths
                                .iter()
                                .map(|(id, cm, sl, fs)| {
                                    Json::obj(vec![
                                        ("stack_id", Json::u64(*id)),
                                        ("cm_fs", Json::u64(*cm)),
                                        ("slices", Json::u64(*sl)),
                                        ("first_seen", Json::u64(*fs)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
        ])
        .to_compact()
    }

    #[test]
    fn partials_from_several_producers_merge_like_the_tree() {
        let a = format!(
            "{}\n{}\n",
            line(1, 0, &[(7, 100, 2, 40), (9, 50, 1, 41)]),
            line(2, 0, &[(7, 30, 1, 90)]),
        );
        let b = format!("{}\n", line(1, 1, &[(7, 1000, 3, 12)]));
        let mut agg = PartialAggregator::new();
        agg.ingest("nodeA", &a);
        agg.ingest("nodeB", &b);
        assert_eq!(agg.quarantined(), 0);
        assert_eq!(agg.producers()[0].stats.partials, 2);
        let top = agg.top(10);
        assert_eq!(top.len(), 2);
        // Path 7: sums combine, first_seen takes the minimum.
        assert_eq!(top[0].stack_id, 7);
        assert_eq!(top[0].cm_fs, 1130);
        assert_eq!(top[0].slices, 6);
        assert_eq!(top[0].first_seen, 12);
        assert_eq!(top[1].stack_id, 9);
        let r = agg.render(5);
        assert!(r.contains("nodeA: 2 line(s) ok, 2 partial(s), 0 quarantined"));
        assert!(r.contains("stack      7"));
    }

    #[test]
    fn other_valid_event_kinds_are_skipped_not_quarantined() {
        let text = format!(
            "{{\"schema\": {SCHEMA_VERSION}, \"event\": \"window\", \"window\": {{}}}}\n{}\n",
            line(1, 0, &[(3, 10, 1, 5)]),
        );
        let mut agg = PartialAggregator::new();
        agg.ingest("p", &text);
        let s = &agg.producers()[0].stats;
        assert_eq!(s.lines_ok, 2, "skipped lines still count as ok");
        assert_eq!(s.partials, 1);
        assert_eq!(s.quarantined, 0);
    }

    #[test]
    fn malformed_lines_are_quarantined_with_counters_and_first_error() {
        let cases = [
            "{not json at all",
            "{\"event\": \"shard_window\"}",
            "{\"schema\": 2, \"event\": \"shard_window\"}",
            "{\"schema\": 1, \"event\": 7}",
            "{\"schema\": 1, \"event\": \"shard_window\"}",
            "{\"schema\": 1, \"event\": \"shard_window\", \"shard_window\": {\"paths\": [{\"stack_id\": 1}]}}",
        ];
        for bad in cases {
            let text = format!("{bad}\n{}\n", line(1, 0, &[(5, 10, 1, 2)]));
            let mut agg = PartialAggregator::new();
            agg.ingest("p", &text);
            let s = &agg.producers()[0].stats;
            assert_eq!(s.quarantined, 1, "{bad} should quarantine");
            assert_eq!(s.partials, 1, "the good line still merges: {bad}");
            assert!(s.first_error.is_some(), "{bad}");
        }
        // A foreign schema version names both versions in the reason.
        let mut agg = PartialAggregator::new();
        agg.ingest("p", "{\"schema\": 2, \"event\": \"shard_window\"}\n");
        let err = agg.producers()[0].stats.first_error.clone().unwrap();
        assert!(err.contains('2') && err.contains('1'), "{err}");
    }

    #[test]
    fn a_corrupt_line_never_half_applies() {
        // Two paths, second one mistyped: the first must NOT merge.
        let text = "{\"schema\": 1, \"event\": \"shard_window\", \"shard_window\": \
                    {\"paths\": [\
                    {\"stack_id\": 1, \"cm_fs\": 5, \"slices\": 1, \"first_seen\": 2},\
                    {\"stack_id\": \"oops\"}]}}\n";
        let mut agg = PartialAggregator::new();
        agg.ingest("p", text);
        assert_eq!(agg.quarantined(), 1);
        assert!(agg.top(10).is_empty(), "nothing may merge from a bad line");
    }

    #[test]
    fn deterministic_corruption_is_survived_and_accounted() {
        let clean: String = (0..8)
            .map(|i| format!("{}\n", line(i, 0, &[(i, 100, 1, i)])))
            .collect();
        // Corrupt EVERY line of the dirty producer: truncations and
        // lost tails are guaranteed quarantine; a clobbered line may
        // still parse (then it is merged, or skipped if the event name
        // was the casualty) — so assert bounds, not exact counts. The
        // clean producer is the control: it must be untouched by its
        // peer's corruption.
        let dirty = corrupt_jsonl(&clean, 0xC0FFEE, 1);
        let mut agg = PartialAggregator::new();
        agg.ingest("clean", &clean);
        agg.ingest("dirty", &dirty);
        let c = &agg.producers()[0].stats;
        let d = &agg.producers()[1].stats;
        assert_eq!(c.partials, 8);
        assert_eq!(c.quarantined, 0);
        assert!(d.quarantined >= 1, "stats: {d:?}");
        assert!(d.first_error.is_some());
        assert!(d.partials + d.quarantined <= 8 + d.lines_ok);
        // Every clean path survives regardless of the dirty peer.
        assert_eq!(agg.top(16).len(), 8);
        assert!(agg.render(3).contains("dirty:"));
    }
}
