//! Parallel lane workers — the tree strategy's shard folders on real OS
//! threads (`--lane-threads N`, N > 1).
//!
//! The ownership split mirrors a real GAPP deployment, where one reader
//! thread per `PERF_EVENT_ARRAY` buffer consumes concurrently with the
//! application: the *driver* thread owns the simulated kernel, the ring
//! shards, the drop cursors and the sinks; each *lane worker* owns the
//! consumer-side fold state of the shards assigned to it (a
//! [`SliceAssembler`] + [`WindowAccumulator`] per lane — both
//! compile-asserted `Send` below). The hand-off is an SPSC channel per
//! worker: the driver drains a shard into a `Vec<Stamped<Record>>` and
//! sends it as one [`LaneMsg::Feed`]; drained batches are recycled back
//! over a return channel so the steady state allocates nothing.
//!
//! Workers fold *eagerly* on every feed. That is byte-equivalent to the
//! inline path's fold-at-window-close because each lane's records arrive
//! in shard FIFO (= ascending `(t, seq)`) order across feeds, every
//! window aggregate is associative, and app attribution is immutable
//! once assigned (a pid is tagged at `task_newtask`, before any of its
//! slices can be drained — so a worker's registry read never races the
//! write that matters to it).
//!
//! The barrier protocol is the window close: the driver sends one
//! [`LaneMsg::Close`] to every worker, and each replies with one
//! [`LaneWindow`] per owned lane — the shard's partial merge snapshot
//! plus its buffered activity-matrix records. Matrix records
//! (`Interval`/`SlotAssign`/`SlotFree`) stay on the driver thread for
//! the same reason the inline tree re-serializes them: thread slots are
//! a *global* resource recycled across CPUs and the analysis batches f32
//! rows in record-sequence order, so this substream must replay in
//! global `(t, seq)` order through the single [`UserProbe`] —
//! [`merge_matrix_into`] runs that k-way merge at window close, off the
//! hot path. Everything thread-count-dependent thus happens *between*
//! windows; within one, lanes are data-independent, which is what makes
//! the output byte-identical for every `N`.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, RwLock};
use std::thread;

use crate::ebpf::ringbuf::Stamped;

use super::super::records::Record;
use super::super::userspace::{
    MergedPath, ShardLane, SliceAssembler, UserProbe,
};
use super::multi::AppRegistry;
use super::window::WindowAccumulator;

// The whole point of the refactor: everything a lane worker owns must
// cross a thread boundary. Checked here, at compile time, so a future
// `Rc`/`RefCell` sneaking into the fold state fails the build instead
// of the spawn.
const fn assert_send<T: Send>() {}
const _: () = {
    assert_send::<SliceAssembler>();
    assert_send::<WindowAccumulator>();
    assert_send::<ShardLane>();
    assert_send::<LaneMsg>();
    assert_send::<LaneWindow>();
};

/// Driver → worker hand-off.
pub enum LaneMsg {
    /// One shard's drained records, in shard FIFO order. `lane` is the
    /// ring shard index (the worker owning `lane % nworkers` receives
    /// it).
    Feed {
        lane: usize,
        recs: Vec<Stamped<Record>>,
    },
    /// Window-close barrier: reply with one [`LaneWindow`] per owned
    /// lane, then start accumulating the next window.
    Close,
}

/// One shard's window close, produced by a lane worker: the shard-local
/// partial snapshot plus the matrix records the driver must re-merge.
pub struct LaneWindow {
    /// Ring shard this window covers.
    pub shard: usize,
    /// Slices folded this window (including ones excluded from the
    /// merge for dropped stack ids).
    pub slices_in: u64,
    /// The shard-local merge snapshot (ascending capture stamp — each
    /// lane's fold order is its shard's FIFO order).
    pub paths: Vec<MergedPath>,
    /// Buffered activity-matrix records in shard FIFO (= ascending
    /// `(t, seq)`) order, awaiting the driver's global re-merge.
    pub matrix: Vec<Stamped<Record>>,
}

/// The driver-side handle to a set of lane workers: per-worker feed
/// senders, per-worker window receivers, and the buffer-recycle return
/// channel. Holds no thread handles — the workers are scoped
/// (`std::thread::scope`) and join when every sender in this struct is
/// dropped, which is why the session driver resets the core's dispatch
/// *before* its scope exits.
pub struct LaneIo {
    txs: Vec<Sender<LaneMsg>>,
    rxs: Vec<Receiver<Vec<LaneWindow>>>,
    recycle: Receiver<Vec<Stamped<Record>>>,
    /// Locally-pooled empty batches (skipped sends land here).
    pool: Vec<Vec<Stamped<Record>>>,
    nworkers: usize,
    nshards: usize,
}

impl LaneIo {
    pub fn num_shards(&self) -> usize {
        self.nshards
    }

    pub fn num_workers(&self) -> usize {
        self.nworkers
    }

    /// An empty batch buffer for the next shard drain — recycled from a
    /// worker when one has come back, fresh otherwise.
    pub fn take_buf(&mut self) -> Vec<Stamped<Record>> {
        if let Some(b) = self.pool.pop() {
            return b;
        }
        self.recycle.try_recv().unwrap_or_default()
    }

    /// Hand one shard's drained batch to its lane worker. Empty batches
    /// are pooled instead of sent (a quiet shard costs no message).
    pub fn feed(&mut self, lane: usize, recs: Vec<Stamped<Record>>) {
        debug_assert!(lane < self.nshards);
        if recs.is_empty() {
            self.pool.push(recs);
            return;
        }
        self.txs[lane % self.nworkers]
            .send(LaneMsg::Feed { lane, recs })
            .expect("lane worker exited before its window closed");
    }

    /// The window-close barrier: ask every worker to close its lanes
    /// and collect one [`LaneWindow`] per ring shard, in shard order.
    pub fn close_window(&mut self) -> Vec<LaneWindow> {
        for tx in &self.txs {
            tx.send(LaneMsg::Close)
                .expect("lane worker exited before its window closed");
        }
        let mut out = Vec::with_capacity(self.nshards);
        for rx in &self.rxs {
            out.extend(
                rx.recv()
                    .expect("lane worker died before replying to a window close"),
            );
        }
        out.sort_by_key(|w| w.shard);
        out
    }
}

/// Spawn `min(lane_threads, nshards)` scoped lane workers; worker `w`
/// owns every shard `i` with `i % nworkers == w`. The returned
/// [`LaneIo`] is the only link to them: dropping it disconnects the
/// feed channels and the workers exit, letting the enclosing
/// `thread::scope` join.
pub fn spawn_lane_workers<'scope>(
    scope: &'scope thread::Scope<'scope, '_>,
    lane_threads: usize,
    nshards: usize,
    registry: Option<Arc<RwLock<AppRegistry>>>,
) -> LaneIo {
    let nworkers = lane_threads.min(nshards).max(1);
    let (recycle_tx, recycle_rx) = channel();
    let mut txs = Vec::with_capacity(nworkers);
    let mut rxs = Vec::with_capacity(nworkers);
    for w in 0..nworkers {
        let (tx_msg, rx_msg) = channel::<LaneMsg>();
        let (tx_win, rx_win) = channel::<Vec<LaneWindow>>();
        let shards: Vec<usize> = (w..nshards).step_by(nworkers).collect();
        let reg = registry.clone();
        let recycle = recycle_tx.clone();
        scope.spawn(move || worker_loop(shards, rx_msg, tx_win, recycle, reg));
        txs.push(tx_msg);
        rxs.push(rx_win);
    }
    LaneIo {
        txs,
        rxs,
        recycle: recycle_rx,
        pool: Vec::new(),
        nworkers,
        nshards,
    }
}

/// One lane's worker-owned fold state (the threaded analogue of
/// [`ShardLane`] + the per-shard [`WindowAccumulator`] the inline
/// consumer keeps).
struct WorkerLane {
    shard: usize,
    asm: SliceAssembler,
    wacc: WindowAccumulator,
    matrix: Vec<Stamped<Record>>,
}

fn worker_loop(
    shards: Vec<usize>,
    rx: Receiver<LaneMsg>,
    tx: Sender<Vec<LaneWindow>>,
    recycle: Sender<Vec<Stamped<Record>>>,
    registry: Option<Arc<RwLock<AppRegistry>>>,
) {
    let mut lanes: Vec<WorkerLane> = shards
        .into_iter()
        .map(|shard| WorkerLane {
            shard,
            asm: SliceAssembler::new(),
            wacc: WindowAccumulator::new(),
            matrix: Vec::new(),
        })
        .collect();
    // Exiting on a disconnected feed channel is the shutdown protocol:
    // the driver drops its LaneIo, every Sender dies, recv() errors.
    while let Ok(msg) = rx.recv() {
        match msg {
            LaneMsg::Feed { lane, mut recs } => {
                let l = lanes
                    .iter_mut()
                    .find(|l| l.shard == lane)
                    .expect("batch fed to a lane this worker does not own");
                let WorkerLane {
                    asm, wacc, matrix, ..
                } = l;
                for r in &recs {
                    if !asm.consume(&r.rec) {
                        matrix.push(*r);
                    }
                }
                recs.clear();
                // Driver may already be gone mid-teardown; the buffer
                // just isn't recycled then.
                let _ = recycle.send(recs);
                // Eager fold: one registry read lock per batch, one
                // lookup per slice — same sequence, same attribution as
                // the inline fold at window close.
                let reg = registry.as_ref().map(|r| r.read().unwrap());
                for s in asm.slices.drain(..) {
                    let app = reg.as_ref().map_or(0, |g| g.app_of(s.pid));
                    wacc.add_slice(&s, app);
                }
            }
            LaneMsg::Close => {
                let mut out = Vec::with_capacity(lanes.len());
                for l in lanes.iter_mut() {
                    let slices_in = l.wacc.slices_in;
                    out.push(LaneWindow {
                        shard: l.shard,
                        slices_in,
                        paths: l.wacc.snapshot(),
                        matrix: std::mem::take(&mut l.matrix),
                    });
                }
                if tx.send(out).is_err() {
                    return;
                }
            }
        }
    }
}

/// Replay every lane window's buffered activity-matrix records into
/// `user` in global `(t, seq)` order — the driver-thread half of the
/// window-close barrier, mirroring the inline
/// [`super::super::userspace::ShardLanes::feed_matrix_into`]. Each
/// window's buffer is already ascending (shard FIFO order), so a k-way
/// merge over the heads suffices; the heap holds at most one entry per
/// shard.
pub fn merge_matrix_into(windows: &mut [LaneWindow], user: &mut UserProbe) {
    use std::cmp::Reverse;
    if windows.len() == 1 {
        for r in windows[0].matrix.drain(..) {
            user.consume(r.rec);
        }
        return;
    }
    let mut next = vec![0usize; windows.len()];
    let mut heads: std::collections::BinaryHeap<Reverse<(u64, u64, usize)>> =
        std::collections::BinaryHeap::with_capacity(windows.len());
    for (i, w) in windows.iter().enumerate() {
        if let Some(r) = w.matrix.first() {
            heads.push(Reverse((r.t, r.seq, i)));
        }
    }
    while let Some(Reverse((_, _, i))) = heads.pop() {
        let rec = windows[i].matrix[next[i]];
        next[i] += 1;
        user.consume(rec.rec);
        if let Some(r) = windows[i].matrix.get(next[i]) {
            heads.push(Reverse((r.t, r.seq, i)));
        }
    }
    for w in windows.iter_mut() {
        w.matrix.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::WaitKind;

    fn end(ts_id: u64, pid: u32, stack_id: u32) -> Record {
        Record::SliceEnd {
            ts_id,
            pid,
            cm_ns: 50.0 + ts_id as f64,
            threads_av: 1.0,
            ip: 0x10 * ts_id,
            stack_id,
            stack_top: 0,
            wait: WaitKind::Futex,
            woken_by: 0,
        }
    }

    fn stamped(t: u64, seq: u64, rec: Record) -> Stamped<Record> {
        Stamped { t, seq, rec }
    }

    /// Feed the same per-shard record streams to (a) scoped workers at
    /// several thread counts and (b) an inline shard-local fold; the
    /// per-shard window snapshots must agree byte for byte, and matrix
    /// records must come back in shard FIFO order for the re-merge.
    #[test]
    fn workers_fold_byte_identically_to_the_inline_lanes() {
        // Two shards; each stream is its own FIFO. Slice lifecycles are
        // shard-affine; matrix records interleave globally.
        let shard0 = vec![
            stamped(10, 1, Record::Sample { pid: 1, ip: 0xA }),
            stamped(11, 3, Record::SlotAssign { pid: 1, slot: 0 }),
            stamped(12, 5, end(1, 1, 9)),
            stamped(14, 7, Record::Sample { pid: 1, ip: 0xB }),
            stamped(15, 9, end(3, 1, 9)),
        ];
        let shard1 = vec![
            stamped(10, 2, Record::Sample { pid: 2, ip: 0xC }),
            stamped(11, 4, end(2, 2, 7)),
            stamped(13, 6, Record::SlotFree { pid: 1, slot: 0 }),
        ];

        // Inline oracle: shard-local assemblers + accumulators.
        let mut oracle: Vec<(u64, Vec<MergedPath>)> = Vec::new();
        for recs in [&shard0, &shard1] {
            let mut asm = SliceAssembler::new();
            let mut wacc = WindowAccumulator::new();
            for r in recs.iter() {
                asm.consume(&r.rec);
            }
            for s in asm.slices.drain(..) {
                wacc.add_slice(&s, 0);
            }
            oracle.push((wacc.slices_in, wacc.snapshot()));
        }

        for threads in [1usize, 2, 4] {
            let windows = std::thread::scope(|s| {
                let mut io = spawn_lane_workers(s, threads, 2, None);
                assert_eq!(io.num_workers(), threads.min(2));
                // Split shard 0 across two feeds: a slice may span the
                // hand-off boundary (sample in one batch, end in the
                // next) and must still pair.
                io.feed(0, shard0[..3].to_vec());
                io.feed(1, shard1.clone());
                io.feed(0, shard0[3..].to_vec());
                io.feed(1, Vec::new()); // quiet drain: no message
                io.close_window()
            });
            assert_eq!(windows.len(), 2);
            for (w, (slices_in, paths)) in windows.iter().zip(&oracle) {
                assert_eq!(w.slices_in, *slices_in, "threads={threads}");
                assert_eq!(w.paths.len(), paths.len());
                for (a, b) in w.paths.iter().zip(paths) {
                    assert_eq!(a.stack_id, b.stack_id);
                    assert_eq!(a.cm_fs, b.cm_fs);
                    assert_eq!(a.first_seen, b.first_seen);
                    assert_eq!(a.addr_freq, b.addr_freq);
                }
            }
            // Matrix records survive in shard FIFO order, slices don't
            // leak into the matrix buffers.
            assert_eq!(windows[0].matrix.len(), 1);
            assert_eq!(windows[0].matrix[0].seq, 3);
            assert_eq!(windows[1].matrix.len(), 1);
            assert_eq!(windows[1].matrix[0].seq, 6);
        }
    }

    /// Closing again after a close starts a fresh window (accumulators
    /// reset, matrix buffers drained), and the registry attributes apps
    /// through the shared lock.
    #[test]
    fn close_resets_for_the_next_window_and_registry_attributes() {
        let reg = Arc::new(RwLock::new(AppRegistry::new()));
        {
            let mut r = reg.write().unwrap();
            r.begin_app("a");
            r.on_task_new(1, 0);
            r.end_spawn();
            r.begin_app("b");
            r.on_task_new(2, 0);
            r.end_spawn();
        }
        std::thread::scope(|s| {
            let mut io = spawn_lane_workers(s, 2, 2, Some(reg.clone()));
            io.feed(0, vec![stamped(10, 1, end(1, 1, 3))]);
            io.feed(1, vec![stamped(11, 2, end(2, 2, 4))]);
            let w1 = io.close_window();
            assert_eq!(w1[0].paths[0].app_slices[&0], 1);
            assert_eq!(w1[1].paths[0].app_slices[&1], 1);
            let w2 = io.close_window();
            assert_eq!(w2.len(), 2);
            assert!(w2.iter().all(|w| w.slices_in == 0));
            assert!(w2.iter().all(|w| w.paths.is_empty() && w.matrix.is_empty()));
        });
    }

    #[test]
    fn matrix_re_merge_replays_global_capture_order() {
        use crate::gapp::records::{mask_set, SlotMask};
        use crate::runtime::AnalysisEngine;
        let mut mask: SlotMask = [0; 2];
        mask_set(&mut mask, 0);
        // Slot 0 owned by pid 1 (shard 0), recycled to pid 2 via shard
        // 1 — replay must interleave by (t, seq) or the second interval
        // charges the wrong pid.
        let mut windows = vec![
            LaneWindow {
                shard: 0,
                slices_in: 0,
                paths: Vec::new(),
                matrix: vec![
                    stamped(1, 1, Record::SlotAssign { pid: 1, slot: 0 }),
                    stamped(2, 2, Record::Interval { dur: 500, mask }),
                    stamped(5, 5, Record::Interval { dur: 300, mask }),
                ],
            },
            LaneWindow {
                shard: 1,
                slices_in: 0,
                paths: Vec::new(),
                matrix: vec![
                    stamped(3, 3, Record::SlotFree { pid: 1, slot: 0 }),
                    stamped(4, 4, Record::SlotAssign { pid: 2, slot: 0 }),
                ],
            },
        ];
        let mut user = UserProbe::new(AnalysisEngine::native());
        merge_matrix_into(&mut windows, &mut user);
        user.flush_batch();
        assert_eq!(user.records_processed, 5);
        assert!((user.totals.get(1).unwrap().cm_ns - 500.0).abs() < 1e-3);
        assert!((user.totals.get(2).unwrap().cm_ns - 300.0).abs() < 1e-3);
        assert!(windows.iter().all(|w| w.matrix.is_empty()));
    }
}
