//! The epoch-based ring-buffer consumer — the poll-loop analogue of a
//! `PERF_EVENT_ARRAY` user-space reader.
//!
//! The batch profiler drains the rings once at `finish()`; the streaming
//! analyzer instead interleaves simulation epochs with full drains. The
//! transport is sharded per CPU, so a [`ShardedConsumer`] holds one
//! [`RingCursor`] per shard: each epoch it drains every shard (the drain
//! itself re-establishes the global record order from the capture
//! timestamps) and reads per-shard [`EpochDelta`]s, so producer-side
//! drops are charged both to the epoch in which they occurred *and* to
//! the CPU buffer that overflowed — the two axes a real deployment tunes
//! buffer pages against.

use crate::ebpf::ringbuf::{EpochDelta, RingCursor};

use super::super::GappCore;

/// Per-epoch drain statistics (one entry per window in the live report).
#[derive(Clone, Debug, Default)]
pub struct EpochStats {
    /// Epoch index (1-based, matching window numbering).
    pub epoch: u64,
    /// Ring activity attributed to this epoch, summed across shards.
    pub delta: EpochDelta,
    /// The same activity broken down by shard (indexed by shard id).
    pub per_shard: Vec<EpochDelta>,
}

/// Drains the shared kernel/user core once per epoch, one cursor per
/// ring shard.
#[derive(Debug, Default)]
pub struct ShardedConsumer {
    cursors: Vec<RingCursor>,
    /// Epochs completed so far.
    pub epochs: u64,
    /// Total drops observed across all epochs and shards (must equal
    /// the rings' aggregated counter — the accounting identity the
    /// tests pin down).
    pub total_dropped: u64,
    /// Cumulative drops per shard (sums to `total_dropped`).
    pub shard_dropped: Vec<u64>,
}

impl ShardedConsumer {
    /// A consumer for `nshards` ring shards whose first epoch is charged
    /// everything since the rings were created (cursors start at zero).
    pub fn new(nshards: usize) -> ShardedConsumer {
        ShardedConsumer {
            cursors: vec![RingCursor::default(); nshards],
            epochs: 0,
            total_dropped: 0,
            shard_dropped: vec![0; nshards],
        }
    }

    pub fn num_shards(&self) -> usize {
        self.cursors.len()
    }

    /// Drain everything currently buffered (all shards, globally
    /// re-ordered) into the user-space probe and close the epoch:
    /// returns the per-shard ring activity since the previous call.
    /// Mid-epoch drains triggered by the kernel probe's per-shard
    /// drain-threshold are included (they belong to this epoch).
    pub fn drain_epoch(&mut self, core: &mut GappCore) -> EpochStats {
        debug_assert_eq!(self.cursors.len(), core.kernel.rings.num_shards());
        core.drain();
        let mut total = EpochDelta::default();
        let mut per_shard = Vec::with_capacity(self.cursors.len());
        for (i, cur) in self.cursors.iter_mut().enumerate() {
            let d = cur.advance(core.kernel.rings.shard(i));
            total.absorb(&d);
            self.shard_dropped[i] += d.dropped;
            per_shard.push(d);
        }
        self.epochs += 1;
        self.total_dropped += total.dropped;
        EpochStats {
            epoch: self.epochs,
            delta: total,
            per_shard,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::records::Record;
    use crate::gapp::GappConfig;
    use crate::runtime::AnalysisEngine;

    fn tiny_core(ring_capacity: usize, shards: usize) -> GappCore {
        let cfg = GappConfig {
            ring_capacity,
            shards: Some(shards),
            // The consumer under test is the only drainer; the single
            // `drain_threshold` knob now lives in `GappConfig` alone
            // (it used to be duplicated into `GappCore`).
            drain_threshold: usize::MAX,
            ..Default::default()
        };
        GappCore {
            kernel: crate::gapp::probes::KernelProbes::new(cfg, 2).unwrap(),
            user: crate::gapp::userspace::UserProbe::new(AnalysisEngine::native()),
        }
    }

    fn sample(pid: u32, ip: u64) -> Record {
        Record::Sample { pid, ip }
    }

    #[test]
    fn drops_are_charged_to_their_epoch() {
        let mut core = tiny_core(4, 1);
        let mut cons = ShardedConsumer::new(1);
        // Epoch 1: overflow by 2.
        for i in 0..6 {
            core.kernel.rings.push(0, i, sample(1, i));
        }
        let e1 = cons.drain_epoch(&mut core);
        assert_eq!(e1.epoch, 1);
        assert_eq!(e1.delta.dropped, 2);
        assert_eq!(e1.delta.drained, 4);
        assert_eq!(core.kernel.rings.len(), 0);
        // Epoch 2: no overflow.
        core.kernel.rings.push(0, 9, sample(1, 9));
        let e2 = cons.drain_epoch(&mut core);
        assert_eq!(e2.delta.dropped, 0);
        assert_eq!(e2.delta.drained, 1);
        // Epoch 3: overflow by 1.
        for i in 0..5 {
            core.kernel.rings.push(0, 20 + i, sample(1, 20 + i));
        }
        let e3 = cons.drain_epoch(&mut core);
        assert_eq!(e3.delta.dropped, 1);
        // Accounting identity: per-epoch drops sum to the global figure.
        assert_eq!(cons.total_dropped, core.kernel.rings.stats().dropped);
        assert_eq!(cons.epochs, 3);
        // Everything drained reached the user probe.
        assert_eq!(core.user.records_processed, 4 + 1 + 4);
    }

    #[test]
    fn quiet_epoch_reports_zero_deltas() {
        let mut core = tiny_core(8, 1);
        let mut cons = ShardedConsumer::new(1);
        core.kernel.rings.push(0, 5, Record::SliceDiscard { pid: 3 });
        assert_eq!(cons.drain_epoch(&mut core).delta.drained, 1);
        let quiet = cons.drain_epoch(&mut core);
        assert_eq!(quiet.delta, crate::ebpf::EpochDelta::default());
        assert_eq!(quiet.per_shard, vec![crate::ebpf::EpochDelta::default()]);
        assert_eq!(cons.epochs, 2);
    }

    #[test]
    fn sharded_drops_attribute_to_shard_and_epoch() {
        let mut core = tiny_core(2, 2);
        let mut cons = ShardedConsumer::new(2);
        // Epoch 1: CPU 0 overflows its shard by 3; CPU 1 stays clean.
        for i in 0..5 {
            core.kernel.rings.push(0, i, sample(1, i));
        }
        core.kernel.rings.push(1, 9, sample(2, 9));
        let e1 = cons.drain_epoch(&mut core);
        assert_eq!(e1.per_shard.len(), 2);
        assert_eq!(e1.per_shard[0].dropped, 3);
        assert_eq!(e1.per_shard[1].dropped, 0);
        assert_eq!(e1.delta.dropped, 3);
        // Epoch 2: the other shard overflows by 1.
        for i in 0..3 {
            core.kernel.rings.push(1, 20 + i, sample(2, 20 + i));
        }
        let e2 = cons.drain_epoch(&mut core);
        assert_eq!(e2.per_shard[0].dropped, 0);
        assert_eq!(e2.per_shard[1].dropped, 1);
        // Accounting identity, both axes: per-shard per-epoch drop
        // deltas sum to the global dropped counter.
        assert_eq!(cons.shard_dropped, vec![3, 1]);
        assert_eq!(
            cons.shard_dropped.iter().sum::<u64>(),
            core.kernel.rings.stats().dropped
        );
        assert_eq!(cons.total_dropped, core.kernel.rings.stats().dropped);
        // Per-shard counters on the rings agree with the cursors.
        let per = core.kernel.rings.shard_stats();
        assert_eq!(per[0].dropped, 3);
        assert_eq!(per[1].dropped, 1);
    }
}
