//! The epoch-based ring-buffer consumer — the poll-loop analogue of a
//! `PERF_EVENT_ARRAY` user-space reader.
//!
//! The batch profiler drains the rings once at `finish()`; the streaming
//! analyzer instead interleaves simulation epochs with full drains. The
//! transport is sharded per CPU, so a [`ShardedConsumer`] holds one
//! [`RingCursor`] per shard: each epoch it drains every shard and reads
//! per-shard [`EpochDelta`]s, so producer-side drops are charged both to
//! the epoch in which they occurred *and* to the CPU buffer that
//! overflowed — the two axes a real deployment tunes buffer pages
//! against.
//!
//! How the drained records reach the aggregation depends on the
//! session's `MergeStrategy`. Under `Serial` the drain k-way-merges
//! every shard back into one `(time, seq)`-ordered stream feeding a
//! single accumulator. Under `Tree` the consumer is a *tree of shard
//! folders*: each shard drains in shard order into its own lane and
//! shard-local [`WindowAccumulator`], and [`ShardedConsumer::
//! fold_partials`] returns the per-shard partial snapshots the driver
//! combines through the pairwise merge tree at window close.

use crate::ebpf::ringbuf::{EpochDelta, RingCursor};
use crate::simkernel::Pid;

use super::super::userspace::MergedPath;
use super::super::{GappCore, LaneDispatch};
use super::window::WindowAccumulator;

/// Per-epoch drain statistics (one entry per window in the live report).
#[derive(Clone, Debug, Default)]
pub struct EpochStats {
    /// Epoch index (1-based, matching window numbering).
    pub epoch: u64,
    /// Ring activity attributed to this epoch, summed across shards.
    pub delta: EpochDelta,
    /// The same activity broken down by shard (indexed by shard id).
    pub per_shard: Vec<EpochDelta>,
}

/// One shard's partial window aggregation, produced by
/// [`ShardedConsumer::fold_partials`] at window close.
pub struct ShardPartial {
    /// Ring shard this partial covers.
    pub shard: usize,
    /// Slices this shard's accumulator folded this window (including
    /// slices excluded from the merge for dropped stack ids).
    pub slices_in: u64,
    /// The shard-local merge snapshot (shard-local first-seen order —
    /// which, per shard, is already ascending capture stamp).
    pub paths: Vec<MergedPath>,
}

/// Drains the shared kernel/user core once per epoch, one cursor per
/// ring shard — and, under the tree strategy, one shard-local
/// [`WindowAccumulator`] per shard.
#[derive(Default)]
pub struct ShardedConsumer {
    cursors: Vec<RingCursor>,
    /// Per-shard window accumulators (tree strategy; idle under serial).
    waccs: Vec<WindowAccumulator>,
    /// Epochs completed so far.
    pub epochs: u64,
    /// Total drops observed across all epochs and shards (must equal
    /// the rings' aggregated counter — the accounting identity the
    /// tests pin down).
    pub total_dropped: u64,
    /// Cumulative drops per shard (sums to `total_dropped`).
    pub shard_dropped: Vec<u64>,
}

impl ShardedConsumer {
    /// A consumer for `nshards` ring shards whose first epoch is charged
    /// everything since the rings were created (cursors start at zero).
    pub fn new(nshards: usize) -> ShardedConsumer {
        ShardedConsumer {
            cursors: vec![RingCursor::default(); nshards],
            waccs: (0..nshards).map(|_| WindowAccumulator::new()).collect(),
            epochs: 0,
            total_dropped: 0,
            shard_dropped: vec![0; nshards],
        }
    }

    pub fn num_shards(&self) -> usize {
        self.cursors.len()
    }

    /// Drain everything currently buffered into the consumer side and
    /// close the epoch: returns the per-shard ring activity since the
    /// previous call. Mid-epoch drains triggered by the kernel probe's
    /// per-shard drain-threshold are included (they belong to this
    /// epoch). Serial: one globally re-ordered stream into the user
    /// probe. Tree: per-shard drains into the core's lanes, then the
    /// buffered matrix substream is re-merged into the user probe in
    /// global capture order (the one place the tree still serializes —
    /// slot state and f32 batch grouping are globally order-sensitive).
    pub fn drain_epoch(&mut self, core: &mut GappCore) -> EpochStats {
        debug_assert_eq!(self.cursors.len(), core.kernel.rings.num_shards());
        core.drain();
        {
            // Inline tree only: threaded lanes buffer their matrix
            // records worker-side and the driver replays them at the
            // window-close barrier instead (`close_lane_window`) —
            // batch grouping depends only on record order, which the
            // deferred replay preserves.
            let GappCore { lanes, user, .. } = &mut *core;
            if let LaneDispatch::Inline(l) = lanes {
                l.feed_matrix_into(user);
            }
        }
        let mut total = EpochDelta::default();
        let mut per_shard = Vec::with_capacity(self.cursors.len());
        for (i, cur) in self.cursors.iter_mut().enumerate() {
            let d = cur.advance(core.kernel.rings.shard(i));
            total.absorb(&d);
            self.shard_dropped[i] += d.dropped;
            per_shard.push(d);
        }
        self.epochs += 1;
        self.total_dropped += total.dropped;
        EpochStats {
            epoch: self.epochs,
            delta: total,
            per_shard,
        }
    }

    /// Tree strategy, window close: fold each lane's assembled slices
    /// (in shard order — no cross-shard comparisons) into that shard's
    /// accumulator and snapshot the partials. `app_of` attributes each
    /// slice to its owning application (attribution is per pid and
    /// immutable once assigned, so folding shard-locally cannot change
    /// it). The driver combines the returned partials through
    /// [`super::window::merge_tree`].
    ///
    /// Panics if the core was built for the serial strategy (no lanes).
    pub fn fold_partials(
        &mut self,
        core: &mut GappCore,
        app_of: impl Fn(Pid) -> u16,
    ) -> Vec<ShardPartial> {
        let lanes = match &mut core.lanes {
            LaneDispatch::Inline(l) => l,
            _ => panic!(
                "fold_partials requires inline MergeStrategy::Tree lanes \
                 (serial cores have none; threaded lanes fold in their \
                 workers and close via GappCore::close_lane_window)"
            ),
        };
        debug_assert_eq!(lanes.len(), self.waccs.len());
        let mut out = Vec::with_capacity(self.waccs.len());
        for (i, lane) in lanes.iter_mut().enumerate() {
            let w = &mut self.waccs[i];
            for s in lane.asm.slices.drain(..) {
                w.add_slice(&s, app_of(s.pid));
            }
            let slices_in = w.slices_in;
            out.push(ShardPartial {
                shard: i,
                slices_in,
                paths: w.snapshot(),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::records::Record;
    use crate::gapp::{GappConfig, MergeStrategy};
    use crate::runtime::AnalysisEngine;

    fn core_with(ring_capacity: usize, shards: usize, merge: MergeStrategy) -> GappCore {
        let cfg = GappConfig {
            ring_capacity,
            shards: Some(shards),
            // The consumer under test is the only drainer; the single
            // `drain_threshold` knob now lives in `GappConfig` alone
            // (it used to be duplicated into `GappCore`).
            drain_threshold: usize::MAX,
            merge,
            ..Default::default()
        };
        let lanes = match merge {
            MergeStrategy::Serial => LaneDispatch::None,
            MergeStrategy::Tree => LaneDispatch::Inline(
                crate::gapp::userspace::ShardLanes::new(shards),
            ),
        };
        GappCore {
            kernel: crate::gapp::probes::KernelProbes::new(cfg, 2).unwrap(),
            user: crate::gapp::userspace::UserProbe::new(AnalysisEngine::native()),
            lanes,
            hazard: Default::default(),
        }
    }

    /// The serial-strategy core the pre-tree tests were written
    /// against: every drained record reaches `core.user` directly.
    fn tiny_core(ring_capacity: usize, shards: usize) -> GappCore {
        core_with(ring_capacity, shards, MergeStrategy::Serial)
    }

    fn sample(pid: u32, ip: u64) -> Record {
        Record::Sample { pid, ip }
    }

    #[test]
    fn drops_are_charged_to_their_epoch() {
        let mut core = tiny_core(4, 1);
        let mut cons = ShardedConsumer::new(1);
        // Epoch 1: overflow by 2.
        for i in 0..6 {
            core.kernel.rings.push(0, i, sample(1, i));
        }
        let e1 = cons.drain_epoch(&mut core);
        assert_eq!(e1.epoch, 1);
        assert_eq!(e1.delta.dropped, 2);
        assert_eq!(e1.delta.drained, 4);
        assert_eq!(core.kernel.rings.len(), 0);
        // Epoch 2: no overflow.
        core.kernel.rings.push(0, 9, sample(1, 9));
        let e2 = cons.drain_epoch(&mut core);
        assert_eq!(e2.delta.dropped, 0);
        assert_eq!(e2.delta.drained, 1);
        // Epoch 3: overflow by 1.
        for i in 0..5 {
            core.kernel.rings.push(0, 20 + i, sample(1, 20 + i));
        }
        let e3 = cons.drain_epoch(&mut core);
        assert_eq!(e3.delta.dropped, 1);
        // Accounting identity: per-epoch drops sum to the global figure.
        assert_eq!(cons.total_dropped, core.kernel.rings.stats().dropped);
        assert_eq!(cons.epochs, 3);
        // Everything drained reached the user probe.
        assert_eq!(core.user.records_processed, 4 + 1 + 4);
    }

    #[test]
    fn quiet_epoch_reports_zero_deltas() {
        let mut core = tiny_core(8, 1);
        let mut cons = ShardedConsumer::new(1);
        core.kernel.rings.push(0, 5, Record::SliceDiscard { pid: 3 });
        assert_eq!(cons.drain_epoch(&mut core).delta.drained, 1);
        let quiet = cons.drain_epoch(&mut core);
        assert_eq!(quiet.delta, crate::ebpf::EpochDelta::default());
        assert_eq!(quiet.per_shard, vec![crate::ebpf::EpochDelta::default()]);
        assert_eq!(cons.epochs, 2);
    }

    #[test]
    fn sharded_drops_attribute_to_shard_and_epoch() {
        let mut core = tiny_core(2, 2);
        let mut cons = ShardedConsumer::new(2);
        // Epoch 1: CPU 0 overflows its shard by 3; CPU 1 stays clean.
        for i in 0..5 {
            core.kernel.rings.push(0, i, sample(1, i));
        }
        core.kernel.rings.push(1, 9, sample(2, 9));
        let e1 = cons.drain_epoch(&mut core);
        assert_eq!(e1.per_shard.len(), 2);
        assert_eq!(e1.per_shard[0].dropped, 3);
        assert_eq!(e1.per_shard[1].dropped, 0);
        assert_eq!(e1.delta.dropped, 3);
        // Epoch 2: the other shard overflows by 1.
        for i in 0..3 {
            core.kernel.rings.push(1, 20 + i, sample(2, 20 + i));
        }
        let e2 = cons.drain_epoch(&mut core);
        assert_eq!(e2.per_shard[0].dropped, 0);
        assert_eq!(e2.per_shard[1].dropped, 1);
        // Accounting identity, both axes: per-shard per-epoch drop
        // deltas sum to the global dropped counter.
        assert_eq!(cons.shard_dropped, vec![3, 1]);
        assert_eq!(
            cons.shard_dropped.iter().sum::<u64>(),
            core.kernel.rings.stats().dropped
        );
        assert_eq!(cons.total_dropped, core.kernel.rings.stats().dropped);
        // Per-shard counters on the rings agree with the cursors.
        let per = core.kernel.rings.shard_stats();
        assert_eq!(per[0].dropped, 3);
        assert_eq!(per[1].dropped, 1);
    }

    #[test]
    fn tree_mode_folds_slices_shard_locally() {
        let mut core = core_with(64, 2, MergeStrategy::Tree);
        let mut cons = ShardedConsumer::new(2);
        let end = |ts_id: u64, pid: u32, stack_id: u32| Record::SliceEnd {
            ts_id,
            pid,
            cm_ns: 100.0,
            threads_av: 1.0,
            ip: 0x10 * ts_id,
            stack_id,
            stack_top: 0,
            wait: crate::simkernel::WaitKind::Futex,
            woken_by: 0,
        };
        // Slices interleave across CPUs; each slice's sample precedes
        // its end on the same CPU (shard affinity).
        core.kernel.rings.push(0, 1, Record::Sample { pid: 1, ip: 0xA });
        core.kernel.rings.push(1, 2, Record::Sample { pid: 2, ip: 0xB });
        core.kernel.rings.push(1, 3, end(1, 2, 7));
        core.kernel.rings.push(0, 4, end(2, 1, 9));
        let e = cons.drain_epoch(&mut core);
        assert_eq!(e.delta.drained, 4);
        // Slice records never reach the user probe under the tree.
        assert_eq!(core.user.records_processed, 0);
        assert_eq!(core.user.slices().len(), 0);
        let parts = cons.fold_partials(&mut core, |_| 0);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].slices_in, 1);
        assert_eq!(parts[1].slices_in, 1);
        // Shard-local pairing matched each sample with its slice.
        assert_eq!(parts[0].paths[0].stack_id, 9);
        assert_eq!(parts[0].paths[0].addr_freq[&0xA], 1);
        assert_eq!(parts[1].paths[0].stack_id, 7);
        assert_eq!(parts[1].paths[0].addr_freq[&0xB], 1);
        // first_seen carries the capture stamp for the order merge.
        assert_eq!(parts[0].paths[0].first_seen, 2);
        assert_eq!(parts[1].paths[0].first_seen, 1);
        // Accumulators reset per window.
        let parts2 = cons.fold_partials(&mut core, |_| 0);
        assert_eq!(parts2[0].slices_in, 0);
        assert!(parts2[0].paths.is_empty());
    }

    #[test]
    fn tree_mode_re_merges_matrix_records_in_capture_order() {
        let mut core = core_with(64, 2, MergeStrategy::Tree);
        let mut cons = ShardedConsumer::new(2);
        // Slot 0 is owned by pid 1 on shard 0, then recycled to pid 2
        // via records on shard 1. The re-merge must replay the global
        // capture order, or the interval would charge the wrong pid.
        core.kernel.rings.push(0, 1, Record::SlotAssign { pid: 1, slot: 0 });
        let mut mask: crate::gapp::records::SlotMask = [0; 2];
        crate::gapp::records::mask_set(&mut mask, 0);
        core.kernel.rings.push(0, 2, Record::Interval { dur: 500, mask });
        core.kernel.rings.push(1, 3, Record::SlotFree { pid: 1, slot: 0 });
        core.kernel.rings.push(1, 4, Record::SlotAssign { pid: 2, slot: 0 });
        core.kernel.rings.push(0, 5, Record::Interval { dur: 300, mask });
        cons.drain_epoch(&mut core);
        core.user.flush_batch();
        assert_eq!(core.user.records_processed, 5);
        let t1 = core.user.totals.get(1).unwrap();
        let t2 = core.user.totals.get(2).unwrap();
        assert!((t1.cm_ns - 500.0).abs() < 1e-3, "{}", t1.cm_ns);
        assert!((t2.cm_ns - 300.0).abs() < 1e-3, "{}", t2.cm_ns);
    }

    #[test]
    fn tree_and_serial_epoch_accounting_agree() {
        // Same push plan against both strategies: drained/dropped
        // deltas and the (epoch × shard) identity must be identical.
        let plan = |core: &mut GappCore| {
            for i in 0..5 {
                core.kernel.rings.push(0, i, sample(1, i));
            }
            core.kernel.rings.push(1, 9, sample(2, 9));
        };
        let mut results = Vec::new();
        for merge in [MergeStrategy::Serial, MergeStrategy::Tree] {
            let mut core = core_with(2, 2, merge);
            let mut cons = ShardedConsumer::new(2);
            plan(&mut core);
            let e = cons.drain_epoch(&mut core);
            assert_eq!(
                cons.total_dropped,
                core.kernel.rings.stats().dropped,
                "{merge:?}"
            );
            results.push((e.delta, e.per_shard));
        }
        assert_eq!(results[0], results[1]);
    }
}
