//! The epoch-based ring-buffer consumer — the poll-loop analogue of a
//! `BPF_MAP_TYPE_RINGBUF` / `PERF_EVENT_ARRAY` user-space reader.
//!
//! The batch profiler drains the ring once at `finish()`; the streaming
//! analyzer instead interleaves simulation epochs with full drains, and
//! uses a [`RingCursor`] so producer-side drops are charged to the
//! epoch in which they occurred rather than one run-global counter.

use crate::ebpf::ringbuf::{EpochDelta, RingCursor};

use super::super::GappCore;

/// Per-epoch drain statistics (one entry per window in the live report).
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochStats {
    /// Epoch index (1-based, matching window numbering).
    pub epoch: u64,
    /// Ring activity attributed to this epoch.
    pub delta: EpochDelta,
}

/// Drains the shared kernel/user core once per epoch.
#[derive(Debug, Default)]
pub struct EpochConsumer {
    cursor: RingCursor,
    /// Epochs completed so far.
    pub epochs: u64,
    /// Total drops observed across all epochs (must equal the ring's
    /// global counter — the accounting identity the tests pin down).
    pub total_dropped: u64,
}

impl EpochConsumer {
    /// A consumer whose first epoch is charged everything since the
    /// ring was created (cursor starts at zero).
    pub fn new() -> EpochConsumer {
        EpochConsumer::default()
    }

    /// Drain everything currently buffered into the user-space probe and
    /// close the epoch: returns the ring activity since the previous
    /// call. Mid-epoch drains triggered by the kernel probe's
    /// drain-threshold are included (they belong to this epoch).
    pub fn drain_epoch(&mut self, core: &mut GappCore) -> EpochStats {
        core.drain();
        let delta = self.cursor.advance(&core.kernel.ring);
        self.epochs += 1;
        self.total_dropped += delta.dropped;
        EpochStats {
            epoch: self.epochs,
            delta,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::records::Record;
    use crate::gapp::GappConfig;
    use crate::runtime::AnalysisEngine;

    fn tiny_core(ring_capacity: usize) -> GappCore {
        let cfg = GappConfig {
            ring_capacity,
            // The consumer under test is the only drainer.
            drain_threshold: usize::MAX,
            ..Default::default()
        };
        GappCore {
            kernel: crate::gapp::probes::KernelProbes::new(cfg, 2).unwrap(),
            user: crate::gapp::userspace::UserProbe::new(AnalysisEngine::native()),
            drain_threshold: usize::MAX,
        }
    }

    fn sample(pid: u32, ip: u64) -> Record {
        Record::Sample { pid, ip }
    }

    #[test]
    fn drops_are_charged_to_their_epoch() {
        let mut core = tiny_core(4);
        let mut cons = EpochConsumer::new();
        // Epoch 1: overflow by 2.
        for i in 0..6 {
            core.kernel.ring.push(sample(1, i));
        }
        let e1 = cons.drain_epoch(&mut core);
        assert_eq!(e1.epoch, 1);
        assert_eq!(e1.delta.dropped, 2);
        assert_eq!(e1.delta.drained, 4);
        assert_eq!(core.kernel.ring.len(), 0);
        // Epoch 2: no overflow.
        core.kernel.ring.push(sample(1, 9));
        let e2 = cons.drain_epoch(&mut core);
        assert_eq!(e2.delta.dropped, 0);
        assert_eq!(e2.delta.drained, 1);
        // Epoch 3: overflow by 1.
        for i in 0..5 {
            core.kernel.ring.push(sample(1, 20 + i));
        }
        let e3 = cons.drain_epoch(&mut core);
        assert_eq!(e3.delta.dropped, 1);
        // Accounting identity: per-epoch drops sum to the global figure.
        assert_eq!(cons.total_dropped, core.kernel.ring.stats.dropped);
        assert_eq!(cons.epochs, 3);
        // Everything drained reached the user probe.
        assert_eq!(core.user.records_processed, 4 + 1 + 4);
    }

    #[test]
    fn quiet_epoch_reports_zero_deltas() {
        let mut core = tiny_core(8);
        let mut cons = EpochConsumer::new();
        core.kernel.ring.push(Record::SliceDiscard { pid: 3 });
        assert_eq!(cons.drain_epoch(&mut core).delta.drained, 1);
        let quiet = cons.drain_epoch(&mut core);
        assert_eq!(quiet.delta, crate::ebpf::EpochDelta::default());
        assert_eq!(cons.epochs, 2);
    }
}
