//! Per-window incremental aggregation.
//!
//! Each epoch window folds its critical slices into a
//! [`WindowAccumulator`]; closing the window yields a *snapshot* — a
//! `Vec<MergedPath>` whose aggregates are all associative (integer
//! CMetric femtoseconds, integer counts). [`merge_snapshots`] folds any
//! sequence of snapshots back into one merge that is bit-identical to a
//! single batch merge over the concatenated slice stream, which is what
//! lets the streaming analyzer report per-window *and* cumulative
//! results without ever retaining per-slice state.

use crate::gapp::userspace::{MergedPath, PathAccumulator, SliceEntry};

/// One window's aggregation state. Memory is O(distinct stack ids seen
/// this window); `snapshot()` resets it for the next window while
/// keeping allocations.
#[derive(Default)]
pub struct WindowAccumulator {
    acc: PathAccumulator,
    /// Slices fed this window (including ones excluded from the merge
    /// because their stack id was dropped at stack-map capacity).
    pub slices_in: u64,
}

impl WindowAccumulator {
    pub fn new() -> WindowAccumulator {
        WindowAccumulator::default()
    }

    /// Fold one critical slice, attributed to application `app`.
    pub fn add_slice(&mut self, s: &SliceEntry, app: u16) {
        self.acc.add_slice(s, app);
        self.slices_in += 1;
    }

    /// Distinct call paths merged so far this window.
    pub fn paths(&self) -> usize {
        self.acc.len()
    }

    /// Close the window: take its merged paths (first-seen order) and
    /// reset for the next window.
    pub fn snapshot(&mut self) -> Vec<MergedPath> {
        self.slices_in = 0;
        self.acc.take_paths()
    }
}

/// Fold window snapshots, in window order, into one merged path list.
/// The result is exactly — bit for bit — what a single batch merge over
/// the concatenated slice stream produces, because every per-path
/// aggregate is associative and first-seen order is preserved across
/// windows.
pub fn merge_snapshots<'a, I>(snapshots: I) -> Vec<MergedPath>
where
    I: IntoIterator<Item = &'a [MergedPath]>,
{
    let mut acc = PathAccumulator::new();
    for snap in snapshots {
        for p in snap {
            acc.merge_path(p);
        }
    }
    acc.take_paths()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::WaitKind;

    fn slice(i: u64) -> SliceEntry {
        SliceEntry {
            ts_id: i,
            pid: 1 + (i % 4) as u32,
            cm_ns: 5.0 + i as f64 * 1.375,
            threads_av: 1.0,
            stack_id: (i % 3) as u32,
            addrs: vec![0x100 + i % 5],
            from_stack_top: false,
            wait: WaitKind::Futex,
            woken_by: 0,
        }
    }

    #[test]
    fn snapshot_resets_for_the_next_window() {
        let mut w = WindowAccumulator::new();
        for i in 0..6 {
            w.add_slice(&slice(i), 0);
        }
        assert_eq!(w.slices_in, 6);
        assert_eq!(w.paths(), 3);
        let snap = w.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(w.slices_in, 0);
        assert_eq!(w.paths(), 0);
        // Next window starts clean and re-keys the same ids.
        w.add_slice(&slice(0), 0);
        assert_eq!(w.paths(), 1);
        assert_eq!(w.snapshot()[0].stack_id, 0);
    }

    #[test]
    fn merged_snapshots_equal_one_big_window() {
        let slices: Vec<SliceEntry> = (0..40).map(slice).collect();
        // One big window.
        let mut big = WindowAccumulator::new();
        for s in &slices {
            big.add_slice(s, 0);
        }
        let batch = big.snapshot();
        // Three ragged windows.
        let mut w = WindowAccumulator::new();
        let mut snaps: Vec<Vec<MergedPath>> = Vec::new();
        for (i, s) in slices.iter().enumerate() {
            w.add_slice(s, 0);
            if i == 7 || i == 23 {
                snaps.push(w.snapshot());
            }
        }
        snaps.push(w.snapshot());
        let merged = merge_snapshots(snaps.iter().map(|s| s.as_slice()));
        assert_eq!(merged.len(), batch.len());
        for (a, b) in batch.iter().zip(&merged) {
            assert_eq!(a.stack_id, b.stack_id);
            assert_eq!(a.cm_fs, b.cm_fs, "integer CMetric must match exactly");
            assert_eq!(a.slices, b.slices);
            assert_eq!(a.addr_freq, b.addr_freq);
        }
    }
}
