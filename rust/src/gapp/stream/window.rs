//! Per-window incremental aggregation.
//!
//! Each epoch window folds its critical slices into a
//! [`WindowAccumulator`]; closing the window yields a *snapshot* — a
//! `Vec<MergedPath>` whose aggregates are all associative (integer
//! CMetric femtoseconds, integer counts). [`merge_snapshots`] folds any
//! sequence of snapshots back into one merge that is bit-identical to a
//! single batch merge over the concatenated slice stream, which is what
//! lets the streaming analyzer report per-window *and* cumulative
//! results without ever retaining per-slice state.
//!
//! The same associativity carries the *spatial* split: under
//! `MergeStrategy::Tree` each ring shard folds its own sub-stream into
//! a shard-local accumulator, and [`merge_tree`] combines the S
//! partials pairwise (O(log S) depth). [`merge_pair`] reconciles the
//! output order through the paths' `first_seen` capture stamps, so the
//! tree result is byte-identical to the serial global-stream fold for
//! *every* tree shape (property-tested).

use crate::gapp::userspace::{MergedPath, PathAccumulator, SliceEntry};

/// One window's aggregation state. Memory is O(distinct stack ids seen
/// this window); `snapshot()` resets it for the next window while
/// keeping allocations.
#[derive(Default)]
pub struct WindowAccumulator {
    acc: PathAccumulator,
    /// Slices fed this window (including ones excluded from the merge
    /// because their stack id was dropped at stack-map capacity).
    pub slices_in: u64,
}

impl WindowAccumulator {
    pub fn new() -> WindowAccumulator {
        WindowAccumulator::default()
    }

    /// Fold one critical slice, attributed to application `app`.
    pub fn add_slice(&mut self, s: &SliceEntry, app: u16) {
        self.acc.add_slice(s, app);
        self.slices_in += 1;
    }

    /// Distinct call paths merged so far this window.
    pub fn paths(&self) -> usize {
        self.acc.len()
    }

    /// Close the window: take its merged paths (first-seen order) and
    /// reset for the next window.
    pub fn snapshot(&mut self) -> Vec<MergedPath> {
        self.slices_in = 0;
        self.acc.take_paths()
    }

    /// Fold another window accumulator into this one (leaving `o` reset
    /// for reuse) — `merge(a, b)` at the accumulator level. Snapshot
    /// merging ([`merge_pair`]) is what the tree driver uses; this
    /// exists for callers that combine live accumulators directly.
    /// Note the resulting insertion order is self-then-other: callers
    /// that need the canonical serial order must [`sort_canonical`] the
    /// eventual snapshot (merge_pair does this for you).
    pub fn merge_from(&mut self, o: &mut WindowAccumulator) {
        self.slices_in += o.slices_in;
        for p in &o.snapshot() {
            self.acc.merge_path(p);
        }
    }
}

/// Canonical snapshot order: ascending `first_seen` capture stamp. For
/// a fold over the globally-ordered stream this sort is a no-op (paths
/// are first seen in ascending stamp order); for a merge of shard
/// partials it *reconstructs* exactly that order, because a path's
/// merged `first_seen` is the stamp of its globally-earliest slice.
/// The `stack_id` tiebreak only matters for synthetic paths that never
/// absorbed a slice (`first_seen == u64::MAX`).
pub fn sort_canonical(paths: &mut [MergedPath]) {
    paths.sort_by_key(|p| (p.first_seen, p.stack_id));
}

/// Reusable scratch for the pairwise merges: a pool of
/// [`PathAccumulator`]s handed out per merge and recycled afterwards
/// (the lane-worker `LaneMsg::Feed` buffer-recycling pattern). A
/// long-running tree session that window-closes thousands of times
/// stops allocating a fresh accumulator — and its slot table — per
/// pair: `take_paths` resets an accumulator while keeping its
/// allocations, so a parked accumulator is ready for the next merge.
#[derive(Default)]
pub struct MergePool {
    accs: Vec<PathAccumulator>,
}

impl MergePool {
    pub fn new() -> MergePool {
        MergePool::default()
    }

    fn take(&mut self) -> PathAccumulator {
        self.accs.pop().unwrap_or_default()
    }

    fn put(&mut self, acc: PathAccumulator) {
        self.accs.push(acc);
    }

    /// Accumulators currently parked for reuse.
    pub fn parked(&self) -> usize {
        self.accs.len()
    }
}

/// The binary merge proper, into a caller-provided accumulator. The
/// accumulator is left reset (via `take_paths`) and reusable.
fn merge_pair_with(
    acc: &mut PathAccumulator,
    a: Vec<MergedPath>,
    b: Vec<MergedPath>,
) -> Vec<MergedPath> {
    for p in a.iter().chain(b.iter()) {
        acc.merge_path(p);
    }
    let mut out = acc.take_paths();
    sort_canonical(&mut out);
    out
}

/// Merge two partial snapshots into one canonical-order snapshot —
/// the binary node of the pairwise merge tree. Associative and
/// commutative: aggregates combine through [`MergedPath::merge_from`]
/// (all associative) and the order reconciles via [`sort_canonical`].
pub fn merge_pair(a: Vec<MergedPath>, b: Vec<MergedPath>) -> Vec<MergedPath> {
    merge_pair_with(&mut PathAccumulator::new(), a, b)
}

/// [`merge_pair`] with the scratch accumulator drawn from (and parked
/// back into) `pool` instead of freshly allocated.
pub fn merge_pair_pooled(
    a: Vec<MergedPath>,
    b: Vec<MergedPath>,
    pool: &mut MergePool,
) -> Vec<MergedPath> {
    let mut acc = pool.take();
    let out = merge_pair_with(&mut acc, a, b);
    pool.put(acc);
    out
}

/// Combine S shard-partial snapshots through a pairwise merge tree of
/// O(log S) depth: each round merges adjacent pairs until one snapshot
/// remains. The result equals the serial fold of the globally-ordered
/// stream byte for byte, for every tree shape — associativity plus
/// stamp-keyed order reconciliation (property-tested in
/// `rust/tests/streaming_golden.rs`).
pub fn merge_tree(parts: Vec<Vec<MergedPath>>) -> Vec<MergedPath> {
    merge_tree_pooled(parts, &mut MergePool::new())
}

/// [`merge_tree`] drawing its pairwise scratch from `pool`: one
/// accumulator serves every pair of every round, and a caller that
/// merges repeatedly (the window-close path, the tier folds) reuses it
/// across calls instead of allocating per pair.
pub fn merge_tree_pooled(
    mut parts: Vec<Vec<MergedPath>>,
    pool: &mut MergePool,
) -> Vec<MergedPath> {
    match parts.len() {
        0 => return Vec::new(),
        1 => {
            // A single shard still canonicalizes: its local fold order
            // is already ascending-stamp, so this is a no-op sort, but
            // the contract is "canonical order out" regardless of S.
            let mut only = parts.pop().unwrap();
            sort_canonical(&mut only);
            return only;
        }
        _ => {}
    }
    while parts.len() > 1 {
        let mut next = Vec::with_capacity((parts.len() + 1) / 2);
        let mut it = parts.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(merge_pair_pooled(a, b, pool)),
                None => next.push(a), // odd one out rides up a level
            }
        }
        parts = next;
    }
    parts.pop().unwrap()
}

/// [`merge_tree`] with the sibling merges of each round running on
/// scoped OS threads (`--lane-threads N`, N > 1). The tree shape is
/// identical — the same pairwise rounds, the same odd-one-rides-up
/// rule — and results are joined in spawn order, so the output is
/// byte-identical to the sequential tree (and therefore to the serial
/// fold) for every thread count. At most `max_threads` merges run
/// concurrently per wave; the waves of one round are processed in
/// order, which keeps determinism without any cross-thread ordering
/// protocol.
pub fn merge_tree_parallel(
    parts: Vec<Vec<MergedPath>>,
    max_threads: usize,
) -> Vec<MergedPath> {
    merge_tree_parallel_pooled(parts, max_threads, &mut MergePool::new())
}

/// [`merge_tree_parallel`] drawing per-thread scratch accumulators from
/// `pool`: each sibling merge of a wave takes one accumulator into its
/// thread and parks it back after the join, so a persistent caller-held
/// pool caps allocation at the peak wave width instead of one fresh
/// accumulator per pair per window.
pub fn merge_tree_parallel_pooled(
    mut parts: Vec<Vec<MergedPath>>,
    max_threads: usize,
    pool: &mut MergePool,
) -> Vec<MergedPath> {
    if max_threads <= 1 || parts.len() < 2 {
        return merge_tree_pooled(parts, pool);
    }
    while parts.len() > 1 {
        let mut pairs: Vec<(Vec<MergedPath>, Vec<MergedPath>)> = Vec::new();
        let mut carry: Option<Vec<MergedPath>> = None;
        let mut it = parts.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => pairs.push((a, b)),
                None => carry = Some(a), // odd one out rides up a level
            }
        }
        let mut next: Vec<Vec<MergedPath>> = Vec::with_capacity(pairs.len() + 1);
        let mut waves = pairs.into_iter();
        loop {
            let wave: Vec<(Vec<MergedPath>, Vec<MergedPath>)> =
                waves.by_ref().take(max_threads).collect();
            if wave.is_empty() {
                break;
            }
            std::thread::scope(|s| {
                let handles: Vec<_> = wave
                    .into_iter()
                    .map(|(a, b)| {
                        let mut acc = pool.take();
                        s.spawn(move || {
                            let out = merge_pair_with(&mut acc, a, b);
                            (out, acc)
                        })
                    })
                    .collect();
                for h in handles {
                    let (out, acc) = h.join().expect("sibling merge panicked");
                    next.push(out);
                    pool.put(acc);
                }
            });
        }
        if let Some(c) = carry {
            next.push(c);
        }
        parts = next;
    }
    match parts.pop() {
        // One input never entered the pair loop: canonicalize like
        // merge_tree's single-snapshot arm does.
        Some(mut only) => {
            sort_canonical(&mut only);
            only
        }
        None => Vec::new(),
    }
}

/// Fold window snapshots, in window order, into one merged path list.
/// The result is exactly — bit for bit — what a single batch merge over
/// the concatenated slice stream produces, because every per-path
/// aggregate is associative and first-seen order is preserved across
/// windows.
pub fn merge_snapshots<'a, I>(snapshots: I) -> Vec<MergedPath>
where
    I: IntoIterator<Item = &'a [MergedPath]>,
{
    let mut acc = PathAccumulator::new();
    for snap in snapshots {
        for p in snap {
            acc.merge_path(p);
        }
    }
    acc.take_paths()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkernel::WaitKind;

    fn slice(i: u64) -> SliceEntry {
        SliceEntry {
            ts_id: i,
            pid: 1 + (i % 4) as u32,
            cm_ns: 5.0 + i as f64 * 1.375,
            threads_av: 1.0,
            stack_id: (i % 3) as u32,
            addrs: vec![0x100 + i % 5],
            from_stack_top: false,
            wait: WaitKind::Futex,
            woken_by: 0,
        }
    }

    #[test]
    fn snapshot_resets_for_the_next_window() {
        let mut w = WindowAccumulator::new();
        for i in 0..6 {
            w.add_slice(&slice(i), 0);
        }
        assert_eq!(w.slices_in, 6);
        assert_eq!(w.paths(), 3);
        let snap = w.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(w.slices_in, 0);
        assert_eq!(w.paths(), 0);
        // Next window starts clean and re-keys the same ids.
        w.add_slice(&slice(0), 0);
        assert_eq!(w.paths(), 1);
        assert_eq!(w.snapshot()[0].stack_id, 0);
    }

    /// Compare two snapshots field by field (the byte-identity oracle
    /// minus rendering).
    fn assert_snapshots_equal(a: &[MergedPath], b: &[MergedPath]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.stack_id, y.stack_id, "path order diverged");
            assert_eq!(x.cm_fs, y.cm_fs);
            assert_eq!(x.first_seen, y.first_seen);
            assert_eq!(x.slices, y.slices);
            assert_eq!(x.addr_freq, y.addr_freq);
            assert_eq!(x.wait_hist, y.wait_hist);
            assert_eq!(x.wakers, y.wakers);
            assert_eq!(x.app_slices, y.app_slices);
        }
    }

    #[test]
    fn shard_partials_merge_tree_equals_the_serial_fold() {
        // Deal one stream onto 4 "shards" round-robin (each preserving
        // relative order like a per-CPU FIFO), fold each shard locally,
        // and tree-merge the partials: must equal the serial fold of
        // the stream in capture order.
        let slices: Vec<SliceEntry> = (0..48).map(slice).collect();
        let mut serial = WindowAccumulator::new();
        for s in &slices {
            serial.add_slice(s, 0);
        }
        let serial_snap = serial.snapshot();

        let mut shards: Vec<WindowAccumulator> =
            (0..4).map(|_| WindowAccumulator::new()).collect();
        for (i, s) in slices.iter().enumerate() {
            shards[i % 4].add_slice(s, 0);
        }
        let parts: Vec<Vec<MergedPath>> =
            shards.iter_mut().map(|w| w.snapshot()).collect();
        let merged = merge_tree(parts);
        assert_snapshots_equal(&serial_snap, &merged);
        // A serial fold is already in canonical (ascending-stamp) order.
        let mut resorted = serial_snap.clone();
        sort_canonical(&mut resorted);
        assert_snapshots_equal(&serial_snap, &resorted);
    }

    #[test]
    fn merge_pair_is_commutative_and_tree_shape_invariant() {
        let slices: Vec<SliceEntry> = (0..30).map(slice).collect();
        let mut parts: Vec<Vec<MergedPath>> = Vec::new();
        let mut w = WindowAccumulator::new();
        for (i, s) in slices.iter().enumerate() {
            w.add_slice(s, 0);
            if i % 7 == 6 {
                parts.push(w.snapshot());
            }
        }
        parts.push(w.snapshot());
        assert!(parts.len() >= 4);
        let balanced = merge_tree(parts.clone());
        // Left-deep fold, and the same with every pair flipped.
        let mut left = parts[0].clone();
        for p in &parts[1..] {
            left = merge_pair(left, p.clone());
        }
        let mut flipped = parts[0].clone();
        for p in &parts[1..] {
            flipped = merge_pair(p.clone(), flipped);
        }
        assert_snapshots_equal(&balanced, &left);
        assert_snapshots_equal(&balanced, &flipped);
    }

    #[test]
    fn accumulator_merge_from_drains_the_source() {
        let mut a = WindowAccumulator::new();
        let mut b = WindowAccumulator::new();
        for i in 0..6 {
            a.add_slice(&slice(i), 0);
        }
        for i in 6..10 {
            b.add_slice(&slice(i), 0);
        }
        a.merge_from(&mut b);
        assert_eq!(a.slices_in, 10);
        assert_eq!(b.slices_in, 0);
        assert_eq!(b.paths(), 0);
        let snap = a.snapshot();
        let mut serial = WindowAccumulator::new();
        for i in 0..10 {
            serial.add_slice(&slice(i), 0);
        }
        assert_snapshots_equal(&serial.snapshot(), &snap);
    }

    #[test]
    fn parallel_merge_tree_is_byte_identical_at_every_thread_count() {
        let slices: Vec<SliceEntry> = (0..60).map(slice).collect();
        for nparts in [1usize, 2, 3, 4, 5, 8] {
            let mut shards: Vec<WindowAccumulator> =
                (0..nparts).map(|_| WindowAccumulator::new()).collect();
            for (i, s) in slices.iter().enumerate() {
                shards[i % nparts].add_slice(s, 0);
            }
            let parts: Vec<Vec<MergedPath>> =
                shards.iter_mut().map(|w| w.snapshot()).collect();
            let sequential = merge_tree(parts.clone());
            for threads in [1usize, 2, 4, 7] {
                let parallel = merge_tree_parallel(parts.clone(), threads);
                assert_snapshots_equal(&sequential, &parallel);
            }
        }
        assert!(merge_tree_parallel(Vec::new(), 4).is_empty());
    }

    #[test]
    fn pooled_merges_are_byte_identical_and_recycle_scratch() {
        let slices: Vec<SliceEntry> = (0..60).map(slice).collect();
        let mut shards: Vec<WindowAccumulator> =
            (0..5).map(|_| WindowAccumulator::new()).collect();
        for (i, s) in slices.iter().enumerate() {
            shards[i % 5].add_slice(s, 0);
        }
        let parts: Vec<Vec<MergedPath>> =
            shards.iter_mut().map(|w| w.snapshot()).collect();
        let plain = merge_tree(parts.clone());
        let mut pool = MergePool::new();
        // Repeated merges through one pool: identical output every
        // time (a recycled accumulator must behave like a fresh one)…
        for round in 0..3 {
            let pooled = merge_tree_pooled(parts.clone(), &mut pool);
            assert_snapshots_equal(&plain, &pooled);
            assert!(pool.parked() >= 1, "round {round}: scratch must park");
            for threads in [2usize, 4] {
                let par =
                    merge_tree_parallel_pooled(parts.clone(), threads, &mut pool);
                assert_snapshots_equal(&plain, &par);
            }
        }
        // …and the pool never grows past the peak concurrent demand
        // (sequential tree: 1; parallel waves: at most the wave width).
        assert!(pool.parked() <= 4, "parked {}", pool.parked());
    }

    #[test]
    fn merge_tree_handles_empty_and_single_inputs() {
        assert!(merge_tree(Vec::new()).is_empty());
        assert!(merge_tree(vec![Vec::new(), Vec::new()]).is_empty());
        let mut w = WindowAccumulator::new();
        w.add_slice(&slice(1), 0);
        w.add_slice(&slice(2), 0);
        let snap = w.snapshot();
        let via_tree = merge_tree(vec![snap.clone()]);
        assert_snapshots_equal(&snap, &via_tree);
    }

    #[test]
    fn merged_snapshots_equal_one_big_window() {
        let slices: Vec<SliceEntry> = (0..40).map(slice).collect();
        // One big window.
        let mut big = WindowAccumulator::new();
        for s in &slices {
            big.add_slice(s, 0);
        }
        let batch = big.snapshot();
        // Three ragged windows.
        let mut w = WindowAccumulator::new();
        let mut snaps: Vec<Vec<MergedPath>> = Vec::new();
        for (i, s) in slices.iter().enumerate() {
            w.add_slice(s, 0);
            if i == 7 || i == 23 {
                snaps.push(w.snapshot());
            }
        }
        snaps.push(w.snapshot());
        let merged = merge_snapshots(snaps.iter().map(|s| s.as_slice()));
        assert_eq!(merged.len(), batch.len());
        for (a, b) in batch.iter().zip(&merged) {
            assert_eq!(a.stack_id, b.stack_id);
            assert_eq!(a.cm_fs, b.cm_fs, "integer CMetric must match exactly");
            assert_eq!(a.slices, b.slices);
            assert_eq!(a.addr_freq, b.addr_freq);
        }
    }
}
