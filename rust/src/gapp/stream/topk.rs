//! Bounded top-K tracking — a *space-saving* sketch (Metwally et al.,
//! "Efficient computation of frequent and top-k elements in data
//! streams").
//!
//! The streaming analyzer must report cumulative top-K bottlenecks over
//! an unbounded run while holding O(K) state, no matter how many
//! distinct call paths flow past (stack-map LRU recycling means the id
//! space itself can churn). The sketch keeps `cap` counters; a new key
//! arriving at capacity seizes the minimum counter, inheriting its
//! count as the overestimation error. Guarantees: every tracked count
//! is an upper bound on the true count, off by at most its recorded
//! `err`, and any key whose true count exceeds the minimum counter is
//! guaranteed to be tracked.

use std::hash::Hash;

use crate::util::FxHashMap;

/// One tracked counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Counter {
    count: u64,
    /// Maximum overestimation inherited when the key seized a slot.
    err: u64,
}

/// Space-saving top-K sketch over keys of type `K`.
///
/// `K: Ord` is required so minimum-victim selection and reporting break
/// ties deterministically (reports must not depend on map iteration
/// order).
#[derive(Clone, Debug)]
pub struct SpaceSaving<K: Eq + Hash + Copy + Ord> {
    cap: usize,
    counters: FxHashMap<K, Counter>,
}

impl<K: Eq + Hash + Copy + Ord> SpaceSaving<K> {
    /// A sketch tracking at most `cap` keys (`cap >= 1`).
    pub fn new(cap: usize) -> SpaceSaving<K> {
        SpaceSaving {
            cap: cap.max(1),
            counters: FxHashMap::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.counters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
    }

    /// Add `weight` to `key` (weighted increments: the analyzer feeds
    /// per-window CMetric femtoseconds, not unit counts).
    pub fn add(&mut self, key: K, weight: u64) {
        if let Some(c) = self.counters.get_mut(&key) {
            c.count += weight;
            return;
        }
        if self.counters.len() < self.cap {
            self.counters.insert(key, Counter { count: weight, err: 0 });
            return;
        }
        // Seize the minimum counter (ties: smallest key — deterministic).
        let (&vk, &vc) = self
            .counters
            .iter()
            .min_by(|(ka, ca), (kb, cb)| ca.count.cmp(&cb.count).then(ka.cmp(kb)))
            .expect("cap >= 1");
        self.counters.remove(&vk);
        self.counters.insert(
            key,
            Counter {
                count: vc.count + weight,
                err: vc.count,
            },
        );
    }

    /// Top `n` keys as `(key, count_upper_bound, max_overestimate)`,
    /// descending by count (ties: smallest key first).
    pub fn top(&self, n: usize) -> Vec<(K, u64, u64)> {
        let mut v: Vec<(K, u64, u64)> = self
            .counters
            .iter()
            .map(|(k, c)| (*k, c.count, c.err))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_below_capacity() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(8);
        for (k, w) in [(1u32, 10u64), (2, 5), (1, 7), (3, 1)] {
            s.add(k, w);
        }
        assert_eq!(s.top(3), vec![(1, 17, 0), (2, 5, 0), (3, 1, 0)]);
    }

    #[test]
    fn heavy_hitters_survive_at_capacity() {
        // Two heavy keys plus a stream of distinct light keys through a
        // 4-slot sketch: the heavy keys must stay tracked and ranked on
        // top, with counts bounded by true + err.
        let mut s: SpaceSaving<u32> = SpaceSaving::new(4);
        for i in 0..200u32 {
            s.add(1000, 50);
            s.add(2000, 30);
            s.add(i, 1); // light churn
        }
        let top = s.top(2);
        assert_eq!(top[0].0, 1000);
        assert_eq!(top[1].0, 2000);
        for (_, count, err) in &s.top(4) {
            assert!(count >= err, "count is an upper bound: {count} >= {err}");
        }
        // Upper-bound property for the heavy keys.
        assert!(top[0].1 >= 200 * 50);
        assert!(top[1].1 >= 200 * 30);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn eviction_inherits_minimum_count_as_error() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(2);
        s.add(1, 10);
        s.add(2, 3);
        s.add(3, 1); // seizes key 2's slot (min count 3)
        let top = s.top(2);
        assert_eq!(top[0], (1, 10, 0));
        assert_eq!(top[1], (3, 4, 3)); // 3 inherited + 1 own, err 3
    }

    #[test]
    fn min_victim_tie_breaks_by_smallest_key() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(2);
        s.add(7, 5);
        s.add(3, 5);
        s.add(9, 1); // tie on count 5 → key 3 is the victim
        let keys: Vec<u32> = s.top(2).into_iter().map(|(k, _, _)| k).collect();
        assert!(keys.contains(&7) && keys.contains(&9), "{keys:?}");
    }
}
