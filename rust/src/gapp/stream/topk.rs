//! Bounded top-K tracking — a *space-saving* sketch (Metwally et al.,
//! "Efficient computation of frequent and top-k elements in data
//! streams").
//!
//! The streaming analyzer must report cumulative top-K bottlenecks over
//! an unbounded run while holding O(K) state, no matter how many
//! distinct call paths flow past (stack-map LRU recycling means the id
//! space itself can churn). The sketch keeps `cap` counters; a new key
//! arriving at capacity seizes the minimum counter, inheriting its
//! count as the overestimation error. Guarantees: every tracked count
//! is an upper bound on the true count, off by at most its recorded
//! `err`, and any key whose true count exceeds the minimum counter is
//! guaranteed to be tracked.
//!
//! Eviction is O(log cap) amortized: minimum-victim selection goes
//! through a lazy-deletion min-heap over `(count, key)` instead of a
//! full O(cap) scan per insert, so churn-heavy streams (many distinct
//! light keys) no longer degrade to O(n·cap). Stale heap entries are
//! skipped on pop and compacted away when they outnumber the live
//! counters by 8×.
//!
//! Weights accumulate with saturating adds (debug builds assert):
//! the analyzer feeds integer-femtosecond CMetric weights, and at
//! 1e15 fs/s a long multi-app run can reach the top of `u64` — a wrap
//! there would silently reorder the top-K.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::hash::Hash;

use crate::util::{FxHashMap, sat_add};

/// One tracked counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Counter {
    count: u64,
    /// Maximum overestimation inherited when the key seized a slot.
    err: u64,
    /// Incarnation of this key's map entry: heap entries from before an
    /// eviction recycled the key are recognized as stale by it.
    gen: u64,
}

/// Space-saving top-K sketch over keys of type `K`.
///
/// `K: Ord` is required so minimum-victim selection and reporting break
/// ties deterministically (reports must not depend on map iteration
/// order).
#[derive(Clone, Debug)]
pub struct SpaceSaving<K: Eq + Hash + Copy + Ord> {
    cap: usize,
    counters: FxHashMap<K, Counter>,
    /// Lazy min-heap over `(count, key, gen)`. Every counter mutation
    /// pushes its latest state; an entry is live iff it matches the
    /// map's current `(count, gen)` for its key. The heap top therefore
    /// yields the true minimum counter, ties broken by smallest key —
    /// the same victim the old full scan picked.
    heap: BinaryHeap<Reverse<(u64, K, u64)>>,
    next_gen: u64,
}

impl<K: Eq + Hash + Copy + Ord> SpaceSaving<K> {
    /// A sketch tracking at most `cap` keys. `cap = 0` is rejected
    /// loudly (it used to be silently bumped to 1): user-facing knobs
    /// validate earlier with a real error message.
    pub fn new(cap: usize) -> SpaceSaving<K> {
        assert!(cap >= 1, "SpaceSaving capacity must be >= 1");
        SpaceSaving {
            cap,
            counters: FxHashMap::default(),
            heap: BinaryHeap::new(),
            next_gen: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.counters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
    }

    /// Add `weight` to `key` (weighted increments: the analyzer feeds
    /// per-window CMetric femtoseconds, not unit counts).
    pub fn add(&mut self, key: K, weight: u64) {
        if let Some(c) = self.counters.get_mut(&key) {
            c.count = sat_add(c.count, weight);
            self.heap.push(Reverse((c.count, key, c.gen)));
            self.maybe_compact();
            return;
        }
        if self.counters.len() < self.cap {
            self.next_gen += 1;
            let c = Counter {
                count: weight,
                err: 0,
                gen: self.next_gen,
            };
            self.counters.insert(key, c);
            self.heap.push(Reverse((weight, key, c.gen)));
            self.maybe_compact();
            return;
        }
        // Seize the minimum counter (ties: smallest key — deterministic).
        // Stale heap entries (superseded counts, evicted keys) are
        // popped and discarded; every live counter always has its
        // latest state in the heap, so this cannot run dry.
        let (vk, vcount) = loop {
            let Reverse((cnt, k, g)) =
                self.heap.pop().expect("live counters always have heap entries");
            match self.counters.get(&k) {
                Some(c) if c.gen == g && c.count == cnt => break (k, cnt),
                _ => continue,
            }
        };
        self.counters.remove(&vk);
        self.next_gen += 1;
        let c = Counter {
            count: sat_add(vcount, weight),
            err: vcount,
            gen: self.next_gen,
        };
        self.counters.insert(key, c);
        self.heap.push(Reverse((c.count, key, c.gen)));
        self.maybe_compact();
    }

    /// Rebuild the heap from live counters when stale entries dominate
    /// (amortized O(1) per add; bounds heap memory at O(cap)).
    fn maybe_compact(&mut self) {
        if self.heap.len() > (self.cap * 8).max(64) {
            self.heap.clear();
            self.heap.extend(
                self.counters
                    .iter()
                    .map(|(k, c)| Reverse((c.count, *k, c.gen))),
            );
        }
    }

    /// Serialize the sketch: `(capacity, counters)` with counters as
    /// `(key, count, err)` sorted ascending by key — a deterministic,
    /// order-independent snapshot for the checkpoint writer. The heap
    /// and generation counters are reconstruction details, not state:
    /// victim selection depends only on the live `(count, key)` pairs,
    /// so [`from_parts`] rebuilds them fresh.
    ///
    /// [`from_parts`]: SpaceSaving::from_parts
    pub fn export(&self) -> (usize, Vec<(K, u64, u64)>) {
        let mut v: Vec<(K, u64, u64)> = self
            .counters
            .iter()
            .map(|(k, c)| (*k, c.count, c.err))
            .collect();
        v.sort_by_key(|e| e.0);
        (self.cap, v)
    }

    /// Rebuild a sketch from an [`export`] snapshot. Errors (instead of
    /// panicking) on impossible shapes — more entries than capacity, a
    /// duplicated key — so a corrupt checkpoint surfaces as a message,
    /// not an assertion failure deep in the sketch.
    ///
    /// [`export`]: SpaceSaving::export
    pub fn from_parts(cap: usize, entries: &[(K, u64, u64)]) -> Result<SpaceSaving<K>, String> {
        if cap < 1 {
            return Err("sketch capacity must be >= 1".to_string());
        }
        if entries.len() > cap {
            return Err(format!(
                "sketch has {} counters but capacity {cap}",
                entries.len()
            ));
        }
        let mut s = SpaceSaving::new(cap);
        for &(k, count, err) in entries {
            s.next_gen += 1;
            let c = Counter {
                count,
                err,
                gen: s.next_gen,
            };
            if s.counters.insert(k, c).is_some() {
                return Err("sketch snapshot repeats a key".to_string());
            }
            s.heap.push(Reverse((count, k, c.gen)));
        }
        Ok(s)
    }

    /// Top `n` keys as `(key, count_upper_bound, max_overestimate)`,
    /// descending by count (ties: smallest key first).
    pub fn top(&self, n: usize) -> Vec<(K, u64, u64)> {
        let mut v: Vec<(K, u64, u64)> = self
            .counters
            .iter()
            .map(|(k, c)| (*k, c.count, c.err))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }
}

/// Time-decayed space-saving sketch (`--decay-half-life-us`): counts
/// halve every `half_life_ns` of *simulated* time, so its top-K answers
/// "hot recently" where [`SpaceSaving`] answers "hot ever". Same
/// algorithm, same lazy-deletion heap; decay is applied lazily, once
/// per [`advance_to`] call (the windowed driver advances at each window
/// close), by scaling every live counter by `0.5^(Δt / half_life)` in
/// one O(cap) pass and rebuilding the heap from the rescaled counters.
///
/// The space-saving guarantees carry over relative to the *decayed*
/// stream: every tracked count upper-bounds the key's decayed true
/// weight, off by at most its (equally decayed) `err`. Decayed values
/// floor to integers, so a key untouched for many half-lives decays to
/// zero and becomes the natural next victim.
///
/// Determinism: the scale factor is computed in f64 (IEEE semantics,
/// bit-stable for a given binary) and floored back to `u64`, and
/// [`export`]/[`from_parts`] snapshot the decayed counts themselves —
/// a restored sketch never re-derives a decay it already applied.
///
/// [`advance_to`]: DecayedSpaceSaving::advance_to
/// [`export`]: DecayedSpaceSaving::export
/// [`from_parts`]: DecayedSpaceSaving::from_parts
#[derive(Clone, Debug)]
pub struct DecayedSpaceSaving<K: Eq + Hash + Copy + Ord> {
    inner: SpaceSaving<K>,
    half_life_ns: u64,
    /// Simulated timestamp the counters are currently decayed to.
    now_ns: u64,
}

impl<K: Eq + Hash + Copy + Ord> DecayedSpaceSaving<K> {
    /// A decayed sketch tracking at most `cap` keys with the given
    /// half-life (simulated ns). Both knobs validate earlier on the
    /// user-facing path; the asserts catch library misuse.
    pub fn new(cap: usize, half_life_ns: u64) -> DecayedSpaceSaving<K> {
        assert!(half_life_ns >= 1, "decay half-life must be >= 1 ns");
        DecayedSpaceSaving {
            inner: SpaceSaving::new(cap),
            half_life_ns,
            now_ns: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    pub fn half_life_ns(&self) -> u64 {
        self.half_life_ns
    }

    /// Timestamp the counters are decayed to (last `advance_to`).
    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    /// Decay every counter to `now_ns`. Monotonic: a stale timestamp
    /// (at or before the current decay point) is a no-op, so replayed
    /// or widened windows cannot decay twice.
    pub fn advance_to(&mut self, now_ns: u64) {
        if now_ns <= self.now_ns {
            return;
        }
        let dt = (now_ns - self.now_ns) as f64;
        self.now_ns = now_ns;
        let factor = (-(dt / self.half_life_ns as f64)).exp2();
        for c in self.inner.counters.values_mut() {
            c.count = (c.count as f64 * factor) as u64;
            c.err = (c.err as f64 * factor) as u64;
        }
        // Every counter changed at once: rebuild the heap rather than
        // pushing `cap` now-stale entries beside the old ones.
        self.inner.heap.clear();
        let counters = &self.inner.counters;
        self.inner
            .heap
            .extend(counters.iter().map(|(k, c)| Reverse((c.count, *k, c.gen))));
    }

    /// Add `weight` to `key` at the current decay point (call
    /// [`advance_to`] first to decay up to the observation time).
    ///
    /// [`advance_to`]: DecayedSpaceSaving::advance_to
    pub fn add(&mut self, key: K, weight: u64) {
        self.inner.add(key, weight);
    }

    /// Top `n` keys by decayed count (see [`SpaceSaving::top`]).
    pub fn top(&self, n: usize) -> Vec<(K, u64, u64)> {
        self.inner.top(n)
    }

    /// Serialize: `(capacity, decayed-to timestamp, counters)` with the
    /// counters key-sorted (see [`SpaceSaving::export`]). The half-life
    /// is a configuration knob, not state — the checkpoint fingerprint
    /// carries it.
    pub fn export(&self) -> (usize, u64, Vec<(K, u64, u64)>) {
        let (cap, entries) = self.inner.export();
        (cap, self.now_ns, entries)
    }

    /// Rebuild from an [`export`] snapshot; errors loudly on impossible
    /// shapes like [`SpaceSaving::from_parts`].
    ///
    /// [`export`]: DecayedSpaceSaving::export
    pub fn from_parts(
        cap: usize,
        half_life_ns: u64,
        now_ns: u64,
        entries: &[(K, u64, u64)],
    ) -> Result<DecayedSpaceSaving<K>, String> {
        if half_life_ns < 1 {
            return Err("decay half-life must be >= 1 ns".to_string());
        }
        Ok(DecayedSpaceSaving {
            inner: SpaceSaving::from_parts(cap, entries)?,
            half_life_ns,
            now_ns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn exact_below_capacity() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(8);
        for (k, w) in [(1u32, 10u64), (2, 5), (1, 7), (3, 1)] {
            s.add(k, w);
        }
        assert_eq!(s.top(3), vec![(1, 17, 0), (2, 5, 0), (3, 1, 0)]);
    }

    #[test]
    fn heavy_hitters_survive_at_capacity() {
        // Two heavy keys plus a stream of distinct light keys through a
        // 4-slot sketch: the heavy keys must stay tracked and ranked on
        // top, with counts bounded by true + err.
        let mut s: SpaceSaving<u32> = SpaceSaving::new(4);
        for i in 0..200u32 {
            s.add(1000, 50);
            s.add(2000, 30);
            s.add(i, 1); // light churn
        }
        let top = s.top(2);
        assert_eq!(top[0].0, 1000);
        assert_eq!(top[1].0, 2000);
        for (_, count, err) in &s.top(4) {
            assert!(count >= err, "count is an upper bound: {count} >= {err}");
        }
        // Upper-bound property for the heavy keys.
        assert!(top[0].1 >= 200 * 50);
        assert!(top[1].1 >= 200 * 30);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn eviction_inherits_minimum_count_as_error() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(2);
        s.add(1, 10);
        s.add(2, 3);
        s.add(3, 1); // seizes key 2's slot (min count 3)
        let top = s.top(2);
        assert_eq!(top[0], (1, 10, 0));
        assert_eq!(top[1], (3, 4, 3)); // 3 inherited + 1 own, err 3
    }

    #[test]
    fn min_victim_tie_breaks_by_smallest_key() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(2);
        s.add(7, 5);
        s.add(3, 5);
        s.add(9, 1); // tie on count 5 → key 3 is the victim
        let keys: Vec<u32> = s.top(2).into_iter().map(|(k, _, _)| k).collect();
        assert!(keys.contains(&7) && keys.contains(&9), "{keys:?}");
    }

    #[test]
    #[should_panic(expected = "capacity must be >= 1")]
    fn zero_capacity_sketch_is_rejected() {
        let _ = SpaceSaving::<u32>::new(0);
    }

    #[test]
    fn export_restore_round_trip_preserves_future_behaviour() {
        // A restored sketch must not just report the same top-K: it must
        // keep *behaving* identically — same victims, same inherited
        // errors — under any continuation stream.
        let mut rng = Prng::new(0x5EED);
        let mut original: SpaceSaving<u32> = SpaceSaving::new(5);
        for _ in 0..300 {
            original.add(rng.below(32) as u32, 1 + rng.below(9));
        }
        let (cap, entries) = original.export();
        assert_eq!(cap, 5);
        assert!(entries.windows(2).all(|w| w[0].0 < w[1].0), "sorted by key");
        let mut restored = SpaceSaving::from_parts(cap, &entries).unwrap();
        assert_eq!(restored.top(5), original.top(5));
        for _ in 0..300 {
            let (k, w) = (rng.below(32) as u32, 1 + rng.below(9));
            original.add(k, w);
            restored.add(k, w);
        }
        assert_eq!(restored.top(5), original.top(5));
        assert_eq!(restored.export(), original.export());
    }

    #[test]
    fn from_parts_rejects_impossible_snapshots() {
        let err = SpaceSaving::<u32>::from_parts(0, &[]).unwrap_err();
        assert!(err.contains(">= 1"), "{err}");
        let err = SpaceSaving::<u32>::from_parts(1, &[(1, 2, 0), (2, 3, 0)]).unwrap_err();
        assert!(err.contains("capacity"), "{err}");
        let err = SpaceSaving::<u32>::from_parts(4, &[(1, 2, 0), (1, 3, 0)]).unwrap_err();
        assert!(err.contains("repeats"), "{err}");
    }

    /// The old implementation, verbatim in behaviour: O(cap) min scan
    /// per eviction. The heap-backed version must pick bit-identical
    /// victims (including the smallest-key tie-break) on any stream.
    struct NaiveRef {
        cap: usize,
        counters: Vec<(u32, u64, u64)>, // (key, count, err)
    }

    impl NaiveRef {
        fn add(&mut self, key: u32, weight: u64) {
            if let Some(c) = self.counters.iter_mut().find(|c| c.0 == key) {
                c.1 += weight;
                return;
            }
            if self.counters.len() < self.cap {
                self.counters.push((key, weight, 0));
                return;
            }
            let vi = (0..self.counters.len())
                .min_by(|&a, &b| {
                    let (ka, ca) = (self.counters[a].0, self.counters[a].1);
                    let (kb, cb) = (self.counters[b].0, self.counters[b].1);
                    ca.cmp(&cb).then(ka.cmp(&kb))
                })
                .unwrap();
            let vc = self.counters[vi].1;
            self.counters[vi] = (key, vc + weight, vc);
        }

        fn top(&self) -> Vec<(u32, u64, u64)> {
            let mut v = self.counters.clone();
            v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            v
        }
    }

    #[test]
    fn indexed_eviction_matches_the_naive_min_scan_under_churn() {
        // Churn-heavy random streams: the lazy-heap eviction must stay
        // exactly equivalent to the full-scan reference, compaction and
        // re-insertion of previously evicted keys included.
        let mut rng = Prng::new(0xD1CE);
        for case in 0..20 {
            let cap = 1 + rng.pick(8);
            let mut fast: SpaceSaving<u32> = SpaceSaving::new(cap);
            let mut slow = NaiveRef {
                cap,
                counters: Vec::new(),
            };
            for _ in 0..400 {
                // Small key space → heavy reuse of evicted keys.
                let key = rng.below(24) as u32;
                let w = 1 + rng.below(9);
                fast.add(key, w);
                slow.add(key, w);
            }
            assert_eq!(
                fast.top(cap),
                slow.top(),
                "case {case} (cap {cap}) diverged from the reference"
            );
            assert!(
                fast.heap.len() <= (cap * 8).max(64) + 1,
                "stale entries must be compacted away"
            );
        }
    }

    #[test]
    fn decay_halves_counts_per_half_life_and_reranks() {
        let mut s: DecayedSpaceSaving<u32> = DecayedSpaceSaving::new(4, 1_000);
        s.add(1, 800); // hot early…
        s.advance_to(2_000); // …then idle for two half-lives: 800 → 200
        assert_eq!(s.top(1), vec![(1, 200, 0)]);
        s.add(2, 300); // a newly hot key overtakes the decayed one
        let top = s.top(2);
        assert_eq!(top[0], (2, 300, 0));
        assert_eq!(top[1], (1, 200, 0));
        // Monotonic: a stale or repeated timestamp is a no-op.
        s.advance_to(2_000);
        s.advance_to(1_500);
        assert_eq!(s.top(2), top);
        // A key idle long enough decays to zero and is the next victim.
        s.advance_to(2_000 + 1_000 * 64);
        assert_eq!(s.top(2), vec![(1, 0, 0), (2, 0, 0)]);
    }

    #[test]
    fn decayed_export_restore_preserves_future_behaviour() {
        let mut rng = Prng::new(0xFADE);
        let mut original: DecayedSpaceSaving<u32> = DecayedSpaceSaving::new(5, 10_000);
        let mut now = 0u64;
        for _ in 0..200 {
            now += rng.below(5_000);
            original.advance_to(now);
            original.add(rng.below(32) as u32, 1 + rng.below(9));
        }
        let (cap, snap_now, entries) = original.export();
        assert_eq!(cap, 5);
        assert_eq!(snap_now, now);
        let mut restored =
            DecayedSpaceSaving::from_parts(cap, 10_000, snap_now, &entries).unwrap();
        assert_eq!(restored.top(5), original.top(5));
        // Identical continuation: same decays, same victims.
        for _ in 0..200 {
            now += rng.below(5_000);
            let (k, w) = (rng.below(32) as u32, 1 + rng.below(9));
            original.advance_to(now);
            restored.advance_to(now);
            original.add(k, w);
            restored.add(k, w);
        }
        assert_eq!(restored.export(), original.export());
        // Impossible shapes stay loud errors.
        let err =
            DecayedSpaceSaving::<u32>::from_parts(1, 0, 0, &[]).unwrap_err();
        assert!(err.contains("half-life"), "{err}");
    }

    #[test]
    fn decayed_heap_stays_consistent_across_advances() {
        // Eviction right after a decay must pick the decayed minimum:
        // the heap is rebuilt from the rescaled counters, so a stale
        // pre-decay entry can never elect the victim.
        let mut s: DecayedSpaceSaving<u32> = DecayedSpaceSaving::new(2, 1_000);
        s.add(1, 1_000); // will decay to 125
        s.add(2, 400); // will decay to 50 — the post-decay minimum
        s.advance_to(3_000);
        s.add(3, 10); // must seize key 2 (min 50), inheriting err 50
        let top = s.top(2);
        assert_eq!(top[0], (1, 125, 0));
        assert_eq!(top[1], (3, 60, 50));
    }

    #[test]
    fn near_max_weights_never_wrap_the_ranking() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(4);
        // Exact accumulation at the extreme end of u64: no wrap.
        s.add(1, u64::MAX - 10);
        s.add(2, 100);
        assert_eq!(s.top(2), vec![(1, u64::MAX - 10, 0), (2, 100, 0)]);
        s.add(1, 10); // lands exactly on u64::MAX — still exact
        assert_eq!(s.top(1), vec![(1, u64::MAX, 0)]);
        // One more add would overflow: release builds saturate at MAX
        // (key 1 stays on top) instead of wrapping to a tiny count and
        // silently reordering the top-K; debug builds assert.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.add(1, 10);
            s.top(1)
        }));
        if cfg!(debug_assertions) {
            assert!(r.is_err(), "debug builds must flag counter saturation");
        } else {
            assert_eq!(r.unwrap(), vec![(1, u64::MAX, 0)]);
        }
    }
}
