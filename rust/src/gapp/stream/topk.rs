//! Bounded top-K tracking — a *space-saving* sketch (Metwally et al.,
//! "Efficient computation of frequent and top-k elements in data
//! streams").
//!
//! The streaming analyzer must report cumulative top-K bottlenecks over
//! an unbounded run while holding O(K) state, no matter how many
//! distinct call paths flow past (stack-map LRU recycling means the id
//! space itself can churn). The sketch keeps `cap` counters; a new key
//! arriving at capacity seizes the minimum counter, inheriting its
//! count as the overestimation error. Guarantees: every tracked count
//! is an upper bound on the true count, off by at most its recorded
//! `err`, and any key whose true count exceeds the minimum counter is
//! guaranteed to be tracked.
//!
//! Eviction is O(log cap) amortized: minimum-victim selection goes
//! through a lazy-deletion min-heap over `(count, key)` instead of a
//! full O(cap) scan per insert, so churn-heavy streams (many distinct
//! light keys) no longer degrade to O(n·cap). Stale heap entries are
//! skipped on pop and compacted away when they outnumber the live
//! counters by 8×.
//!
//! Weights accumulate with saturating adds (debug builds assert):
//! the analyzer feeds integer-femtosecond CMetric weights, and at
//! 1e15 fs/s a long multi-app run can reach the top of `u64` — a wrap
//! there would silently reorder the top-K.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::hash::Hash;

use crate::util::{FxHashMap, sat_add};

/// One tracked counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Counter {
    count: u64,
    /// Maximum overestimation inherited when the key seized a slot.
    err: u64,
    /// Incarnation of this key's map entry: heap entries from before an
    /// eviction recycled the key are recognized as stale by it.
    gen: u64,
}

/// Space-saving top-K sketch over keys of type `K`.
///
/// `K: Ord` is required so minimum-victim selection and reporting break
/// ties deterministically (reports must not depend on map iteration
/// order).
#[derive(Clone, Debug)]
pub struct SpaceSaving<K: Eq + Hash + Copy + Ord> {
    cap: usize,
    counters: FxHashMap<K, Counter>,
    /// Lazy min-heap over `(count, key, gen)`. Every counter mutation
    /// pushes its latest state; an entry is live iff it matches the
    /// map's current `(count, gen)` for its key. The heap top therefore
    /// yields the true minimum counter, ties broken by smallest key —
    /// the same victim the old full scan picked.
    heap: BinaryHeap<Reverse<(u64, K, u64)>>,
    next_gen: u64,
}

impl<K: Eq + Hash + Copy + Ord> SpaceSaving<K> {
    /// A sketch tracking at most `cap` keys. `cap = 0` is rejected
    /// loudly (it used to be silently bumped to 1): user-facing knobs
    /// validate earlier with a real error message.
    pub fn new(cap: usize) -> SpaceSaving<K> {
        assert!(cap >= 1, "SpaceSaving capacity must be >= 1");
        SpaceSaving {
            cap,
            counters: FxHashMap::default(),
            heap: BinaryHeap::new(),
            next_gen: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.counters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
    }

    /// Add `weight` to `key` (weighted increments: the analyzer feeds
    /// per-window CMetric femtoseconds, not unit counts).
    pub fn add(&mut self, key: K, weight: u64) {
        if let Some(c) = self.counters.get_mut(&key) {
            c.count = sat_add(c.count, weight);
            self.heap.push(Reverse((c.count, key, c.gen)));
            self.maybe_compact();
            return;
        }
        if self.counters.len() < self.cap {
            self.next_gen += 1;
            let c = Counter {
                count: weight,
                err: 0,
                gen: self.next_gen,
            };
            self.counters.insert(key, c);
            self.heap.push(Reverse((weight, key, c.gen)));
            self.maybe_compact();
            return;
        }
        // Seize the minimum counter (ties: smallest key — deterministic).
        // Stale heap entries (superseded counts, evicted keys) are
        // popped and discarded; every live counter always has its
        // latest state in the heap, so this cannot run dry.
        let (vk, vcount) = loop {
            let Reverse((cnt, k, g)) =
                self.heap.pop().expect("live counters always have heap entries");
            match self.counters.get(&k) {
                Some(c) if c.gen == g && c.count == cnt => break (k, cnt),
                _ => continue,
            }
        };
        self.counters.remove(&vk);
        self.next_gen += 1;
        let c = Counter {
            count: sat_add(vcount, weight),
            err: vcount,
            gen: self.next_gen,
        };
        self.counters.insert(key, c);
        self.heap.push(Reverse((c.count, key, c.gen)));
        self.maybe_compact();
    }

    /// Rebuild the heap from live counters when stale entries dominate
    /// (amortized O(1) per add; bounds heap memory at O(cap)).
    fn maybe_compact(&mut self) {
        if self.heap.len() > (self.cap * 8).max(64) {
            self.heap.clear();
            self.heap.extend(
                self.counters
                    .iter()
                    .map(|(k, c)| Reverse((c.count, *k, c.gen))),
            );
        }
    }

    /// Serialize the sketch: `(capacity, counters)` with counters as
    /// `(key, count, err)` sorted ascending by key — a deterministic,
    /// order-independent snapshot for the checkpoint writer. The heap
    /// and generation counters are reconstruction details, not state:
    /// victim selection depends only on the live `(count, key)` pairs,
    /// so [`from_parts`] rebuilds them fresh.
    ///
    /// [`from_parts`]: SpaceSaving::from_parts
    pub fn export(&self) -> (usize, Vec<(K, u64, u64)>) {
        let mut v: Vec<(K, u64, u64)> = self
            .counters
            .iter()
            .map(|(k, c)| (*k, c.count, c.err))
            .collect();
        v.sort_by_key(|e| e.0);
        (self.cap, v)
    }

    /// Rebuild a sketch from an [`export`] snapshot. Errors (instead of
    /// panicking) on impossible shapes — more entries than capacity, a
    /// duplicated key — so a corrupt checkpoint surfaces as a message,
    /// not an assertion failure deep in the sketch.
    ///
    /// [`export`]: SpaceSaving::export
    pub fn from_parts(cap: usize, entries: &[(K, u64, u64)]) -> Result<SpaceSaving<K>, String> {
        if cap < 1 {
            return Err("sketch capacity must be >= 1".to_string());
        }
        if entries.len() > cap {
            return Err(format!(
                "sketch has {} counters but capacity {cap}",
                entries.len()
            ));
        }
        let mut s = SpaceSaving::new(cap);
        for &(k, count, err) in entries {
            s.next_gen += 1;
            let c = Counter {
                count,
                err,
                gen: s.next_gen,
            };
            if s.counters.insert(k, c).is_some() {
                return Err("sketch snapshot repeats a key".to_string());
            }
            s.heap.push(Reverse((count, k, c.gen)));
        }
        Ok(s)
    }

    /// Top `n` keys as `(key, count_upper_bound, max_overestimate)`,
    /// descending by count (ties: smallest key first).
    pub fn top(&self, n: usize) -> Vec<(K, u64, u64)> {
        let mut v: Vec<(K, u64, u64)> = self
            .counters
            .iter()
            .map(|(k, c)| (*k, c.count, c.err))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn exact_below_capacity() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(8);
        for (k, w) in [(1u32, 10u64), (2, 5), (1, 7), (3, 1)] {
            s.add(k, w);
        }
        assert_eq!(s.top(3), vec![(1, 17, 0), (2, 5, 0), (3, 1, 0)]);
    }

    #[test]
    fn heavy_hitters_survive_at_capacity() {
        // Two heavy keys plus a stream of distinct light keys through a
        // 4-slot sketch: the heavy keys must stay tracked and ranked on
        // top, with counts bounded by true + err.
        let mut s: SpaceSaving<u32> = SpaceSaving::new(4);
        for i in 0..200u32 {
            s.add(1000, 50);
            s.add(2000, 30);
            s.add(i, 1); // light churn
        }
        let top = s.top(2);
        assert_eq!(top[0].0, 1000);
        assert_eq!(top[1].0, 2000);
        for (_, count, err) in &s.top(4) {
            assert!(count >= err, "count is an upper bound: {count} >= {err}");
        }
        // Upper-bound property for the heavy keys.
        assert!(top[0].1 >= 200 * 50);
        assert!(top[1].1 >= 200 * 30);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn eviction_inherits_minimum_count_as_error() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(2);
        s.add(1, 10);
        s.add(2, 3);
        s.add(3, 1); // seizes key 2's slot (min count 3)
        let top = s.top(2);
        assert_eq!(top[0], (1, 10, 0));
        assert_eq!(top[1], (3, 4, 3)); // 3 inherited + 1 own, err 3
    }

    #[test]
    fn min_victim_tie_breaks_by_smallest_key() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(2);
        s.add(7, 5);
        s.add(3, 5);
        s.add(9, 1); // tie on count 5 → key 3 is the victim
        let keys: Vec<u32> = s.top(2).into_iter().map(|(k, _, _)| k).collect();
        assert!(keys.contains(&7) && keys.contains(&9), "{keys:?}");
    }

    #[test]
    #[should_panic(expected = "capacity must be >= 1")]
    fn zero_capacity_sketch_is_rejected() {
        let _ = SpaceSaving::<u32>::new(0);
    }

    #[test]
    fn export_restore_round_trip_preserves_future_behaviour() {
        // A restored sketch must not just report the same top-K: it must
        // keep *behaving* identically — same victims, same inherited
        // errors — under any continuation stream.
        let mut rng = Prng::new(0x5EED);
        let mut original: SpaceSaving<u32> = SpaceSaving::new(5);
        for _ in 0..300 {
            original.add(rng.below(32) as u32, 1 + rng.below(9));
        }
        let (cap, entries) = original.export();
        assert_eq!(cap, 5);
        assert!(entries.windows(2).all(|w| w[0].0 < w[1].0), "sorted by key");
        let mut restored = SpaceSaving::from_parts(cap, &entries).unwrap();
        assert_eq!(restored.top(5), original.top(5));
        for _ in 0..300 {
            let (k, w) = (rng.below(32) as u32, 1 + rng.below(9));
            original.add(k, w);
            restored.add(k, w);
        }
        assert_eq!(restored.top(5), original.top(5));
        assert_eq!(restored.export(), original.export());
    }

    #[test]
    fn from_parts_rejects_impossible_snapshots() {
        let err = SpaceSaving::<u32>::from_parts(0, &[]).unwrap_err();
        assert!(err.contains(">= 1"), "{err}");
        let err = SpaceSaving::<u32>::from_parts(1, &[(1, 2, 0), (2, 3, 0)]).unwrap_err();
        assert!(err.contains("capacity"), "{err}");
        let err = SpaceSaving::<u32>::from_parts(4, &[(1, 2, 0), (1, 3, 0)]).unwrap_err();
        assert!(err.contains("repeats"), "{err}");
    }

    /// The old implementation, verbatim in behaviour: O(cap) min scan
    /// per eviction. The heap-backed version must pick bit-identical
    /// victims (including the smallest-key tie-break) on any stream.
    struct NaiveRef {
        cap: usize,
        counters: Vec<(u32, u64, u64)>, // (key, count, err)
    }

    impl NaiveRef {
        fn add(&mut self, key: u32, weight: u64) {
            if let Some(c) = self.counters.iter_mut().find(|c| c.0 == key) {
                c.1 += weight;
                return;
            }
            if self.counters.len() < self.cap {
                self.counters.push((key, weight, 0));
                return;
            }
            let vi = (0..self.counters.len())
                .min_by(|&a, &b| {
                    let (ka, ca) = (self.counters[a].0, self.counters[a].1);
                    let (kb, cb) = (self.counters[b].0, self.counters[b].1);
                    ca.cmp(&cb).then(ka.cmp(&kb))
                })
                .unwrap();
            let vc = self.counters[vi].1;
            self.counters[vi] = (key, vc + weight, vc);
        }

        fn top(&self) -> Vec<(u32, u64, u64)> {
            let mut v = self.counters.clone();
            v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            v
        }
    }

    #[test]
    fn indexed_eviction_matches_the_naive_min_scan_under_churn() {
        // Churn-heavy random streams: the lazy-heap eviction must stay
        // exactly equivalent to the full-scan reference, compaction and
        // re-insertion of previously evicted keys included.
        let mut rng = Prng::new(0xD1CE);
        for case in 0..20 {
            let cap = 1 + rng.pick(8);
            let mut fast: SpaceSaving<u32> = SpaceSaving::new(cap);
            let mut slow = NaiveRef {
                cap,
                counters: Vec::new(),
            };
            for _ in 0..400 {
                // Small key space → heavy reuse of evicted keys.
                let key = rng.below(24) as u32;
                let w = 1 + rng.below(9);
                fast.add(key, w);
                slow.add(key, w);
            }
            assert_eq!(
                fast.top(cap),
                slow.top(),
                "case {case} (cap {cap}) diverged from the reference"
            );
            assert!(
                fast.heap.len() <= (cap * 8).max(64) + 1,
                "stale entries must be compacted away"
            );
        }
    }

    #[test]
    fn near_max_weights_never_wrap_the_ranking() {
        let mut s: SpaceSaving<u32> = SpaceSaving::new(4);
        // Exact accumulation at the extreme end of u64: no wrap.
        s.add(1, u64::MAX - 10);
        s.add(2, 100);
        assert_eq!(s.top(2), vec![(1, u64::MAX - 10, 0), (2, 100, 0)]);
        s.add(1, 10); // lands exactly on u64::MAX — still exact
        assert_eq!(s.top(1), vec![(1, u64::MAX, 0)]);
        // One more add would overflow: release builds saturate at MAX
        // (key 1 stays on top) instead of wrapping to a tiny count and
        // silently reordering the top-K; debug builds assert.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            s.add(1, 10);
            s.top(1)
        }));
        if cfg!(debug_assertions) {
            assert!(r.is_err(), "debug builds must flag counter saturation");
        } else {
            assert_eq!(r.unwrap(), vec![(1, u64::MAX, 0)]);
        }
    }
}
