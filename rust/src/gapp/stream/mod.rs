//! The streaming online analyzer — GAPP's always-on half.
//!
//! The batch pipeline (`gapp::profile`) drains the ring buffer once at
//! the end of a run and merges everything in one pass, which caps it at
//! post-mortem use. This subsystem inverts that control flow, the way
//! the paper's deployment runs against long-lived daemons (§4: the
//! user-space probe "runs concurrently with the application"):
//!
//! * [`consumer`] — an epoch-based consumer over the *sharded* per-CPU
//!   rings (the `PERF_EVENT_ARRAY` poll-loop analogue): one cursor per
//!   shard, drained together once per simulation epoch with the global
//!   record order re-established from capture timestamps, attributing
//!   ring drops to both the epoch and the CPU buffer they occurred in.
//! * [`window`] — per-window incremental aggregation with mergeable
//!   snapshots: all aggregates are associative, so concatenated window
//!   snapshots merge to *exactly* the batch result (golden-tested).
//! * [`topk`] — a bounded space-saving sketch for cumulative top-K over
//!   unbounded runs in O(K) memory.
//! * [`multi`] — system-wide mode: several applications share one
//!   kernel, with per-app attribution learned from `task_newtask`.
//! * [`live`] — per-window top-K report rendering.
//!
//! [`run_live`] wires it all together: simulate one epoch window
//! (`Kernel::run_until`), drain, aggregate, report, repeat. Memory
//! stays O(top-K + live stack ids) regardless of run length — no
//! per-slice state survives its window.

pub mod consumer;
pub mod live;
pub mod multi;
pub mod topk;
pub mod window;

pub use consumer::{EpochStats, ShardedConsumer};
pub use live::{LiveLine, WindowReport};
use live::live_lines;
pub use multi::{AppRegistry, RegistryProbe};
pub use topk::SpaceSaving;
pub use window::{merge_snapshots, WindowAccumulator};

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use crate::ebpf::StackMap;
use crate::runtime::AnalysisEngine;
use crate::simkernel::{Kernel, KernelConfig, RunOutcome, Time};
use crate::workload::App;

use super::symbolize::Symbolizer;
use super::userspace::{PathAccumulator, SliceEntry};
use super::{build_report, GappConfig, GappSession, Report, ReportCtx};

/// Streaming-analyzer configuration.
#[derive(Clone, Debug)]
pub struct LiveConfig {
    /// Epoch window length (simulated ns). The CLI flag is `--window-us`.
    pub window_ns: Time,
    /// Bottleneck lines per window report.
    pub top_k: usize,
    /// Capacity of the cumulative space-saving sketch.
    pub sketch_entries: usize,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            window_ns: 5_000_000, // 5 ms
            top_k: 5,
            sketch_entries: 64,
        }
    }
}

/// Compact per-window record retained after the window's full report
/// has been handed to the callback (keeps `LiveRun` O(windows), not
/// O(windows × paths)).
#[derive(Clone, Copy, Debug)]
pub struct WindowSummary {
    pub index: u64,
    pub slices: u64,
    pub drained: u64,
    pub drops: u64,
}

/// Result of one streaming session.
pub struct LiveRun {
    /// Final report, built from the *merged window snapshots* — proven
    /// byte-identical to the batch report by the streaming golden test.
    pub report: Report,
    pub windows: Vec<WindowSummary>,
    /// Cumulative top-K from the space-saving sketch:
    /// `(stack_id, cm_fs_upper_bound, max_overestimate_fs)`. Ids are
    /// stable (re-interned under kernel-side LRU recycling); app
    /// attribution lives in the merged paths, not the sketch key, so a
    /// path whose dominant app shifts between windows still accumulates
    /// under one counter.
    pub sketch_top: Vec<(u32, u64, u64)>,
    /// `sketch_top` rendered for display (`gapp live` prints these as
    /// the cumulative tail of the session).
    pub sketch_lines: Vec<String>,
    pub runtime_ns: Time,
}

/// Profile one or more applications *online*: simulate epoch windows,
/// drain the ring each epoch, aggregate incrementally, and emit one
/// [`WindowReport`] per window through `on_window`. With several apps
/// the kernel hosts them concurrently (system-wide mode) and every
/// bottleneck is attributed to its owning application.
pub fn run_live(
    apps: &[App],
    kcfg: KernelConfig,
    gcfg: GappConfig,
    engine: AnalysisEngine,
    lcfg: LiveConfig,
    mut on_window: impl FnMut(&WindowReport),
) -> Result<LiveRun> {
    anyhow::ensure!(!apps.is_empty(), "live mode needs at least one app");
    anyhow::ensure!(
        lcfg.window_ns > 0,
        "window length must be positive (--window-us 0 would never close a window)"
    );
    anyhow::ensure!(
        lcfg.top_k >= 1,
        "top_k must be >= 1 (--top 0 would report nothing)"
    );
    anyhow::ensure!(
        lcfg.sketch_entries >= 1,
        "sketch_entries must be >= 1 (--sketch 0 cannot track anything)"
    );
    let top_n = gcfg.top_n;
    let stack_lru = gcfg.stack_lru;
    let session = GappSession::new(gcfg, kcfg.cpus, engine)?;
    let mut kernel = Kernel::new(kcfg);
    kernel.attach_probe(session.probe());
    // System-wide attribution: a zero-cost probe tags every task with
    // its application (children inherit), so attaching it cannot
    // perturb the simulated timeline relative to a batch run.
    let registry = Rc::new(RefCell::new(AppRegistry::new()));
    kernel.attach_probe(Box::new(RegistryProbe::new(registry.clone())));
    for app in apps {
        registry.borrow_mut().begin_app(&app.name);
        app.spawn_into(&mut kernel);
        registry.borrow_mut().end_spawn();
    }
    let names: Vec<String> = registry.borrow().names().to_vec();
    let multi_app = apps.len() > 1;
    let mut syms: Vec<Symbolizer<'_>> = apps
        .iter()
        .map(|a| Symbolizer::new(a.symtab.as_ref()))
        .collect();

    // One cursor per ring shard: the transport is per-CPU perf buffers,
    // drained together at each epoch boundary.
    let mut consumer = ShardedConsumer::new(session.core.borrow().kernel.rings.num_shards());
    let mut wacc = WindowAccumulator::new();
    let mut cumulative = PathAccumulator::new();
    let mut sketch: SpaceSaving<u32> = SpaceSaving::new(lcfg.sketch_entries);
    let mut scratch: Vec<SliceEntry> = Vec::new();
    let mut summaries: Vec<WindowSummary> = Vec::new();
    let mut window_drops: Vec<u64> = Vec::new();
    // Kernel-side LRU recycles stack ids mid-run, so everything that
    // outlives a window (cumulative merge, sketch, final report) must
    // not key on raw kernel ids. Snapshots are re-interned here — at
    // window close, while id → frames is still fresh — into a stable
    // userspace map. Without LRU, kernel ids are already stable and
    // this stays `None`.
    let mut user_stacks: Option<StackMap> = if stack_lru {
        Some(StackMap::new("live_user_stacks", 1 << 20))
    } else {
        None
    };

    let mut epoch: u64 = 0;
    let runtime_ns = loop {
        epoch += 1;
        let limit = lcfg.window_ns.saturating_mul(epoch);
        let outcome = kernel.run_until(limit)?;
        let (end_ns, done) = match outcome {
            RunOutcome::Done(t) => (t, true),
            RunOutcome::Paused(t) => (t, false),
        };
        let start_ns = lcfg.window_ns.saturating_mul(epoch - 1).min(end_ns);
        let wr = {
            let mut core = session.core.borrow_mut();
            let estats = consumer.drain_epoch(&mut core);
            scratch.clear();
            core.user.drain_slices_into(&mut scratch);
            {
                let reg = registry.borrow();
                for s in &scratch {
                    wacc.add_slice(s, reg.app_of(s.pid));
                }
            }
            let slices_in = wacc.slices_in;
            let mut snapshot = wacc.snapshot();
            if let Some(us) = user_stacks.as_mut() {
                for p in &mut snapshot {
                    let frames = core.kernel.stacks.resolve(p.stack_id);
                    p.stack_id = us.intern(frames);
                }
            }
            let ranked = core.user.rank_merged(&snapshot, lcfg.top_k);
            let stacks = user_stacks.as_ref().unwrap_or(&core.kernel.stacks);
            let top = live_lines(&ranked, stacks, &names, &mut syms, multi_app);
            WindowReport {
                index: epoch,
                start_ns,
                end_ns,
                slices: slices_in,
                drained: estats.delta.drained,
                drops: estats.delta.dropped,
                shard_drops: estats.per_shard.iter().map(|d| d.dropped).collect(),
                top,
                snapshot,
            }
        };
        on_window(&wr);
        // Fold the window into the cumulative state; the snapshot dies
        // here, keeping resident memory O(top-K + live stack ids).
        for p in &wr.snapshot {
            cumulative.merge_path(p);
            sketch.add(p.stack_id, p.cm_fs);
        }
        window_drops.push(wr.drops);
        summaries.push(WindowSummary {
            index: wr.index,
            slices: wr.slices,
            drained: wr.drained,
            drops: wr.drops,
        });
        if done {
            break end_ns;
        }
    };

    // Final report from the merged window snapshots (post-processing
    // proper starts here, mirroring the batch `finish`).
    let ppt_start = Instant::now();
    let mut core = session.core.borrow_mut();
    core.user.flush_batch();
    let merged = cumulative.take_paths();
    let ranked = core.user.rank_merged(&merged, top_n);
    // Cumulative sketch tail: the sketch tracks raw stack ids; app
    // ownership comes from the cumulative merge (address spaces may
    // overlap between apps in system-wide mode, so each site must be
    // symbolized through the app that owns the path).
    let sketch_top = sketch.top(lcfg.top_k);
    let sketch_lines: Vec<String> = {
        let stacks = user_stacks.as_ref().unwrap_or(&core.kernel.stacks);
        let owner_of: crate::util::FxHashMap<u32, usize> = merged
            .iter()
            .map(|p| (p.stack_id, p.owner_app(multi_app, syms.len())))
            .collect();
        sketch_top
            .iter()
            .map(|(id, cm_fs, err_fs)| {
                let owner = owner_of.get(id).copied().unwrap_or(0);
                let site = match stacks.resolve(*id).last() {
                    Some(a) => syms[owner].render(*a),
                    None => "<no frames>".to_string(),
                };
                let app_name = names
                    .get(owner)
                    .cloned()
                    .unwrap_or_else(|| format!("app{owner}"));
                format!(
                    "{:<14} {:>9.3} ms (+{:.3} max over)  {}",
                    app_name,
                    *cm_fs as f64 / 1e12,
                    *err_fs as f64 / 1e12,
                    site,
                )
            })
            .collect()
    };
    let ctx = ReportCtx {
        label: names.join("+"),
        syms: apps
            .iter()
            .map(|a| (a.name.as_str(), a.symtab.as_ref()))
            .collect(),
        multi_app,
        window_drops,
        stacks: user_stacks.as_ref(),
    };
    let mut report = build_report(&core, &kernel, runtime_ns, &ranked, ctx, ppt_start);
    if let Some(us) = user_stacks.as_ref() {
        // The stable userspace re-intern map is part of the analyzer:
        // if it saturates on a long run, the loss must be as visible as
        // the kernel map's own drop counter.
        report.stack_drops += us.stats.drops;
    }
    Ok(LiveRun {
        report,
        windows: summaries,
        sketch_top,
        sketch_lines,
        runtime_ns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::apps;

    #[test]
    fn live_single_app_produces_windows_and_report() {
        let app = apps::canneal(8, 5);
        let mut seen = 0u64;
        let run = run_live(
            std::slice::from_ref(&app),
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
            LiveConfig {
                window_ns: 2_000_000,
                ..Default::default()
            },
            |w| {
                seen += 1;
                assert_eq!(w.index, seen);
                assert!(w.end_ns >= w.start_ns);
            },
        )
        .unwrap();
        assert!(seen > 1, "expected multiple windows, got {seen}");
        assert_eq!(run.windows.len() as u64, seen);
        assert_eq!(run.report.window_drops.len() as u64, seen);
        assert!(!run.report.bottlenecks.is_empty());
        assert_eq!(run.report.app, "canneal");
        // Ring never overflowed at default capacity.
        assert_eq!(run.report.ring_dropped, 0);
        // The sketch tracked cumulative paths and rendered them.
        assert!(!run.sketch_top.is_empty());
        assert_eq!(run.sketch_top.len(), run.sketch_lines.len());
        assert!(run.sketch_lines[0].contains("ms"));
    }

    #[test]
    fn live_rejects_empty_app_list() {
        let err = run_live(
            &[],
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
            LiveConfig::default(),
            |_| {},
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one app"));
    }
}
