//! The streaming online analyzer — GAPP's always-on half.
//!
//! The batch pipeline (`gapp::profile`) drains the ring buffer once at
//! the end of a run and merges everything in one pass, which caps it at
//! post-mortem use. This subsystem inverts that control flow, the way
//! the paper's deployment runs against long-lived daemons (§4: the
//! user-space probe "runs concurrently with the application"):
//!
//! * [`consumer`] — an epoch-based consumer over the *sharded* per-CPU
//!   rings (the `PERF_EVENT_ARRAY` poll-loop analogue): one cursor per
//!   shard, drained once per simulation epoch, attributing ring drops
//!   to both the epoch and the CPU buffer they occurred in.
//! * [`window`] — per-window incremental aggregation with mergeable
//!   snapshots: all aggregates are associative, so concatenated window
//!   snapshots merge to *exactly* the batch result (golden-tested).
//!
//! # Merge strategies
//!
//! How drained records reach the window accumulators is governed by
//! `GappConfig::merge` (`--merge serial|tree`); the two strategies
//! render **byte-identical** reports (golden + property tested):
//!
//! * **`serial`** — the pre-tree consumer: every epoch, all shards are
//!   k-way merged back into one `(time, seq)`-ordered stream (a
//!   serialization point that grows with the shard count), and a
//!   single [`WindowAccumulator`] folds it.
//! * **`tree`** (default) — shard-local folding: each shard drains *in
//!   shard order* into its own lane and [`WindowAccumulator`]; at
//!   window close the S partials combine through a pairwise merge tree
//!   ([`merge_tree`], O(log S) depth). Correctness splits the record
//!   stream in two: slice records (`Sample`/`SliceDiscard`/`SliceEnd`)
//!   are *shard-affine* — a timeslice runs on one CPU, so its whole
//!   pairing lifecycle lands in one shard FIFO — and fold locally;
//!   activity-matrix records (`Interval`/`SlotAssign`/`SlotFree`)
//!   mutate *global* state (thread slots, f32 batch grouping) and are
//!   still re-merged by capture stamp, but only at window close, off
//!   the hot path. Output order reconciles through each merged path's
//!   `first_seen` capture stamp, which reproduces the serial
//!   first-seen order exactly. With `--lane-threads N` (N > 1) the
//!   shard folds move onto real OS threads ([`lanes`]): the driver
//!   hands each shard's drained records to its lane worker over an
//!   SPSC channel and collects one partial per shard at the
//!   window-close barrier — still byte-identical output at every N.
//! * [`topk`] — a bounded space-saving sketch for cumulative top-K over
//!   unbounded runs in O(K) memory, plus a time-decayed variant
//!   ([`DecayedSpaceSaving`], `--decay-half-life-us`) answering "hot
//!   recently" beside "hot ever".
//! * [`tiers`] — base-B tier pyramid over closed windows
//!   (`--compact-base`): retained per-window state drops from
//!   O(windows) to O(B·log T) while the cumulative report stays
//!   byte-identical to the uncompacted run.
//! * [`multi`] — system-wide mode: several applications share one
//!   kernel, with per-app attribution learned from `task_newtask`.
//! * [`live`] — per-window top-K report rendering.
//!
//! The epoch-windowed driver itself lives in [`super::session`]: a
//! [`super::Session`] with a window set simulates one epoch
//! (`Kernel::run_until`), drains, aggregates, emits one
//! `WindowClosed` event, and repeats. Memory stays O(top-K + live
//! stack ids) regardless of run length — no per-slice state survives
//! its window. [`run_live`] remains as a thin deprecated
//! callback-style wrapper over that driver.

pub mod consumer;
pub mod lanes;
pub mod live;
pub mod multi;
pub mod partials;
pub mod tiers;
pub mod topk;
pub mod window;

pub use consumer::{EpochStats, ShardPartial, ShardedConsumer};
pub use lanes::{spawn_lane_workers, LaneIo, LaneMsg, LaneWindow};
pub use live::{LiveLine, WindowReport};
pub use multi::{AppRegistry, RegistryProbe};
pub use tiers::{TierEntry, TierFold, TierPyramid};
pub use topk::{DecayedSpaceSaving, SpaceSaving};
pub use window::{
    merge_pair, merge_pair_pooled, merge_snapshots, merge_tree,
    merge_tree_parallel, merge_tree_parallel_pooled, merge_tree_pooled,
    sort_canonical, MergePool, WindowAccumulator,
};

use anyhow::Result;

use crate::runtime::AnalysisEngine;
use crate::simkernel::{KernelConfig, Time};
use crate::workload::App;

use super::sink::{FnSink, ReportEvent};
use super::{Report, GappConfig, Session};

/// Streaming-analyzer configuration.
#[derive(Clone, Debug)]
pub struct LiveConfig {
    /// Epoch window length (simulated ns). The CLI flag is `--window-us`.
    pub window_ns: Time,
    /// Bottleneck lines per window report.
    pub top_k: usize,
    /// Capacity of the cumulative space-saving sketch.
    pub sketch_entries: usize,
    /// Emit one `ReportEvent::ShardWindow` per (window × shard) with
    /// that shard's partial aggregation (tree strategy only). Off by
    /// default; the JSONL sink serializes these so a future
    /// cross-process consumer can ship shard partials and run the same
    /// merge tree across machines.
    pub shard_partials: bool,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            window_ns: 5_000_000, // 5 ms
            top_k: 5,
            sketch_entries: 64,
            shard_partials: false,
        }
    }
}

/// Compact per-window record retained after the window's full report
/// has been handed to the callback (keeps `LiveRun` O(windows), not
/// O(windows × paths)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowSummary {
    pub index: u64,
    pub slices: u64,
    pub drained: u64,
    pub drops: u64,
}

/// Result of one streaming session.
pub struct LiveRun {
    /// Final report, built from the *merged window snapshots* — proven
    /// byte-identical to the batch report by the streaming golden test.
    pub report: Report,
    pub windows: Vec<WindowSummary>,
    /// Cumulative top-K from the space-saving sketch:
    /// `(stack_id, cm_fs_upper_bound, max_overestimate_fs)`. Ids are
    /// stable (re-interned under kernel-side LRU recycling); app
    /// attribution lives in the merged paths, not the sketch key, so a
    /// path whose dominant app shifts between windows still accumulates
    /// under one counter.
    pub sketch_top: Vec<(u32, u64, u64)>,
    /// `sketch_top` rendered for display (`gapp live` prints these as
    /// the cumulative tail of the session).
    pub sketch_lines: Vec<String>,
    pub runtime_ns: Time,
}

/// Profile one or more applications *online*: simulate epoch windows,
/// drain the ring each epoch, aggregate incrementally, and emit one
/// [`WindowReport`] per window through `on_window`. With several apps
/// the kernel hosts them concurrently (system-wide mode) and every
/// bottleneck is attributed to its owning application.
///
/// Thin wrapper over the [`Session`] builder (the windowed driver
/// lives there and emits typed events; this adapts the `WindowClosed`
/// stream back onto the old callback). Kept so pre-sink callers
/// compile unchanged; new code should build a [`Session`].
#[deprecated(
    note = "use gapp::Session::builder(engine).app(..).live(lcfg).sink(..).run()"
)]
pub fn run_live(
    apps: &[App],
    kcfg: KernelConfig,
    gcfg: GappConfig,
    engine: AnalysisEngine,
    lcfg: LiveConfig,
    mut on_window: impl FnMut(&WindowReport),
) -> Result<LiveRun> {
    anyhow::ensure!(!apps.is_empty(), "live mode needs at least one app");
    let mut session = Session::builder(engine)
        .kernel(kcfg)
        .config(gcfg)
        .live(lcfg)
        .sink(FnSink(|ev: &ReportEvent<'_>| {
            if let ReportEvent::WindowClosed(w) = ev {
                on_window(w);
            }
        }));
    for app in apps {
        session = session.app(app);
    }
    let out = session.run()?;
    Ok(LiveRun {
        report: out.report,
        windows: out.windows,
        sketch_top: out.sketch_top,
        sketch_lines: out.sketch_lines,
        runtime_ns: out.runtime_ns,
    })
}

#[cfg(test)]
// The deprecated callback wrapper is itself under test (it must relay
// every window the Session driver emits).
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::workload::apps;

    #[test]
    fn live_single_app_produces_windows_and_report() {
        let app = apps::canneal(8, 5);
        let mut seen = 0u64;
        let run = run_live(
            std::slice::from_ref(&app),
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
            LiveConfig {
                window_ns: 2_000_000,
                ..Default::default()
            },
            |w| {
                seen += 1;
                assert_eq!(w.index, seen);
                assert!(w.end_ns >= w.start_ns);
            },
        )
        .unwrap();
        assert!(seen > 1, "expected multiple windows, got {seen}");
        assert_eq!(run.windows.len() as u64, seen);
        assert_eq!(run.report.window_drops.len() as u64, seen);
        assert!(!run.report.bottlenecks.is_empty());
        assert_eq!(run.report.app, "canneal");
        // Ring never overflowed at default capacity.
        assert_eq!(run.report.ring_dropped, 0);
        // The sketch tracked cumulative paths and rendered them.
        assert!(!run.sketch_top.is_empty());
        assert_eq!(run.sketch_top.len(), run.sketch_lines.len());
        assert!(run.sketch_lines[0].contains("ms"));
    }

    #[test]
    fn live_rejects_empty_app_list() {
        let err = run_live(
            &[],
            KernelConfig::default(),
            GappConfig::default(),
            AnalysisEngine::native(),
            LiveConfig::default(),
            |_| {},
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one app"));
    }
}
