//! Live per-window top-K reporting.
//!
//! Each closed epoch window becomes a [`WindowReport`]: the window's
//! top-K bottleneck call paths (ranked by window CMetric) with per-app
//! attribution, plus the ring activity attributed to the window. The
//! driver hands each report to a callback as it is produced — `gapp
//! live` prints them as the "simulation" progresses, exactly how the
//! paper's always-on deployment would tail a long-running daemon.

use std::fmt;

use crate::ebpf::StackMap;
use crate::gapp::classify;
use crate::gapp::symbolize::Symbolizer;
use crate::gapp::userspace::MergedPath;

/// One ranked line of a window report.
#[derive(Clone, Debug)]
pub struct LiveLine {
    pub rank: usize,
    /// Owning application (dominant app of the path's slices).
    pub app: String,
    /// CMetric accumulated by this path *within the window*, ms.
    pub cm_ms: f64,
    pub slices: u64,
    pub class: &'static str,
    /// Innermost call-path frame, symbolized.
    pub site: String,
}

/// One closed epoch window of the streaming analyzer.
#[derive(Clone, Debug)]
pub struct WindowReport {
    /// 1-based window index.
    pub index: u64,
    pub start_ns: u64,
    pub end_ns: u64,
    /// Critical slices aggregated this window.
    pub slices: u64,
    /// Ring records drained during this window's epoch (all shards).
    pub drained: u64,
    /// Ring drops attributed to this window's epoch (all shards).
    pub drops: u64,
    /// The same drops broken down by ring shard (indexed by shard id);
    /// rendered only when the window actually lost records.
    pub shard_drops: Vec<u64>,
    /// Emergency ring drains performed while this window was open
    /// (`--on-overflow degrade` only; rendered only when nonzero).
    pub degraded_drains: u64,
    /// Whether this window widened by absorbing the next epoch under
    /// the degrade policy (rendered only when true).
    pub widened: bool,
    /// Top-K bottlenecks of the window, ranked by window CMetric.
    pub top: Vec<LiveLine>,
    /// The full window merge snapshot (first-seen order). The driver
    /// folds it into the cumulative merge after the callback returns —
    /// concatenating these snapshots is provably equivalent to one
    /// batch merge, which the streaming golden test pins down.
    pub snapshot: Vec<MergedPath>,
}

impl fmt::Display for WindowReport {
    /// Delegates to [`crate::gapp::sink::human::render_window`] — the
    /// renderer lives with the sinks now; this impl only keeps
    /// `print!("{window}")`-style callers working.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&crate::gapp::sink::human::render_window(self))
    }
}

/// Render ranked window paths as report lines. `syms` and `names` are
/// indexed by application id; single-app sessions attribute everything
/// to app 0.
pub(crate) fn live_lines(
    ranked: &[MergedPath],
    stacks: &StackMap,
    names: &[String],
    syms: &mut [Symbolizer<'_>],
    multi_app: bool,
) -> Vec<LiveLine> {
    ranked
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let owner = m.owner_app(multi_app, syms.len());
            let frames = stacks.resolve(m.stack_id);
            let site = match frames.last() {
                Some(a) => syms[owner].render(*a),
                None => "<no frames>".to_string(),
            };
            LiveLine {
                rank: i + 1,
                app: names
                    .get(owner)
                    .cloned()
                    .unwrap_or_else(|| format!("app{owner}")),
                cm_ms: m.total_cm_ns / 1e6,
                slices: m.slices,
                class: classify::classify(m).label(),
                site,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::userspace::{PathAccumulator, SliceEntry};
    use crate::simkernel::WaitKind;
    use crate::workload::SymbolTable;

    #[test]
    fn lines_render_with_app_attribution_and_sites() {
        let mut st = SymbolTable::new();
        let f = st.add("anchor_hash", "dedup.c", 88);
        let addr = st.addr_of(f);
        let mut stacks = StackMap::new("stacks", 8);
        let sid = stacks.intern(&[addr]);

        let mut acc = PathAccumulator::new();
        acc.add_slice(
            &SliceEntry {
                ts_id: 1,
                pid: 4,
                cm_ns: 2_500_000.0,
                threads_av: 1.0,
                stack_id: sid,
                addrs: vec![addr],
                from_stack_top: false,
                wait: WaitKind::Queue,
                woken_by: 0,
            },
            1,
        );
        let paths = acc.take_paths();
        let names = vec!["mysql".to_string(), "dedup".to_string()];
        let mut syms = vec![Symbolizer::new(&st), Symbolizer::new(&st)];
        let lines = live_lines(&paths, &stacks, &names, &mut syms, true);
        assert_eq!(lines.len(), 1);
        assert_eq!(lines[0].app, "dedup");
        assert_eq!(lines[0].class, "pipeline queue");
        assert!(lines[0].site.starts_with("anchor_hash"));
        assert!((lines[0].cm_ms - 2.5).abs() < 1e-9);

        let wr = WindowReport {
            index: 3,
            start_ns: 10_000_000,
            end_ns: 15_000_000,
            slices: 1,
            drained: 12,
            drops: 0,
            shard_drops: vec![0, 0],
            degraded_drains: 0,
            widened: false,
            top: lines,
            snapshot: paths,
        };
        let s = wr.to_string();
        assert!(s.contains("[w   3"));
        assert!(s.contains("drops 0"));
        assert!(s.contains("dedup"));
        assert!(s.contains("anchor_hash"));
        // A lossless window never renders a shard breakdown.
        assert!(!s.contains("[s"));
    }

    #[test]
    fn empty_window_renders_placeholder() {
        let wr = WindowReport {
            index: 1,
            start_ns: 0,
            end_ns: 5_000_000,
            slices: 0,
            drained: 0,
            drops: 0,
            shard_drops: Vec::new(),
            degraded_drains: 0,
            widened: false,
            top: Vec::new(),
            snapshot: Vec::new(),
        };
        assert!(wr.to_string().contains("no critical slices"));
    }

    #[test]
    fn lossy_window_renders_per_shard_drops() {
        let mut wr = WindowReport {
            index: 2,
            start_ns: 0,
            end_ns: 5_000_000,
            slices: 0,
            drained: 9,
            drops: 4,
            shard_drops: vec![0, 3, 0, 1],
            degraded_drains: 0,
            widened: false,
            top: Vec::new(),
            snapshot: Vec::new(),
        };
        let s = wr.to_string();
        assert!(s.contains("drops 4 [s1:3 s3:1]"), "{s}");
        // A lossy single-ring window keeps the pre-shard format: the
        // breakdown would just restate the total.
        wr.shard_drops = vec![4];
        let s = wr.to_string();
        assert!(s.contains("drops 4\n"), "{s}");
        assert!(!s.contains("[s0"), "{s}");
    }
}
