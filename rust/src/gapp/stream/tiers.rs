//! Tiered window compaction — bounded-memory multi-day profiling.
//!
//! The flat windowed driver retains one `WindowSummary` (and one drop
//! counter) per closed window: O(windows) state, which a multi-day
//! `gapp live` run eventually spends its memory on. This module bounds
//! that at O(B·log T) for T windows with a **tier pyramid**, the
//! downsampling-store shape time-series databases use:
//!
//! * level 0 holds the last closed windows *raw* — summary plus the
//!   window's merged path snapshot;
//! * when a level accumulates `B` entries, they fold through the
//!   existing associative merge tree ([`merge_tree_pooled`]) into one
//!   entry of the next level, which covers `B`× the window span.
//!
//! The retained entry count is exactly the digit sum of T written in
//! base B (each level is one digit), so it is ≤ (B−1)·(⌊log_B T⌋+1) —
//! property-tested. Because every per-path aggregate is associative
//! and output order reconciles through `first_seen` capture stamps
//! (proven for the shard merge tree, reused verbatim here), folding
//! the retained entries chronologically reproduces the uncompacted
//! cumulative merge **byte for byte**: compaction changes what is
//! *retained*, never what is *reported*.
//!
//! Entries are immutable once created; each caches its serialized
//! checkpoint rendering (`cached_json`) so periodic checkpoint writes
//! re-serialize only entries created since the last write — the
//! append-only serialization contract of checkpoint size governance.

use crate::gapp::stream::window::{merge_tree_pooled, MergePool};
use crate::gapp::stream::WindowSummary;
use crate::gapp::userspace::{MergedPath, PathAccumulator};

/// One retained pyramid entry: the fold of a contiguous run of
/// `last_index - first_index + 1` closed windows (a level-0 entry
/// covers exactly one). Immutable once created — folds consume entries
/// and create a new one a level up.
#[derive(Clone, Debug)]
pub struct TierEntry {
    /// Pyramid level (0 = raw window, `l` covers `B^l` windows).
    pub level: u32,
    /// First covered window index (1-based, inclusive).
    pub first_index: u64,
    /// Last covered window index (inclusive).
    pub last_index: u64,
    /// Aggregate of the covered windows: `index` is the last covered
    /// window, the counters are sums over the span.
    pub summary: WindowSummary,
    /// Covered windows that recorded ring drops.
    pub lossy_windows: u64,
    /// Folded path snapshot of the span, in canonical
    /// (ascending-`first_seen`) order.
    pub paths: Vec<MergedPath>,
    /// Serialized checkpoint rendering, filled in by the first
    /// checkpoint write that covers this entry (entries never change,
    /// so later writes splice the cached bytes instead of re-walking
    /// the paths).
    pub(crate) cached_json: Option<String>,
}

impl TierEntry {
    /// Assemble an entry (checkpoint restore and tests; the pyramid
    /// builds its own entries internally).
    pub fn new(
        level: u32,
        first_index: u64,
        last_index: u64,
        summary: WindowSummary,
        lossy_windows: u64,
        paths: Vec<MergedPath>,
    ) -> TierEntry {
        TierEntry {
            level,
            first_index,
            last_index,
            summary,
            lossy_windows,
            paths,
            cached_json: None,
        }
    }

    /// Windows this entry covers.
    pub fn windows(&self) -> u64 {
        self.last_index - self.first_index + 1
    }

    /// The shape key resume integrity compares (everything except the
    /// folded paths, which a replay deliberately skips rebuilding).
    fn shape(&self) -> (u32, u64, u64, WindowSummary, u64) {
        (
            self.level,
            self.first_index,
            self.last_index,
            self.summary,
            self.lossy_windows,
        )
    }
}

/// One fold performed by a [`TierPyramid::push`]: `B` entries of
/// `level - 1` collapsed into one entry at `level`. Surfaced so the
/// driver can emit an additive `tier` event per fold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TierFold {
    /// Level the folded entry landed on (≥ 1).
    pub level: u32,
    pub first_index: u64,
    pub last_index: u64,
    /// Windows the folded entry covers.
    pub windows: u64,
    /// Total entries retained across the pyramid after this fold.
    pub retained: u64,
}

/// The pyramid itself (see the module docs). All whole-run aggregates
/// the final report needs — window count, drop totals, lossy-window
/// count — are maintained exactly, so the report renders byte-identical
/// to the flat history it replaces.
pub struct TierPyramid {
    base: usize,
    /// `levels[l]` holds the at-rest entries of level `l`, oldest
    /// first. At most `base - 1` per level (a `base`-th arrival folds).
    levels: Vec<Vec<TierEntry>>,
    pool: MergePool,
    windows_total: u64,
    slices_total: u64,
    drained_total: u64,
    drops_total: u64,
    lossy_windows: u64,
}

impl TierPyramid {
    /// A pyramid with fold base `B ≥ 2` (user-facing knobs validate
    /// earlier with a real error; the assert catches library misuse).
    pub fn new(base: usize) -> TierPyramid {
        assert!(base >= 2, "tier pyramid base must be >= 2");
        TierPyramid {
            base,
            levels: Vec::new(),
            pool: MergePool::new(),
            windows_total: 0,
            slices_total: 0,
            drained_total: 0,
            drops_total: 0,
            lossy_windows: 0,
        }
    }

    pub fn base(&self) -> usize {
        self.base
    }

    /// Closed windows pushed so far (the T the pyramid compacts).
    pub fn windows_total(&self) -> u64 {
        self.windows_total
    }

    pub fn slices_total(&self) -> u64 {
        self.slices_total
    }

    pub fn drained_total(&self) -> u64 {
        self.drained_total
    }

    /// Ring drops summed over every closed window.
    pub fn drops_total(&self) -> u64 {
        self.drops_total
    }

    /// Closed windows that recorded ring drops.
    pub fn lossy_windows(&self) -> u64 {
        self.lossy_windows
    }

    /// Retained entries across all levels — the digit sum of
    /// [`windows_total`](TierPyramid::windows_total) in base B, so
    /// O(B·log T).
    pub fn entries(&self) -> u64 {
        self.levels.iter().map(|l| l.len() as u64).sum()
    }

    /// Levels currently materialized (⌊log_B T⌋ + 1 once T ≥ 1).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Retained merged paths summed over every entry (the memory-bound
    /// property tests this against O(entries × live stack ids)).
    pub fn retained_paths(&self) -> usize {
        self.levels
            .iter()
            .flat_map(|l| l.iter())
            .map(|e| e.paths.len())
            .sum()
    }

    /// Retained entries oldest-first: higher levels strictly predate
    /// lower ones (a level folds upward before newer windows land), and
    /// entries within a level are in push order.
    pub fn entries_chronological(&self) -> impl Iterator<Item = &TierEntry> {
        self.levels.iter().rev().flat_map(|l| l.iter())
    }

    /// Mutable chronological walk (the checkpoint writer fills each
    /// entry's serialization cache in place).
    pub fn entries_chronological_mut(
        &mut self,
    ) -> impl Iterator<Item = &mut TierEntry> {
        self.levels.iter_mut().rev().flat_map(|l| l.iter_mut())
    }

    /// Push one closed window (its summary plus merged path snapshot)
    /// and cascade any folds it triggers, lowest level first. Returns
    /// the folds performed, for event emission.
    pub fn push(
        &mut self,
        summary: WindowSummary,
        paths: Vec<MergedPath>,
    ) -> Vec<TierFold> {
        self.windows_total += 1;
        self.slices_total += summary.slices;
        self.drained_total += summary.drained;
        self.drops_total += summary.drops;
        let lossy = u64::from(summary.drops > 0);
        self.lossy_windows += lossy;
        if self.levels.is_empty() {
            self.levels.push(Vec::new());
        }
        self.levels[0].push(TierEntry {
            level: 0,
            first_index: summary.index,
            last_index: summary.index,
            summary,
            lossy_windows: lossy,
            paths,
            cached_json: None,
        });
        let mut folds = Vec::new();
        let mut l = 0;
        while self.levels[l].len() >= self.base {
            let drained = std::mem::take(&mut self.levels[l]);
            let folded = fold_entries(drained, (l + 1) as u32, &mut self.pool);
            if self.levels.len() <= l + 1 {
                self.levels.push(Vec::new());
            }
            self.levels[l + 1].push(folded);
            let e = self.levels[l + 1].last().unwrap();
            folds.push(TierFold {
                level: e.level,
                first_index: e.first_index,
                last_index: e.last_index,
                windows: e.windows(),
                retained: self.entries(),
            });
            l += 1;
        }
        folds
    }

    /// Fold every retained entry, oldest first, into the cumulative
    /// merge — byte-identical (fields *and* order) to the uncompacted
    /// run's per-window fold: entry spans are disjoint and
    /// chronological, and `first_seen` stamps increase across windows,
    /// so insertion order reproduces the flat ascending-stamp order
    /// exactly.
    pub fn merged_cumulative(&self) -> Vec<MergedPath> {
        let mut acc = PathAccumulator::new();
        for e in self.entries_chronological() {
            for p in &e.paths {
                acc.merge_path(p);
            }
        }
        acc.take_paths()
    }

    /// Aggregate summaries of the retained entries, oldest first (what
    /// the final event reports instead of the flat per-window list).
    pub fn summaries(&self) -> Vec<WindowSummary> {
        self.entries_chronological().map(|e| e.summary).collect()
    }

    /// Structural equality minus the folded paths: what a resume
    /// replay — which deliberately skips rebuilding analysis state —
    /// can verify against the checkpointed pyramid.
    pub fn same_shape(&self, other: &TierPyramid) -> bool {
        self.base == other.base
            && self.windows_total == other.windows_total
            && self.slices_total == other.slices_total
            && self.drained_total == other.drained_total
            && self.drops_total == other.drops_total
            && self.lossy_windows == other.lossy_windows
            && self
                .entries_chronological()
                .map(TierEntry::shape)
                .eq(other.entries_chronological().map(TierEntry::shape))
    }

    /// Rebuild a pyramid from checkpointed entries (chronological,
    /// oldest first). Totals are recomputed from the entries; callers
    /// cross-check them against the checkpoint's stored totals. Errors
    /// loudly on shapes no push sequence can produce.
    pub fn restore(base: usize, entries: Vec<TierEntry>) -> Result<TierPyramid, String> {
        if base < 2 {
            return Err("tier pyramid base must be >= 2".to_string());
        }
        let mut p = TierPyramid::new(base);
        let mut next_index = 1u64;
        let mut prev_level: Option<u32> = None;
        for e in entries {
            if e.first_index != next_index {
                return Err(format!(
                    "tier checkpoint is not contiguous: entry covering windows \
                     {}..={} follows window {}",
                    e.first_index,
                    e.last_index,
                    next_index - 1
                ));
            }
            if e.last_index < e.first_index || e.summary.index != e.last_index {
                return Err(format!(
                    "tier checkpoint entry covering windows {}..={} is \
                     inconsistent with its summary (index {})",
                    e.first_index, e.last_index, e.summary.index
                ));
            }
            if let Some(prev) = prev_level {
                if e.level > prev {
                    return Err(format!(
                        "tier checkpoint levels are not chronological: a \
                         level-{} entry follows a level-{} entry",
                        e.level, prev
                    ));
                }
            }
            prev_level = Some(e.level);
            next_index = e.last_index + 1;
            p.windows_total += e.windows();
            p.slices_total += e.summary.slices;
            p.drained_total += e.summary.drained;
            p.drops_total += e.summary.drops;
            p.lossy_windows += e.lossy_windows;
            let level = e.level as usize;
            while p.levels.len() <= level {
                p.levels.push(Vec::new());
            }
            if p.levels[level].len() + 1 >= base {
                return Err(format!(
                    "tier checkpoint holds {} entries at level {level}, but a \
                     base-{base} pyramid folds at {base} — it was written by a \
                     different configuration",
                    p.levels[level].len() + 1
                ));
            }
            p.levels[level].push(e);
        }
        // Entries landed grouped by level in arrival (chronological)
        // order; the chronological walk reads highest level first,
        // which matches because the monotonicity check above
        // guarantees higher levels exclusively hold older windows.
        Ok(p)
    }
}

/// Collapse a full level (oldest first) into one entry a level up.
fn fold_entries(entries: Vec<TierEntry>, level: u32, pool: &mut MergePool) -> TierEntry {
    debug_assert!(entries.len() >= 2, "a fold needs at least two entries");
    let first_index = entries.first().unwrap().first_index;
    let last_index = entries.last().unwrap().last_index;
    let mut summary = WindowSummary {
        index: last_index,
        slices: 0,
        drained: 0,
        drops: 0,
    };
    let mut lossy_windows = 0u64;
    let mut parts = Vec::with_capacity(entries.len());
    for e in entries {
        summary.slices += e.summary.slices;
        summary.drained += e.summary.drained;
        summary.drops += e.summary.drops;
        lossy_windows += e.lossy_windows;
        parts.push(e.paths);
    }
    // The associative merge tree reconciles order through `first_seen`,
    // so the folded snapshot equals the serial fold of the span.
    let paths = merge_tree_pooled(parts, pool);
    TierEntry {
        level,
        first_index,
        last_index,
        summary,
        lossy_windows,
        paths,
        cached_json: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::userspace::SliceEntry;
    use crate::gapp::stream::WindowAccumulator;
    use crate::simkernel::WaitKind;
    use crate::util::check::property;
    use crate::util::Prng;

    /// Synthetic slice with a globally increasing capture stamp, the
    /// invariant the windowed driver provides (stamps are assigned in
    /// time order and windows partition time).
    fn slice(stamp: u64, id_space: u64) -> SliceEntry {
        SliceEntry {
            ts_id: stamp,
            pid: 1 + (stamp % 4) as u32,
            cm_ns: 5.0 + (stamp % 17) as f64 * 1.375,
            threads_av: 1.0,
            stack_id: (stamp % id_space) as u32,
            addrs: vec![0x100 + stamp % 5],
            from_stack_top: false,
            wait: WaitKind::Futex,
            woken_by: 0,
        }
    }

    /// Build `t` windows of `per` slices each; returns the per-window
    /// (summary, snapshot) pairs plus the flat cumulative fold.
    fn synth_windows(
        t: u64,
        per: u64,
        id_space: u64,
        drops_of: impl Fn(u64) -> u64,
    ) -> (Vec<(WindowSummary, Vec<MergedPath>)>, Vec<MergedPath>) {
        let mut stamp = 0u64;
        let mut wacc = WindowAccumulator::new();
        let mut flat = PathAccumulator::new();
        let mut windows = Vec::new();
        for index in 1..=t {
            for _ in 0..per {
                stamp += 1;
                wacc.add_slice(&slice(stamp, id_space), 0);
            }
            let snap = wacc.snapshot();
            for p in &snap {
                flat.merge_path(p);
            }
            windows.push((
                WindowSummary {
                    index,
                    slices: per,
                    drained: per * 2,
                    drops: drops_of(index),
                },
                snap,
            ));
        }
        (windows, flat.take_paths())
    }

    /// Digit sum of `n` written in base `b` — the exact retained-entry
    /// count of a pyramid after `n` pushes.
    fn digit_sum(mut n: u64, b: u64) -> u64 {
        let mut s = 0;
        while n > 0 {
            s += n % b;
            n /= b;
        }
        s
    }

    fn assert_paths_equal(a: &[MergedPath], b: &[MergedPath]) {
        assert_eq!(a.len(), b.len(), "path count diverged");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.stack_id, y.stack_id, "path order diverged");
            assert_eq!(x.cm_fs, y.cm_fs);
            assert_eq!(x.first_seen, y.first_seen);
            assert_eq!(x.slices, y.slices);
            assert_eq!(x.addr_freq, y.addr_freq);
            assert_eq!(x.wait_hist, y.wait_hist);
            assert_eq!(x.wakers, y.wakers);
            assert_eq!(x.app_slices, y.app_slices);
        }
    }

    #[test]
    fn compacted_cumulative_is_byte_identical_to_the_flat_fold() {
        for base in [2usize, 3, 4, 8] {
            for t in [1u64, 7, 16, 65] {
                let (windows, flat) =
                    synth_windows(t, 9, 13, |i| if i % 5 == 0 { 3 } else { 0 });
                let mut p = TierPyramid::new(base);
                for (summary, snap) in windows {
                    p.push(summary, snap);
                }
                assert_paths_equal(&flat, &p.merged_cumulative());
                assert_eq!(p.windows_total(), t, "base {base} t {t}");
                assert_eq!(p.drops_total(), (t / 5) * 3);
                assert_eq!(p.lossy_windows(), t / 5);
                assert_eq!(p.entries(), digit_sum(t, base as u64));
            }
        }
    }

    #[test]
    fn folds_cascade_and_report_their_spans() {
        let (windows, _) = synth_windows(8, 4, 7, |_| 0);
        let mut p = TierPyramid::new(2);
        let mut all_folds = Vec::new();
        for (summary, snap) in windows {
            all_folds.push(p.push(summary, snap));
        }
        // Base 2, 8 windows: pushes 2, 4, 6, 8 fold; 4 and 8 cascade.
        assert!(all_folds[0].is_empty() && all_folds[2].is_empty());
        assert_eq!(all_folds[1].len(), 1); // windows 1-2 → level 1
        assert_eq!(all_folds[3].len(), 2); // 3-4 → L1, then 1-4 → L2
        assert_eq!(all_folds[7].len(), 3); // 7-8 → L1, 5-8 → L2, 1-8 → L3
        let last = all_folds[7][2];
        assert_eq!(
            (last.level, last.first_index, last.last_index, last.windows),
            (3, 1, 8, 8)
        );
        assert_eq!(last.retained, 1); // the whole run collapsed into one
        assert_eq!(p.depth(), 4);
        // Chronology: higher levels strictly precede lower ones.
        let spans: Vec<(u64, u64)> = p
            .entries_chronological()
            .map(|e| (e.first_index, e.last_index))
            .collect();
        for w in spans.windows(2) {
            assert_eq!(w[1].0, w[0].1 + 1, "spans must be contiguous");
        }
    }

    /// The headline memory bound, against a 10k-window synthetic run:
    /// retained entries are exactly the base-B digit sum of T (never
    /// O(T)), and retained paths are bounded by entries × the live id
    /// space — O(K + live stack ids + B·log T) overall.
    #[test]
    fn ten_thousand_windows_retain_logarithmic_state() {
        let id_space = 17u64;
        let (windows, flat) = synth_windows(10_000, 3, id_space, |_| 0);
        let mut p = TierPyramid::new(4);
        for (summary, snap) in windows {
            p.push(summary, snap);
        }
        assert_eq!(p.windows_total(), 10_000);
        assert_eq!(p.entries(), digit_sum(10_000, 4));
        assert!(p.entries() <= 3 * 8, "digit sum of 10k in base 4");
        assert!(
            p.retained_paths() as u64 <= p.entries() * id_space,
            "retained paths {} must be bounded by entries {} × ids {}",
            p.retained_paths(),
            p.entries(),
            id_space
        );
        // And the report is still exact.
        assert_paths_equal(&flat, &p.merged_cumulative());
    }

    #[test]
    fn memory_bound_holds_over_randomized_run_lengths() {
        property("tier pyramid memory bound", 24, |rng: &mut Prng| {
            let base = 2 + rng.below(7) as usize;
            let t = 1 + rng.below(600);
            let id_space = 3 + rng.below(20);
            let (windows, flat) =
                synth_windows(t, 1 + rng.below(6), id_space, |i| i % 7);
            let mut p = TierPyramid::new(base);
            for (summary, snap) in windows {
                p.push(summary, snap);
            }
            assert_eq!(p.entries(), digit_sum(t, base as u64));
            assert!(p.entries() <= (base as u64 - 1) * (p.depth() as u64));
            assert!(p.retained_paths() as u64 <= p.entries() * id_space);
            assert_paths_equal(&flat, &p.merged_cumulative());
            // Aggregates survive every fold exactly.
            assert_eq!(p.windows_total(), t);
            assert_eq!(p.drops_total(), (1..=t).map(|i| i % 7).sum::<u64>());
            assert_eq!(
                p.lossy_windows(),
                (1..=t).filter(|i| i % 7 != 0).count() as u64
            );
            assert_eq!(
                p.summaries().iter().map(|s| s.slices).sum::<u64>(),
                p.slices_total()
            );
        });
    }

    #[test]
    fn restore_round_trips_and_rejects_foreign_shapes() {
        let (windows, _) = synth_windows(11, 5, 9, |i| i % 3);
        let mut p = TierPyramid::new(3);
        for (summary, snap) in windows {
            p.push(summary, snap);
        }
        let entries: Vec<TierEntry> =
            p.entries_chronological().cloned().collect();
        let r = TierPyramid::restore(3, entries.clone()).unwrap();
        assert!(p.same_shape(&r));
        assert_paths_equal(&p.merged_cumulative(), &r.merged_cumulative());
        // A replayed (paths-free) pyramid still matches shapes.
        let mut replay = TierPyramid::new(3);
        let (windows2, _) = synth_windows(11, 5, 9, |i| i % 3);
        for (summary, _snap) in windows2 {
            replay.push(summary, Vec::new());
        }
        assert!(replay.same_shape(&p));
        // …and diverging histories are caught.
        let mut other = TierPyramid::new(3);
        let (windows3, _) = synth_windows(11, 5, 9, |_| 0);
        for (summary, _snap) in windows3 {
            other.push(summary, Vec::new());
        }
        assert!(!other.same_shape(&p));
        // Impossible restores error loudly.
        let err = TierPyramid::restore(1, Vec::new()).unwrap_err();
        assert!(err.contains(">= 2"), "{err}");
        let mut gap = entries.clone();
        gap.remove(0);
        let err = TierPyramid::restore(3, gap).unwrap_err();
        assert!(err.contains("contiguous"), "{err}");
        let mut unsorted = entries;
        unsorted.reverse();
        assert!(TierPyramid::restore(3, unsorted).is_err());
    }
}
