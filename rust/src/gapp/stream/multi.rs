//! System-wide mode: several applications share one simulated kernel,
//! and every bottleneck in the live report is attributed to the
//! application that owns it.
//!
//! Attribution is learned the way a real system-wide deployment learns
//! it — from the `task_newtask` tracepoint. Root threads are tagged with
//! the application being spawned; children inherit their parent's tag,
//! so whole process trees attribute correctly without any cooperation
//! from the workload.

use std::sync::{Arc, RwLock};

use crate::simkernel::{Event, Pid, Probe};
use crate::util::PidMap;

/// pid → application-id registry for one system-wide session.
#[derive(Debug, Default)]
pub struct AppRegistry {
    names: Vec<String>,
    of: PidMap<u16>,
    /// Application currently being spawned (root-thread tagging window).
    spawning: Option<u16>,
}

impl AppRegistry {
    pub fn new() -> AppRegistry {
        AppRegistry::default()
    }

    /// Open the tagging window for one application's root threads.
    /// Returns its application id.
    pub fn begin_app(&mut self, name: &str) -> u16 {
        let id = self.names.len() as u16;
        self.names.push(name.to_string());
        self.spawning = Some(id);
        id
    }

    /// Close the tagging window (after `App::spawn_into` returns).
    pub fn end_spawn(&mut self) {
        self.spawning = None;
    }

    /// `task_newtask` handler: tag roots with the app being spawned,
    /// children with their parent's app.
    pub fn on_task_new(&mut self, pid: Pid, parent: Pid) {
        let app = match self.spawning {
            Some(a) => Some(a),
            None => self.of.get(parent).copied(),
        };
        if let Some(a) = app {
            self.of.insert(pid, a);
        }
    }

    /// Application id of `pid` (0 — the first app — when unknown).
    pub fn app_of(&self, pid: Pid) -> u16 {
        self.of.get(pid).copied().unwrap_or(0)
    }

    /// Attribution closure for the window folders. A pid's application
    /// is assigned at `task_newtask` (before any of its slices can
    /// exist) and never changes, so attribution is insensitive to
    /// *when* a slice is folded — mid-epoch watermark drain, epoch
    /// close, serial stream or shard-local lane all agree. That
    /// invariant is what keeps the per-app registry correct under the
    /// merge tree without any per-shard registry state.
    pub fn tagger(&self) -> impl Fn(Pid) -> u16 + '_ {
        move |pid| self.app_of(pid)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

/// Zero-cost probe feeding `task_newtask` events into the registry.
/// Costs nothing on the simulated timeline, so attaching it cannot
/// perturb a run relative to a single-app batch profile (the streaming
/// golden tests depend on that).
///
/// The registry is shared as `Arc<RwLock<..>>` so parallel lane workers
/// (`--lane-threads N`) can read attribution concurrently while the
/// driver thread writes `task_newtask` updates. A pid's app is assigned
/// before any of its slices can be drained and handed to a worker, so a
/// worker's read never races the write that matters to it.
pub struct RegistryProbe {
    reg: Arc<RwLock<AppRegistry>>,
}

impl RegistryProbe {
    pub fn new(reg: Arc<RwLock<AppRegistry>>) -> RegistryProbe {
        RegistryProbe { reg }
    }
}

impl Probe for RegistryProbe {
    fn on_event(&mut self, ev: &Event<'_>) -> u64 {
        if let Event::TaskNew { pid, parent, .. } = ev {
            self.reg.write().unwrap().on_task_new(*pid, *parent);
        }
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roots_tagged_children_inherit() {
        let mut r = AppRegistry::new();
        let a = r.begin_app("mysql");
        r.on_task_new(1, 0);
        r.on_task_new(2, 0);
        r.end_spawn();
        let b = r.begin_app("dedup");
        r.on_task_new(3, 0);
        r.end_spawn();
        // Children spawned during the run inherit their parent's app.
        r.on_task_new(10, 2);
        r.on_task_new(11, 3);
        r.on_task_new(12, 10);
        assert_eq!((a, b), (0, 1));
        assert_eq!(r.app_of(1), 0);
        assert_eq!(r.app_of(2), 0);
        assert_eq!(r.app_of(3), 1);
        assert_eq!(r.app_of(10), 0);
        assert_eq!(r.app_of(11), 1);
        assert_eq!(r.app_of(12), 0);
        assert_eq!(r.names(), &["mysql".to_string(), "dedup".to_string()]);
    }

    #[test]
    fn unknown_pids_default_to_app_zero() {
        let r = AppRegistry::new();
        assert_eq!(r.app_of(99), 0);
    }

    #[test]
    fn probe_feeds_registry_at_zero_cost() {
        let reg = Arc::new(RwLock::new(AppRegistry::new()));
        reg.write().unwrap().begin_app("a");
        let mut probe = RegistryProbe::new(reg.clone());
        let cost = probe.on_event(&Event::TaskNew {
            time: 0,
            cpu: 0,
            pid: 5,
            parent: 0,
            comm: "t",
        });
        assert_eq!(cost, 0);
        reg.write().unwrap().end_spawn();
        assert_eq!(reg.read().unwrap().app_of(5), 0);
    }
}
