//! The final profile: a frequency table of functions and source lines
//! per critical call path, plus the run statistics behind Table 2.

use std::collections::HashMap;
use std::fmt;

use crate::simkernel::Pid;

use super::classify::BottleneckClass;

/// One resolved sample line in a bottleneck entry.
#[derive(Clone, Debug)]
pub struct SampleLine {
    pub rendered: String,
    /// Bare function name when resolvable (used by assertions/benches).
    pub function: Option<String>,
    pub count: u64,
}

/// One ranked bottleneck (a merged call path).
#[derive(Clone, Debug)]
pub struct Bottleneck {
    pub rank: usize,
    pub total_cm_ms: f64,
    pub slices: u64,
    /// §7 extension: the bottleneck's class (futex / barrier / queue /
    /// I/O / messaging / compute), from the per-slice wait kinds.
    pub class: BottleneckClass,
    /// §7 extension: threads whose wakeups gated these slices
    /// ("critical lock holders"), as (comm, count), descending.
    pub top_wakers: Vec<(String, u64)>,
    /// System-wide mode: slice counts per application, descending
    /// (empty for single-app profiles, so batch reports are unchanged).
    pub apps: Vec<(String, u64)>,
    /// Symbolized call path, outermost → innermost.
    pub call_path: Vec<String>,
    /// Sample frequency table, descending by count.
    pub samples: Vec<SampleLine>,
    pub stack_top_samples: u64,
}

/// Per-thread CMetric totals (Figures 4 and 5 are plots of this).
#[derive(Clone, Debug)]
pub struct ThreadCm {
    pub pid: Pid,
    pub comm: String,
    pub cm_ms: f64,
    pub wall_ms: f64,
}

/// Full profiling report.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub app: String,
    pub backend: &'static str,
    /// Simulated application runtime under the profiler (ns).
    pub runtime_ns: u64,
    pub bottlenecks: Vec<Bottleneck>,
    pub threads: Vec<ThreadCm>,
    // ---- Table-2 style statistics --------------------------------------
    pub total_slices: u64,
    pub critical_slices: u64,
    pub samples: u64,
    pub intervals: u64,
    pub ring_dropped: u64,
    /// Per-shard ring counters (one entry per per-CPU ring; a single
    /// entry for `--shards 1`). `ring_dropped` is their summed drops;
    /// the breakdown shows *which* CPU's buffer needs more pages when
    /// records were lost.
    pub ring_shards: Vec<crate::ebpf::RingBufStats>,
    /// Distinct call paths interned by the in-kernel stack map
    /// (`bpf_get_stackid`-style ids carried by ring records).
    pub stack_ids: u64,
    /// New stacks dropped because a stack map hit capacity — nonzero
    /// means `GappConfig::stack_map_entries` needs raising, exactly like
    /// tuning a real `BPF_MAP_TYPE_STACK_TRACE` max_entries. In `live`
    /// LRU mode this also includes drops from the stable userspace
    /// re-intern map, so saturation anywhere in the pipeline is visible.
    pub stack_drops: u64,
    /// Stacks evicted to recycle their ids (`GappConfig::stack_lru`).
    pub stack_evictions: u64,
    /// Streaming analyzer only: ring-buffer drops attributed to the
    /// epoch window in which they occurred (index = window). Empty for
    /// batch profiles, whose single global figure is `ring_dropped`.
    pub window_drops: Vec<u64>,
    /// Peak memory estimate, bytes (column M).
    pub memory_bytes: u64,
    /// Post-processing time, host seconds (column PPT).
    pub ppt_seconds: f64,
    /// Total probe cost charged to the app's CPUs (ns).
    pub probe_cost_ns: u64,
}

impl Report {
    /// Critical ratio CR (critical / total timeslices).
    pub fn critical_ratio(&self) -> f64 {
        if self.total_slices == 0 {
            0.0
        } else {
            self.critical_slices as f64 / self.total_slices as f64
        }
    }

    /// Top critical *functions* across all ranked paths — the headline
    /// the paper quotes per app in Table 2. Aggregates sample counts by
    /// function name over all bottleneck entries.
    pub fn top_functions(&self, n: usize) -> Vec<(String, u64)> {
        let mut freq: HashMap<&str, u64> = HashMap::new();
        for b in &self.bottlenecks {
            for s in &b.samples {
                if let Some(f) = &s.function {
                    *freq.entry(f.as_str()).or_insert(0) += s.count;
                }
            }
        }
        let mut v: Vec<(String, u64)> =
            freq.into_iter().map(|(k, c)| (k.to_string(), c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }

    /// Total sample count attributed to a given function name.
    pub fn samples_of(&self, function: &str) -> u64 {
        self.bottlenecks
            .iter()
            .flat_map(|b| b.samples.iter())
            .filter(|s| s.function.as_deref() == Some(function))
            .map(|s| s.count)
            .sum()
    }

    /// CMetric per thread as (comm, cm_ms), in pid order.
    pub fn thread_cm_series(&self) -> Vec<(String, f64)> {
        self.threads
            .iter()
            .map(|t| (t.comm.clone(), t.cm_ms))
            .collect()
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "== GAPP profile: {} (backend: {}) ==", self.app, self.backend)?;
        writeln!(
            f,
            "runtime {:.1} ms | slices {} (critical {} = {:.2}%) | samples {} | stacks {}{} | mem {:.1} MB | ppt {:.2} s",
            self.runtime_ns as f64 / 1e6,
            self.total_slices,
            self.critical_slices,
            100.0 * self.critical_ratio(),
            self.samples,
            self.stack_ids,
            if self.stack_drops > 0 {
                format!(" (+{} dropped)", self.stack_drops)
            } else {
                String::new()
            },
            self.memory_bytes as f64 / (1024.0 * 1024.0),
            self.ppt_seconds,
        )?;
        if !self.window_drops.is_empty() {
            let total: u64 = self.window_drops.iter().sum();
            let lossy = self.window_drops.iter().filter(|d| **d > 0).count();
            writeln!(
                f,
                "windows {} | ring drops {} in {} window(s)",
                self.window_drops.len(),
                total,
                lossy,
            )?;
        }
        // Per-shard breakdown, only when records were actually lost on a
        // multi-ring transport (lossless runs render identically across
        // shard counts — the sharded-vs-single-ring golden relies on it).
        if self.ring_dropped > 0 && self.ring_shards.len() > 1 {
            let lossy: Vec<String> = self
                .ring_shards
                .iter()
                .enumerate()
                .filter(|(_, s)| s.dropped > 0)
                .map(|(i, s)| format!("s{i} dropped {} (peak {})", s.dropped, s.peak))
                .collect();
            writeln!(f, "ring shards: {}", lossy.join(", "))?;
        }
        for b in &self.bottlenecks {
            writeln!(
                f,
                "\n#{} [{}] CMetric {:.2} ms over {} slices{}",
                b.rank,
                b.class.label(),
                b.total_cm_ms,
                b.slices,
                if b.stack_top_samples > 0 {
                    format!(" ({} stack-top)", b.stack_top_samples)
                } else {
                    String::new()
                }
            )?;
            writeln!(f, "  call path:")?;
            for (i, frame) in b.call_path.iter().enumerate() {
                writeln!(f, "    {:indent$}{}", "", frame, indent = i)?;
            }
            if !b.apps.is_empty() {
                let ap: Vec<String> = b
                    .apps
                    .iter()
                    .map(|(a, n)| format!("{a} x{n}"))
                    .collect();
                writeln!(f, "  apps: {}", ap.join(", "))?;
            }
            if !b.top_wakers.is_empty() {
                let wk: Vec<String> = b
                    .top_wakers
                    .iter()
                    .map(|(c, n)| format!("{c} x{n}"))
                    .collect();
                writeln!(f, "  woken by: {}", wk.join(", "))?;
            }
            writeln!(f, "  samples:")?;
            for s in b.samples.iter().take(6) {
                writeln!(f, "    {:>6}  {}", s.count, s.rendered)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> Report {
        Report {
            app: "test".into(),
            bottlenecks: vec![
                Bottleneck {
                    rank: 1,
                    total_cm_ms: 10.0,
                    slices: 5,
                    class: BottleneckClass::Synchronization,
                    top_wakers: vec![("parent".into(), 4)],
                    apps: vec![("mysql".into(), 4), ("dedup".into(), 1)],
                    call_path: vec!["main".into(), "emd".into()],
                    samples: vec![
                        SampleLine {
                            rendered: "emd (emd.c:57)".into(),
                            function: Some("emd".into()),
                            count: 7,
                        },
                        SampleLine {
                            rendered: "dist (d.c:9)".into(),
                            function: Some("dist".into()),
                            count: 3,
                        },
                    ],
                    stack_top_samples: 0,
                },
                Bottleneck {
                    rank: 2,
                    total_cm_ms: 4.0,
                    slices: 2,
                    class: BottleneckClass::Compute,
                    top_wakers: vec![],
                    apps: vec![],
                    call_path: vec!["main".into()],
                    samples: vec![SampleLine {
                        rendered: "emd (emd.c:60)".into(),
                        function: Some("emd".into()),
                        count: 2,
                    }],
                    stack_top_samples: 1,
                },
            ],
            total_slices: 100,
            critical_slices: 7,
            ..Default::default()
        }
    }

    #[test]
    fn critical_ratio_and_top_functions() {
        let r = report();
        assert!((r.critical_ratio() - 0.07).abs() < 1e-12);
        let top = r.top_functions(2);
        assert_eq!(top[0], ("emd".to_string(), 9));
        assert_eq!(top[1], ("dist".to_string(), 3));
        assert_eq!(r.samples_of("emd"), 9);
    }

    #[test]
    fn display_renders() {
        let s = report().to_string();
        assert!(s.contains("GAPP profile"));
        assert!(s.contains("emd (emd.c:57)"));
        assert!(s.contains("stack-top"));
        assert!(s.contains("synchronization (futex)"));
        assert!(s.contains("woken by: parent x4"));
        assert!(s.contains("apps: mysql x4, dedup x1"));
        // Batch report: no window line.
        assert!(!s.contains("windows "));
    }

    #[test]
    fn display_window_drops_line_only_when_streaming() {
        let mut r = report();
        r.window_drops = vec![0, 3, 0, 2];
        let s = r.to_string();
        assert!(s.contains("windows 4 | ring drops 5 in 2 window(s)"));
    }

    #[test]
    fn display_shard_breakdown_only_when_lossy_and_sharded() {
        use crate::ebpf::RingBufStats;
        let shard = |dropped: u64, peak: usize| RingBufStats {
            pushed: 10,
            dropped,
            drained: 10,
            peak,
        };
        // Lossless sharded run: no breakdown (byte-stable rendering).
        let mut r = report();
        r.ring_shards = vec![shard(0, 4), shard(0, 7)];
        assert!(!r.to_string().contains("ring shards"));
        // Lossy sharded run: only the lossy shards are listed.
        r.ring_dropped = 5;
        r.ring_shards = vec![shard(0, 4), shard(5, 9)];
        let s = r.to_string();
        assert!(s.contains("ring shards: s1 dropped 5 (peak 9)"), "{s}");
        assert!(!s.contains("s0 dropped"));
        // Lossy single ring: no breakdown line (nothing to break down).
        r.ring_shards = vec![shard(5, 9)];
        assert!(!r.to_string().contains("ring shards"));
    }
}
