//! The final profile: a frequency table of functions and source lines
//! per critical call path, plus the run statistics behind Table 2.

use std::fmt;
use std::sync::OnceLock;

use crate::simkernel::Pid;
use crate::util::FxHashMap;

use super::classify::BottleneckClass;

/// One resolved sample line in a bottleneck entry.
#[derive(Clone, Debug)]
pub struct SampleLine {
    pub rendered: String,
    /// Bare function name when resolvable (used by assertions/benches).
    pub function: Option<String>,
    pub count: u64,
}

/// One ranked bottleneck (a merged call path).
#[derive(Clone, Debug)]
pub struct Bottleneck {
    pub rank: usize,
    pub total_cm_ms: f64,
    pub slices: u64,
    /// §7 extension: the bottleneck's class (futex / barrier / queue /
    /// I/O / messaging / compute), from the per-slice wait kinds.
    pub class: BottleneckClass,
    /// §7 extension: threads whose wakeups gated these slices
    /// ("critical lock holders"), as (comm, count), descending.
    pub top_wakers: Vec<(String, u64)>,
    /// System-wide mode: slice counts per application, descending
    /// (empty for single-app profiles, so batch reports are unchanged).
    pub apps: Vec<(String, u64)>,
    /// Symbolized call path, outermost → innermost.
    pub call_path: Vec<String>,
    /// Sample frequency table, descending by count.
    pub samples: Vec<SampleLine>,
    pub stack_top_samples: u64,
}

/// Per-thread CMetric totals (Figures 4 and 5 are plots of this).
#[derive(Clone, Debug)]
pub struct ThreadCm {
    pub pid: Pid,
    pub comm: String,
    pub cm_ms: f64,
    pub wall_ms: f64,
}

/// Full profiling report.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub app: String,
    pub backend: &'static str,
    /// Simulated application runtime under the profiler (ns).
    pub runtime_ns: u64,
    pub bottlenecks: Vec<Bottleneck>,
    pub threads: Vec<ThreadCm>,
    // ---- Table-2 style statistics --------------------------------------
    pub total_slices: u64,
    pub critical_slices: u64,
    pub samples: u64,
    pub intervals: u64,
    pub ring_dropped: u64,
    /// Per-shard ring counters (one entry per per-CPU ring; a single
    /// entry for `--shards 1`). `ring_dropped` is their summed drops;
    /// the breakdown shows *which* CPU's buffer needs more pages when
    /// records were lost.
    pub ring_shards: Vec<crate::ebpf::RingBufStats>,
    /// Distinct call paths interned by the in-kernel stack map
    /// (`bpf_get_stackid`-style ids carried by ring records).
    pub stack_ids: u64,
    /// New stacks dropped because a stack map hit capacity — nonzero
    /// means `GappConfig::stack_map_entries` needs raising, exactly like
    /// tuning a real `BPF_MAP_TYPE_STACK_TRACE` max_entries. In `live`
    /// LRU mode this also includes drops from the stable userspace
    /// re-intern map, so saturation anywhere in the pipeline is visible.
    pub stack_drops: u64,
    /// Stacks evicted to recycle their ids (`GappConfig::stack_lru`).
    pub stack_evictions: u64,
    /// Streaming analyzer only: ring-buffer drops attributed to the
    /// epoch window in which they occurred (index = window). Empty for
    /// batch profiles, whose single global figure is `ring_dropped` —
    /// and empty under `--compact-base`, where the per-window breakdown
    /// is folded away and only the aggregates below survive.
    pub window_drops: Vec<u64>,
    /// Streaming analyzer only: windows closed over the whole run.
    /// Unlike `window_drops.len()` this survives tier compaction, so
    /// the renderers use it (0 for batch profiles, which render no
    /// window line at all).
    pub windows_total: u64,
    /// Windows that recorded ring drops (count of nonzero
    /// `window_drops` entries, maintained through compaction).
    pub windows_lossy: u64,
    /// Ring drops summed over all windows (equals `window_drops`'s sum
    /// when that breakdown is retained).
    pub windows_drop_total: u64,
    /// Graceful degradation (`--on-overflow degrade`): windows that
    /// widened by absorbing the next epoch instead of shedding records.
    /// Zero (and unrendered) for shed-policy and batch runs.
    pub degraded_windows: u64,
    /// Emergency ring drains performed to avert overflow under the
    /// degrade policy (each one kept records a shed run would drop).
    pub degraded_drains: u64,
    /// Peak memory estimate, bytes (column M).
    pub memory_bytes: u64,
    /// Post-processing time, host seconds (column PPT).
    pub ppt_seconds: f64,
    /// Total probe cost charged to the app's CPUs (ns).
    pub probe_cost_ns: u64,
    /// Lazily-built function-name → total-samples index behind
    /// [`Report::samples_of`] / [`Report::top_functions`] (those used
    /// to rescan every bottleneck's sample table per query). Built on
    /// first query; a `Report` is immutable once assembled, so the
    /// cache never invalidates — mutating `bottlenecks` *after* the
    /// first query is outside the contract and will not be reflected
    /// (see `fn_index_is_built_once` in the tests). `OnceLock`, not
    /// `OnceCell`, so the cache does not cost `Report` its `Sync`.
    pub(crate) fn_index: OnceLock<FxHashMap<String, u64>>,
}

impl Report {
    /// Critical ratio CR (critical / total timeslices). An empty run
    /// (zero total slices) is 0.0, never NaN — `0/0` would otherwise
    /// propagate into every rendered and serialized output
    /// (regression-tested, and JSON cannot even represent NaN).
    pub fn critical_ratio(&self) -> f64 {
        if self.total_slices == 0 {
            0.0
        } else {
            self.critical_slices as f64 / self.total_slices as f64
        }
    }

    /// The function-frequency index, built on first use (one pass over
    /// every bottleneck's sample table; queries after that are O(1)
    /// lookups / O(F log F) sorts instead of per-query rescans).
    fn fn_freq(&self) -> &FxHashMap<String, u64> {
        self.fn_index.get_or_init(|| {
            let mut freq: FxHashMap<String, u64> = FxHashMap::default();
            for b in &self.bottlenecks {
                for s in &b.samples {
                    if let Some(f) = &s.function {
                        *freq.entry(f.clone()).or_insert(0) += s.count;
                    }
                }
            }
            freq
        })
    }

    /// Top critical *functions* across all ranked paths — the headline
    /// the paper quotes per app in Table 2. Aggregates sample counts by
    /// function name over all bottleneck entries.
    ///
    /// Ordering contract (relied on by the experiment tables and the
    /// figure goldens): descending by total sample count, ties broken
    /// by ascending function name — fully deterministic regardless of
    /// index iteration order.
    pub fn top_functions(&self, n: usize) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> = self
            .fn_freq()
            .iter()
            .map(|(k, c)| (k.clone(), *c))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }

    /// Total sample count attributed to a given function name. O(1)
    /// after the first query (was an O(bottlenecks × samples) scan per
    /// call — the experiment harness queries dozens of functions per
    /// report).
    pub fn samples_of(&self, function: &str) -> u64 {
        self.fn_freq().get(function).copied().unwrap_or(0)
    }

    /// CMetric per thread as (comm, cm_ms), in pid order.
    pub fn thread_cm_series(&self) -> Vec<(String, f64)> {
        self.threads
            .iter()
            .map(|t| (t.comm.clone(), t.cm_ms))
            .collect()
    }
}

impl fmt::Display for Report {
    /// Delegates to [`crate::gapp::sink::human::render_report`] — the
    /// renderer moved out of the data struct and into the text sink
    /// backend; this impl only keeps `println!("{report}")`-style
    /// callers working (and is pinned byte-identical by the sink
    /// golden tests).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&crate::gapp::sink::human::render_report(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> Report {
        Report {
            app: "test".into(),
            bottlenecks: vec![
                Bottleneck {
                    rank: 1,
                    total_cm_ms: 10.0,
                    slices: 5,
                    class: BottleneckClass::Synchronization,
                    top_wakers: vec![("parent".into(), 4)],
                    apps: vec![("mysql".into(), 4), ("dedup".into(), 1)],
                    call_path: vec!["main".into(), "emd".into()],
                    samples: vec![
                        SampleLine {
                            rendered: "emd (emd.c:57)".into(),
                            function: Some("emd".into()),
                            count: 7,
                        },
                        SampleLine {
                            rendered: "dist (d.c:9)".into(),
                            function: Some("dist".into()),
                            count: 3,
                        },
                    ],
                    stack_top_samples: 0,
                },
                Bottleneck {
                    rank: 2,
                    total_cm_ms: 4.0,
                    slices: 2,
                    class: BottleneckClass::Compute,
                    top_wakers: vec![],
                    apps: vec![],
                    call_path: vec!["main".into()],
                    samples: vec![SampleLine {
                        rendered: "emd (emd.c:60)".into(),
                        function: Some("emd".into()),
                        count: 2,
                    }],
                    stack_top_samples: 1,
                },
            ],
            total_slices: 100,
            critical_slices: 7,
            ..Default::default()
        }
    }

    #[test]
    fn critical_ratio_and_top_functions() {
        let r = report();
        assert!((r.critical_ratio() - 0.07).abs() < 1e-12);
        let top = r.top_functions(2);
        assert_eq!(top[0], ("emd".to_string(), 9));
        assert_eq!(top[1], ("dist".to_string(), 3));
        assert_eq!(r.samples_of("emd"), 9);
        assert_eq!(r.samples_of("not_present"), 0);
    }

    #[test]
    fn critical_ratio_of_empty_run_is_zero_not_nan() {
        // Regression: an empty run (canceled app, zero-length window
        // session) has 0 total slices; 0/0 must not leak NaN into the
        // ratio, the rendered header, or the JSON output.
        let r = Report::default();
        assert_eq!(r.critical_ratio(), 0.0);
        assert!(r.critical_ratio().is_finite());
        let s = r.to_string();
        assert!(s.contains("critical 0 = 0.00%"), "{s}");
        assert!(!s.contains("NaN"), "{s}");
    }

    #[test]
    fn top_functions_ordering_contract_is_count_desc_then_name_asc() {
        // samples_of/top_functions are index-backed now; the ordering
        // contract (count desc, name asc on ties) must hold no matter
        // how the index iterates.
        let mut r = report();
        // Give "aaa" and "zzz" the same count as "dist".
        r.bottlenecks[1].samples = vec![
            SampleLine {
                rendered: "zzz (z.c:1)".into(),
                function: Some("zzz".into()),
                count: 3,
            },
            SampleLine {
                rendered: "aaa (a.c:1)".into(),
                function: Some("aaa".into()),
                count: 3,
            },
        ];
        let top = r.top_functions(10);
        assert_eq!(
            top,
            vec![
                ("emd".to_string(), 7),
                ("aaa".to_string(), 3),
                ("dist".to_string(), 3),
                ("zzz".to_string(), 3),
            ]
        );
        // Truncation keeps the prefix of that same order.
        assert_eq!(r.top_functions(2), top[..2].to_vec());
    }

    #[test]
    fn report_stays_send_and_sync() {
        // The lazy index must not cost Report its auto traits — library
        // users hand finished reports to other threads.
        fn assert_traits<T: Send + Sync>() {}
        assert_traits::<Report>();
    }

    #[test]
    fn fn_index_is_built_once() {
        // The documented contract: the index freezes the sample tables
        // at first query; the two queries must agree with each other
        // (and a clone carries the cache along consistently).
        let r = report();
        let before = r.top_functions(10);
        assert_eq!(r.samples_of("emd"), 9);
        let clone = r.clone();
        assert_eq!(clone.top_functions(10), before);
        assert_eq!(clone.samples_of("dist"), 3);
    }

    #[test]
    fn display_renders() {
        let s = report().to_string();
        assert!(s.contains("GAPP profile"));
        assert!(s.contains("emd (emd.c:57)"));
        assert!(s.contains("stack-top"));
        assert!(s.contains("synchronization (futex)"));
        assert!(s.contains("woken by: parent x4"));
        assert!(s.contains("apps: mysql x4, dedup x1"));
        // Batch report: no window line.
        assert!(!s.contains("windows "));
    }

    #[test]
    fn display_window_drops_line_only_when_streaming() {
        let mut r = report();
        r.window_drops = vec![0, 3, 0, 2];
        r.windows_total = 4;
        r.windows_lossy = 2;
        r.windows_drop_total = 5;
        let s = r.to_string();
        assert!(s.contains("windows 4 | ring drops 5 in 2 window(s)"));
        // Under --compact-base the per-window breakdown is folded away
        // but the aggregates survive — the line renders identically.
        r.window_drops = Vec::new();
        assert_eq!(r.to_string(), s);
    }

    #[test]
    fn display_shard_breakdown_only_when_lossy_and_sharded() {
        use crate::ebpf::RingBufStats;
        let shard = |dropped: u64, peak: usize| RingBufStats {
            pushed: 10,
            dropped,
            drained: 10,
            peak,
        };
        // Lossless sharded run: no breakdown (byte-stable rendering).
        let mut r = report();
        r.ring_shards = vec![shard(0, 4), shard(0, 7)];
        assert!(!r.to_string().contains("ring shards"));
        // Lossy sharded run: only the lossy shards are listed.
        r.ring_dropped = 5;
        r.ring_shards = vec![shard(0, 4), shard(5, 9)];
        let s = r.to_string();
        assert!(s.contains("ring shards: s1 dropped 5 (peak 9)"), "{s}");
        assert!(!s.contains("s0 dropped"));
        // Lossy single ring: no breakdown line (nothing to break down).
        r.ring_shards = vec![shard(5, 9)];
        assert!(!r.to_string().contains("ring shards"));
    }
}
