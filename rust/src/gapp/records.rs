//! Records exchanged between the kernel probes and the user-space probe
//! through the eBPF circular buffer (paper Figure 2).
//!
//! Every record is fixed-size `Copy` POD — exactly what a real perf/BPF
//! ring buffer carries. Critical-slice records reference their call path
//! by stack id (interned in-kernel by [`crate::ebpf::StackMap`], the
//! `bpf_get_stackid()` mechanism) instead of owning a frame vector, so
//! pushing and popping records never touches the heap.

use crate::simkernel::{Pid, Time, WaitKind};

/// Bitmask over the 128 thread slots of one activity-matrix row.
pub type SlotMask = [u64; 2];

#[inline]
pub fn mask_set(m: &mut SlotMask, slot: usize) {
    m[slot / 64] |= 1 << (slot % 64);
}

#[inline]
pub fn mask_clear(m: &mut SlotMask, slot: usize) {
    m[slot / 64] &= !(1 << (slot % 64));
}

#[inline]
pub fn mask_count(m: &SlotMask) -> u32 {
    m[0].count_ones() + m[1].count_ones()
}

/// One circular-buffer record (fixed-size, `Copy`, no heap fields).
#[derive(Clone, Copy, Debug)]
pub enum Record {
    /// A thread slot was assigned to / freed from a pid (lets the
    /// user-space side attribute activity-matrix columns to threads).
    SlotAssign { pid: Pid, slot: usize },
    SlotFree { pid: Pid, slot: usize },
    /// One switching interval: duration and the set of active app
    /// threads during it. These rows feed the batched XLA analysis.
    Interval { dur: Time, mask: SlotMask },
    /// End of a *critical* timeslice (threads_av < N_min): CMetric delta,
    /// the interned id of the stack walked at the switch, and the IP at
    /// switch-out.
    SliceEnd {
        ts_id: u64,
        pid: Pid,
        cm_ns: f64,
        threads_av: f64,
        ip: u64,
        /// Stack id from the in-kernel stack map
        /// ([`crate::ebpf::STACK_ID_DROPPED`] when interning failed).
        stack_id: u32,
        /// Innermost captured frame, carried inline so the user probe's
        /// "from stack top" fallback (§4.4) needs no map lookup.
        stack_top: u64,
        /// What the thread blocked on at the end of this slice (§7
        /// classification extension; None = preempted/exited).
        wait: WaitKind,
        /// The thread whose wakeup started this slice (0 = none/timer) —
        /// the §7 "futex waker" attribution that separates critical from
        /// non-critical lock holders.
        woken_by: Pid,
    },
    /// End of a non-critical timeslice: the user probe must discard any
    /// sampled instruction pointers accumulated for this thread (§4.4).
    SliceDiscard { pid: Pid },
    /// Sampling-probe hit: IP of an app thread while the active-thread
    /// count was below N_min (§4.3).
    Sample { pid: Pid, ip: u64 },
    /// Filler record carrying no analysis payload. Fault injection uses
    /// it to model a burst of unrelated ring traffic (another tracer
    /// sharing the buffer, a perf storm): it consumes ring capacity and
    /// drain bandwidth but folds into nothing downstream.
    Noise,
}

// Compile-time guarantees: records stay POD-sized and trivially
// copyable (the zero-allocation ring-buffer contract). The sharded
// transport wraps each record in a 16-byte `(time, seq)` capture stamp
// — the perf-record-header analogue — which must keep the wire size
// within two cache lines.
const _: () = {
    const fn assert_copy<T: Copy>() {}
    assert_copy::<Record>();
    assert_copy::<crate::ebpf::Stamped<Record>>();
    assert!(std::mem::size_of::<Record>() <= 64);
    assert!(std::mem::size_of::<crate::ebpf::Stamped<Record>>() <= 80);
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_ops() {
        let mut m: SlotMask = [0; 2];
        mask_set(&mut m, 0);
        mask_set(&mut m, 63);
        mask_set(&mut m, 64);
        mask_set(&mut m, 127);
        assert_eq!(mask_count(&m), 4);
        mask_clear(&mut m, 63);
        assert_eq!(mask_count(&m), 3);
        assert_eq!(m[0], 1);
        assert_eq!(m[1], 1 | (1 << 63));
    }

    #[test]
    fn record_is_one_cacheline() {
        assert!(std::mem::size_of::<Record>() <= 64);
    }
}
