//! Bottleneck classification — the paper's §7 future-work extension,
//! implemented: "in order to automate the process of bottleneck
//! classification we have recently experimented with tracking I/O system
//! calls … and tracing kernel-level synchronization ('futex') calls …
//! by combining GAPP's existing criticality information with an analysis
//! of futex 'wakers' it is relatively easy to distinguish critical from
//! non-critical lock holders."
//!
//! The kernel probe records, per critical timeslice, the wait class the
//! thread blocked into (futex / barrier / queue / I/O / channel — what a
//! real deployment learns from the futex + syscall tracepoints) and the
//! pid whose wakeup *started* the slice (the waker). Classification is
//! then a per-call-path majority vote, and the waker histogram names the
//! lock-holder threads that gate each bottleneck.

use crate::simkernel::WaitKind;

use super::userspace::MergedPath;

/// High-level bottleneck class for a merged call path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BottleneckClass {
    /// Lock/condvar (futex) contention.
    Synchronization,
    /// Barrier / fork-join imbalance.
    Imbalance,
    /// Pipeline-queue backpressure or starvation.
    Pipeline,
    /// Blocking I/O.
    Io,
    /// Message-passing wait.
    Messaging,
    /// CPU-bound work (slices ending by preemption/exit) — includes
    /// busy-wait loops, which never block.
    Compute,
}

impl BottleneckClass {
    /// Every class, in the deterministic vote order.
    pub const ALL: [BottleneckClass; 6] = [
        BottleneckClass::Synchronization,
        BottleneckClass::Imbalance,
        BottleneckClass::Pipeline,
        BottleneckClass::Io,
        BottleneckClass::Messaging,
        BottleneckClass::Compute,
    ];

    pub fn label(self) -> &'static str {
        match self {
            BottleneckClass::Synchronization => "synchronization (futex)",
            BottleneckClass::Imbalance => "barrier / load imbalance",
            BottleneckClass::Pipeline => "pipeline queue",
            BottleneckClass::Io => "blocking I/O",
            BottleneckClass::Messaging => "message passing",
            BottleneckClass::Compute => "compute / busy-wait",
        }
    }

    /// Inverse of [`label`](Self::label) — how the JSON sink's
    /// deserializer recovers the class from a serialized report.
    /// Labels are part of schema v1: renaming one is a breaking change.
    pub fn from_label(label: &str) -> Option<BottleneckClass> {
        BottleneckClass::ALL.into_iter().find(|c| c.label() == label)
    }
}

/// The class each wait kind votes for. Total over [`WaitKind`]: every
/// slice a probe can record maps to exactly one class, so [`classify`]
/// covers any histogram a [`MergedPath`] can carry — adding a wait
/// kind without deciding its class is a compile error here.
pub fn class_of_wait(k: WaitKind) -> BottleneckClass {
    match k {
        WaitKind::Futex => BottleneckClass::Synchronization,
        WaitKind::Barrier => BottleneckClass::Imbalance,
        WaitKind::Queue => BottleneckClass::Pipeline,
        WaitKind::Io => BottleneckClass::Io,
        WaitKind::Channel => BottleneckClass::Messaging,
        WaitKind::None => BottleneckClass::Compute,
    }
}

/// Classify a merged path by majority wait kind over its slices.
///
/// The vote is deterministic by construction: it walks a fixed variant
/// order (futex, barrier, queue, I/O, channel, none) and a candidate
/// replaces the leader only on a *strictly greater* count, so a tie —
/// two-way or n-way — always resolves to the kind earliest in that
/// order. Map iteration order never leaks into reports (the streaming
/// analyzer's window-merged histograms are built in a different
/// insertion order than the batch ones), and an empty or all-zero
/// histogram falls through to the `None` seed, i.e. `Compute`.
pub fn classify(path: &MergedPath) -> BottleneckClass {
    const ORDER: [WaitKind; 6] = [
        WaitKind::Futex,
        WaitKind::Barrier,
        WaitKind::Queue,
        WaitKind::Io,
        WaitKind::Channel,
        WaitKind::None,
    ];
    let mut best = (WaitKind::None, 0u64);
    for k in ORDER {
        let n = path.wait_hist.get(&k).copied().unwrap_or(0);
        if n > best.1 {
            best = (k, n);
        }
    }
    class_of_wait(best.0)
}

/// Top wakers of a path, descending — "critical lock holders" (§7).
pub fn top_wakers(path: &MergedPath, n: usize) -> Vec<(u32, u64)> {
    let mut v: Vec<(u32, u64)> = path.wakers.iter().map(|(p, c)| (*p, *c)).collect();
    v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    v.truncate(n);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::FxHashMap;

    fn path(waits: &[(WaitKind, u64)], wakers: &[(u32, u64)]) -> MergedPath {
        MergedPath {
            stack_id: 0,
            cm_fs: 1_000_000,
            total_cm_ns: 1.0,
            first_seen: u64::MAX,
            slices: waits.iter().map(|(_, n)| n).sum(),
            addr_freq: FxHashMap::default(),
            stack_top_samples: 0,
            wait_hist: waits.iter().copied().collect(),
            wakers: wakers.iter().copied().collect(),
            app_slices: FxHashMap::default(),
        }
    }

    #[test]
    fn majority_vote_classification() {
        let p = path(&[(WaitKind::Futex, 10), (WaitKind::Io, 3)], &[]);
        assert_eq!(classify(&p), BottleneckClass::Synchronization);
        let p = path(&[(WaitKind::Queue, 5)], &[]);
        assert_eq!(classify(&p), BottleneckClass::Pipeline);
        let p = path(&[(WaitKind::None, 2), (WaitKind::Barrier, 7)], &[]);
        assert_eq!(classify(&p), BottleneckClass::Imbalance);
        let p = path(&[], &[]);
        assert_eq!(classify(&p), BottleneckClass::Compute);
    }

    #[test]
    fn tied_votes_resolve_by_fixed_variant_order() {
        // Io and Futex tie; Futex precedes Io in the canonical order, so
        // the class must not depend on map iteration order.
        let p = path(&[(WaitKind::Io, 4), (WaitKind::Futex, 4)], &[]);
        assert_eq!(classify(&p), BottleneckClass::Synchronization);
        // Three-way tie: earliest of the tied kinds in vote order wins
        // (Barrier beats Queue and Channel).
        let p = path(
            &[(WaitKind::Channel, 3), (WaitKind::Queue, 3), (WaitKind::Barrier, 3)],
            &[],
        );
        assert_eq!(classify(&p), BottleneckClass::Imbalance);
        // Zero-count entries are not votes: a histogram of only zeros
        // classifies like an empty one.
        let p = path(&[(WaitKind::Io, 0), (WaitKind::Queue, 0)], &[]);
        assert_eq!(classify(&p), BottleneckClass::Compute);
        // A real vote beats any number of zero entries ahead of it.
        let p = path(&[(WaitKind::Futex, 0), (WaitKind::Channel, 1)], &[]);
        assert_eq!(classify(&p), BottleneckClass::Messaging);
    }

    #[test]
    fn every_wait_kind_maps_to_exactly_one_class() {
        // class_of_wait is the single source of truth for the vote →
        // class mapping; a majority of kind k must classify as
        // class_of_wait(k) for every kind.
        const KINDS: [WaitKind; 6] = [
            WaitKind::Futex,
            WaitKind::Barrier,
            WaitKind::Queue,
            WaitKind::Io,
            WaitKind::Channel,
            WaitKind::None,
        ];
        let mut seen = Vec::new();
        for k in KINDS {
            let p = path(&[(k, 5)], &[]);
            assert_eq!(classify(&p), class_of_wait(k), "{k:?}");
            seen.push(class_of_wait(k));
        }
        // The mapping is a bijection onto the full taxonomy.
        for c in BottleneckClass::ALL {
            assert!(seen.contains(&c), "{c:?} unreachable from any wait kind");
        }
    }

    #[test]
    fn wakers_ranked() {
        let p = path(&[(WaitKind::Futex, 3)], &[(9, 5), (2, 11), (4, 1)]);
        assert_eq!(top_wakers(&p, 2), vec![(2, 11), (9, 5)]);
    }

    #[test]
    fn labels_are_informative() {
        assert!(BottleneckClass::Io.label().contains("I/O"));
        assert!(BottleneckClass::Synchronization.label().contains("futex"));
    }

    #[test]
    fn labels_round_trip_and_are_distinct() {
        for c in BottleneckClass::ALL {
            assert_eq!(BottleneckClass::from_label(c.label()), Some(c));
        }
        let mut labels: Vec<&str> =
            BottleneckClass::ALL.iter().map(|c| c.label()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), BottleneckClass::ALL.len());
        assert_eq!(BottleneckClass::from_label("nope"), None);
    }
}
