//! GAPP configuration: the paper's tunables.

use crate::simkernel::Time;

/// Report output format (`--format`): which [`crate::gapp::sink`]
/// backend the CLI drives the session through.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReportFormat {
    /// Human-readable text — byte-identical to the pre-sink CLI.
    #[default]
    Text,
    /// One versioned JSON document per session (`schema: 1`).
    Json,
    /// One JSON object per event line (streaming transport shape).
    Jsonl,
}

impl ReportFormat {
    /// Accepted `--format` values, in display order.
    pub const NAMES: [&'static str; 3] = ["text", "json", "jsonl"];

    pub fn from_name(name: &str) -> Option<ReportFormat> {
        match name {
            "text" => Some(ReportFormat::Text),
            "json" => Some(ReportFormat::Json),
            "jsonl" => Some(ReportFormat::Jsonl),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ReportFormat::Text => "text",
            ReportFormat::Json => "json",
            ReportFormat::Jsonl => "jsonl",
        }
    }
}

/// How window (and batch) aggregation combines the per-CPU ring shards
/// (`--merge`): through one globally re-serialized record stream, or
/// through shard-local partial accumulators merged pairwise.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MergeStrategy {
    /// K-way merge every shard back into one `(time, seq)`-ordered
    /// stream and fold it through a single accumulator — the pre-tree
    /// consumer, kept as the equivalence oracle.
    Serial,
    /// Fold each shard's records in shard order into a shard-local
    /// accumulator; combine the partials through a pairwise merge tree
    /// at window close. Only the order-sensitive activity-matrix
    /// records still cross shards in `(time, seq)` order. Provably
    /// byte-identical to `Serial` (golden-tested), scales with the
    /// shard count instead of funnelling through one merge point.
    #[default]
    Tree,
}

impl MergeStrategy {
    /// Accepted `--merge` values, in display order.
    pub const NAMES: [&'static str; 2] = ["serial", "tree"];

    pub fn from_name(name: &str) -> Option<MergeStrategy> {
        match name {
            "serial" => Some(MergeStrategy::Serial),
            "tree" => Some(MergeStrategy::Tree),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MergeStrategy::Serial => "serial",
            MergeStrategy::Tree => "tree",
        }
    }
}

/// What the session does when a ring shard is about to overflow
/// (`--on-overflow`): shed records like a real perf buffer, or degrade
/// the analysis resolution to avoid losing data.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverflowPolicy {
    /// Drop the newest records once a ring is full and count the drops
    /// — the kernel-side behaviour of a real BPF ring buffer, and the
    /// historical behaviour of every GAPP mode.
    #[default]
    Shed,
    /// Keep the data, lose resolution instead: emergency-drain a ring
    /// that is about to overflow, and widen the current epoch window
    /// (absorb the next epoch) when that happened, so the analyzer
    /// trades per-window granularity for completeness. Every decision
    /// is accounted in the report and emitted as a `Degraded` event.
    Degrade,
}

impl OverflowPolicy {
    /// Accepted `--on-overflow` values, in display order.
    pub const NAMES: [&'static str; 2] = ["shed", "degrade"];

    pub fn from_name(name: &str) -> Option<OverflowPolicy> {
        match name {
            "shed" => Some(OverflowPolicy::Shed),
            "degrade" => Some(OverflowPolicy::Degrade),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OverflowPolicy::Shed => "shed",
            OverflowPolicy::Degrade => "degrade",
        }
    }
}

/// Profiler configuration (§5.1 defaults).
#[derive(Clone, Debug)]
pub struct GappConfig {
    /// Parallelism threshold N_min. `None` → n/2 where n is the number
    /// of application threads observed so far (the paper's default).
    pub nmin: Option<f64>,
    /// Sampling period Δt (default 3 ms).
    pub dt: Time,
    /// Stack-capture depth M (top entries kept per trace).
    pub stack_depth: usize,
    /// Number of bottleneck call paths reported (top N).
    pub top_n: usize,
    /// Ring-buffer capacity in records, *per shard* — matching how real
    /// perf buffer pages are sized per CPU.
    pub ring_capacity: usize,
    /// Ring shards (per-CPU perf buffers). `None` → one per simulated
    /// CPU, the `PERF_EVENT_ARRAY` deployment shape; `Some(1)` is the
    /// single shared ring. The CLI flag is `--shards`.
    pub shards: Option<usize>,
    /// Stack-trace map capacity: distinct critical-slice call paths the
    /// kernel can intern before the eviction policy kicks in.
    pub stack_map_entries: usize,
    /// At stack-map capacity: `false` (default) drops new stacks and
    /// counts them (`bpf_get_stackid` `-ENOMEM`); `true` evicts the
    /// least-recently-seen stack and recycles its id — what long-running
    /// daemons under `gapp live` need so the map never saturates.
    /// Intended for `gapp live`, which re-interns window snapshots into
    /// a stable userspace map at window close; a *batch* profile keyed
    /// on recycled ids can conflate evicted paths, so leave this off
    /// for batch runs.
    pub stack_lru: bool,
    /// Drain a ring shard into the user-space engine when it holds at
    /// least this many records (the paper's concurrent user probe; the
    /// watermark is per shard, like a real per-CPU buffer's wakeup).
    pub drain_threshold: usize,
    /// Shard-aggregation strategy (CLI `--merge serial|tree`): how the
    /// per-CPU ring shards reach the window/batch accumulators. The
    /// strategies render byte-identical reports; `Serial` is kept as
    /// the equivalence oracle and for A/B benching.
    pub merge: MergeStrategy,
    /// Report output format (CLI `--format text|json|jsonl`). Only the
    /// CLI consults this — library callers attach sinks directly.
    pub format: ReportFormat,
    /// Report destination path (CLI `--output FILE`); `None` = stdout.
    pub output: Option<String>,
    /// Overflow policy (CLI `--on-overflow shed|degrade`): what the
    /// session does when a ring shard is about to overflow. `Shed`
    /// (default) keeps the historical drop-and-count behaviour.
    pub on_overflow: OverflowPolicy,
    /// Lane-worker OS threads (CLI `--lane-threads N`): how many real
    /// threads fold the per-shard lanes under the tree strategy. `1`
    /// (default) keeps today's single-thread tree — every lane folds
    /// inline on the driver thread, so all goldens hold unchanged.
    /// `N > 1` hands each shard's drained records to a scoped worker
    /// thread over an SPSC channel and parallelizes the window-close
    /// merge tree by depth. Byte-identical output at every N (the
    /// folds are shard-local and the merge tree is deterministic);
    /// requires `merge == Tree` and more than one shard.
    pub lane_threads: usize,
    /// Tiered window compaction base (CLI `--compact-base B`): retain
    /// closed-window state in a base-B tier pyramid instead of flat
    /// per-window arrays. Level 0 holds the last B raw window
    /// snapshots; a full level folds through the associative merge
    /// tree into one entry of the next level, so retained state is
    /// O(B·log T) for T windows while the final cumulative report
    /// stays byte-identical to the uncompacted run. `None` (default)
    /// keeps the flat history — today's behaviour. Must be >= 2 when
    /// set (a base-1 pyramid would fold every push and never spread
    /// windows across a level). Inert for batch sessions, which close
    /// no windows.
    pub compact_base: Option<usize>,
    /// Half-life of the time-decayed "recent" top-K sketch, in
    /// simulated microseconds (CLI `--decay-half-life-us H`). When
    /// set, the windowed driver feeds a second space-saving sketch
    /// whose counts halve every H µs of simulated time, and the final
    /// report grows an additive `recent` block beside the cumulative
    /// top-K — "hot in the last hour" next to "hot ever", both in
    /// O(K). `None` (default) disables the block; must be >= 1 when
    /// set (a zero half-life decays everything instantly).
    pub decay_half_life_us: Option<u64>,
}

impl Default for GappConfig {
    fn default() -> Self {
        GappConfig {
            nmin: None,
            dt: 3_000_000, // 3 ms
            stack_depth: 16,
            top_n: 5,
            ring_capacity: 1 << 20,
            shards: None,
            stack_map_entries: 1 << 14,
            stack_lru: false,
            drain_threshold: 1 << 14,
            merge: MergeStrategy::Tree,
            format: ReportFormat::Text,
            output: None,
            on_overflow: OverflowPolicy::Shed,
            lane_threads: 1,
            compact_base: None,
            decay_half_life_us: None,
        }
    }
}

impl GappConfig {
    /// Reject configurations that would silently produce a useless run:
    /// a 0-capacity ring drops every record, `top_n = 0` reports
    /// nothing, a zero sampling period or drain threshold makes no
    /// sense. Called by `KernelProbes::new`, so every construction path
    /// (CLI, tests, library users) gets a real error instead of quiet
    /// misbehaviour.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.ring_capacity >= 1,
            "ring_capacity must be >= 1 (a 0-capacity ring drops every record)"
        );
        anyhow::ensure!(
            self.top_n >= 1,
            "top_n must be >= 1 (--top 0 would report nothing)"
        );
        anyhow::ensure!(self.stack_depth >= 1, "stack_depth must be >= 1");
        anyhow::ensure!(
            self.stack_map_entries >= 1,
            "stack_map_entries must be >= 1"
        );
        anyhow::ensure!(self.dt >= 1, "dt (sampling period) must be positive");
        if let Some(n) = self.nmin {
            // NaN/±inf parse fine as f64 ("--nmin nan") but poison the
            // criticality comparison and cannot serialize to JSON.
            anyhow::ensure!(
                n.is_finite() && n >= 0.0,
                "nmin must be a finite, non-negative thread count"
            );
        }
        anyhow::ensure!(
            self.drain_threshold >= 1,
            "drain_threshold must be >= 1 (use usize::MAX to disable mid-epoch drains)"
        );
        if let Some(s) = self.shards {
            anyhow::ensure!(s >= 1, "shards must be >= 1 (--shards 0 is meaningless)");
        }
        anyhow::ensure!(
            self.lane_threads >= 1,
            "lane_threads must be >= 1 (--lane-threads 0 would fold nothing)"
        );
        if self.lane_threads > 1 {
            // Extra lane workers only exist on the tree path, where the
            // per-shard folds are independent until window close. A
            // silent fallback would misreport the measured configuration,
            // so both dead-end combinations are real errors.
            anyhow::ensure!(
                self.merge == MergeStrategy::Tree,
                "lane_threads > 1 requires the tree merge strategy \
                 (--merge serial folds one global stream — there are no \
                 independent lanes for extra threads to work on)"
            );
            anyhow::ensure!(
                self.shards != Some(1),
                "lane_threads > 1 requires more than one ring shard \
                 (--shards 1 has a single lane, so extra lane threads \
                 would idle; raise --shards or drop --lane-threads)"
            );
        }
        if let Some(b) = self.compact_base {
            // Base 0 and 1 are both degenerate: 0 can never hold a
            // window, 1 would fold on every push and the pyramid would
            // degenerate to a single ever-rolling entry with no raw
            // tail to report from.
            anyhow::ensure!(
                b >= 2,
                "compact_base must be >= 2 (a base-{b} pyramid cannot \
                 spread windows across a tier level)"
            );
        }
        if let Some(h) = self.decay_half_life_us {
            anyhow::ensure!(
                h >= 1,
                "decay_half_life_us must be >= 1 (a zero half-life \
                 decays every count to nothing instantly)"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = GappConfig::default();
        assert_eq!(c.dt, 3_000_000);
        assert!(c.nmin.is_none());
        assert!(c.shards.is_none()); // per-CPU perf buffers by default
        assert_eq!(c.merge, MergeStrategy::Tree); // shard-local folding
        assert_eq!(c.format, ReportFormat::Text);
        assert!(c.output.is_none());
        assert_eq!(c.on_overflow, OverflowPolicy::Shed);
        assert_eq!(c.lane_threads, 1); // single-thread tree by default
        assert!(c.compact_base.is_none()); // flat per-window history
        assert!(c.decay_half_life_us.is_none()); // no recent block
        assert!(c.validate().is_ok());
    }

    #[test]
    fn overflow_policy_names_round_trip() {
        for name in OverflowPolicy::NAMES {
            let p = OverflowPolicy::from_name(name).unwrap();
            assert_eq!(p.name(), name);
        }
        assert!(OverflowPolicy::from_name("bogus").is_none());
        assert_eq!(OverflowPolicy::default(), OverflowPolicy::Shed);
    }

    #[test]
    fn report_format_names_round_trip() {
        for name in ReportFormat::NAMES {
            let f = ReportFormat::from_name(name).unwrap();
            assert_eq!(f.name(), name);
        }
        assert!(ReportFormat::from_name("xml").is_none());
        assert_eq!(ReportFormat::default(), ReportFormat::Text);
    }

    #[test]
    fn merge_strategy_names_round_trip() {
        for name in MergeStrategy::NAMES {
            let m = MergeStrategy::from_name(name).unwrap();
            assert_eq!(m.name(), name);
        }
        assert!(MergeStrategy::from_name("bogus").is_none());
        assert_eq!(MergeStrategy::default(), MergeStrategy::Tree);
    }

    #[test]
    fn zero_knobs_are_rejected_with_real_errors() {
        let cases: Vec<(GappConfig, &str)> = vec![
            (
                GappConfig {
                    ring_capacity: 0,
                    ..Default::default()
                },
                "ring_capacity",
            ),
            (
                GappConfig {
                    top_n: 0,
                    ..Default::default()
                },
                "top_n",
            ),
            (
                GappConfig {
                    dt: 0,
                    ..Default::default()
                },
                "dt",
            ),
            (
                GappConfig {
                    drain_threshold: 0,
                    ..Default::default()
                },
                "drain_threshold",
            ),
            (
                GappConfig {
                    shards: Some(0),
                    ..Default::default()
                },
                "shards",
            ),
            (
                GappConfig {
                    stack_depth: 0,
                    ..Default::default()
                },
                "stack_depth",
            ),
            (
                GappConfig {
                    stack_map_entries: 0,
                    ..Default::default()
                },
                "stack_map_entries",
            ),
            (
                GappConfig {
                    lane_threads: 0,
                    ..Default::default()
                },
                "lane_threads",
            ),
        ];
        for (cfg, what) in cases {
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains(what), "error {err:?} should name {what}");
        }
    }

    #[test]
    fn non_finite_nmin_is_rejected() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
            let cfg = GappConfig {
                nmin: Some(bad),
                ..Default::default()
            };
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains("nmin"), "{err}");
        }
        let cfg = GappConfig {
            nmin: Some(8.0),
            ..Default::default()
        };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn lane_threads_dead_end_combinations_are_real_errors() {
        // Serial has no independent lanes for extra threads to fold.
        let cfg = GappConfig {
            lane_threads: 2,
            merge: MergeStrategy::Serial,
            ..Default::default()
        };
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("lane_threads"), "{err}");
        assert!(err.contains("serial"), "{err}");
        // One shard means one lane: extra workers would idle silently.
        let cfg = GappConfig {
            lane_threads: 2,
            shards: Some(1),
            ..Default::default()
        };
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("lane_threads"), "{err}");
        assert!(err.contains("shard"), "{err}");
        // The working shapes validate: tree + several shards, any N.
        for n in [1usize, 2, 4, 16] {
            let cfg = GappConfig {
                lane_threads: n,
                shards: Some(4),
                ..Default::default()
            };
            assert!(cfg.validate().is_ok(), "lane_threads {n}");
        }
        // N = 1 is today's inline tree and composes with everything.
        let cfg = GappConfig {
            lane_threads: 1,
            merge: MergeStrategy::Serial,
            shards: Some(1),
            ..Default::default()
        };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn degenerate_compaction_knobs_are_rejected() {
        for bad in [0usize, 1] {
            let cfg = GappConfig {
                compact_base: Some(bad),
                ..Default::default()
            };
            let err = cfg.validate().unwrap_err().to_string();
            assert!(err.contains("compact_base"), "{err}");
            assert!(err.contains(">= 2"), "{err}");
        }
        let cfg = GappConfig {
            decay_half_life_us: Some(0),
            ..Default::default()
        };
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("decay_half_life_us"), "{err}");
        // The working shapes validate, alone and combined.
        for (b, h) in [(Some(2), None), (Some(8), Some(1)), (None, Some(1_000_000))] {
            let cfg = GappConfig {
                compact_base: b,
                decay_half_life_us: h,
                ..Default::default()
            };
            assert!(cfg.validate().is_ok(), "base {b:?} half-life {h:?}");
        }
    }
}
