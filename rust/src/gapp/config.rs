//! GAPP configuration: the paper's tunables.

use crate::simkernel::Time;

/// Profiler configuration (§5.1 defaults).
#[derive(Clone, Debug)]
pub struct GappConfig {
    /// Parallelism threshold N_min. `None` → n/2 where n is the number
    /// of application threads observed so far (the paper's default).
    pub nmin: Option<f64>,
    /// Sampling period Δt (default 3 ms).
    pub dt: Time,
    /// Stack-capture depth M (top entries kept per trace).
    pub stack_depth: usize,
    /// Number of bottleneck call paths reported (top N).
    pub top_n: usize,
    /// Ring-buffer capacity (records).
    pub ring_capacity: usize,
    /// Stack-trace map capacity: distinct critical-slice call paths the
    /// kernel can intern before new stacks are dropped (and counted).
    pub stack_map_entries: usize,
    /// Drain the ring buffer into the user-space engine when it holds at
    /// least this many records (the paper's concurrent user probe).
    pub drain_threshold: usize,
}

impl Default for GappConfig {
    fn default() -> Self {
        GappConfig {
            nmin: None,
            dt: 3_000_000, // 3 ms
            stack_depth: 16,
            top_n: 5,
            ring_capacity: 1 << 20,
            stack_map_entries: 1 << 14,
            drain_threshold: 1 << 14,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = GappConfig::default();
        assert_eq!(c.dt, 3_000_000);
        assert!(c.nmin.is_none());
    }
}
