//! GAPP configuration: the paper's tunables.

use crate::simkernel::Time;

/// Profiler configuration (§5.1 defaults).
#[derive(Clone, Debug)]
pub struct GappConfig {
    /// Parallelism threshold N_min. `None` → n/2 where n is the number
    /// of application threads observed so far (the paper's default).
    pub nmin: Option<f64>,
    /// Sampling period Δt (default 3 ms).
    pub dt: Time,
    /// Stack-capture depth M (top entries kept per trace).
    pub stack_depth: usize,
    /// Number of bottleneck call paths reported (top N).
    pub top_n: usize,
    /// Ring-buffer capacity (records).
    pub ring_capacity: usize,
    /// Stack-trace map capacity: distinct critical-slice call paths the
    /// kernel can intern before the eviction policy kicks in.
    pub stack_map_entries: usize,
    /// At stack-map capacity: `false` (default) drops new stacks and
    /// counts them (`bpf_get_stackid` `-ENOMEM`); `true` evicts the
    /// least-recently-seen stack and recycles its id — what long-running
    /// daemons under `gapp live` need so the map never saturates.
    /// Intended for `gapp live`, which re-interns window snapshots into
    /// a stable userspace map at window close; a *batch* profile keyed
    /// on recycled ids can conflate evicted paths, so leave this off
    /// for batch runs.
    pub stack_lru: bool,
    /// Drain the ring buffer into the user-space engine when it holds at
    /// least this many records (the paper's concurrent user probe).
    pub drain_threshold: usize,
}

impl Default for GappConfig {
    fn default() -> Self {
        GappConfig {
            nmin: None,
            dt: 3_000_000, // 3 ms
            stack_depth: 16,
            top_n: 5,
            ring_capacity: 1 << 20,
            stack_map_entries: 1 << 14,
            stack_lru: false,
            drain_threshold: 1 << 14,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = GappConfig::default();
        assert_eq!(c.dt, 3_000_000);
        assert!(c.nmin.is_none());
    }
}
