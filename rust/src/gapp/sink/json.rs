//! Machine-readable backends: one versioned JSON document per session
//! ([`JsonSink`]) or one JSON object per event line ([`JsonlSink`]).
//!
//! # Schema versioning policy (v1)
//!
//! Every emitted document/line carries `"schema": 1`. The number is
//! bumped only on *breaking* changes (a field renamed, retyped, or
//! removed, or event framing changed); adding fields is always allowed
//! within a version, so consumers must ignore keys they do not know.
//! The schema is deliberately hand-rolled over [`crate::util::json`] —
//! `u64` counters (femtosecond CMetrics, runtimes) exceed 2^53 and
//! must not pass through a float.
//!
//! [`report_from_json`] inverts [`report_json`] losslessly: the sink
//! golden tests re-render a parsed document through the human renderer
//! and byte-compare against the direct text output. That inverse is
//! the seam future merge-tree / cross-process tooling builds on.

use std::io;

use anyhow::{anyhow, Result};

use crate::ebpf::RingBufStats;
use crate::gapp::classify::BottleneckClass;
use crate::gapp::config::GappConfig;
use crate::gapp::report::{Bottleneck, Report, SampleLine, ThreadCm};
use crate::gapp::stream::WindowReport;
use crate::util::json::Json;

use super::{
    FinalEvent, ReportEvent, ReportSink, ScorecardEvent, SessionInfo, ShardWindowEvent,
    SymbolsEvent,
};

/// Schema version stamped on every document and JSONL line.
pub const SCHEMA_VERSION: u64 = 1;

// ---- serialization -----------------------------------------------------

fn opt_u64(v: Option<u64>) -> Json {
    v.map(Json::u64).unwrap_or(Json::Null)
}

fn opt_str(v: &Option<String>) -> Json {
    v.as_ref().map(Json::str).unwrap_or(Json::Null)
}

pub fn config_json(c: &GappConfig) -> Json {
    Json::obj(vec![
        (
            "nmin",
            c.nmin.map(Json::f64).unwrap_or(Json::Null),
        ),
        ("dt_ns", Json::u64(c.dt)),
        ("stack_depth", Json::usize(c.stack_depth)),
        ("top_n", Json::usize(c.top_n)),
        ("ring_capacity", Json::usize(c.ring_capacity)),
        ("shards", opt_u64(c.shards.map(|s| s as u64))),
        ("stack_map_entries", Json::usize(c.stack_map_entries)),
        ("stack_lru", Json::Bool(c.stack_lru)),
        ("drain_threshold", Json::usize(c.drain_threshold)),
        ("merge", Json::str(c.merge.name())),
        ("format", Json::str(c.format.name())),
        ("output", opt_str(&c.output)),
        ("on_overflow", Json::str(c.on_overflow.name())),
        ("lane_threads", Json::usize(c.lane_threads)),
        ("compact_base", opt_u64(c.compact_base.map(|b| b as u64))),
        ("decay_half_life_us", opt_u64(c.decay_half_life_us)),
    ])
}

pub fn session_info_json(s: &SessionInfo) -> Json {
    Json::obj(vec![
        ("mode", Json::str(s.mode.name())),
        (
            "apps",
            Json::Arr(s.apps.iter().map(Json::str).collect()),
        ),
        ("shards", Json::usize(s.shards)),
        ("window_ns", opt_u64(s.window_ns)),
        ("config", config_json(&s.config)),
    ])
}

/// One shard's partial window aggregation (opt-in; tree strategy).
/// Each path carries its associative aggregates plus the `first_seen`
/// capture stamp, which is all a cross-process consumer needs to run
/// the same pairwise merge (`stream::merge_tree`) over partials shipped
/// from several producers: sums combine, stamps take the minimum, and
/// the canonical order falls out of the stamps.
pub fn shard_window_json(sw: &ShardWindowEvent<'_>) -> Json {
    Json::obj(vec![
        ("index", Json::u64(sw.index)),
        ("shard", Json::usize(sw.shard)),
        ("slices", Json::u64(sw.slices)),
        ("drained", Json::u64(sw.drained)),
        ("drops", Json::u64(sw.drops)),
        (
            "paths",
            Json::Arr(
                sw.paths
                    .iter()
                    .map(|p| {
                        let mut fields = vec![
                            ("stack_id", Json::u64(p.stack_id as u64)),
                            ("cm_fs", Json::u64(p.cm_fs)),
                            ("slices", Json::u64(p.slices)),
                            ("first_seen", Json::u64(p.first_seen)),
                        ];
                        // Additive within schema v1: per-app (or, in a
                        // fleet-merged stream, per-producer) slice
                        // attribution. Readers that predate the key
                        // ignore it; the merge math never consumes it
                        // (sums and stamps above are self-sufficient).
                        if !p.app_slices.is_empty() {
                            let mut apps: Vec<(u16, u64)> =
                                p.app_slices.iter().map(|(a, n)| (*a, *n)).collect();
                            apps.sort_unstable();
                            fields.push((
                                "apps",
                                Json::Arr(
                                    apps.into_iter()
                                        .map(|(a, n)| {
                                            Json::obj(vec![
                                                ("app", Json::u64(a as u64)),
                                                ("slices", Json::u64(n)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The symbol-exchange payload: every newly interned stack id with its
/// raw frames and the producer-side rendering of each frame. Ids are
/// session-stable by contract (an id, once announced, never changes
/// meaning), so a consumer needs each entry exactly once — re-announcing
/// an id with *different* frames is a protocol violation a fleet reader
/// quarantines.
pub fn symbols_json(sy: &SymbolsEvent<'_>) -> Json {
    Json::obj(vec![(
        "entries",
        Json::Arr(
            sy.entries
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("stack_id", Json::u64(e.stack_id as u64)),
                        (
                            "frames",
                            Json::Arr(e.frames.iter().map(|a| Json::u64(*a)).collect()),
                        ),
                        (
                            "rendered",
                            Json::Arr(e.rendered.iter().map(Json::str).collect()),
                        ),
                    ])
                })
                .collect(),
        ),
    )])
}

/// One closed window. The in-memory merge snapshot is deliberately not
/// serialized — it is an implementation detail of the cumulative merge
/// (and O(paths) per window); the ranked top-K plus the accounting is
/// the window's reportable surface.
pub fn window_json(w: &WindowReport) -> Json {
    Json::obj(vec![
        ("index", Json::u64(w.index)),
        ("start_ns", Json::u64(w.start_ns)),
        ("end_ns", Json::u64(w.end_ns)),
        ("slices", Json::u64(w.slices)),
        ("drained", Json::u64(w.drained)),
        ("drops", Json::u64(w.drops)),
        (
            "shard_drops",
            Json::Arr(w.shard_drops.iter().map(|d| Json::u64(*d)).collect()),
        ),
        ("degraded_drains", Json::u64(w.degraded_drains)),
        ("widened", Json::Bool(w.widened)),
        (
            "top",
            Json::Arr(
                w.top
                    .iter()
                    .map(|l| {
                        Json::obj(vec![
                            ("rank", Json::usize(l.rank)),
                            ("app", Json::str(&l.app)),
                            ("cm_ms", Json::f64(l.cm_ms)),
                            ("slices", Json::u64(l.slices)),
                            ("class", Json::str(l.class)),
                            ("site", Json::str(&l.site)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// One scorecard: per-class confusion counts with the derived ratios
/// emitted for consumer convenience (the counts are the source of
/// truth — an aggregator re-sums `tp`/`fp`/`fn`, never the floats).
pub fn scorecard_json(sc: &ScorecardEvent) -> Json {
    let overall = sc.overall();
    let row = |class: &str, r: &super::ScoreRow| {
        Json::obj(vec![
            ("class", Json::str(class)),
            ("tp", Json::u64(r.tp)),
            ("fp", Json::u64(r.fp)),
            ("fn", Json::u64(r.fn_)),
            ("precision", Json::f64(r.precision())),
            ("recall", Json::f64(r.recall())),
            ("f1", Json::f64(r.f1())),
        ])
    };
    Json::obj(vec![
        ("scope", Json::str(&sc.scope)),
        ("cases", Json::u64(sc.cases)),
        (
            "rows",
            Json::Arr(sc.rows.iter().map(|r| row(r.class.label(), r)).collect()),
        ),
        ("overall", row("overall", &overall)),
        (
            "assignments",
            Json::Arr(
                sc.assignments
                    .iter()
                    .map(|a| {
                        Json::obj(vec![
                            ("app", Json::str(&a.app)),
                            ("truth", Json::str(a.truth.label())),
                            (
                                "predicted",
                                a.predicted
                                    .map(|p| Json::str(p.label()))
                                    .unwrap_or(Json::Null),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn ring_stats_json(s: &RingBufStats) -> Json {
    Json::obj(vec![
        ("pushed", Json::u64(s.pushed)),
        ("dropped", Json::u64(s.dropped)),
        ("drained", Json::u64(s.drained)),
        ("peak", Json::usize(s.peak)),
    ])
}

fn bottleneck_json(b: &Bottleneck) -> Json {
    Json::obj(vec![
        ("rank", Json::usize(b.rank)),
        ("total_cm_ms", Json::f64(b.total_cm_ms)),
        ("slices", Json::u64(b.slices)),
        ("class", Json::str(b.class.label())),
        ("stack_top_samples", Json::u64(b.stack_top_samples)),
        (
            "call_path",
            Json::Arr(b.call_path.iter().map(Json::str).collect()),
        ),
        (
            "apps",
            Json::Arr(
                b.apps
                    .iter()
                    .map(|(a, n)| {
                        Json::obj(vec![("app", Json::str(a)), ("slices", Json::u64(*n))])
                    })
                    .collect(),
            ),
        ),
        (
            "top_wakers",
            Json::Arr(
                b.top_wakers
                    .iter()
                    .map(|(c, n)| {
                        Json::obj(vec![("comm", Json::str(c)), ("count", Json::u64(*n))])
                    })
                    .collect(),
            ),
        ),
        (
            "samples",
            Json::Arr(
                b.samples
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("rendered", Json::str(&s.rendered)),
                            (
                                "function",
                                s.function
                                    .as_ref()
                                    .map(Json::str)
                                    .unwrap_or(Json::Null),
                            ),
                            ("count", Json::u64(s.count)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// The full report, every field. `critical_ratio` is derived and
/// emitted for consumer convenience; [`report_from_json`] ignores it.
pub fn report_json(r: &Report) -> Json {
    Json::obj(vec![
        ("app", Json::str(&r.app)),
        ("backend", Json::str(r.backend)),
        ("runtime_ns", Json::u64(r.runtime_ns)),
        ("total_slices", Json::u64(r.total_slices)),
        ("critical_slices", Json::u64(r.critical_slices)),
        ("critical_ratio", Json::f64(r.critical_ratio())),
        ("samples", Json::u64(r.samples)),
        ("intervals", Json::u64(r.intervals)),
        ("ring_dropped", Json::u64(r.ring_dropped)),
        (
            "ring_shards",
            Json::Arr(r.ring_shards.iter().map(ring_stats_json).collect()),
        ),
        ("stack_ids", Json::u64(r.stack_ids)),
        ("stack_drops", Json::u64(r.stack_drops)),
        ("stack_evictions", Json::u64(r.stack_evictions)),
        (
            "window_drops",
            Json::Arr(r.window_drops.iter().map(|d| Json::u64(*d)).collect()),
        ),
        // Additive within schema v1: the compaction-surviving window
        // aggregates (under `--compact-base` the per-window breakdown
        // above is empty and these carry the whole-run figures).
        ("windows_total", Json::u64(r.windows_total)),
        ("windows_lossy", Json::u64(r.windows_lossy)),
        ("windows_drop_total", Json::u64(r.windows_drop_total)),
        ("degraded_windows", Json::u64(r.degraded_windows)),
        ("degraded_drains", Json::u64(r.degraded_drains)),
        ("memory_bytes", Json::u64(r.memory_bytes)),
        ("ppt_seconds", Json::f64(r.ppt_seconds)),
        ("probe_cost_ns", Json::u64(r.probe_cost_ns)),
        (
            "threads",
            Json::Arr(
                r.threads
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("pid", Json::u64(t.pid as u64)),
                            ("comm", Json::str(&t.comm)),
                            ("cm_ms", Json::f64(t.cm_ms)),
                            ("wall_ms", Json::f64(t.wall_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "bottlenecks",
            Json::Arr(r.bottlenecks.iter().map(bottleneck_json).collect()),
        ),
    ])
}

// ---- deserialization ---------------------------------------------------

fn req<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
}

fn req_u64(v: &Json, key: &str) -> Result<u64> {
    req(v, key)?
        .as_u64()
        .ok_or_else(|| anyhow!("field {key:?} is not a u64"))
}

fn req_f64(v: &Json, key: &str) -> Result<f64> {
    req(v, key)?
        .as_f64()
        .ok_or_else(|| anyhow!("field {key:?} is not a number"))
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    Ok(req(v, key)?
        .as_str()
        .ok_or_else(|| anyhow!("field {key:?} is not a string"))?
        .to_string())
}

fn req_arr<'a>(v: &'a Json, key: &str) -> Result<&'a [Json]> {
    req(v, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("field {key:?} is not an array"))
}

/// A u64 field that newer writers emit and older documents lack:
/// absent → 0 (the additive-fields policy), present-but-mistyped →
/// error (corruption must not decode as zero).
fn opt_u64_or_zero(v: &Json, key: &str) -> Result<u64> {
    match v.get(key) {
        None => Ok(0),
        Some(j) => j
            .as_u64()
            .ok_or_else(|| anyhow!("field {key:?} is not a u64")),
    }
}

fn u64_arr(v: &Json, key: &str) -> Result<Vec<u64>> {
    req_arr(v, key)?
        .iter()
        .map(|d| d.as_u64().ok_or_else(|| anyhow!("{key:?}: non-u64 entry")))
        .collect()
}

/// `Report::backend` is `&'static str`; map the serialized name back
/// onto the known backend set (anything unknown — e.g. a future
/// backend read by an old binary — degrades to a recognizable label
/// rather than failing the whole parse).
fn backend_from_name(name: &str) -> &'static str {
    match name {
        "native" => "native",
        "xla" => "xla",
        _ => "(foreign backend)",
    }
}

fn bottleneck_from_json(v: &Json) -> Result<Bottleneck> {
    let class_label = req_str(v, "class")?;
    let class = BottleneckClass::from_label(&class_label)
        .ok_or_else(|| anyhow!("unknown bottleneck class {class_label:?}"))?;
    let samples = req_arr(v, "samples")?
        .iter()
        .map(|s| {
            Ok(SampleLine {
                rendered: req_str(s, "rendered")?,
                function: match req(s, "function")? {
                    Json::Null => None,
                    f => Some(
                        f.as_str()
                            .ok_or_else(|| anyhow!("sample function is not a string"))?
                            .to_string(),
                    ),
                },
                count: req_u64(s, "count")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Bottleneck {
        rank: req_u64(v, "rank")? as usize,
        total_cm_ms: req_f64(v, "total_cm_ms")?,
        slices: req_u64(v, "slices")?,
        class,
        top_wakers: req_arr(v, "top_wakers")?
            .iter()
            .map(|w| Ok((req_str(w, "comm")?, req_u64(w, "count")?)))
            .collect::<Result<Vec<_>>>()?,
        apps: req_arr(v, "apps")?
            .iter()
            .map(|a| Ok((req_str(a, "app")?, req_u64(a, "slices")?)))
            .collect::<Result<Vec<_>>>()?,
        call_path: req_arr(v, "call_path")?
            .iter()
            .map(|f| {
                Ok(f.as_str()
                    .ok_or_else(|| anyhow!("call_path frame is not a string"))?
                    .to_string())
            })
            .collect::<Result<Vec<_>>>()?,
        samples,
        stack_top_samples: req_u64(v, "stack_top_samples")?,
    })
}

/// Rebuild a [`Report`] from the object [`report_json`] emitted. The
/// round-trip is lossless: re-rendering the result through the human
/// renderer byte-matches the original (golden-tested), which is what
/// makes JSON a faithful transport for downstream diff/merge tooling.
pub fn report_from_json(v: &Json) -> Result<Report> {
    // Reject a foreign schema version outright instead of best-effort
    // decoding: v0 predates fields this reader requires, and a future
    // v2 means a *breaking* change by policy (additive changes never
    // bump the version), so any field could have moved or been retyped.
    // The bare `report` object inside a v1 document carries no stamp
    // (the enclosing document does) — the check applies when a stamp is
    // present, e.g. on a stamped standalone report.
    if let Some(s) = v.get("schema") {
        let got = s
            .as_u64()
            .ok_or_else(|| anyhow!("field \"schema\" is not a u64"))?;
        if got != SCHEMA_VERSION {
            return Err(anyhow!(
                "unsupported report schema version {got}: this reader understands \
                 version {SCHEMA_VERSION} only (schema bumps are breaking by policy, \
                 so best-effort decoding would silently misread fields)"
            ));
        }
    }
    let window_drops = u64_arr(v, "window_drops")?;
    // Older documents predate the compaction-surviving window
    // aggregates, but they always carry the full per-window vector, so
    // deriving the totals from it reproduces exactly what a newer
    // writer would have stamped.
    let opt_or = |key: &str, derived: u64| -> Result<u64> {
        match v.get(key) {
            None => Ok(derived),
            Some(j) => j
                .as_u64()
                .ok_or_else(|| anyhow!("field {key:?} is not a u64")),
        }
    };
    let windows_total = opt_or("windows_total", window_drops.len() as u64)?;
    let windows_lossy = opt_or(
        "windows_lossy",
        window_drops.iter().filter(|d| **d > 0).count() as u64,
    )?;
    let windows_drop_total =
        opt_or("windows_drop_total", window_drops.iter().sum())?;
    Ok(Report {
        app: req_str(v, "app")?,
        backend: backend_from_name(&req_str(v, "backend")?),
        runtime_ns: req_u64(v, "runtime_ns")?,
        bottlenecks: req_arr(v, "bottlenecks")?
            .iter()
            .map(bottleneck_from_json)
            .collect::<Result<Vec<_>>>()?,
        threads: req_arr(v, "threads")?
            .iter()
            .map(|t| {
                Ok(ThreadCm {
                    pid: req_u64(t, "pid")? as u32,
                    comm: req_str(t, "comm")?,
                    cm_ms: req_f64(t, "cm_ms")?,
                    wall_ms: req_f64(t, "wall_ms")?,
                })
            })
            .collect::<Result<Vec<_>>>()?,
        total_slices: req_u64(v, "total_slices")?,
        critical_slices: req_u64(v, "critical_slices")?,
        samples: req_u64(v, "samples")?,
        intervals: req_u64(v, "intervals")?,
        ring_dropped: req_u64(v, "ring_dropped")?,
        ring_shards: req_arr(v, "ring_shards")?
            .iter()
            .map(|s| {
                Ok(RingBufStats {
                    pushed: req_u64(s, "pushed")?,
                    dropped: req_u64(s, "dropped")?,
                    drained: req_u64(s, "drained")?,
                    peak: req_u64(s, "peak")? as usize,
                })
            })
            .collect::<Result<Vec<_>>>()?,
        stack_ids: req_u64(v, "stack_ids")?,
        stack_drops: req_u64(v, "stack_drops")?,
        stack_evictions: req_u64(v, "stack_evictions")?,
        window_drops,
        windows_total,
        windows_lossy,
        windows_drop_total,
        degraded_windows: opt_u64_or_zero(v, "degraded_windows")?,
        degraded_drains: opt_u64_or_zero(v, "degraded_drains")?,
        memory_bytes: req_u64(v, "memory_bytes")?,
        ppt_seconds: req_f64(v, "ppt_seconds")?,
        probe_cost_ns: req_u64(v, "probe_cost_ns")?,
        ..Default::default()
    })
}

fn sketch_json(top: &[(u32, u64, u64)], lines: &[String]) -> Json {
    Json::obj(vec![
        (
            "top",
            Json::Arr(
                top.iter()
                    .map(|(id, cm, err)| {
                        Json::obj(vec![
                            ("stack_id", Json::u64(*id as u64)),
                            ("cm_fs_upper", Json::u64(*cm)),
                            ("max_overestimate_fs", Json::u64(*err)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("lines", Json::Arr(lines.iter().map(Json::str).collect())),
    ])
}

/// The third element is the decayed recent-window sketch — `None`
/// unless `--decay-half-life-us` produced one, so documents from plain
/// runs keep their exact v1 byte shape (additive-fields policy).
fn final_json(fe: &FinalEvent<'_>) -> (Json, Json, Option<Json>) {
    let recent = if fe.recent_top.is_empty() && fe.recent_lines.is_empty() {
        None
    } else {
        Some(sketch_json(fe.recent_top, fe.recent_lines))
    };
    (
        report_json(fe.report),
        sketch_json(fe.sketch_top, fe.sketch_lines),
        recent,
    )
}

// ---- sinks -------------------------------------------------------------

/// One pretty-printed JSON document for the whole session, written at
/// `SessionEnd` (a half-written run leaves no partial document —
/// truncation is detectable, matching the "schema or nothing" policy).
pub struct JsonSink<W: io::Write> {
    w: W,
    session: Json,
    windows: Vec<Json>,
    report: Json,
    cumulative: Json,
    recent: Option<Json>,
    scorecards: Vec<Json>,
}

impl<W: io::Write> JsonSink<W> {
    pub fn new(w: W) -> JsonSink<W> {
        JsonSink {
            w,
            session: Json::Null,
            windows: Vec::new(),
            report: Json::Null,
            cumulative: Json::Null,
            recent: None,
            scorecards: Vec::new(),
        }
    }

    pub fn into_inner(self) -> W {
        self.w
    }
}

impl<W: io::Write> ReportSink for JsonSink<W> {
    fn on_event(&mut self, ev: &ReportEvent<'_>) -> Result<()> {
        match ev {
            ReportEvent::SessionStart(info) => {
                self.session = session_info_json(info);
            }
            // Shard partials and their symbol exchange are a
            // streaming-transport payload; the one-document session
            // summary keeps its v1 shape (and its size) whether or not
            // they are enabled.
            ReportEvent::Symbols(_) => {}
            ReportEvent::ShardWindow(_) => {}
            // Same policy for degradation notices: the accounting lands
            // in the window and report objects, so the document already
            // carries it.
            ReportEvent::Degraded { .. } => {}
            // Tier folds are compaction bookkeeping for streaming
            // consumers; the document's report object already carries
            // the whole-run aggregates.
            ReportEvent::TierFolded { .. } => {}
            ReportEvent::WindowClosed(wr) => {
                self.windows.push(window_json(wr));
            }
            ReportEvent::Final(fe) => {
                let (report, cumulative, recent) = final_json(fe);
                self.report = report;
                self.cumulative = cumulative;
                self.recent = recent;
            }
            ReportEvent::Scorecard(sc) => {
                self.scorecards.push(scorecard_json(sc));
            }
            ReportEvent::SessionEnd { runtime_ns } => {
                let mut fields = vec![
                    ("schema", Json::u64(SCHEMA_VERSION)),
                    ("type", Json::str("gapp.session")),
                    ("session", std::mem::replace(&mut self.session, Json::Null)),
                    ("windows", Json::Arr(std::mem::take(&mut self.windows))),
                    ("report", std::mem::replace(&mut self.report, Json::Null)),
                    (
                        "cumulative_topk",
                        std::mem::replace(&mut self.cumulative, Json::Null),
                    ),
                ];
                // Additive within schema v1: only decayed-top-K runs
                // carry a recent sketch, so plain profiling documents
                // keep their exact byte shape (golden-enforced).
                if let Some(recent) = self.recent.take() {
                    fields.push(("recent_topk", recent));
                }
                // Same policy: only scenario sessions emit Scorecard
                // events.
                if !self.scorecards.is_empty() {
                    fields.push((
                        "scorecards",
                        Json::Arr(std::mem::take(&mut self.scorecards)),
                    ));
                }
                fields.push(("runtime_ns", Json::u64(*runtime_ns)));
                let doc = Json::obj(fields);
                self.w.write_all(doc.to_pretty().as_bytes())?;
                self.w.write_all(b"\n")?;
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

/// One compact JSON object per line, one line per event — the
/// streaming-transport shape (tail it, ship it over a socket, replay
/// it). Concatenating the `"window"` lines reconstructs the live run's
/// per-window accounting exactly (golden-tested against
/// `Report::window_drops`).
pub struct JsonlSink<W: io::Write> {
    w: W,
    /// Flush after every line. File outputs keep the buffered default;
    /// live transports (pipes, sockets) need each event on the wire the
    /// moment it is emitted — a buffered writer would hold the tail of
    /// a live stream until `finish`, which for a long-lived producer is
    /// indefinitely.
    flush_each: bool,
}

impl<W: io::Write> JsonlSink<W> {
    pub fn new(w: W) -> JsonlSink<W> {
        JsonlSink {
            w,
            flush_each: false,
        }
    }

    /// Line-buffered transport mode: every event is flushed as soon as
    /// it is written, so a reader on the other end of a pipe or socket
    /// sees it immediately.
    pub fn streaming(w: W) -> JsonlSink<W> {
        JsonlSink { w, flush_each: true }
    }

    pub fn into_inner(self) -> W {
        self.w
    }

    fn line(&mut self, event: &str, mut fields: Vec<(&str, Json)>) -> Result<()> {
        let mut all = vec![
            ("schema", Json::u64(SCHEMA_VERSION)),
            ("event", Json::str(event)),
        ];
        all.append(&mut fields);
        self.w.write_all(Json::obj(all).to_compact().as_bytes())?;
        self.w.write_all(b"\n")?;
        if self.flush_each {
            self.w.flush()?;
        }
        Ok(())
    }
}

impl<W: io::Write> ReportSink for JsonlSink<W> {
    fn on_event(&mut self, ev: &ReportEvent<'_>) -> Result<()> {
        match ev {
            ReportEvent::SessionStart(info) => self.line(
                "session_start",
                vec![("session", session_info_json(info))],
            ),
            ReportEvent::Symbols(sy) => {
                self.line("symbols", vec![("symbols", symbols_json(sy))])
            }
            ReportEvent::ShardWindow(sw) => self.line(
                "shard_window",
                vec![("shard_window", shard_window_json(sw))],
            ),
            ReportEvent::Degraded {
                window,
                drains,
                widened,
            } => self.line(
                "degraded",
                vec![(
                    "degraded",
                    Json::obj(vec![
                        ("window", Json::u64(*window)),
                        ("drains", Json::u64(*drains)),
                        ("widened", Json::Bool(*widened)),
                    ]),
                )],
            ),
            ReportEvent::WindowClosed(wr) => {
                self.line("window", vec![("window", window_json(wr))])
            }
            ReportEvent::TierFolded {
                level,
                first_window,
                last_window,
                windows,
                retained,
            } => self.line(
                "tier",
                vec![(
                    "tier",
                    Json::obj(vec![
                        ("level", Json::u64(*level as u64)),
                        ("first_window", Json::u64(*first_window)),
                        ("last_window", Json::u64(*last_window)),
                        ("windows", Json::u64(*windows)),
                        ("retained", Json::u64(*retained)),
                    ]),
                )],
            ),
            ReportEvent::Final(fe) => {
                let (report, cumulative, recent) = final_json(fe);
                let mut fields =
                    vec![("report", report), ("cumulative_topk", cumulative)];
                if let Some(recent) = recent {
                    fields.push(("recent_topk", recent));
                }
                self.line("final", fields)
            }
            ReportEvent::Scorecard(sc) => {
                self.line("scorecard", vec![("scorecard", scorecard_json(sc))])
            }
            ReportEvent::SessionEnd { runtime_ns } => self.line(
                "session_end",
                vec![("runtime_ns", Json::u64(*runtime_ns))],
            ),
        }
    }

    fn finish(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::sink::SessionMode;

    fn sample_report() -> Report {
        Report {
            app: "mysql+dedup".into(),
            backend: "native",
            runtime_ns: u64::MAX - 7, // beyond f64 precision on purpose
            bottlenecks: vec![Bottleneck {
                rank: 1,
                total_cm_ms: 1.25,
                slices: 4,
                class: BottleneckClass::Pipeline,
                top_wakers: vec![("worker-1".into(), 3)],
                apps: vec![("mysql".into(), 3), ("dedup".into(), 1)],
                call_path: vec!["main".into(), "enqueue \"x\"".into()],
                samples: vec![
                    SampleLine {
                        rendered: "emd (emd.c:57)".into(),
                        function: Some("emd".into()),
                        count: 7,
                    },
                    SampleLine {
                        rendered: "??".into(),
                        function: None,
                        count: 1,
                    },
                ],
                stack_top_samples: 2,
            }],
            threads: vec![ThreadCm {
                pid: 12,
                comm: "worker".into(),
                cm_ms: 0.5,
                wall_ms: 1.5,
            }],
            total_slices: 100,
            critical_slices: 7,
            samples: 55,
            intervals: 20,
            ring_dropped: 5,
            ring_shards: vec![RingBufStats {
                pushed: 60,
                dropped: 5,
                drained: 55,
                peak: 9,
            }],
            stack_ids: 3,
            stack_drops: 1,
            stack_evictions: 2,
            window_drops: vec![0, 5],
            windows_total: 2,
            windows_lossy: 1,
            windows_drop_total: 5,
            memory_bytes: 4096,
            ppt_seconds: 0.125,
            probe_cost_ns: 777,
            ..Default::default()
        }
    }

    #[test]
    fn report_round_trips_through_json() {
        let r = sample_report();
        let parsed = Json::parse(&report_json(&r).to_pretty()).unwrap();
        let rt = report_from_json(&parsed).unwrap();
        // The human rendering is the equality oracle: every field the
        // report can show must survive.
        assert_eq!(rt.to_string(), r.to_string());
        // And fields the renderer elides must survive too.
        assert_eq!(rt.runtime_ns, r.runtime_ns);
        assert_eq!(rt.probe_cost_ns, r.probe_cost_ns);
        assert_eq!(rt.intervals, r.intervals);
        assert_eq!(rt.samples_of("emd"), 7);
        assert_eq!(rt.ring_shards.len(), 1);
        assert_eq!(rt.ring_shards[0].peak, 9);
    }

    #[test]
    fn mismatched_schema_versions_are_rejected_with_a_real_error() {
        // A stamped report from schema v0 or a future v2 must refuse to
        // decode — the version is bumped only on breaking changes, so
        // best-effort decoding would silently misread fields.
        for bad in [0u64, 2] {
            let mut j = report_json(&sample_report());
            if let Json::Obj(fields) = &mut j {
                fields.insert(0, ("schema".to_string(), Json::u64(bad)));
            }
            let err = report_from_json(&j).unwrap_err().to_string();
            assert!(
                err.contains(&format!("version {bad}")),
                "v{bad}: error should name the version, got {err:?}"
            );
            assert!(err.contains("1"), "{err}");
        }
        // The supported version (and the historical unstamped shape)
        // both still decode.
        let mut j = report_json(&sample_report());
        if let Json::Obj(fields) = &mut j {
            fields.insert(0, ("schema".to_string(), Json::u64(SCHEMA_VERSION)));
        }
        assert!(report_from_json(&j).is_ok());
        assert!(report_from_json(&report_json(&sample_report())).is_ok());
        // A mistyped stamp is corruption, not "absent".
        let mut j = report_json(&sample_report());
        if let Json::Obj(fields) = &mut j {
            fields.insert(0, ("schema".to_string(), Json::str("one")));
        }
        assert!(report_from_json(&j).is_err());
    }

    #[test]
    fn degrade_accounting_round_trips_and_streams() {
        // Report fields survive the JSON round-trip…
        let mut r = sample_report();
        r.degraded_windows = 2;
        r.degraded_drains = 9;
        let parsed = Json::parse(&report_json(&r).to_compact()).unwrap();
        let rt = report_from_json(&parsed).unwrap();
        assert_eq!(rt.degraded_windows, 2);
        assert_eq!(rt.degraded_drains, 9);
        assert_eq!(rt.to_string(), r.to_string());
        // …and an old document without them decodes to zero.
        let rt = report_from_json(&report_json(&sample_report())).unwrap();
        assert_eq!((rt.degraded_windows, rt.degraded_drains), (0, 0));

        // The JSONL stream frames a schema-stamped "degraded" line.
        let mut sink = JsonlSink::new(Vec::new());
        sink.on_event(&ReportEvent::Degraded {
            window: 3,
            drains: 4,
            widened: true,
        })
        .unwrap();
        sink.finish().unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        let v = Json::parse(out.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("schema").unwrap().as_u64(), Some(SCHEMA_VERSION));
        assert_eq!(v.get("event").unwrap().as_str(), Some("degraded"));
        let body = v.get("degraded").unwrap();
        assert_eq!(body.get("window").unwrap().as_u64(), Some(3));
        assert_eq!(body.get("drains").unwrap().as_u64(), Some(4));
        assert_eq!(body.get("widened").unwrap().as_bool(), Some(true));

        // The one-document sink ignores the notice (additive event).
        let mut doc = JsonSink::new(Vec::new());
        doc.on_event(&ReportEvent::Degraded {
            window: 1,
            drains: 1,
            widened: false,
        })
        .unwrap();
        doc.on_event(&ReportEvent::SessionEnd { runtime_ns: 1 }).unwrap();
        doc.finish().unwrap();
        let parsed =
            Json::parse(&String::from_utf8(doc.into_inner()).unwrap()).unwrap();
        assert_eq!(parsed.get("windows").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn unknown_class_labels_fail_loudly() {
        let mut j = report_json(&sample_report());
        if let Json::Obj(fields) = &mut j {
            let b = fields
                .iter_mut()
                .find(|(k, _)| k == "bottlenecks")
                .unwrap();
            if let Json::Arr(items) = &mut b.1 {
                if let Json::Obj(bf) = &mut items[0] {
                    bf.iter_mut().find(|(k, _)| k == "class").unwrap().1 =
                        Json::str("not a class");
                }
            }
        }
        let err = report_from_json(&j).unwrap_err().to_string();
        assert!(err.contains("not a class"), "{err}");
    }

    #[test]
    fn jsonl_emits_one_schema_stamped_line_per_event() {
        let info = SessionInfo {
            mode: SessionMode::Live,
            apps: vec!["canneal".to_string()],
            shards: 4,
            window_ns: Some(5_000_000),
            config: GappConfig::default(),
        };
        let r = sample_report();
        let mut sink = JsonlSink::new(Vec::new());
        sink.on_event(&ReportEvent::SessionStart(&info)).unwrap();
        sink.on_event(&ReportEvent::Final(FinalEvent {
            report: &r,
            windows: &[],
            windows_total: 2,
            sketch_top: &[(3, 100, 10)],
            sketch_lines: &["line".to_string()],
            recent_top: &[],
            recent_lines: &[],
        }))
        .unwrap();
        sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 42 })
            .unwrap();
        sink.finish().unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 3);
        for (line, ev) in lines.iter().zip(["session_start", "final", "session_end"]) {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.get("schema").unwrap().as_u64(), Some(SCHEMA_VERSION));
            assert_eq!(v.get("event").unwrap().as_str(), Some(ev));
        }
        let start = Json::parse(lines[0]).unwrap();
        assert_eq!(
            start
                .get("session")
                .and_then(|s| s.get("window_ns"))
                .and_then(|w| w.as_u64()),
            Some(5_000_000)
        );
        let end = Json::parse(lines[2]).unwrap();
        assert_eq!(end.get("runtime_ns").unwrap().as_u64(), Some(42));
    }

    #[test]
    fn jsonl_serializes_shard_partials_and_json_document_ignores_them() {
        use crate::gapp::userspace::{PathAccumulator, SliceEntry};
        use crate::simkernel::WaitKind;
        let mut acc = PathAccumulator::new();
        acc.add_slice(
            &SliceEntry {
                ts_id: 41,
                pid: 3,
                cm_ns: 2.5,
                threads_av: 1.0,
                stack_id: 9,
                addrs: vec![0x40],
                from_stack_top: false,
                wait: WaitKind::Futex,
                woken_by: 0,
            },
            0,
        );
        let paths = acc.take_paths();
        let sw = ShardWindowEvent {
            index: 2,
            shard: 1,
            slices: 1,
            drained: 7,
            drops: 0,
            paths: &paths,
        };
        let mut sink = JsonlSink::new(Vec::new());
        sink.on_event(&ReportEvent::ShardWindow(sw)).unwrap();
        sink.finish().unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        let v = Json::parse(out.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("event").unwrap().as_str(), Some("shard_window"));
        let body = v.get("shard_window").unwrap();
        assert_eq!(body.get("index").unwrap().as_u64(), Some(2));
        assert_eq!(body.get("shard").unwrap().as_u64(), Some(1));
        let p = &body.get("paths").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("stack_id").unwrap().as_u64(), Some(9));
        assert_eq!(p.get("first_seen").unwrap().as_u64(), Some(41));
        assert_eq!(p.get("cm_fs").unwrap().as_u64(), Some(2_500_000));

        // The one-document sink keeps its shape: partials contribute
        // nothing (additive event kinds stay out of the v1 document).
        let mut doc = JsonSink::new(Vec::new());
        doc.on_event(&ReportEvent::ShardWindow(sw)).unwrap();
        doc.on_event(&ReportEvent::SessionEnd { runtime_ns: 1 }).unwrap();
        doc.finish().unwrap();
        let parsed =
            Json::parse(&String::from_utf8(doc.into_inner()).unwrap()).unwrap();
        assert_eq!(parsed.get("windows").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn scorecards_stream_as_lines_and_stack_additively_in_the_document() {
        use crate::gapp::sink::{Assignment, ScoreRow, ScorecardEvent};
        let sc = ScorecardEvent {
            scope: "seed=7".to_string(),
            cases: 1,
            rows: vec![
                ScoreRow {
                    class: BottleneckClass::Synchronization,
                    tp: 1,
                    fp: 0,
                    fn_: 0,
                },
                ScoreRow {
                    class: BottleneckClass::Io,
                    tp: 0,
                    fp: 1,
                    fn_: 1,
                },
            ],
            assignments: vec![Assignment {
                app: "lock_convoy#0".to_string(),
                truth: BottleneckClass::Synchronization,
                predicted: Some(BottleneckClass::Synchronization),
            }],
        };

        // JSONL: one schema-stamped "scorecard" line.
        let mut sink = JsonlSink::new(Vec::new());
        sink.on_event(&ReportEvent::Scorecard(&sc)).unwrap();
        sink.finish().unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        let v = Json::parse(out.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("schema").unwrap().as_u64(), Some(SCHEMA_VERSION));
        assert_eq!(v.get("event").unwrap().as_str(), Some("scorecard"));
        let body = v.get("scorecard").unwrap();
        assert_eq!(body.get("scope").unwrap().as_str(), Some("seed=7"));
        let rows = body.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].get("class").unwrap().as_str(),
            Some("synchronization (futex)")
        );
        assert_eq!(rows[0].get("precision").unwrap().as_f64(), Some(1.0));
        assert_eq!(rows[1].get("recall").unwrap().as_f64(), Some(0.0));
        let overall = body.get("overall").unwrap();
        // overall sums the counts: tp 1, fp 1, fn 1 → p = r = 0.5.
        assert_eq!(overall.get("tp").unwrap().as_u64(), Some(1));
        assert_eq!(overall.get("precision").unwrap().as_f64(), Some(0.5));
        let asn = &body.get("assignments").unwrap().as_arr().unwrap()[0];
        assert_eq!(asn.get("app").unwrap().as_str(), Some("lock_convoy#0"));
        assert_eq!(
            asn.get("predicted").unwrap().as_str(),
            Some("synchronization (futex)")
        );

        // JSON document: scorecards appear only when emitted, keeping
        // plain profiling documents byte-identical.
        let mut doc = JsonSink::new(Vec::new());
        doc.on_event(&ReportEvent::SessionEnd { runtime_ns: 1 }).unwrap();
        doc.finish().unwrap();
        let plain = Json::parse(&String::from_utf8(doc.into_inner()).unwrap()).unwrap();
        assert!(plain.get("scorecards").is_none(), "additive key leaked");

        let mut doc = JsonSink::new(Vec::new());
        doc.on_event(&ReportEvent::Scorecard(&sc)).unwrap();
        doc.on_event(&ReportEvent::SessionEnd { runtime_ns: 1 }).unwrap();
        doc.finish().unwrap();
        let with = Json::parse(&String::from_utf8(doc.into_inner()).unwrap()).unwrap();
        assert_eq!(with.get("scorecards").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn symbols_stream_as_schema_stamped_lines_and_stay_out_of_documents() {
        use crate::gapp::sink::SymbolEntry;
        let entries = vec![SymbolEntry {
            stack_id: 7,
            frames: vec![0x40, 0x90],
            rendered: vec!["emd (emd.c:57)".to_string(), "main".to_string()],
        }];
        let sy = SymbolsEvent { entries: &entries };
        let mut sink = JsonlSink::new(Vec::new());
        sink.on_event(&ReportEvent::Symbols(sy)).unwrap();
        sink.finish().unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        let v = Json::parse(out.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("schema").unwrap().as_u64(), Some(SCHEMA_VERSION));
        assert_eq!(v.get("event").unwrap().as_str(), Some("symbols"));
        let e = &v.get("symbols").unwrap().get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("stack_id").unwrap().as_u64(), Some(7));
        assert_eq!(e.get("frames").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            e.get("rendered").unwrap().as_arr().unwrap()[0].as_str(),
            Some("emd (emd.c:57)")
        );

        // The one-document sink ignores the exchange (additive event).
        let mut doc = JsonSink::new(Vec::new());
        doc.on_event(&ReportEvent::Symbols(sy)).unwrap();
        doc.on_event(&ReportEvent::SessionEnd { runtime_ns: 1 }).unwrap();
        doc.finish().unwrap();
        let parsed =
            Json::parse(&String::from_utf8(doc.into_inner()).unwrap()).unwrap();
        assert_eq!(parsed.get("windows").unwrap().as_arr().unwrap().len(), 0);
    }

    /// An [`io::Write`] that records every flush and how many bytes had
    /// been written when it happened — the oracle for transport mode.
    struct FlushProbe {
        written: usize,
        flushes: Vec<usize>,
    }

    impl io::Write for &mut FlushProbe {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.written += buf.len();
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            self.flushes.push(self.written);
            Ok(())
        }
    }

    #[test]
    fn streaming_jsonl_flushes_every_event_as_it_is_emitted() {
        // Transport mode: each event is on the wire (flushed) the
        // moment on_event returns — a reader never waits for finish().
        let mut probe = FlushProbe { written: 0, flushes: Vec::new() };
        {
            let mut sink = JsonlSink::streaming(&mut probe);
            sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 1 }).unwrap();
        }
        assert_eq!(probe.flushes.len(), 1, "one flush per event");
        assert_eq!(
            probe.flushes[0], probe.written,
            "the whole line was flushed, not a prefix"
        );
        let after_first = probe.written;
        {
            let mut sink = JsonlSink::streaming(&mut probe);
            sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 2 }).unwrap();
            sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 3 }).unwrap();
        }
        assert_eq!(probe.flushes.len(), 3);
        assert!(probe.flushes[1] > after_first);

        // The default constructor keeps the buffered behavior: no
        // flush until finish().
        let mut probe = FlushProbe { written: 0, flushes: Vec::new() };
        {
            let mut sink = JsonlSink::new(&mut probe);
            sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 1 }).unwrap();
        }
        assert!(probe.flushes.is_empty(), "buffered mode must not flush per event");
        {
            let mut sink = JsonlSink::new(&mut probe);
            sink.finish().unwrap();
        }
        assert_eq!(probe.flushes.len(), 1);
    }

    #[test]
    fn json_sink_writes_one_document_at_session_end() {
        let info = SessionInfo {
            mode: SessionMode::Batch,
            apps: vec!["canneal".to_string()],
            shards: 1,
            window_ns: None,
            config: GappConfig::default(),
        };
        let r = sample_report();
        let mut sink = JsonSink::new(Vec::new());
        sink.on_event(&ReportEvent::SessionStart(&info)).unwrap();
        // Nothing hits the writer before SessionEnd.
        sink.on_event(&ReportEvent::Final(FinalEvent {
            report: &r,
            windows: &[],
            windows_total: 2,
            sketch_top: &[],
            sketch_lines: &[],
            recent_top: &[],
            recent_lines: &[],
        }))
        .unwrap();
        sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 9 })
            .unwrap();
        sink.finish().unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        let doc = Json::parse(&out).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_u64(), Some(SCHEMA_VERSION));
        assert_eq!(doc.get("type").unwrap().as_str(), Some("gapp.session"));
        assert_eq!(doc.get("windows").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(doc.get("runtime_ns").unwrap().as_u64(), Some(9));
        // A run without the decayed sketch carries no recent_topk key
        // at all (additive-fields policy keeps plain documents stable).
        assert!(doc.get("recent_topk").is_none());
        let rt = report_from_json(doc.get("report").unwrap()).unwrap();
        assert_eq!(rt.to_string(), r.to_string());
    }

    #[test]
    fn window_aggregates_round_trip_and_old_documents_derive_them() {
        // New documents stamp the aggregates explicitly…
        let r = sample_report();
        let j = report_json(&r);
        assert_eq!(j.get("windows_total").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("windows_lossy").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("windows_drop_total").unwrap().as_u64(), Some(5));
        let rt = report_from_json(&j).unwrap();
        assert_eq!(rt.windows_total, 2);
        assert_eq!(rt.windows_lossy, 1);
        assert_eq!(rt.windows_drop_total, 5);
        // …and an old document without them derives the same figures
        // from the per-window vector it always carried, so re-rendering
        // stays byte-identical.
        let mut old = j.to_compact();
        for key in [
            "\"windows_total\":2,",
            "\"windows_lossy\":1,",
            "\"windows_drop_total\":5,",
        ] {
            assert!(old.contains(key), "compact doc should contain {key}");
            old = old.replace(key, "");
        }
        let rt = report_from_json(&Json::parse(&old).unwrap()).unwrap();
        assert_eq!(rt.windows_total, 2);
        assert_eq!(rt.windows_lossy, 1);
        assert_eq!(rt.windows_drop_total, 5);
        assert_eq!(rt.to_string(), r.to_string());
    }

    #[test]
    fn tier_folds_stream_as_jsonl_lines_and_recent_topk_is_additive() {
        // The JSONL transport frames each fold as a schema-stamped
        // "tier" line…
        let mut sink = JsonlSink::new(Vec::new());
        sink.on_event(&ReportEvent::TierFolded {
            level: 2,
            first_window: 1,
            last_window: 64,
            windows: 64,
            retained: 3,
        })
        .unwrap();
        let r = sample_report();
        let recent_top = [(9u32, 4_000u64, 250u64)];
        let recent_lines = ["recent line".to_string()];
        sink.on_event(&ReportEvent::Final(FinalEvent {
            report: &r,
            windows: &[],
            windows_total: 2,
            sketch_top: &[(3, 100, 10)],
            sketch_lines: &[],
            recent_top: &recent_top,
            recent_lines: &recent_lines,
        }))
        .unwrap();
        sink.finish().unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        let tier = Json::parse(lines[0]).unwrap();
        assert_eq!(tier.get("schema").unwrap().as_u64(), Some(SCHEMA_VERSION));
        assert_eq!(tier.get("event").unwrap().as_str(), Some("tier"));
        let body = tier.get("tier").unwrap();
        assert_eq!(body.get("level").unwrap().as_u64(), Some(2));
        assert_eq!(body.get("first_window").unwrap().as_u64(), Some(1));
        assert_eq!(body.get("last_window").unwrap().as_u64(), Some(64));
        assert_eq!(body.get("windows").unwrap().as_u64(), Some(64));
        assert_eq!(body.get("retained").unwrap().as_u64(), Some(3));
        // …and a final line from a decayed run carries recent_topk
        // beside the cumulative sketch.
        let fin = Json::parse(lines[1]).unwrap();
        let recent = fin.get("recent_topk").unwrap();
        let top = recent.get("top").unwrap().as_arr().unwrap();
        assert_eq!(top[0].get("stack_id").unwrap().as_u64(), Some(9));
        assert_eq!(top[0].get("cm_fs_upper").unwrap().as_u64(), Some(4_000));

        // The one-document sink ignores tier folds (additive event) but
        // keeps the recent sketch when one was produced.
        let mut doc = JsonSink::new(Vec::new());
        doc.on_event(&ReportEvent::TierFolded {
            level: 1,
            first_window: 1,
            last_window: 8,
            windows: 8,
            retained: 1,
        })
        .unwrap();
        doc.on_event(&ReportEvent::Final(FinalEvent {
            report: &r,
            windows: &[],
            windows_total: 2,
            sketch_top: &[],
            sketch_lines: &[],
            recent_top: &recent_top,
            recent_lines: &recent_lines,
        }))
        .unwrap();
        doc.on_event(&ReportEvent::SessionEnd { runtime_ns: 1 }).unwrap();
        doc.finish().unwrap();
        let parsed =
            Json::parse(&String::from_utf8(doc.into_inner()).unwrap()).unwrap();
        assert_eq!(parsed.get("windows").unwrap().as_arr().unwrap().len(), 0);
        let recent = parsed.get("recent_topk").unwrap();
        let lines = recent.get("lines").unwrap().as_arr().unwrap();
        assert_eq!(lines[0].as_str(), Some("recent line"));
    }
}
