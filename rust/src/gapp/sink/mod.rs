//! Report sinks: the output seam of the profiler.
//!
//! Every GAPP mode — batch (`gapp profile`), live windows (`gapp
//! live`), system-wide multi-app — drives one session that *emits
//! typed events* instead of printing strings:
//!
//! * [`ReportEvent::SessionStart`] — the resolved configuration, the
//!   application list and the transport shard count, before any work.
//! * [`ReportEvent::ShardWindow`] — *opt-in* (`LiveConfig::
//!   shard_partials`, tree strategy only): one event per (window ×
//!   shard) carrying that shard's partial aggregation before the merge
//!   tree combines it — the seam a cross-process merge ships as JSONL.
//! * [`ReportEvent::Symbols`] — *opt-in*, paired with `ShardWindow`:
//!   newly interned stack ids with frames + symbolization, so a
//!   cross-process consumer (`gapp serve` / `gapp aggregate`) can
//!   resolve every id the partials carry.
//! * [`ReportEvent::WindowClosed`] — one closed epoch window (live
//!   mode only): the window's top-K, drain/drop accounting, and the
//!   per-shard drop breakdown.
//! * [`ReportEvent::Final`] — the merged end-of-run [`Report`] plus the
//!   live tail (per-window summaries, cumulative sketch lines).
//! * [`ReportEvent::SessionEnd`] — the simulated runtime; the last
//!   event of every session.
//!
//! A [`ReportSink`] consumes that stream. Backends: [`HumanSink`]
//! (byte-identical to the pre-sink CLI text — golden-enforced),
//! [`JsonSink`] (one versioned document per session), [`JsonlSink`]
//! (one event per line, transport-friendly), [`TeeSink`] / [`FnSink`]
//! (multiplexing and callbacks). Future transports (sockets, merge
//! trees over per-shard aggregations, dashboards) implement the same
//! trait and plug into [`super::Session`] unchanged.

pub mod human;
pub mod json;

pub use human::HumanSink;
pub use json::{report_from_json, JsonSink, JsonlSink};

use std::io;

use anyhow::Result;

use super::classify::BottleneckClass;
use super::config::{GappConfig, ReportFormat};
use super::report::Report;
use super::stream::{WindowReport, WindowSummary};
use super::userspace::MergedPath;

/// How the session drives its kernel: one batch run, or epoch windows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionMode {
    Batch,
    Live,
}

impl SessionMode {
    pub fn name(self) -> &'static str {
        match self {
            SessionMode::Batch => "batch",
            SessionMode::Live => "live",
        }
    }
}

/// Everything known at session start.
#[derive(Clone, Debug)]
pub struct SessionInfo {
    pub mode: SessionMode,
    /// Profiled application names, in spawn order (application ids in
    /// per-app attributions index into this list).
    pub apps: Vec<String>,
    /// Resolved ring-shard count (the per-CPU default applied).
    pub shards: usize,
    /// Epoch window length; `None` for batch sessions.
    pub window_ns: Option<u64>,
    pub config: GappConfig,
}

/// The end-of-run payload: the merged report plus the live-mode tail
/// that the CLI used to assemble by hand.
#[derive(Clone, Copy, Debug)]
pub struct FinalEvent<'a> {
    pub report: &'a Report,
    /// One summary per closed window (empty for batch). Under
    /// `--compact-base` these are the retained *tier-entry* summaries
    /// (each covering a contiguous run of windows, counters summed), so
    /// the list stays O(B·log T); sums over it are unchanged.
    pub windows: &'a [WindowSummary],
    /// Windows actually closed (equals `windows.len()` without
    /// compaction; the true count with it). 0 for batch.
    pub windows_total: u64,
    /// Cumulative space-saving top-K:
    /// `(stack_id, cm_fs_upper_bound, max_overestimate_fs)`.
    pub sketch_top: &'a [(u32, u64, u64)],
    /// The sketch rendered for display (empty for batch).
    pub sketch_lines: &'a [String],
    /// Time-decayed top-K (`--decay-half-life-us`): same shape as
    /// `sketch_top`, counts exponentially decayed toward the end of the
    /// run. Empty when the knob is off — additive within schema v1.
    pub recent_top: &'a [(u32, u64, u64)],
    /// `recent_top` rendered for display (empty when the knob is off).
    pub recent_lines: &'a [String],
}

/// One ring shard's partial window aggregation, emitted before the
/// merge tree combines the partials (opt-in; see the module docs).
/// Within schema v1 this is an *additive* event kind: it only appears
/// when explicitly requested, so consumers that predate it never see
/// it, and (per the versioning policy) consumers must skip unknown
/// event kinds anyway.
#[derive(Clone, Copy, Debug)]
pub struct ShardWindowEvent<'a> {
    /// 1-based window index (matches the following `WindowClosed`).
    pub index: u64,
    /// Ring shard this partial covers.
    pub shard: usize,
    /// Slices this shard folded this window.
    pub slices: u64,
    /// Ring records drained from / dropped on this shard this epoch.
    pub drained: u64,
    pub drops: u64,
    /// The shard-local merge snapshot. Aggregates are associative and
    /// the `first_seen` stamps reconcile ordering, so concatenating
    /// these partials across processes and running `merge_tree`
    /// reproduces the window snapshot exactly.
    pub paths: &'a [MergedPath],
}

/// One newly interned stack: its stable id, raw frame addresses, and
/// the producer-side symbolization of each address. Shipped once per
/// id (the id-stability contract: an id, once announced, always means
/// the same frames for the rest of the session), so a cross-process
/// consumer can resolve every id in later `shard_window` partials
/// without access to the producer's symbol tables.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SymbolEntry {
    pub stack_id: u32,
    /// Raw frame addresses, innermost first (the interned stack).
    pub frames: Vec<u64>,
    /// `frames` rendered by the producer's symbolizer, same order.
    pub rendered: Vec<String>,
}

/// The symbol-exchange event: every stack id first interned during the
/// window about to be emitted (opt-in, with `ShardWindow`; additive
/// within schema v1 like the other opt-in kinds).
#[derive(Clone, Copy, Debug)]
pub struct SymbolsEvent<'a> {
    pub entries: &'a [SymbolEntry],
}

/// One per-class row of a classification scorecard. Only the integer
/// confusion counts are stored; the derived ratios are computed on
/// demand so merged scorecards stay exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScoreRow {
    pub class: BottleneckClass,
    /// True positives: the class was injected and reported.
    pub tp: u64,
    /// False positives: the class was reported for another injection.
    pub fp: u64,
    /// False negatives: the class was injected but not reported.
    pub fn_: u64,
}

impl ScoreRow {
    /// `tp / (tp + fp)`; 0 when the class was never predicted.
    pub fn precision(&self) -> f64 {
        ratio(self.tp, self.tp + self.fp)
    }

    /// `tp / (tp + fn)`; 0 when the class was never injected.
    pub fn recall(&self) -> f64 {
        ratio(self.tp, self.tp + self.fn_)
    }

    /// Harmonic mean of precision and recall; 0 when both are 0.
    pub fn f1(&self) -> f64 {
        let (p, r) = (self.precision(), self.recall());
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

/// One labeled app's verdict inside a scorecard: what was injected
/// versus what `classify()` reported for the highest-ranked bottleneck
/// attributed to that app (`None` = nothing in the top-K matched).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub app: String,
    pub truth: BottleneckClass,
    pub predicted: Option<BottleneckClass>,
}

/// A bottleneck-classification scorecard: per-class precision /
/// recall / F1 of the report's top-K classes against the scenario's
/// injected ground-truth labels (see `crate::scenario`). `rows`
/// always carries every [`BottleneckClass`] in `ALL` order; matrix
/// aggregates sum the integer counts across cases and leave
/// `assignments` empty.
#[derive(Clone, Debug, PartialEq)]
pub struct ScorecardEvent {
    /// What was scored (`case 0: seed=7`, `matrix aggregate`, …).
    pub scope: String,
    /// Expanded scenario cases this card covers.
    pub cases: u64,
    pub rows: Vec<ScoreRow>,
    pub assignments: Vec<Assignment>,
}

impl ScorecardEvent {
    /// Micro-averaged totals across the rows (summed counts).
    pub fn overall(&self) -> ScoreRow {
        let mut total = ScoreRow {
            class: BottleneckClass::Compute,
            tp: 0,
            fp: 0,
            fn_: 0,
        };
        for r in &self.rows {
            total.tp += r.tp;
            total.fp += r.fp;
            total.fn_ += r.fn_;
        }
        total
    }
}

/// One event of a profiling session, in emission order:
/// `SessionStart ((Symbols)? (ShardWindow)* (Degraded)? WindowClosed
/// (TierFolded)*)* Final (Scorecard)? SessionEnd`
/// (`Symbols`/`ShardWindow` only when opted in; `Degraded` only under
/// `--on-overflow degrade` and only for windows that degraded;
/// `TierFolded` only under `--compact-base` and only after windows
/// whose close triggered folds; `Scorecard` only for scenario
/// sessions).
#[derive(Clone, Copy, Debug)]
pub enum ReportEvent<'a> {
    SessionStart(&'a SessionInfo),
    /// Newly interned stack ids with their frames and symbolization
    /// (additive within schema v1; emitted with `ShardWindow`, before
    /// the window's partials, so a consumer can resolve every id it is
    /// about to receive).
    Symbols(SymbolsEvent<'a>),
    ShardWindow(ShardWindowEvent<'a>),
    /// Graceful-degradation notice (additive within schema v1, like
    /// `ShardWindow`): the window about to close absorbed overflow
    /// pressure instead of shedding records — `drains` emergency ring
    /// drains ran, and `widened` says whether the window traded
    /// granularity by absorbing the following epoch.
    Degraded {
        /// 1-based window index (matches the following `WindowClosed`).
        window: u64,
        /// Emergency drains performed while the window was open.
        drains: u64,
        /// Whether the window was widened by one epoch in response.
        widened: bool,
    },
    WindowClosed(&'a WindowReport),
    /// Tier compaction notice (additive within schema v1, like
    /// `ShardWindow`: only `--compact-base` sessions emit it): the
    /// window that just closed filled a pyramid level, folding `B`
    /// entries into one covering `first_window..=last_window`. A
    /// cascade emits one event per level folded.
    TierFolded {
        /// Level the folded entry landed on (≥ 1).
        level: u32,
        /// First window the folded entry covers (1-based, inclusive).
        first_window: u64,
        /// Last window covered (inclusive).
        last_window: u64,
        /// Windows covered (`last_window - first_window + 1`).
        windows: u64,
        /// Entries retained across the pyramid after this fold.
        retained: u64,
    },
    Final(FinalEvent<'a>),
    /// Classification quality versus injected ground truth (additive
    /// within schema v1, like `ShardWindow`: only scenario sessions
    /// emit it — `gapp scenario run` after `Final`, `gapp scenario
    /// matrix` once per case plus one aggregate — so the byte-stable
    /// output of every pre-existing mode is unchanged).
    Scorecard(&'a ScorecardEvent),
    SessionEnd { runtime_ns: u64 },
}

/// A consumer of session events. Implementations must tolerate the
/// batch stream (no `WindowClosed` events) and must not assume they
/// see `SessionEnd` on error paths — flushing belongs in [`finish`].
///
/// [`finish`]: ReportSink::finish
pub trait ReportSink {
    fn on_event(&mut self, ev: &ReportEvent<'_>) -> Result<()>;

    /// Called once after the session's last event; flush buffers here.
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

impl<S: ReportSink + ?Sized> ReportSink for Box<S> {
    fn on_event(&mut self, ev: &ReportEvent<'_>) -> Result<()> {
        (**self).on_event(ev)
    }

    fn finish(&mut self) -> Result<()> {
        (**self).finish()
    }
}

/// Multiplex one event stream into two sinks (nest for more). Both
/// sinks see every event; the first error wins.
pub struct TeeSink<A: ReportSink, B: ReportSink> {
    pub a: A,
    pub b: B,
}

impl<A: ReportSink, B: ReportSink> TeeSink<A, B> {
    pub fn new(a: A, b: B) -> TeeSink<A, B> {
        TeeSink { a, b }
    }
}

impl<A: ReportSink, B: ReportSink> ReportSink for TeeSink<A, B> {
    fn on_event(&mut self, ev: &ReportEvent<'_>) -> Result<()> {
        self.a.on_event(ev)?;
        self.b.on_event(ev)
    }

    fn finish(&mut self) -> Result<()> {
        self.a.finish()?;
        self.b.finish()
    }
}

/// A sink from a closure — the adapter behind the deprecated
/// callback-style `run_live` wrapper, and handy in tests.
pub struct FnSink<F: FnMut(&ReportEvent<'_>)>(pub F);

impl<F: FnMut(&ReportEvent<'_>)> ReportSink for FnSink<F> {
    fn on_event(&mut self, ev: &ReportEvent<'_>) -> Result<()> {
        (self.0)(ev);
        Ok(())
    }
}

/// Sink for a `--format` selection over an opened writer (the CLI's
/// stdout or `--output` file).
pub fn for_writer(format: ReportFormat, w: Box<dyn io::Write>) -> Box<dyn ReportSink> {
    match format {
        ReportFormat::Text => Box::new(HumanSink::new(w)),
        ReportFormat::Json => Box::new(JsonSink::new(w)),
        ReportFormat::Jsonl => Box::new(JsonlSink::new(w)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_sink(hits: std::rc::Rc<std::cell::Cell<u32>>) -> impl ReportSink {
        FnSink(move |_ev: &ReportEvent<'_>| hits.set(hits.get() + 1))
    }

    #[test]
    fn tee_delivers_every_event_to_both_sinks() {
        let a = std::rc::Rc::new(std::cell::Cell::new(0));
        let b = std::rc::Rc::new(std::cell::Cell::new(0));
        let mut tee = TeeSink::new(count_sink(a.clone()), count_sink(b.clone()));
        tee.on_event(&ReportEvent::SessionEnd { runtime_ns: 1 }).unwrap();
        tee.on_event(&ReportEvent::SessionEnd { runtime_ns: 2 }).unwrap();
        tee.finish().unwrap();
        assert_eq!((a.get(), b.get()), (2, 2));
    }

    #[test]
    fn score_row_ratios_handle_empty_denominators() {
        let zero = ScoreRow { class: BottleneckClass::Io, tp: 0, fp: 0, fn_: 0 };
        assert_eq!((zero.precision(), zero.recall(), zero.f1()), (0.0, 0.0, 0.0));
        let row = ScoreRow { class: BottleneckClass::Io, tp: 3, fp: 1, fn_: 2 };
        assert_eq!(row.precision(), 0.75);
        assert_eq!(row.recall(), 0.6);
        assert!((row.f1() - 2.0 * 0.75 * 0.6 / 1.35).abs() < 1e-12);
    }

    #[test]
    fn boxed_sinks_forward() {
        let n = std::rc::Rc::new(std::cell::Cell::new(0));
        let mut boxed: Box<dyn ReportSink + '_> = Box::new(count_sink(n.clone()));
        boxed
            .on_event(&ReportEvent::SessionEnd { runtime_ns: 0 })
            .unwrap();
        boxed.finish().unwrap();
        assert_eq!(n.get(), 1);
    }
}
