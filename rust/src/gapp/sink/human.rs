//! The human-readable text backend — the renderer that used to live on
//! `impl Display for Report` / `WindowReport`.
//!
//! Byte-compatibility is a hard contract here: [`render_report`] and
//! [`render_window`] produce exactly the strings the pre-sink CLI
//! printed (the `Display` impls now delegate to them, and the sink
//! golden tests pin the framing), so `gapp profile` / `gapp live`
//! output is unchanged by the sink redesign, shard count and mode
//! notwithstanding.

use std::fmt::Write as _;
use std::io;

use anyhow::Result;

use crate::gapp::report::Report;
use crate::gapp::stream::WindowReport;

use super::{FinalEvent, ReportEvent, ReportSink, ScorecardEvent, SessionMode};

/// Render the final report exactly as `Display` always has.
pub fn render_report(r: &Report) -> String {
    let mut f = String::new();
    // Writing to a String is infallible; unwrap keeps the body clean.
    let w = &mut f;
    writeln!(w, "== GAPP profile: {} (backend: {}) ==", r.app, r.backend).unwrap();
    writeln!(
        w,
        "runtime {:.1} ms | slices {} (critical {} = {:.2}%) | samples {} | stacks {}{} | mem {:.1} MB | ppt {:.2} s",
        r.runtime_ns as f64 / 1e6,
        r.total_slices,
        r.critical_slices,
        100.0 * r.critical_ratio(),
        r.samples,
        r.stack_ids,
        if r.stack_drops > 0 {
            format!(" (+{} dropped)", r.stack_drops)
        } else {
            String::new()
        },
        r.memory_bytes as f64 / (1024.0 * 1024.0),
        r.ppt_seconds,
    )
    .unwrap();
    // Rendered from the O(1) aggregates, never by walking the
    // O(windows) breakdown (which `--compact-base` folds away
    // entirely): text is byte-identical either way, and a multi-day
    // run's report costs the same to render as a short one's.
    if r.windows_total > 0 {
        writeln!(
            w,
            "windows {} | ring drops {} in {} window(s)",
            r.windows_total, r.windows_drop_total, r.windows_lossy,
        )
        .unwrap();
    }
    // Degradation accounting, only when the degrade policy actually
    // fired (shed-policy and lossless runs render byte-identically to
    // the historical format — the goldens rely on it).
    if r.degraded_windows > 0 || r.degraded_drains > 0 {
        writeln!(
            w,
            "degraded: {} window(s) widened | {} emergency drain(s)",
            r.degraded_windows, r.degraded_drains,
        )
        .unwrap();
    }
    // Per-shard breakdown, only when records were actually lost on a
    // multi-ring transport (lossless runs render identically across
    // shard counts — the sharded-vs-single-ring golden relies on it).
    if r.ring_dropped > 0 && r.ring_shards.len() > 1 {
        let lossy: Vec<String> = r
            .ring_shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.dropped > 0)
            .map(|(i, s)| format!("s{i} dropped {} (peak {})", s.dropped, s.peak))
            .collect();
        writeln!(w, "ring shards: {}", lossy.join(", ")).unwrap();
    }
    for b in &r.bottlenecks {
        writeln!(
            w,
            "\n#{} [{}] CMetric {:.2} ms over {} slices{}",
            b.rank,
            b.class.label(),
            b.total_cm_ms,
            b.slices,
            if b.stack_top_samples > 0 {
                format!(" ({} stack-top)", b.stack_top_samples)
            } else {
                String::new()
            }
        )
        .unwrap();
        writeln!(w, "  call path:").unwrap();
        for (i, frame) in b.call_path.iter().enumerate() {
            writeln!(w, "    {:indent$}{}", "", frame, indent = i).unwrap();
        }
        if !b.apps.is_empty() {
            let ap: Vec<String> = b
                .apps
                .iter()
                .map(|(a, n)| format!("{a} x{n}"))
                .collect();
            writeln!(w, "  apps: {}", ap.join(", ")).unwrap();
        }
        if !b.top_wakers.is_empty() {
            let wk: Vec<String> = b
                .top_wakers
                .iter()
                .map(|(c, n)| format!("{c} x{n}"))
                .collect();
            writeln!(w, "  woken by: {}", wk.join(", ")).unwrap();
        }
        writeln!(w, "  samples:").unwrap();
        for s in b.samples.iter().take(6) {
            writeln!(w, "    {:>6}  {}", s.count, s.rendered).unwrap();
        }
    }
    f
}

/// Render one live window exactly as `Display` always has.
pub fn render_window(wr: &WindowReport) -> String {
    let mut f = String::new();
    let w = &mut f;
    write!(
        w,
        "[w{:>4} {:>10.3}-{:>10.3} ms] slices {} | paths {} | drained {} | drops {}",
        wr.index,
        wr.start_ns as f64 / 1e6,
        wr.end_ns as f64 / 1e6,
        wr.slices,
        wr.snapshot.len(),
        wr.drained,
        wr.drops,
    )
    .unwrap();
    // Shard breakdown only when lossy AND actually sharded — a
    // single-ring total has nothing to break down (mirrors the
    // report's guard, and keeps `--shards 1` output unchanged).
    if wr.drops > 0 && wr.shard_drops.len() > 1 {
        let lossy: Vec<String> = wr
            .shard_drops
            .iter()
            .enumerate()
            .filter(|(_, d)| **d > 0)
            .map(|(i, d)| format!("s{i}:{d}"))
            .collect();
        if !lossy.is_empty() {
            write!(w, " [{}]", lossy.join(" ")).unwrap();
        }
    }
    // Degrade-policy accounting, appended only when it fired — windows
    // under the default shed policy render byte-identically to the
    // historical format.
    if wr.degraded_drains > 0 || wr.widened {
        write!(w, " | degraded drains {}", wr.degraded_drains).unwrap();
        if wr.widened {
            write!(w, " (widened)").unwrap();
        }
    }
    writeln!(w).unwrap();
    if wr.top.is_empty() {
        writeln!(w, "  (no critical slices this window)").unwrap();
    }
    for l in &wr.top {
        writeln!(
            w,
            "  #{:<2} {:<14} {:>9.3} ms x{:<5} {:<24} {}",
            l.rank, l.app, l.cm_ms, l.slices, l.class, l.site,
        )
        .unwrap();
    }
    f
}

/// Render the live-mode session tail (the lines `gapp live` prints
/// after the last window) — shared by [`HumanSink`] and the golden
/// test that pins it against the pre-sink CLI assembly.
pub fn render_live_tail(fe: &FinalEvent<'_>) -> String {
    let mut s = String::new();
    s.push('\n');
    // `windows_total`, not `windows.len()`: under `--compact-base` the
    // retained summaries are tier entries, but the header still counts
    // real windows — byte-identical to the uncompacted run.
    let _ = writeln!(
        s,
        "== final (merged from {} windows) ==",
        fe.windows_total
    );
    s.push_str(&render_report(fe.report));
    if !fe.sketch_lines.is_empty() {
        s.push('\n');
        let _ = writeln!(
            s,
            "cumulative top-{} (space-saving sketch; counts are upper bounds):",
            fe.sketch_lines.len()
        );
        for l in fe.sketch_lines {
            let _ = writeln!(s, "  {l}");
        }
    }
    // The decayed block only exists when `--decay-half-life-us` is on,
    // so pre-existing output stays byte-stable (golden-enforced).
    if !fe.recent_lines.is_empty() {
        s.push('\n');
        let _ = writeln!(
            s,
            "recent top-{} (decayed space-saving; counts are upper bounds):",
            fe.recent_lines.len()
        );
        for l in fe.recent_lines {
            let _ = writeln!(s, "  {l}");
        }
    }
    // Tier-entry summaries sum their covered windows' drops exactly, so
    // this figure is compaction-invariant too.
    let lossy: u64 = fe.windows.iter().map(|w| w.drops).sum();
    if lossy > 0 {
        let _ = writeln!(
            s,
            "note: {lossy} ring drops occurred; see per-window attribution above"
        );
    }
    s
}

/// Render a classification scorecard as a fixed-width table: one row
/// per [`crate::gapp::classify::BottleneckClass`] (in `ALL` order, as
/// produced by the scorer), a micro-averaged `overall` row, and —
/// for single-case cards — the per-app truth/predicted assignments.
pub fn render_scorecard(sc: &ScorecardEvent) -> String {
    let mut s = String::new();
    let w = &mut s;
    writeln!(
        w,
        "== scorecard: {} ({} case{}) ==",
        sc.scope,
        sc.cases,
        if sc.cases == 1 { "" } else { "s" },
    )
    .unwrap();
    writeln!(
        w,
        "{:<24} {:>4} {:>4} {:>4} {:>10} {:>8} {:>8}",
        "class", "tp", "fp", "fn", "precision", "recall", "f1",
    )
    .unwrap();
    let overall = sc.overall();
    let labeled = sc
        .rows
        .iter()
        .map(|r| (r.class.label(), r))
        .chain(std::iter::once(("overall", &overall)));
    for (name, r) in labeled {
        writeln!(
            w,
            "{:<24} {:>4} {:>4} {:>4} {:>10.3} {:>8.3} {:>8.3}",
            name,
            r.tp,
            r.fp,
            r.fn_,
            r.precision(),
            r.recall(),
            r.f1(),
        )
        .unwrap();
    }
    for a in &sc.assignments {
        writeln!(
            w,
            "  {:<20} injected {:<24} reported {}",
            a.app,
            a.truth.label(),
            match a.predicted {
                Some(c) => c.label(),
                None => "(absent from top-K)",
            },
        )
        .unwrap();
    }
    s
}

/// Text backend: what the CLI printed before sinks existed, byte for
/// byte. Batch sessions print the report (plus the trailing newline
/// `println!` used to add); live sessions print each window as it
/// closes, then the final header, report, cumulative sketch and the
/// lossy-run note.
pub struct HumanSink<W: io::Write> {
    w: W,
    mode: SessionMode,
}

impl<W: io::Write> HumanSink<W> {
    pub fn new(w: W) -> HumanSink<W> {
        HumanSink {
            w,
            // Overwritten by SessionStart; batch is the conservative
            // default (prints nothing until Final).
            mode: SessionMode::Batch,
        }
    }

    /// The wrapped writer (tests read the buffer back).
    pub fn into_inner(self) -> W {
        self.w
    }
}

impl<W: io::Write> ReportSink for HumanSink<W> {
    fn on_event(&mut self, ev: &ReportEvent<'_>) -> Result<()> {
        match ev {
            ReportEvent::SessionStart(info) => {
                self.mode = info.mode;
            }
            // Shard partials and the symbol exchange are a
            // machine-transport payload; the text backend stays
            // byte-identical to the pre-sink CLI whether or not they
            // are enabled.
            ReportEvent::Symbols(_) => {}
            ReportEvent::ShardWindow(_) => {}
            // Degradation is rendered inline on the window line and in
            // the final report's accounting — the standalone notice is
            // for machine consumers.
            ReportEvent::Degraded { .. } => {}
            // Tier folds are bookkeeping, not analysis: the text
            // output stays byte-identical with compaction on or off
            // (the JSONL sink ships them for machine consumers).
            ReportEvent::TierFolded { .. } => {}
            ReportEvent::WindowClosed(wr) => {
                self.w.write_all(render_window(wr).as_bytes())?;
            }
            ReportEvent::Final(fe) => match self.mode {
                SessionMode::Batch => {
                    self.w.write_all(render_report(fe.report).as_bytes())?;
                    self.w.write_all(b"\n")?;
                }
                SessionMode::Live => {
                    self.w.write_all(render_live_tail(fe).as_bytes())?;
                }
            },
            // Scorecards only exist in scenario sessions, so rendering
            // them unconditionally cannot perturb the golden-enforced
            // output of the pre-existing modes.
            ReportEvent::Scorecard(sc) => {
                self.w.write_all(render_scorecard(sc).as_bytes())?;
            }
            ReportEvent::SessionEnd { .. } => {}
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::config::GappConfig;
    use crate::gapp::sink::SessionInfo;

    fn start(mode: SessionMode) -> SessionInfo {
        SessionInfo {
            mode,
            apps: vec!["test".to_string()],
            shards: 1,
            window_ns: None,
            config: GappConfig::default(),
        }
    }

    #[test]
    fn batch_final_matches_println_of_display() {
        let report = Report {
            app: "test".into(),
            total_slices: 10,
            critical_slices: 2,
            ..Default::default()
        };
        let mut sink = HumanSink::new(Vec::new());
        sink.on_event(&ReportEvent::SessionStart(&start(SessionMode::Batch)))
            .unwrap();
        sink.on_event(&ReportEvent::Final(FinalEvent {
            report: &report,
            windows: &[],
            windows_total: 0,
            sketch_top: &[],
            sketch_lines: &[],
            recent_top: &[],
            recent_lines: &[],
        }))
        .unwrap();
        sink.on_event(&ReportEvent::SessionEnd { runtime_ns: 0 })
            .unwrap();
        sink.finish().unwrap();
        let out = String::from_utf8(sink.into_inner()).unwrap();
        // Exactly what `println!("{report}")` produced.
        assert_eq!(out, format!("{report}\n"));
    }

    #[test]
    fn display_delegates_to_render_report() {
        let report = Report {
            app: "delegate".into(),
            total_slices: 4,
            critical_slices: 1,
            ..Default::default()
        };
        assert_eq!(report.to_string(), render_report(&report));
    }

    #[test]
    fn degrade_accounting_renders_only_when_it_fired() {
        // Shed-policy reports stay byte-identical: no degrade line.
        let mut report = Report {
            app: "test".into(),
            ..Default::default()
        };
        assert!(!render_report(&report).contains("degraded"));
        report.degraded_windows = 2;
        report.degraded_drains = 7;
        let s = render_report(&report);
        assert!(
            s.contains("degraded: 2 window(s) widened | 7 emergency drain(s)"),
            "{s}"
        );

        let mut wr = crate::gapp::stream::WindowReport {
            index: 1,
            start_ns: 0,
            end_ns: 5_000_000,
            slices: 0,
            drained: 0,
            drops: 0,
            shard_drops: Vec::new(),
            degraded_drains: 0,
            widened: false,
            top: Vec::new(),
            snapshot: Vec::new(),
        };
        assert!(!render_window(&wr).contains("degraded"));
        wr.degraded_drains = 3;
        assert!(render_window(&wr).contains("| degraded drains 3\n"));
        wr.widened = true;
        assert!(render_window(&wr).contains("| degraded drains 3 (widened)\n"));
    }

    #[test]
    fn scorecard_renders_rows_overall_and_assignments() {
        use crate::gapp::classify::BottleneckClass;
        use crate::gapp::sink::{Assignment, ScoreRow, ScorecardEvent};
        let sc = ScorecardEvent {
            scope: "case 0: seed=7".to_string(),
            cases: 1,
            rows: vec![
                ScoreRow { class: BottleneckClass::Synchronization, tp: 1, fp: 0, fn_: 0 },
                ScoreRow { class: BottleneckClass::Io, tp: 0, fp: 1, fn_: 1 },
            ],
            assignments: vec![Assignment {
                app: "io_storm#0".to_string(),
                truth: BottleneckClass::Io,
                predicted: Some(BottleneckClass::Synchronization),
            }],
        };
        let s = render_scorecard(&sc);
        assert!(s.starts_with("== scorecard: case 0: seed=7 (1 case) ==\n"), "{s}");
        assert!(s.contains("synchronization (futex)"), "{s}");
        // Overall row micro-averages the counts: tp 1, fp 1, fn 1.
        assert!(s.contains("overall"), "{s}");
        assert!(s.contains("0.500"), "{s}");
        assert!(s.contains("injected blocking I/O"), "{s}");
        let mut sink = HumanSink::new(Vec::new());
        sink.on_event(&ReportEvent::Scorecard(&sc)).unwrap();
        sink.finish().unwrap();
        assert_eq!(String::from_utf8(sink.into_inner()).unwrap(), s);
    }

    #[test]
    fn live_tail_renders_header_sketch_and_lossy_note() {
        use crate::gapp::stream::WindowSummary;
        let report = Report {
            app: "live".into(),
            ..Default::default()
        };
        let windows = vec![
            WindowSummary {
                index: 1,
                slices: 3,
                drained: 10,
                drops: 0,
            },
            WindowSummary {
                index: 2,
                slices: 1,
                drained: 4,
                drops: 2,
            },
        ];
        let lines = vec!["appA        1.000 ms  site".to_string()];
        let tail = render_live_tail(&FinalEvent {
            report: &report,
            windows: &windows,
            windows_total: 2,
            sketch_top: &[],
            sketch_lines: &lines,
            recent_top: &[],
            recent_lines: &[],
        });
        assert!(tail.starts_with("\n== final (merged from 2 windows) ==\n"));
        assert!(tail.contains("cumulative top-1 (space-saving sketch"));
        assert!(tail.contains("note: 2 ring drops occurred"));
        // No decayed sketch ⇒ no recent block (byte-stable output).
        assert!(!tail.contains("recent top-"));
        // With one, the block lands between the cumulative sketch and
        // the lossy note.
        let recent = vec!["appA        0.250 ms  site".to_string()];
        let with_recent = render_live_tail(&FinalEvent {
            report: &report,
            windows: &windows,
            windows_total: 2,
            sketch_top: &[],
            sketch_lines: &lines,
            recent_top: &[],
            recent_lines: &recent,
        });
        let at = with_recent
            .find("recent top-1 (decayed space-saving; counts are upper bounds):")
            .unwrap();
        assert!(at > with_recent.find("cumulative top-1").unwrap());
        assert!(at < with_recent.find("note: 2 ring drops").unwrap());
        // Under compaction the summaries list holds tier entries but
        // the header still counts true windows.
        let folded = vec![WindowSummary {
            index: 2,
            slices: 4,
            drained: 14,
            drops: 2,
        }];
        let compacted = render_live_tail(&FinalEvent {
            report: &report,
            windows: &folded,
            windows_total: 2,
            sketch_top: &[],
            sketch_lines: &lines,
            recent_top: &[],
            recent_lines: &[],
        });
        assert_eq!(compacted, tail);
    }
}
