//! The user-space probe (paper §4.4): drains the circular buffer,
//! assembles sampled instruction pointers per timeslice, merges
//! identical call paths, and ranks the merged entries by total CMetric.
//!
//! This is where the three-layer architecture bites: the per-thread
//! CMetric accumulation (the paper's kernel-side `cm_hash`) is computed
//! here by streaming interval rows through the AOT-compiled XLA analysis
//! program in fixed-size batches. The in-kernel scalar path is retained
//! as a cross-check (`KernelProbes::cm_hash`), and an integration
//! test asserts the two agree.
//!
//! Call paths arrive as interned `u32` stack ids (see
//! [`crate::ebpf::StackMap`]), so the merge groups by id — an integer
//! key — instead of hashing full frame vectors; ids are resolved back
//! to frames only when a path reaches the final report.
//!
//! The merge itself is *incremental*: a [`PathAccumulator`] folds slices
//! (or previously-folded [`MergedPath`] snapshots) in arrival order, and
//! every aggregate it keeps is associative — CMetric totals accumulate
//! in integer femtoseconds, counts in integers — so the streaming
//! analyzer's window snapshots merge to *exactly* what one batch merge
//! over the concatenated stream produces. The batch path below is the
//! one-window special case; `gapp::stream` drives the many-window case.

use crate::ebpf::ringbuf::Stamped;
use crate::runtime::{AnalysisEngine, T_SLOTS};
use crate::simkernel::{Pid, WaitKind};
use crate::util::{FxHashMap, PidMap, sat_add};

use super::records::Record;

/// A critical timeslice awaiting the merge phase.
#[derive(Clone, Debug)]
pub struct SliceEntry {
    pub ts_id: u64,
    pub pid: Pid,
    pub cm_ns: f64,
    pub threads_av: f64,
    /// Interned id of the call path captured at the switch.
    pub stack_id: u32,
    /// Sampled IPs attributed to this slice (plus the switch IP).
    pub addrs: Vec<u64>,
    /// True when no samples landed and the stack top was substituted
    /// (the paper's "from stack top" label).
    pub from_stack_top: bool,
    /// What the slice ended waiting on (§7 classification).
    pub wait: WaitKind,
    /// Thread whose wakeup started this slice (0 = none/timer).
    pub woken_by: Pid,
}

/// A merged call path: summed CMetric + address frequency table.
///
/// Every field is an associative aggregate, so two `MergedPath`s for the
/// same stack id combine losslessly with [`MergedPath::merge_from`] —
/// the property the streaming analyzer's window snapshots rely on.
#[derive(Clone, Debug)]
pub struct MergedPath {
    /// Interned call-path id (resolve via the kernel stack map).
    pub stack_id: u32,
    /// Total CMetric in femtoseconds. This integer is the authoritative
    /// accumulator: integer addition is associative, so window-merged
    /// totals are bit-identical to batch totals regardless of where the
    /// window boundaries fell.
    pub cm_fs: u64,
    /// Total CMetric in ns — derived from [`MergedPath::cm_fs`].
    pub total_cm_ns: f64,
    /// Capture stamp (`SliceEntry::ts_id`) of the earliest slice folded
    /// into this path — `u64::MAX` until one is. Slice ids are assigned
    /// in kernel capture order, so sorting merged paths by this stamp
    /// reproduces exactly the first-seen order a single consumer of the
    /// globally-ordered stream would have produced. This is what lets
    /// shard-local partial accumulators (which each see only their
    /// shard's sub-order) merge back to the serial result byte for
    /// byte: every other field is an associative aggregate, and the
    /// output *order* reconciles through `min(first_seen)`.
    pub first_seen: u64,
    pub slices: u64,
    pub addr_freq: FxHashMap<u64, u64>,
    pub stack_top_samples: u64,
    /// Wait-kind histogram over the merged slices (§7 classification).
    pub wait_hist: FxHashMap<WaitKind, u64>,
    /// Waker histogram: who ended the waits that started these slices.
    pub wakers: FxHashMap<Pid, u64>,
    /// Slice counts per application id (system-wide mode attribution;
    /// single-app profiles put everything under app 0).
    pub app_slices: FxHashMap<u16, u64>,
}

/// CMetric quantization: ns (f64) → femtoseconds (u64). Sub-femtosecond
/// CMetric error is far below anything the report renders, and integer
/// femtoseconds make the merge associative.
#[inline]
fn cm_fs_of(cm_ns: f64) -> u64 {
    (cm_ns * 1e6).round() as u64
}

impl MergedPath {
    pub(crate) fn new(stack_id: u32) -> MergedPath {
        MergedPath {
            stack_id,
            cm_fs: 0,
            total_cm_ns: 0.0,
            first_seen: u64::MAX,
            slices: 0,
            addr_freq: FxHashMap::default(),
            stack_top_samples: 0,
            wait_hist: FxHashMap::default(),
            wakers: FxHashMap::default(),
            app_slices: FxHashMap::default(),
        }
    }

    /// Fold one critical slice into this path. The integer-femtosecond
    /// CMetric accumulates saturating: at 1e15 fs/s a long multi-app
    /// run can reach the top of `u64`, and a wrap would silently demote
    /// the heaviest path in the ranking.
    fn absorb(&mut self, s: &SliceEntry, app: u16) {
        self.cm_fs = sat_add(self.cm_fs, cm_fs_of(s.cm_ns));
        self.total_cm_ns = self.cm_fs as f64 / 1e6;
        self.first_seen = self.first_seen.min(s.ts_id);
        self.slices += 1;
        for a in &s.addrs {
            *self.addr_freq.entry(*a).or_insert(0) += 1;
        }
        if s.from_stack_top {
            self.stack_top_samples += 1;
        }
        *self.wait_hist.entry(s.wait).or_insert(0) += 1;
        if s.woken_by != 0 {
            *self.wakers.entry(s.woken_by).or_insert(0) += 1;
        }
        *self.app_slices.entry(app).or_insert(0) += 1;
    }

    /// Fold another merged snapshot of the *same* stack id into this
    /// one (window-snapshot concatenation).
    pub fn merge_from(&mut self, o: &MergedPath) {
        debug_assert_eq!(self.stack_id, o.stack_id);
        self.cm_fs = sat_add(self.cm_fs, o.cm_fs);
        self.total_cm_ns = self.cm_fs as f64 / 1e6;
        self.first_seen = self.first_seen.min(o.first_seen);
        self.slices += o.slices;
        for (a, n) in &o.addr_freq {
            *self.addr_freq.entry(*a).or_insert(0) += n;
        }
        self.stack_top_samples += o.stack_top_samples;
        for (k, n) in &o.wait_hist {
            *self.wait_hist.entry(*k).or_insert(0) += n;
        }
        for (p, n) in &o.wakers {
            *self.wakers.entry(*p).or_insert(0) += n;
        }
        for (a, n) in &o.app_slices {
            *self.app_slices.entry(*a).or_insert(0) += n;
        }
    }

    /// Application owning the most slices of this path (ties go to the
    /// lowest app id — deterministic regardless of map iteration order).
    pub fn dominant_app(&self) -> u16 {
        let mut best: Option<(u16, u64)> = None;
        for (a, n) in &self.app_slices {
            let better = match best {
                None => true,
                Some((ba, bn)) => *n > bn || (*n == bn && *a < ba),
            };
            if better {
                best = Some((*a, *n));
            }
        }
        best.map(|(a, _)| a).unwrap_or(0)
    }

    /// Index of the symbol source / display name to attribute this path
    /// to, clamped to the `napps` tables available. The single shared
    /// owner rule for report assembly *and* live window lines — the two
    /// must never disagree about who owns a path.
    pub fn owner_app(&self, multi_app: bool, napps: usize) -> usize {
        if !multi_app || napps == 0 {
            return 0;
        }
        (self.dominant_app() as usize).min(napps - 1)
    }
}

/// Incremental stack-id-keyed merge: feeds on slices (or window
/// snapshots) in arrival order and keeps one [`MergedPath`] per distinct
/// id, in first-seen order. Memory is O(distinct live stack ids), never
/// O(slices) — the invariant that lets the streaming analyzer run
/// unbounded. The grouping index is a dense id → slot vector (ids are
/// assigned densely by the kernel stack map).
#[derive(Default)]
pub struct PathAccumulator {
    /// stack_id → merged index + 1 (0 = unseen). Reset lazily by
    /// `take_paths`, so repeated window snapshots reuse the allocation.
    slot_for: Vec<u32>,
    paths: Vec<MergedPath>,
}

impl PathAccumulator {
    pub fn new() -> PathAccumulator {
        PathAccumulator::default()
    }

    /// Slot index for `stack_id`, creating the path on first sight.
    /// Slices whose stack was dropped at stack-map capacity carry
    /// [`crate::ebpf::STACK_ID_DROPPED`] and are excluded by callers —
    /// distinct overflowed paths must not be conflated.
    fn slot(&mut self, stack_id: u32) -> usize {
        let idx = stack_id as usize;
        if idx >= self.slot_for.len() {
            self.slot_for.resize(idx + 1, 0);
        }
        if self.slot_for[idx] == 0 {
            self.paths.push(MergedPath::new(stack_id));
            self.slot_for[idx] = self.paths.len() as u32;
            self.paths.len() - 1
        } else {
            (self.slot_for[idx] - 1) as usize
        }
    }

    /// Fold one critical slice, attributed to application `app`.
    pub fn add_slice(&mut self, s: &SliceEntry, app: u16) {
        if s.stack_id == crate::ebpf::STACK_ID_DROPPED {
            return;
        }
        let i = self.slot(s.stack_id);
        self.paths[i].absorb(s, app);
    }

    /// Fold one already-merged path (window-snapshot concatenation).
    pub fn merge_path(&mut self, p: &MergedPath) {
        if p.stack_id == crate::ebpf::STACK_ID_DROPPED {
            return;
        }
        let i = self.slot(p.stack_id);
        self.paths[i].merge_from(p);
    }

    /// Fold another accumulator's merged paths into this one —
    /// `merge(a, b)` at the accumulator level. Aggregates combine
    /// associatively/commutatively (every [`MergedPath`] field is a
    /// sum/min), but the *insertion order* after this call is
    /// self-then-other, not the canonical ascending-stamp order: take
    /// the snapshot and `sort_canonical` it (what
    /// `stream::window::merge_pair` does) wherever serial-equivalent
    /// ordering matters.
    pub fn merge_from(&mut self, o: &PathAccumulator) {
        for p in o.paths() {
            self.merge_path(p);
        }
    }

    /// Merged paths so far, in first-seen order.
    pub fn paths(&self) -> &[MergedPath] {
        &self.paths
    }

    pub fn len(&self) -> usize {
        self.paths.len()
    }

    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }

    /// Take the merged paths, resetting the accumulator for the next
    /// window while keeping the dense-index allocation.
    pub fn take_paths(&mut self) -> Vec<MergedPath> {
        for p in &self.paths {
            self.slot_for[p.stack_id as usize] = 0;
        }
        std::mem::take(&mut self.paths)
    }
}

/// Per-thread totals from the batched XLA analysis.
#[derive(Clone, Debug, Default)]
pub struct ThreadTotals {
    pub cm_ns: f64,
    pub wall_ns: f64,
}

/// The per-pid slice-pairing stage of the user probe (§4.4): buffers
/// sampled IPs per thread and pairs them with the `SliceEnd` /
/// `SliceDiscard` that closes the slice.
///
/// Split out of [`UserProbe`] because this stage is *shard-affine*: a
/// timeslice runs on one CPU, so its samples, its discard and its end
/// record all fire on that CPU and land in that CPU's ring shard, and
/// the pairing state empties at every slice boundary. One assembler per
/// shard therefore produces exactly the `SliceEntry`s one assembler
/// over the globally-ordered stream would — the invariant that lets the
/// merge tree fold slice records without any cross-shard timestamp
/// merge. (The activity-matrix records are *not* shard-affine — slots
/// are global — and stay on the globally-ordered path.)
#[derive(Default)]
pub struct SliceAssembler {
    // Pending per-pid sample buffers. Dense table; a slice end *moves*
    // the buffer into its SliceEntry, a discard clears it in place, so
    // the steady state re-uses allocations.
    pending_samples: PidMap<Vec<u64>>,
    /// Assembled slices, in this assembler's arrival order.
    pub slices: Vec<SliceEntry>,
}

impl SliceAssembler {
    pub fn new() -> SliceAssembler {
        SliceAssembler::default()
    }

    /// Consume `rec` if it belongs to the slice-pairing stage; returns
    /// false (untouched) for activity-matrix records.
    pub fn consume(&mut self, rec: &Record) -> bool {
        match *rec {
            Record::Sample { pid, ip } => {
                self.pending_samples.get_mut_or(pid, Vec::new).push(ip);
            }
            Record::SliceDiscard { pid } => {
                // Reject pending samples for this thread (§4.4).
                if let Some(v) = self.pending_samples.get_mut(pid) {
                    v.clear();
                }
            }
            Record::SliceEnd {
                ts_id,
                pid,
                cm_ns,
                threads_av,
                ip,
                stack_id,
                stack_top,
                wait,
                woken_by,
            } => {
                let mut addrs = self
                    .pending_samples
                    .get_mut(pid)
                    .map(std::mem::take)
                    .unwrap_or_default();
                // The IP at the switch itself is a valid sample.
                if ip != 0 {
                    addrs.push(ip);
                }
                // Fallback: no samples → attribute to the stack top
                // (return address of the caller), labelled as such.
                let from_stack_top = addrs.is_empty();
                if from_stack_top && stack_top != 0 {
                    addrs.push(stack_top);
                }
                self.slices.push(SliceEntry {
                    ts_id,
                    pid,
                    cm_ns,
                    threads_av,
                    stack_id,
                    addrs,
                    from_stack_top,
                    wait,
                    woken_by,
                });
            }
            _ => return false,
        }
        true
    }

    /// Approximate memory footprint (paper column M).
    pub fn memory_bytes(&self) -> u64 {
        let slices: u64 = self
            .slices
            .iter()
            .map(|s| 64 + 8 * s.addrs.len() as u64)
            .sum();
        let samples: u64 = self
            .pending_samples
            .iter()
            .map(|(_, v)| 8 * v.len() as u64)
            .sum();
        slices + samples
    }
}

/// User-space engine state.
pub struct UserProbe {
    engine: AnalysisEngine,
    // Batch under construction (reused across drains: zero-alloc path).
    a_flat: Vec<f32>,
    t_vec: Vec<f32>,
    rows: usize,
    // pid ↔ slot attribution over time (slots are recycled).
    slot_owner: Vec<Option<Pid>>,
    /// Accumulated per-pid totals (committed when slots are freed or at
    /// flush time). Dense pid table: iteration is pid-ordered.
    pub totals: PidMap<ThreadTotals>,
    /// The pid-paired slice stage (serial path; the merge tree runs one
    /// assembler per shard instead — see [`ShardLanes`]).
    asm: SliceAssembler,
    pub records_processed: u64,
    pub batch_flushes: u64,
}

impl UserProbe {
    pub fn new(engine: AnalysisEngine) -> UserProbe {
        let batch = engine.batch;
        let t_slots = engine.t_slots;
        UserProbe {
            engine,
            a_flat: vec![0.0; batch * t_slots],
            t_vec: vec![0.0; batch],
            rows: 0,
            slot_owner: vec![None; T_SLOTS],
            totals: PidMap::new(),
            asm: SliceAssembler::new(),
            records_processed: 0,
            batch_flushes: 0,
        }
    }

    /// Slices assembled so far (batch path; the streaming driver drains
    /// them per epoch via [`UserProbe::drain_slices_into`]).
    pub fn slices(&self) -> &[SliceEntry] {
        &self.asm.slices
    }

    pub fn backend_name(&self) -> &'static str {
        self.engine.backend_name()
    }

    /// Consume one record from the circular buffer.
    pub fn consume(&mut self, rec: Record) {
        self.records_processed += 1;
        // Slice-stage records (samples and slice boundaries) pair
        // per-pid state; everything else feeds the activity matrix.
        if self.asm.consume(&rec) {
            return;
        }
        match rec {
            Record::SlotAssign { pid, slot } => {
                // A reassignment invalidates per-slot accumulation —
                // flush the open batch first.
                if slot < self.slot_owner.len() {
                    if self.slot_owner[slot].is_some() {
                        self.flush_batch();
                    }
                    self.slot_owner[slot] = Some(pid);
                }
            }
            Record::SlotFree { pid, slot } => {
                // Commit what this slot accumulated so far.
                self.flush_batch();
                if slot < self.slot_owner.len() {
                    debug_assert_eq!(self.slot_owner[slot], Some(pid));
                    self.slot_owner[slot] = None;
                }
            }
            Record::Interval { dur, mask } => {
                let t_slots = self.engine.t_slots;
                let row = self.rows;
                let base = row * t_slots;
                for w in 0..2 {
                    let mut bits = mask[w];
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        let slot = w * 64 + b;
                        if slot < t_slots {
                            self.a_flat[base + slot] = 1.0;
                        }
                        bits &= bits - 1;
                    }
                }
                self.t_vec[row] = dur as f32;
                self.rows += 1;
                if self.rows == self.engine.batch {
                    self.flush_batch();
                }
            }
            // Injected filler traffic: consumes ring capacity and drain
            // bandwidth, contributes nothing to the analysis.
            Record::Noise => {}
            // Handled by the slice assembler above.
            Record::Sample { .. } | Record::SliceDiscard { .. } | Record::SliceEnd { .. } => {
                unreachable!("slice-stage records are consumed by the assembler")
            }
        }
    }

    /// Run the open batch through the analysis engine and fold the
    /// per-slot outputs into per-pid totals.
    pub fn flush_batch(&mut self) {
        if self.rows == 0 {
            return;
        }
        // Zero-padding the tail is exact (empty rows contribute nothing).
        let out = self
            .engine
            .analyze(&self.a_flat, &self.t_vec)
            .expect("analysis engine");
        for (slot, owner) in self.slot_owner.iter().enumerate() {
            if let Some(pid) = owner {
                if out.cm[slot] > 0.0 {
                    let t = self.totals.get_mut_or(*pid, ThreadTotals::default);
                    t.cm_ns += out.cm[slot] as f64;
                    t.wall_ns += out.wall[slot] as f64;
                }
            }
        }
        self.batch_flushes += 1;
        self.a_flat.fill(0.0);
        self.t_vec.fill(0.0);
        self.rows = 0;
    }

    /// Merge identical call paths (paper §4.4 post-processing) and rank
    /// by total CMetric via the compiled top-K artifact. Grouping is by
    /// interned stack id — one integer compare per slice — in
    /// first-seen order (deterministic: ids are assigned in capture
    /// order by the kernel). This is the one-window special case of the
    /// incremental merge: all buffered slices fold into a single
    /// [`PathAccumulator`]. Slices whose stack was dropped at stack-map
    /// capacity are excluded (the kernel's `stack_drops` counter reports
    /// the loss).
    pub fn merge_and_rank(&mut self, top_n: usize) -> Vec<MergedPath> {
        self.flush_batch();
        let mut acc = PathAccumulator::new();
        for s in &self.asm.slices {
            acc.add_slice(s, 0);
        }
        let paths = acc.take_paths();
        self.rank_merged(&paths, top_n)
    }

    /// Rank already-merged paths by total CMetric through the analysis
    /// engine's top-K artifact, preserving first-seen order on ties.
    pub fn rank_merged(&mut self, paths: &[MergedPath], top_n: usize) -> Vec<MergedPath> {
        let scores: Vec<f32> = paths.iter().map(|p| p.total_cm_ns as f32).collect();
        let ranked = self.engine.rank(&scores, top_n).expect("rank engine");
        ranked
            .into_iter()
            .map(|(i, _)| paths[i].clone())
            .collect()
    }

    /// Move buffered slice entries into `out` (arrival order preserved).
    /// The streaming analyzer drains per epoch so resident slice memory
    /// stays bounded by one window; the batch path never calls this and
    /// keeps slices in place for `merge_and_rank`.
    pub fn drain_slices_into(&mut self, out: &mut Vec<SliceEntry>) {
        out.append(&mut self.asm.slices);
    }

    /// Approximate user-space memory footprint (paper column M).
    pub fn memory_bytes(&self) -> u64 {
        let batch = (self.a_flat.len() * 4 + self.t_vec.len() * 4) as u64;
        self.asm.memory_bytes() + batch
    }
}

/// One ring shard's consumer-side state under the merge tree: a
/// shard-local [`SliceAssembler`] plus a FIFO of the order-sensitive
/// activity-matrix records awaiting the global re-merge.
#[derive(Default)]
pub struct ShardLane {
    /// Shard-local slice pairing (provably equivalent to pairing on the
    /// globally-ordered stream — see [`SliceAssembler`]).
    pub asm: SliceAssembler,
    /// Buffered `Interval`/`SlotAssign`/`SlotFree` records in shard
    /// FIFO (= ascending `(t, seq)`) order. Slot numbers are a *global*
    /// resource recycled across CPUs, and the analysis batches f32 rows
    /// whose grouping follows the record sequence, so this substream
    /// must reach the [`UserProbe`] in global capture order — it is the
    /// one part of the stream the tree still re-serializes (at window
    /// close, off the hot path).
    matrix: Vec<Stamped<Record>>,
    /// Records this lane consumed (slice + matrix).
    pub records_routed: u64,
}

/// The shard-local half of the merge-tree consumer: one [`ShardLane`]
/// per ring shard. Probes' watermark drains and the epoch drain both
/// route records here in shard order; at window close the buffered
/// matrix substream is k-way-merged (by capture stamp) into the
/// [`UserProbe`] and each lane's assembled slices fold into that
/// shard's partial accumulator.
///
/// This is the *inline* (driver-thread) topology. With
/// `--lane-threads N > 1` the same per-lane state lives inside scoped
/// worker threads instead — see [`super::stream::lanes`], which
/// compile-asserts the lane state is `Send` and reproduces this type's
/// routing and window-close behaviour byte for byte.
#[derive(Default)]
pub struct ShardLanes {
    lanes: Vec<ShardLane>,
}

impl ShardLanes {
    pub fn new(nshards: usize) -> ShardLanes {
        ShardLanes {
            lanes: (0..nshards).map(|_| ShardLane::default()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut ShardLane> {
        self.lanes.iter_mut()
    }

    /// Route one stamped record drained from shard `i`: slice-stage
    /// records fold into the lane's assembler immediately (shard order
    /// suffices — shard affinity); matrix records queue for the global
    /// re-merge at window close.
    #[inline]
    pub fn route(&mut self, i: usize, rec: Stamped<Record>) {
        let lane = &mut self.lanes[i];
        lane.records_routed += 1;
        if !lane.asm.consume(&rec.rec) {
            lane.matrix.push(rec);
        }
    }

    /// Feed every buffered activity-matrix record into `user` in global
    /// `(t, seq)` order — a k-way merge over the lane FIFOs (each lane
    /// buffers in ascending stamp order already). Runs at window close,
    /// not on the hot path; the heap holds at most one head per lane.
    pub fn feed_matrix_into(&mut self, user: &mut UserProbe) {
        use std::cmp::Reverse;
        if self.lanes.len() == 1 {
            for r in self.lanes[0].matrix.drain(..) {
                user.consume(r.rec);
            }
            return;
        }
        let mut next = vec![0usize; self.lanes.len()];
        let mut heads: std::collections::BinaryHeap<Reverse<(u64, u64, usize)>> =
            std::collections::BinaryHeap::with_capacity(self.lanes.len());
        for (i, l) in self.lanes.iter().enumerate() {
            if let Some(r) = l.matrix.first() {
                heads.push(Reverse((r.t, r.seq, i)));
            }
        }
        while let Some(Reverse((_, _, i))) = heads.pop() {
            let rec = self.lanes[i].matrix[next[i]];
            next[i] += 1;
            user.consume(rec.rec);
            if let Some(r) = self.lanes[i].matrix.get(next[i]) {
                heads.push(Reverse((r.t, r.seq, i)));
            }
        }
        for l in &mut self.lanes {
            l.matrix.clear(); // keep the allocations for the next window
        }
    }

    /// Approximate consumer-side memory footprint across lanes.
    pub fn memory_bytes(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| {
                l.asm.memory_bytes()
                    + (l.matrix.len() * std::mem::size_of::<Stamped<Record>>()) as u64
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::records::{mask_set, SlotMask};

    fn probe() -> UserProbe {
        UserProbe::new(AnalysisEngine::native())
    }

    fn interval(slots: &[usize], dur: u64) -> Record {
        let mut mask: SlotMask = [0; 2];
        for s in slots {
            mask_set(&mut mask, *s);
        }
        Record::Interval { dur, mask }
    }

    fn slice_end(ts_id: u64, pid: Pid, cm_ns: f64, stack_id: u32) -> Record {
        Record::SliceEnd {
            ts_id,
            pid,
            cm_ns,
            threads_av: 1.0,
            ip: 0,
            stack_id,
            stack_top: 0,
            wait: WaitKind::Futex,
            woken_by: 0,
        }
    }

    #[test]
    fn totals_accumulate_per_pid() {
        let mut u = probe();
        u.consume(Record::SlotAssign { pid: 10, slot: 0 });
        u.consume(Record::SlotAssign { pid: 11, slot: 1 });
        u.consume(interval(&[0, 1], 100));
        u.consume(interval(&[0], 50));
        u.flush_batch();
        let t10 = u.totals.get(10).unwrap();
        let t11 = u.totals.get(11).unwrap();
        assert!((t10.cm_ns - 100.0).abs() < 1e-3); // 50 + 50
        assert!((t11.cm_ns - 50.0).abs() < 1e-3);
        assert!((t10.wall_ns - 150.0).abs() < 1e-3);
    }

    #[test]
    fn slot_recycling_flushes_first() {
        let mut u = probe();
        u.consume(Record::SlotAssign { pid: 1, slot: 0 });
        u.consume(interval(&[0], 100));
        u.consume(Record::SlotFree { pid: 1, slot: 0 });
        u.consume(Record::SlotAssign { pid: 2, slot: 0 });
        u.consume(interval(&[0], 70));
        u.flush_batch();
        assert!((u.totals.get(1).unwrap().cm_ns - 100.0).abs() < 1e-3);
        assert!((u.totals.get(2).unwrap().cm_ns - 70.0).abs() < 1e-3);
    }

    #[test]
    fn discard_rejects_pending_samples() {
        let mut u = probe();
        u.consume(Record::Sample { pid: 5, ip: 0xA });
        u.consume(Record::SliceDiscard { pid: 5 });
        u.consume(Record::Sample { pid: 5, ip: 0xB });
        u.consume(Record::SliceEnd {
            ts_id: 1,
            pid: 5,
            cm_ns: 10.0,
            threads_av: 1.0,
            ip: 0,
            stack_id: 7,
            stack_top: 0x100,
            wait: WaitKind::Futex,
            woken_by: 0,
        });
        assert_eq!(u.slices().len(), 1);
        assert_eq!(u.slices()[0].addrs, vec![0xB]); // 0xA was rejected
        assert!(!u.slices()[0].from_stack_top);
    }

    #[test]
    fn stack_top_fallback_when_no_samples() {
        let mut u = probe();
        u.consume(Record::SliceEnd {
            ts_id: 1,
            pid: 5,
            cm_ns: 10.0,
            threads_av: 1.0,
            ip: 0,
            stack_id: 3,
            stack_top: 0x200,
            wait: WaitKind::Io,
            woken_by: 0,
        });
        assert!(u.slices()[0].from_stack_top);
        assert_eq!(u.slices()[0].addrs, vec![0x200]);
    }

    #[test]
    fn merge_sums_identical_call_paths() {
        let mut u = probe();
        for i in 0..3 {
            u.consume(Record::SliceEnd {
                ts_id: i,
                pid: 1,
                cm_ns: 100.0,
                threads_av: 1.0,
                ip: 0xAA,
                stack_id: 1,
                stack_top: 0x200,
                wait: WaitKind::Futex,
                woken_by: 9,
            });
        }
        u.consume(Record::SliceEnd {
            ts_id: 9,
            pid: 2,
            cm_ns: 50.0,
            threads_av: 1.0,
            ip: 0xBB,
            stack_id: 2,
            stack_top: 0x300,
            wait: WaitKind::Queue,
            woken_by: 0,
        });
        let top = u.merge_and_rank(5);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].stack_id, 1);
        assert!((top[0].total_cm_ns - 300.0).abs() < 1e-6);
        assert_eq!(top[0].slices, 3);
        assert_eq!(top[0].addr_freq[&0xAA], 3);
        assert_eq!(top[0].wait_hist[&WaitKind::Futex], 3);
        assert_eq!(top[0].wakers[&9], 3);
        assert_eq!(top[1].stack_id, 2);
        assert_eq!(top[1].wait_hist[&WaitKind::Queue], 1);
    }

    #[test]
    fn rank_respects_top_n() {
        let mut u = probe();
        for p in 0..10u64 {
            u.consume(slice_end(p, 1, (p + 1) as f64, p as u32));
        }
        let top = u.merge_and_rank(3);
        assert_eq!(top.len(), 3);
        assert!(top[0].total_cm_ns >= top[1].total_cm_ns);
        assert!(top[1].total_cm_ns >= top[2].total_cm_ns);
        assert!((top[0].total_cm_ns - 10.0).abs() < 1e-6);
    }

    #[test]
    fn dropped_stack_ids_are_excluded_from_merge() {
        let mut u = probe();
        u.consume(slice_end(1, 1, 100.0, 0));
        // Two slices whose stacks overflowed the kernel stack map: they
        // may be *different* call paths, so they must not merge.
        u.consume(slice_end(2, 1, 500.0, crate::ebpf::STACK_ID_DROPPED));
        u.consume(slice_end(3, 2, 600.0, crate::ebpf::STACK_ID_DROPPED));
        let top = u.merge_and_rank(5);
        assert_eq!(top.len(), 1);
        assert_eq!(top[0].stack_id, 0);
        assert!((top[0].total_cm_ns - 100.0).abs() < 1e-9);
    }

    #[test]
    fn window_snapshots_merge_to_the_batch_merge() {
        // Split one slice stream at arbitrary window boundaries: the
        // merged snapshots must equal the one-window (batch) merge
        // bit-for-bit, including the integer CMetric accumulator.
        let mk = |i: u64| SliceEntry {
            ts_id: i,
            pid: (1 + i % 3) as Pid,
            cm_ns: 10.0 + (i as f64) * 0.737,
            threads_av: 1.0,
            stack_id: (i % 5) as u32,
            addrs: vec![0x400 + i % 7],
            from_stack_top: i % 4 == 0,
            wait: if i % 2 == 0 { WaitKind::Futex } else { WaitKind::Queue },
            woken_by: (i % 2) as Pid,
        };
        let slices: Vec<SliceEntry> = (0..100).map(mk).collect();
        let mut batch = PathAccumulator::new();
        for s in &slices {
            batch.add_slice(s, (s.pid % 2) as u16);
        }
        let batch_paths = batch.take_paths();

        let mut windows: Vec<Vec<MergedPath>> = Vec::new();
        let mut w = PathAccumulator::new();
        for (i, s) in slices.iter().enumerate() {
            w.add_slice(s, (s.pid % 2) as u16);
            // Ragged boundaries: 13, 13+29, … (same accumulator reused).
            if i % 29 == 12 {
                windows.push(w.take_paths());
            }
        }
        windows.push(w.take_paths());
        assert!(windows.len() > 2);

        let mut merged = PathAccumulator::new();
        for win in &windows {
            for p in win {
                merged.merge_path(p);
            }
        }
        let merged_paths = merged.take_paths();
        assert_eq!(merged_paths.len(), batch_paths.len());
        for (a, b) in batch_paths.iter().zip(&merged_paths) {
            assert_eq!(a.stack_id, b.stack_id, "first-seen order must match");
            assert_eq!(a.cm_fs, b.cm_fs);
            assert_eq!(a.slices, b.slices);
            assert_eq!(a.addr_freq, b.addr_freq);
            assert_eq!(a.stack_top_samples, b.stack_top_samples);
            assert_eq!(a.wait_hist, b.wait_hist);
            assert_eq!(a.wakers, b.wakers);
            assert_eq!(a.app_slices, b.app_slices);
        }
    }

    #[test]
    fn dominant_app_breaks_ties_deterministically() {
        let mut p = MergedPath::new(0);
        *p.app_slices.entry(3).or_insert(0) += 2;
        *p.app_slices.entry(1).or_insert(0) += 2;
        *p.app_slices.entry(2).or_insert(0) += 1;
        assert_eq!(p.dominant_app(), 1); // tie on 2 slices → lowest id
        *p.app_slices.entry(3).or_insert(0) += 1;
        assert_eq!(p.dominant_app(), 3);
        assert_eq!(MergedPath::new(9).dominant_app(), 0);
    }

    #[test]
    fn near_max_cm_weights_never_wrap_the_accumulator() {
        // Regression for the unchecked `cm_fs +=`: two window snapshots
        // of the same path whose integer-femtosecond totals sit near
        // u64::MAX. Exact up to the boundary; past it, release builds
        // saturate (path stays ranked on top) and debug builds assert.
        let near = |cm_fs: u64| MergedPath {
            stack_id: 1,
            cm_fs,
            total_cm_ns: cm_fs as f64 / 1e6,
            first_seen: u64::MAX,
            slices: 1,
            addr_freq: FxHashMap::default(),
            stack_top_samples: 0,
            wait_hist: FxHashMap::default(),
            wakers: FxHashMap::default(),
            app_slices: FxHashMap::default(),
        };
        let mut acc = PathAccumulator::new();
        acc.merge_path(&near(u64::MAX - 100));
        acc.merge_path(&near(100)); // lands exactly on u64::MAX
        assert_eq!(acc.paths()[0].cm_fs, u64::MAX);

        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut acc = PathAccumulator::new();
            acc.merge_path(&near(u64::MAX - 100));
            acc.merge_path(&near(200)); // overflows
            acc.take_paths()[0].cm_fs
        }));
        if cfg!(debug_assertions) {
            assert!(r.is_err(), "debug builds must flag CMetric saturation");
        } else {
            assert_eq!(r.unwrap(), u64::MAX, "release builds must saturate");
        }
    }

    #[test]
    fn sample_buffers_are_reused_across_slices() {
        let mut u = probe();
        u.consume(Record::Sample { pid: 3, ip: 0x1 });
        u.consume(slice_end(1, 3, 5.0, 0));
        // Buffer moved into the slice; a fresh sample starts a new one.
        u.consume(Record::Sample { pid: 3, ip: 0x2 });
        u.consume(slice_end(2, 3, 5.0, 0));
        assert_eq!(u.slices()[0].addrs, vec![0x1]);
        assert_eq!(u.slices()[1].addrs, vec![0x2]);
    }
}
