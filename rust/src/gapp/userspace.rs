//! The user-space probe (paper §4.4): drains the circular buffer,
//! assembles sampled instruction pointers per timeslice, merges
//! identical call paths, and ranks the merged entries by total CMetric.
//!
//! This is where the three-layer architecture bites: the per-thread
//! CMetric accumulation (the paper's kernel-side `cm_hash`) is computed
//! here by streaming interval rows through the AOT-compiled XLA analysis
//! program in fixed-size batches. The in-kernel scalar path is retained
//! as a cross-check (`KernelProbes::cm_hash`), and an integration
//! test asserts the two agree.
//!
//! Call paths arrive as interned `u32` stack ids (see
//! [`crate::ebpf::StackMap`]), so the merge groups by id — an integer
//! key — instead of hashing full frame vectors; ids are resolved back
//! to frames only when a path reaches the final report.

use crate::runtime::{AnalysisEngine, T_SLOTS};
use crate::simkernel::{Pid, WaitKind};
use crate::util::{FxHashMap, PidMap};

use super::records::Record;

/// A critical timeslice awaiting the merge phase.
#[derive(Clone, Debug)]
pub struct SliceEntry {
    pub ts_id: u64,
    pub pid: Pid,
    pub cm_ns: f64,
    pub threads_av: f64,
    /// Interned id of the call path captured at the switch.
    pub stack_id: u32,
    /// Sampled IPs attributed to this slice (plus the switch IP).
    pub addrs: Vec<u64>,
    /// True when no samples landed and the stack top was substituted
    /// (the paper's "from stack top" label).
    pub from_stack_top: bool,
    /// What the slice ended waiting on (§7 classification).
    pub wait: WaitKind,
    /// Thread whose wakeup started this slice (0 = none/timer).
    pub woken_by: Pid,
}

/// A merged call path: summed CMetric + address frequency table.
#[derive(Clone, Debug)]
pub struct MergedPath {
    /// Interned call-path id (resolve via the kernel stack map).
    pub stack_id: u32,
    pub total_cm_ns: f64,
    pub slices: u64,
    pub addr_freq: FxHashMap<u64, u64>,
    pub stack_top_samples: u64,
    /// Wait-kind histogram over the merged slices (§7 classification).
    pub wait_hist: FxHashMap<WaitKind, u64>,
    /// Waker histogram: who ended the waits that started these slices.
    pub wakers: FxHashMap<Pid, u64>,
}

impl MergedPath {
    fn new(stack_id: u32) -> MergedPath {
        MergedPath {
            stack_id,
            total_cm_ns: 0.0,
            slices: 0,
            addr_freq: FxHashMap::default(),
            stack_top_samples: 0,
            wait_hist: FxHashMap::default(),
            wakers: FxHashMap::default(),
        }
    }
}

/// Per-thread totals from the batched XLA analysis.
#[derive(Clone, Debug, Default)]
pub struct ThreadTotals {
    pub cm_ns: f64,
    pub wall_ns: f64,
}

/// User-space engine state.
pub struct UserProbe {
    engine: AnalysisEngine,
    // Batch under construction (reused across drains: zero-alloc path).
    a_flat: Vec<f32>,
    t_vec: Vec<f32>,
    rows: usize,
    // pid ↔ slot attribution over time (slots are recycled).
    slot_owner: Vec<Option<Pid>>,
    /// Accumulated per-pid totals (committed when slots are freed or at
    /// flush time). Dense pid table: iteration is pid-ordered.
    pub totals: PidMap<ThreadTotals>,
    // Pending per-pid sample buffers. Dense table; a slice end *moves*
    // the buffer into its SliceEntry, a discard clears it in place, so
    // the steady state re-uses allocations.
    pending_samples: PidMap<Vec<u64>>,
    pub slices: Vec<SliceEntry>,
    pub records_processed: u64,
    pub batch_flushes: u64,
}

impl UserProbe {
    pub fn new(engine: AnalysisEngine) -> UserProbe {
        let batch = engine.batch;
        let t_slots = engine.t_slots;
        UserProbe {
            engine,
            a_flat: vec![0.0; batch * t_slots],
            t_vec: vec![0.0; batch],
            rows: 0,
            slot_owner: vec![None; T_SLOTS],
            totals: PidMap::new(),
            pending_samples: PidMap::new(),
            slices: Vec::new(),
            records_processed: 0,
            batch_flushes: 0,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.engine.backend_name()
    }

    /// Consume one record from the circular buffer.
    pub fn consume(&mut self, rec: Record) {
        self.records_processed += 1;
        match rec {
            Record::SlotAssign { pid, slot } => {
                // A reassignment invalidates per-slot accumulation —
                // flush the open batch first.
                if slot < self.slot_owner.len() {
                    if self.slot_owner[slot].is_some() {
                        self.flush_batch();
                    }
                    self.slot_owner[slot] = Some(pid);
                }
            }
            Record::SlotFree { pid, slot } => {
                // Commit what this slot accumulated so far.
                self.flush_batch();
                if slot < self.slot_owner.len() {
                    debug_assert_eq!(self.slot_owner[slot], Some(pid));
                    self.slot_owner[slot] = None;
                }
            }
            Record::Interval { dur, mask } => {
                let t_slots = self.engine.t_slots;
                let row = self.rows;
                let base = row * t_slots;
                for w in 0..2 {
                    let mut bits = mask[w];
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        let slot = w * 64 + b;
                        if slot < t_slots {
                            self.a_flat[base + slot] = 1.0;
                        }
                        bits &= bits - 1;
                    }
                }
                self.t_vec[row] = dur as f32;
                self.rows += 1;
                if self.rows == self.engine.batch {
                    self.flush_batch();
                }
            }
            Record::Sample { pid, ip } => {
                self.pending_samples.get_mut_or(pid, Vec::new).push(ip);
            }
            Record::SliceDiscard { pid } => {
                // Reject pending samples for this thread (§4.4).
                if let Some(v) = self.pending_samples.get_mut(pid) {
                    v.clear();
                }
            }
            Record::SliceEnd {
                ts_id,
                pid,
                cm_ns,
                threads_av,
                ip,
                stack_id,
                stack_top,
                wait,
                woken_by,
            } => {
                let mut addrs = self
                    .pending_samples
                    .get_mut(pid)
                    .map(std::mem::take)
                    .unwrap_or_default();
                // The IP at the switch itself is a valid sample.
                if ip != 0 {
                    addrs.push(ip);
                }
                // Fallback: no samples → attribute to the stack top
                // (return address of the caller), labelled as such.
                let from_stack_top = addrs.is_empty();
                if from_stack_top && stack_top != 0 {
                    addrs.push(stack_top);
                }
                self.slices.push(SliceEntry {
                    ts_id,
                    pid,
                    cm_ns,
                    threads_av,
                    stack_id,
                    addrs,
                    from_stack_top,
                    wait,
                    woken_by,
                });
            }
        }
    }

    /// Run the open batch through the analysis engine and fold the
    /// per-slot outputs into per-pid totals.
    pub fn flush_batch(&mut self) {
        if self.rows == 0 {
            return;
        }
        // Zero-padding the tail is exact (empty rows contribute nothing).
        let out = self
            .engine
            .analyze(&self.a_flat, &self.t_vec)
            .expect("analysis engine");
        for (slot, owner) in self.slot_owner.iter().enumerate() {
            if let Some(pid) = owner {
                if out.cm[slot] > 0.0 {
                    let t = self.totals.get_mut_or(*pid, ThreadTotals::default);
                    t.cm_ns += out.cm[slot] as f64;
                    t.wall_ns += out.wall[slot] as f64;
                }
            }
        }
        self.batch_flushes += 1;
        self.a_flat.fill(0.0);
        self.t_vec.fill(0.0);
        self.rows = 0;
    }

    /// Merge identical call paths (paper §4.4 post-processing) and rank
    /// by total CMetric via the compiled top-K artifact. Grouping is by
    /// interned stack id — one integer compare per slice — in
    /// first-seen order (deterministic: ids are assigned in capture
    /// order by the kernel).
    pub fn merge_and_rank(&mut self, top_n: usize) -> Vec<MergedPath> {
        self.flush_batch();
        // Stack ids are dense (0, 1, 2, … in capture order), so the
        // grouping index is a plain vector: slot_for[id] = merged index
        // + 1 (0 = unseen). Slices whose stack was dropped at stack-map
        // capacity carry STACK_ID_DROPPED and are *excluded* — distinct
        // overflowed paths must not be conflated into one bogus entry
        // (the kernel's `stack_drops` counter reports the loss).
        let mut slot_for: Vec<u32> = Vec::new();
        let mut paths: Vec<MergedPath> = Vec::new();
        for s in &self.slices {
            if s.stack_id == crate::ebpf::STACK_ID_DROPPED {
                continue;
            }
            let idx = s.stack_id as usize;
            if idx >= slot_for.len() {
                slot_for.resize(idx + 1, 0);
            }
            let i = if slot_for[idx] == 0 {
                paths.push(MergedPath::new(s.stack_id));
                slot_for[idx] = paths.len() as u32;
                paths.len() - 1
            } else {
                (slot_for[idx] - 1) as usize
            };
            let e = &mut paths[i];
            e.total_cm_ns += s.cm_ns;
            e.slices += 1;
            for a in &s.addrs {
                *e.addr_freq.entry(*a).or_insert(0) += 1;
            }
            if s.from_stack_top {
                e.stack_top_samples += 1;
            }
            *e.wait_hist.entry(s.wait).or_insert(0) += 1;
            if s.woken_by != 0 {
                *e.wakers.entry(s.woken_by).or_insert(0) += 1;
            }
        }
        let scores: Vec<f32> = paths.iter().map(|p| p.total_cm_ns as f32).collect();
        let ranked = self
            .engine
            .rank(&scores, top_n)
            .expect("rank engine");
        ranked
            .into_iter()
            .map(|(i, _)| paths[i].clone())
            .collect()
    }

    /// Approximate user-space memory footprint (paper column M).
    pub fn memory_bytes(&self) -> u64 {
        let slices: u64 = self
            .slices
            .iter()
            .map(|s| 64 + 8 * s.addrs.len() as u64)
            .sum();
        let batch = (self.a_flat.len() * 4 + self.t_vec.len() * 4) as u64;
        let samples: u64 = self
            .pending_samples
            .iter()
            .map(|(_, v)| 8 * v.len() as u64)
            .sum();
        slices + batch + samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gapp::records::{mask_set, SlotMask};

    fn probe() -> UserProbe {
        UserProbe::new(AnalysisEngine::native())
    }

    fn interval(slots: &[usize], dur: u64) -> Record {
        let mut mask: SlotMask = [0; 2];
        for s in slots {
            mask_set(&mut mask, *s);
        }
        Record::Interval { dur, mask }
    }

    fn slice_end(ts_id: u64, pid: Pid, cm_ns: f64, stack_id: u32) -> Record {
        Record::SliceEnd {
            ts_id,
            pid,
            cm_ns,
            threads_av: 1.0,
            ip: 0,
            stack_id,
            stack_top: 0,
            wait: WaitKind::Futex,
            woken_by: 0,
        }
    }

    #[test]
    fn totals_accumulate_per_pid() {
        let mut u = probe();
        u.consume(Record::SlotAssign { pid: 10, slot: 0 });
        u.consume(Record::SlotAssign { pid: 11, slot: 1 });
        u.consume(interval(&[0, 1], 100));
        u.consume(interval(&[0], 50));
        u.flush_batch();
        let t10 = u.totals.get(10).unwrap();
        let t11 = u.totals.get(11).unwrap();
        assert!((t10.cm_ns - 100.0).abs() < 1e-3); // 50 + 50
        assert!((t11.cm_ns - 50.0).abs() < 1e-3);
        assert!((t10.wall_ns - 150.0).abs() < 1e-3);
    }

    #[test]
    fn slot_recycling_flushes_first() {
        let mut u = probe();
        u.consume(Record::SlotAssign { pid: 1, slot: 0 });
        u.consume(interval(&[0], 100));
        u.consume(Record::SlotFree { pid: 1, slot: 0 });
        u.consume(Record::SlotAssign { pid: 2, slot: 0 });
        u.consume(interval(&[0], 70));
        u.flush_batch();
        assert!((u.totals.get(1).unwrap().cm_ns - 100.0).abs() < 1e-3);
        assert!((u.totals.get(2).unwrap().cm_ns - 70.0).abs() < 1e-3);
    }

    #[test]
    fn discard_rejects_pending_samples() {
        let mut u = probe();
        u.consume(Record::Sample { pid: 5, ip: 0xA });
        u.consume(Record::SliceDiscard { pid: 5 });
        u.consume(Record::Sample { pid: 5, ip: 0xB });
        u.consume(Record::SliceEnd {
            ts_id: 1,
            pid: 5,
            cm_ns: 10.0,
            threads_av: 1.0,
            ip: 0,
            stack_id: 7,
            stack_top: 0x100,
            wait: WaitKind::Futex,
            woken_by: 0,
        });
        assert_eq!(u.slices.len(), 1);
        assert_eq!(u.slices[0].addrs, vec![0xB]); // 0xA was rejected
        assert!(!u.slices[0].from_stack_top);
    }

    #[test]
    fn stack_top_fallback_when_no_samples() {
        let mut u = probe();
        u.consume(Record::SliceEnd {
            ts_id: 1,
            pid: 5,
            cm_ns: 10.0,
            threads_av: 1.0,
            ip: 0,
            stack_id: 3,
            stack_top: 0x200,
            wait: WaitKind::Io,
            woken_by: 0,
        });
        assert!(u.slices[0].from_stack_top);
        assert_eq!(u.slices[0].addrs, vec![0x200]);
    }

    #[test]
    fn merge_sums_identical_call_paths() {
        let mut u = probe();
        for i in 0..3 {
            u.consume(Record::SliceEnd {
                ts_id: i,
                pid: 1,
                cm_ns: 100.0,
                threads_av: 1.0,
                ip: 0xAA,
                stack_id: 1,
                stack_top: 0x200,
                wait: WaitKind::Futex,
                woken_by: 9,
            });
        }
        u.consume(Record::SliceEnd {
            ts_id: 9,
            pid: 2,
            cm_ns: 50.0,
            threads_av: 1.0,
            ip: 0xBB,
            stack_id: 2,
            stack_top: 0x300,
            wait: WaitKind::Queue,
            woken_by: 0,
        });
        let top = u.merge_and_rank(5);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].stack_id, 1);
        assert!((top[0].total_cm_ns - 300.0).abs() < 1e-6);
        assert_eq!(top[0].slices, 3);
        assert_eq!(top[0].addr_freq[&0xAA], 3);
        assert_eq!(top[0].wait_hist[&WaitKind::Futex], 3);
        assert_eq!(top[0].wakers[&9], 3);
        assert_eq!(top[1].stack_id, 2);
        assert_eq!(top[1].wait_hist[&WaitKind::Queue], 1);
    }

    #[test]
    fn rank_respects_top_n() {
        let mut u = probe();
        for p in 0..10u64 {
            u.consume(slice_end(p, 1, (p + 1) as f64, p as u32));
        }
        let top = u.merge_and_rank(3);
        assert_eq!(top.len(), 3);
        assert!(top[0].total_cm_ns >= top[1].total_cm_ns);
        assert!(top[1].total_cm_ns >= top[2].total_cm_ns);
        assert!((top[0].total_cm_ns - 10.0).abs() < 1e-6);
    }

    #[test]
    fn dropped_stack_ids_are_excluded_from_merge() {
        let mut u = probe();
        u.consume(slice_end(1, 1, 100.0, 0));
        // Two slices whose stacks overflowed the kernel stack map: they
        // may be *different* call paths, so they must not merge.
        u.consume(slice_end(2, 1, 500.0, crate::ebpf::STACK_ID_DROPPED));
        u.consume(slice_end(3, 2, 600.0, crate::ebpf::STACK_ID_DROPPED));
        let top = u.merge_and_rank(5);
        assert_eq!(top.len(), 1);
        assert_eq!(top[0].stack_id, 0);
        assert!((top[0].total_cm_ns - 100.0).abs() < 1e-9);
    }

    #[test]
    fn sample_buffers_are_reused_across_slices() {
        let mut u = probe();
        u.consume(Record::Sample { pid: 3, ip: 0x1 });
        u.consume(slice_end(1, 3, 5.0, 0));
        // Buffer moved into the slice; a fresh sample starts a new one.
        u.consume(Record::Sample { pid: 3, ip: 0x2 });
        u.consume(slice_end(2, 3, 5.0, 0));
        assert_eq!(u.slices[0].addrs, vec![0x1]);
        assert_eq!(u.slices[1].addrs, vec![0x2]);
    }
}
