//! Crash-safe session snapshots: a versioned `checkpoint: 1` JSON
//! document holding everything the streaming analyzer accumulates
//! *across* windows, written atomically at every window close.
//!
//! # What is (and is not) in a checkpoint
//!
//! The simulated kernel is deterministic and the analysis never feeds
//! back into it, so kernel/transport state needs no serialization: a
//! restore rebuilds the session from the same configuration and
//! *replays* the completed epochs (draining rings normally, skipping
//! the analysis-side folds the checkpoint already covers), which
//! reproduces the exact pre-crash kernel, lane and drop state. The
//! checkpoint therefore carries only the analysis accumulators that
//! replay skips:
//!
//! * the cumulative merged call paths (in cumulative insertion order),
//! * the space-saving sketch counters,
//! * the stable re-interned userspace stack map (LRU mode),
//! * per-window summaries, drop attribution and degrade counters.
//!
//! Replay doubles as an integrity check: the replayed per-window
//! summaries must match the checkpointed ones exactly, otherwise the
//! checkpoint belongs to a different run and the restore fails loudly.
//!
//! # Atomic-write contract
//!
//! [`Checkpoint::write_atomic`] writes `<path>.tmp` and renames it over
//! `<path>` — a crash mid-write leaves either the previous complete
//! checkpoint or a stray `.tmp`, never a torn document. The schema
//! follows the sink policy: `checkpoint` is bumped only on breaking
//! changes; unknown keys are ignored on load.

use crate::ebpf::{StackMap, StackMapStats};
use crate::gapp::stream::{DecayedSpaceSaving, TierEntry, TierPyramid, WindowSummary};
use crate::gapp::userspace::MergedPath;
use crate::simkernel::WaitKind;
use crate::util::json::Json;
use crate::util::FxHashMap;

/// Version stamp of the checkpoint document.
pub const CHECKPOINT_VERSION: u64 = 1;

/// The configuration surface a checkpoint is only valid against. A
/// resume with any mismatching knob would replay a *different* run and
/// silently corrupt the analysis, so every field is checked on restore
/// with an error naming the knob. The one exception is
/// [`Fingerprint::lane_threads`]: thread count changes scheduling but
/// never the output bytes, so a mismatch there is a named note, not an
/// error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fingerprint {
    /// "live" or "batch".
    pub mode: String,
    pub merge: String,
    /// Resolved ring-shard count.
    pub shards: usize,
    /// Epoch window length (0 for batch).
    pub window_ns: u64,
    /// Profiled application names, in spawn order.
    pub apps: Vec<String>,
    pub stack_lru: bool,
    pub on_overflow: String,
    pub ring_capacity: usize,
    pub drain_threshold: u64,
    /// Sampling period Δt (ns).
    pub dt: u64,
    /// `--lane-threads` the writing session ran with. Recorded for
    /// provenance; checked softly (see the struct docs).
    pub lane_threads: u64,
    /// `--compact-base` (0 = compaction off). Hard-checked: a resume
    /// that flips compaction would find the wrong history shape
    /// (tiers vs flat arrays) in the checkpoint.
    pub compact_base: u64,
    /// `--decay-half-life-us` (0 = no decayed sketch). Hard-checked: a
    /// different half-life continues the recent sketch differently.
    pub decay_half_life_us: u64,
}

impl Fingerprint {
    /// Compare against the fingerprint of the resuming session; the
    /// first mismatch is reported by knob name, stored vs current.
    /// `Ok` carries the benign notes (knobs that differ but cannot
    /// change the output — today only `lane_threads`).
    pub fn check(&self, current: &Fingerprint) -> Result<Vec<String>, String> {
        let mismatch = |knob: &str, stored: String, now: String| {
            Err(format!(
                "checkpoint was written by a different configuration: \
                 {knob} is {stored} in the checkpoint but {now} in this session"
            ))
        };
        if self.mode != current.mode {
            return mismatch("mode", self.mode.clone(), current.mode.clone());
        }
        if self.merge != current.merge {
            return mismatch("merge", self.merge.clone(), current.merge.clone());
        }
        if self.shards != current.shards {
            return mismatch("shards", self.shards.to_string(), current.shards.to_string());
        }
        if self.window_ns != current.window_ns {
            return mismatch(
                "window_ns",
                self.window_ns.to_string(),
                current.window_ns.to_string(),
            );
        }
        if self.apps != current.apps {
            return mismatch(
                "apps",
                format!("{:?}", self.apps),
                format!("{:?}", current.apps),
            );
        }
        if self.stack_lru != current.stack_lru {
            return mismatch(
                "stack_lru",
                self.stack_lru.to_string(),
                current.stack_lru.to_string(),
            );
        }
        if self.on_overflow != current.on_overflow {
            return mismatch(
                "on_overflow",
                self.on_overflow.clone(),
                current.on_overflow.clone(),
            );
        }
        if self.ring_capacity != current.ring_capacity {
            return mismatch(
                "ring_capacity",
                self.ring_capacity.to_string(),
                current.ring_capacity.to_string(),
            );
        }
        if self.drain_threshold != current.drain_threshold {
            return mismatch(
                "drain_threshold",
                self.drain_threshold.to_string(),
                current.drain_threshold.to_string(),
            );
        }
        if self.dt != current.dt {
            return mismatch("dt", self.dt.to_string(), current.dt.to_string());
        }
        let onoff = |v: u64| if v == 0 { "off".to_string() } else { v.to_string() };
        if self.compact_base != current.compact_base {
            return mismatch(
                "compact_base",
                onoff(self.compact_base),
                onoff(current.compact_base),
            );
        }
        if self.decay_half_life_us != current.decay_half_life_us {
            return mismatch(
                "decay_half_life_us",
                onoff(self.decay_half_life_us),
                onoff(current.decay_half_life_us),
            );
        }
        let mut notes = Vec::new();
        if self.lane_threads != current.lane_threads {
            // Lane workers change who folds a shard, never what the
            // fold produces (byte-identity is golden-tested at every
            // thread count), so a resume may legally change it.
            notes.push(format!(
                "lane-threads differs (checkpoint {}, session {}); thread \
                 count affects scheduling only, never the output bytes — \
                 resuming anyway",
                self.lane_threads, current.lane_threads
            ));
        }
        Ok(notes)
    }
}

/// Snapshot of the stable userspace stack map (LRU mode): every
/// interned call path in id order, plus the stat counters (which feed
/// `Report::stack_drops` and would otherwise be inflated by replay).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StackSnapshot {
    /// `frames[id]` is the call path interned under dense id `id`.
    pub frames: Vec<Vec<u64>>,
    pub hits: u64,
    pub inserts: u64,
    pub drops: u64,
    pub evictions: u64,
}

impl StackSnapshot {
    /// Capture the current id → frames mapping and counters.
    pub fn of(map: &StackMap) -> StackSnapshot {
        StackSnapshot {
            frames: (0..map.len() as u32)
                .map(|id| map.resolve(id).to_vec())
                .collect(),
            hits: map.stats.hits,
            inserts: map.stats.inserts,
            drops: map.stats.drops,
            evictions: map.stats.evictions,
        }
    }

    /// Rebuild a map with the identical dense id assignment: interning
    /// content-deduped paths in id order reassigns 0..n in order. The
    /// stat counters are overwritten afterwards — re-interning must not
    /// count as new inserts.
    pub fn rebuild(&self, name: &'static str, capacity: usize) -> Result<StackMap, String> {
        let mut map = StackMap::new(name, capacity);
        for (id, frames) in self.frames.iter().enumerate() {
            let got = map.intern(frames);
            if got != id as u32 {
                return Err(format!(
                    "stack snapshot is inconsistent: path {id} re-interned as id {got} \
                     (duplicate or out-of-order frames in the checkpoint)"
                ));
            }
        }
        map.stats = StackMapStats {
            hits: self.hits,
            inserts: self.inserts,
            drops: self.drops,
            evictions: self.evictions,
        };
        Ok(map)
    }
}

/// One serialized session snapshot.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    /// Simkernel epochs completed (not windows: under the degrade
    /// policy a widened window spans two epochs, so replay is keyed on
    /// epochs and re-derives the window boundaries deterministically).
    pub epochs: u64,
    pub fingerprint: Option<Fingerprint>,
    /// Per-window summaries of everything closed so far — also the
    /// replay integrity oracle.
    pub summaries: Vec<WindowSummary>,
    pub window_drops: Vec<u64>,
    pub degraded_windows: u64,
    pub degraded_drains: u64,
    /// Cumulative merged paths, in cumulative insertion order.
    pub cumulative: Vec<MergedPath>,
    pub sketch_cap: usize,
    /// Sketch counters as `(stack_id, count, err)`, sorted by key.
    pub sketch: Vec<(u32, u64, u64)>,
    /// Stable userspace stack map (`Some` iff the run uses `--lru`).
    pub stacks: Option<StackSnapshot>,
    /// Tier pyramid (`Some` iff the run uses `--compact-base`). When
    /// present, [`Checkpoint::summaries`], `window_drops` and
    /// `cumulative` are empty — the pyramid *is* the history, and the
    /// cumulative merge re-derives from it on restore.
    pub tiers: Option<TierSnapshot>,
    /// Decayed top-K sketch (`Some` iff `--decay-half-life-us`).
    pub recent: Option<RecentSnapshot>,
}

/// Serialized tier pyramid. Entries are kept as **pre-rendered compact
/// JSON strings**, chronological (oldest first): a pyramid entry is
/// immutable once folded, so periodic checkpoint writes splice the
/// cached rendering verbatim ([`Json::Raw`]) and only entries created
/// since the previous write pay serialization cost — append-only tier
/// serialization under the unchanged atomic-rename contract.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TierSnapshot {
    pub base: u64,
    pub windows_total: u64,
    pub slices_total: u64,
    pub drained_total: u64,
    pub drops_total: u64,
    pub lossy_windows: u64,
    /// One compact JSON object per retained entry, oldest first.
    pub entries_json: Vec<String>,
}

/// Serialized [`DecayedSpaceSaving`] state: capacity, the decay clock,
/// and the key-sorted counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecentSnapshot {
    pub cap: usize,
    pub now_ns: u64,
    /// `(stack_id, count, err)` sorted by key.
    pub counters: Vec<(u32, u64, u64)>,
}

impl Default for Fingerprint {
    fn default() -> Fingerprint {
        Fingerprint {
            mode: String::new(),
            merge: String::new(),
            shards: 0,
            window_ns: 0,
            apps: Vec::new(),
            stack_lru: false,
            on_overflow: String::new(),
            ring_capacity: 0,
            drain_threshold: 0,
            dt: 0,
            lane_threads: 1,
            compact_base: 0,
            decay_half_life_us: 0,
        }
    }
}

// ---- serialization -----------------------------------------------------

fn wait_kind_name(w: WaitKind) -> &'static str {
    match w {
        WaitKind::None => "none",
        WaitKind::Futex => "futex",
        WaitKind::Barrier => "barrier",
        WaitKind::Queue => "queue",
        WaitKind::Io => "io",
        WaitKind::Channel => "channel",
    }
}

fn wait_kind_from_name(name: &str) -> Option<WaitKind> {
    match name {
        "none" => Some(WaitKind::None),
        "futex" => Some(WaitKind::Futex),
        "barrier" => Some(WaitKind::Barrier),
        "queue" => Some(WaitKind::Queue),
        "io" => Some(WaitKind::Io),
        "channel" => Some(WaitKind::Channel),
        _ => None,
    }
}

/// A u64-keyed histogram as `[[key, count], …]` sorted by key — hash
/// maps iterate nondeterministically, and checkpoint bytes must be
/// deterministic (the serial-vs-tree equivalence test diffs documents).
fn hist_json<K: Copy + Ord + Into<u64>>(h: &FxHashMap<K, u64>) -> Json {
    let mut entries: Vec<(u64, u64)> = h.iter().map(|(k, v)| ((*k).into(), *v)).collect();
    entries.sort_by_key(|e| e.0);
    Json::Arr(
        entries
            .into_iter()
            .map(|(k, v)| Json::Arr(vec![Json::u64(k), Json::u64(v)]))
            .collect(),
    )
}

fn path_json(p: &MergedPath) -> Json {
    let mut waits: Vec<(&'static str, u64)> = p
        .wait_hist
        .iter()
        .map(|(k, v)| (wait_kind_name(*k), *v))
        .collect();
    waits.sort_by_key(|e| e.0);
    Json::obj(vec![
        ("stack_id", Json::u64(p.stack_id as u64)),
        ("cm_fs", Json::u64(p.cm_fs)),
        ("first_seen", Json::u64(p.first_seen)),
        ("slices", Json::u64(p.slices)),
        ("stack_top_samples", Json::u64(p.stack_top_samples)),
        ("addr_freq", hist_json(&p.addr_freq)),
        (
            "wait_hist",
            Json::Arr(
                waits
                    .into_iter()
                    .map(|(k, v)| Json::Arr(vec![Json::str(k), Json::u64(v)]))
                    .collect(),
            ),
        ),
        ("wakers", hist_json(&p.wakers)),
        ("app_slices", hist_json(&p.app_slices)),
    ])
}

fn fingerprint_json(f: &Fingerprint) -> Json {
    Json::obj(vec![
        ("mode", Json::str(&f.mode)),
        ("merge", Json::str(&f.merge)),
        ("shards", Json::usize(f.shards)),
        ("window_ns", Json::u64(f.window_ns)),
        ("apps", Json::Arr(f.apps.iter().map(Json::str).collect())),
        ("stack_lru", Json::Bool(f.stack_lru)),
        ("on_overflow", Json::str(&f.on_overflow)),
        ("ring_capacity", Json::usize(f.ring_capacity)),
        ("drain_threshold", Json::u64(f.drain_threshold)),
        ("dt", Json::u64(f.dt)),
        ("lane_threads", Json::u64(f.lane_threads)),
        ("compact_base", Json::u64(f.compact_base)),
        ("decay_half_life_us", Json::u64(f.decay_half_life_us)),
    ])
}

/// Render one pyramid entry as its checkpoint object (compact text —
/// the shape [`TierSnapshot::parse_entries`] reads back).
fn tier_entry_json(e: &TierEntry) -> String {
    Json::obj(vec![
        ("level", Json::u64(e.level as u64)),
        ("first", Json::u64(e.first_index)),
        ("last", Json::u64(e.last_index)),
        ("slices", Json::u64(e.summary.slices)),
        ("drained", Json::u64(e.summary.drained)),
        ("drops", Json::u64(e.summary.drops)),
        ("lossy", Json::u64(e.lossy_windows)),
        ("paths", Json::Arr(e.paths.iter().map(path_json).collect())),
    ])
    .to_compact()
}

/// Snapshot a pyramid for checkpointing, filling each entry's
/// serialization cache in place: entries are immutable once folded, so
/// after the first write covering an entry, every later periodic write
/// reuses its cached bytes — serialization cost per write is
/// O(entries created since the last write), not O(retained state).
pub fn tier_snapshot_of(p: &mut TierPyramid) -> TierSnapshot {
    let snap = TierSnapshot {
        base: p.base() as u64,
        windows_total: p.windows_total(),
        slices_total: p.slices_total(),
        drained_total: p.drained_total(),
        drops_total: p.drops_total(),
        lossy_windows: p.lossy_windows(),
        entries_json: Vec::new(),
    };
    let mut entries_json = Vec::new();
    for e in p.entries_chronological_mut() {
        if e.cached_json.is_none() {
            e.cached_json = Some(tier_entry_json(e));
        }
        entries_json.push(e.cached_json.clone().unwrap());
    }
    TierSnapshot {
        entries_json,
        ..snap
    }
}

/// Snapshot a decayed sketch for checkpointing.
pub fn recent_snapshot_of(d: &DecayedSpaceSaving<u32>) -> RecentSnapshot {
    let (cap, now_ns, counters) = d.export();
    RecentSnapshot {
        cap,
        now_ns,
        counters,
    }
}

impl TierSnapshot {
    /// Parse the serialized entries back into pyramid entries
    /// (chronological). Restored entries keep their source text as the
    /// serialization cache, so the first post-restore checkpoint write
    /// is as cheap as any other.
    pub fn parse_entries(&self) -> Result<Vec<TierEntry>, String> {
        self.entries_json
            .iter()
            .map(|text| {
                let v = Json::parse(text)
                    .map_err(|e| format!("checkpoint: corrupt tier entry: {e}"))?;
                let last = get_u64(&v, "tier entry", "last")?;
                let paths = v
                    .get("paths")
                    .and_then(|a| a.as_arr())
                    .ok_or("checkpoint: tier entry \"paths\" is not an array")?
                    .iter()
                    .map(path_from_json)
                    .collect::<Result<Vec<_>, _>>()?;
                let mut e = TierEntry::new(
                    get_u64(&v, "tier entry", "level")? as u32,
                    get_u64(&v, "tier entry", "first")?,
                    last,
                    WindowSummary {
                        index: last,
                        slices: get_u64(&v, "tier entry", "slices")?,
                        drained: get_u64(&v, "tier entry", "drained")?,
                        drops: get_u64(&v, "tier entry", "drops")?,
                    },
                    get_u64(&v, "tier entry", "lossy")?,
                    paths,
                );
                e.cached_json = Some(text.clone());
                Ok(e)
            })
            .collect()
    }
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("checkpoint", Json::u64(CHECKPOINT_VERSION)),
            ("epochs", Json::u64(self.epochs)),
            (
                "fingerprint",
                self.fingerprint
                    .as_ref()
                    .map(fingerprint_json)
                    .unwrap_or(Json::Null),
            ),
            (
                "summaries",
                Json::Arr(
                    self.summaries
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("index", Json::u64(s.index)),
                                ("slices", Json::u64(s.slices)),
                                ("drained", Json::u64(s.drained)),
                                ("drops", Json::u64(s.drops)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "window_drops",
                Json::Arr(self.window_drops.iter().map(|d| Json::u64(*d)).collect()),
            ),
            ("degraded_windows", Json::u64(self.degraded_windows)),
            ("degraded_drains", Json::u64(self.degraded_drains)),
            (
                "cumulative",
                Json::Arr(self.cumulative.iter().map(path_json).collect()),
            ),
            (
                "sketch",
                Json::obj(vec![
                    ("cap", Json::usize(self.sketch_cap)),
                    (
                        "counters",
                        Json::Arr(
                            self.sketch
                                .iter()
                                .map(|(k, c, e)| {
                                    Json::Arr(vec![
                                        Json::u64(*k as u64),
                                        Json::u64(*c),
                                        Json::u64(*e),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "stacks",
                match &self.stacks {
                    None => Json::Null,
                    Some(s) => Json::obj(vec![
                        (
                            "frames",
                            Json::Arr(
                                s.frames
                                    .iter()
                                    .map(|f| {
                                        Json::Arr(
                                            f.iter().map(|a| Json::u64(*a)).collect(),
                                        )
                                    })
                                    .collect(),
                            ),
                        ),
                        ("hits", Json::u64(s.hits)),
                        ("inserts", Json::u64(s.inserts)),
                        ("drops", Json::u64(s.drops)),
                        ("evictions", Json::u64(s.evictions)),
                    ]),
                },
            ),
            (
                "tiers",
                match &self.tiers {
                    None => Json::Null,
                    Some(t) => Json::obj(vec![
                        ("base", Json::u64(t.base)),
                        ("windows", Json::u64(t.windows_total)),
                        ("slices", Json::u64(t.slices_total)),
                        ("drained", Json::u64(t.drained_total)),
                        ("drops", Json::u64(t.drops_total)),
                        ("lossy", Json::u64(t.lossy_windows)),
                        (
                            // Cached pre-rendered entries splice
                            // verbatim (see `TierSnapshot`).
                            "entries",
                            Json::Arr(
                                t.entries_json
                                    .iter()
                                    .map(|s| Json::Raw(s.clone()))
                                    .collect(),
                            ),
                        ),
                    ]),
                },
            ),
            (
                "recent",
                match &self.recent {
                    None => Json::Null,
                    Some(r) => Json::obj(vec![
                        ("cap", Json::usize(r.cap)),
                        ("now_ns", Json::u64(r.now_ns)),
                        (
                            "counters",
                            Json::Arr(
                                r.counters
                                    .iter()
                                    .map(|(k, c, e)| {
                                        Json::Arr(vec![
                                            Json::u64(*k as u64),
                                            Json::u64(*c),
                                            Json::u64(*e),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ]),
                },
            ),
        ])
    }

    pub fn from_json(doc: &Json) -> Result<Checkpoint, String> {
        let version = doc
            .get("checkpoint")
            .ok_or("checkpoint: missing \"checkpoint\" version stamp")?
            .as_u64()
            .ok_or("checkpoint: \"checkpoint\" is not a u64")?;
        if version != CHECKPOINT_VERSION {
            return Err(format!(
                "checkpoint: unsupported version {version} (this build reads \
                 version {CHECKPOINT_VERSION}; version bumps are breaking by policy)"
            ));
        }
        let fingerprint = match doc.get("fingerprint") {
            None | Some(Json::Null) => None,
            Some(f) => Some(Fingerprint {
                mode: get_str(f, "fingerprint", "mode")?,
                merge: get_str(f, "fingerprint", "merge")?,
                shards: get_u64(f, "fingerprint", "shards")? as usize,
                window_ns: get_u64(f, "fingerprint", "window_ns")?,
                apps: f
                    .get("apps")
                    .and_then(|a| a.as_arr())
                    .ok_or("checkpoint: \"fingerprint.apps\" is not an array")?
                    .iter()
                    .map(|a| {
                        a.as_str()
                            .map(String::from)
                            .ok_or("checkpoint: app name is not a string".to_string())
                    })
                    .collect::<Result<Vec<_>, _>>()?,
                stack_lru: f
                    .get("stack_lru")
                    .and_then(|b| b.as_bool())
                    .ok_or("checkpoint: \"fingerprint.stack_lru\" is not a bool")?,
                on_overflow: get_str(f, "fingerprint", "on_overflow")?,
                ring_capacity: get_u64(f, "fingerprint", "ring_capacity")? as usize,
                drain_threshold: get_u64(f, "fingerprint", "drain_threshold")?,
                dt: get_u64(f, "fingerprint", "dt")?,
                // Absent in pre-lane checkpoints; those were written by
                // the single-threaded fold, i.e. one lane thread.
                lane_threads: f
                    .get("lane_threads")
                    .and_then(|v| v.as_u64())
                    .unwrap_or(1),
                // Absent in pre-compaction checkpoints ⇒ both off.
                compact_base: f
                    .get("compact_base")
                    .and_then(|v| v.as_u64())
                    .unwrap_or(0),
                decay_half_life_us: f
                    .get("decay_half_life_us")
                    .and_then(|v| v.as_u64())
                    .unwrap_or(0),
            }),
        };
        let summaries = doc
            .get("summaries")
            .and_then(|s| s.as_arr())
            .ok_or("checkpoint: \"summaries\" is not an array")?
            .iter()
            .map(|s| {
                Ok(WindowSummary {
                    index: get_u64(s, "summaries", "index")?,
                    slices: get_u64(s, "summaries", "slices")?,
                    drained: get_u64(s, "summaries", "drained")?,
                    drops: get_u64(s, "summaries", "drops")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let window_drops = doc
            .get("window_drops")
            .and_then(|w| w.as_arr())
            .ok_or("checkpoint: \"window_drops\" is not an array")?
            .iter()
            .map(|d| {
                d.as_u64()
                    .ok_or("checkpoint: non-u64 window_drops entry".to_string())
            })
            .collect::<Result<Vec<_>, _>>()?;
        let cumulative = doc
            .get("cumulative")
            .and_then(|c| c.as_arr())
            .ok_or("checkpoint: \"cumulative\" is not an array")?
            .iter()
            .map(path_from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let sketch_obj = doc.get("sketch").ok_or("checkpoint: missing \"sketch\"")?;
        let sketch_cap = get_u64(sketch_obj, "sketch", "cap")? as usize;
        let sketch = sketch_obj
            .get("counters")
            .and_then(|c| c.as_arr())
            .ok_or("checkpoint: \"sketch.counters\" is not an array")?
            .iter()
            .map(|e| {
                let t = triple_u64(e, "sketch counter")?;
                Ok((t.0 as u32, t.1, t.2))
            })
            .collect::<Result<Vec<_>, String>>()?;
        let stacks = match doc.get("stacks") {
            None | Some(Json::Null) => None,
            Some(s) => Some(StackSnapshot {
                frames: s
                    .get("frames")
                    .and_then(|f| f.as_arr())
                    .ok_or("checkpoint: \"stacks.frames\" is not an array")?
                    .iter()
                    .map(|f| {
                        f.as_arr()
                            .ok_or("checkpoint: stack frames entry is not an array")?
                            .iter()
                            .map(|a| {
                                a.as_u64()
                                    .ok_or("checkpoint: non-u64 frame address".to_string())
                            })
                            .collect::<Result<Vec<_>, _>>()
                    })
                    .collect::<Result<Vec<_>, _>>()?,
                hits: get_u64(s, "stacks", "hits")?,
                inserts: get_u64(s, "stacks", "inserts")?,
                drops: get_u64(s, "stacks", "drops")?,
                evictions: get_u64(s, "stacks", "evictions")?,
            }),
        };
        let tiers = match doc.get("tiers") {
            None | Some(Json::Null) => None,
            Some(t) => Some(TierSnapshot {
                base: get_u64(t, "tiers", "base")?,
                windows_total: get_u64(t, "tiers", "windows")?,
                slices_total: get_u64(t, "tiers", "slices")?,
                drained_total: get_u64(t, "tiers", "drained")?,
                drops_total: get_u64(t, "tiers", "drops")?,
                lossy_windows: get_u64(t, "tiers", "lossy")?,
                // Re-rendering a parsed entry is the identity (keys
                // keep order, numbers keep their literal text), so the
                // stored strings equal the written ones byte for byte.
                entries_json: t
                    .get("entries")
                    .and_then(|e| e.as_arr())
                    .ok_or("checkpoint: \"tiers.entries\" is not an array")?
                    .iter()
                    .map(|e| e.to_compact())
                    .collect(),
            }),
        };
        let recent = match doc.get("recent") {
            None | Some(Json::Null) => None,
            Some(r) => Some(RecentSnapshot {
                cap: get_u64(r, "recent", "cap")? as usize,
                now_ns: get_u64(r, "recent", "now_ns")?,
                counters: r
                    .get("counters")
                    .and_then(|c| c.as_arr())
                    .ok_or("checkpoint: \"recent.counters\" is not an array")?
                    .iter()
                    .map(|e| {
                        let t = triple_u64(e, "recent counter")?;
                        Ok((t.0 as u32, t.1, t.2))
                    })
                    .collect::<Result<Vec<_>, String>>()?,
            }),
        };
        Ok(Checkpoint {
            epochs: get_u64(doc, "checkpoint", "epochs")?,
            fingerprint,
            summaries,
            window_drops,
            degraded_windows: get_u64(doc, "checkpoint", "degraded_windows")?,
            degraded_drains: get_u64(doc, "checkpoint", "degraded_drains")?,
            cumulative,
            sketch_cap,
            sketch,
            stacks,
            tiers,
            recent,
        })
    }

    /// Write the checkpoint atomically: serialize to `<path>.tmp`, then
    /// rename over `path`. A crash at any point leaves either the old
    /// complete document or a stray temp file — never a torn one.
    pub fn write_atomic(&self, path: &str) -> anyhow::Result<()> {
        let tmp = format!("{path}.tmp");
        let text = self.to_json().to_compact();
        std::fs::write(&tmp, text.as_bytes())
            .map_err(|e| anyhow::anyhow!("cannot write checkpoint {tmp:?}: {e}"))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("cannot publish checkpoint {path:?}: {e}"))?;
        Ok(())
    }

    /// Read and parse a checkpoint file.
    pub fn load(path: &str) -> anyhow::Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read checkpoint {path:?}: {e}"))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("checkpoint {path:?} is corrupt: {e}"))?;
        Checkpoint::from_json(&doc)
            .map_err(|e| anyhow::anyhow!("checkpoint {path:?}: {e}"))
    }
}

fn get_u64(v: &Json, ctx: &str, key: &str) -> Result<u64, String> {
    v.get(key)
        .ok_or_else(|| format!("checkpoint: {ctx:?} is missing {key:?}"))?
        .as_u64()
        .ok_or_else(|| format!("checkpoint: {ctx:?} field {key:?} is not a u64"))
}

fn get_str(v: &Json, ctx: &str, key: &str) -> Result<String, String> {
    Ok(v.get(key)
        .ok_or_else(|| format!("checkpoint: {ctx:?} is missing {key:?}"))?
        .as_str()
        .ok_or_else(|| format!("checkpoint: {ctx:?} field {key:?} is not a string"))?
        .to_string())
}

fn triple_u64(v: &Json, what: &str) -> Result<(u64, u64, u64), String> {
    let arr = v
        .as_arr()
        .ok_or_else(|| format!("checkpoint: {what} is not an array"))?;
    if arr.len() != 3 {
        return Err(format!("checkpoint: {what} must have 3 entries"));
    }
    let n = |j: &Json| {
        j.as_u64()
            .ok_or_else(|| format!("checkpoint: {what} entry is not a u64"))
    };
    Ok((n(&arr[0])?, n(&arr[1])?, n(&arr[2])?))
}

fn pair_u64(v: &Json, what: &str) -> Result<(u64, u64), String> {
    let arr = v
        .as_arr()
        .ok_or_else(|| format!("checkpoint: {what} is not an array"))?;
    if arr.len() != 2 {
        return Err(format!("checkpoint: {what} must have 2 entries"));
    }
    let n = |j: &Json| {
        j.as_u64()
            .ok_or_else(|| format!("checkpoint: {what} entry is not a u64"))
    };
    Ok((n(&arr[0])?, n(&arr[1])?))
}

fn path_from_json(v: &Json) -> Result<MergedPath, String> {
    let mut p = MergedPath::new(get_u64(v, "path", "stack_id")? as u32);
    p.cm_fs = get_u64(v, "path", "cm_fs")?;
    p.total_cm_ns = p.cm_fs as f64 / 1e6;
    p.first_seen = get_u64(v, "path", "first_seen")?;
    p.slices = get_u64(v, "path", "slices")?;
    p.stack_top_samples = get_u64(v, "path", "stack_top_samples")?;
    for e in v
        .get("addr_freq")
        .and_then(|a| a.as_arr())
        .ok_or("checkpoint: path \"addr_freq\" is not an array")?
    {
        let (k, n) = pair_u64(e, "addr_freq entry")?;
        p.addr_freq.insert(k, n);
    }
    for e in v
        .get("wait_hist")
        .and_then(|a| a.as_arr())
        .ok_or("checkpoint: path \"wait_hist\" is not an array")?
    {
        let arr = e
            .as_arr()
            .ok_or("checkpoint: wait_hist entry is not an array")?;
        if arr.len() != 2 {
            return Err("checkpoint: wait_hist entry must have 2 entries".to_string());
        }
        let name = arr[0]
            .as_str()
            .ok_or("checkpoint: wait kind is not a string")?;
        let kind = wait_kind_from_name(name)
            .ok_or_else(|| format!("checkpoint: unknown wait kind {name:?}"))?;
        let n = arr[1]
            .as_u64()
            .ok_or("checkpoint: wait_hist count is not a u64")?;
        p.wait_hist.insert(kind, n);
    }
    for e in v
        .get("wakers")
        .and_then(|a| a.as_arr())
        .ok_or("checkpoint: path \"wakers\" is not an array")?
    {
        let (k, n) = pair_u64(e, "wakers entry")?;
        p.wakers.insert(k as u32, n);
    }
    for e in v
        .get("app_slices")
        .and_then(|a| a.as_arr())
        .ok_or("checkpoint: path \"app_slices\" is not an array")?
    {
        let (k, n) = pair_u64(e, "app_slices entry")?;
        p.app_slices.insert(k as u16, n);
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_path(id: u32) -> MergedPath {
        let mut p = MergedPath::new(id);
        p.cm_fs = 2_500_000_000;
        p.total_cm_ns = p.cm_fs as f64 / 1e6;
        p.first_seen = 41;
        p.slices = 3;
        p.stack_top_samples = 1;
        p.addr_freq.insert(0x40, 2);
        p.addr_freq.insert(0x80, 1);
        p.wait_hist.insert(WaitKind::Futex, 2);
        p.wait_hist.insert(WaitKind::None, 1);
        p.wakers.insert(7, 2);
        p.app_slices.insert(0, 3);
        p
    }

    fn sample_checkpoint() -> Checkpoint {
        Checkpoint {
            epochs: 3,
            fingerprint: Some(Fingerprint {
                mode: "live".into(),
                merge: "tree".into(),
                shards: 4,
                window_ns: 5_000_000,
                apps: vec!["mysql".into(), "dedup".into()],
                stack_lru: true,
                on_overflow: "degrade".into(),
                ring_capacity: 1 << 20,
                drain_threshold: 1 << 14,
                dt: 3_000_000,
                lane_threads: 1,
                compact_base: 0,
                decay_half_life_us: 0,
            }),
            summaries: vec![
                WindowSummary {
                    index: 1,
                    slices: 5,
                    drained: 40,
                    drops: 0,
                },
                WindowSummary {
                    index: 2,
                    slices: 2,
                    drained: 13,
                    drops: 4,
                },
            ],
            window_drops: vec![0, 4],
            degraded_windows: 1,
            degraded_drains: 2,
            cumulative: vec![sample_path(0), sample_path(2)],
            sketch_cap: 64,
            sketch: vec![(0, 100, 0), (2, 50, 10)],
            stacks: Some(StackSnapshot {
                frames: vec![vec![0x40, 0x80], vec![0x90]],
                hits: 6,
                inserts: 2,
                drops: 0,
                evictions: 0,
            }),
            tiers: None,
            recent: None,
        }
    }

    #[test]
    fn checkpoints_round_trip_through_json() {
        let cp = sample_checkpoint();
        let doc = Json::parse(&cp.to_json().to_compact()).unwrap();
        let rt = Checkpoint::from_json(&doc).unwrap();
        assert_eq!(rt.epochs, cp.epochs);
        assert_eq!(rt.fingerprint, cp.fingerprint);
        assert_eq!(rt.window_drops, cp.window_drops);
        assert_eq!(rt.degraded_windows, 1);
        assert_eq!(rt.degraded_drains, 2);
        assert_eq!(rt.summaries.len(), 2);
        assert_eq!(rt.summaries[1].drops, 4);
        assert_eq!(rt.sketch_cap, 64);
        assert_eq!(rt.sketch, cp.sketch);
        assert_eq!(rt.stacks, cp.stacks);
        assert_eq!(rt.cumulative.len(), 2);
        let (a, b) = (&rt.cumulative[0], &cp.cumulative[0]);
        assert_eq!(a.stack_id, b.stack_id);
        assert_eq!(a.cm_fs, b.cm_fs);
        assert_eq!(a.first_seen, b.first_seen);
        assert_eq!(a.addr_freq, b.addr_freq);
        assert_eq!(a.wait_hist, b.wait_hist);
        assert_eq!(a.wakers, b.wakers);
        assert_eq!(a.app_slices, b.app_slices);
        assert!((a.total_cm_ns - b.total_cm_ns).abs() < 1e-9);
        // Serialization is deterministic (maps are key-sorted).
        assert_eq!(cp.to_json().to_compact(), rt.to_json().to_compact());
    }

    #[test]
    fn atomic_write_and_load_round_trip() {
        let dir = std::env::temp_dir().join("gapp_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        let path = path.to_str().unwrap();
        let cp = sample_checkpoint();
        cp.write_atomic(path).unwrap();
        // The temp file never survives a successful publish.
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let rt = Checkpoint::load(path).unwrap();
        assert_eq!(rt.to_json().to_compact(), cp.to_json().to_compact());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn foreign_versions_and_corrupt_documents_error_loudly() {
        let err = Checkpoint::from_json(&Json::parse("{\"checkpoint\": 2}").unwrap())
            .unwrap_err();
        assert!(err.contains("version 2"), "{err}");
        let err = Checkpoint::from_json(&Json::parse("{\"epochs\": 1}").unwrap())
            .unwrap_err();
        assert!(err.contains("version stamp"), "{err}");
        let err = Checkpoint::from_json(
            &Json::parse("{\"checkpoint\": 1, \"epochs\": 1}").unwrap(),
        )
        .unwrap_err();
        assert!(err.contains("summaries"), "{err}");
        // Unknown wait kinds (corruption or a foreign writer) fail.
        let mut doc = sample_checkpoint().to_json().to_compact();
        doc = doc.replace("futex", "vibes");
        let err =
            Checkpoint::from_json(&Json::parse(&doc).unwrap()).unwrap_err();
        assert!(err.contains("vibes"), "{err}");
    }

    #[test]
    fn fingerprint_mismatches_name_the_knob() {
        let a = sample_checkpoint().fingerprint.unwrap();
        let mut b = a.clone();
        b.shards = 1;
        let err = a.check(&b).unwrap_err();
        assert!(err.contains("shards"), "{err}");
        assert!(err.contains('4') && err.contains('1'), "{err}");
        let mut c = a.clone();
        c.merge = "serial".into();
        let err = a.check(&c).unwrap_err();
        assert!(err.contains("merge"), "{err}");
        assert!(a.check(&a.clone()).unwrap().is_empty());
    }

    /// Satellite invariant of the lane-thread refactor: thread count
    /// never reaches the analysis state, so checkpoints written at
    /// different `--lane-threads` differ *only* in the fingerprint
    /// field — and resuming across thread counts is a named note, not
    /// a "different configuration" error.
    #[test]
    fn thread_counts_change_one_fingerprint_field_and_resume_freely() {
        let cp1 = sample_checkpoint();
        let mut cp4 = cp1.clone();
        cp4.fingerprint.as_mut().unwrap().lane_threads = 4;
        let (a, b) = (cp1.to_json().to_compact(), cp4.to_json().to_compact());
        assert_eq!(a.replace("\"lane_threads\":1", "\"lane_threads\":4"), b);
        let fp1 = cp1.fingerprint.unwrap();
        let fp4 = cp4.fingerprint.unwrap();
        let notes = fp1.check(&fp4).unwrap();
        assert_eq!(notes.len(), 1);
        assert!(notes[0].contains("lane-threads"), "{}", notes[0]);
        assert!(
            notes[0].contains("checkpoint 1") && notes[0].contains("session 4"),
            "{}",
            notes[0]
        );
        // Pre-lane checkpoints (no lane_threads key) parse as 1.
        let doc = a.replace(",\"lane_threads\":1", "");
        let old = Checkpoint::from_json(&Json::parse(&doc).unwrap()).unwrap();
        assert_eq!(old.fingerprint.unwrap().lane_threads, 1);
    }

    #[test]
    fn tier_snapshots_round_trip_and_reuse_cached_entry_bytes() {
        // Five windows into a base-2 pyramid: retained entries are the
        // binary digits of 5 (101₂ → one level-2, one level-0 entry).
        let mut p = TierPyramid::new(2);
        for i in 1..=5u64 {
            let mut path = sample_path(i as u32);
            path.first_seen = i * 100;
            p.push(
                WindowSummary {
                    index: i,
                    slices: 3,
                    drained: 10,
                    drops: (i == 4) as u64,
                },
                vec![path],
            );
        }
        let snap1 = tier_snapshot_of(&mut p);
        assert_eq!(snap1.entries_json.len() as u64, p.entries());
        assert_eq!(snap1.entries_json.len(), 2);
        // Second snapshot splices the cached bytes — identical.
        let snap2 = tier_snapshot_of(&mut p);
        assert_eq!(snap1, snap2);
        // New windows create new entries; pre-existing ones keep their
        // exact cached rendering (append-only serialization).
        let mut path6 = sample_path(6);
        path6.first_seen = 600;
        p.push(
            WindowSummary {
                index: 6,
                slices: 3,
                drained: 10,
                drops: 0,
            },
            vec![path6],
        );
        let snap3 = tier_snapshot_of(&mut p);
        assert_eq!(snap3.entries_json[0], snap1.entries_json[0]);
        // Full checkpoint round trip, Raw splicing included.
        let mut recent = DecayedSpaceSaving::new(4, 1_000);
        recent.add(1, 800);
        recent.advance_to(2_000);
        recent.add(2, 300);
        let mut cp = sample_checkpoint();
        cp.summaries.clear();
        cp.window_drops.clear();
        cp.cumulative.clear();
        {
            let fp = cp.fingerprint.as_mut().unwrap();
            fp.compact_base = 2;
            fp.decay_half_life_us = 1;
        }
        cp.tiers = Some(snap3.clone());
        cp.recent = Some(recent_snapshot_of(&recent));
        let doc = Json::parse(&cp.to_json().to_compact()).unwrap();
        let rt = Checkpoint::from_json(&doc).unwrap();
        assert_eq!(rt.tiers, cp.tiers);
        assert_eq!(rt.recent, cp.recent);
        assert_eq!(rt.to_json().to_compact(), cp.to_json().to_compact());
        // Entries parse back into a pyramid with the identical shape
        // and serialization (warm cache on the restored side too).
        let entries = rt.tiers.as_ref().unwrap().parse_entries().unwrap();
        let mut restored = TierPyramid::restore(2, entries).unwrap();
        assert!(restored.same_shape(&p));
        assert_eq!(tier_snapshot_of(&mut restored), snap3);
        // The decayed sketch restores to identical export state.
        let r = rt.recent.as_ref().unwrap();
        let back =
            DecayedSpaceSaving::from_parts(r.cap, 1_000, r.now_ns, r.counters.clone())
                .unwrap();
        assert_eq!(back.export(), recent.export());
    }

    #[test]
    fn compaction_knobs_are_hard_fingerprint_checks_and_default_off() {
        let a = sample_checkpoint().fingerprint.unwrap();
        let mut b = a.clone();
        b.compact_base = 8;
        let err = a.check(&b).unwrap_err();
        assert!(err.contains("compact_base"), "{err}");
        assert!(err.contains("off") && err.contains('8'), "{err}");
        let mut c = a.clone();
        c.decay_half_life_us = 1_000_000;
        let err = a.check(&c).unwrap_err();
        assert!(err.contains("decay_half_life_us"), "{err}");
        // Pre-compaction checkpoints (no such keys) parse as off, and
        // absent tiers/recent sections parse as None.
        let doc = sample_checkpoint()
            .to_json()
            .to_compact()
            .replace(",\"compact_base\":0,\"decay_half_life_us\":0", "")
            .replace(",\"tiers\":null,\"recent\":null", "");
        let old = Checkpoint::from_json(&Json::parse(&doc).unwrap()).unwrap();
        let fp = old.fingerprint.unwrap();
        assert_eq!((fp.compact_base, fp.decay_half_life_us), (0, 0));
        assert!(old.tiers.is_none() && old.recent.is_none());
    }

    #[test]
    fn stack_snapshots_rebuild_with_identical_ids_and_stats() {
        let mut map = StackMap::new("orig", 1 << 10);
        let a = map.intern(&[0x40, 0x80]);
        let b = map.intern(&[0x90]);
        let a2 = map.intern(&[0x40, 0x80]); // hit
        assert_eq!(a, a2);
        let snap = StackSnapshot::of(&map);
        let rebuilt = snap.rebuild("rebuilt", 1 << 10).unwrap();
        assert_eq!(rebuilt.len(), 2);
        assert_eq!(rebuilt.resolve(a), map.resolve(a));
        assert_eq!(rebuilt.resolve(b), map.resolve(b));
        assert_eq!(rebuilt.stats.hits, map.stats.hits);
        assert_eq!(rebuilt.stats.inserts, map.stats.inserts);
        // A duplicated path cannot rebuild densely — loud error.
        let bad = StackSnapshot {
            frames: vec![vec![1], vec![1]],
            ..Default::default()
        };
        let err = bad.rebuild("dup", 16).unwrap_err();
        assert!(err.contains("re-interned"), "{err}");
    }
}
