//! Deterministic fault injection for crash/degradation testing.
//!
//! A [`FaultPlan`] is a small JSON document (CLI `--fault-plan FILE`)
//! describing adverse conditions the session driver injects into an
//! otherwise-normal run, all keyed on the deterministic epoch counter
//! so a faulted run is exactly reproducible (and replayable on
//! restore):
//!
//! * **overflow bursts** — push a burst of payload-free
//!   [`Record::Noise`] records into one ring shard at an epoch start,
//!   modelling a foreign tracer or perf storm sharing the buffer;
//! * **a stalled shard lane** — suppress the watermark consumer for one
//!   shard over an epoch range, so its ring fills and (under the shed
//!   policy) drops, modelling a wedged per-CPU reader; the window-close
//!   epoch drain still runs, as a restarted reader would catch up;
//! * **kill points** — abort the session with an error right after a
//!   chosen window closes (and after its checkpoint is written), the
//!   crash half of the kill → restore → finish recovery invariant;
//! * **corrupt JSONL** — [`corrupt_jsonl`] deterministically truncates
//!   and mutates partial-event lines, feeding the quarantine path of
//!   the fleet aggregation reader.
//!
//! [`HazardControl`] is the live per-session state those injections
//! (and the `--on-overflow degrade` policy) maintain; it lives on
//! [`crate::gapp::GappCore`] so the probe hot path can consult it.
//!
//! [`Record::Noise`]: crate::gapp::records::Record::Noise

use crate::util::json::Json;
use crate::util::Prng;

/// Version stamp of the fault-plan document.
pub const FAULT_PLAN_VERSION: u64 = 1;

/// One injected burst of foreign ring traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OverflowBurst {
    /// 1-based epoch at whose start the burst is pushed.
    pub epoch: u64,
    /// CPU whose ring shard receives the burst (routed `cpu % shards`,
    /// like every other record).
    pub cpu: usize,
    /// Number of `Record::Noise` records pushed.
    pub records: u64,
}

/// A stalled shard-lane consumer: watermark drains for `shard` are
/// suppressed while `from_epoch <= epoch < from_epoch + epochs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StallSpec {
    pub shard: usize,
    /// 1-based first stalled epoch.
    pub from_epoch: u64,
    /// Number of consecutive stalled epochs.
    pub epochs: u64,
}

/// A deterministic schedule of injected faults (`--fault-plan FILE`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub bursts: Vec<OverflowBurst>,
    pub stall: Option<StallSpec>,
    /// Abort the session (with a recognizable error) right after this
    /// 1-based window closes — after the window's checkpoint write, so
    /// recovery can resume from it. `Some(0)` kills a batch session
    /// before its single run (degenerate: resume restarts from zero).
    pub kill_after_window: Option<u64>,
}

impl FaultPlan {
    /// Parse a fault-plan document. Unknown keys are rejected — a typo
    /// in a fault plan must not silently disable the fault it meant to
    /// inject (the opposite of the sink-schema policy, on purpose:
    /// plans are operator input, not wire data).
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let doc = Json::parse(text).map_err(|e| format!("fault plan: {e}"))?;
        let fields = match &doc {
            Json::Obj(fields) => fields,
            _ => return Err("fault plan: document must be an object".to_string()),
        };
        let version = doc
            .get("fault_plan")
            .ok_or("fault plan: missing \"fault_plan\" version stamp")?
            .as_u64()
            .ok_or("fault plan: \"fault_plan\" is not a u64")?;
        if version != FAULT_PLAN_VERSION {
            return Err(format!(
                "fault plan: unsupported version {version} (expected {FAULT_PLAN_VERSION})"
            ));
        }
        let mut plan = FaultPlan::default();
        for (key, value) in fields {
            match key.as_str() {
                "fault_plan" => {}
                "overflow_bursts" => {
                    let arr = value
                        .as_arr()
                        .ok_or("fault plan: \"overflow_bursts\" is not an array")?;
                    for b in arr {
                        plan.bursts.push(OverflowBurst {
                            epoch: field_u64(b, "overflow_bursts", "epoch")?,
                            cpu: field_u64(b, "overflow_bursts", "cpu")? as usize,
                            records: field_u64(b, "overflow_bursts", "records")?,
                        });
                    }
                }
                "stall" => {
                    plan.stall = Some(StallSpec {
                        shard: field_u64(value, "stall", "shard")? as usize,
                        from_epoch: field_u64(value, "stall", "from_epoch")?,
                        epochs: field_u64(value, "stall", "epochs")?,
                    });
                }
                "kill_after_window" => {
                    plan.kill_after_window = Some(value.as_u64().ok_or(
                        "fault plan: \"kill_after_window\" is not a u64",
                    )?);
                }
                other => {
                    return Err(format!(
                        "fault plan: unknown key {other:?} (a typo would silently \
                         disable the fault it meant to inject)"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Read and parse `--fault-plan FILE`.
    pub fn load(path: &str) -> Result<FaultPlan, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read fault plan {path:?}: {e}"))?;
        FaultPlan::parse(&text)
    }

    /// Bursts scheduled for the start of `epoch` (1-based).
    pub fn bursts_at(&self, epoch: u64) -> impl Iterator<Item = &OverflowBurst> {
        self.bursts.iter().filter(move |b| b.epoch == epoch)
    }

    /// The shard whose watermark consumer is stalled during `epoch`.
    pub fn stalled_shard_at(&self, epoch: u64) -> Option<usize> {
        self.stall.and_then(|s| {
            (epoch >= s.from_epoch && epoch < s.from_epoch.saturating_add(s.epochs))
                .then_some(s.shard)
        })
    }
}

fn field_u64(v: &Json, ctx: &str, key: &str) -> Result<u64, String> {
    v.get(key)
        .ok_or_else(|| format!("fault plan: {ctx:?} entry missing {key:?}"))?
        .as_u64()
        .ok_or_else(|| format!("fault plan: {ctx:?} field {key:?} is not a u64"))
}

/// Live fault/degradation state consulted on the probe hot path. Lives
/// on [`crate::gapp::GappCore`]; the session driver re-arms it per
/// epoch from the [`FaultPlan`] and the overflow policy, so a resumed
/// run replays the exact same hazards.
#[derive(Clone, Copy, Debug, Default)]
pub struct HazardControl {
    /// `--on-overflow degrade`: emergency-drain rings about to
    /// overflow instead of letting them shed.
    pub degrade: bool,
    /// Watermark (and emergency) drains suppressed for this shard —
    /// the stalled-lane fault for the current epoch.
    pub stalled_shard: Option<usize>,
    /// Emergency drains performed since the current window opened
    /// (taken and reset by the driver at window close).
    pub window_drains: u64,
    /// Cumulative emergency drains over the whole session.
    pub total_drains: u64,
}

/// Headroom (in records) at which the degrade policy emergency-drains
/// a ring. The check runs after the probe handler pushed this event's
/// records (an event emits at most a handful), so a small margin is
/// needed to act strictly before the ring can overflow.
pub const DEGRADE_HEADROOM: usize = 8;

/// Deterministically corrupt a JSONL stream: every `every`-th line is
/// either truncated mid-way, has one character clobbered, or loses its
/// closing brace — the three corruption shapes a torn write or a
/// garbled transport produces. Returns the corrupted text; line count
/// is preserved. Used by the quarantine tests and the CI smoke.
pub fn corrupt_jsonl(text: &str, seed: u64, every: usize) -> String {
    assert!(every >= 1, "corrupt_jsonl: every must be >= 1");
    let mut rng = Prng::new(seed);
    let mut out = String::with_capacity(text.len());
    for (i, line) in text.lines().enumerate() {
        if i % every == every - 1 && !line.is_empty() {
            let chars: Vec<char> = line.chars().collect();
            match rng.below(3) {
                // Torn write: keep a strict, non-empty prefix.
                0 if chars.len() >= 2 => {
                    let keep = 1 + rng.below(chars.len() as u64 - 1) as usize;
                    out.extend(chars[..keep].iter());
                }
                // Bit rot: clobber one character.
                1 => {
                    let at = rng.below(chars.len() as u64) as usize;
                    let mut c = chars.clone();
                    c[at] = '#';
                    out.extend(c.iter());
                }
                // Lost tail: drop the final character.
                _ => out.extend(chars[..chars.len() - 1].iter()),
            }
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_parse_and_answer_schedule_queries() {
        let plan = FaultPlan::parse(
            r#"{
                "fault_plan": 1,
                "overflow_bursts": [
                    {"epoch": 2, "cpu": 1, "records": 300},
                    {"epoch": 2, "cpu": 3, "records": 50},
                    {"epoch": 4, "cpu": 0, "records": 10}
                ],
                "stall": {"shard": 1, "from_epoch": 3, "epochs": 2},
                "kill_after_window": 3
            }"#,
        )
        .unwrap();
        assert_eq!(plan.bursts_at(2).count(), 2);
        assert_eq!(plan.bursts_at(1).count(), 0);
        assert_eq!(plan.bursts_at(4).next().unwrap().records, 10);
        assert_eq!(plan.stalled_shard_at(2), None);
        assert_eq!(plan.stalled_shard_at(3), Some(1));
        assert_eq!(plan.stalled_shard_at(4), Some(1));
        assert_eq!(plan.stalled_shard_at(5), None);
        assert_eq!(plan.kill_after_window, Some(3));
    }

    #[test]
    fn empty_plan_is_valid_and_inert() {
        let plan = FaultPlan::parse(r#"{"fault_plan": 1}"#).unwrap();
        assert_eq!(plan, FaultPlan::default());
        assert_eq!(plan.stalled_shard_at(1), None);
        assert!(plan.kill_after_window.is_none());
    }

    #[test]
    fn bad_plans_get_descriptive_errors() {
        for (text, what) in [
            ("[1]", "object"),
            ("{\"overflow_bursts\": []}", "version stamp"),
            ("{\"fault_plan\": 2}", "version 2"),
            ("{\"fault_plan\": 1, \"krash\": true}", "krash"),
            (
                "{\"fault_plan\": 1, \"stall\": {\"shard\": 0}}",
                "from_epoch",
            ),
            (
                "{\"fault_plan\": 1, \"overflow_bursts\": [{\"epoch\": 1}]}",
                "cpu",
            ),
            ("{\"fault_plan\": 1, \"kill_after_window\": \"x\"}", "u64"),
            ("{not json", "fault plan"),
        ] {
            let err = FaultPlan::parse(text).unwrap_err();
            assert!(err.contains(what), "{text}: {err:?} should mention {what:?}");
        }
    }

    #[test]
    fn jsonl_corruption_is_deterministic_and_line_preserving() {
        let text = "{\"a\":1}\n{\"b\":2}\n{\"c\":3}\n{\"d\":4}\n";
        let a = corrupt_jsonl(text, 7, 2);
        let b = corrupt_jsonl(text, 7, 2);
        assert_eq!(a, b, "same seed must corrupt identically");
        assert_eq!(a.lines().count(), 4);
        // Untouched lines survive verbatim; touched lines differ.
        let (orig, corr): (Vec<&str>, Vec<&str>) =
            (text.lines().collect(), a.lines().collect());
        assert_eq!(orig[0], corr[0]);
        assert_eq!(orig[2], corr[2]);
        assert_ne!(orig[1], corr[1]);
        assert_ne!(orig[3], corr[3]);
        // Seeding matters: some other seed must corrupt differently
        // (any single pair of seeds may collide on these short lines).
        assert!(
            (8..40).any(|seed| corrupt_jsonl(text, seed, 2) != a),
            "corruption ignores its seed"
        );
    }
}
