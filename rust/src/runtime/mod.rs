//! PJRT runtime: load the AOT-compiled analysis artifacts and serve them
//! on the profiling hot path.
//!
//! `make artifacts` runs Python once (jax → StableHLO → HLO text, see
//! `python/compile/aot.py`); this module loads those files with
//! `HloModuleProto::from_text_file`, compiles them on the PJRT CPU
//! client, and executes them from Rust. Python never runs at profile
//! time. A pure-Rust native backend implements the identical math so
//! the system degrades gracefully when `artifacts/` is absent — and so
//! tests can assert Rust-vs-XLA equality.

pub mod engine;
pub mod analysis;

pub use analysis::{AnalysisEngine, AnalyzeOut, Backend};
pub use engine::XlaEngine;

/// Thread-slot width of the compiled artifacts (matches python DEFAULT_T).
pub const T_SLOTS: usize = 128;
/// Interval-batch size of the primary analyze artifact.
pub const BATCH: usize = 1024;
/// Call-path capacity of the primary rank artifact.
pub const RANK_P: usize = 1024;
/// K of the primary rank artifact.
pub const RANK_K: usize = 16;

/// Locate the artifacts directory: $GAPP_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("GAPP_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
