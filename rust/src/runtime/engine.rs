//! The XLA/PJRT execution engine: one compiled executable per artifact.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md). Executables are
//! compiled once at load; per-batch work is literal creation + execute.
//!
//! The PJRT path needs the external `xla` crate, which only exists in
//! the artifact-building image — it is gated behind the `xla` cargo
//! feature. Without the feature this module compiles a stub whose
//! loaders fail cleanly, so `AnalysisEngine::auto()` falls back to the
//! bit-equivalent native backend and the rest of the crate is unchanged.

/// Outputs of one analyze() batch.
#[derive(Clone, Debug, Default)]
pub struct AnalyzeRaw {
    pub cm: Vec<f32>,
    pub wall: Vec<f32>,
    pub threads_av: Vec<f32>,
    pub global_cm: f32,
}

#[cfg(feature = "xla")]
mod imp {
    use std::path::Path;

    use anyhow::{Context, Result};

    use super::super::{BATCH, RANK_K, RANK_P, T_SLOTS};
    use super::AnalyzeRaw;

    /// Compiled PJRT executables for the analysis graphs.
    pub struct XlaEngine {
        client: xla::PjRtClient,
        analyze: xla::PjRtLoadedExecutable,
        rank: xla::PjRtLoadedExecutable,
        pub batch: usize,
        pub t_slots: usize,
        pub rank_p: usize,
        pub rank_k: usize,
        /// Number of execute() calls (for perf accounting).
        pub executions: u64,
    }

    impl XlaEngine {
        /// Load and compile the primary artifacts from `dir`.
        pub fn load(dir: &Path) -> Result<XlaEngine> {
            Self::load_variant(dir, BATCH, T_SLOTS)
        }

        /// Load a specific analyze variant (batch-size sweep in §Perf).
        pub fn load_variant(dir: &Path, batch: usize, t_slots: usize) -> Result<XlaEngine> {
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            let analyze_path = dir.join(format!("cmetric_b{batch}_t{t_slots}.hlo.txt"));
            let rank_path = dir.join(format!("rank_p{RANK_P}_k{RANK_K}.hlo.txt"));
            let analyze = Self::compile(&client, &analyze_path)?;
            let rank = Self::compile(&client, &rank_path)?;
            Ok(XlaEngine {
                client,
                analyze,
                rank,
                batch,
                t_slots,
                rank_p: RANK_P,
                rank_k: RANK_K,
                executions: 0,
            })
        }

        fn compile(
            client: &xla::PjRtClient,
            path: &Path,
        ) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not UTF-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))
        }

        /// Run the batched CMetric analysis: `a` is row-major `[batch × T]`
        /// in {0,1}, `t` is `[batch]` durations (ns as f32).
        pub fn analyze(&mut self, a: &[f32], t: &[f32]) -> Result<AnalyzeRaw> {
            anyhow::ensure!(a.len() == self.batch * self.t_slots, "bad A shape");
            anyhow::ensure!(t.len() == self.batch, "bad t shape");
            let a_lit = xla::Literal::vec1(a)
                .reshape(&[self.batch as i64, self.t_slots as i64])?;
            let t_lit = xla::Literal::vec1(t);
            let result = self.analyze.execute::<xla::Literal>(&[a_lit, t_lit])?[0][0]
                .to_literal_sync()?;
            self.executions += 1;
            let (cm, wall, tav, gcm) = result.to_tuple4()?;
            Ok(AnalyzeRaw {
                cm: cm.to_vec::<f32>()?,
                wall: wall.to_vec::<f32>()?,
                threads_av: tav.to_vec::<f32>()?,
                global_cm: gcm.to_vec::<f32>()?[0],
            })
        }

        /// Top-K over a padded score vector: returns (index, value) pairs,
        /// descending.
        pub fn rank(&mut self, scores: &[f32]) -> Result<Vec<(usize, f32)>> {
            anyhow::ensure!(scores.len() == self.rank_p, "bad scores shape");
            let s_lit = xla::Literal::vec1(scores);
            let result = self.rank.execute::<xla::Literal>(&[s_lit])?[0][0]
                .to_literal_sync()?;
            self.executions += 1;
            let (vals, idx) = result.to_tuple2()?;
            let vals = vals.to_vec::<f32>()?;
            let idx = idx.to_vec::<i32>()?;
            Ok(idx
                .into_iter()
                .zip(vals)
                .map(|(i, v)| (i as usize, v))
                .collect())
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::AnalyzeRaw;

    /// Stub engine compiled when the `xla` feature is off: loaders fail,
    /// so no instance can exist and the execute paths are unreachable.
    pub struct XlaEngine {
        pub batch: usize,
        pub t_slots: usize,
        pub rank_p: usize,
        pub rank_k: usize,
        pub executions: u64,
    }

    impl XlaEngine {
        pub fn load(_dir: &Path) -> Result<XlaEngine> {
            bail!("XLA backend not compiled in (build with --features xla)")
        }

        pub fn load_variant(
            _dir: &Path,
            _batch: usize,
            _t_slots: usize,
        ) -> Result<XlaEngine> {
            bail!("XLA backend not compiled in (build with --features xla)")
        }

        pub fn analyze(&mut self, _a: &[f32], _t: &[f32]) -> Result<AnalyzeRaw> {
            bail!("XLA backend not compiled in")
        }

        pub fn rank(&mut self, _scores: &[f32]) -> Result<Vec<(usize, f32)>> {
            bail!("XLA backend not compiled in")
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }
}

pub use imp::XlaEngine;

#[cfg(test)]
mod tests {
    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_loader_fails_cleanly() {
        let e = super::XlaEngine::load(std::path::Path::new("artifacts")).unwrap_err();
        assert!(format!("{e}").contains("not compiled in"));
    }
}
