//! The analysis engine used by GAPP's user-space probe: XLA-backed when
//! artifacts are present, with a bit-equivalent native fallback.
//!
//! The native backend exists for three reasons: (a) `cargo test` must
//! pass in a tree where `make artifacts` has not run; (b) the
//! Rust-vs-XLA equivalence test is the end-to-end numeric check of the
//! whole AOT path; (c) the §Perf pass compares the two on the same
//! batches.

use anyhow::Result;

use super::engine::{AnalyzeRaw, XlaEngine};
use super::{artifacts_dir, BATCH, RANK_K, RANK_P, T_SLOTS};

/// Which implementation serves the analysis.
pub enum Backend {
    /// AOT-compiled XLA executables via PJRT.
    Xla(Box<XlaEngine>),
    /// Pure-Rust reference implementation of the same math.
    Native,
}

/// Outputs of one analyze() batch (native or XLA).
pub type AnalyzeOut = AnalyzeRaw;

/// Batched CMetric analysis + top-K ranking.
pub struct AnalysisEngine {
    pub backend: Backend,
    pub batch: usize,
    pub t_slots: usize,
    /// Batches analyzed (perf accounting).
    pub batches: u64,
    /// Reused padding buffer for XLA rank calls. The streaming analyzer
    /// ranks once per epoch window, so the pad must not be a fresh
    /// allocation per call.
    rank_pad: Vec<f32>,
}

impl AnalysisEngine {
    /// Prefer XLA when artifacts exist; fall back to native.
    pub fn auto() -> AnalysisEngine {
        match XlaEngine::load(&artifacts_dir()) {
            Ok(e) => AnalysisEngine {
                batch: e.batch,
                t_slots: e.t_slots,
                backend: Backend::Xla(Box::new(e)),
                batches: 0,
                rank_pad: Vec::new(),
            },
            Err(_) => AnalysisEngine::native(),
        }
    }

    pub fn native() -> AnalysisEngine {
        AnalysisEngine {
            backend: Backend::Native,
            batch: BATCH,
            t_slots: T_SLOTS,
            batches: 0,
            rank_pad: Vec::new(),
        }
    }

    pub fn xla() -> Result<AnalysisEngine> {
        let e = XlaEngine::load(&artifacts_dir())?;
        Ok(AnalysisEngine {
            batch: e.batch,
            t_slots: e.t_slots,
            backend: Backend::Xla(Box::new(e)),
            batches: 0,
            rank_pad: Vec::new(),
        })
    }

    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Xla(_) => "xla",
            Backend::Native => "native",
        }
    }

    /// Analyze one (possibly zero-padded) batch: `a` row-major
    /// `[batch × t_slots]`, `t` `[batch]`.
    pub fn analyze(&mut self, a: &[f32], t: &[f32]) -> Result<AnalyzeOut> {
        self.batches += 1;
        match &mut self.backend {
            Backend::Xla(e) => e.analyze(a, t),
            Backend::Native => Ok(native_analyze(a, t, self.t_slots)),
        }
    }

    /// Top-K over call-path scores (padded/truncated to the artifact's P).
    pub fn rank(&mut self, scores: &[f32], k: usize) -> Result<Vec<(usize, f32)>> {
        match &mut self.backend {
            Backend::Xla(e) => {
                self.rank_pad.clear();
                self.rank_pad.resize(RANK_P, 0.0);
                let n = scores.len().min(RANK_P);
                self.rank_pad[..n].copy_from_slice(&scores[..n]);
                let mut out = e.rank(&self.rank_pad)?;
                out.truncate(k.min(RANK_K));
                // Drop zero-padded winners beyond the real entries.
                out.retain(|(i, v)| *i < scores.len() && *v > 0.0);
                Ok(out)
            }
            Backend::Native => Ok(native_rank(scores, k)),
        }
    }
}

/// Native twin of the Layer-1/2 analysis (same contract as model.analyze).
pub fn native_analyze(a: &[f32], t: &[f32], t_slots: usize) -> AnalyzeOut {
    let b = t.len();
    debug_assert_eq!(a.len(), b * t_slots);
    let mut cm = vec![0f32; t_slots];
    let mut wall = vec![0f32; t_slots];
    let mut gcm = 0f32;
    for i in 0..b {
        let row = &a[i * t_slots..(i + 1) * t_slots];
        let n: f32 = row.iter().sum();
        if n <= 0.0 {
            continue;
        }
        let c = t[i] / n.max(1.0);
        gcm += c;
        for (j, aij) in row.iter().enumerate() {
            if *aij > 0.0 {
                cm[j] += c;
                wall[j] += t[i];
            }
        }
    }
    let threads_av = cm
        .iter()
        .zip(&wall)
        .map(|(c, w)| if *c > 0.0 { w / c.max(1e-30) } else { 0.0 })
        .collect();
    AnalyzeOut {
        cm,
        wall,
        threads_av,
        global_cm: gcm,
    }
}

/// Native top-K: descending, stable on ties, zero scores excluded.
pub fn native_rank(scores: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|x, y| {
        scores[*y]
            .partial_cmp(&scores[*x])
            .unwrap()
            .then(x.cmp(y))
    });
    idx.into_iter()
        .take(k)
        .filter(|i| scores[*i] > 0.0)
        .map(|i| (i, scores[i]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn random_batch(seed: u64, b: usize, t_slots: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Prng::new(seed);
        let a: Vec<f32> = (0..b * t_slots)
            .map(|_| if rng.chance(0.08) { 1.0 } else { 0.0 })
            .collect();
        let t: Vec<f32> = (0..b).map(|_| rng.exp(1e6) as f32).collect();
        (a, t)
    }

    #[test]
    fn native_conservation() {
        let (a, t) = random_batch(3, 256, 64);
        let out = native_analyze(&a, &t, 64);
        let busy: f32 = (0..256)
            .filter(|i| a[i * 64..(i + 1) * 64].iter().sum::<f32>() > 0.0)
            .map(|i| t[i])
            .sum();
        let total_cm: f32 = out.cm.iter().sum();
        assert!((total_cm - busy).abs() / busy.max(1.0) < 1e-3);
    }

    #[test]
    fn native_threads_av_bounds() {
        let (a, t) = random_batch(5, 128, 32);
        let out = native_analyze(&a, &t, 32);
        for (j, tav) in out.threads_av.iter().enumerate() {
            if out.cm[j] > 0.0 {
                assert!(*tav >= 1.0 - 1e-4 && *tav <= 32.0 + 1e-4);
            } else {
                assert_eq!(*tav, 0.0);
            }
        }
    }

    #[test]
    fn native_rank_ordering() {
        let scores = vec![3.0, 0.0, 9.0, 9.0, 1.0];
        let r = native_rank(&scores, 4);
        assert_eq!(r[0].0, 2); // stable tie: first index wins
        assert_eq!(r[1].0, 3);
        assert_eq!(r[2].0, 0);
        assert_eq!(r[3].0, 4); // zero excluded entirely
        assert_eq!(r.len(), 4);
    }

    #[test]
    fn engine_native_analyze_works() {
        let mut e = AnalysisEngine::native();
        let b = e.batch;
        let ts = e.t_slots;
        let (a, t) = random_batch(7, b, ts);
        let out = e.analyze(&a, &t).unwrap();
        assert_eq!(out.cm.len(), ts);
        assert!(out.global_cm > 0.0);
    }
}
