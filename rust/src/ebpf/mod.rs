//! eBPF-like tracing substrate.
//!
//! The real GAPP is a set of eBPF programs attached to kernel tracepoints,
//! communicating with a bcc user-space process through eBPF *maps* and a
//! circular (perf) buffer. This module reproduces those mechanisms so the
//! profiler layer above is written against the same primitives the paper
//! describes (Table 1, Figure 2):
//!
//! * [`maps`] — hash/array/scalar maps with global and per-CPU flavours and
//!   byte-accounting (the paper's memory column M).
//! * [`ringbuf`] — the bounded circular buffers kernel probes write and
//!   the user-space probe drains; overflow drops records, as perf
//!   buffers do. [`ShardedRing`] is the per-CPU `PERF_EVENT_ARRAY`
//!   flavour: one FIFO per CPU, globally re-ordered at read time by the
//!   records' capture timestamps.
//! * [`stackmap`] — the `BPF_MAP_TYPE_STACK_TRACE` analogue: probes intern
//!   walked stacks to dense `u32` ids at capture time so ring records stay
//!   fixed-size POD; user space resolves ids only at report time.
//! * [`verifier`] — a verifier-lite enforcing the static resource bounds
//!   eBPF would (map counts/sizes, stack-capture depth and stack-map
//!   capacity, sampling period).
//!
//! Probe *cost* is not modeled here — it is charged by the simulated
//! kernel when probes return their handler cost (see
//! `simkernel::tracepoint::cost`).

pub mod maps;
pub mod ringbuf;
pub mod stackmap;
pub mod verifier;

pub use maps::{HashMap64, PerCpuScalar, Scalar};
pub use ringbuf::{EpochDelta, RingBuf, RingBufStats, RingCursor, ShardedRing, Stamped};
pub use stackmap::{EvictPolicy, StackMap, StackMapStats, STACK_ID_DROPPED};
pub use verifier::{ProgramSpec, Verifier, VerifierError};
