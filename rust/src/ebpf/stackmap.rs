//! Stack-trace interning map — the analogue of `BPF_MAP_TYPE_STACK_TRACE`.
//!
//! The real GAPP never ships raw stacks through the perf buffer: the
//! `sched_switch` probe calls `bpf_get_stackid()`, which walks the
//! stack, hashes the frames and stores them in a bounded kernel map,
//! returning a small integer id. Ring-buffer records then carry the id
//! (4 bytes) instead of up to 127 frames, and user space resolves ids
//! back to frames only when a call path actually reaches the report.
//! That interning is a big part of the paper's ~4% overhead claim.
//!
//! This map reproduces the mechanism: frames are stored once in a flat
//! arena, an FxHash bucket index (hash → chain of candidate ids) gives
//! O(1) expected lookup with exact frame comparison, and capacity is
//! bounded — once `max_entries` distinct stacks exist, further *new*
//! stacks are dropped and counted (the `bpf_get_stackid` failure mode a
//! deployment tunes `max_entries` against), while known stacks keep
//! resolving. Ids are dense (0, 1, 2, …) in first-capture order, so the
//! user-space merge can group by id with a dense table.

use crate::util::fxhash::{hash_words, FxHashMap};

/// Sentinel id returned when the map is full and the stack is new
/// (mirrors `bpf_get_stackid()` returning `-ENOMEM`). Resolves to an
/// empty frame slice.
pub const STACK_ID_DROPPED: u32 = u32::MAX;

const NO_NEXT: u32 = u32::MAX;

/// Hit/insert/drop counters for one stack map.
#[derive(Clone, Copy, Debug, Default)]
pub struct StackMapStats {
    /// Lookups that found an existing id.
    pub hits: u64,
    /// New stacks interned.
    pub inserts: u64,
    /// New stacks dropped because the map was full.
    pub drops: u64,
}

/// Bounded stack-trace interner: `&[u64]` frames → dense `u32` id.
#[derive(Debug)]
pub struct StackMap {
    name: &'static str,
    max_entries: usize,
    /// Flat frame arena; spans index into it.
    frames: Vec<u64>,
    /// id → (offset, len) into `frames`.
    spans: Vec<(u32, u32)>,
    /// id → next id in the same hash bucket (NO_NEXT terminates).
    chain: Vec<u32>,
    /// frame-hash → chain head id.
    heads: FxHashMap<u64, u32>,
    pub stats: StackMapStats,
}

impl StackMap {
    pub fn new(name: &'static str, max_entries: usize) -> StackMap {
        StackMap {
            name,
            max_entries,
            frames: Vec::new(),
            spans: Vec::new(),
            chain: Vec::new(),
            heads: FxHashMap::default(),
            stats: StackMapStats::default(),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Intern a stack, returning its id — an existing id when the exact
    /// frame sequence was seen before, a fresh dense id otherwise, or
    /// [`STACK_ID_DROPPED`] when the map is at capacity. The steady-state
    /// path (known stack) performs no allocation.
    pub fn intern(&mut self, stack: &[u64]) -> u32 {
        let h = hash_words(stack);
        let mut cur = self.heads.get(&h).copied();
        while let Some(id) = cur {
            if self.frames_of(id) == stack {
                self.stats.hits += 1;
                return id;
            }
            let next = self.chain[id as usize];
            cur = if next == NO_NEXT { None } else { Some(next) };
        }
        if self.spans.len() >= self.max_entries || self.frames.len() > u32::MAX as usize
        {
            self.stats.drops += 1;
            return STACK_ID_DROPPED;
        }
        let id = self.spans.len() as u32;
        let offset = self.frames.len() as u32;
        self.frames.extend_from_slice(stack);
        self.spans.push((offset, stack.len() as u32));
        // Link into the bucket chain (new entry becomes the head).
        let prev_head = self.heads.insert(h, id).unwrap_or(NO_NEXT);
        self.chain.push(prev_head);
        self.stats.inserts += 1;
        id
    }

    /// Resolve an id back to its frames; unknown or dropped ids resolve
    /// to the empty slice.
    #[inline]
    pub fn resolve(&self, id: u32) -> &[u64] {
        match self.spans.get(id as usize) {
            Some(&(off, len)) => &self.frames[off as usize..(off + len) as usize],
            None => &[],
        }
    }

    fn frames_of(&self, id: u32) -> &[u64] {
        let (off, len) = self.spans[id as usize];
        &self.frames[off as usize..(off + len) as usize]
    }

    /// Number of distinct stacks interned.
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.max_entries
    }

    /// Current storage footprint: arena + spans + chain + bucket index
    /// (≈32 B of `HashMap` overhead per bucket entry).
    pub fn bytes(&self) -> u64 {
        (self.frames.len() * 8 + self.spans.len() * 8 + self.chain.len() * 4) as u64
            + (self.heads.len() as u64) * 32
    }

    /// Static admission estimate for the verifier: what a fully-loaded
    /// map of `entries` stacks at capture depth `depth` would occupy.
    pub fn bytes_for(entries: usize, depth: usize) -> u64 {
        (entries as u64) * (depth as u64 * 8 + 44)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interns_and_resolves() {
        let mut m = StackMap::new("stacks", 16);
        let a = m.intern(&[0x100, 0x200, 0x300]);
        let b = m.intern(&[0x100, 0x200, 0x300]);
        let c = m.intern(&[0x100, 0x200]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(m.resolve(a), &[0x100, 0x200, 0x300]);
        assert_eq!(m.resolve(c), &[0x100, 0x200]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.stats.hits, 1);
        assert_eq!(m.stats.inserts, 2);
        assert_eq!(m.stats.drops, 0);
    }

    #[test]
    fn ids_are_dense_in_first_capture_order() {
        let mut m = StackMap::new("stacks", 16);
        for i in 0..5u64 {
            assert_eq!(m.intern(&[i]), i as u32);
        }
    }

    #[test]
    fn empty_stack_is_a_valid_entry() {
        let mut m = StackMap::new("stacks", 4);
        let id = m.intern(&[]);
        assert_eq!(m.resolve(id), &[] as &[u64]);
        assert_eq!(m.intern(&[]), id);
    }

    #[test]
    fn capacity_drops_new_stacks_but_keeps_old_ones() {
        let mut m = StackMap::new("stacks", 2);
        let a = m.intern(&[1]);
        let b = m.intern(&[2]);
        let d = m.intern(&[3]); // full → dropped
        assert_eq!(d, STACK_ID_DROPPED);
        assert_eq!(m.stats.drops, 1);
        // Known stacks still hit.
        assert_eq!(m.intern(&[1]), a);
        assert_eq!(m.intern(&[2]), b);
        // The sentinel resolves to nothing.
        assert_eq!(m.resolve(STACK_ID_DROPPED), &[] as &[u64]);
    }

    #[test]
    fn colliding_bucket_chains_stay_exact() {
        // Force many entries through; exactness must hold regardless of
        // how FxHash buckets them.
        let mut m = StackMap::new("stacks", 4096);
        let mut ids = Vec::new();
        for i in 0..1000u64 {
            ids.push(m.intern(&[i, i ^ 0xABCD, i.wrapping_mul(31)]));
        }
        for (i, id) in ids.iter().enumerate() {
            let i = i as u64;
            assert_eq!(m.resolve(*id), &[i, i ^ 0xABCD, i.wrapping_mul(31)]);
        }
        assert!(m.bytes() > 0);
        assert!(StackMap::bytes_for(1000, 3) >= 1000 * 24);
    }
}
